//! Large-scale integral histograms on multiple devices (§4.6, Fig. 18).
//!
//! A 128-bin HD frame's tensor (≈450 MB at f32) stresses single-device
//! memory in the paper's setting; the coordinator splits the bins into
//! 8-bin group tasks on a queue and a pool of PJRT workers pulls them —
//! the same code path the paper uses to push 32 GB tensors through four
//! GTX 480s.  This example sweeps the worker count and verifies the
//! assembled tensor against the single-device result.
//!
//! ```sh
//! make artifacts && cargo run --release --example multi_gpu_large_image
//! ```

use anyhow::{anyhow, Result};
use inthist::coordinator::task_queue::{BinTaskQueue, TaskQueueConfig};
use inthist::prelude::*;
use inthist::video::synth::SyntheticVideo;
use std::sync::Arc;

const SIZE: usize = 512;
const BINS: usize = 128;
const GROUP: usize = 8;

fn main() -> Result<()> {
    let manifest = Arc::new(ArtifactManifest::load("artifacts")?);
    let artifact = format!("wf_tis_{SIZE}x{SIZE}_b{GROUP}_t64");
    manifest
        .find_named(&artifact)
        .ok_or_else(|| anyhow!("missing {artifact} — run `make artifacts`"))?;

    let video = SyntheticVideo::new(SIZE, SIZE, 4, 7);
    let image = Arc::new(video.frame(0).binned(BINS));
    println!(
        "== {SIZE}x{SIZE} frame, {BINS} bins in {} tasks of {GROUP} ({} MB tensor) ==\n",
        BINS / GROUP,
        BINS * SIZE * SIZE * 4 / 1_000_000
    );

    println!("{:<8} {:>10} {:>12} {:>12} {:>20}", "workers", "wall s", "fr/sec", "efficiency", "tasks per worker");
    let mut reference: Option<IntegralHistogram> = None;
    let mut fps_by_workers = Vec::new();
    for workers in [1usize, 2, 4] {
        let queue = BinTaskQueue::new(
            Arc::clone(&manifest),
            TaskQueueConfig { workers, group: GROUP, artifact: artifact.clone() },
        )?;
        // warm-up compiles each worker's executor outside the timing
        let _ = queue.compute_discard(&image, BINS)?;
        let (ih, report) = queue.compute(&image, BINS)?;
        println!(
            "{workers:<8} {:>10.3} {:>12.3} {:>11.0}% {:>20}",
            report.wall.as_secs_f64(),
            report.fps(),
            report.efficiency(workers) * 100.0,
            format!("{:?}", report.per_worker)
        );
        fps_by_workers.push(report.fps());
        match &reference {
            None => reference = Some(ih),
            Some(r) => assert_eq!(
                r.max_abs_diff(&ih),
                0.0,
                "worker counts must not change the result"
            ),
        }
        queue.shutdown();
    }

    // Correctness: the assembled 128-bin tensor equals Algorithm 1.
    let cpu = inthist::histogram::parallel::integral_histogram_parallel(&image, 8);
    assert_eq!(reference.unwrap().max_abs_diff(&cpu), 0.0, "pool result must match Algorithm 1");
    println!("\nassembled tensor verified against CPU Algorithm 1");
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!(
        "scaling 1→4 workers: {:.2}x on {cores} host core(s) \
         (paper: near-linear on 4 physical GPUs; on a single-core host the \
         pool demonstrates the queueing/distribution mechanism, not wall-clock \
         scaling — see EXPERIMENTS.md Fig. 16/17 notes)",
        fps_by_workers[2] / fps_by_workers[0]
    );
    println!("multi-device large-image OK");
    Ok(())
}
