//! Large-scale integral histograms across a worker pool (§4.6,
//! Fig. 18), on the sharded execution subsystem.
//!
//! A 128-bin 512×512 frame's tensor (≈134 MB at f32) is partitioned by
//! the `ShardPlanner` into bin-range shards, streamed through a
//! `ShardExecutor` worker set (the multi-GPU substitute), and
//! reassembled from `(frame_id, shard_id)`-tagged results — the same
//! structure the paper uses to push 32 GB tensors through four
//! GTX 480s.  The example sweeps the worker count, verifies the
//! assembled tensor against the CPU baseline, and prints the
//! planner's *predicted* per-shard cost (PCIe + memory-bandwidth
//! models for the paper's GTX 480) next to the *measured* CPU-substrate
//! kernel time, so the Fig. 18 schedule arithmetic is visible.
//!
//! ```sh
//! cargo run --release --example multi_gpu_large_image
//! ```

use anyhow::Result;
use inthist::prelude::*;
use inthist::simulator::pcie::Card;
use inthist::video::synth::SyntheticVideo;
use std::sync::Arc;

const SIZE: usize = 512;
const BINS: usize = 128;
const GROUP: usize = 8;

fn main() -> Result<()> {
    let video = SyntheticVideo::new(SIZE, SIZE, 4, 7);
    let image = Arc::new(video.frame(0).binned(BINS));
    let policy = ShardPolicy {
        memory_budget: 1 << 30,
        workers: 4,
        max_group: GROUP,
        ..ShardPolicy::default()
    };
    let plan = ShardPlanner::new(policy).plan(BINS, SIZE, SIZE);
    println!(
        "== {SIZE}x{SIZE} frame, {BINS} bins in {} shards (group {}, strip rows {}) — {} MB tensor ==\n",
        plan.shards.len(),
        plan.group,
        plan.strip_rows,
        plan.tensor_nbytes() / 1_000_000
    );

    println!("{:<8} {:>10} {:>12} {:>12} {:>20}", "workers", "wall s", "fr/sec", "efficiency", "shards per worker");
    let mut reference: Option<IntegralHistogram> = None;
    let mut fps_by_workers = Vec::new();
    let mut last_report: Option<ShardReport> = None;
    for workers in [1usize, 2, 4] {
        let exec = ShardExecutor::new(ShardExecutorConfig { workers, ..Default::default() });
        // warm-up spawns the checkout engines outside the timing
        let mut out = IntegralHistogram::zeros(0, 0, 0);
        let _ = exec.submit(&image, &plan)?.reassemble_into(&mut out)?;
        let report = exec.submit(&image, &plan)?.reassemble_into(&mut out)?;
        println!(
            "{workers:<8} {:>10.3} {:>12.3} {:>11.0}% {:>20}",
            report.wall.as_secs_f64(),
            report.fps(),
            report.efficiency(workers) * 100.0,
            format!("{:?}", report.per_worker)
        );
        fps_by_workers.push(report.fps());
        match &reference {
            None => reference = Some(out.clone()),
            Some(r) => assert_eq!(
                r.max_abs_diff(&out),
                0.0,
                "worker counts must not change the result"
            ),
        }
        last_report = Some(report);
    }

    // Correctness: the assembled 128-bin tensor equals Algorithm 1.
    let cpu = inthist::histogram::parallel::integral_histogram_parallel(&image, 8);
    assert_eq!(reference.unwrap().max_abs_diff(&cpu), 0.0, "sharded result must match Algorithm 1");
    println!("\nassembled tensor verified against CPU Algorithm 1");

    // Predicted (paper's GTX 480 models) vs measured (CPU substrate)
    // per-shard cost — the Fig. 18 schedule arithmetic side by side.
    let card = Card::Gtx480;
    let predicted = plan.predict(card);
    let report = last_report.expect("at least one run");
    println!("\npredicted per-shard cost ({}) vs measured CPU kernel time:", card.name());
    println!(
        "{:<8} {:>6} {:>6} {:>16} {:>16} {:>16}",
        "shard", "bins", "rows", "pred kernel ms", "pred transfer ms", "measured ms"
    );
    for spec in plan.shards.iter().take(4) {
        let p = predicted[spec.shard_id];
        println!(
            "{:<8} {:>6} {:>6} {:>16.3} {:>16.3} {:>16.3}",
            spec.shard_id,
            spec.nbins,
            spec.nrows,
            p.kernel.as_secs_f64() * 1e3,
            p.transfer.as_secs_f64() * 1e3,
            report.kernel_by_shard[spec.shard_id].as_secs_f64() * 1e3
        );
    }
    if plan.shards.len() > 4 {
        println!("…        ({} more shards)", plan.shards.len() - 4);
    }
    let total = plan.predict_total(card, 4);
    println!(
        "\nplan totals on 4x {}: predicted wall {:.3} s (kernel {:.3} s, transfer {:.3} s) — \
         the paper's Fig. 18 regime; measured CPU-substrate wall above demonstrates the \
         same queueing/distribution mechanism.",
        card.name(),
        total.wall.as_secs_f64(),
        total.serial_kernel.as_secs_f64(),
        total.serial_transfer.as_secs_f64()
    );
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!(
        "scaling 1→4 workers: {:.2}x on {cores} host core(s) \
         (paper: near-linear on 4 physical GPUs; on a single-core host the \
         pool demonstrates the queueing/distribution mechanism, not wall-clock \
         scaling — see EXPERIMENTS.md Fig. 16/17 notes)",
        fps_by_workers[2] / fps_by_workers[0]
    );
    // --- closing the predicted-vs-measured loop (DESIGN.md §9) ---
    // The paper-prior prediction above describes a GTX 480, not this
    // host, so its per-shard numbers are off by construction.  A
    // calibrated run — startup microbench, then live shard timings fed
    // back through the executor's instruments — must shrink the gap.
    let cal = Arc::new(Calibrator::default());
    cal.calibrate();
    let cal_exec = ShardExecutor::with_instruments(
        ShardExecutorConfig { workers: 4, ..Default::default() },
        None,
        Some(Arc::clone(&cal)),
    );
    let mut out = IntegralHistogram::zeros(0, 0, 0);
    // Warm-up feeds the first round of live measurements into the EWMA.
    let _ = cal_exec.submit(&image, &plan)?.reassemble_into(&mut out)?;
    let cal_report = cal_exec.submit(&image, &plan)?.reassemble_into(&mut out)?;
    assert_eq!(cpu.max_abs_diff(&out), 0.0, "calibrated run must stay bit-identical");
    let gap = |pred: &[ShardCost]| -> f64 {
        let mut sum = 0.0;
        for s in &plan.shards {
            let p = pred[s.shard_id].kernel.as_secs_f64();
            let m = cal_report.kernel_by_shard[s.shard_id].as_secs_f64().max(1e-9);
            sum += (p - m).abs() / m;
        }
        sum / plan.shards.len() as f64
    };
    let gap_prior = gap(&plan.predict(card));
    let gap_cal = gap(&plan.predict_with(&cal.snapshot()));
    println!(
        "\npredicted-vs-measured per-shard kernel gap (mean |pred-meas|/meas): \
         paper prior {:.1}% -> calibrated {:.1}% ({} live samples)",
        100.0 * gap_prior,
        100.0 * gap_cal,
        cal.snapshot().samples
    );
    assert!(
        gap_cal <= gap_prior,
        "calibration must not widen the predicted-vs-measured gap \
         (prior {gap_prior:.3}, calibrated {gap_cal:.3})"
    );
    println!("multi-device large-image OK");
    Ok(())
}
