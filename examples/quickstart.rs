//! Quickstart: compute an integral histogram through the AOT/PJRT path
//! and answer region queries in constant time.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use anyhow::Result;
use inthist::histogram::region::Rect;
use inthist::histogram::sequential::integral_histogram_seq;
use inthist::histogram::types::Strategy;
use inthist::prelude::*;
use inthist::video::synth::SyntheticVideo;

fn main() -> Result<()> {
    // 1. Load the artifact manifest and build the engine (WF-TiS, 32 bins).
    let mut engine = Engine::from_artifact_dir("artifacts")?;

    // 2. Grab a frame of synthetic video (512×512 grayscale).
    let video = SyntheticVideo::new(512, 512, 4, 7);
    let frame = video.frame(0);

    // 3. Compute the 32-bin integral histogram on the PJRT device.
    let (ih, kernel) = engine.compute_frame_timed(&frame)?;
    println!(
        "computed {}x{}x{} tensor ({:.1} MB) in {:.2} ms ({})",
        ih.bins,
        ih.h,
        ih.w,
        ih.nbytes() as f64 / 1e6,
        kernel.as_secs_f64() * 1e3,
        engine.config().strategy,
    );

    // 4. Histogram of ANY rectangle is now four lookups per bin (Eq. 2).
    let rect = Rect::with_size(100, 100, 128, 128);
    let hist = ih.region(rect);
    println!("\nhistogram of {rect:?} (mass {}):", hist.iter().sum::<f32>());
    let max_bin = hist.iter().enumerate().max_by(|a, b| a.1.total_cmp(b.1)).unwrap().0;
    for (b, v) in hist.iter().enumerate() {
        if *v > 0.0 {
            let bar = "#".repeat((v / hist[max_bin] * 40.0) as usize);
            println!("  bin {b:>2}: {v:>8} {bar}");
        }
    }

    // 5. Cross-check against the CPU reference implementation (Alg. 1).
    let cpu = integral_histogram_seq(&frame.binned(32));
    let diff = cpu.max_abs_diff(&ih);
    println!("\nmax |GPU - CPU| over the full tensor: {diff}");
    assert_eq!(diff, 0.0, "PJRT result must match Algorithm 1 exactly");

    // 6. Other strategies produce the identical tensor (Algorithms 2-5).
    for s in [Strategy::CwSts, Strategy::CwTis] {
        let (alt, t) = engine.compute_timed(s, &frame.binned(32))?;
        println!("{s}: identical={} kernel={:.2} ms", alt == ih, t.as_secs_f64() * 1e3);
    }

    println!("\nquickstart OK");
    Ok(())
}
