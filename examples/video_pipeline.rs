//! End-to-end driver: real-time video analytics over a frame stream.
//!
//! This is the system-level validation run recorded in EXPERIMENTS.md:
//! 100 frames of 512×512 synthetic video stream through the full stack —
//! source → quantization → dual-buffered pipeline (Algorithm 6) →
//! AOT WF-TiS kernel on PJRT → simulated PCIe D2H → motion detector +
//! region-query batcher consuming the tensors — and the run reports
//! frame rate, latency, stage pressure and the dual-buffering speedup
//! against the serial (lanes = 1) baseline.
//!
//! ```sh
//! make artifacts && cargo run --release --example video_pipeline
//! ```

use anyhow::{anyhow, Result};
use inthist::analytics::motion::MotionDetector;
use inthist::coordinator::batcher::QueryBatcher;
use inthist::coordinator::pipeline::{Pipeline, PipelineConfig, TransferModel};
use inthist::histogram::region::Rect;
use inthist::histogram::types::Strategy;
use inthist::prelude::*;
use inthist::simulator::pcie::{Card, PcieModel};
use inthist::video::synth::SyntheticVideo;
use std::sync::Arc;

const FRAMES: usize = 100;
const SIZE: usize = 512;
const BINS: usize = 32;

fn main() -> Result<()> {
    let manifest = Arc::new(ArtifactManifest::load("artifacts")?);
    let meta = manifest
        .find_strategy(Strategy::WfTis, SIZE, SIZE, BINS)
        .ok_or_else(|| anyhow!("no wf_tis {SIZE}x{SIZE} b{BINS} artifact — run `make artifacts`"))?
        .clone();
    let model = PcieModel::for_card(Card::TitanX);

    println!("== end-to-end: {FRAMES} frames of {SIZE}x{SIZE}, {BINS} bins, WF-TiS ==\n");

    let mut results = Vec::new();
    for lanes in [1usize, 2] {
        // Downstream consumers: block-motion detector + query batcher.
        let mut motion = MotionDetector::new(8, 0.05);
        let mut batcher = QueryBatcher::new();
        let mut active_total = 0usize;
        let mut consumed = 0usize;

        let cfg = PipelineConfig::new(meta.name.clone(), BINS)
            .lanes(lanes)
            .transfer(TransferModel::Simulated { model, scale: 1.0 });
        let src = Box::new(SyntheticVideo::new(SIZE, SIZE, 4, 7).take_frames(FRAMES));
        let report = Pipeline::new(Arc::clone(&manifest), cfg).run_with(src, |seq, ih| {
            // per-frame analytics on the streamed-out tensor
            let map = motion.step(&ih);
            active_total += map.active_blocks().len();
            batcher.submit(seq as u64, Rect::with_size(64, 64, 128, 128));
            batcher.submit(seq as u64 | 1 << 32, Rect::with_size(256, 256, 128, 128));
            let responses = batcher.flush(&ih);
            consumed += responses.len();
        })?;

        let t = &report.throughput;
        println!("--- lanes = {lanes} ---");
        println!("frames            : {}", t.frames);
        println!("wall time         : {:.3} s", t.wall.as_secs_f64());
        println!("frame rate        : {:.2} fr/sec", t.fps());
        println!("mean latency      : {:.1} ms", t.mean_latency().as_secs_f64() * 1e3);
        let lat = t.latency_summary();
        println!(
            "latency tail      : p50 {:.1} | p95 {:.1} | p99 {:.1} ms, jitter {:.2} ms",
            lat.p50_ms, lat.p95_ms, lat.p99_ms, lat.jitter_ms
        );
        println!(
            "stage totals (ms) : read {:.0} | h2d {:.0} | kernel {:.0} | d2h {:.0}",
            t.stage_total(|s| s.read).as_secs_f64() * 1e3,
            t.stage_total(|s| s.h2d).as_secs_f64() * 1e3,
            t.stage_total(|s| s.kernel).as_secs_f64() * 1e3,
            t.stage_total(|s| s.d2h).as_secs_f64() * 1e3
        );
        println!("overlap speedup   : {:.2}x vs serial estimate", t.overlap_speedup());
        println!("queue high-water  : {:?}", report.queue_high_water);
        println!("motion blocks     : {active_total} activations over the run");
        println!("region queries    : {consumed} answered\n");
        assert_eq!(t.frames, FRAMES, "every frame must be processed");
        assert_eq!(consumed, 2 * FRAMES, "two queries per frame");
        results.push((lanes, t.fps()));
    }

    let speedup = results[1].1 / results[0].1;
    println!("dual-buffering frame-rate gain (lanes 2 vs 1): {speedup:.2}x");
    println!("e2e driver OK");
    Ok(())
}
