//! Histogram-based object tracking on the integral-histogram service —
//! the vision workload the paper's introduction motivates (ref [13]).
//!
//! A synthetic video contains moving bright blobs with known ground
//! truth.  Per frame, the engine computes the integral histogram via the
//! AOT WF-TiS kernel; trackers then run an exhaustive window search
//! around their last position, each candidate scored with an O(bins)
//! Eq. 2 lookup.  Reports per-object tracking error and throughput.
//!
//! ```sh
//! make artifacts && cargo run --release --example object_tracking
//! ```

use anyhow::Result;
use inthist::analytics::tracker::{center_distance, Track, TrackerConfig};
use inthist::prelude::*;
use inthist::video::synth::SyntheticVideo;
use std::time::Instant;

const SIZE: usize = 256;
const FRAMES: usize = 40;
const N_BLOBS: usize = 3;

fn main() -> Result<()> {
    let mut engine = Engine::from_artifact_dir("artifacts")?;
    let video = SyntheticVideo::new(SIZE, SIZE, N_BLOBS, 11);

    // Initialize one track per blob from the first frame's tensor.
    let first = video.frame(0);
    let (ih0, _) = engine.compute_frame_timed(&first)?;
    let cfg = TrackerConfig { radius: 8, stride: 1, adapt: 0.05 };
    let mut tracks: Vec<Track> = (0..N_BLOBS)
        .map(|i| Track::init(&ih0, video.blob_rect(i, 0), cfg))
        .collect();

    println!("tracking {N_BLOBS} objects over {FRAMES} frames of {SIZE}x{SIZE} video");
    println!(
        "search: {} candidate windows/object/frame, each O(bins) via Eq. 2\n",
        tracks[0].candidates_per_step()
    );

    let mut err_sum = vec![0.0f64; N_BLOBS];
    let mut kernel_ms = 0.0f64;
    let t0 = Instant::now();
    for t in 1..FRAMES {
        let frame = video.frame(t);
        let (ih, k) = engine.compute_frame_timed(&frame)?;
        kernel_ms += k.as_secs_f64() * 1e3;
        for (i, track) in tracks.iter_mut().enumerate() {
            let predicted = track.step(&ih);
            let truth = video.blob_rect(i, t);
            err_sum[i] += center_distance(predicted, truth);
        }
    }
    let wall = t0.elapsed();

    println!("{:<8} {:>14} {:>10}", "object", "mean err (px)", "final score");
    let mut ok = 0;
    for (i, track) in tracks.iter().enumerate() {
        let mean_err = err_sum[i] / (FRAMES - 1) as f64;
        println!("{i:<8} {mean_err:>14.2} {:>10.3}", track.score);
        // blobs move ≤ ~2.8 px/frame within an 8-px search radius: a
        // working tracker stays within a few pixels of ground truth
        if mean_err < 8.0 {
            ok += 1;
        }
    }
    println!("\nframes/sec (incl. tracking): {:.2}", (FRAMES - 1) as f64 / wall.as_secs_f64());
    println!("mean kernel time           : {:.2} ms", kernel_ms / (FRAMES - 1) as f64);
    assert!(ok >= N_BLOBS - 1, "at least {} of {N_BLOBS} tracks must hold", N_BLOBS - 1);
    println!("object tracking OK ({ok}/{N_BLOBS} tracks held)");
    Ok(())
}
