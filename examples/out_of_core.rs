//! Out-of-core integral histograms: a 128-bin frame whose `b×h×w`
//! tensor exceeds the host memory budget, served end-to-end through
//! the sharded subsystem (§4.6 / Fig. 18 on a bounded-memory host).
//!
//! The server refuses to assemble the tensor in RAM, the shard planner
//! splits it into bin-range/row-strip shards sized to the budget, the
//! executor streams them through its worker set, and the reassembled
//! planes land in a spill-backed `TensorStore` that answers Eq. 2
//! region queries with four 4-byte reads per bin — the full tensor is
//! never resident.
//!
//! ```sh
//! cargo run --release --example out_of_core
//! ```

use anyhow::Result;
use inthist::histogram::region::region_histogram;
use inthist::prelude::*;
use inthist::video::synth::SyntheticVideo;
use std::path::PathBuf;
use std::sync::Arc;

const SIZE: usize = 512;
const BINS: usize = 128;
const BUDGET: usize = 8 << 20; // 8 MiB host budget

fn main() -> Result<()> {
    let tensor = BINS * SIZE * SIZE * 4;
    println!(
        "== {SIZE}x{SIZE} frame, {BINS} bins: {:.0} MB tensor under an {:.0} MB budget ==\n",
        tensor as f64 / 1e6,
        BUDGET as f64 / 1e6
    );

    // An offline manifest is enough: the shard route runs on the CPU
    // engine substrate.
    let dir = PathBuf::from("artifacts");
    let manifest = Arc::new(ArtifactManifest::load(&dir).unwrap_or(ArtifactManifest {
        dir,
        profile: "offline".into(),
        artifacts: vec![],
    }));
    let mut cfg = ServerConfig::default();
    cfg.engine.bins = BINS;
    cfg.engine.device_memory_budget = 1 << 20; // everything is "large" here
    cfg.engine.cpu_fallback_budget = BUDGET; // no whole-frame CPU escape hatch
    cfg.host_memory_budget = BUDGET;
    cfg.shard_workers = 4;
    let server = Server::new(manifest, cfg);

    let video = SyntheticVideo::new(SIZE, SIZE, 4, 7);
    let frame = video.frame(0);

    // The in-RAM route must refuse — that is the point of the budget.
    let img = frame.binned(BINS);
    match server.compute(&img) {
        Err(e) => println!("in-RAM route refused as expected:\n  {e}\n"),
        Ok(_) => anyhow::bail!("a {tensor}-byte tensor must not assemble in RAM"),
    }

    // The spilled route completes inside the budget.
    let mut session = server.open_session()?;
    let (store, report) = session.process_spilled(&frame)?;
    println!(
        "spilled compute: {} shards in {:.2} s ({:.2} fr/sec), tasks per worker {:?}",
        report.shards,
        report.wall.as_secs_f64(),
        report.fps(),
        report.per_worker
    );
    println!(
        "peak resident {:.2} MB of a {:.0} MB tensor ({:.1}%), within budget: {}",
        report.peak_resident_bytes as f64 / 1e6,
        tensor as f64 / 1e6,
        100.0 * report.peak_resident_bytes as f64 / tensor as f64,
        report.peak_resident_bytes <= BUDGET
    );
    assert!(
        report.peak_resident_bytes <= BUDGET,
        "peak resident {} exceeded the {BUDGET} B budget",
        report.peak_resident_bytes
    );
    println!("spill file: {} ({:.0} MB on disk)\n", store.path().display(), store.nbytes() as f64 / 1e6);

    // Region queries straight from the spilled planes, verified
    // against the in-RAM path on a downsized reference region.
    let rects = [
        Rect::with_size(0, 0, SIZE, SIZE),
        Rect::with_size(SIZE / 4, SIZE / 4, SIZE / 2, SIZE / 2),
        Rect::with_size(10, 500, 33, 9),
    ];
    let reference = inthist::histogram::sequential::integral_histogram_seq(&img);
    for rect in rects {
        let spilled = store.query(rect)?;
        let in_ram = region_histogram(&reference, rect);
        assert_eq!(spilled, in_ram, "spilled query deviates at {rect:?}");
        let mass: f32 = spilled.iter().sum();
        println!(
            "query {:>3}x{:<3} at ({:>3},{:<3}): mass {:>9.0}  (bit-identical to in-RAM)",
            rect.height(),
            rect.width(),
            rect.r0,
            rect.c0,
            mass
        );
    }
    println!("\nout-of-core OK: full tensor never resident, queries exact");
    Ok(())
}
