//! Integration tests over the coordinator: dual-buffered pipeline,
//! bin task queue, and the engine front door.

use inthist::coordinator::pipeline::{Pipeline, PipelineConfig, TransferModel};
use inthist::coordinator::router::{Engine, EngineConfig};
use inthist::coordinator::task_queue::{BinTaskQueue, TaskQueueConfig};
use inthist::histogram::sequential::integral_histogram_seq;
use inthist::histogram::types::Strategy;
use inthist::runtime::artifact::ArtifactManifest;
use inthist::simulator::pcie::{Card, PcieModel};
use inthist::video::synth::SyntheticVideo;
use std::sync::{Arc, Mutex};

fn manifest() -> Option<Arc<ArtifactManifest>> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match ArtifactManifest::load(&dir) {
        Ok(m) => Some(Arc::new(m)),
        Err(_) => {
            eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
            None
        }
    }
}

const ART_128: &str = "wf_tis_128x128_b32_t64";

#[test]
fn pipeline_processes_every_frame_in_order() {
    let Some(m) = manifest() else { return };
    if m.find_named(ART_128).is_none() {
        return;
    }
    let frames = 8;
    let cfg = PipelineConfig::new(ART_128, 32).lanes(2);
    let src = Box::new(SyntheticVideo::new(128, 128, 2, 1).take_frames(frames));
    let seen = Mutex::new(Vec::new());
    let report = Pipeline::new(m, cfg)
        .run_with(src, |seq, ih| {
            assert_eq!((ih.bins, ih.h, ih.w), (32, 128, 128));
            seen.lock().unwrap().push(seq);
        })
        .expect("pipeline run");
    assert_eq!(report.throughput.frames, frames);
    // dual-buffered stages preserve order (single channel per stage)
    assert_eq!(*seen.lock().unwrap(), (0..frames).collect::<Vec<_>>());
    let stats = &report.throughput.stats;
    assert_eq!(stats.len(), frames);
    assert!(stats.iter().all(|s| s.kernel.as_nanos() > 0), "kernel times recorded");
}

#[test]
fn pipeline_results_match_algorithm1() {
    let Some(m) = manifest() else { return };
    if m.find_named(ART_128).is_none() {
        return;
    }
    let video = SyntheticVideo::new(128, 128, 3, 5);
    let cfg = PipelineConfig::new(ART_128, 32).lanes(2);
    let src = Box::new(SyntheticVideo::new(128, 128, 3, 5).take_frames(3));
    let ok = Mutex::new(0usize);
    Pipeline::new(m, cfg)
        .run_with(src, |seq, ih| {
            let expected = integral_histogram_seq(&video.frame(seq).binned(32));
            assert_eq!(expected.max_abs_diff(&ih), 0.0, "frame {seq}");
            *ok.lock().unwrap() += 1;
        })
        .expect("pipeline run");
    assert_eq!(*ok.lock().unwrap(), 3);
}

#[test]
fn serial_and_dual_agree() {
    let Some(m) = manifest() else { return };
    if m.find_named(ART_128).is_none() {
        return;
    }
    for lanes in [1usize, 3] {
        let cfg = PipelineConfig::new(ART_128, 32).lanes(lanes);
        let src = Box::new(SyntheticVideo::new(128, 128, 2, 9).take_frames(4));
        let report = Pipeline::new(Arc::clone(&m), cfg).run(src).unwrap();
        assert_eq!(report.throughput.frames, 4, "lanes={lanes}");
        assert_eq!(report.lanes, lanes);
    }
}

#[test]
fn dual_buffering_overlaps_simulated_transfers() {
    let Some(m) = manifest() else { return };
    if m.find_named(ART_128).is_none() {
        return;
    }
    // Scale transfers up so they rival the kernel: overlap must beat serial.
    let model = PcieModel::for_card(Card::Gtx480);
    let transfer = TransferModel::Simulated { model, scale: 20.0 };
    let mut fps = Vec::new();
    for lanes in [1usize, 2] {
        let cfg = PipelineConfig::new(ART_128, 32).lanes(lanes).transfer(transfer);
        let src = Box::new(SyntheticVideo::new(128, 128, 2, 1).take_frames(10));
        let report = Pipeline::new(Arc::clone(&m), cfg).run(src).unwrap();
        fps.push(report.fps());
    }
    assert!(
        fps[1] > fps[0] * 1.2,
        "dual-buffering should clearly beat serial when transfer ≈ kernel \
         (serial {:.2} fps, dual {:.2} fps)",
        fps[0],
        fps[1]
    );
}

#[test]
fn task_queue_matches_direct_execution() {
    let Some(m) = manifest() else { return };
    let artifact = "wf_tis_512x512_b8_t64";
    if m.find_named(artifact).is_none() {
        return;
    }
    let video = SyntheticVideo::new(512, 512, 4, 7);
    let image = Arc::new(video.frame(0).binned(32));
    let queue = BinTaskQueue::new(
        Arc::clone(&m),
        TaskQueueConfig { workers: 2, group: 8, artifact: artifact.into(), cpu_fallback: false },
    )
    .expect("queue");
    let (ih, report) = queue.compute(&image, 32).expect("grouped compute");
    queue.shutdown();
    assert_eq!(report.tasks, 4);
    assert_eq!(report.per_worker.iter().sum::<usize>(), 4);
    let expected = integral_histogram_seq(&image);
    assert_eq!(expected.max_abs_diff(&ih), 0.0, "grouped result deviates");
}

#[test]
fn task_queue_rejects_mismatched_group() {
    let Some(m) = manifest() else { return };
    let artifact = "wf_tis_512x512_b8_t64";
    if m.find_named(artifact).is_none() {
        return;
    }
    assert!(BinTaskQueue::new(
        Arc::clone(&m),
        TaskQueueConfig { workers: 1, group: 16, artifact: artifact.into(), cpu_fallback: false },
    )
    .is_err());
    let queue = BinTaskQueue::new(
        Arc::clone(&m),
        TaskQueueConfig { workers: 1, group: 8, artifact: artifact.into(), cpu_fallback: false },
    )
    .unwrap();
    let img = Arc::new(SyntheticVideo::new(512, 512, 1, 0).frame(0).binned(12));
    assert!(queue.compute(&img, 12).is_err(), "12 bins not divisible by 8");
    queue.shutdown();
}

#[test]
fn engine_serves_frames_and_queries() {
    let Some(m) = manifest() else { return };
    if m.find_strategy(Strategy::WfTis, 512, 512, 32).is_none() {
        return;
    }
    let mut engine = Engine::new(Arc::clone(&m), EngineConfig::default());
    let video = SyntheticVideo::new(512, 512, 4, 7);
    let frame = video.frame(0);
    let rects = vec![
        inthist::histogram::region::Rect::with_size(0, 0, 512, 512),
        inthist::histogram::region::Rect::with_size(17, 33, 90, 120),
    ];
    let (ih, hists) = engine.serve(&frame, &rects).expect("serve");
    assert_eq!(hists.len(), 2);
    let expected = integral_histogram_seq(&frame.binned(32));
    assert_eq!(expected.max_abs_diff(&ih), 0.0);
    for (i, &r) in rects.iter().enumerate() {
        let cpu = inthist::histogram::region::region_histogram(&expected, r);
        assert_eq!(hists[i], cpu, "engine query {i}");
    }
    assert!(engine.cached_executors() >= 1);
}

#[test]
fn engine_reuses_cached_executors() {
    let Some(m) = manifest() else { return };
    if m.find_strategy(Strategy::WfTis, 128, 128, 32).is_none() {
        return;
    }
    let mut cfg = EngineConfig::default();
    cfg.bins = 32;
    let mut engine = Engine::new(Arc::clone(&m), cfg);
    let img = SyntheticVideo::new(128, 128, 2, 2).frame(0).binned(32);
    engine.compute(Strategy::WfTis, &img).unwrap();
    let n = engine.cached_executors();
    engine.compute(Strategy::WfTis, &img).unwrap();
    assert_eq!(engine.cached_executors(), n, "second call must reuse the executor");
}
