//! Chaos property tests (build with `--features fault-injection`).
//!
//! Under a seeded fault schedule — worker panics, spurious compute
//! errors, slow workers, corrupted spill bytes — the system must keep
//! its contract: every submitted frame resolves to a BIT-IDENTICAL
//! tensor or a TYPED [`ShardError`] before its deadline; nothing hangs
//! (a watchdog aborts the process otherwise); no lock poisoning takes
//! the process down; and the injected-vs-recovered counters reconcile
//! exactly.  Each test also reaches the schedule's `max_per_site` cap
//! and proves trailing fault-free traffic is bit-identical — chaos
//! must not leave residue.
#![cfg(feature = "fault-injection")]

use inthist::fault::{FaultInjector, FaultSite, FaultSpec};
use inthist::histogram::sequential::integral_histogram_seq;
use inthist::histogram::types::{BinnedImage, IntegralHistogram};
use inthist::shard::{ShardError, ShardExecutor, ShardExecutorConfig, ShardPlanner, ShardPolicy};
use inthist::util::prng::Xoshiro256;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn random_image(h: usize, w: usize, bins: usize, seed: u64) -> Arc<BinnedImage> {
    let mut rng = Xoshiro256::new(seed);
    let mut data = vec![0i32; h * w];
    rng.fill_bins(&mut data, bins as u32);
    Arc::new(BinnedImage::new(h, w, bins, data))
}

fn policy(budget: usize, workers: usize) -> ShardPolicy {
    ShardPolicy { memory_budget: budget, workers, ..ShardPolicy::default() }
}

/// Hang detector: aborts the whole process if the owning test has not
/// disarmed it (by dropping it) before `timeout`.  "No hangs" is part
/// of the fault contract, so a hang must fail CI loudly instead of
/// waiting for the harness timeout.
struct Watchdog {
    done: Arc<AtomicBool>,
}

impl Watchdog {
    fn arm(label: &'static str, timeout: Duration) -> Watchdog {
        let done = Arc::new(AtomicBool::new(false));
        let flag = Arc::clone(&done);
        std::thread::spawn(move || {
            let t0 = Instant::now();
            while t0.elapsed() < timeout {
                if flag.load(Ordering::Acquire) {
                    return;
                }
                std::thread::sleep(Duration::from_millis(25));
            }
            eprintln!("watchdog: '{label}' exceeded {timeout:?} — aborting");
            std::process::abort();
        });
        Watchdog { done }
    }
}

impl Drop for Watchdog {
    fn drop(&mut self) {
        self.done.store(true, Ordering::Release);
    }
}

/// The core chaos property, over several seeds: with panics, spurious
/// errors and delays injected into shard compute, every frame is
/// bit-identical or fails typed before its deadline; the executor's
/// recovery counters reconcile exactly with what was injected; all
/// workers survive; and once the schedule caps out, trailing frames
/// are clean and bit-identical.
#[test]
fn chaos_frames_are_bit_identical_or_typed_errors() {
    let _wd = Watchdog::arm("chaos_frames", Duration::from_secs(120));
    let mut report = Vec::new();
    for seed in [1u64, 7, 42] {
        let spec = FaultSpec {
            shard_panic: 0.05,
            shard_error: 0.10,
            shard_delay: 0.02,
            delay: Duration::from_millis(1),
            max_per_site: 24,
            ..FaultSpec::default()
        };
        let fi = Arc::new(FaultInjector::new(seed, spec));
        let exec = ShardExecutor::with_faults(
            ShardExecutorConfig { workers: 3, max_attempts: 4, ..Default::default() },
            Arc::clone(&fi),
        );
        let plan = ShardPlanner::new(policy(10 << 10, 3)).plan(6, 40, 30);
        assert!(plan.shards.len() >= 4, "want real fan-out");

        let mut ok_frames = 0usize;
        let mut failed_frames = 0usize;
        let mut frame = 0u64;
        // Drive frames until the schedule caps out, then a few more.
        while fi.stats().injected[FaultSite::ShardCompute.index()] < spec.max_per_site {
            let img = random_image(40, 30, 6, 1000 + frame);
            let expected = integral_histogram_seq(&img);
            let ticket = exec.submit(&img, &plan).expect("submit");
            let mut out = IntegralHistogram::zeros(0, 0, 0);
            match ticket.reassemble_into_deadline(&mut out, Duration::from_secs(30)) {
                Ok(rep) => {
                    assert_eq!(
                        expected.max_abs_diff(&out),
                        0.0,
                        "seed {seed} frame {frame}: recovered frame must be bit-identical"
                    );
                    assert_eq!(rep.shards, plan.shards.len());
                    ok_frames += 1;
                }
                Err(e) => {
                    // Typed by construction; the variant must carry the
                    // right frame and be a compute-path failure (no
                    // deadline fired with 30 s of slack, workers live).
                    match &e {
                        ShardError::ComputeFailed { .. } | ShardError::ComputePanicked { .. } => {}
                        other => panic!("seed {seed} frame {frame}: unexpected error {other}"),
                    }
                    failed_frames += 1;
                }
            }
            frame += 1;
            assert!(frame < 500, "schedule should cap out long before 500 frames");
        }

        // Trailing clean traffic: the capped schedule injects nothing
        // more, and recovery left no residue.  (Fully reassembling
        // these frames also quiesces any attempt still in flight from
        // a failed frame's early ticket return, so the counter
        // reconciliation below compares settled values.)
        for t in 0..3u64 {
            let img = random_image(40, 30, 6, 9000 + t);
            let expected = integral_histogram_seq(&img);
            let ticket = exec.submit(&img, &plan).expect("submit");
            let mut out = IntegralHistogram::zeros(0, 0, 0);
            ticket
                .reassemble_into_deadline(&mut out, Duration::from_secs(30))
                .expect("clean trailing frame");
            assert_eq!(expected.max_abs_diff(&out), 0.0, "trailing frame {t}");
        }

        // Reconciliation: every injected panic/error was observed by
        // the supervisor as exactly one failed attempt, and nothing
        // else was.
        let st = fi.stats();
        let xs = exec.stats();
        assert_eq!(xs.attempt_failures, st.panics + st.errors, "seed {seed}");
        assert_eq!(xs.attempt_panics, st.panics, "seed {seed}");
        assert_eq!(xs.engines_discarded, st.panics, "every panicked engine discarded");
        assert_eq!(xs.workers_alive, 3, "workers survive injected panics");
        assert_eq!(xs.frames_failed, failed_frames, "seed {seed}");
        assert_eq!(xs.frames_abandoned, 0);
        assert!(ok_frames > 0, "seed {seed}: some frames must survive chaos");

        report.push(format!(
            "{{\"seed\":{seed},\"frames\":{},\"ok\":{ok_frames},\"failed\":{failed_frames},\
             \"injected_panics\":{},\"injected_errors\":{},\"injected_delays\":{},\
             \"shards_recovered\":{},\"workers_alive\":{}}}",
            frame + 3,
            st.panics,
            st.errors,
            st.delays,
            xs.shards_recovered,
            xs.workers_alive
        ));
    }
    // Machine-readable chaos report for the CI artifact upload.
    let json = format!("{{\"suite\":\"chaos_frames\",\"runs\":[{}]}}\n", report.join(","));
    let _ = std::fs::create_dir_all("target");
    let _ = std::fs::write("target/chaos_report.json", json);
}

/// Transient read-side spill corruption is healed by the
/// checksum-verify-reread path: the frame stays bit-identical and the
/// store counts the rereads, with zero verify failures.
#[test]
fn spill_read_corruption_recovers_bit_identical() {
    let _wd = Watchdog::arm("spill_read_corruption", Duration::from_secs(60));
    let spec = FaultSpec { spill_corrupt_read: 1.0, max_per_site: 3, ..FaultSpec::default() };
    let fi = Arc::new(FaultInjector::new(11, spec));
    let exec = ShardExecutor::with_faults(
        ShardExecutorConfig { workers: 2, ..Default::default() },
        Arc::clone(&fi),
    );
    let img = random_image(45, 21, 7, 8);
    let plan = ShardPlanner::new(policy(10 << 10, 2)).plan(7, 45, 21);
    let (store, _report) =
        exec.submit(&img, &plan).expect("submit").reassemble_spilled().expect("spill");
    let expected = integral_histogram_seq(&img);
    let back = store.to_histogram().expect("transient corruption must be healed by reread");
    assert_eq!(expected.max_abs_diff(&back), 0.0);
    assert!(store.verify_rereads() >= 1, "at least one reread must have fired");
    assert_eq!(store.verify_failures(), 0, "no persistent corruption");
    assert!(fi.stats().corrupt_reads >= 1);
}

/// Persistent write-side spill corruption (bad bytes reached disk) is
/// DETECTED, never served: reads of the damaged row fail typed with a
/// checksum mismatch after one reread.
#[test]
fn spill_write_corruption_fails_typed_not_silent() {
    let _wd = Watchdog::arm("spill_write_corruption", Duration::from_secs(60));
    let spec = FaultSpec { spill_corrupt_write: 1.0, max_per_site: 1, ..FaultSpec::default() };
    let fi = Arc::new(FaultInjector::new(13, spec));
    let exec = ShardExecutor::with_faults(
        ShardExecutorConfig { workers: 2, ..Default::default() },
        Arc::clone(&fi),
    );
    let img = random_image(45, 21, 7, 8);
    let plan = ShardPlanner::new(policy(10 << 10, 2)).plan(7, 45, 21);
    let (store, _report) =
        exec.submit(&img, &plan).expect("submit").reassemble_spilled().expect("spill completes");
    assert_eq!(fi.stats().corrupt_writes, 1, "exactly one write corrupted");
    let err = store
        .to_histogram()
        .err()
        .expect("persistently corrupt plane must not materialize")
        .to_string();
    assert!(err.contains("checksum mismatch"), "{err}");
    assert_eq!(store.verify_failures(), 1);
}

/// A torn (short) spill write — the prefix a power cut leaves behind —
/// is DETECTED on read, never served: the missing tail fails the row
/// checksum typed, and the injector counted exactly one short write
/// (partitioned from the corrupt-write draw, which stays at zero).
#[test]
fn spill_short_write_fails_typed_not_silent() {
    let _wd = Watchdog::arm("spill_short_write", Duration::from_secs(60));
    let spec = FaultSpec { spill_short_write: 1.0, max_per_site: 1, ..FaultSpec::default() };
    let fi = Arc::new(FaultInjector::new(17, spec));
    let exec = ShardExecutor::with_faults(
        ShardExecutorConfig { workers: 2, ..Default::default() },
        Arc::clone(&fi),
    );
    let img = random_image(45, 21, 7, 8);
    let plan = ShardPlanner::new(policy(10 << 10, 2)).plan(7, 45, 21);
    let (store, _report) =
        exec.submit(&img, &plan).expect("submit").reassemble_spilled().expect("spill completes");
    let st = fi.stats();
    assert_eq!((st.short_writes, st.corrupt_writes), (1, 0), "one torn write, no byte flips");
    let err = store
        .to_histogram()
        .err()
        .expect("a torn plane must not materialize")
        .to_string();
    assert!(err.contains("checksum mismatch"), "{err}");
    assert!(store.verify_failures() >= 1);
}

/// The artifact load path's `SpillRead` probe at the integration
/// level: under a corrupt-read schedule the manifest load either fails
/// typed or visibly differs from the clean parse — and once the
/// schedule caps out, loads are clean again (no residue).
#[test]
fn artifact_load_corruption_is_never_served_silently() {
    use inthist::runtime::artifact::ArtifactManifest;

    let _wd = Watchdog::arm("artifact_load_corruption", Duration::from_secs(60));
    let dir = std::env::temp_dir().join(format!("ih_chaos_artifact_{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("temp dir");
    let manifest_text = r#"{
      "profile": "chaos",
      "artifacts": [
        {"name": "wf_tis_32x32_b8_t16", "kind": "strategy", "strategy": "wf_tis",
         "height": 32, "width": 32, "padded_h": 32, "padded_w": 32,
         "bins": 8, "tile": 16, "n_rects": 0, "file": "wf_tis_32x32_b8_t16.hlo.txt",
         "inputs": [{"name": "image", "dtype": "i32", "shape": [32, 32]}],
         "outputs": [{"name": "ih", "dtype": "f32", "shape": [8, 32, 32]}]}
      ]
    }"#;
    std::fs::write(dir.join("manifest.json"), manifest_text).expect("write manifest");
    let clean = ArtifactManifest::load(&dir).expect("clean load");

    let spec = FaultSpec { spill_corrupt_read: 1.0, max_per_site: 2, ..FaultSpec::default() };
    let fi = FaultInjector::new(19, spec);
    for round in 0..2 {
        match ArtifactManifest::load_with_faults(&dir, Some(&fi)) {
            Err(_) => {} // typed rejection
            Ok(m) => assert!(
                m.profile != clean.profile || m.artifacts != clean.artifacts,
                "round {round}: corrupted manifest must not come back clean"
            ),
        }
    }
    assert_eq!(fi.stats().corrupt_reads, 2);
    // Schedule capped: trailing loads are clean, parse equals clean.
    let after = ArtifactManifest::load_with_faults(&dir, Some(&fi)).expect("clean after cap");
    assert_eq!(after.profile, clean.profile);
    assert_eq!(after.artifacts, clean.artifacts);
    std::fs::remove_dir_all(&dir).ok();
}

/// Interleaving independence: the multiset of injected faults depends
/// only on (seed, site, occurrence index), not on which threads hit
/// the probes — four racing threads and one serial run inject the
/// same counts.
#[test]
fn schedule_is_interleaving_independent() {
    let spec = FaultSpec {
        shard_panic: 0.1,
        shard_error: 0.2,
        shard_delay: 0.05,
        delay: Duration::ZERO,
        ..FaultSpec::default()
    };
    let serial = FaultInjector::new(77, spec);
    for _ in 0..400 {
        let _ = serial.decide(FaultSite::ShardCompute);
    }
    let racy = Arc::new(FaultInjector::new(77, spec));
    std::thread::scope(|s| {
        for _ in 0..4 {
            let fi = Arc::clone(&racy);
            s.spawn(move || {
                for _ in 0..100 {
                    let _ = fi.decide(FaultSite::ShardCompute);
                }
            });
        }
    });
    let a = serial.stats();
    let b = racy.stats();
    assert_eq!(a.occurrences, b.occurrences);
    assert_eq!(a.injected, b.injected);
    assert_eq!((a.panics, a.errors, a.delays), (b.panics, b.errors, b.delays));
}

/// The server stays a well-behaved supervisor under chaos: concurrent
/// sessions over faulty shard workers each get bit-identical tensors
/// or typed errors, the admission slots all come back, and the server
/// drains and shuts down within its timeout.
#[test]
fn server_survives_chaos_and_drains() {
    use inthist::coordinator::server::{Server, ServerConfig, ServerState};
    use inthist::runtime::artifact::ArtifactManifest;
    use inthist::video::synth::SyntheticVideo;
    use std::path::PathBuf;

    let _wd = Watchdog::arm("server_chaos", Duration::from_secs(120));
    let spec = FaultSpec {
        shard_panic: 0.04,
        shard_error: 0.08,
        shard_delay: 0.02,
        delay: Duration::from_millis(1),
        max_per_site: 16,
        ..FaultSpec::default()
    };
    let fi = Arc::new(FaultInjector::new(21, spec));
    let mut cfg = ServerConfig::default();
    cfg.engine.bins = 8;
    cfg.engine.device_memory_budget = 1 << 10; // 40×40 frames route sharded
    cfg.shard_workers = 3;
    cfg.shard_max_attempts = 4;
    cfg.frame_deadline = Some(Duration::from_secs(30));
    cfg.faults = Some(Arc::clone(&fi));
    let manifest = Arc::new(ArtifactManifest {
        dir: PathBuf::from("/nonexistent"),
        profile: "chaos".into(),
        artifacts: vec![],
    });
    let srv = Server::new(manifest, cfg);

    std::thread::scope(|s| {
        for t in 0..3u64 {
            let srv = srv.clone();
            s.spawn(move || {
                let mut session = srv.open_session().expect("admission");
                let video = SyntheticVideo::new(40, 40, 2, 3 + t);
                for f in 0..8usize {
                    let frame = video.frame(f);
                    let expected = integral_histogram_seq(&frame.binned(8));
                    match session.process(&frame) {
                        Ok(ih) => {
                            assert_eq!(
                                expected.max_abs_diff(&ih),
                                0.0,
                                "thread {t} frame {f}: must be bit-identical"
                            );
                        }
                        Err(e) => {
                            // Typed shard failure surfaced through anyhow.
                            let msg = format!("{e:#}");
                            assert!(
                                msg.contains("shard") || msg.contains("frame"),
                                "thread {t} frame {f}: untyped error: {msg}"
                            );
                        }
                    }
                }
            });
        }
    });

    // Every admission slot returned; the executor kept its workers.
    assert_eq!(srv.sessions_active(), 0);
    let health = srv.health();
    assert_eq!(health.shard_workers_alive, health.shard_workers_total);
    assert_eq!(health.shard_frames_abandoned, 0);

    // Graceful end-of-life under chaos: drain, then shutdown, joined.
    assert!(srv.drain(Duration::from_secs(30)), "must drain inside the timeout");
    assert!(srv.shutdown(Duration::from_secs(30)));
    assert_eq!(srv.health().state, ServerState::Stopped);
}

/// The multi-process plane under a seeded abort schedule: when
/// `FaultSite::WorkerAbort` fires at dispatch, the supervisor SIGKILLs
/// the chosen child — a real `kill -9` mid-frame, the failure mode
/// `catch_unwind` cannot contain.  Every frame must still reassemble
/// bit-identical after the respawn or fail typed; the pool must be
/// back at full strength; trailing traffic after the schedule caps
/// must be clean.
#[test]
fn proc_worker_sigkills_are_survived_bit_identical() {
    use inthist::proc::{ProcPoolConfig, ProcSupervisor};
    use std::path::PathBuf;

    let _wd = Watchdog::arm("proc_worker_sigkills", Duration::from_secs(240));
    let spec = FaultSpec { worker_abort: 0.15, max_per_site: 6, ..FaultSpec::default() };
    let fi = Arc::new(FaultInjector::new(23, spec));
    let cfg = ProcPoolConfig {
        workers: 2,
        max_attempts: 6,
        worker_bin: Some(PathBuf::from(env!("CARGO_BIN_EXE_proc-worker"))),
        calibrate_children: false,
        ..Default::default()
    };
    let sup = ProcSupervisor::with_faults(cfg, Some(Arc::clone(&fi))).expect("spawn pool");
    let plan = ShardPlanner::new(policy(10 << 10, 3)).plan(6, 40, 30);
    assert!(plan.shards.len() >= 4, "want real fan-out");

    let mut ok_frames = 0usize;
    let mut failed_frames = 0usize;
    let mut frame = 0u64;
    while fi.stats().injected[FaultSite::WorkerAbort.index()] < spec.max_per_site {
        let img = random_image(40, 30, 6, 5000 + frame);
        let expected = integral_histogram_seq(&img);
        let ticket = sup.submit(&img, &plan).expect("submit");
        let mut out = IntegralHistogram::zeros(0, 0, 0);
        match ticket.reassemble_into_deadline(&mut out, Duration::from_secs(60)) {
            Ok(rep) => {
                assert_eq!(
                    expected.max_abs_diff(&out),
                    0.0,
                    "frame {frame}: bit-identity must survive SIGKILL + respawn"
                );
                assert_eq!(rep.shards, plan.shards.len());
                ok_frames += 1;
            }
            Err(e) => match &e {
                // Attempt exhaustion across repeated kills is a legal,
                // typed outcome; anything else is a bug.
                ShardError::ComputeFailed { .. } | ShardError::ComputePanicked { .. } => {
                    failed_frames += 1;
                }
                other => panic!("frame {frame}: unexpected error {other}"),
            },
        }
        frame += 1;
        assert!(frame < 400, "abort schedule should cap out quickly");
    }

    // Trailing clean traffic: the capped schedule kills no more
    // children, and recovery left no residue.
    for t in 0..2u64 {
        let img = random_image(40, 30, 6, 7000 + t);
        let expected = integral_histogram_seq(&img);
        let ticket = sup.submit(&img, &plan).expect("submit");
        let mut out = IntegralHistogram::zeros(0, 0, 0);
        ticket
            .reassemble_into_deadline(&mut out, Duration::from_secs(60))
            .expect("clean trailing frame");
        assert_eq!(expected.max_abs_diff(&out), 0.0, "trailing frame {t}");
    }

    let st = fi.stats();
    let ps = sup.stats();
    assert_eq!(st.worker_aborts, spec.max_per_site, "schedule capped exactly");
    assert!(ps.respawns >= 1, "kills must be survived by respawn: {ps:?}");
    assert_eq!(ps.workers_alive, 2, "pool back at full strength: {ps:?}");
    assert!(ok_frames >= 1, "some frames must survive the kills: {ps:?}");
    assert!(
        ok_frames + failed_frames == frame as usize,
        "every frame resolved exactly once: {ok_frames}+{failed_frames} != {frame}"
    );
}

/// What a deterministic chaos proxy does to the n-th supervisor
/// connection it carries.
#[derive(Clone, Copy, Debug)]
enum WireFault {
    /// Sever both directions after forwarding this many child→parent
    /// bytes — a connection drop mid-shard, and because the cut can
    /// land inside a frame, a half-written frame on the parent's
    /// reader.
    Cut(u64),
    /// XOR `len` child→parent bytes starting at stream offset `at`
    /// with 0xFF — checksum corruption (payload bytes) or framing
    /// garbage (header bytes) over the wire; both must surface typed.
    Garble { at: u64, len: u64 },
    /// Forward verbatim (the directive every connection past the
    /// schedule gets, so trailing traffic is provably clean).
    Clean,
}

/// A byte-level TCP chaos proxy between a remote supervisor and a
/// `proc-worker --listen` process: connection `n` gets `schedule[n]`,
/// connections past the schedule run clean.  Faults target the
/// child→parent direction — partial chunks, completions, heartbeats —
/// the direction whose loss or corruption the supervisor must turn
/// into reconnect + requeue, never a hang or a wrong tensor.
fn chaos_proxy(upstream: String, schedule: Vec<WireFault>) -> String {
    use std::net::{Shutdown, TcpListener, TcpStream};
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind proxy");
    let addr = listener.local_addr().expect("proxy addr").to_string();
    std::thread::spawn(move || {
        let mut conn = 0usize;
        loop {
            let Ok((client, _)) = listener.accept() else { continue };
            let fault = schedule.get(conn).copied().unwrap_or(WireFault::Clean);
            conn += 1;
            let Ok(up) = TcpStream::connect(&upstream) else {
                let _ = client.shutdown(Shutdown::Both);
                continue;
            };
            client.set_nodelay(true).ok();
            up.set_nodelay(true).ok();
            // Parent→child: verbatim.
            let (c_rd, u_wr) = (client.try_clone().expect("clone"), up.try_clone().expect("clone"));
            std::thread::spawn(move || {
                let _ = std::io::copy(&mut &c_rd, &mut &u_wr);
                let _ = u_wr.shutdown(Shutdown::Both);
            });
            // Child→parent: the faulted direction.
            std::thread::spawn(move || {
                let mut buf = [0u8; 4096];
                let mut pos: u64 = 0;
                loop {
                    let n = match std::io::Read::read(&mut &up, &mut buf) {
                        Ok(0) | Err(_) => break,
                        Ok(n) => n,
                    };
                    let mut end = n;
                    match fault {
                        WireFault::Clean => {}
                        WireFault::Garble { at, len } => {
                            for i in 0..n as u64 {
                                if pos + i >= at && pos + i < at + len {
                                    buf[i as usize] ^= 0xFF;
                                }
                            }
                        }
                        WireFault::Cut(limit) => {
                            if pos >= limit {
                                break;
                            }
                            end = end.min((limit - pos) as usize);
                        }
                    }
                    if std::io::Write::write_all(&mut &client, &buf[..end]).is_err() {
                        break;
                    }
                    pos += end as u64;
                    if matches!(fault, WireFault::Cut(limit) if pos >= limit) {
                        break;
                    }
                }
                let _ = client.shutdown(Shutdown::Both);
                let _ = up.shutdown(Shutdown::Both);
            });
        }
    });
    addr
}

/// Remote-node chaos over loopback TCP: a seeded wire-fault schedule —
/// connection drops mid-shard, half-written frames, a reconnect storm
/// of consecutive cuts, and checksum corruption over the wire — and
/// every frame must still reassemble bit-identical or fail typed; the
/// supervisor must redial through every drop (counter-asserted) and
/// trailing clean traffic must carry no residue.
#[test]
fn remote_wire_chaos_keeps_frames_bit_identical_or_typed() {
    use inthist::proc::{ProcPoolConfig, ProcSupervisor};
    use std::path::PathBuf;

    let _wd = Watchdog::arm("remote_wire_chaos", Duration::from_secs(240));
    // The listening worker (the "remote host").
    let mut worker = std::process::Command::new(env!("CARGO_BIN_EXE_proc-worker"))
        .args(["--listen", "127.0.0.1:0", "--calibrate", "0"])
        .stdout(std::process::Stdio::piped())
        .stderr(std::process::Stdio::null())
        .spawn()
        .expect("spawn listening proc-worker");
    let mut line = String::new();
    std::io::BufRead::read_line(
        &mut std::io::BufReader::new(worker.stdout.take().expect("stdout")),
        &mut line,
    )
    .expect("LISTEN line");
    let upstream = line.trim().strip_prefix("LISTEN ").expect("LISTEN prefix").to_string();
    // All offsets are safely past the ~40-byte Hello handshake, so
    // every reconnect attempt itself succeeds and the fault lands on
    // shard traffic: one mid-shard cut, one wire corruption, then a
    // storm of two quick cuts back-to-back, then clean forever.
    let proxy = chaos_proxy(
        upstream,
        vec![
            WireFault::Cut(1400),
            WireFault::Garble { at: 600, len: 64 },
            WireFault::Cut(900),
            WireFault::Cut(700),
        ],
    );
    let sup = ProcSupervisor::new(ProcPoolConfig {
        workers: 0,
        max_attempts: 8,
        remote_workers: vec![proxy],
        worker_bin: Some(PathBuf::from(env!("CARGO_BIN_EXE_proc-worker"))),
        calibrate_children: false,
        ..Default::default()
    })
    .expect("connect through chaos proxy");
    let plan = ShardPlanner::new(policy(10 << 10, 3)).plan(6, 40, 30);
    assert!(plan.shards.len() >= 4, "want real fan-out");

    let (mut ok_frames, mut failed_frames) = (0usize, 0usize);
    for frame in 0..10u64 {
        let img = random_image(40, 30, 6, 6000 + frame);
        let expected = integral_histogram_seq(&img);
        let ticket = sup.submit(&img, &plan).expect("submit");
        let mut out = IntegralHistogram::zeros(0, 0, 0);
        match ticket.reassemble_into_deadline(&mut out, Duration::from_secs(60)) {
            Ok(_) => {
                assert_eq!(
                    expected.max_abs_diff(&out),
                    0.0,
                    "frame {frame}: bit-identity must survive wire chaos"
                );
                ok_frames += 1;
            }
            Err(e) => match &e {
                ShardError::ComputeFailed { .. } | ShardError::ComputePanicked { .. } => {
                    failed_frames += 1;
                }
                other => panic!("frame {frame}: unexpected error {other}"),
            },
        }
    }
    // Trailing clean traffic: the schedule is exhausted, connections
    // run verbatim, and recovery left no residue.
    for t in 0..2u64 {
        let img = random_image(40, 30, 6, 8000 + t);
        let expected = integral_histogram_seq(&img);
        let ticket = sup.submit(&img, &plan).expect("submit");
        let mut out = IntegralHistogram::zeros(0, 0, 0);
        ticket
            .reassemble_into_deadline(&mut out, Duration::from_secs(60))
            .expect("clean trailing frame");
        assert_eq!(expected.max_abs_diff(&out), 0.0, "trailing frame {t}");
    }

    let ps = sup.stats();
    assert!(
        ps.remote_reconnects >= 2,
        "the cut schedule must have forced redials: {ps:?}"
    );
    assert_eq!(ps.workers_alive, 1, "the remote node ends alive: {ps:?}");
    assert!(ps.stream_dispatched >= plan.shards.len(), "{ps:?}");
    assert!(ok_frames >= 1, "some frames must survive wire chaos: {ps:?}");
    assert_eq!(ok_frames + failed_frames, 10, "every frame resolved exactly once");
    let _ = worker.kill();
    let _ = worker.wait();
}
