//! Property-based randomized tests over the coordinator-level
//! invariants: every CPU implementation agrees with Algorithm 1 across
//! random shapes/bins/tiles/threads, Eq. 2 equals brute-force counting,
//! the binning partition is exact, and the task-queue bin-shift trick
//! is a bijection.  (The offline build has no proptest; the sweep
//! driver below plays the same role with an explicit seeded PRNG so
//! failures print a reproducible case.)

use inthist::histogram::binning::{bin_range, quantize_frame, quantize_u8};
use inthist::histogram::parallel::{integral_histogram_crossweave, integral_histogram_parallel};
use inthist::histogram::region::{region_histogram, Rect};
use inthist::histogram::sequential::{
    integral_histogram_seq, integral_histogram_seq_imagemajor, integral_histogram_seq_rowsum,
};
use inthist::histogram::tiled::{integral_histogram_tiled, integral_histogram_tiled_twopass};
use inthist::histogram::types::BinnedImage;
use inthist::util::prng::Xoshiro256;

fn random_image(rng: &mut Xoshiro256, h: usize, w: usize, bins: usize) -> BinnedImage {
    let mut data = vec![0i32; h * w];
    rng.fill_bins(&mut data, bins as u32);
    BinnedImage::new(h, w, bins, data)
}

/// Run `cases` random cases, printing the failing case before panicking.
fn forall(seed: u64, cases: usize, f: impl Fn(&mut Xoshiro256, usize)) {
    let mut rng = Xoshiro256::new(seed);
    for case in 0..cases {
        f(&mut rng, case);
    }
}

#[test]
fn all_cpu_impls_agree_property() {
    forall(0xA11CE, 25, |rng, case| {
        let h = rng.range(1, 70);
        let w = rng.range(1, 70);
        let bins = rng.range(1, 17);
        let tile = rng.range(1, 40);
        let threads = rng.range(1, 9);
        let img = random_image(rng, h, w, bins);
        let reference = integral_histogram_seq(&img);
        let ctx = format!("case {case}: h={h} w={w} bins={bins} tile={tile} threads={threads}");
        assert_eq!(reference.max_abs_diff(&integral_histogram_seq_rowsum(&img)), 0.0, "rowsum {ctx}");
        assert_eq!(
            reference.max_abs_diff(&integral_histogram_seq_imagemajor(&img)),
            0.0,
            "imagemajor {ctx}"
        );
        assert_eq!(
            reference.max_abs_diff(&integral_histogram_tiled(&img, tile)),
            0.0,
            "tiled {ctx}"
        );
        assert_eq!(
            reference.max_abs_diff(&integral_histogram_tiled_twopass(&img, tile)),
            0.0,
            "twopass {ctx}"
        );
        assert_eq!(
            reference.max_abs_diff(&integral_histogram_parallel(&img, threads)),
            0.0,
            "parallel {ctx}"
        );
        assert_eq!(
            reference.max_abs_diff(&integral_histogram_crossweave(&img, threads)),
            0.0,
            "crossweave {ctx}"
        );
    });
}

#[test]
fn region_equals_brute_force_property() {
    forall(0xB0B, 40, |rng, case| {
        let h = rng.range(1, 60);
        let w = rng.range(1, 60);
        let bins = rng.range(1, 9);
        let img = random_image(rng, h, w, bins);
        let ih = integral_histogram_seq(&img);
        let r0 = rng.range(0, h);
        let c0 = rng.range(0, w);
        let r1 = rng.range(r0, h);
        let c1 = rng.range(c0, w);
        let rect = Rect::new(r0, c0, r1, c1);
        let fast = region_histogram(&ih, rect);
        let mut slow = vec![0.0f32; bins];
        for r in r0..=r1 {
            for c in c0..=c1 {
                slow[img.at(r, c) as usize] += 1.0;
            }
        }
        assert_eq!(fast, slow, "case {case}: {rect:?} on {h}x{w}x{bins}");
        // mass equals area
        assert_eq!(fast.iter().sum::<f32>(), rect.area() as f32, "case {case} mass");
    });
}

#[test]
fn region_additivity_property() {
    // h(R) of a rect split into left|right halves must equal the sum of
    // the halves — the inclusion-exclusion consistency of Eq. 2.
    forall(0xADD, 30, |rng, case| {
        let h = rng.range(2, 50);
        let w = rng.range(2, 50);
        let img = random_image(rng, h, w, 8);
        let ih = integral_histogram_seq(&img);
        let r0 = rng.range(0, h - 1);
        let r1 = rng.range(r0, h);
        let c0 = rng.range(0, w - 1);
        let c1 = rng.range(c0 + 1, w);
        let split = rng.range(c0, c1);
        let whole = region_histogram(&ih, Rect::new(r0, c0, r1, c1));
        let left = region_histogram(&ih, Rect::new(r0, c0, r1, split));
        let right = region_histogram(&ih, Rect::new(r0, split + 1, r1, c1));
        for b in 0..8 {
            assert_eq!(whole[b], left[b] + right[b], "case {case} bin {b}");
        }
    });
}

#[test]
fn quantizer_is_monotone_partition_property() {
    for bins in [1usize, 2, 3, 16, 32, 100, 256] {
        let mut prev = 0i32;
        let mut counts = vec![0usize; bins];
        for v in 0u8..=255 {
            let b = quantize_u8(v, bins);
            assert!((0..bins as i32).contains(&b), "bins={bins} v={v} → {b}");
            assert!(b >= prev, "quantizer must be monotone (bins={bins}, v={v})");
            prev = b;
            counts[b as usize] += 1;
        }
        // every bin non-empty and widths balanced within 1 level
        let min = counts.iter().min().unwrap();
        let max = counts.iter().max().unwrap();
        assert!(*min >= 1, "bins={bins}: empty bin");
        assert!(max - min <= 1, "bins={bins}: unbalanced widths {counts:?}");
        // bin_range round-trips the partition boundaries
        for b in 0..bins {
            let (lo, hi) = bin_range(b, bins);
            assert_eq!(counts[b], hi as usize - lo as usize + 1, "bins={bins} b={b}");
        }
    }
}

#[test]
fn bin_shift_trick_is_exact_property() {
    // The device pool computes bins [off, off+g) by shifting image
    // values; verify the reassembled planes equal the direct planes.
    forall(0x5417, 10, |rng, case| {
        let h = rng.range(4, 40);
        let w = rng.range(4, 40);
        let total = 32usize;
        let group = 8usize;
        let img = random_image(rng, h, w, total);
        let direct = integral_histogram_seq(&img);
        for off in (0..total).step_by(group) {
            let shifted = BinnedImage {
                h,
                w,
                bins: group,
                data: img
                    .data
                    .iter()
                    .map(|&v| if v >= off as i32 { v - off as i32 } else { -1 })
                    .collect(),
            };
            let partial = integral_histogram_seq(&shifted);
            for b in 0..group {
                for (i, &v) in partial.plane(b).iter().enumerate() {
                    assert_eq!(
                        v,
                        direct.plane(off + b)[i],
                        "case {case} off={off} bin={b} idx={i}"
                    );
                }
            }
        }
    });
}

#[test]
fn quantize_frame_matches_scalar_property() {
    forall(0xF00D, 10, |rng, _| {
        let h = rng.range(1, 20);
        let w = rng.range(1, 20);
        let bins = rng.range(1, 64);
        let pixels: Vec<u8> = (0..h * w).map(|_| rng.range(0, 256) as u8).collect();
        let img = quantize_frame(&pixels, h, w, bins);
        for (i, &p) in pixels.iter().enumerate() {
            assert_eq!(img.data[i], quantize_u8(p, bins));
        }
    });
}
