//! Property tests of the multi-process execution plane against real
//! child processes.  Cargo builds the `proc-worker` bin for
//! integration tests and hands us its path via
//! `CARGO_BIN_EXE_proc-worker`, so everything here exercises true
//! process boundaries: spawn, pipes, spill files, SIGKILL, respawn.
//!
//! The contract under test is the executor's, verbatim: every
//! submitted frame either reassembles **bit-identical** to the
//! in-process result or resolves to exactly one typed `ShardError`.

use inthist::histogram::sequential::integral_histogram_seq;
use inthist::histogram::types::{BinnedImage, IntegralHistogram};
use inthist::proc::{plan_for_nodes, DataPlane, ProcPoolConfig, ProcSupervisor};
use inthist::shard::{ShardError, ShardExecutor, ShardExecutorConfig, ShardPlanner, ShardPolicy};
use inthist::video::synth::SyntheticVideo;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn worker_bin() -> PathBuf {
    PathBuf::from(env!("CARGO_BIN_EXE_proc-worker"))
}

fn pool_config(workers: usize) -> ProcPoolConfig {
    ProcPoolConfig {
        workers,
        worker_bin: Some(worker_bin()),
        calibrate_children: false, // prior snapshots: fast startup
        ..Default::default()
    }
}

/// A planner forced into several shards even at test-sized frames.
fn planner(workers: usize, budget: usize) -> ShardPlanner {
    ShardPlanner::new(ShardPolicy { workers, memory_budget: budget, ..Default::default() })
}

fn binned(h: usize, w: usize, bins: usize, seed: u64) -> BinnedImage {
    SyntheticVideo::new(h, w, 2, seed).frame(0).binned(bins)
}

/// Watchdog: a hung supervisor must fail the suite loudly, not stall
/// CI (same idiom as tests/fault_property.rs).
struct Watchdog {
    cancel: Arc<std::sync::atomic::AtomicBool>,
}

impl Watchdog {
    fn arm(limit: Duration, what: &'static str) -> Watchdog {
        let cancel = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let c = Arc::clone(&cancel);
        std::thread::spawn(move || {
            let t0 = Instant::now();
            while t0.elapsed() < limit {
                if c.load(std::sync::atomic::Ordering::Acquire) {
                    return;
                }
                std::thread::sleep(Duration::from_millis(50));
            }
            eprintln!("WATCHDOG: {what} exceeded {limit:?}; aborting");
            std::process::abort();
        });
        Watchdog { cancel }
    }
}

impl Drop for Watchdog {
    fn drop(&mut self) {
        self.cancel.store(true, std::sync::atomic::Ordering::Release);
    }
}

/// A `proc-worker --listen` child on loopback: the closest thing to a
/// remote node a single-machine test can have.  Binds port 0, parses
/// the `LISTEN <addr>` announcement, and kills the process on drop.
/// One listener can back any number of remote node slots — each
/// supervisor connection gets its own serve thread — which is also
/// how reconnect-after-drop works: the supervisor just dials again.
struct RemoteWorker {
    child: std::process::Child,
    addr: String,
}

impl RemoteWorker {
    fn spawn() -> RemoteWorker {
        let mut child = std::process::Command::new(worker_bin())
            .args(["--listen", "127.0.0.1:0", "--calibrate", "0"])
            .stdout(std::process::Stdio::piped())
            .stderr(std::process::Stdio::null())
            .spawn()
            .expect("spawn listening proc-worker");
        let stdout = child.stdout.take().expect("listener stdout");
        let mut line = String::new();
        std::io::BufRead::read_line(&mut std::io::BufReader::new(stdout), &mut line)
            .expect("read LISTEN line");
        let addr = line
            .trim()
            .strip_prefix("LISTEN ")
            .unwrap_or_else(|| panic!("expected LISTEN <addr>, got {line:?}"))
            .to_string();
        RemoteWorker { child, addr }
    }
}

impl Drop for RemoteWorker {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

/// Cross-process bit-identity on adversarial geometries: single-row
/// strips, single-column images, prime dimensions, bins ≫ rows — the
/// shapes where off-by-one strip/bin arithmetic dies.  Each frame is
/// computed by real child processes and must match both the serial
/// oracle and the in-process executor exactly.
#[test]
fn cross_process_results_are_bit_identical_on_adversarial_shapes() {
    let _wd = Watchdog::arm(Duration::from_secs(120), "cross-process bit-identity");
    let shapes: &[(usize, usize, usize)] = &[
        (33, 1, 7),   // single-column image
        (1, 64, 4),   // single-row image
        (61, 37, 13), // everything prime
        (16, 16, 32), // more bins than rows
        (96, 80, 8),  // bread-and-butter
    ];
    let sup = ProcSupervisor::new(pool_config(2)).expect("spawn pool");
    let exec = ShardExecutor::new(ShardExecutorConfig {
        workers: 2,
        engine_workers: 1,
        channel_depth: 0,
        max_attempts: 3,
    });
    for (i, &(h, w, bins)) in shapes.iter().enumerate() {
        let img = binned(h, w, bins, 40 + i as u64);
        let image = Arc::new(img.clone());
        // Budget small enough to force several shards per frame.
        let plan = planner(2, (bins * h * w * 4 / 3).max(4096)).plan(bins, h, w);
        let oracle = integral_histogram_seq(&img);

        let ticket = sup.submit(&image, &plan).expect("proc submit");
        let mut got = IntegralHistogram::zeros(bins, h, w);
        ticket.reassemble_into(&mut got).expect("proc reassembly");
        assert_eq!(oracle.max_abs_diff(&got), 0.0, "proc vs serial, shape {h}x{w}x{bins}");

        let ticket = exec.submit(&image, &plan).expect("in-process submit");
        let mut inproc = IntegralHistogram::zeros(bins, h, w);
        ticket.reassemble_into(&mut inproc).expect("in-process reassembly");
        assert_eq!(
            inproc.max_abs_diff(&got),
            0.0,
            "proc vs in-process executor, shape {h}x{w}x{bins}"
        );
    }
    let stats = sup.stats();
    assert_eq!(stats.shard_failures, 0, "{stats:?}");
    assert_eq!(stats.checksum_failures, 0, "{stats:?}");
    assert!(stats.completed >= shapes.len(), "{stats:?}");
}

/// The remote tentpole, happy path: a pure-remote pool (zero local
/// children, every node a loopback TCP socket to a `proc-worker
/// --listen` process) must produce frames bit-identical to the serial
/// oracle on the same adversarial shapes the pipe plane is tested on
/// — and every shard must have travelled the chunked stream data
/// plane (counter-asserted; remote nodes have no spill-file or shm
/// alternative).
#[test]
fn remote_loopback_pool_is_bit_identical_on_adversarial_shapes() {
    let _wd = Watchdog::arm(Duration::from_secs(120), "remote loopback bit-identity");
    let listener = RemoteWorker::spawn();
    // Two node slots over one listener process: each connection gets
    // its own serve loop, like two remote hosts would.
    let sup = ProcSupervisor::new(ProcPoolConfig {
        workers: 0,
        remote_workers: vec![listener.addr.clone(), listener.addr.clone()],
        ..pool_config(0)
    })
    .expect("connect remote pool");
    assert_eq!(sup.workers(), 2, "both remote slots are nodes");
    let shapes: &[(usize, usize, usize)] = &[
        (33, 1, 7),   // single-column image
        (1, 64, 4),   // single-row image
        (61, 37, 13), // everything prime
        (16, 16, 32), // more bins than rows
        (96, 80, 8),  // bread-and-butter
    ];
    let mut shards_total = 0;
    for (i, &(h, w, bins)) in shapes.iter().enumerate() {
        let img = binned(h, w, bins, 40 + i as u64);
        let image = Arc::new(img.clone());
        let plan = planner(2, (bins * h * w * 4 / 3).max(4096)).plan(bins, h, w);
        shards_total += plan.shards.len();
        let oracle = integral_histogram_seq(&img);
        let ticket = sup.submit(&image, &plan).expect("remote submit");
        let mut got = IntegralHistogram::zeros(bins, h, w);
        ticket.reassemble_into(&mut got).expect("remote reassembly");
        assert_eq!(oracle.max_abs_diff(&got), 0.0, "remote vs serial, shape {h}x{w}x{bins}");
    }
    let stats = sup.stats();
    assert_eq!(stats.remote_workers, 2, "{stats:?}");
    assert_eq!(stats.shard_failures, 0, "{stats:?}");
    assert_eq!(stats.checksum_failures, 0, "{stats:?}");
    assert!(
        stats.stream_dispatched >= shards_total,
        "every remote shard rides the stream plane (≥ {shards_total}): {stats:?}"
    );
    assert_eq!(stats.shm_dispatched, 0, "no ring on a pure-remote pool: {stats:?}");
}

/// A mixed fleet — one local pipe child beside one remote socket node
/// — serves frames bit-identically, with the remote node's shards on
/// the stream plane and the local node's on its native plane.
#[test]
fn mixed_local_and_remote_pool_is_bit_identical() {
    let _wd = Watchdog::arm(Duration::from_secs(120), "mixed local+remote pool");
    let listener = RemoteWorker::spawn();
    let sup = ProcSupervisor::new(ProcPoolConfig {
        remote_workers: vec![listener.addr.clone()],
        ..pool_config(1)
    })
    .expect("spawn mixed pool");
    assert_eq!(sup.workers(), 2);
    let (h, w, bins) = (72, 56, 16);
    for t in 0..4u64 {
        let img = Arc::new(binned(h, w, bins, 300 + t));
        let oracle = integral_histogram_seq(&binned(h, w, bins, 300 + t));
        let plan = planner(2, bins * h * w).plan(bins, h, w);
        let ticket = sup.submit(&img, &plan).expect("submit");
        let mut got = IntegralHistogram::zeros(bins, h, w);
        ticket.reassemble_into(&mut got).expect("mixed reassembly");
        assert_eq!(oracle.max_abs_diff(&got), 0.0, "frame {t} bit-identity on a mixed pool");
    }
    let stats = sup.stats();
    assert_eq!(stats.remote_workers, 1, "{stats:?}");
    assert!(stats.stream_dispatched >= 1, "the remote node must have carried work: {stats:?}");
    assert!(
        stats.dispatched > stats.stream_dispatched,
        "the local node must have carried work too: {stats:?}"
    );
    assert_eq!(stats.shard_failures, 0, "{stats:?}");
}

/// The remote reap→reconnect→requeue ladder: drop the socket to a
/// remote node mid-frame and the supervisor must reconnect to the
/// same listener, requeue the dead connection's in-flight shards, and
/// finish every frame bit-identical — the socket analog of the
/// SIGKILL respawn guarantee below.
#[test]
fn remote_disconnect_mid_frame_reconnects_and_completes() {
    let _wd = Watchdog::arm(Duration::from_secs(120), "remote disconnect reconnect");
    let listener = RemoteWorker::spawn();
    let sup = ProcSupervisor::new(ProcPoolConfig {
        workers: 0,
        remote_workers: vec![listener.addr.clone(), listener.addr.clone()],
        ..pool_config(0)
    })
    .expect("connect remote pool");
    let (h, w, bins) = (72, 56, 16);
    for t in 0..6u64 {
        let img = Arc::new(binned(h, w, bins, 900 + t));
        let oracle = integral_histogram_seq(&binned(h, w, bins, 900 + t));
        let plan = planner(2, bins * h * w).plan(bins, h, w);
        let ticket = sup.submit(&img, &plan).expect("submit");
        if t == 1 || t == 3 {
            // Mid-frame: stream chunks for this ticket are in flight.
            sup.kill_worker((t % 2) as usize).expect("drop connection");
        }
        let mut got = IntegralHistogram::zeros(bins, h, w);
        ticket.reassemble_into(&mut got).expect("frame must survive the disconnect");
        assert_eq!(oracle.max_abs_diff(&got), 0.0, "frame {t} bit-identity across a disconnect");
    }
    let stats = sup.stats();
    assert!(stats.remote_reconnects >= 1, "a dropped socket must be redialed: {stats:?}");
    assert_eq!(stats.workers_alive, 2, "pool back at full strength: {stats:?}");
    assert_eq!(stats.shard_failures, 0, "no frame may fail for a survivable drop: {stats:?}");
}

/// The headline guarantee: SIGKILL a child mid-frame and every
/// in-flight frame still completes bit-identical after the respawn.
#[test]
fn sigkilled_worker_is_respawned_and_frames_complete_bit_identical() {
    let _wd = Watchdog::arm(Duration::from_secs(120), "SIGKILL respawn");
    let sup = ProcSupervisor::new(pool_config(2)).expect("spawn pool");
    let (h, w, bins) = (72, 56, 16);
    let oracles: Vec<IntegralHistogram> = (0..6)
        .map(|t| integral_histogram_seq(&binned(h, w, bins, 900 + t)))
        .collect();
    for (t, oracle) in oracles.iter().enumerate() {
        let img = Arc::new(binned(h, w, bins, 900 + t as u64));
        let plan = planner(2, bins * h * w).plan(bins, h, w);
        let ticket = sup.submit(&img, &plan).expect("submit");
        if t == 1 || t == 3 {
            // Mid-frame: shards of this ticket are in flight right now.
            sup.kill_worker(t % 2).expect("kill hook");
        }
        let mut got = IntegralHistogram::zeros(bins, h, w);
        ticket.reassemble_into(&mut got).expect("frame must survive the kill");
        assert_eq!(oracle.max_abs_diff(&got), 0.0, "frame {t} bit-identity across a kill");
    }
    let stats = sup.stats();
    assert!(stats.respawns >= 1, "a killed child must be replaced: {stats:?}");
    assert_eq!(stats.workers_alive, 2, "pool back at full strength: {stats:?}");
    assert_eq!(stats.shard_failures, 0, "no frame may fail for a survivable kill: {stats:?}");
}

/// Deadline-aware scheduling on the proc plane: a frame submitted with
/// an already-blown deadline resolves typed without its shards ever
/// reaching a child.
#[test]
fn expired_deadline_is_dropped_before_dispatch() {
    let _wd = Watchdog::arm(Duration::from_secs(60), "proc deadline drop");
    let sup = ProcSupervisor::new(pool_config(1)).expect("spawn pool");
    let (h, w, bins) = (64, 48, 8);
    let img = Arc::new(binned(h, w, bins, 7));
    let plan = planner(1, bins * h * w).plan(bins, h, w);
    let before = sup.stats().dispatched;
    let ticket = sup.submit_with_deadline(&img, &plan, Duration::ZERO).expect("submit");
    std::thread::sleep(Duration::from_millis(60)); // let the dispatcher see it
    let mut out = IntegralHistogram::zeros(bins, h, w);
    match ticket.reassemble_into(&mut out) {
        Err(ShardError::DeadlineExceeded { completed, .. }) => {
            assert_eq!(completed, 0, "nothing was computed for a dead frame");
        }
        other => panic!("expected DeadlineExceeded, got {other:?}"),
    }
    let stats = sup.stats();
    assert!(stats.skipped_deadline >= 1, "{stats:?}");
    assert_eq!(stats.dispatched, before, "expired shards never reach a child: {stats:?}");

    // And a generous deadline still completes bit-identically.
    let ticket = sup.submit_with_deadline(&img, &plan, Duration::from_secs(60)).expect("submit");
    let mut got = IntegralHistogram::zeros(bins, h, w);
    ticket.reassemble_into(&mut got).expect("healthy deadline");
    let oracle = integral_histogram_seq(&binned(h, w, bins, 7));
    assert_eq!(oracle.max_abs_diff(&got), 0.0);
}

/// Per-node calibrated placement end-to-end: children report their
/// snapshots over the protocol, the placement pass sizes a plan per
/// node, and an assigned submit completes bit-identical.
#[test]
fn calibration_reports_drive_per_node_placement() {
    let _wd = Watchdog::arm(Duration::from_secs(60), "per-node placement");
    let sup = ProcSupervisor::new(pool_config(2)).expect("spawn pool");
    let calibrated = sup.wait_calibrated(Duration::from_secs(30));
    assert_eq!(calibrated, 2, "every child must report a snapshot");
    let snaps = sup.snapshots();
    assert!(snaps.iter().all(|s| s.is_some()), "{snaps:?}");

    let (h, w, bins) = (80, 64, 16);
    let planner = planner(2, bins * h * w);
    let (plan, map) = plan_for_nodes(&planner, bins, h, w, &snaps);
    assert_eq!(map.calibrated_nodes, 2);
    assert_eq!(map.assignment.len(), plan.shards.len());
    assert!(map.assignment.iter().all(|&n| n < 2));

    let img = Arc::new(binned(h, w, bins, 3));
    let ticket = sup.submit_assigned(&img, &plan, &map.assignment).expect("assigned submit");
    let mut got = IntegralHistogram::zeros(bins, h, w);
    ticket.reassemble_into(&mut got).expect("assigned reassembly");
    let oracle = integral_histogram_seq(&binned(h, w, bins, 3));
    assert_eq!(oracle.max_abs_diff(&got), 0.0);
}

/// The shm data plane against the spill-file plane, same frames, same
/// adversarial geometries: results must be bit-identical, and the shm
/// supervisor must actually have used its ring (counter-asserted) —
/// otherwise this test would vacuously compare the file plane to
/// itself.
#[cfg(unix)]
#[test]
fn shm_data_plane_is_bit_identical_to_the_file_plane() {
    if !inthist::proc::shm::available() {
        eprintln!("skipping: no shared-memory data plane on this platform");
        return;
    }
    let _wd = Watchdog::arm(Duration::from_secs(120), "shm vs file plane");
    let shapes: &[(usize, usize, usize)] = &[
        (33, 1, 7),   // single-column image
        (1, 64, 4),   // single-row image
        (61, 37, 13), // everything prime
        (16, 16, 32), // more bins than rows
        (96, 80, 8),  // bread-and-butter
    ];
    let file_sup = ProcSupervisor::new(ProcPoolConfig {
        data_plane: DataPlane::File,
        ..pool_config(2)
    })
    .expect("spawn file-plane pool");
    let shm_sup = ProcSupervisor::new(ProcPoolConfig {
        data_plane: DataPlane::Shm,
        ..pool_config(2)
    })
    .expect("spawn shm-plane pool");
    for (i, &(h, w, bins)) in shapes.iter().enumerate() {
        let img = binned(h, w, bins, 70 + i as u64);
        let image = Arc::new(img.clone());
        let plan = planner(2, (bins * h * w * 4 / 3).max(4096)).plan(bins, h, w);
        let oracle = integral_histogram_seq(&img);

        let ticket = shm_sup.submit(&image, &plan).expect("shm submit");
        let mut shm_got = IntegralHistogram::zeros(bins, h, w);
        ticket.reassemble_into(&mut shm_got).expect("shm reassembly");
        assert_eq!(oracle.max_abs_diff(&shm_got), 0.0, "shm vs serial, shape {h}x{w}x{bins}");

        let ticket = file_sup.submit(&image, &plan).expect("file submit");
        let mut file_got = IntegralHistogram::zeros(bins, h, w);
        ticket.reassemble_into(&mut file_got).expect("file reassembly");
        assert_eq!(
            file_got.max_abs_diff(&shm_got),
            0.0,
            "shm vs file plane, shape {h}x{w}x{bins}"
        );
    }
    let shm_stats = shm_sup.stats();
    assert!(shm_stats.shm_dispatched >= 1, "the ring must have carried shards: {shm_stats:?}");
    assert_eq!(shm_stats.checksum_failures, 0, "{shm_stats:?}");
    assert_eq!(shm_stats.shard_failures, 0, "{shm_stats:?}");
    let file_stats = file_sup.stats();
    assert_eq!(file_stats.shm_dispatched, 0, "file plane must never touch a ring: {file_stats:?}");
}

/// Reclaim-on-reap: SIGKILL a child while its ring slots are loaded
/// and the supervisor must take the slots back before the respawn —
/// counter-asserted, and the killed frames still complete
/// bit-identical.  Dispatch timing is racy by nature, so the kill is
/// retried across frames until a reap observes in-flight slots (the
/// watchdog bounds the loop).
#[cfg(unix)]
#[test]
fn sigkilled_worker_has_its_ring_slots_reclaimed() {
    if !inthist::proc::shm::available() {
        eprintln!("skipping: no shared-memory data plane on this platform");
        return;
    }
    let _wd = Watchdog::arm(Duration::from_secs(120), "shm SIGKILL slot reclaim");
    let sup = ProcSupervisor::new(ProcPoolConfig {
        data_plane: DataPlane::Shm,
        ..pool_config(2)
    })
    .expect("spawn pool");
    let (h, w, bins) = (72, 56, 16);
    let mut reclaimed = 0;
    for t in 0..20u64 {
        let img = Arc::new(binned(h, w, bins, 500 + t));
        let oracle = integral_histogram_seq(&binned(h, w, bins, 500 + t));
        let plan = planner(2, bins * h * w).plan(bins, h, w);
        let ticket = sup.submit(&img, &plan).expect("submit");
        // Let the dispatcher load strips into ring slots, then kill.
        std::thread::sleep(Duration::from_millis(10));
        sup.kill_worker((t % 2) as usize).expect("kill hook");
        let mut got = IntegralHistogram::zeros(bins, h, w);
        ticket.reassemble_into(&mut got).expect("frame must survive the kill");
        assert_eq!(oracle.max_abs_diff(&got), 0.0, "frame {t} bit-identity across a kill");
        reclaimed = sup.stats().slots_reclaimed;
        if reclaimed >= 1 {
            break;
        }
    }
    let stats = sup.stats();
    assert!(reclaimed >= 1, "a reap must reclaim the dead child's in-flight slots: {stats:?}");
    assert!(stats.respawns >= 1, "{stats:?}");
    assert_eq!(stats.workers_alive, 2, "pool back at full strength: {stats:?}");
    assert_eq!(stats.shard_failures, 0, "no frame may fail for a survivable kill: {stats:?}");
}

/// The heartbeat false-kill regression: a child that is slow to boot
/// (long calibration, cold page cache) used to be killed by the
/// heartbeat watchdog before it ever spoke, looping the pool through
/// useless respawns.  Enforcement now starts at the child's first
/// message — the averted kill is counted, the child is never killed,
/// and its first frame completes bit-identical.
#[test]
fn slow_booting_child_survives_the_heartbeat_watchdog() {
    let _wd = Watchdog::arm(Duration::from_secs(60), "slow-boot heartbeat aversion");
    let mut cfg = pool_config(1);
    cfg.heartbeat = Duration::from_millis(50);
    cfg.heartbeat_timeout = Duration::from_millis(150);
    // Child silent for 3× the heartbeat timeout before its first byte.
    cfg.boot_delay = Duration::from_millis(500);
    let sup = ProcSupervisor::new(cfg).expect("spawn pool");
    let (h, w, bins) = (64, 48, 8);
    let img = Arc::new(binned(h, w, bins, 11));
    let plan = planner(1, bins * h * w).plan(bins, h, w);
    let ticket = sup.submit(&img, &plan).expect("submit");
    let mut got = IntegralHistogram::zeros(bins, h, w);
    ticket.reassemble_into(&mut got).expect("the slow-booting child must serve the frame");
    let oracle = integral_histogram_seq(&binned(h, w, bins, 11));
    assert_eq!(oracle.max_abs_diff(&got), 0.0);
    let stats = sup.stats();
    assert_eq!(stats.respawns, 0, "a booting child must never be heartbeat-killed: {stats:?}");
    assert!(
        stats.heartbeat_kills_averted >= 1,
        "the watchdog must have observed (and spared) the silent boot: {stats:?}"
    );
    assert_eq!(stats.shard_failures, 0, "{stats:?}");
}

/// The server front door behind `process_isolation`: large frames run
/// in child processes, bit-identical to the in-process route, and the
/// snapshot exposes the proc-plane counters.
#[test]
fn server_routes_large_frames_through_the_proc_plane() {
    use inthist::prelude::*;
    use inthist::runtime::artifact::ArtifactManifest;

    let _wd = Watchdog::arm(Duration::from_secs(120), "server proc route");
    let manifest = Arc::new(ArtifactManifest {
        dir: PathBuf::from("/nonexistent"),
        profile: "test".into(),
        artifacts: vec![],
    });
    let mut cfg = ServerConfig::default();
    cfg.engine.bins = 8;
    cfg.engine.device_memory_budget = 1 << 10; // 40×40 routes large
    cfg.process_isolation = true;
    cfg.proc = ProcPoolConfig {
        workers: 2,
        worker_bin: Some(worker_bin()),
        calibrate_children: false,
        ..Default::default()
    };
    let srv = Server::new(manifest, cfg);
    let img = SyntheticVideo::new(40, 40, 1, 2).frame(0).binned(8);
    let (ih, _) = srv.compute(&img).expect("proc-isolated large route");
    let oracle = integral_histogram_seq(&img);
    assert_eq!(oracle.max_abs_diff(&ih), 0.0, "process-isolated route is bit-identical");
    let snap = srv.snapshot();
    let proc = snap.proc.expect("proc supervisor built on first large frame");
    assert_eq!(proc.workers_alive, 2, "{proc:?}");
    assert!(proc.completed >= 1, "{proc:?}");
    assert!(srv.shutdown(Duration::from_secs(10)), "shutdown joins the proc plane");
}
