//! Property tests for the sharded out-of-core subsystem: planner →
//! executor → reassembler → sink must be invisible — bit-identical to
//! the single-shard Algorithm-1 path on adversarial shapes, under
//! interleaving, and through the spill-backed store, with peak
//! resident bytes counter-asserted against the memory budget.

use inthist::histogram::region::{region_histogram, Rect};
use inthist::histogram::sequential::integral_histogram_seq;
use inthist::histogram::types::{BinnedImage, IntegralHistogram};
use inthist::shard::{ShardExecutor, ShardExecutorConfig, ShardPlanner, ShardPolicy};
use inthist::util::prng::Xoshiro256;
use std::sync::Arc;

fn random_image(h: usize, w: usize, bins: usize, seed: u64) -> Arc<BinnedImage> {
    let mut rng = Xoshiro256::new(seed);
    let mut data = vec![0i32; h * w];
    rng.fill_bins(&mut data, bins as u32);
    Arc::new(BinnedImage::new(h, w, bins, data))
}

fn policy(budget: usize, workers: usize) -> ShardPolicy {
    ShardPolicy { memory_budget: budget, workers, ..ShardPolicy::default() }
}

/// Full pipeline at adversarial shapes and budgets: single-row images,
/// single-column images, one bin, bins ≫ shards, budgets that force
/// one-row strips — all bit-identical to Algorithm 1.
#[test]
fn sharded_pipeline_matches_algorithm_1_on_adversarial_shapes() {
    let exec = ShardExecutor::new(ShardExecutorConfig { workers: 3, ..Default::default() });
    let cases: &[(usize, usize, usize, usize)] = &[
        // (h, w, bins, budget)
        (1, 1, 1, 1 << 20),
        (1, 97, 5, 1 << 10),
        (97, 1, 5, 1 << 10),
        (7, 3, 9, 256),
        (33, 47, 8, 8 << 10),
        (40, 40, 1, 2 << 10),
        (64, 48, 128, 64 << 10),
        (13, 61, 32, 1 << 20),
    ];
    for (i, &(h, w, bins, budget)) in cases.iter().enumerate() {
        let img = random_image(h, w, bins, 100 + i as u64);
        let plan = ShardPlanner::new(policy(budget, 3)).plan(bins, h, w);
        let ticket = exec.submit(&img, &plan).expect("submit");
        let mut out = IntegralHistogram::zeros(0, 0, 0);
        let report = ticket.reassemble_into(&mut out).expect("reassemble");
        let expected = integral_histogram_seq(&img);
        assert_eq!(
            expected.max_abs_diff(&out),
            0.0,
            "case {i}: {h}x{w}x{bins} budget {budget} ({} shards)",
            plan.shards.len()
        );
        assert_eq!(report.shards, plan.shards.len());
    }
}

/// The ISSUE acceptance property: a 128-bin frame whose full tensor
/// exceeds the memory budget completes through the `TensorStore` with
/// peak resident tensor bytes ≤ budget (counter-asserted), and its
/// region queries are bit-identical to the in-RAM single-shard path.
#[test]
fn out_of_core_frame_stays_inside_the_budget_and_answers_queries() {
    let (h, w, bins) = (96, 80, 128);
    let budget = 256 << 10; // 256 KiB
    let tensor_bytes = bins * h * w * 4;
    assert!(tensor_bytes > budget, "premise: tensor ({tensor_bytes} B) must exceed the budget");

    let exec = ShardExecutor::new(ShardExecutorConfig { workers: 4, ..Default::default() });
    let img = random_image(h, w, bins, 42);
    let plan = ShardPlanner::new(policy(budget, 4)).plan(bins, h, w);
    assert!(plan.spill, "planner must flag the spill");
    let ticket = exec.submit(&img, &plan).expect("submit");
    let (store, report) = ticket.reassemble_spilled().expect("out-of-core reassembly");

    assert!(
        report.peak_resident_bytes <= budget,
        "peak resident {} B must stay within the {budget} B budget \
         (tensor is {tensor_bytes} B)",
        report.peak_resident_bytes
    );
    assert_eq!(store.bytes_written(), tensor_bytes, "every plane landed on disk");

    // Region queries against the spilled planes vs the in-RAM
    // single-shard path, on adversarial rects.
    let expected = integral_histogram_seq(&img);
    let mut rng = Xoshiro256::new(7);
    let mut rects = vec![
        Rect::new(0, 0, h - 1, w - 1),     // whole frame
        Rect::new(0, 0, 0, 0),             // single pixel at the origin
        Rect::new(h - 1, w - 1, h - 1, w - 1), // single pixel at the corner
        Rect::new(0, 0, h - 1, 0),         // single column
        Rect::new(0, 0, 0, w - 1),         // single row
    ];
    for _ in 0..40 {
        let r0 = rng.range(0, h);
        let c0 = rng.range(0, w);
        let r1 = rng.range(r0, h);
        let c1 = rng.range(c0, w);
        rects.push(Rect::new(r0, c0, r1, c1));
    }
    for rect in rects {
        assert_eq!(
            store.query(rect).expect("store query"),
            region_histogram(&expected, rect),
            "store-served query must be bit-identical at {rect:?}"
        );
    }
}

/// Interleaving correctness: frames submitted concurrently from many
/// threads share one worker set, overlap in flight, and each
/// reassembles bit-identically.
#[test]
fn interleaved_frames_from_concurrent_threads_stay_isolated() {
    let exec = ShardExecutor::new(ShardExecutorConfig { workers: 2, ..Default::default() });
    let plan = ShardPlanner::new(policy(12 << 10, 2)).plan(6, 44, 36);
    assert!(plan.shards.len() >= 4);
    std::thread::scope(|scope| {
        for tid in 0..4u64 {
            let exec = &exec;
            let plan = &plan;
            scope.spawn(move || {
                for rep in 0..2 {
                    let img = random_image(44, 36, 6, 1000 + tid * 10 + rep);
                    let ticket = exec.submit(&img, plan).expect("submit");
                    let mut out = IntegralHistogram::zeros(0, 0, 0);
                    ticket.reassemble_into(&mut out).expect("reassemble");
                    let expected = integral_histogram_seq(&img);
                    assert_eq!(
                        expected.max_abs_diff(&out),
                        0.0,
                        "thread {tid} rep {rep}: cross-frame contamination"
                    );
                }
            });
        }
    });
    let stats = exec.stats();
    assert_eq!(stats.jobs, 8 * plan.shards.len(), "every shard of every frame ran");
    assert_eq!(stats.frames_inflight, 0, "all tickets settled");
    assert!(
        stats.frames_inflight_peak >= 2,
        "concurrent submitters must actually interleave (peak {})",
        stats.frames_inflight_peak
    );
}

/// Steady state: repeated frames at one geometry reuse pooled partial
/// buffers and checked-out engines instead of allocating.
#[test]
fn steady_state_recycles_partials_and_engines() {
    let exec = ShardExecutor::new(ShardExecutorConfig { workers: 2, ..Default::default() });
    let plan = ShardPlanner::new(policy(16 << 10, 2)).plan(8, 40, 32);
    let img = random_image(40, 32, 8, 9);
    for _ in 0..2 {
        let ticket = exec.submit(&img, &plan).expect("submit");
        let mut out = IntegralHistogram::zeros(0, 0, 0);
        ticket.reassemble_into(&mut out).expect("reassemble");
    }
    let warm = exec.stats();
    for _ in 0..6 {
        let ticket = exec.submit(&img, &plan).expect("submit");
        let mut out = IntegralHistogram::zeros(0, 0, 0);
        ticket.reassemble_into(&mut out).expect("reassemble");
    }
    let steady = exec.stats();
    assert_eq!(
        steady.engines_created, warm.engines_created,
        "steady state must not create engines"
    );
    // The arena only allocates when concurrency exceeds its historical
    // peak; allow a ±2 scheduling wobble but no per-frame growth (6
    // frames × many shards would otherwise add dozens of buffers).
    assert!(
        steady.partial_pool.allocated <= warm.partial_pool.allocated + 2,
        "steady state must serve partials from the arena (allocated {} → {})",
        warm.partial_pool.allocated,
        steady.partial_pool.allocated
    );
    assert!(steady.partial_pool.reused > warm.partial_pool.reused);
}
