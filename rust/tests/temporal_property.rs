//! Dedicated coverage for the spatio-temporal integral histogram
//! (`histogram/temporal.rs`: `box_histogram`, `stability`, `nbytes`)
//! plus the cross-subsystem property the ISSUE names: `TensorStore`-
//! served region queries are bit-identical to the in-RAM
//! `region::query` path on adversarial shapes.

use inthist::histogram::region::{region_histogram, Rect};
use inthist::histogram::sequential::integral_histogram_seq;
use inthist::histogram::temporal::TemporalIntegralHistogram;
use inthist::histogram::types::BinnedImage;
use inthist::shard::TensorStore;
use inthist::util::prng::Xoshiro256;

fn random_frames(n: usize, h: usize, w: usize, bins: usize, seed: u64) -> Vec<BinnedImage> {
    let mut rng = Xoshiro256::new(seed);
    (0..n)
        .map(|_| {
            let mut data = vec![0i32; h * w];
            rng.fill_bins(&mut data, bins as u32);
            BinnedImage::new(h, w, bins, data)
        })
        .collect()
}

fn brute_box(frames: &[BinnedImage], bins: usize, t0: usize, t1: usize, rect: Rect) -> Vec<f32> {
    let mut h = vec![0.0f32; bins];
    for f in &frames[t0..=t1] {
        for r in rect.r0..=rect.r1 {
            for c in rect.c0..=rect.c1 {
                let v = f.at(r, c);
                if v >= 0 {
                    h[v as usize] += 1.0;
                }
            }
        }
    }
    h
}

/// `box_histogram` equals brute-force counting on degenerate and
/// skewed geometries — single-frame windows, single-row/column images,
/// one bin, window = whole sequence.
#[test]
fn box_histogram_matches_brute_force_on_adversarial_shapes() {
    let cases: &[(usize, usize, usize, usize)] = &[
        // (frames, h, w, bins)
        (1, 1, 1, 1),
        (2, 1, 31, 4),
        (3, 31, 1, 4),
        (5, 9, 13, 1),
        (4, 12, 7, 16),
        (8, 6, 6, 3),
    ];
    for (ci, &(nt, h, w, bins)) in cases.iter().enumerate() {
        let frames = random_frames(nt, h, w, bins, 50 + ci as u64);
        let tih = TemporalIntegralHistogram::build(&frames, bins);
        let mut rng = Xoshiro256::new(9 + ci as u64);
        for _ in 0..25 {
            let t0 = rng.range(0, nt);
            let t1 = rng.range(t0, nt);
            let r0 = rng.range(0, h);
            let r1 = rng.range(r0, h);
            let c0 = rng.range(0, w);
            let c1 = rng.range(c0, w);
            let rect = Rect::new(r0, c0, r1, c1);
            assert_eq!(
                tih.box_histogram(t0, t1, rect),
                brute_box(&frames, bins, t0, t1, rect),
                "case {ci}: t {t0}..={t1} {rect:?}"
            );
        }
    }
}

/// A sliding window over a constant-then-changing sequence: stability
/// is 1 while the window sits in the constant prefix, and exactly the
/// modal fraction once the window spans the change.
#[test]
fn stability_tracks_the_modal_fraction_of_a_window() {
    let h = 6;
    let mut frames: Vec<BinnedImage> = (0..4).map(|_| BinnedImage::new(h, h, 4, vec![1; h * h])).collect();
    frames.extend((0..2).map(|_| BinnedImage::new(h, h, 4, vec![3; h * h])));
    let tih = TemporalIntegralHistogram::build(&frames, 4);
    let whole = Rect::new(0, 0, h - 1, h - 1);
    assert_eq!(tih.stability(0, 3, whole), 1.0, "constant prefix is perfectly stable");
    // Window of 3 frames: two of bin 1, one of bin 3 → modal 2/3.
    let s = tih.stability(2, 4, whole);
    assert!((s - 2.0 / 3.0).abs() < 1e-6, "got {s}");
    // Fully inside the suffix: stable again.
    assert_eq!(tih.stability(4, 5, whole), 1.0);
}

/// Degenerate regions: a single pixel over a single frame is one
/// count; stability of any non-empty box is at least 1/bins.
#[test]
fn single_pixel_boxes_count_one() {
    let frames = random_frames(3, 5, 7, 4, 77);
    let tih = TemporalIntegralHistogram::build(&frames, 4);
    for t in 0..3 {
        for r in 0..5 {
            for c in 0..7 {
                let hist = tih.box_histogram(t, t, Rect::new(r, c, r, c));
                assert_eq!(hist.iter().sum::<f32>(), 1.0);
                let v = frames[t].at(r, c) as usize;
                assert_eq!(hist[v], 1.0);
                assert_eq!(tih.stability(t, t, Rect::new(r, c, r, c)), 1.0);
            }
        }
    }
}

/// `nbytes` is exactly `bins × frames × h × w × 4` — the §2.1
/// footprint argument (a temporal window multiplies the already
/// bin-amplified tensor again, which is why the out-of-core store
/// exists).
#[test]
fn nbytes_reports_the_full_tensor_footprint() {
    let frames = random_frames(5, 8, 12, 6, 3);
    let tih = TemporalIntegralHistogram::build(&frames, 6);
    assert_eq!(tih.nbytes(), 6 * 5 * 8 * 12 * 4);
    let one = TemporalIntegralHistogram::build(&frames[..1], 6);
    assert_eq!(one.nbytes(), 6 * 8 * 12 * 4);
}

/// The ISSUE property: `TensorStore`-served region queries are
/// bit-identical to in-RAM `region::query` on adversarial shapes —
/// single-row and single-column tensors, one bin, border-hugging and
/// single-pixel rects.
#[test]
fn tensor_store_queries_match_in_ram_region_queries_on_adversarial_shapes() {
    let cases: &[(usize, usize, usize)] = &[
        // (h, w, bins)
        (1, 1, 1),
        (1, 53, 7),
        (53, 1, 7),
        (9, 9, 1),
        (17, 29, 12),
        (40, 8, 3),
    ];
    for (ci, &(h, w, bins)) in cases.iter().enumerate() {
        let mut rng = Xoshiro256::new(300 + ci as u64);
        let mut data = vec![0i32; h * w];
        rng.fill_bins(&mut data, bins as u32);
        let img = BinnedImage::new(h, w, bins, data);
        let ih = integral_histogram_seq(&img);

        let store = TensorStore::spill(bins, h, w).expect("spill store");
        for b in 0..bins {
            store.write_rows(b, 0, ih.plane(b)).expect("spill plane");
        }

        let mut rects = vec![
            Rect::new(0, 0, h - 1, w - 1),
            Rect::new(0, 0, 0, 0),
            Rect::new(h - 1, 0, h - 1, w - 1),
            Rect::new(0, w - 1, h - 1, w - 1),
        ];
        for _ in 0..30 {
            let r0 = rng.range(0, h);
            let c0 = rng.range(0, w);
            let r1 = rng.range(r0, h);
            let c1 = rng.range(c0, w);
            rects.push(Rect::new(r0, c0, r1, c1));
        }
        for rect in rects {
            assert_eq!(
                store.query(rect).expect("store query"),
                region_histogram(&ih, rect),
                "case {ci} ({h}x{w}x{bins}) at {rect:?}"
            );
        }
    }
}
