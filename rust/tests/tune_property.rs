//! Property tests for the calibration + auto-tuning loop (DESIGN.md
//! §9): whatever the calibrator claims — a pure prior, live EWMA
//! state, or adversarial garbage — planning must stay executable and
//! budget-respecting, tuned kernels must stay bit-identical to the
//! scalar reference on every shape, the tuning cache must be stable
//! for a repeated shape, and the batched spilled-query path must be
//! bit-identical to the per-corner reference.

use inthist::histogram::engine::kernel::KernelVariant;
use inthist::histogram::engine::wavefront::{
    integral_histogram_fused_v, integral_histogram_wavefront_v,
};
use inthist::histogram::region::{region_histogram, Rect};
use inthist::histogram::sequential::integral_histogram_seq;
use inthist::histogram::types::BinnedImage;
use inthist::shard::{ShardPlanner, ShardPolicy, TensorStore};
use inthist::simulator::pcie::Card;
use inthist::tune::{autotune, Calibrator, CostSnapshot, TunedPlanner};
use inthist::util::prng::Xoshiro256;
use std::sync::Arc;
use std::time::Duration;

fn random_image(h: usize, w: usize, bins: usize, seed: u64) -> BinnedImage {
    let mut rng = Xoshiro256::new(seed);
    let mut data = vec![0i32; h * w];
    rng.fill_bins(&mut data, bins as u32);
    BinnedImage::new(h, w, bins, data)
}

/// Draw one adversarial estimate: a rotation through every class of
/// garbage a broken clock or poisoned EWMA cell could produce, plus
/// legitimate extreme magnitudes.
fn hostile_value(rng: &mut Xoshiro256) -> f64 {
    match rng.next_u64() % 8 {
        0 => f64::NAN,
        1 => f64::INFINITY,
        2 => f64::NEG_INFINITY,
        3 => 0.0,
        4 => -1.0e9,
        5 => f64::MIN_POSITIVE, // denormal-adjacent but "valid"
        6 => 1.0e300,
        _ => (rng.next_u64() % 1_000_000) as f64 + 1.0,
    }
}

fn hostile_snapshot(seed: u64) -> CostSnapshot {
    let mut rng = Xoshiro256::new(seed);
    CostSnapshot {
        memcpy_bps: hostile_value(&mut rng),
        tile_throughput: std::array::from_fn(|_| hostile_value(&mut rng)),
        tile_throughput_tuned: std::array::from_fn(|_| hostile_value(&mut rng)),
        dispatch_overhead_s: hostile_value(&mut rng),
        spill_read_latency_s: hostile_value(&mut rng),
        spill_read_bps: hostile_value(&mut rng),
        samples: rng.next_u64() % 1000,
    }
}

/// Shard plans costed under adversarial snapshots must stay valid and
/// inside the memory budget: the snapshot steers the *choice*, never
/// the feasibility.  (The per-shard budget can only be undercut by the
/// planner's own hard floor of one whole row, which `plan` applies
/// with and without calibration.)
#[test]
fn shard_plans_respect_the_budget_under_adversarial_snapshots() {
    for seed in 0..64u64 {
        let snap = hostile_snapshot(seed);
        for &(bins, h, w, budget, workers) in &[
            (32usize, 512usize, 512usize, 256usize << 10, 4usize),
            (8, 64, 64, 4 << 10, 2),
            (128, 100, 3000, 1 << 20, 8),
            (1, 1, 1, 64, 1),
        ] {
            let policy = ShardPolicy {
                memory_budget: budget,
                workers,
                ..ShardPolicy::default()
            };
            let planner = ShardPlanner::new(policy);
            let plan = planner.plan_calibrated(bins, h, w, &snap);
            assert!(!plan.shards.is_empty(), "seed {seed}: empty plan");
            let per_shard_budget = budget / workers.max(1);
            assert!(
                plan.max_shard_nbytes() <= per_shard_budget.max(w * 4),
                "seed {seed} {bins}x{h}x{w}: shard of {} B over the {} B budget",
                plan.max_shard_nbytes(),
                per_shard_budget
            );
            // Costing the winner under its own snapshot stays finite.
            let cost = plan.predict_total_with(&snap.sanitized(Card::Gtx480), workers);
            assert!(cost.wall.as_secs_f64().is_finite());
        }
    }
}

/// The tuned planner under adversarial calibration state: plans stay
/// executable, and in sanitized-model terms never cost more than the
/// static planner's choice (the static plan is always a candidate and
/// ties keep it).
#[test]
fn tuned_plans_match_or_beat_static_under_any_snapshot() {
    use inthist::histogram::engine::planner::{Planner, Schedule};
    for seed in 0..32u64 {
        let snap = hostile_snapshot(seed).sanitized(Card::Gtx480);
        for &(h, w, bins, workers) in &[
            (512usize, 512usize, 32usize, 8usize),
            (3, 4096, 8, 4),
            (1, 1, 1, 1),
            (47, 1, 3, 2),
        ] {
            let base = Planner::default();
            let fixed = base.plan(h, w, bins, workers);
            // Drive the search directly with the hostile-but-sanitized
            // snapshot through model_cost: the tuned planner's own
            // search uses the identical sanitize-then-cost pipeline.
            let cal = Arc::new(Calibrator::new(Card::Gtx480));
            let t = TunedPlanner::new(cal);
            let tuned = t.plan(h, w, bins, workers);
            assert!(tuned.tile >= 1);
            assert!(tuned.workers >= 1 && tuned.workers <= workers.max(1));
            if tuned.schedule == Schedule::Serial {
                assert_eq!(tuned.workers, 1);
            }
            // Dominance under the snapshot the planner actually costed
            // with (its calibrator's sanitized view): the static plan
            // was a candidate, so the winner can only match or beat it.
            let own = t.calibrator().snapshot().sanitized(Card::Gtx480);
            assert!(
                autotune::model_cost(&own, &tuned, h, w, bins)
                    <= autotune::model_cost(&own, &fixed, h, w, bins),
                "seed {seed} {h}x{w}x{bins}@{workers}: tuned must not model-cost worse"
            );
            // And the hostile snapshot, once sanitized, never yields a
            // non-finite cost for any executable plan.
            let ct = autotune::model_cost(&snap, &tuned, h, w, bins);
            let cf = autotune::model_cost(&snap, &fixed, h, w, bins);
            assert!(ct.is_finite() && cf.is_finite(), "seed {seed}: non-finite model cost");
        }
    }
}

/// Every tuned-kernel path — fused serial and wavefront-parallel, all
/// tile candidates plus deliberately awkward tiles — is bit-identical
/// to the sequential scalar reference on adversarial shapes, including
/// widths below the unroll lane width (w < 4), single rows, single
/// columns, and tile-straddling primes.
#[test]
fn tuned_kernels_are_bit_identical_on_adversarial_shapes() {
    let shapes: &[(usize, usize, usize)] = &[
        (1, 1, 1),
        (1, 7, 3),    // single row
        (7, 1, 3),    // single column
        (5, 2, 4),    // w < lane width
        (2, 3, 9),    // h < w < lane width
        (17, 19, 5),  // primes straddling tile 16
        (33, 31, 6),  // one past / one short of tile 32
        (64, 64, 8),  // exact tile multiples
        (3, 129, 2),  // wide ribbon, one past tile 128
    ];
    let tiles: &[usize] = &[1, 3, 16, 32, 64, 128];
    for (si, &(h, w, bins)) in shapes.iter().enumerate() {
        let img = random_image(h, w, bins, 0xBEEF + si as u64);
        let expected = integral_histogram_seq(&img);
        for &tile in tiles {
            for variant in KernelVariant::ALL {
                let fused = integral_histogram_fused_v(&img, tile, variant);
                assert_eq!(
                    expected.max_abs_diff(&fused),
                    0.0,
                    "fused {h}x{w}x{bins} tile {tile} {variant:?}"
                );
                for workers in [1usize, 3] {
                    let wf = integral_histogram_wavefront_v(&img, tile, workers, variant);
                    assert_eq!(
                        expected.max_abs_diff(&wf),
                        0.0,
                        "wavefront {h}x{w}x{bins} tile {tile} x{workers} {variant:?}"
                    );
                }
            }
        }
    }
}

/// Cache stability: once a shape is planned, later live measurements —
/// even ones that would flip the search's answer — must not change the
/// plan handed out for that shape.  A stable mapping is the §4
/// configuration contract; recalibration is an explicit cache drop,
/// not a silent flip mid-stream.
#[test]
fn tuning_cache_is_stable_for_a_repeated_shape() {
    let cal = Arc::new(Calibrator::new(Card::Gtx480));
    let t = TunedPlanner::new(Arc::clone(&cal));
    let first = t.plan(200, 300, 16, 4);
    // Feed measurements that scream "tile 16 / tuned kernel is 1000×".
    for _ in 0..256 {
        cal.observe_tile(16, KernelVariant::Tuned, 1e9, Duration::from_millis(1));
    }
    for round in 0..8 {
        assert_eq!(t.plan(200, 300, 16, 4), first, "round {round}: cached plan must hold");
    }
    let s = t.stats();
    assert_eq!(s.misses, 1, "one search ever");
    assert_eq!(s.hits, 8);
    // A fresh planner over the same (now measurement-rich) calibrator
    // may well choose differently — that is the supported recalibration
    // path, and its choice is executable too.
    let fresh = TunedPlanner::new(cal);
    let p = fresh.plan(200, 300, 16, 4);
    assert!(p.tile >= 1 && p.workers >= 1);
}

/// Persistence keeps plans stable across a restart: save, load into a
/// fresh planner over a *different* calibration state, and the loaded
/// geometries plan identically without searching.
#[test]
fn persisted_cache_survives_a_restart_with_drifted_calibration() {
    let t = TunedPlanner::new(Arc::new(Calibrator::new(Card::Gtx480)));
    let a = t.plan(200, 300, 16, 4);
    let b = t.plan(64, 64, 8, 2);
    let path = std::env::temp_dir()
        .join(format!("inthist-tune-prop-{}.json", std::process::id()));
    t.save_to(&path).expect("save");

    let drifted = Arc::new(Calibrator::new(Card::TitanX));
    for _ in 0..64 {
        drifted.observe_tile(128, KernelVariant::Tuned, 1e9, Duration::from_millis(1));
    }
    let fresh = TunedPlanner::new(drifted);
    let n = fresh.load_from(&path).expect("load");
    assert_eq!(n, 2);
    assert_eq!(fresh.plan(200, 300, 16, 4), a);
    assert_eq!(fresh.plan(64, 64, 8, 2), b);
    assert_eq!(fresh.stats().misses, 0, "loaded entries skip the search");
    std::fs::remove_file(&path).ok();
}

/// The batched coalesced [`TensorStore::query`] sweep: random rects
/// over random spilled tensors are bit-identical to both the
/// per-corner reference implementation and the in-RAM Eq. 2 oracle,
/// while issuing strictly fewer read calls than the 4·bins reference
/// would.
#[test]
fn batched_spilled_queries_are_bit_identical_across_a_random_sweep() {
    for seed in 0..4u64 {
        let mut rng = Xoshiro256::new(0x5EED + seed);
        let (h, w, bins) = (
            8 + (rng.next_u64() % 40) as usize,
            8 + (rng.next_u64() % 40) as usize,
            1 + (rng.next_u64() % 12) as usize,
        );
        let img = random_image(h, w, bins, 77 + seed);
        let expected = integral_histogram_seq(&img);
        let store = TensorStore::spill(bins, h, w).expect("spill store");
        for b in 0..bins {
            store
                .write_rows(b, 0, &expected.data[b * h * w..(b + 1) * h * w])
                .expect("plane write");
        }
        store.flush().expect("flush");

        let calls_before = store.read_calls();
        let mut rects = 0usize;
        for _ in 0..40 {
            let r0 = (rng.next_u64() as usize) % h;
            let c0 = (rng.next_u64() as usize) % w;
            let rh = 1 + (rng.next_u64() as usize) % (h - r0);
            let rw = 1 + (rng.next_u64() as usize) % (w - c0);
            let rect = Rect::with_size(r0, c0, rh, rw);
            let batched = store.query(rect).expect("batched query");
            let reference = store.query_reference(rect).expect("reference query");
            assert_eq!(batched, reference, "seed {seed} rect {rect:?}");
            assert_eq!(batched, region_histogram(&expected, rect), "seed {seed} rect {rect:?}");
            rects += 1;
        }
        let calls = store.read_calls() - calls_before;
        // Reference alone would issue up to 4·bins reads per rect (plus
        // the same again for the oracle call); the batched pass must
        // stay below its share even counting the reference's reads.
        assert!(
            calls < rects * 8 * bins.max(1) + rects,
            "seed {seed}: {calls} read calls for {rects} rects at {bins} bins"
        );
    }
}

/// End-to-end closure of the loop on the engine path: a tuned engine
/// and an untuned engine agree bit-identically on a stream of frames
/// while the tuned one feeds measurements back into the calibrator.
#[test]
fn tuned_engine_stream_stays_bit_identical_while_feeding_the_loop() {
    use inthist::histogram::engine::ScanEngine;
    let cal = Arc::new(Calibrator::new(Card::Gtx480));
    let tuner = Arc::new(TunedPlanner::new(Arc::clone(&cal)));
    let mut tuned = ScanEngine::with_tuner(3, Arc::clone(&tuner));
    let mut plain = ScanEngine::new(3);
    for t in 0..6u64 {
        let img = random_image(60 + (t as usize % 3) * 7, 45, 5, 400 + t);
        let expected = integral_histogram_seq(&img);
        let a = tuned.compute(&img);
        let b = plain.compute(&img);
        assert_eq!(expected.max_abs_diff(&a), 0.0, "frame {t} tuned");
        assert_eq!(expected.max_abs_diff(&b), 0.0, "frame {t} plain");
    }
    assert!(cal.snapshot().samples >= 6, "every tuned frame must feed the EWMA loop");
    assert!(tuner.stats().misses >= 1);
}
