//! Integration tests over the PJRT runtime: every AOT strategy artifact
//! must reproduce Algorithm 1 bit-exactly, padding must be transparent,
//! and the fused serve graph must agree with CPU region queries.
//!
//! These tests need `artifacts/` (run `make artifacts`); they skip with
//! a notice when it is absent so plain `cargo test` stays green in a
//! fresh checkout.

use inthist::histogram::region::Rect;
use inthist::histogram::sequential::integral_histogram_seq;
use inthist::histogram::types::Strategy;
use inthist::runtime::artifact::{ArtifactKind, ArtifactManifest};
use inthist::runtime::client::HistogramExecutor;
use inthist::video::synth::SyntheticVideo;

fn manifest() -> Option<ArtifactManifest> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match ArtifactManifest::load(&dir) {
        Ok(m) => Some(m),
        Err(_) => {
            eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
            None
        }
    }
}

#[test]
fn all_strategies_match_algorithm1_at_128() {
    let Some(m) = manifest() else { return };
    let video = SyntheticVideo::new(128, 128, 3, 42);
    let img = video.frame(5).binned(32);
    let expected = integral_histogram_seq(&img);
    for strat in Strategy::ALL {
        let Some(meta) = m.find_strategy(strat, 128, 128, 32) else {
            continue;
        };
        let exe = HistogramExecutor::compile(&m, meta).expect("compile");
        let got = exe.compute(&img).expect("execute");
        assert_eq!(
            expected.max_abs_diff(&got),
            0.0,
            "strategy {strat} deviates from Algorithm 1"
        );
    }
}

#[test]
fn wf_tis_tile_sweep_consistent() {
    let Some(m) = manifest() else { return };
    let video = SyntheticVideo::new(512, 512, 4, 7);
    let img = video.frame(0).binned(32);
    let expected = integral_histogram_seq(&img);
    for tile in [16usize, 32, 64] {
        let Some(meta) = m.find_strategy_tile(Strategy::WfTis, 512, 512, 32, tile) else {
            continue;
        };
        let exe = HistogramExecutor::compile(&m, meta).expect("compile");
        let got = exe.compute(&img).expect("execute");
        assert_eq!(expected.max_abs_diff(&got), 0.0, "tile {tile} deviates");
    }
}

#[test]
fn padded_artifact_crops_correctly() {
    let Some(m) = manifest() else { return };
    // HD artifacts are padded 720→768 rows; the runtime must crop back.
    let Some(meta) = m.find_strategy(Strategy::WfTis, 720, 1280, 8) else {
        eprintln!("SKIP: no HD b8 artifact");
        return;
    };
    assert!(meta.padded_h > meta.height, "test requires a padded artifact");
    let video = SyntheticVideo::new(720, 1280, 3, 3);
    let img = video.frame(0).binned(8);
    let exe = HistogramExecutor::compile(&m, meta).expect("compile");
    let got = exe.compute(&img).expect("execute");
    assert_eq!((got.h, got.w, got.bins), (720, 1280, 8));
    let expected = integral_histogram_seq(&img);
    assert_eq!(expected.max_abs_diff(&got), 0.0, "padding must be invisible");
}

#[test]
fn serve_graph_matches_cpu_queries() {
    let Some(m) = manifest() else { return };
    let serve = m.find_kind(ArtifactKind::Serve);
    let Some(meta) = serve.first() else {
        eprintln!("SKIP: no serve artifact");
        return;
    };
    let video = SyntheticVideo::new(meta.height, meta.width, 4, 9);
    let img = video.frame(2).binned(meta.bins);
    let rects = vec![
        Rect::new(0, 0, meta.height - 1, meta.width - 1),
        Rect::with_size(10, 20, 50, 60),
        Rect::with_size(100, 100, 1, 1),
    ];
    let exe = HistogramExecutor::compile(&m, meta).expect("compile");
    let (ih, hists, _) = exe.compute_with_queries(&img, &rects).expect("serve");
    let expected = integral_histogram_seq(&img);
    assert_eq!(expected.max_abs_diff(&ih), 0.0);
    for (i, &r) in rects.iter().enumerate() {
        let cpu = inthist::histogram::region::region_histogram(&expected, r);
        assert_eq!(hists[i], cpu, "serve query {i} deviates from Eq. 2");
    }
}

#[test]
fn query_artifact_matches_cpu() {
    let Some(m) = manifest() else { return };
    let queries = m.find_kind(ArtifactKind::Query);
    let Some(meta) = queries.first() else {
        eprintln!("SKIP: no query artifact");
        return;
    };
    let video = SyntheticVideo::new(meta.height, meta.width, 4, 13);
    let img = video.frame(0).binned(meta.bins);
    let ih = integral_histogram_seq(&img);
    let rects = vec![Rect::with_size(5, 5, 40, 40), Rect::with_size(0, 0, 1, 7)];
    let exe = HistogramExecutor::compile(&m, meta).expect("compile");
    let got = exe.query(&ih, &rects).expect("query");
    for (i, &r) in rects.iter().enumerate() {
        let cpu = inthist::histogram::region::region_histogram(&ih, r);
        assert_eq!(got[i], cpu, "query artifact row {i}");
    }
}

#[test]
fn executor_rejects_wrong_geometry() {
    let Some(m) = manifest() else { return };
    let Some(meta) = m.find_strategy(Strategy::WfTis, 128, 128, 32) else {
        return;
    };
    let exe = HistogramExecutor::compile(&m, meta).expect("compile");
    let img = SyntheticVideo::new(64, 64, 1, 0).frame(0).binned(32);
    assert!(exe.compute(&img).is_err(), "wrong image size must be rejected");
}

#[test]
fn kernel_time_ordering_matches_paper() {
    // The paper's central performance claim, §4.1: WF-TiS ≤ CW-TiS ≤
    // CW-STS in kernel time.  Verified at 256² (fast enough for CI).
    let Some(m) = manifest() else { return };
    let video = SyntheticVideo::new(256, 256, 4, 7);
    let img = video.frame(0).binned(32);
    let mut times = std::collections::HashMap::new();
    for strat in [Strategy::CwSts, Strategy::CwTis, Strategy::WfTis] {
        let Some(meta) = m.find_strategy(strat, 256, 256, 32) else {
            return;
        };
        let exe = HistogramExecutor::compile(&m, meta).expect("compile");
        let _ = exe.compute_timed(&img).unwrap(); // warm-up
        let mut best = f64::MAX;
        for _ in 0..3 {
            let (_, t) = exe.compute_timed(&img).unwrap();
            best = best.min(t.as_secs_f64());
        }
        times.insert(strat, best);
    }
    assert!(
        times[&Strategy::WfTis] < times[&Strategy::CwSts],
        "WF-TiS must beat CW-STS (wf={:.4}s sts={:.4}s)",
        times[&Strategy::WfTis],
        times[&Strategy::CwSts]
    );
    assert!(
        times[&Strategy::CwTis] < times[&Strategy::CwSts],
        "CW-TiS must beat CW-STS"
    );
}
