//! Property tests for the `ScanEngine` subsystem: every planner
//! schedule must reproduce the Algorithm-1 literal bit-exactly on
//! adversarial shapes, recycled `FramePool` buffers must be invisible
//! in the output, and the zero-alloc `CpuPipeline` path must keep its
//! arena counters flat in steady state.

use inthist::coordinator::frame_pool::FramePool;
use inthist::coordinator::pipeline::{CpuPipeline, CpuPipelineConfig};
use inthist::histogram::engine::{Planner, ScanEngine, Schedule};
use inthist::histogram::sequential::integral_histogram_seq;
use inthist::histogram::types::BinnedImage;
use inthist::util::prng::Xoshiro256;
use inthist::video::synth::SyntheticVideo;
use std::sync::Mutex;

fn random_image(rng: &mut Xoshiro256, h: usize, w: usize, bins: usize) -> BinnedImage {
    let mut data = vec![0i32; h * w];
    rng.fill_bins(&mut data, bins as u32);
    BinnedImage::new(h, w, bins, data)
}

/// Adversarial geometries: single row/column, dims not multiples of the
/// tile, single pixel, extreme aspect ratios.
const ADVERSARIAL: [(usize, usize); 8] =
    [(1, 1), (1, 97), (83, 1), (37, 53), (64, 64), (5, 301), (129, 96), (17, 250)];

#[test]
fn every_schedule_matches_algorithm1_on_adversarial_shapes() {
    let mut rng = Xoshiro256::new(0xE27);
    for &(h, w) in &ADVERSARIAL {
        for bins in [1usize, 3, 32] {
            // tiles: smaller than, equal to, not dividing, and larger
            // than the image extent
            for tile in [4usize, 16, 64, 300] {
                let img = random_image(&mut rng, h, w, bins);
                let expected = integral_histogram_seq(&img);
                for schedule in [Schedule::Serial, Schedule::BinParallel, Schedule::Wavefront] {
                    let planner = Planner {
                        tile_override: Some(tile),
                        schedule_override: Some(schedule),
                    };
                    let mut eng = ScanEngine::with_planner(4, planner);
                    let got = eng.compute(&img);
                    assert_eq!(
                        expected.max_abs_diff(&got),
                        0.0,
                        "h={h} w={w} bins={bins} tile={tile} {schedule:?}"
                    );
                }
            }
        }
    }
}

#[test]
fn auto_planner_matches_algorithm1_randomized() {
    let mut rng = Xoshiro256::new(0x91A);
    for case in 0..20 {
        let h = rng.range(1, 90);
        let w = rng.range(1, 90);
        let bins = rng.range(1, 33);
        let workers = rng.range(1, 6);
        let img = random_image(&mut rng, h, w, bins);
        let expected = integral_histogram_seq(&img);
        let mut eng = ScanEngine::new(workers);
        let got = eng.compute(&img);
        let plan = eng.last_plan().unwrap();
        assert_eq!(
            expected.max_abs_diff(&got),
            0.0,
            "case {case}: h={h} w={w} bins={bins} workers={workers} plan={plan:?}"
        );
    }
}

/// Padding pixels (bin −1, the §3.4 rule) count in no plane under every
/// schedule.
#[test]
fn padding_pixels_count_nowhere() {
    let mut rng = Xoshiro256::new(7);
    let mut img = random_image(&mut rng, 41, 29, 8);
    for i in (0..img.data.len()).step_by(7) {
        img.data[i] = -1;
    }
    let expected = integral_histogram_seq(&img);
    for schedule in [Schedule::Serial, Schedule::BinParallel, Schedule::Wavefront] {
        let planner = Planner { tile_override: Some(16), schedule_override: Some(schedule) };
        let mut eng = ScanEngine::with_planner(3, planner);
        let got = eng.compute(&img);
        assert_eq!(expected.max_abs_diff(&got), 0.0, "{schedule:?}");
    }
}

/// The FramePool reuse contract: a recycled (dirty) buffer yields
/// bit-identical output, and reuse is observable in the counters.
#[test]
fn frame_pool_reuse_is_bit_identical() {
    let pool = FramePool::new();
    let video = SyntheticVideo::new(96, 112, 3, 21);
    let img_a = video.frame(0).binned(16);
    let img_b = video.frame(7).binned(16);
    let mut eng = ScanEngine::new(4);

    let fresh_a = integral_histogram_seq(&img_a);
    let fresh_b = integral_histogram_seq(&img_b);

    let mut t = pool.acquire(16, 96, 112);
    eng.compute_into(&img_a, &mut t);
    assert_eq!(fresh_a.max_abs_diff(&t), 0.0);
    pool.release(t);

    // Recycle the dirty buffer for a different frame ...
    let mut t = pool.acquire(16, 96, 112);
    eng.compute_into(&img_b, &mut t);
    assert_eq!(fresh_b.max_abs_diff(&t), 0.0, "dirty reuse must be invisible");
    pool.release(t);

    // ... and back again, bit-identically to the first pass.
    let mut t = pool.acquire(16, 96, 112);
    eng.compute_into(&img_a, &mut t);
    assert_eq!(fresh_a.max_abs_diff(&t), 0.0);
    pool.release(t);

    let stats = pool.stats();
    assert_eq!(stats.allocated, 1, "one buffer must serve every frame");
    assert_eq!(stats.reused, 2);
    assert_eq!(stats.idle, 1);
}

/// Steady-state CpuPipeline: every frame correct and in order, and the
/// tensor arena stops allocating after warm-up (the zero-alloc claim).
#[test]
fn cpu_pipeline_is_zero_alloc_in_steady_state() {
    let frames = 12usize;
    let lanes = 2usize;
    let (h, w, bins) = (128usize, 160usize, 8usize);
    let video = SyntheticVideo::new(h, w, 3, 5);
    let pipeline = CpuPipeline::new(CpuPipelineConfig::new(bins).lanes(lanes).workers(2));
    let src = Box::new(SyntheticVideo::new(h, w, 3, 5).take_frames(frames));
    let seen = Mutex::new(Vec::new());
    let report = pipeline
        .run_with(src, |seq, ih| {
            assert_eq!((ih.bins, ih.h, ih.w), (bins, h, w));
            let expected = integral_histogram_seq(&video.frame(seq).binned(bins));
            assert_eq!(expected.max_abs_diff(&ih), 0.0, "frame {seq}");
            seen.lock().unwrap().push(seq);
            // dropping `ih` here returns its buffer to the arena
        })
        .expect("pipeline run");
    assert_eq!(report.throughput.frames, frames);
    assert_eq!(*seen.lock().unwrap(), (0..frames).collect::<Vec<_>>());
    let stats = pipeline.pool().stats();
    // Live tensors are bounded by the pipeline depth (compute stage +
    // lanes queued + sink), never by the frame count: after warm-up
    // every frame reuses a returned buffer.
    assert!(
        stats.allocated <= lanes + 2,
        "steady state must not allocate per frame: {stats:?}"
    );
    assert_eq!(stats.allocated + stats.reused, frames);
    assert!(stats.reused >= frames - (lanes + 2));
}

/// A lane's engine — and its parked worker pool — must persist across
/// runs: the second stream on the same pipeline spawns zero threads
/// and reuses the arena.
#[test]
fn cpu_pipeline_engine_persists_across_runs() {
    let (h, w, bins, frames) = (128usize, 160usize, 8usize, 6usize);
    let video = SyntheticVideo::new(h, w, 3, 13);
    let pipeline = CpuPipeline::new(CpuPipelineConfig::new(bins).lanes(2).workers(2));
    for run in 0..3 {
        let src = Box::new(SyntheticVideo::new(h, w, 3, 13).take_frames(frames));
        let report = pipeline
            .run_with(src, |seq, ih| {
                let expected = integral_histogram_seq(&video.frame(seq).binned(bins));
                assert_eq!(expected.max_abs_diff(&ih), 0.0, "run {run} frame {seq}");
            })
            .expect("pipeline run");
        assert_eq!(report.throughput.frames, frames);
    }
    let pool_stats = pipeline.engine_pool_stats();
    assert_eq!(pool_stats.spawned, 1, "one helper spawned once, ever: {pool_stats:?}");
    assert_eq!(pool_stats.jobs, 3 * frames, "every frame of every run is one pool job");
    let arena = pipeline.pool().stats();
    assert_eq!(
        arena.allocated + arena.reused,
        3 * frames,
        "later runs recycle the first run's tensors: {arena:?}"
    );
    assert!(arena.allocated <= 4, "{arena:?}");
}

/// Serial (lanes = 1) CPU pipeline agrees and recycles through one
/// buffer.
#[test]
fn cpu_pipeline_serial_lane() {
    let video = SyntheticVideo::new(64, 64, 2, 9);
    let pipeline = CpuPipeline::new(CpuPipelineConfig::new(4).lanes(1));
    let src = Box::new(SyntheticVideo::new(64, 64, 2, 9).take_frames(5));
    let mut checked = 0usize;
    let report = pipeline
        .run_with(src, |seq, ih| {
            let expected = integral_histogram_seq(&video.frame(seq).binned(4));
            assert_eq!(expected.max_abs_diff(&ih), 0.0);
            checked += 1;
        })
        .expect("serial run");
    assert_eq!(report.lanes, 1);
    assert_eq!(checked, 5);
    let stats = pipeline.pool().stats();
    assert_eq!(stats.allocated, 1, "serial lane cycles one buffer: {stats:?}");
    assert_eq!(stats.reused, 4);
}

/// A sink may detach a tensor from the arena with `take` — it must not
/// return to the pool.
#[test]
fn pipeline_sink_can_keep_tensors() {
    let pipeline = CpuPipeline::new(CpuPipelineConfig::new(4).lanes(2));
    let src = Box::new(SyntheticVideo::new(32, 32, 1, 3).take_frames(3));
    let kept = Mutex::new(Vec::new());
    pipeline
        .run_with(src, |seq, ih| {
            if seq == 1 {
                kept.lock().unwrap().push(ih.take());
            }
        })
        .expect("run");
    assert_eq!(kept.lock().unwrap().len(), 1);
    let stats = pipeline.pool().stats();
    assert_eq!(
        stats.allocated,
        stats.idle + 1,
        "the detached tensor must not be on the free list: {stats:?}"
    );
}
