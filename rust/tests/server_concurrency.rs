//! Concurrency properties of the shared serving layer: N threads
//! hammering one `Server` must produce tensors bit-identical to serial
//! execution, and once warm the serving path must neither allocate
//! per-frame buffers nor spawn threads — both read off the server's
//! counters (the PR's acceptance criteria).

use inthist::coordinator::router::Route;
use inthist::coordinator::server::{Server, ServerConfig};
use inthist::histogram::sequential::integral_histogram_seq;
use inthist::histogram::types::IntegralHistogram;
use inthist::runtime::artifact::ArtifactManifest;
use inthist::video::synth::SyntheticVideo;
use std::path::PathBuf;
use std::sync::Arc;

fn empty_manifest() -> Arc<ArtifactManifest> {
    Arc::new(ArtifactManifest {
        dir: PathBuf::from("/nonexistent"),
        profile: "test".into(),
        artifacts: vec![],
    })
}

const H: usize = 120;
const W: usize = 160;
const BINS: usize = 8;
const DISTINCT: usize = 6;

fn test_server() -> Server {
    let mut cfg = ServerConfig::default();
    cfg.engine.bins = BINS;
    cfg.workers_per_stream = 2; // parallel plans => the worker pools are exercised
    Server::new(empty_manifest(), cfg)
}

fn expected_tensors(video: &SyntheticVideo) -> Vec<IntegralHistogram> {
    (0..DISTINCT).map(|t| integral_histogram_seq(&video.frame(t).binned(BINS))).collect()
}

#[test]
fn hammered_server_is_bit_identical_to_serial() {
    let server = test_server();
    let video = SyntheticVideo::new(H, W, 3, 11);
    let expected = expected_tensors(&video);
    let threads = 4usize;
    let frames_per_thread = 12usize;

    std::thread::scope(|scope| {
        for tid in 0..threads {
            let server = &server;
            let video = &video;
            let expected = &expected;
            scope.spawn(move || {
                let mut session = server.open_session().expect("admitted");
                for i in 0..frames_per_thread {
                    let t = (tid * 7 + i) % DISTINCT;
                    let ih = session.process(&video.frame(t)).expect("compute");
                    assert_eq!(
                        expected[t].max_abs_diff(&ih),
                        0.0,
                        "thread {tid} frame {i} (video frame {t}) diverged from serial"
                    );
                }
                assert_eq!(session.stats().frames, frames_per_thread);
            });
        }
    });

    let snap = server.snapshot();
    assert_eq!(snap.frames, threads * frames_per_thread);
    assert_eq!(snap.sessions_opened, threads);
    assert_eq!(snap.sessions_active, 0, "all sessions dropped");
    assert!(snap.sessions_peak <= threads);
    // Engines are bounded by peak concurrency, never by frame count.
    assert!(
        snap.engines_created <= threads,
        "checkout engines must be reused: {snap:?}"
    );
    assert!(snap.frame_pool.allocated <= threads, "tensor arena bounded: {snap:?}");
    assert_eq!(
        snap.frame_pool.allocated + snap.frame_pool.reused,
        threads * frames_per_thread
    );
    assert_eq!(snap.latency.n, threads * frames_per_thread);
    assert!(snap.latency.p50_ms > 0.0);
    assert!(snap.latency.p99_ms >= snap.latency.p50_ms);
}

#[test]
fn steady_state_counters_stay_flat() {
    let server = test_server();
    let video = SyntheticVideo::new(H, W, 3, 11);
    let expected = expected_tensors(&video);

    // Warm-up: some concurrency, then quiesce.
    std::thread::scope(|scope| {
        for tid in 0..3 {
            let server = &server;
            let video = &video;
            scope.spawn(move || {
                for i in 0..4 {
                    let img = video.frame((tid + i) % DISTINCT).binned(BINS);
                    let (_ih, _d) = server.compute(&img).expect("warm-up compute");
                }
            });
        }
    });

    let warm = server.snapshot();
    assert!(warm.threads_spawned >= 1, "parallel plans must have spawned pools: {warm:?}");

    // Steady state: sequential traffic must reuse everything.
    let extra = 20usize;
    let mut session = server.open_session().expect("admitted");
    for i in 0..extra {
        let t = i % DISTINCT;
        let ih = session.process(&video.frame(t)).expect("steady compute");
        assert_eq!(expected[t].max_abs_diff(&ih), 0.0, "steady frame {i}");
    }
    drop(session);

    let steady = server.snapshot();
    assert_eq!(
        steady.engines_created, warm.engines_created,
        "steady state must not create engines"
    );
    assert_eq!(
        steady.threads_spawned, warm.threads_spawned,
        "steady state must spawn zero threads"
    );
    assert_eq!(
        steady.frame_pool.allocated, warm.frame_pool.allocated,
        "steady state must allocate zero per-frame buffers"
    );
    assert_eq!(steady.frame_pool.reused, warm.frame_pool.reused + extra);
    assert_eq!(
        steady.pool_jobs,
        warm.pool_jobs + extra,
        "every steady frame is one parked-pool job"
    );
    assert_eq!(steady.frames, warm.frames + extra);
}

#[test]
fn admission_is_thread_safe_and_bounded() {
    let mut cfg = ServerConfig::default();
    cfg.engine.bins = BINS;
    cfg.max_sessions = 3;
    let server = Server::new(empty_manifest(), cfg);

    // 8 threads race for 3 slots; the winners hold their sessions
    // until every thread has finished, so exactly 3 can win.
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for _ in 0..8 {
            let server = &server;
            handles.push(scope.spawn(move || server.open_session().ok()));
        }
        let sessions: Vec<_> = handles
            .into_iter()
            .map(|h| h.join().expect("no panic"))
            .collect();
        let admitted = sessions.iter().filter(|s| s.is_some()).count();
        assert_eq!(admitted, 3, "exactly max_sessions admitted");
        drop(sessions);
    });
    // scope end dropped every admitted session: slots all free again
    assert_eq!(server.sessions_active(), 0);
    let s = server.open_session().expect("slots released");
    drop(s);
    let snap = server.snapshot();
    assert_eq!(snap.sessions_rejected, 5);
    assert_eq!(snap.sessions_peak, 3);
}

#[test]
fn large_route_shares_the_front_door_under_concurrency() {
    let mut cfg = ServerConfig::default();
    cfg.engine.bins = BINS;
    cfg.engine.device_memory_budget = 1 << 10; // everything routes "large"
    let server = Server::new(empty_manifest(), cfg);
    assert_eq!(server.route_for(H, W), Route::TaskQueue);
    let video = SyntheticVideo::new(H, W, 2, 5);
    let expected = expected_tensors(&video);
    std::thread::scope(|scope| {
        for tid in 0..3 {
            let server = &server;
            let video = &video;
            let expected = &expected;
            scope.spawn(move || {
                for i in 0..4 {
                    let t = (tid + 2 * i) % DISTINCT;
                    let img = video.frame(t).binned(BINS);
                    // the shared shard executor serves it, same door
                    let (ih, _) = server.compute(&img).expect("large-route compute");
                    assert_eq!(expected[t].max_abs_diff(&ih), 0.0);
                }
            });
        }
    });
    assert_eq!(server.snapshot().frames, 12);
}
