//! Offline stub of the `xla-rs` PJRT surface.
//!
//! The real backend links `libxla` and executes the AOT artifacts under
//! `artifacts/`; this build environment has neither the shared library
//! nor the artifacts, so the runtime layer is stubbed at the FFI
//! boundary: every type the crate's `runtime` module names exists with
//! the same shape, construction of a client succeeds (so `inthist info`
//! can report the platform), and everything that would actually parse or
//! execute HLO returns [`XlaError`] instead of segfaulting on a missing
//! library.  The integration tests already skip when `artifacts/` is
//! absent, so the stub keeps `cargo build && cargo test` green while the
//! CPU `ScanEngine` serves as the offline hot path (see DESIGN.md §4).

use std::borrow::Borrow;
use std::fmt;
use std::path::Path;

const STUB_MSG: &str =
    "XLA/PJRT backend not available in this offline build (stub crate); \
     use the CPU ScanEngine path or link the real xla-rs crate";

/// Error type mirroring `xla_rs::Error` as far as callers observe it.
#[derive(Debug, Clone)]
pub struct XlaError {
    pub message: String,
}

impl XlaError {
    fn stub(what: &str) -> XlaError {
        XlaError { message: format!("{what}: {STUB_MSG}") }
    }
}

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.message)
    }
}

impl std::error::Error for XlaError {}

/// A PJRT client handle.  Construction succeeds so callers can query
/// the platform; compilation is where the stub reports itself.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        Ok(PjRtClient { _private: () })
    }

    pub fn platform_name(&self) -> String {
        "cpu (xla stub, no PJRT runtime)".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        Err(XlaError::stub("compile"))
    }
}

/// Parsed HLO module (never constructible in the stub).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto, XlaError> {
        Err(XlaError::stub(&format!("parse HLO {}", path.as_ref().display())))
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// A compiled executable (never constructible in the stub).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Mirrors `xla_rs`: one result buffer list per device.
    pub fn execute<A: Borrow<Literal>>(
        &self,
        _args: &[A],
    ) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        Err(XlaError::stub("execute"))
    }
}

/// A device buffer holding one result tensor.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        Err(XlaError::stub("to_literal_sync"))
    }
}

/// Element types a [`Literal`] can carry.
pub trait NativeType: Copy {}

impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for u8 {}

/// Host literal: shape-erased constant data.  The stub keeps the byte
/// length so error paths stay honest about what they were handed.
pub struct Literal {
    elements: usize,
}

impl Literal {
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { elements: data.len() }
    }

    pub fn element_count(&self) -> usize {
        self.elements
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal, XlaError> {
        let n: i64 = dims.iter().product();
        if n < 0 || n as usize != self.elements {
            return Err(XlaError {
                message: format!("reshape {:?} does not cover {} elements", dims, self.elements),
            });
        }
        Ok(Literal { elements: self.elements })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, XlaError> {
        Err(XlaError::stub("to_vec"))
    }

    pub fn to_tuple1(&self) -> Result<Literal, XlaError> {
        Err(XlaError::stub("to_tuple1"))
    }

    pub fn to_tuple2(&self) -> Result<(Literal, Literal), XlaError> {
        Err(XlaError::stub("to_tuple2"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_constructs_but_cannot_compile() {
        let c = PjRtClient::cpu().unwrap();
        assert!(c.platform_name().contains("stub"));
        assert_eq!(c.device_count(), 0);
        let proto = HloModuleProto::from_text_file("/nonexistent.hlo.txt");
        assert!(proto.is_err());
    }

    #[test]
    fn literal_tracks_shape() {
        let l = Literal::vec1(&[1i32, 2, 3, 4, 5, 6]);
        assert_eq!(l.element_count(), 6);
        assert!(l.reshape(&[2, 3]).is_ok());
        assert!(l.reshape(&[4, 2]).is_err());
        assert!(l.to_vec::<f32>().is_err());
    }

    #[test]
    fn execute_reports_stub() {
        let c = PjRtClient::cpu().unwrap();
        let comp = XlaComputation::from_proto(&HloModuleProto { _private: () });
        let e = c.compile(&comp).err().unwrap();
        assert!(e.to_string().contains("offline"), "{e}");
    }
}
