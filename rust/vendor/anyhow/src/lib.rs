//! Offline drop-in subset of the `anyhow` API.
//!
//! The build environment has no crates.io access, so this path crate
//! provides the slice of `anyhow` the workspace actually uses: the
//! [`Error`] type (a flattened cause chain), the [`Result`] alias, the
//! [`anyhow!`] / [`bail!`] macros, and the [`Context`] extension trait
//! for both `Result` and `Option`.
//!
//! Semantics mirror upstream where it matters here:
//! * any `E: std::error::Error + Send + Sync + 'static` converts into
//!   [`Error`] via `?` (the cause chain is captured eagerly as strings);
//! * `{}` displays the outermost message, `{:#}` the full chain
//!   separated by `": "` (the format `main.rs` prints);
//! * [`Error`] deliberately does **not** implement `std::error::Error`,
//!   which is what keeps the blanket `From` impl coherent — same trick
//!   as upstream.

use std::fmt;

/// Error type: an outermost message plus its flattened cause chain.
pub struct Error {
    chain: Vec<String>,
}

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Construct from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Prepend a higher-level context message to the chain.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The messages from outermost context to root cause.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.chain.split_first() {
            None => Ok(()),
            Some((head, rest)) => {
                write!(f, "{head}")?;
                if !rest.is_empty() {
                    write!(f, "\n\nCaused by:")?;
                    for (i, cause) in rest.iter().enumerate() {
                        write!(f, "\n    {i}: {cause}")?;
                    }
                }
                Ok(())
            }
        }
    }
}

/// Capture `e` and every `source()` below it as the cause chain.
/// (`Error` itself does not implement `std::error::Error`, so this
/// blanket impl does not overlap the reflexive `From<Error>`.)
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// Extension trait attaching context to fallible values.
pub trait Context<T> {
    /// Wrap the error with a fixed higher-level message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;

    /// Wrap the error with a lazily evaluated message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        match self {
            Ok(v) => Ok(v),
            Err(e) => Err(e.into().context(context)),
        }
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        match self {
            Ok(v) => Ok(v),
            Err(e) => Err(e.into().context(f())),
        }
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        match self {
            Some(v) => Ok(v),
            None => Err(Error::msg(context)),
        }
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        match self {
            Some(v) => Ok(v),
            None => Err(Error::msg(f())),
        }
    }
}

/// Construct an [`Error`] from a message, a format string, or any
/// displayable expression — the upstream `anyhow!` surface.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($fmt:expr, $($arg:tt)+) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)+))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// `return Err(anyhow!(...))`.
#[macro_export]
macro_rules! bail {
    ($($tt:tt)+) => {
        return ::std::result::Result::Err($crate::anyhow!($($tt)+))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = f().unwrap_err();
        assert_eq!(e.to_string(), "gone");
    }

    #[test]
    fn context_prepends() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading manifest").unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: gone");
        assert_eq!(e.root_cause(), "gone");
    }

    #[test]
    fn option_context() {
        let v: Option<u8> = None;
        let e = v.with_context(|| format!("missing {}", "thing")).unwrap_err();
        assert_eq!(e.to_string(), "missing thing");
        assert_eq!(Some(3u8).context("x").unwrap(), 3);
    }

    #[test]
    fn macros() {
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
        let e = anyhow!("x = {}", 7);
        assert_eq!(e.to_string(), "x = 7");
        let s = String::from("owned message");
        let e = anyhow!(s);
        assert_eq!(e.to_string(), "owned message");
        fn f() -> Result<()> {
            bail!("stop {}", 1);
        }
        assert_eq!(f().unwrap_err().to_string(), "stop 1");
    }

    #[test]
    fn debug_shows_chain() {
        let e = Error::msg("root").context("mid").context("top");
        let d = format!("{e:?}");
        assert!(d.contains("top") && d.contains("Caused by") && d.contains("root"), "{d}");
    }

    #[test]
    fn chain_iterates_outside_in() {
        let e = Error::msg("root").context("top");
        let v: Vec<&str> = e.chain().collect();
        assert_eq!(v, vec!["top", "root"]);
    }
}
