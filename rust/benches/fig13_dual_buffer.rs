//! Bench: transfer-bound figures — Fig. 11 (kernel vs transfer),
//! Fig. 13 (dual-buffering on HD sequences), Fig. 15 (frame rates).

fn main() {
    let dir = format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"));
    let reps = std::env::var("BENCH_REPS").ok().and_then(|v| v.parse().ok()).unwrap_or(5);
    for fig in ["fig11", "fig13", "fig15"] {
        if let Err(e) = inthist::figures::run(&dir, fig, reps) {
            eprintln!("[{fig}] skipped: {e:#}");
        }
    }
}
