//! Bench: CPU-substrate hot paths (ablation + §Perf pass input).
//!
//! Covers the design choices DESIGN.md calls out: Algorithm-1 literal vs
//! running-row-sum vs image-major vs tiled single-pass vs tiled two-pass
//! (the §3.5 memory-traffic ablation on CPU), thread scaling of the
//! parallel baseline, and region-query/batcher throughput.

use inthist::coordinator::batcher::QueryBatcher;
use inthist::histogram::parallel::{integral_histogram_crossweave, integral_histogram_parallel};
use inthist::histogram::region::{region_histogram, Rect};
use inthist::histogram::sequential::{
    integral_histogram_seq, integral_histogram_seq_imagemajor, integral_histogram_seq_rowsum,
};
use inthist::histogram::tiled::{integral_histogram_tiled, integral_histogram_tiled_twopass};
use inthist::util::stats::{render_table, BenchRow};
use inthist::video::synth::SyntheticVideo;

fn main() {
    let reps = std::env::var("BENCH_REPS").ok().and_then(|v| v.parse().ok()).unwrap_or(5);
    let video = SyntheticVideo::new(512, 512, 4, 7);
    let img = video.frame(0).binned(32);

    // --- single-thread variants (ablation of the data-movement scheme) ---
    let mut rows = Vec::new();
    rows.push(BenchRow::measure("alg1 literal (4-term recurrence)", 1, reps, || {
        std::hint::black_box(integral_histogram_seq(&img));
    }));
    rows.push(BenchRow::measure("rowsum (running row sums)", 1, reps, || {
        std::hint::black_box(integral_histogram_seq_rowsum(&img));
    }));
    rows.push(BenchRow::measure("image-major (1 image pass)", 1, reps, || {
        std::hint::black_box(integral_histogram_seq_imagemajor(&img));
    }));
    rows.push(BenchRow::measure("tiled single-pass (WF-TiS on CPU)", 1, reps, || {
        std::hint::black_box(integral_histogram_tiled(&img, 64));
    }));
    rows.push(BenchRow::measure("tiled two-pass (CW-TiS on CPU)", 1, reps, || {
        std::hint::black_box(integral_histogram_tiled_twopass(&img, 64));
    }));
    print!("{}", render_table("CPU single-thread variants, 512x512x32", &rows));

    // --- tile-size sweep of the cache-blocked variant ---
    let mut rows = Vec::new();
    for tile in [16usize, 32, 64, 128, 256] {
        rows.push(BenchRow::measure(format!("tile {tile}x{tile}"), 1, reps, || {
            std::hint::black_box(integral_histogram_tiled(&img, tile));
        }));
    }
    print!("{}", render_table("tile-size sweep (single-pass), 512x512x32", &rows));

    // --- thread scaling (the OpenMP-baseline analogue, Fig. 19 input) ---
    let mut rows = Vec::new();
    for threads in [1usize, 2, 4, 8, 16] {
        rows.push(BenchRow::measure(format!("bin-parallel, {threads} threads"), 1, reps, || {
            std::hint::black_box(integral_histogram_parallel(&img, threads));
        }));
    }
    rows.push(BenchRow::measure("cross-weave, 8 threads", 1, reps, || {
        std::hint::black_box(integral_histogram_crossweave(&img, 8));
    }));
    print!("{}", render_table("CPU thread scaling, 512x512x32", &rows));

    // --- region-query service throughput ---
    let ih = integral_histogram_seq(&img);
    let rects: Vec<Rect> = (0..1000)
        .map(|i| Rect::with_size((i * 7) % 300, (i * 13) % 300, 64 + i % 100, 64 + i % 64))
        .collect();
    let mut rows = Vec::new();
    rows.push(BenchRow::measure("1000 region queries (Eq. 2)", 1, reps, || {
        for &r in &rects {
            std::hint::black_box(region_histogram(&ih, r));
        }
    }));
    rows.push(BenchRow::measure("1000 queries via batcher (20% dup)", 1, reps, || {
        let mut b = QueryBatcher::new();
        for (i, &r) in rects.iter().enumerate() {
            b.submit(i as u64, if i % 5 == 0 { rects[0] } else { r });
        }
        std::hint::black_box(b.flush(&ih));
    }));
    print!("{}", render_table("region-query service, 32 bins", &rows));
}
