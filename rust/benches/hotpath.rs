//! Bench: CPU-substrate hot paths (ablation + §Perf pass input).
//!
//! Covers the design choices DESIGN.md calls out: Algorithm-1 literal vs
//! running-row-sum vs image-major vs tiled single-pass vs tiled two-pass
//! (the §3.5 memory-traffic ablation on CPU), thread scaling of the
//! parallel baseline, the `ScanEngine` (fused multi-bin wavefront) vs
//! every baseline at high and low bin counts, the `FramePool`
//! steady-state allocation behaviour, and region-query/batcher
//! throughput.
//!
//! Besides the human-readable tables, the run emits a machine-readable
//! `BENCH_hotpath.json` at the repo root (per-variant median ns,
//! implied fps, config, derived speedups, pool counters) so the perf
//! trajectory is tracked across PRs.

use inthist::coordinator::batcher::QueryBatcher;
use inthist::coordinator::frame_pool::FramePool;
use inthist::histogram::engine::{
    integral_histogram_fused, integral_histogram_fused_v, integral_histogram_wavefront,
    KernelVariant, Planner, ScanEngine, Schedule,
};
use inthist::histogram::parallel::{integral_histogram_crossweave, integral_histogram_parallel};
use inthist::histogram::region::{region_histogram, Rect};
use inthist::histogram::sequential::{
    integral_histogram_seq, integral_histogram_seq_imagemajor, integral_histogram_seq_rowsum,
};
use inthist::histogram::tiled::{integral_histogram_tiled, integral_histogram_tiled_twopass};
use inthist::tune::{Calibrator, TunedPlanner};
use inthist::util::stats::{render_table, BenchRow};
use inthist::video::synth::SyntheticVideo;
use std::sync::Arc;

/// Rows accumulated for the JSON report: (group, row).
struct Report {
    rows: Vec<(String, BenchRow)>,
}

impl Report {
    fn push(&mut self, group: &str, row: &BenchRow) {
        self.rows.push((group.to_string(), row.clone()));
    }

    fn push_all(&mut self, group: &str, rows: &[BenchRow]) {
        for r in rows {
            self.push(group, r);
        }
    }
}

fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

/// A pinned-schedule engine measuring the steady-state (pooled-buffer)
/// compute path.
fn engine_row(
    label: &str,
    reps: usize,
    img: &inthist::histogram::types::BinnedImage,
    schedule: Schedule,
    workers: usize,
    tile: usize,
) -> BenchRow {
    let planner = Planner { tile_override: Some(tile), schedule_override: Some(schedule) };
    let mut eng = ScanEngine::with_planner(workers, planner);
    let mut out = eng.compute(img); // warm buffers + scratch outside timing
    BenchRow::measure(label, 1, reps, || {
        eng.compute_into(img, &mut out);
        std::hint::black_box(&out);
    })
}

fn main() {
    let reps = std::env::var("BENCH_REPS").ok().and_then(|v| v.parse().ok()).unwrap_or(5);
    let video = SyntheticVideo::new(512, 512, 4, 7);
    let img = video.frame(0).binned(32);
    let mut report = Report { rows: Vec::new() };

    // --- single-thread variants (ablation of the data-movement scheme) ---
    let mut rows = Vec::new();
    rows.push(BenchRow::measure("alg1 literal (4-term recurrence)", 1, reps, || {
        std::hint::black_box(integral_histogram_seq(&img));
    }));
    rows.push(BenchRow::measure("rowsum (running row sums)", 1, reps, || {
        std::hint::black_box(integral_histogram_seq_rowsum(&img));
    }));
    rows.push(BenchRow::measure("image-major (1 image pass)", 1, reps, || {
        std::hint::black_box(integral_histogram_seq_imagemajor(&img));
    }));
    rows.push(BenchRow::measure("tiled single-pass (WF-TiS on CPU)", 1, reps, || {
        std::hint::black_box(integral_histogram_tiled(&img, 64));
    }));
    rows.push(BenchRow::measure("tiled two-pass (CW-TiS on CPU)", 1, reps, || {
        std::hint::black_box(integral_histogram_tiled_twopass(&img, 64));
    }));
    rows.push(BenchRow::measure("engine fused serial (multi-bin tiles)", 1, reps, || {
        std::hint::black_box(integral_histogram_fused(&img, 64));
    }));
    print!("{}", render_table("CPU single-thread variants, 512x512x32", &rows));
    report.push_all("single_thread", &rows);

    // --- tile-size sweep of the fused engine kernel ---
    let mut rows = Vec::new();
    for tile in [16usize, 32, 64, 128, 256] {
        rows.push(BenchRow::measure(format!("fused tile {tile}x{tile}"), 1, reps, || {
            std::hint::black_box(integral_histogram_fused(&img, tile));
        }));
    }
    print!("{}", render_table("engine tile-size sweep (fused serial), 512x512x32", &rows));
    report.push_all("tile_sweep", &rows);

    // --- thread scaling (the OpenMP-baseline analogue, Fig. 19 input) ---
    let mut rows = Vec::new();
    for threads in [1usize, 2, 4, 8, 16] {
        rows.push(BenchRow::measure(format!("bin-parallel, {threads} threads"), 1, reps, || {
            std::hint::black_box(integral_histogram_parallel(&img, threads));
        }));
    }
    rows.push(BenchRow::measure("cross-weave, 8 threads", 1, reps, || {
        std::hint::black_box(integral_histogram_crossweave(&img, 8));
    }));
    for workers in [2usize, 4, 8] {
        rows.push(BenchRow::measure(format!("wavefront, {workers} workers"), 1, reps, || {
            std::hint::black_box(integral_histogram_wavefront(&img, 64, workers));
        }));
    }
    print!("{}", render_table("CPU thread scaling, 512x512x32", &rows));
    report.push_all("thread_scaling", &rows);

    // --- engine vs baseline: the acceptance-criterion comparison ---
    // 32 bins: bin-parallelism has slack; the win must come from fusion
    // + wavefront. 4 bins: bin-parallelism is starved (the low-bin case).
    let par32 = BenchRow::measure("baseline bin-parallel, 4 threads, 32 bins", 1, reps, || {
        std::hint::black_box(integral_histogram_parallel(&img, 4));
    });
    let wf32 = engine_row(
        "engine wavefront, 4 workers, 32 bins (pooled)",
        reps,
        &img,
        Schedule::Wavefront,
        4,
        64,
    );
    let img4 = video.frame(0).binned(4);
    let par4 = BenchRow::measure("baseline bin-parallel, 4 threads, 4 bins", 1, reps, || {
        std::hint::black_box(integral_histogram_parallel(&img4, 4));
    });
    let wf4 = engine_row(
        "engine wavefront, 4 workers, 4 bins (pooled)",
        reps,
        &img4,
        Schedule::Wavefront,
        4,
        64,
    );
    let auto32 = {
        let mut eng = ScanEngine::new(4);
        let mut out = eng.compute(&img);
        BenchRow::measure("engine auto plan, 4 workers, 32 bins (pooled)", 1, reps, || {
            eng.compute_into(&img, &mut out);
            std::hint::black_box(&out);
        })
    };
    let rows = vec![par32.clone(), wf32.clone(), auto32.clone(), par4.clone(), wf4.clone()];
    print!("{}", render_table("engine vs baseline, 512x512, 4 threads", &rows));
    let speedup32 = par32.summary.median / wf32.summary.median;
    let speedup4 = par4.summary.median / wf4.summary.median;
    println!("wavefront speedup vs bin-parallel @32 bins: {speedup32:.2}x (target >= 2.0x)");
    println!("wavefront speedup vs bin-parallel @ 4 bins: {speedup4:.2}x (target >= 1.5x)");
    report.push_all("engine_vs_baseline", &rows);

    // --- FramePool steady state: zero per-frame allocations ---
    let pool = FramePool::new();
    let mut eng = ScanEngine::new(4);
    let pool_row = BenchRow::measure("pooled frame cycle (acquire+scan+release)", 1, reps, || {
        let mut out = pool.acquire(img.bins, img.h, img.w);
        eng.compute_into(&img, &mut out);
        std::hint::black_box(&out);
        pool.release(out);
    });
    let stats = pool.stats();
    print!("{}", render_table("FramePool steady state, 512x512x32", &[pool_row.clone()]));
    println!(
        "pool counters: allocated {} buffer(s), reused {} (steady state allocates nothing)\n",
        stats.allocated, stats.reused
    );
    report.push("frame_pool", &pool_row);

    // --- region-query service throughput ---
    let ih = integral_histogram_seq(&img);
    let rects: Vec<Rect> = (0..1000)
        .map(|i| Rect::with_size((i * 7) % 300, (i * 13) % 300, 64 + i % 100, 64 + i % 64))
        .collect();
    let mut rows = Vec::new();
    rows.push(BenchRow::measure("1000 region queries (Eq. 2)", 1, reps, || {
        for &r in &rects {
            std::hint::black_box(region_histogram(&ih, r));
        }
    }));
    rows.push(BenchRow::measure("1000 queries via batcher (20% dup)", 1, reps, || {
        let mut b = QueryBatcher::new();
        for (i, &r) in rects.iter().enumerate() {
            b.submit(i as u64, if i % 5 == 0 { rects[0] } else { r });
        }
        std::hint::black_box(b.flush(&ih));
    }));
    print!("{}", render_table("region-query service, 32 bins", &rows));
    report.push_all("region_query", &rows);

    // --- calibrated planner vs static planner (DESIGN.md §9 loop) ---
    // One calibrator microbenches at startup; all calibrated engines
    // share one TunedPlanner (one search per geometry) and feed their
    // live timings back.  The static engine is the pre-calibration
    // baseline.  Each geometry reports both medians plus the ratio.
    let cal = Arc::new(Calibrator::default());
    cal.calibrate();
    let tuner = Arc::new(TunedPlanner::new(Arc::clone(&cal)));
    let mut rows = Vec::new();
    let mut cal_ratios: Vec<(String, f64)> = Vec::new();
    for (h, w, bins) in [(512usize, 512usize, 32usize), (512, 512, 4), (128, 2048, 16)] {
        let frame = SyntheticVideo::new(h, w, 4, 7).frame(0);
        let gimg = frame.binned(bins);
        let mut stat_eng = ScanEngine::new(4);
        let mut out = stat_eng.compute(&gimg);
        let srow =
            BenchRow::measure(format!("static plan {h}x{w}x{bins}"), 1, reps, || {
                stat_eng.compute_into(&gimg, &mut out);
                std::hint::black_box(&out);
            });
        let mut cal_eng = ScanEngine::with_tuner(4, Arc::clone(&tuner));
        // Warm pass: runs the one-time plan search and seeds the EWMA.
        cal_eng.compute_into(&gimg, &mut out);
        let crow =
            BenchRow::measure(format!("calibrated plan {h}x{w}x{bins}"), 1, reps, || {
                cal_eng.compute_into(&gimg, &mut out);
                std::hint::black_box(&out);
            });
        cal_ratios.push((format!("{h}x{w}x{bins}"), srow.summary.median / crow.summary.median));
        rows.push(srow);
        rows.push(crow);
    }
    // The kernel-variant lever in isolation at the default tile.
    let kref = BenchRow::measure("kernel reference, tile 64", 1, reps, || {
        std::hint::black_box(integral_histogram_fused_v(&img, 64, KernelVariant::Reference));
    });
    let ktun = BenchRow::measure("kernel tuned (blocked+unrolled), tile 64", 1, reps, || {
        std::hint::black_box(integral_histogram_fused_v(&img, 64, KernelVariant::Tuned));
    });
    let kernel_ratio = kref.summary.median / ktun.summary.median;
    rows.push(kref);
    rows.push(ktun);
    print!("{}", render_table("calibrated vs static planner, 4 workers", &rows));
    for (shape, r) in &cal_ratios {
        println!("calibrated vs static @ {shape}: {r:.2}x (>= 1.0x expected)");
    }
    println!("tuned kernel vs reference @ tile 64: {kernel_ratio:.2}x");
    let tune_stats = tuner.stats();
    let cal_samples = cal.snapshot().samples;
    report.push_all("calibrated_vs_static", &rows);

    // --- machine-readable report at the repo root ---
    let mut json = String::new();
    json.push_str("{\n");
    json.push_str("  \"bench\": \"hotpath\",\n");
    json.push_str("  \"harness\": \"cargo-bench\",\n");
    json.push_str(&format!("  \"reps\": {reps},\n"));
    json.push_str("  \"config\": {\"h\": 512, \"w\": 512, \"bins\": 32, \"low_bins\": 4, \"threads\": 4},\n");
    json.push_str("  \"rows\": [\n");
    for (i, (group, row)) in report.rows.iter().enumerate() {
        let sep = if i + 1 < report.rows.len() { "," } else { "" };
        json.push_str(&format!(
            "    {{\"group\": \"{}\", \"name\": \"{}\", \"median_ns\": {:.0}, \"median_ms\": {:.4}, \"p10_ms\": {:.4}, \"p90_ms\": {:.4}, \"fps\": {:.2}}}{sep}\n",
            json_escape(group),
            json_escape(&row.label),
            row.summary.median * 1e6,
            row.summary.median,
            row.summary.p10,
            row.summary.p90,
            row.fps(),
        ));
    }
    json.push_str("  ],\n");
    json.push_str("  \"derived\": {\n");
    json.push_str(&format!(
        "    \"wavefront_vs_binparallel_32bins_4threads\": {speedup32:.3},\n"
    ));
    json.push_str(&format!(
        "    \"wavefront_vs_binparallel_4bins_4threads\": {speedup4:.3},\n"
    ));
    json.push_str(&format!(
        "    \"frame_pool\": {{\"allocated\": {}, \"reused\": {}}},\n",
        stats.allocated, stats.reused
    ));
    json.push_str("    \"calibrated_vs_static\": {");
    for (i, (shape, r)) in cal_ratios.iter().enumerate() {
        let sep = if i + 1 < cal_ratios.len() { ", " } else { "" };
        json.push_str(&format!("\"{}\": {r:.3}{sep}", json_escape(shape)));
    }
    json.push_str("},\n");
    json.push_str(&format!(
        "    \"tuned_kernel_vs_reference_tile64\": {kernel_ratio:.3},\n"
    ));
    json.push_str(&format!(
        "    \"tune\": {{\"hits\": {}, \"misses\": {}, \"cached\": {}, \"calibration_samples\": {cal_samples}}}\n",
        tune_stats.hits, tune_stats.misses, tune_stats.cached
    ));
    json.push_str("  }\n}\n");
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_hotpath.json");
    match std::fs::write(&path, &json) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }
}
