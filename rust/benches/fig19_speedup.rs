//! Bench: scaling figures — Fig. 16 (multi-device frame rate), Fig. 17
//! (pool vs CPU threading), Fig. 19 (GPU vs CPU speedups), Fig. 20
//! (cross-platform comparison on 640×480).

fn main() {
    let dir = format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"));
    let reps = std::env::var("BENCH_REPS").ok().and_then(|v| v.parse().ok()).unwrap_or(5);
    for fig in ["fig16", "fig17", "fig19", "fig20"] {
        if let Err(e) = inthist::figures::run(&dir, fig, reps) {
            eprintln!("[{fig}] skipped: {e:#}");
        }
    }
}
