//! Bench: multi-stream serving throughput and latency percentiles.
//!
//! Establishes the serving-layer perf trajectory the ISSUE-2 tentpole
//! targets: one shared `Server`, N concurrent streams (sessions) of
//! 640×480 frames at 32 bins, measuring aggregate fps, per-stream fps
//! and the p50/p95/p99 + jitter latency distribution as the stream
//! count grows (1/2/4/8).  Per-stream compute is pinned to one worker
//! so the scaling axis is *streams*, exactly the "many concurrent
//! histogram streams" regime of the adaptive-CUDA-streams follow-up
//! work (PAPERS.md).
//!
//! A second section drives one 4-worker session to exercise the
//! persistent `WorkerPool`: its reuse counters (threads spawned once,
//! one pool job per frame, zero steady-state arena allocations) are
//! reported alongside.
//!
//! Emits `BENCH_serving.json` at the repo root.

use inthist::coordinator::server::{Server, ServerConfig};
use inthist::runtime::artifact::ArtifactManifest;
use inthist::video::source::VideoFrame;
use inthist::video::synth::SyntheticVideo;
use std::path::PathBuf;
use std::sync::{Arc, Barrier};
use std::time::Instant;

const H: usize = 480;
const W: usize = 640;
const BINS: usize = 32;
const DISTINCT: usize = 8;

fn offline_manifest() -> Arc<ArtifactManifest> {
    // The serving bench measures the CPU substrate; with artifacts
    // absent the server routes every frame to the ScanEngine path.
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    Arc::new(ArtifactManifest::load(&dir).unwrap_or(ArtifactManifest {
        dir,
        profile: "offline".into(),
        artifacts: vec![],
    }))
}

fn stream_frames(seed: u64) -> Vec<VideoFrame> {
    let video = SyntheticVideo::new(H, W, 3, seed);
    (0..DISTINCT).map(|t| video.frame(t)).collect()
}

struct StreamsRow {
    streams: usize,
    frames: usize,
    wall_s: f64,
    aggregate_fps: f64,
    per_stream_fps: f64,
    latency: inthist::coordinator::metrics::LatencySummary,
    engines_created: usize,
    threads_spawned: usize,
}

fn run_streams(streams: usize, frames_per_stream: usize, workers_per_stream: usize) -> StreamsRow {
    let mut cfg = ServerConfig::default();
    cfg.engine.bins = BINS;
    cfg.workers_per_stream = workers_per_stream;
    cfg.max_sessions = streams.max(1) * 2;
    let server = Server::new(offline_manifest(), cfg);

    // Pre-generate every stream's frames outside the timed region.
    let frames: Vec<Vec<VideoFrame>> = (0..streams).map(|s| stream_frames(7 + s as u64)).collect();
    // Two-phase start: `ready` fences all warm-ups, then the main
    // thread clears the latency reservoir (so percentiles describe
    // steady state only) before `go` releases the timed loops.
    let ready = Barrier::new(streams + 1);
    let go = Barrier::new(streams + 1);

    let server_ref = &server;
    let ready_ref = &ready;
    let go_ref = &go;
    // The closure's return value is the start instant (taken when the
    // `go` barrier releases every stream); `scope` returns after all
    // stream threads drained, so `elapsed` is the aggregate wall time.
    let t0 = std::thread::scope(|scope| {
        for fs in frames.iter() {
            scope.spawn(move || {
                let mut session = server_ref.open_session().expect("admitted");
                // Warm the lane: engine scratch + one arena tensor.
                let _ = session.process(&fs[0]).expect("warm-up");
                ready_ref.wait();
                go_ref.wait();
                for i in 0..frames_per_stream {
                    let ih = session.process(&fs[i % DISTINCT]).expect("frame");
                    std::hint::black_box(&ih);
                }
            });
        }
        ready_ref.wait();
        server_ref.reset_latency_stats();
        go_ref.wait();
        Instant::now()
    });
    let wall = t0.elapsed();

    let snap = server.snapshot();
    let total = streams * frames_per_stream;
    let wall_s = wall.as_secs_f64().max(1e-9);
    StreamsRow {
        streams,
        frames: total,
        wall_s,
        aggregate_fps: total as f64 / wall_s,
        per_stream_fps: total as f64 / wall_s / streams as f64,
        latency: snap.latency,
        engines_created: snap.engines_created,
        threads_spawned: snap.threads_spawned,
    }
}

fn main() {
    let reps: usize = std::env::var("BENCH_REPS").ok().and_then(|v| v.parse().ok()).unwrap_or(5);
    let frames_per_stream = 8 * reps;

    // --- stream-count scaling sweep (1 worker per stream) ---
    println!("## multi-stream serving, {W}x{H}x{BINS} bins, {frames_per_stream} frames/stream");
    println!(
        "{:<10} {:>10} {:>14} {:>14} {:>9} {:>9} {:>9} {:>10}",
        "streams", "frames", "aggregate fps", "fps/stream", "p50 ms", "p95 ms", "p99 ms", "jitter ms"
    );
    let mut rows = Vec::new();
    for streams in [1usize, 2, 4, 8] {
        let row = run_streams(streams, frames_per_stream, 1);
        println!(
            "{:<10} {:>10} {:>14.1} {:>14.1} {:>9.2} {:>9.2} {:>9.2} {:>10.3}",
            row.streams,
            row.frames,
            row.aggregate_fps,
            row.per_stream_fps,
            row.latency.p50_ms,
            row.latency.p95_ms,
            row.latency.p99_ms,
            row.latency.jitter_ms
        );
        rows.push(row);
    }
    let fps1 = rows[0].aggregate_fps;
    let scaling4 = rows.iter().find(|r| r.streams == 4).map(|r| r.aggregate_fps / fps1).unwrap_or(0.0);
    let scaling8 = rows.iter().find(|r| r.streams == 8).map(|r| r.aggregate_fps / fps1).unwrap_or(0.0);
    println!("aggregate scaling: 4 streams = {scaling4:.2}x of 1 stream (target >= 1.5x), 8 streams = {scaling8:.2}x\n");

    // --- worker-pool reuse: one 4-worker stream in steady state ---
    let pool_frames = 8 * reps;
    let mut cfg = ServerConfig::default();
    cfg.engine.bins = BINS;
    cfg.workers_per_stream = 4;
    let server = Server::new(offline_manifest(), cfg);
    let frames = stream_frames(3);
    let mut session = server.open_session().expect("admitted");
    let _ = session.process(&frames[0]).expect("warm-up"); // spawn + allocate once
    let warm = server.snapshot();
    let t0 = Instant::now();
    for i in 0..pool_frames {
        let ih = session.process(&frames[i % DISTINCT]).expect("frame");
        std::hint::black_box(&ih);
    }
    let pool_wall = t0.elapsed().as_secs_f64().max(1e-9);
    drop(session);
    let steady = server.snapshot();
    let pool_fps = pool_frames as f64 / pool_wall;
    println!("## worker-pool steady state, 1 stream x 4 workers, {pool_frames} frames");
    println!(
        "fps {:.1} | engines created {} | threads spawned {} (warm {}) | pool jobs {} | arena allocated {} reused {}",
        pool_fps,
        steady.engines_created,
        steady.threads_spawned,
        warm.threads_spawned,
        steady.pool_jobs,
        steady.frame_pool.allocated,
        steady.frame_pool.reused
    );
    let zero_spawn_steady_state = steady.threads_spawned == warm.threads_spawned
        && steady.engines_created == warm.engines_created
        && steady.frame_pool.allocated == warm.frame_pool.allocated;
    println!("zero-spawn, zero-alloc steady state: {zero_spawn_steady_state}\n");

    // --- machine-readable report at the repo root ---
    let mut json = String::new();
    json.push_str("{\n");
    json.push_str("  \"bench\": \"serving\",\n");
    json.push_str(&format!("  \"reps\": {reps},\n"));
    json.push_str(&format!(
        "  \"config\": {{\"h\": {H}, \"w\": {W}, \"bins\": {BINS}, \"frames_per_stream\": {frames_per_stream}, \"workers_per_stream\": 1}},\n"
    ));
    json.push_str("  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let sep = if i + 1 < rows.len() { "," } else { "" };
        json.push_str(&format!(
            "    {{\"streams\": {}, \"frames\": {}, \"wall_s\": {:.4}, \"aggregate_fps\": {:.2}, \"per_stream_fps\": {:.2}, \"latency\": {}, \"engines_created\": {}, \"threads_spawned\": {}}}{sep}\n",
            r.streams,
            r.frames,
            r.wall_s,
            r.aggregate_fps,
            r.per_stream_fps,
            r.latency.to_json(),
            r.engines_created,
            r.threads_spawned,
        ));
    }
    json.push_str("  ],\n");
    json.push_str("  \"derived\": {\n");
    json.push_str(&format!("    \"aggregate_scaling_4_streams_vs_1\": {scaling4:.3},\n"));
    json.push_str(&format!("    \"aggregate_scaling_8_streams_vs_1\": {scaling8:.3},\n"));
    json.push_str(&format!(
        "    \"worker_pool\": {{\"fps\": {:.2}, \"frames\": {}, \"engines_created\": {}, \"threads_spawned\": {}, \"pool_jobs\": {}, \"arena_allocated\": {}, \"arena_reused\": {}, \"zero_spawn_steady_state\": {}}}\n",
        pool_fps,
        pool_frames,
        steady.engines_created,
        steady.threads_spawned,
        steady.pool_jobs,
        steady.frame_pool.allocated,
        steady.frame_pool.reused,
        zero_spawn_steady_state,
    ));
    json.push_str("  }\n}\n");
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../BENCH_serving.json");
    match std::fs::write(&path, &json) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }
}
