//! Bench: the sharded out-of-core execution subsystem.
//!
//! Three sections, one `BENCH_shard.json` at the repo root:
//!
//! 1. **Plan sweep** — aggregate throughput and peak resident bytes vs
//!    the memory budget (which drives shard count/granularity) at a
//!    fixed worker count: the cost of finer sharding made visible.
//! 2. **Interleaved vs serial** — the ISSUE-3 acceptance comparison:
//!    N frames through (a) the serial whole-frame `BinTaskQueue`
//!    baseline (one frame owns the pool until assembled — the old
//!    `Server` large route) and (b) the `ShardExecutor` with 1, 2 and
//!    4 frames in flight.  Interleaving fills the per-frame drain tail
//!    and replaces per-task image clones + zeroed partials with
//!    persistent scratch + pooled buffers, so aggregate throughput
//!    must beat the serial queue at ≥ 2 frames in flight.
//! 3. **Out-of-core** — a 128-bin frame whose tensor exceeds the
//!    budget streamed into a spill-backed `TensorStore`: wall time,
//!    peak resident bytes vs tensor size, and spilled query rate.
//! 4. **Supervision overhead** — the armed zero-probability fault
//!    probe vs the plain supervised executor.
//! 5. **Process isolation** — the same schedule through real
//!    `proc-worker` child processes, once per data plane: the
//!    spill-file round-trip (`proc` row), the shared-memory slot
//!    ring (`proc.shm` row), and loopback TCP remote nodes on the
//!    chunked stream plane (`proc.remote` row), so the JSON carries
//!    all three isolation-tax numbers and their ratios — plus the
//!    latency of a frame that survives a SIGKILL mid-flight
//!    (respawn recovery).
//!
//! Run: `cargo bench --bench shard` (BENCH_REPS=1 for the CI smoke).

use inthist::coordinator::task_queue::{BinTaskQueue, TaskQueueConfig};
use inthist::proc::{DataPlane, ProcPoolConfig, ProcSupervisor};
use inthist::histogram::region::Rect;
use inthist::histogram::types::{BinnedImage, IntegralHistogram};
use inthist::runtime::artifact::ArtifactManifest;
use inthist::shard::{FrameTicket, ShardExecutor, ShardExecutorConfig, ShardPlan, ShardPlanner, ShardPolicy};
use inthist::tune::Calibrator;
use inthist::video::synth::SyntheticVideo;
use std::collections::VecDeque;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

const H: usize = 192;
const W: usize = 160;
const BINS: usize = 32;
const GROUP: usize = 4;
const WORKERS: usize = 4;
const DISTINCT: usize = 4;

fn offline_manifest() -> Arc<ArtifactManifest> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    Arc::new(ArtifactManifest::load(&dir).unwrap_or(ArtifactManifest {
        dir,
        profile: "offline".into(),
        artifacts: vec![],
    }))
}

fn images(h: usize, w: usize, bins: usize) -> Vec<Arc<BinnedImage>> {
    let video = SyntheticVideo::new(h, w, 3, 11);
    (0..DISTINCT).map(|t| Arc::new(video.frame(t).binned(bins))).collect()
}

/// Drive `frames` frames through the executor keeping up to `window`
/// tickets in flight, draining in submission order.  Returns
/// (aggregate fps, max peak-resident bytes over the run).
fn run_interleaved(
    exec: &ShardExecutor,
    plan: &ShardPlan,
    imgs: &[Arc<BinnedImage>],
    frames: usize,
    window: usize,
) -> (f64, usize) {
    let mut outs: Vec<IntegralHistogram> =
        (0..window).map(|_| IntegralHistogram::zeros(0, 0, 0)).collect();
    let mut inflight: VecDeque<FrameTicket> = VecDeque::new();
    let mut peak = 0usize;
    let mut submitted = 0usize;
    let mut done = 0usize;
    let t0 = Instant::now();
    while done < frames {
        while inflight.len() < window && submitted < frames {
            let img = &imgs[submitted % imgs.len()];
            inflight.push_back(exec.submit(img, plan).expect("submit"));
            submitted += 1;
        }
        let ticket = inflight.pop_front().expect("ticket in flight");
        let out = &mut outs[done % window];
        let report = ticket.reassemble_into(out).expect("reassemble");
        peak = peak.max(report.peak_resident_bytes);
        std::hint::black_box(&out.data);
        done += 1;
    }
    (frames as f64 / t0.elapsed().as_secs_f64().max(1e-9), peak)
}

/// `run_interleaved`, but submitting through the multi-process
/// supervisor.  Same ticket type, same drain order, so the comparison
/// isolates exactly the process boundary: pipes, spill files, checksums.
fn run_proc_interleaved(
    sup: &ProcSupervisor,
    plan: &ShardPlan,
    imgs: &[Arc<BinnedImage>],
    frames: usize,
    window: usize,
) -> f64 {
    let mut outs: Vec<IntegralHistogram> =
        (0..window).map(|_| IntegralHistogram::zeros(0, 0, 0)).collect();
    let mut inflight: VecDeque<FrameTicket> = VecDeque::new();
    let mut submitted = 0usize;
    let mut done = 0usize;
    let t0 = Instant::now();
    while done < frames {
        while inflight.len() < window && submitted < frames {
            let img = &imgs[submitted % imgs.len()];
            inflight.push_back(sup.submit(img, plan).expect("proc submit"));
            submitted += 1;
        }
        let ticket = inflight.pop_front().expect("ticket in flight");
        let out = &mut outs[done % window];
        ticket.reassemble_into(out).expect("proc reassemble");
        std::hint::black_box(&out.data);
        done += 1;
    }
    frames as f64 / t0.elapsed().as_secs_f64().max(1e-9)
}

struct SweepRow {
    budget: usize,
    shards: usize,
    group: usize,
    strip_rows: usize,
    fps: f64,
    peak_resident: usize,
    /// The calibrated planner's choice on the same (budget, geometry).
    shards_calibrated: usize,
    fps_calibrated: f64,
    /// Predicted makespans under the calibrated snapshot — calibrated
    /// ≤ static by construction (the static plan is a candidate).
    model_wall_static_s: f64,
    model_wall_calibrated_s: f64,
}

fn main() {
    let reps: usize = std::env::var("BENCH_REPS").ok().and_then(|v| v.parse().ok()).unwrap_or(5);
    let frames = 4 * reps;
    let imgs = images(H, W, BINS);

    // --- 1. plan sweep: budget → shard granularity → throughput ---
    // Each budget row plans twice: the static paper-prior planner and
    // the calibrated planner costing candidates with a measured
    // snapshot (DESIGN.md §9).  Both run on a calibrator-instrumented
    // executor, so live shard timings keep feeding the loop as the
    // sweep progresses.
    let cal = Arc::new(Calibrator::default());
    cal.calibrate();
    println!("## plan sweep, {W}x{H}x{BINS} bins, {WORKERS} workers, {frames} frames");
    println!(
        "{:<14} {:>8} {:>7} {:>11} {:>10} {:>16} {:>10} {:>10}",
        "budget", "shards", "group", "strip rows", "fps", "peak resident", "cal shards", "cal fps"
    );
    let mut sweep = Vec::new();
    for budget in [1usize << 30, 4 << 20, 1 << 20, 256 << 10] {
        let policy = ShardPolicy { memory_budget: budget, workers: WORKERS, ..ShardPolicy::default() };
        let planner = ShardPlanner::new(policy);
        let plan = planner.plan(BINS, H, W);
        let snap = cal.snapshot();
        let cal_plan = planner.plan_calibrated(BINS, H, W, &snap);
        let exec = ShardExecutor::with_instruments(
            ShardExecutorConfig { workers: WORKERS, ..Default::default() },
            None,
            Some(Arc::clone(&cal)),
        );
        let _ = run_interleaved(&exec, &plan, &imgs, 2, 1); // warm-up
        let (fps, peak) = run_interleaved(&exec, &plan, &imgs, frames, 2);
        let (cal_fps, _) = run_interleaved(&exec, &cal_plan, &imgs, frames, 2);
        let model_static = plan.predict_total_with(&snap, WORKERS).wall.as_secs_f64();
        let model_cal = cal_plan.predict_total_with(&snap, WORKERS).wall.as_secs_f64();
        println!(
            "{:<14} {:>8} {:>7} {:>11} {:>10.2} {:>16} {:>10} {:>10.2}",
            budget,
            plan.shards.len(),
            plan.group,
            plan.strip_rows,
            fps,
            peak,
            cal_plan.shards.len(),
            cal_fps
        );
        sweep.push(SweepRow {
            budget,
            shards: plan.shards.len(),
            group: plan.group,
            strip_rows: plan.strip_rows,
            fps,
            peak_resident: peak,
            shards_calibrated: cal_plan.shards.len(),
            fps_calibrated: cal_fps,
            model_wall_static_s: model_static,
            model_wall_calibrated_s: model_cal,
        });
    }
    let cal_dominates = sweep.iter().all(|r| r.model_wall_calibrated_s <= r.model_wall_static_s);
    println!(
        "calibrated plan matches or beats static (model wall) on every row: {}",
        if cal_dominates { "PASS" } else { "FAIL" }
    );

    // --- 2. interleaved shard schedule vs serial whole-frame queue ---
    // Both sides split the 32 bins into 4-bin groups and run 4 workers
    // of one CPU engine each; the queue serializes whole frames, the
    // executor interleaves.
    println!("\n## interleaved vs serial, {} tasks/frame of {GROUP} bins, {frames} frames", BINS / GROUP);
    let queue = BinTaskQueue::new(
        offline_manifest(),
        TaskQueueConfig {
            workers: WORKERS,
            group: GROUP,
            artifact: format!("wf_tis_{H}x{W}_b{GROUP}_t64"),
            cpu_fallback: true,
        },
    )
    .expect("baseline queue");
    let _ = queue.compute(&imgs[0], BINS).expect("queue warm-up");
    let t0 = Instant::now();
    for f in 0..frames {
        let (ih, _) = queue.compute(&imgs[f % imgs.len()], BINS).expect("queue frame");
        std::hint::black_box(&ih.data);
    }
    let serial_queue_fps = frames as f64 / t0.elapsed().as_secs_f64().max(1e-9);
    queue.shutdown();
    println!("serial BinTaskQueue (1 frame in flight): {serial_queue_fps:>8.2} fps");

    // Budget sized so the shard plan is the same 4-bin × full-rows
    // decomposition as the queue's task list.
    let policy = ShardPolicy {
        memory_budget: 64 << 20,
        workers: WORKERS,
        max_group: GROUP,
        ..ShardPolicy::default()
    };
    let plan = ShardPlanner::new(policy).plan(BINS, H, W);
    let exec = ShardExecutor::new(ShardExecutorConfig { workers: WORKERS, ..Default::default() });
    let _ = run_interleaved(&exec, &plan, &imgs, 2, 1); // warm-up
    let mut shard_fps = Vec::new();
    for window in [1usize, 2, 4] {
        let (fps, _) = run_interleaved(&exec, &plan, &imgs, frames, window);
        println!(
            "shard executor, {window} frame(s) in flight:   {fps:>8.2} fps ({:.2}x serial queue)",
            fps / serial_queue_fps
        );
        shard_fps.push((window, fps));
    }
    let fps2 = shard_fps.iter().find(|(w, _)| *w == 2).map(|(_, f)| *f).unwrap_or(0.0);
    let beats = fps2 > serial_queue_fps;
    println!(
        "interleaved (2 in flight) vs serial whole-frame queue: {:.2}x — {}",
        fps2 / serial_queue_fps,
        if beats { "PASS" } else { "FAIL" }
    );

    // --- 3. out-of-core: spill a tensor bigger than the budget ---
    let oc_bins = 128;
    let oc_budget = 1usize << 20; // 1 MiB
    let oc_imgs = images(H, W, oc_bins);
    let tensor_bytes = oc_bins * H * W * 4;
    let policy = ShardPolicy { memory_budget: oc_budget, workers: WORKERS, ..ShardPolicy::default() };
    let oc_plan = ShardPlanner::new(policy).plan(oc_bins, H, W);
    let oc_exec = ShardExecutor::new(ShardExecutorConfig { workers: WORKERS, ..Default::default() });
    let t0 = Instant::now();
    let (store, report) = oc_exec
        .submit(&oc_imgs[0], &oc_plan)
        .expect("submit")
        .reassemble_spilled()
        .expect("spill");
    let oc_wall = t0.elapsed().as_secs_f64();
    let mut rng_rects = Vec::new();
    for i in 0..64 {
        let r0 = (i * 3) % (H / 2);
        let c0 = (i * 5) % (W / 2);
        rng_rects.push(Rect::with_size(r0, c0, H / 2, W / 2));
    }
    let tq = Instant::now();
    for &rect in &rng_rects {
        std::hint::black_box(store.query(rect).expect("spilled query"));
    }
    let query_rate = rng_rects.len() as f64 / tq.elapsed().as_secs_f64().max(1e-9);
    println!("\n## out-of-core, {W}x{H}x{oc_bins} bins ({:.1} MB tensor, {:.1} MB budget)", tensor_bytes as f64 / 1e6, oc_budget as f64 / 1e6);
    println!(
        "wall {:.3} s | {} shards | peak resident {} B ({:.1}% of tensor) | within budget: {} | spilled queries {:.0}/s",
        oc_wall,
        report.shards,
        report.peak_resident_bytes,
        100.0 * report.peak_resident_bytes as f64 / tensor_bytes as f64,
        report.peak_resident_bytes <= oc_budget,
        query_rate
    );

    // --- 4. supervision overhead on the hot path ---
    // The supervised executor (catch_unwind per attempt, typed ticket
    // protocol, attempt accounting) IS the hot path now; this row keeps
    // its cost honest.  `fps` re-measures the plain executor on the
    // section-2 schedule.  When the crate is built with
    // `--features fault-injection` we also attach an ARMED injector
    // whose schedule never fires (all probabilities zero), so the probe
    // branch + occurrence counter are exercised on every shard attempt:
    // the delta between the two is the full supervision+probe tax and
    // must stay under 2%.  Without the feature the probe is compiled
    // out and `probed_fps` is null.
    let (sup_fps, _) = run_interleaved(&exec, &plan, &imgs, frames, 2);
    #[cfg(feature = "fault-injection")]
    let probed_fps: Option<f64> = {
        use inthist::fault::{FaultInjector, FaultSpec};
        let fx = ShardExecutor::with_faults(
            ShardExecutorConfig { workers: WORKERS, ..Default::default() },
            Arc::new(FaultInjector::new(1, FaultSpec::default())),
        );
        let _ = run_interleaved(&fx, &plan, &imgs, 2, 1); // warm-up
        let (f, _) = run_interleaved(&fx, &plan, &imgs, frames, 2);
        Some(f)
    };
    #[cfg(not(feature = "fault-injection"))]
    let probed_fps: Option<f64> = None;
    let overhead_pct = probed_fps.map(|p| 100.0 * (sup_fps - p) / sup_fps.max(1e-9));
    println!("\n## supervision overhead (fault probe compiled: {})", cfg!(feature = "fault-injection"));
    println!("supervised executor:            {sup_fps:>8.2} fps");
    match (probed_fps, overhead_pct) {
        (Some(p), Some(o)) => println!(
            "with armed zero-prob injector:  {p:>8.2} fps ({o:+.2}% overhead — {})",
            if o < 2.0 { "PASS (<2%)" } else { "FAIL (>=2%)" }
        ),
        _ => println!("with armed zero-prob injector:  n/a (build with --features fault-injection)"),
    }

    // --- 5. process isolation tax + respawn recovery ---
    // The same section-2 schedule submitted through real `proc-worker`
    // children: every shard crosses a pipe-controlled process boundary
    // and its tensors travel through spill files.  The delta vs the
    // supervised in-process executor (`sup_fps`) is the full isolation
    // tax.  The recovery row SIGKILLs a child with a frame in flight
    // and times the frame end-to-end anyway — respawn + requeue + the
    // recomputed shards, the latency a production kill actually costs.
    let proc_workers = 2usize;
    // Pinned to the spill-file plane: this row is the baseline tax the
    // shm data plane exists to cut.
    let sup = ProcSupervisor::new(ProcPoolConfig {
        workers: proc_workers,
        worker_bin: Some(PathBuf::from(env!("CARGO_BIN_EXE_proc-worker"))),
        calibrate_children: false,
        data_plane: DataPlane::File,
        ..Default::default()
    })
    .expect("spawn proc pool");
    let _ = run_proc_interleaved(&sup, &plan, &imgs, 2, 1); // warm-up
    let proc_fps = run_proc_interleaved(&sup, &plan, &imgs, frames, 2);
    let isolation_tax_pct = 100.0 * (sup_fps - proc_fps) / sup_fps.max(1e-9);

    let t0 = Instant::now();
    let ticket = sup.submit(&imgs[0], &plan).expect("clean submit");
    let mut out = IntegralHistogram::zeros(0, 0, 0);
    ticket.reassemble_into(&mut out).expect("clean frame");
    let clean_frame_ms = t0.elapsed().as_secs_f64() * 1e3;

    let t0 = Instant::now();
    let ticket = sup.submit(&imgs[0], &plan).expect("kill submit");
    sup.kill_worker(0).expect("kill hook");
    ticket.reassemble_into(&mut out).expect("frame survives the kill");
    let killed_frame_ms = t0.elapsed().as_secs_f64() * 1e3;
    let respawn_recovery_ms = (killed_frame_ms - clean_frame_ms).max(0.0);
    let proc_stats = sup.stats();
    println!("\n## process isolation, {proc_workers} worker processes, {frames} frames");
    println!("in-process executor:            {sup_fps:>8.2} fps");
    println!(
        "multi-process (spill files):    {proc_fps:>8.2} fps ({isolation_tax_pct:+.1}% isolation tax)"
    );
    println!(
        "clean frame {clean_frame_ms:.1} ms | frame across a SIGKILL {killed_frame_ms:.1} ms | respawn recovery {respawn_recovery_ms:.1} ms | respawns {}",
        proc_stats.respawns
    );

    // The same schedule on the shared-memory slot ring (Auto resolves
    // to shm where the platform has it, file elsewhere — the emitted
    // row records which plane actually ran).
    let shm_sup = ProcSupervisor::new(ProcPoolConfig {
        workers: proc_workers,
        worker_bin: Some(PathBuf::from(env!("CARGO_BIN_EXE_proc-worker"))),
        calibrate_children: false,
        data_plane: DataPlane::Auto,
        ..Default::default()
    })
    .expect("spawn shm proc pool");
    let shm_plane = shm_sup.data_plane() == DataPlane::Shm;
    let _ = run_proc_interleaved(&shm_sup, &plan, &imgs, 2, 1); // warm-up
    let shm_fps = run_proc_interleaved(&shm_sup, &plan, &imgs, frames, 2);
    let shm_tax_pct = 100.0 * (sup_fps - shm_fps) / sup_fps.max(1e-9);
    let shm_stats = shm_sup.stats();
    println!(
        "multi-process (shm ring):       {shm_fps:>8.2} fps ({shm_tax_pct:+.1}% isolation tax, plane={}, {} shm dispatches, {} fallbacks)",
        if shm_plane { "shm" } else { "file" },
        shm_stats.shm_dispatched,
        shm_stats.shm_fallbacks
    );
    println!(
        "shm tax vs spill-file tax: {shm_tax_pct:.1}% vs {isolation_tax_pct:.1}% — {}",
        if !shm_plane || shm_tax_pct < isolation_tax_pct { "PASS" } else { "FAIL" }
    );

    // The same schedule once more over loopback TCP: one `proc-worker
    // --listen` process backs two remote node slots (each connection
    // gets its own serve loop, like two hosts would), and every strip
    // and partial rides the chunked in-band stream plane — remote
    // nodes have no spill-file or shm alternative.  The delta vs the
    // in-process executor is the full remote tax: socket framing,
    // FNV-1a checksums both ways, and the chunk copies.
    let mut listener = std::process::Command::new(env!("CARGO_BIN_EXE_proc-worker"))
        .args(["--listen", "127.0.0.1:0", "--calibrate", "0"])
        .stdout(std::process::Stdio::piped())
        .stderr(std::process::Stdio::null())
        .spawn()
        .expect("spawn listening proc-worker");
    let remote_addr = {
        use std::io::BufRead;
        let stdout = listener.stdout.take().expect("listener stdout");
        let mut line = String::new();
        std::io::BufReader::new(stdout).read_line(&mut line).expect("read LISTEN line");
        line.trim()
            .strip_prefix("LISTEN ")
            .unwrap_or_else(|| panic!("expected LISTEN <addr>, got {line:?}"))
            .to_string()
    };
    let remote_sup = ProcSupervisor::new(ProcPoolConfig {
        workers: 0,
        remote_workers: vec![remote_addr.clone(), remote_addr],
        calibrate_children: false,
        ..Default::default()
    })
    .expect("connect remote pool");
    let _ = run_proc_interleaved(&remote_sup, &plan, &imgs, 2, 1); // warm-up
    let remote_fps = run_proc_interleaved(&remote_sup, &plan, &imgs, frames, 2);
    let remote_tax_pct = 100.0 * (sup_fps - remote_fps) / sup_fps.max(1e-9);
    let remote_stats = remote_sup.stats();
    drop(remote_sup);
    let _ = listener.kill();
    let _ = listener.wait();
    println!(
        "multi-process (tcp stream):     {remote_fps:>8.2} fps ({remote_tax_pct:+.1}% isolation tax, {} stream dispatches, {} reconnects)",
        remote_stats.stream_dispatched, remote_stats.remote_reconnects
    );

    // --- machine-readable report at the repo root ---
    let mut json = String::new();
    json.push_str("{\n");
    json.push_str("  \"bench\": \"shard\",\n");
    json.push_str("  \"harness\": \"cargo-bench\",\n");
    json.push_str(&format!("  \"reps\": {reps},\n"));
    json.push_str(&format!(
        "  \"config\": {{\"h\": {H}, \"w\": {W}, \"bins\": {BINS}, \"workers\": {WORKERS}, \"frames\": {frames}, \"group\": {GROUP}}},\n"
    ));
    json.push_str("  \"plan_sweep\": [\n");
    for (i, r) in sweep.iter().enumerate() {
        let sep = if i + 1 < sweep.len() { "," } else { "" };
        json.push_str(&format!(
            "    {{\"budget\": {}, \"shards\": {}, \"group\": {}, \"strip_rows\": {}, \"fps\": {:.2}, \"peak_resident_bytes\": {}, \"shards_calibrated\": {}, \"fps_calibrated\": {:.2}, \"model_wall_static_s\": {:.6}, \"model_wall_calibrated_s\": {:.6}}}{sep}\n",
            r.budget, r.shards, r.group, r.strip_rows, r.fps, r.peak_resident,
            r.shards_calibrated, r.fps_calibrated, r.model_wall_static_s, r.model_wall_calibrated_s
        ));
    }
    json.push_str("  ],\n");
    json.push_str("  \"interleave\": {\n");
    json.push_str(&format!("    \"serial_queue_fps\": {serial_queue_fps:.2},\n"));
    json.push_str("    \"shard_fps_by_inflight\": {");
    for (i, (wnd, fps)) in shard_fps.iter().enumerate() {
        let sep = if i + 1 < shard_fps.len() { ", " } else { "" };
        json.push_str(&format!("\"{wnd}\": {fps:.2}{sep}"));
    }
    json.push_str("}\n  },\n");
    json.push_str(&format!(
        "  \"out_of_core\": {{\"bins\": {oc_bins}, \"tensor_bytes\": {tensor_bytes}, \"budget_bytes\": {oc_budget}, \"shards\": {}, \"wall_s\": {:.4}, \"peak_resident_bytes\": {}, \"within_budget\": {}, \"spilled_queries_per_s\": {:.0}}},\n",
        report.shards, oc_wall, report.peak_resident_bytes,
        report.peak_resident_bytes <= oc_budget, query_rate
    ));
    json.push_str(&format!(
        "  \"supervision\": {{\"fault_feature_compiled\": {}, \"fps\": {:.2}, \"probed_fps\": {}, \"overhead_pct\": {}, \"within_2pct\": {}}},\n",
        cfg!(feature = "fault-injection"),
        sup_fps,
        probed_fps.map_or("null".into(), |p| format!("{p:.2}")),
        overhead_pct.map_or("null".into(), |o| format!("{o:.3}")),
        overhead_pct.map_or("null".into(), |o| format!("{}", o < 2.0)),
    ));
    json.push_str(&format!(
        "  \"proc\": {{\"workers\": {proc_workers}, \"data_plane\": \"file\", \"fps_in_process\": {sup_fps:.2}, \"fps_multi_process\": {proc_fps:.2}, \"isolation_tax_pct\": {isolation_tax_pct:.2}, \"clean_frame_ms\": {clean_frame_ms:.2}, \"killed_frame_ms\": {killed_frame_ms:.2}, \"respawn_recovery_ms\": {respawn_recovery_ms:.2}, \"respawns\": {}}},\n",
        proc_stats.respawns
    ));
    json.push_str(&format!(
        "  \"proc.shm\": {{\"workers\": {proc_workers}, \"data_plane\": \"{}\", \"fps_in_process\": {sup_fps:.2}, \"fps_multi_process\": {shm_fps:.2}, \"isolation_tax_pct\": {shm_tax_pct:.2}, \"shm_dispatched\": {}, \"shm_fallbacks\": {}, \"slots_reclaimed\": {}, \"shm_mapped_bytes\": {}}},\n",
        if shm_plane { "shm" } else { "file" },
        shm_stats.shm_dispatched,
        shm_stats.shm_fallbacks,
        shm_stats.slots_reclaimed,
        shm_stats.shm_mapped_bytes
    ));
    json.push_str(&format!(
        "  \"proc.remote\": {{\"workers\": 2, \"data_plane\": \"stream\", \"transport\": \"tcp-loopback\", \"fps_in_process\": {sup_fps:.2}, \"fps_multi_process\": {remote_fps:.2}, \"isolation_tax_pct\": {remote_tax_pct:.2}, \"stream_dispatched\": {}, \"reconnects\": {}}},\n",
        remote_stats.stream_dispatched,
        remote_stats.remote_reconnects
    ));
    json.push_str("  \"derived\": {\n");
    json.push_str(&format!(
        "    \"interleaved_2_inflight_vs_serial_queue\": {:.3},\n",
        fps2 / serial_queue_fps
    ));
    json.push_str(&format!("    \"interleaved_beats_serial_queue\": {beats},\n"));
    json.push_str(&format!(
        "    \"calibrated_matches_or_beats_static_all_rows\": {cal_dominates},\n"
    ));
    json.push_str(&format!(
        "    \"shm_vs_file_fps_ratio\": {:.3},\n",
        shm_fps / proc_fps.max(1e-9)
    ));
    json.push_str(&format!(
        "    \"shm_tax_below_file_tax\": {},\n",
        !shm_plane || shm_tax_pct < isolation_tax_pct
    ));
    json.push_str(&format!(
        "    \"stream_vs_file_fps_ratio\": {:.3},\n",
        remote_fps / proc_fps.max(1e-9)
    ));
    json.push_str(&format!(
        "    \"calibration_samples\": {}\n",
        cal.snapshot().samples
    ));
    json.push_str("  }\n}\n");
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../BENCH_shard.json");
    match std::fs::write(&path, &json) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }
}
