//! Bench: kernel-side figures — Fig. 7 (cumulative kernel time per
//! strategy × size), Fig. 8 (breakdown), Fig. 9/10 (tuning), Eq. 4.
//!
//! Custom harness (the offline build has no criterion); timing and
//! percentile machinery lives in `inthist::util::stats`.

fn main() {
    let dir = format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"));
    let reps = std::env::var("BENCH_REPS").ok().and_then(|v| v.parse().ok()).unwrap_or(5);
    for fig in ["eq4", "fig7", "fig8", "fig9", "fig10"] {
        if let Err(e) = inthist::figures::run(&dir, fig, reps) {
            eprintln!("[{fig}] skipped: {e:#}");
        }
    }
}
