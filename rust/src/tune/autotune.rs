//! [`TunedPlanner`] — the engine planner's choice (schedule × tile ×
//! kernel variant) as a cached search over the calibrated cost model.
//!
//! The static [`Planner`] is a decision table tuned for the paper's
//! hardware; this wrapper re-derives the decision from what the
//! [`Calibrator`] actually measured on *this* host.  Per distinct
//! `(h, w, bins, workers)` the search runs **once** and the winning
//! [`Plan`] is cached — steady-state frames pay one `BTreeMap` lookup
//! under a short-lived mutex (planning is off the per-tile hot path;
//! the kernel itself never touches it).  The cache persists to JSON
//! ([`TunedPlanner::save_to`] / [`TunedPlanner::load_from`]) so a
//! restarted server skips the search too.
//!
//! **The static plan is always a candidate**, costed under the same
//! snapshot — so in model terms the tuned choice can only match or
//! beat the static one, and with a pure-prior snapshot (no
//! measurements yet) the search degenerates gracefully: every tile
//! shows the same prior throughput and the static decision wins its
//! ties.  Snapshots are [`CostSnapshot::sanitized`] before costing, so
//! adversarial calibration inputs cannot make planning panic or emit
//! an inexecutable plan (property-tested in `tests/tune_property.rs`).

use super::{Calibrator, CostSnapshot, TILE_CANDIDATES};
use crate::histogram::engine::kernel::KernelVariant;
use crate::histogram::engine::planner::{Plan, Planner, Schedule};
use crate::util::json;
use crate::util::sync::lock_recover;
use anyhow::{anyhow, Context, Result};
use std::collections::{BTreeMap, BTreeSet};
use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Relative-error band for drift eviction: a cached plan whose
/// prediction misses the measurement by more than this factor of the
/// measurement is stale (cold-start priors, migrated host, thermal
/// change) and gets dropped so the next plan re-searches under fresh
/// numbers.  Wide on purpose — predictions are model-grade, not
/// clock-grade, and evicting on ordinary noise would thrash the cache.
pub const DRIFT_BAND: f64 = 1.5;

/// Cache observability: searches run vs skipped.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TuneStats {
    /// Plans served straight from the cache.
    pub hits: usize,
    /// Searches performed (one per distinct geometry).
    pub misses: usize,
    /// Entries currently cached.
    pub cached: usize,
    /// Entries dropped because a measured report contradicted the
    /// cached plan's prediction beyond [`DRIFT_BAND`].
    pub drift_evictions: usize,
}

/// The auto-tuning planner.  Cheap to share: clone the `Arc` it lives
/// in; engines holding the same instance share one cache, so a
/// geometry is searched once per process, not once per engine.
#[derive(Debug)]
pub struct TunedPlanner {
    base: Planner,
    cal: Arc<Calibrator>,
    cache: Mutex<CacheInner>,
    hits: AtomicUsize,
    misses: AtomicUsize,
    drift_evictions: AtomicUsize,
}

type GeomKey = (usize, usize, usize, usize);

/// The cache state proper, guarded by one mutex so persistence and
/// drift eviction observe it atomically.
#[derive(Debug, Default)]
struct CacheInner {
    plans: BTreeMap<GeomKey, Plan>,
    /// Geometries drift-evicted since this planner was built.  A cache
    /// *file* saved before the eviction still carries the contradicted
    /// entry; [`TunedPlanner::load_from`] consults this set so loading
    /// such a file never resurrects what the measurements killed — only
    /// a fresh live search (which clears the tombstone) brings the
    /// geometry back.
    tombstones: BTreeSet<GeomKey>,
}

impl TunedPlanner {
    pub fn new(cal: Arc<Calibrator>) -> TunedPlanner {
        Self::with_base(Planner::default(), cal)
    }

    /// A tuned planner wrapping a specific base planner.  Base
    /// *overrides* (pinned tile/schedule) win outright: an override is
    /// a test/bench pin, and tuning around it would un-pin it.
    pub fn with_base(base: Planner, cal: Arc<Calibrator>) -> TunedPlanner {
        TunedPlanner {
            base,
            cal,
            cache: Mutex::new(CacheInner::default()),
            hits: AtomicUsize::new(0),
            misses: AtomicUsize::new(0),
            drift_evictions: AtomicUsize::new(0),
        }
    }

    /// The calibrator whose snapshots cost the searches (and which
    /// engines feed their live timings back into).
    pub fn calibrator(&self) -> &Arc<Calibrator> {
        &self.cal
    }

    pub fn stats(&self) -> TuneStats {
        TuneStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            cached: lock_recover(&self.cache).plans.len(),
            drift_evictions: self.drift_evictions.load(Ordering::Relaxed),
        }
    }

    /// Plan for an `h×w`, `bins`-bin request with up to `workers`
    /// threads: cached auto-tune over the calibrated model (see module
    /// docs).
    pub fn plan(&self, h: usize, w: usize, bins: usize, workers: usize) -> Plan {
        assert!(h >= 1 && w >= 1 && bins >= 1, "empty request");
        let workers = workers.max(1);
        if self.base.tile_override.is_some() || self.base.schedule_override.is_some() {
            return self.base.plan(h, w, bins, workers);
        }
        let key = (h, w, bins, workers);
        if let Some(&p) = lock_recover(&self.cache).plans.get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return p;
        }
        let snap = self.cal.snapshot().sanitized(self.cal.card());
        let plan = search_plan(&self.base, &snap, h, w, bins, workers);
        {
            // A fresh search under current measurements supersedes any
            // earlier drift eviction of this geometry.
            let mut cache = lock_recover(&self.cache);
            cache.tombstones.remove(&key);
            cache.plans.insert(key, plan);
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        plan
    }

    /// Drop every cached plan (and reset the hit/miss tallies for it):
    /// the explicit-recalibration path — subsequent plans re-search
    /// under whatever the calibrator measures next.  Returns the number
    /// of entries dropped.
    pub fn clear(&self) -> usize {
        let mut cache = lock_recover(&self.cache);
        let n = cache.plans.len();
        cache.plans.clear();
        cache.tombstones.clear();
        n
    }

    /// Drift check: compare what the cached plan *predicted* for this
    /// geometry against what a completed frame *measured*, and evict
    /// exactly that cache entry when the relative error exceeds
    /// [`DRIFT_BAND`] — the fix for entries cached under cold-start
    /// priors surviving forever after the measurements contradict them
    /// (before this, only an explicit [`Self::clear`] could unstick
    /// them).  Returns `true` when an entry was actually evicted; an
    /// uncached geometry never counts, so the caller can feed every
    /// report through unconditionally.
    pub fn observe_report(
        &self,
        h: usize,
        w: usize,
        bins: usize,
        workers: usize,
        predicted: Duration,
        measured: Duration,
    ) -> bool {
        let (p, m) = (predicted.as_secs_f64(), measured.as_secs_f64());
        if !(p.is_finite() && m.is_finite()) || m <= 0.0 {
            return false; // degenerate clocks prove nothing
        }
        let rel = (p - m).abs() / m;
        if rel <= DRIFT_BAND {
            return false;
        }
        let key = (h, w, bins, workers.max(1));
        let evicted = {
            let mut cache = lock_recover(&self.cache);
            let evicted = cache.plans.remove(&key).is_some();
            if evicted {
                cache.tombstones.insert(key);
            }
            evicted
        };
        if evicted {
            self.drift_evictions.fetch_add(1, Ordering::Relaxed);
        }
        evicted
    }

    /// Persist the tuning cache as JSON (hand-built; the repo's JSON
    /// util is parse-only by design).
    ///
    /// The cache lock is held across the `fs::write` on purpose: a
    /// drain-time save that serialized, dropped the lock, and *then*
    /// wrote would race a concurrent [`Self::observe_report`] drift
    /// eviction — the file on disk keeps the entry the measurements
    /// just killed, and the next `load_from` resurrects it.  Holding
    /// the lock makes save-vs-evict atomic; saves are rare (drain,
    /// explicit persist), so planners never contend on this in steady
    /// state.
    pub fn save_to(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        let cache = lock_recover(&self.cache);
        let mut entries = String::new();
        for (&(h, w, bins, workers), p) in cache.plans.iter() {
            if !entries.is_empty() {
                entries.push(',');
            }
            entries.push_str(&format!(
                "{{\"h\":{h},\"w\":{w},\"bins\":{bins},\"workers\":{workers},\
                 \"schedule\":\"{}\",\"tile\":{},\"plan_workers\":{},\"kernel\":\"{}\"}}",
                schedule_name(p.schedule),
                p.tile,
                p.workers,
                p.kernel.name()
            ));
        }
        let doc = format!("{{\"version\":1,\"entries\":[{entries}]}}\n");
        std::fs::write(path, doc)
            .with_context(|| format!("write tuning cache {}", path.display()))?;
        Ok(())
    }

    /// Load a tuning cache saved by [`Self::save_to`]; returns the
    /// number of entries adopted.  Malformed documents error typed;
    /// entries for geometries already cached are kept as-is (live
    /// searches beat stale files), and entries for geometries this
    /// planner drift-evicted are skipped outright — a stale file never
    /// resurrects a plan the measurements contradicted.
    pub fn load_from(&self, path: impl AsRef<Path>) -> Result<usize> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read tuning cache {}", path.display()))?;
        let doc = json::parse(&text)
            .map_err(|e| anyhow!("tuning cache {}: {e}", path.display()))?;
        if doc.get("version").and_then(|v| v.as_usize()) != Some(1) {
            return Err(anyhow!("tuning cache {}: unsupported version", path.display()));
        }
        let entries = doc
            .get("entries")
            .and_then(|e| e.as_arr())
            .ok_or_else(|| anyhow!("tuning cache {}: missing entries", path.display()))?;
        let mut adopted = 0usize;
        let mut cache = lock_recover(&self.cache);
        for (i, e) in entries.iter().enumerate() {
            let field = |k: &str| {
                e.get(k)
                    .and_then(|v| v.as_usize())
                    .ok_or_else(|| anyhow!("tuning cache entry {i}: bad '{k}'"))
            };
            let (h, w, bins, workers) =
                (field("h")?, field("w")?, field("bins")?, field("workers")?);
            let tile = field("tile")?.max(1);
            let plan_workers = field("plan_workers")?.max(1);
            let schedule = e
                .get("schedule")
                .and_then(|v| v.as_str())
                .and_then(schedule_from_name)
                .ok_or_else(|| anyhow!("tuning cache entry {i}: bad schedule"))?;
            let kernel = e
                .get("kernel")
                .and_then(|v| v.as_str())
                .and_then(KernelVariant::from_name)
                .ok_or_else(|| anyhow!("tuning cache entry {i}: bad kernel"))?;
            if h == 0 || w == 0 || bins == 0 || workers == 0 {
                return Err(anyhow!("tuning cache entry {i}: degenerate geometry"));
            }
            let plan = Plan { schedule, tile, workers: plan_workers, kernel };
            let key = (h, w, bins, workers);
            if cache.tombstones.contains(&key) || cache.plans.contains_key(&key) {
                continue;
            }
            cache.plans.insert(key, plan);
            adopted += 1;
        }
        Ok(adopted)
    }
}

fn schedule_name(s: Schedule) -> &'static str {
    match s {
        Schedule::Serial => "serial",
        Schedule::BinParallel => "bin_parallel",
        Schedule::Wavefront => "wavefront",
    }
}

fn schedule_from_name(s: &str) -> Option<Schedule> {
    match s {
        "serial" => Some(Schedule::Serial),
        "bin_parallel" => Some(Schedule::BinParallel),
        "wavefront" => Some(Schedule::Wavefront),
        _ => None,
    }
}

/// The tuned-kernel variant a snapshot recommends at `tile` — strict
/// improvement required, so ties (e.g. a pure prior, where both
/// variants share one number) keep the reference kernel.
fn best_variant(snap: &CostSnapshot, tile: usize) -> KernelVariant {
    if snap.throughput(tile, KernelVariant::Tuned) > snap.throughput(tile, KernelVariant::Reference)
    {
        KernelVariant::Tuned
    } else {
        KernelVariant::Reference
    }
}

/// Modeled wall seconds for executing `plan` on an `h×w×bins` request
/// under `snap` — the cost function the search minimizes.  Shapes
/// mirror the schedules:
///
/// * Serial: one sweep at the tile's calibrated throughput plus one
///   dispatch.
/// * BinParallel: planes spread over the plan's workers; each plane
///   claim is one dispatch (the §3.3 launch-overhead analog).
/// * Wavefront: the anti-diagonal critical path — at least `tr+tc−1`
///   steps regardless of worker count (Algorithm 5's ramp), otherwise
///   work-bound at the effective width; each step costs one full tile
///   at calibrated throughput plus one dispatch.
pub fn model_cost(snap: &CostSnapshot, plan: &Plan, h: usize, w: usize, bins: usize) -> f64 {
    let pixel_bins = (bins * h * w) as f64;
    let tput = snap.throughput(plan.tile, plan.kernel);
    let d = snap.dispatch_overhead_s;
    match plan.schedule {
        Schedule::Serial => pixel_bins / tput + d,
        Schedule::BinParallel => {
            let wk = plan.workers.max(1) as f64;
            pixel_bins / tput / wk + (bins as f64 / wk).ceil() * d
        }
        Schedule::Wavefront => {
            let tr = h.div_ceil(plan.tile);
            let tc = w.div_ceil(plan.tile);
            let weff = plan.workers.clamp(1, tr.min(tc)) as f64;
            let steps = ((tr * tc) as f64 / weff).max((tr + tc - 1) as f64);
            let tile_elems = (plan.tile * plan.tile * bins) as f64;
            steps * (tile_elems / tput + d)
        }
    }
}

/// One search: the static plan plus every executable
/// `(schedule, tile, kernel)` candidate, lowest modeled cost wins.
/// Deterministic: candidates are enumerated in a fixed order and only
/// a strictly lower cost replaces the incumbent (so the static plan
/// wins all ties).
fn search_plan(
    base: &Planner,
    snap: &CostSnapshot,
    h: usize,
    w: usize,
    bins: usize,
    workers: usize,
) -> Plan {
    let mut best = base.plan(h, w, bins, workers);
    let mut best_cost = model_cost(snap, &best, h, w, bins);
    for &tile in TILE_CANDIDATES.iter() {
        let kernel = best_variant(snap, tile);
        let tr = h.div_ceil(tile);
        let tc = w.div_ceil(tile);
        let diag = tr.min(tc);
        let mut consider = |cand: Plan| {
            let cost = model_cost(snap, &cand, h, w, bins);
            if cost < best_cost {
                best = cand;
                best_cost = cost;
            }
        };
        consider(Plan { schedule: Schedule::Serial, tile, workers: 1, kernel });
        if workers > 1 && diag >= 2 {
            consider(Plan {
                schedule: Schedule::Wavefront,
                tile,
                workers: workers.min(diag),
                kernel,
            });
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::pcie::Card;
    use std::time::Duration;

    fn tuner() -> TunedPlanner {
        TunedPlanner::new(Arc::new(Calibrator::new(Card::Gtx480)))
    }

    /// Plans must satisfy the same invariants the static planner's
    /// outputs do — anything the engine can execute.
    fn assert_executable(p: &Plan, workers: usize) {
        assert!(p.tile >= 1);
        assert!(p.workers >= 1 && p.workers <= workers.max(1));
        if p.schedule == Schedule::Serial {
            assert_eq!(p.workers, 1);
        }
    }

    #[test]
    fn repeated_shape_returns_the_identical_cached_plan() {
        let t = tuner();
        let a = t.plan(512, 512, 32, 8);
        let b = t.plan(512, 512, 32, 8);
        assert_eq!(a, b, "cache must return a stable plan");
        let s = t.stats();
        assert_eq!((s.misses, s.hits, s.cached), (1, 1, 1));
        t.plan(511, 512, 32, 8);
        assert_eq!(t.stats().misses, 2, "new geometry searches once");
    }

    #[test]
    fn pure_prior_matches_or_beats_the_static_plan_in_model_terms() {
        let t = tuner();
        let snap = t.calibrator().snapshot().sanitized(Card::Gtx480);
        for (h, w, bins, workers) in
            [(512usize, 512usize, 32usize, 8usize), (64, 64, 8, 4), (8, 4096, 32, 4), (1, 1, 1, 1)]
        {
            let tuned = t.plan(h, w, bins, workers);
            assert_executable(&tuned, workers);
            let fixed = Planner::default().plan(h, w, bins, workers);
            assert!(
                model_cost(&snap, &tuned, h, w, bins) <= model_cost(&snap, &fixed, h, w, bins),
                "{h}x{w}x{bins}@{workers}: tuned must not model-cost worse than static"
            );
        }
    }

    #[test]
    fn measured_tile_advantage_steers_the_choice() {
        let cal = Arc::new(Calibrator::new(Card::Gtx480));
        // Live traffic says tile 16 with the tuned kernel is 100× the
        // prior; everything else stays at the prior.
        for _ in 0..64 {
            cal.observe_tile(16, KernelVariant::Tuned, 1e8, Duration::from_millis(1));
        }
        let t = TunedPlanner::new(cal);
        let p = t.plan(512, 512, 32, 8);
        assert_eq!(p.tile, 16, "search must follow the measurement");
        assert_eq!(p.kernel, KernelVariant::Tuned);
        assert_executable(&p, 8);
    }

    #[test]
    fn prior_ties_keep_the_reference_kernel() {
        let t = tuner();
        let p = t.plan(512, 512, 32, 8);
        assert_eq!(p.kernel, KernelVariant::Reference, "no measurement → no tuned claim");
    }

    #[test]
    fn base_overrides_are_respected_verbatim() {
        let base = Planner { tile_override: Some(16), schedule_override: Some(Schedule::Serial) };
        let t = TunedPlanner::with_base(base, Arc::new(Calibrator::default()));
        let p = t.plan(512, 512, 32, 8);
        assert_eq!(p, base.plan(512, 512, 32, 8), "overrides must pin the plan");
        assert_eq!(t.stats().cached, 0, "pinned plans bypass the cache");
    }

    #[test]
    fn cache_roundtrips_through_json() {
        let t = tuner();
        let a = t.plan(512, 512, 32, 8);
        let b = t.plan(100, 350, 16, 4);
        let path = std::env::temp_dir()
            .join(format!("inthist-tune-cache-{}.json", std::process::id()));
        t.save_to(&path).expect("save");
        let fresh = tuner();
        let n = fresh.load_from(&path).expect("load");
        assert_eq!(n, 2);
        assert_eq!(fresh.plan(512, 512, 32, 8), a);
        assert_eq!(fresh.plan(100, 350, 16, 4), b);
        let s = fresh.stats();
        assert_eq!((s.hits, s.misses), (2, 0), "loaded entries must skip the search");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn clear_drops_every_cached_plan() {
        let t = tuner();
        t.plan(512, 512, 32, 8);
        t.plan(100, 350, 16, 4);
        assert_eq!(t.clear(), 2);
        assert_eq!(t.stats().cached, 0);
        // Next plan re-searches instead of serving a stale entry.
        let misses_before = t.stats().misses;
        t.plan(512, 512, 32, 8);
        assert_eq!(t.stats().misses, misses_before + 1);
    }

    /// The drift-eviction regression: before `observe_report`, an
    /// entry cached under cold-start priors was kept forever no matter
    /// how badly measurements contradicted it — only an explicit
    /// `clear()` (the whole cache) could unstick it.
    #[test]
    fn seeded_drift_evicts_exactly_the_contradicted_entry() {
        let t = tuner();
        t.plan(512, 512, 32, 8);
        t.plan(100, 350, 16, 4);
        assert_eq!(t.stats().cached, 2);

        // In-band error: a 20% miss is model noise, not drift.
        let kept = t.observe_report(
            512,
            512,
            32,
            8,
            Duration::from_millis(120),
            Duration::from_millis(100),
        );
        assert!(!kept, "in-band error must not evict");
        assert_eq!(t.stats().cached, 2);
        assert_eq!(t.stats().drift_evictions, 0);

        // Seeded drift: prediction 10× the measurement — the cached
        // plan was costed under numbers this host contradicts.
        let evicted = t.observe_report(
            512,
            512,
            32,
            8,
            Duration::from_millis(1000),
            Duration::from_millis(100),
        );
        assert!(evicted, "out-of-band error must evict");
        let s = t.stats();
        assert_eq!(s.cached, 1, "exactly one entry dropped");
        assert_eq!(s.drift_evictions, 1);
        // The untouched geometry still serves from cache...
        let misses = t.stats().misses;
        t.plan(100, 350, 16, 4);
        assert_eq!(t.stats().misses, misses, "other entry undisturbed");
        // ...while the evicted one re-searches.
        t.plan(512, 512, 32, 8);
        assert_eq!(t.stats().misses, misses + 1, "evicted entry re-searches");

        // Re-reporting the same drift on the now-uncached geometry is
        // a no-op: eviction counts actual removals only.
        let again = t.observe_report(
            512,
            512,
            32,
            8,
            Duration::from_millis(1000),
            Duration::from_millis(100),
        );
        // (the re-search just re-cached it, so this evicts again)
        assert!(again);
        assert_eq!(t.stats().drift_evictions, 2);
        let ghost = t.observe_report(
            9999,
            9999,
            9,
            9,
            Duration::from_secs(10),
            Duration::from_millis(1),
        );
        assert!(!ghost, "uncached geometry never counts an eviction");
        assert_eq!(t.stats().drift_evictions, 2);
        // Degenerate measurements prove nothing.
        assert!(!t.observe_report(100, 350, 16, 4, Duration::from_secs(1), Duration::ZERO));
    }

    /// The persistence-race regression: a cache file saved while an
    /// entry was live must not resurrect that entry once a measured
    /// report drift-evicts it — `save_to` holds the cache lock across
    /// the write (save-vs-evict is atomic) and `load_from` consults
    /// the tombstone set, so the evicted geometry re-searches instead
    /// of serving the contradicted plan from disk.
    #[test]
    fn drift_evicted_entry_stays_evicted_across_save_and_load() {
        let t = tuner();
        t.plan(512, 512, 32, 8);
        t.plan(100, 350, 16, 4);
        let path = std::env::temp_dir()
            .join(format!("inthist-tune-tomb-{}.json", std::process::id()));
        // The stale file: saved while both entries were live…
        t.save_to(&path).expect("save");
        // …then measurements kill the 512×512 entry.
        assert!(t.observe_report(
            512,
            512,
            32,
            8,
            Duration::from_secs(1),
            Duration::from_millis(10),
        ));
        assert_eq!(t.stats().cached, 1);
        // Loading the stale file adopts nothing: the live entry is
        // kept as-is, the evicted one is tombstoned.
        assert_eq!(t.load_from(&path).expect("load"), 0);
        assert_eq!(t.stats().cached, 1, "evicted geometry must stay evicted");
        let misses = t.stats().misses;
        t.plan(512, 512, 32, 8);
        assert_eq!(t.stats().misses, misses + 1, "evicted geometry re-searches");
        // The fresh live search superseded the tombstone: it persists
        // and round-trips into a new planner like any other entry.
        t.save_to(&path).expect("save again");
        let fresh = tuner();
        assert_eq!(fresh.load_from(&path).expect("load"), 2);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn malformed_cache_documents_error_typed() {
        let path = std::env::temp_dir()
            .join(format!("inthist-tune-bad-{}.json", std::process::id()));
        std::fs::write(&path, "{\"version\":1,\"entries\":[{\"h\":0}]}").expect("write");
        let t = tuner();
        assert!(t.load_from(&path).is_err());
        std::fs::write(&path, "not json").expect("write");
        assert!(t.load_from(&path).is_err());
        std::fs::write(&path, "{\"version\":2,\"entries\":[]}").expect("write");
        assert!(t.load_from(&path).is_err(), "future versions must be rejected, not guessed");
        std::fs::remove_file(&path).ok();
    }
}
