//! The [`Calibrator`] — one-shot startup microbenches plus lock-free
//! EWMA estimates fed from live traffic.
//!
//! Lifecycle: construct with the cold-start prior for a card
//! ([`super::CostSnapshot::static_prior`]); optionally run
//! [`Calibrator::calibrate`] once at startup (a few milliseconds of
//! microbenches: memcpy bandwidth, fused-kernel throughput at every
//! [`super::TILE_CANDIDATES`] edge × [`KernelVariant`], spill-file read
//! latency/bandwidth); thereafter every engine compute and spill read
//! folds its measurement in through [`Calibrator::observe_tile`] /
//! [`Calibrator::observe_spill_read`].
//!
//! **Concurrency contract.** Estimates are `f64` bit patterns in
//! `AtomicU64`s.  Observers update with a relaxed `fetch_update` EWMA
//! (`new = old + α·(x − old)`); [`Calibrator::snapshot`] is a handful
//! of relaxed loads into a `Copy` [`CostSnapshot`].  No mutex exists
//! anywhere on the path, so a shard worker publishing a timing can
//! never block a planner taking a snapshot (and vice versa).  Estimate
//! fields are independent — a snapshot may mix updates from different
//! instants, which is harmless for cost modeling and the price of
//! being lock-free.
//!
//! Degenerate observations (zero/negative durations from coarse
//! clocks, non-finite throughputs) are dropped at the door, and
//! planners additionally sanitize snapshots against the prior — see
//! [`super::CostSnapshot::sanitized`].

use super::{CostSnapshot, TILE_CANDIDATES};
use crate::histogram::engine::kernel::KernelVariant;
use crate::histogram::engine::wavefront::fused_scan_into_v;
use crate::histogram::engine::TileScratch;
use crate::histogram::types::BinnedImage;
use crate::shard::TensorStore;
use crate::simulator::pcie::Card;
use crate::util::prng::Xoshiro256;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

/// EWMA smoothing factor: one live measurement moves an estimate 25%
/// of the way — a few frames converge, one outlier doesn't whipsaw the
/// planner.
pub const EWMA_ALPHA: f64 = 0.25;

/// Geometry of the calibration microbench frame: large enough that the
/// biggest tile candidate still gets a 2×2 grid and per-run time is
/// well above timer resolution, small enough that the whole sweep
/// (all tiles × variants) stays in the low milliseconds.
const BENCH_H: usize = 192;
const BENCH_W: usize = 192;
const BENCH_BINS: usize = 8;
/// Timed repetitions per microbench point (after one warmup run).
const BENCH_REPS: usize = 2;
/// Memcpy microbench buffer (8 MiB — larger than any sane LLC slice,
/// so this measures memory, not cache).
const MEMCPY_BYTES: usize = 8 << 20;
/// Spill microbench tensor: 1 bin × 32 rows × 1024 cols = 128 KiB.
const SPILL_ROWS: usize = 32;
const SPILL_COLS: usize = 1024;

#[inline]
fn load_f64(cell: &AtomicU64) -> f64 {
    f64::from_bits(cell.load(Ordering::Relaxed))
}

#[inline]
fn store_f64(cell: &AtomicU64, x: f64) {
    if x.is_finite() && x > 0.0 {
        cell.store(x.to_bits(), Ordering::Relaxed);
    }
}

/// Lock-free EWMA fold; drops degenerate samples, adopts the first
/// valid sample outright if the cell itself is degenerate.
#[inline]
fn ewma_f64(cell: &AtomicU64, x: f64) {
    if !x.is_finite() || x <= 0.0 {
        return;
    }
    let _ = cell.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |bits| {
        let old = f64::from_bits(bits);
        let new = if old.is_finite() && old > 0.0 { old + EWMA_ALPHA * (x - old) } else { x };
        Some(new.to_bits())
    });
}

/// Self-calibrating cost-model state.  See the module docs for the
/// lifecycle and concurrency contract.
#[derive(Debug)]
pub struct Calibrator {
    card: Card,
    memcpy_bps: AtomicU64,
    tile_tput: [AtomicU64; TILE_CANDIDATES.len()],
    tile_tput_tuned: [AtomicU64; TILE_CANDIDATES.len()],
    dispatch_s: AtomicU64,
    spill_lat_s: AtomicU64,
    spill_bps: AtomicU64,
    samples: AtomicU64,
}

impl Default for Calibrator {
    /// Prior for the default simulation card (matching
    /// [`crate::shard::ShardPolicy::default`]).
    fn default() -> Calibrator {
        Calibrator::new(Card::Gtx480)
    }
}

impl Calibrator {
    /// A calibrator seeded entirely from the static paper prior for
    /// `card`; no measurement has happened yet.
    pub fn new(card: Card) -> Calibrator {
        let p = CostSnapshot::static_prior(card);
        let seed =
            |x: f64| AtomicU64::new(x.to_bits());
        Calibrator {
            card,
            memcpy_bps: seed(p.memcpy_bps),
            tile_tput: std::array::from_fn(|i| seed(p.tile_throughput[i])),
            tile_tput_tuned: std::array::from_fn(|i| seed(p.tile_throughput_tuned[i])),
            dispatch_s: seed(p.dispatch_overhead_s),
            spill_lat_s: seed(p.spill_read_latency_s),
            spill_bps: seed(p.spill_read_bps),
            samples: AtomicU64::new(0),
        }
    }

    /// The card whose paper constants back this calibrator's prior.
    pub fn card(&self) -> Card {
        self.card
    }

    /// The cold-start prior this calibrator was seeded with.
    pub fn prior(&self) -> CostSnapshot {
        CostSnapshot::static_prior(self.card)
    }

    /// Lock-free point-in-time view — a handful of relaxed loads.
    pub fn snapshot(&self) -> CostSnapshot {
        CostSnapshot {
            memcpy_bps: load_f64(&self.memcpy_bps),
            tile_throughput: std::array::from_fn(|i| load_f64(&self.tile_tput[i])),
            tile_throughput_tuned: std::array::from_fn(|i| load_f64(&self.tile_tput_tuned[i])),
            dispatch_overhead_s: load_f64(&self.dispatch_s),
            spill_read_latency_s: load_f64(&self.spill_lat_s),
            spill_read_bps: load_f64(&self.spill_bps),
            samples: self.samples.load(Ordering::Relaxed),
        }
    }

    /// One-shot startup microbench: overwrites the prior with direct
    /// measurements (live EWMA updates refine from there).  Takes a few
    /// milliseconds; call once, off the serving path.  Any section that
    /// fails (e.g. no writable temp dir for the spill probe) leaves its
    /// prior in place rather than erroring.
    pub fn calibrate(&self) -> CostSnapshot {
        self.bench_memcpy();
        self.bench_tiles();
        self.bench_spill();
        self.samples.fetch_add(1, Ordering::Relaxed);
        self.snapshot()
    }

    fn bench_memcpy(&self) {
        let src = vec![0x5Au8; MEMCPY_BYTES];
        let mut dst = vec![0u8; MEMCPY_BYTES];
        dst.copy_from_slice(&src); // warmup + page fault
        let t0 = Instant::now();
        for _ in 0..BENCH_REPS {
            dst.copy_from_slice(&src);
            std::hint::black_box(&mut dst);
        }
        let s = t0.elapsed().as_secs_f64();
        // copy_from_slice touches 2 bytes of memory per output byte
        // (read + write); report deliverable bandwidth (bytes moved per
        // second), matching how the PCIe beta term is used.
        store_f64(&self.memcpy_bps, (MEMCPY_BYTES * BENCH_REPS) as f64 / s);
    }

    fn bench_tiles(&self) {
        let mut rng = Xoshiro256::new(0xCA11B);
        let mut data = vec![0i32; BENCH_H * BENCH_W];
        rng.fill_bins(&mut data, BENCH_BINS as u32);
        let img = BinnedImage::new(BENCH_H, BENCH_W, BENCH_BINS, data);
        let pixel_bins = (BENCH_H * BENCH_W * BENCH_BINS) as f64;
        let mut colc = vec![0.0f32; BENCH_BINS * BENCH_H];
        let mut out = vec![0.0f32; BENCH_BINS * BENCH_H * BENCH_W];
        let mut scratch = TileScratch::default();
        for (i, &tile) in TILE_CANDIDATES.iter().enumerate() {
            for variant in KernelVariant::ALL {
                // Warmup sizes the scratch and faults the pages in.
                colc.fill(0.0);
                fused_scan_into_v(&img, tile, &mut colc, &mut scratch, &mut out, variant);
                let t0 = Instant::now();
                for _ in 0..BENCH_REPS {
                    colc.fill(0.0);
                    fused_scan_into_v(&img, tile, &mut colc, &mut scratch, &mut out, variant);
                    std::hint::black_box(&mut out);
                }
                let s = t0.elapsed().as_secs_f64();
                let tput = pixel_bins * BENCH_REPS as f64 / s;
                let cell = match variant {
                    KernelVariant::Reference => &self.tile_tput[i],
                    KernelVariant::Tuned => &self.tile_tput_tuned[i],
                };
                store_f64(cell, tput);
            }
        }
    }

    fn bench_spill(&self) {
        let Ok(store) = TensorStore::spill(1, SPILL_ROWS, SPILL_COLS) else { return };
        let rows: Vec<f32> = (0..SPILL_ROWS * SPILL_COLS).map(|i| i as f32).collect();
        if store.write_rows(0, 0, &rows).is_err() {
            return;
        }
        let _ = store.flush();
        // Latency: positioned single-row reads (the Eq. 2 corner-read
        // access shape, amortized over the checksum verify).
        let mut row = vec![0.0f32; SPILL_COLS];
        let t0 = Instant::now();
        let mut reads = 0usize;
        for r in 0..SPILL_ROWS {
            if store.read_rows(0, r, 1, &mut row).is_ok() {
                reads += 1;
            }
        }
        if reads > 0 {
            store_f64(&self.spill_lat_s, t0.elapsed().as_secs_f64() / reads as f64);
        }
        // Bandwidth: one sequential full-tensor read.
        let mut all = vec![0.0f32; SPILL_ROWS * SPILL_COLS];
        let t1 = Instant::now();
        if store.read_rows(0, 0, SPILL_ROWS, &mut all).is_ok() {
            store_f64(&self.spill_bps, (all.len() * 4) as f64 / t1.elapsed().as_secs_f64());
        }
        std::hint::black_box(&all);
    }

    /// Fold one live tile-kernel measurement: an engine (or shard
    /// worker) computed `pixel_bins` output elements with `variant` at
    /// `tile` in `dur` — the `ShardReport.kernel_by_shard` feedback
    /// path.  Lock-free; safe from any thread.
    pub fn observe_tile(&self, tile: usize, variant: KernelVariant, pixel_bins: f64, dur: Duration) {
        let s = dur.as_secs_f64();
        if s <= 0.0 || pixel_bins <= 0.0 {
            return;
        }
        let i = CostSnapshot::tile_index(tile);
        let cell = match variant {
            KernelVariant::Reference => &self.tile_tput[i],
            KernelVariant::Tuned => &self.tile_tput_tuned[i],
        };
        ewma_f64(cell, pixel_bins / s);
        self.samples.fetch_add(1, Ordering::Relaxed);
    }

    /// Fold one live spill-read measurement (`bytes` read in `dur`).
    pub fn observe_spill_read(&self, bytes: usize, dur: Duration) {
        let s = dur.as_secs_f64();
        if s <= 0.0 || bytes == 0 {
            return;
        }
        ewma_f64(&self.spill_lat_s, s);
        ewma_f64(&self.spill_bps, bytes as f64 / s);
        self.samples.fetch_add(1, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn snapshot_starts_at_the_prior() {
        let c = Calibrator::new(Card::TitanX);
        assert_eq!(c.snapshot(), CostSnapshot::static_prior(Card::TitanX));
        assert!(c.snapshot().is_prior());
    }

    #[test]
    fn observe_tile_moves_the_estimate() {
        let c = Calibrator::new(Card::Gtx480);
        let before = c.snapshot().tile_throughput[1];
        // 1e6 elements in 1 ms → 1e9 el/s, far from the prior.
        c.observe_tile(32, KernelVariant::Reference, 1e6, Duration::from_millis(1));
        let after = c.snapshot();
        let expect = before + EWMA_ALPHA * (1e9 - before);
        assert!((after.tile_throughput[1] - expect).abs() < expect * 1e-9);
        assert_eq!(after.samples, 1);
        assert!(!after.is_prior());
        // Other slots untouched.
        assert_eq!(after.tile_throughput[0], before);
        assert_eq!(after.tile_throughput_tuned[1], before);
    }

    #[test]
    fn degenerate_observations_are_dropped() {
        let c = Calibrator::new(Card::Gtx480);
        let before = c.snapshot();
        c.observe_tile(64, KernelVariant::Tuned, 1e6, Duration::ZERO);
        c.observe_tile(64, KernelVariant::Tuned, 0.0, Duration::from_millis(1));
        c.observe_spill_read(0, Duration::from_millis(1));
        c.observe_spill_read(100, Duration::ZERO);
        assert_eq!(c.snapshot(), before, "degenerate samples must not move anything");
    }

    #[test]
    fn calibrate_produces_positive_finite_estimates() {
        let c = Calibrator::new(Card::Gtx480);
        let t0 = Instant::now();
        let s = c.calibrate();
        assert!(t0.elapsed() < Duration::from_secs(10), "microbench must be quick");
        assert!(!s.is_prior());
        assert!(s.memcpy_bps.is_finite() && s.memcpy_bps > 0.0);
        for i in 0..TILE_CANDIDATES.len() {
            assert!(s.tile_throughput[i] > 0.0 && s.tile_throughput[i].is_finite(), "tile {i}");
            assert!(s.tile_throughput_tuned[i] > 0.0, "tuned tile {i}");
        }
        assert!(s.spill_read_latency_s > 0.0 && s.spill_read_bps > 0.0);
        // Sanitizing a real calibration is the identity.
        assert_eq!(s.sanitized(Card::Gtx480), s);
    }

    #[test]
    fn concurrent_observers_never_poison_the_snapshot() {
        let c = Arc::new(Calibrator::new(Card::K40c));
        std::thread::scope(|scope| {
            for t in 0..4u64 {
                let c = Arc::clone(&c);
                scope.spawn(move || {
                    for n in 1..200u64 {
                        let tile = TILE_CANDIDATES[(n % 4) as usize];
                        let v = if n % 2 == 0 { KernelVariant::Reference } else { KernelVariant::Tuned };
                        c.observe_tile(tile, v, (t * n) as f64 + 1.0, Duration::from_nanos(n));
                        c.observe_spill_read(n as usize, Duration::from_nanos(n));
                        let s = c.snapshot();
                        assert!(s.best_throughput().is_finite());
                    }
                });
            }
        });
        let s = c.snapshot();
        assert_eq!(s.samples, 4 * 199 * 2);
        assert_eq!(s.sanitized(Card::K40c), s, "all estimates stay healthy");
    }
}
