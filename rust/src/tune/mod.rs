//! Runtime calibration + auto-tuning — closing the predicted-vs-
//! measured loop (DESIGN.md §9).
//!
//! The paper's central claim is that the *mapping* — tile size, scan
//! order, data organization — determines utilization, and its §3.5/§4
//! cost models pick that mapping from hardware constants.  Those
//! constants describe a GTX Titan X, not this host: the ROADMAP's
//! "predicted-vs-measured drift" item exists because
//! `ShardReport.kernel_by_shard` already measures real per-shard times
//! while both planners keep costing plans with paper numbers.  This
//! module replaces the constants with measurements:
//!
//! * [`Calibrator`] ([`calibrate`]) — one-shot startup microbenches
//!   (memcpy bandwidth, fused-kernel throughput per tile size and
//!   kernel variant, spill-file read latency), then EWMA-updated live
//!   estimates fed from every engine compute and shard report.  The
//!   hot path never locks: estimates live in atomics and
//!   [`Calibrator::snapshot`] is a handful of relaxed loads into a
//!   `Copy` [`CostSnapshot`].
//! * [`TunedPlanner`] ([`autotune`]) — the engine planner's strategy +
//!   tile choice becomes a cached per-`(h, w, bins, workers)` search
//!   over the calibrated model, so steady-state frames pay zero
//!   search; the cache persists to JSON across runs.
//! * The shard planner gains [`crate::shard::ShardPlan::predict_with`]
//!   / [`crate::shard::ShardPlanner::plan_calibrated`] — shard sizing
//!   costed with measured numbers, the static paper constants kept as
//!   the cold-start prior ([`CostSnapshot::static_prior`]).
//!
//! The adaptive-configuration argument comes from "Fast Histograms
//! using Adaptive CUDA Streams" (PAPERS.md): pick the execution
//! configuration online per input, don't fix it offline.

pub mod autotune;
pub mod calibrate;

pub use autotune::{TunedPlanner, TuneStats};
pub use calibrate::Calibrator;

use crate::histogram::engine::kernel::KernelVariant;
use crate::histogram::types::Strategy;
use crate::simulator::gpu_model::{kernel_throughput_prior, LAUNCH_OVERHEAD};
use crate::simulator::pcie::{Card, PcieModel};

/// Tile edges the calibrator benches and the auto-tuner searches over.
/// Covers the planner's whole [`crate::histogram::engine::planner::default_tile`]
/// range plus one step beyond in each direction.
pub const TILE_CANDIDATES: [usize; 4] = [16, 32, 64, 128];

/// A point-in-time, lock-free view of every calibrated estimate — the
/// `Copy` struct both planners cost plans with.  Obtained from
/// [`Calibrator::snapshot`] (relaxed atomic loads, no locks) or from
/// [`CostSnapshot::static_prior`] (the paper constants, used until
/// measurements arrive).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CostSnapshot {
    /// Host memory-copy bandwidth, bytes/s — the stand-in for the
    /// paper's PCIe link on the CPU substrate (image hand-off, tensor
    /// reassembly traffic).
    pub memcpy_bps: f64,
    /// Effective fused-kernel throughput, output elements (pixel·bins)
    /// per second, at each [`TILE_CANDIDATES`] edge — the reference
    /// kernel.
    pub tile_throughput: [f64; TILE_CANDIDATES.len()],
    /// Same, for the tuned (row-blocked + unrolled) kernel variant.
    pub tile_throughput_tuned: [f64; TILE_CANDIDATES.len()],
    /// Per-task dispatch overhead, seconds — the CPU analog of the
    /// §3.3 kernel-launch overhead.  Kept at the paper prior (5 µs):
    /// it is below the measurement noise floor of a one-shot
    /// microbench, and the live tile throughputs already fold the real
    /// hand-off cost in.
    pub dispatch_overhead_s: f64,
    /// Spill-file positioned-read latency, seconds per read call.
    pub spill_read_latency_s: f64,
    /// Spill-file sequential read bandwidth, bytes/s.
    pub spill_read_bps: f64,
    /// Live measurements folded in so far; 0 ⇒ this is a pure prior.
    pub samples: u64,
}

impl CostSnapshot {
    /// The cold-start prior: every estimate derived from the paper's
    /// static models for `card` — §3.5 memory-bandwidth kernel bound,
    /// §3.3 launch overhead, and the PCIe affine transfer model.  This
    /// is exactly what the planners used before calibration existed,
    /// so an uncalibrated system plans identically to the old one.
    pub fn static_prior(card: Card) -> CostSnapshot {
        let tput = kernel_throughput_prior(card, Strategy::WfTis);
        let pcie = PcieModel::for_card(card);
        CostSnapshot {
            memcpy_bps: pcie.beta_bps,
            tile_throughput: [tput; TILE_CANDIDATES.len()],
            tile_throughput_tuned: [tput; TILE_CANDIDATES.len()],
            dispatch_overhead_s: LAUNCH_OVERHEAD.as_secs_f64(),
            spill_read_latency_s: pcie.alpha_s,
            spill_read_bps: pcie.beta_bps,
            samples: 0,
        }
    }

    /// Index of the [`TILE_CANDIDATES`] entry nearest `tile`.
    pub fn tile_index(tile: usize) -> usize {
        let mut best = 0;
        let mut best_d = usize::MAX;
        for (i, &c) in TILE_CANDIDATES.iter().enumerate() {
            let d = c.abs_diff(tile);
            if d < best_d {
                best_d = d;
                best = i;
            }
        }
        best
    }

    /// Calibrated throughput (pixel·bins/s) for a tile edge and kernel
    /// variant (nearest bench point).
    pub fn throughput(&self, tile: usize, variant: KernelVariant) -> f64 {
        let i = Self::tile_index(tile);
        match variant {
            KernelVariant::Reference => self.tile_throughput[i],
            KernelVariant::Tuned => self.tile_throughput_tuned[i],
        }
    }

    /// The best throughput any (tile, variant) pair offers — what a
    /// well-tuned engine achieves on a shard's sub-image.
    pub fn best_throughput(&self) -> f64 {
        self.tile_throughput
            .iter()
            .chain(self.tile_throughput_tuned.iter())
            .copied()
            .fold(f64::MIN_POSITIVE, f64::max)
    }

    /// True until the first live measurement lands.
    pub fn is_prior(&self) -> bool {
        self.samples == 0
    }

    /// Defensive copy for planning: any estimate that is non-finite,
    /// non-positive, or outside its physically plausible band (a
    /// degenerate microbench, a zero-duration observation, poisoned
    /// EWMA state) is replaced by the static prior for `card`.  The
    /// bands matter: a denormal-adjacent throughput like
    /// `f64::MIN_POSITIVE` is "positive and finite" yet dividing any
    /// real work amount by it overflows to infinity, so rates are
    /// bounded to `[1, 1e18]` units/s and per-event times to
    /// `[1e-12, 1e3]` s.  Planners cost with the sanitized view, so
    /// adversarial calibration inputs can skew a plan's *choice* but
    /// never make planning panic, produce a non-finite cost, or bust a
    /// budget (`tests/tune_property.rs`).
    pub fn sanitized(&self, card: Card) -> CostSnapshot {
        let prior = CostSnapshot::static_prior(card);
        let fix =
            |x: f64, p: f64, lo: f64, hi: f64| if x.is_finite() && x >= lo && x <= hi { x } else { p };
        let rate = |x: f64, p: f64| fix(x, p, 1.0, 1e18);
        let time = |x: f64, p: f64| fix(x, p, 1e-12, 1e3);
        let mut s = *self;
        s.memcpy_bps = rate(s.memcpy_bps, prior.memcpy_bps);
        for i in 0..TILE_CANDIDATES.len() {
            s.tile_throughput[i] = rate(s.tile_throughput[i], prior.tile_throughput[i]);
            s.tile_throughput_tuned[i] =
                rate(s.tile_throughput_tuned[i], prior.tile_throughput_tuned[i]);
        }
        s.dispatch_overhead_s = time(s.dispatch_overhead_s, prior.dispatch_overhead_s);
        s.spill_read_latency_s = time(s.spill_read_latency_s, prior.spill_read_latency_s);
        s.spill_read_bps = rate(s.spill_read_bps, prior.spill_read_bps);
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn static_prior_is_positive_and_finite() {
        for card in Card::ALL {
            let s = CostSnapshot::static_prior(card);
            assert!(s.is_prior());
            assert!(s.memcpy_bps > 0.0 && s.memcpy_bps.is_finite());
            assert!(s.best_throughput() > 0.0);
            assert!(s.dispatch_overhead_s > 0.0);
            assert!(s.spill_read_latency_s > 0.0 && s.spill_read_bps > 0.0);
            // §3.5: WF-TiS touches 2 passes × 4 bytes per element.
            let bw = crate::simulator::gpu_model::device_mem_bandwidth(card);
            assert_eq!(s.tile_throughput[0], bw / 8.0, "{}", card.name());
        }
    }

    #[test]
    fn tile_index_picks_nearest_candidate() {
        assert_eq!(CostSnapshot::tile_index(16), 0);
        assert_eq!(CostSnapshot::tile_index(1), 0);
        assert_eq!(CostSnapshot::tile_index(33), 1);
        assert_eq!(CostSnapshot::tile_index(64), 2);
        assert_eq!(CostSnapshot::tile_index(4096), 3);
    }

    #[test]
    fn sanitized_replaces_degenerate_estimates_only() {
        let mut s = CostSnapshot::static_prior(Card::Gtx480);
        s.samples = 9;
        s.memcpy_bps = f64::NAN;
        s.tile_throughput[1] = 0.0;
        s.tile_throughput[3] = f64::MIN_POSITIVE; // would overflow any division
        s.tile_throughput_tuned[2] = f64::INFINITY;
        s.tile_throughput_tuned[3] = 1e300; // far outside the rate band
        s.dispatch_overhead_s = 1e9; // outside the per-event time band
        s.spill_read_bps = -3.0;
        let good = s.tile_throughput[0];
        let fixed = s.sanitized(Card::Gtx480);
        let prior = CostSnapshot::static_prior(Card::Gtx480);
        assert_eq!(fixed.memcpy_bps, prior.memcpy_bps);
        assert_eq!(fixed.tile_throughput[1], prior.tile_throughput[1]);
        assert_eq!(fixed.tile_throughput[3], prior.tile_throughput[3]);
        assert_eq!(fixed.tile_throughput_tuned[2], prior.tile_throughput_tuned[2]);
        assert_eq!(fixed.tile_throughput_tuned[3], prior.tile_throughput_tuned[3]);
        assert_eq!(fixed.dispatch_overhead_s, prior.dispatch_overhead_s);
        assert_eq!(fixed.spill_read_bps, prior.spill_read_bps);
        assert_eq!(fixed.tile_throughput[0], good, "healthy estimates survive");
        assert_eq!(fixed.samples, 9);
    }
}
