//! Poison-tolerant locking.
//!
//! `std::sync::Mutex` poisons itself when a holder panics, and every
//! subsequent `lock().expect(..)` aborts the *next* caller — one crashed
//! request bricks the whole process.  Most locks in this codebase guard
//! state that is valid at every instruction boundary (free-list vectors,
//! LIFO checkout stacks, counter maps): a panic while holding them cannot
//! leave the protected value half-updated, so the poison flag carries no
//! information and the correct policy is to clear it and continue.
//!
//! [`lock_recover`] encodes that policy in one place.  Locks whose
//! invariants *can* break mid-update (e.g. a `ScanEngine` whose wavefront
//! scheduler was interrupted) must not use it — they either keep the
//! fail-fast `expect` or pair recovery with explicit invalidation of the
//! protected value (see `coordinator/pipeline.rs`).

use std::sync::{Mutex, MutexGuard, PoisonError};

/// Lock `m`, recovering from poisoning by taking the guard anyway.
///
/// Only use on locks whose protected state is valid at every instruction
/// boundary, or at call sites that re-validate / replace the state.
pub fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::panic::{catch_unwind, AssertUnwindSafe};
    use std::sync::Mutex;

    #[test]
    fn recovers_after_holder_panics() {
        let m = Mutex::new(vec![1u32, 2, 3]);
        let r = catch_unwind(AssertUnwindSafe(|| {
            let _g = m.lock().unwrap();
            panic!("holder dies");
        }));
        assert!(r.is_err());
        assert!(m.is_poisoned(), "panic while held must poison");
        let g = lock_recover(&m);
        assert_eq!(*g, vec![1, 2, 3], "state untouched by the panic");
    }

    #[test]
    fn plain_lock_on_clean_mutex() {
        let m = Mutex::new(7u8);
        assert_eq!(*lock_recover(&m), 7);
    }
}
