//! Self-contained utility substrates.
//!
//! The build environment is fully offline with a small vendored crate
//! set (no serde, no rand, no criterion), so the pieces a normal project
//! would pull from crates.io are implemented here:
//!
//! * [`json`] — a strict recursive-descent JSON parser for the artifact
//!   manifest emitted by `python/compile/aot.py`.
//! * [`prng`] — a splitmix64/xoshiro256** PRNG for synthetic workloads
//!   and the property-based tests.
//! * [`stats`] — timing statistics (median/percentiles/MAD) used by the
//!   benchmark harness and the figure drivers.
//! * [`sync`] — poison-tolerant locking (`lock_recover`) so one crashed
//!   request cannot brick shared state behind a poisoned `Mutex`.

pub mod json;
pub mod prng;
pub mod stats;
pub mod sync;
