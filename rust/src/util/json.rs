//! Minimal strict JSON parser (RFC 8259 subset sufficient for the
//! artifact manifest).
//!
//! Supports objects, arrays, strings (with `\uXXXX` escapes), numbers,
//! booleans and null.  Numbers are parsed as `f64` (the manifest only
//! contains integers well inside the 2^53 exact range).  The parser
//! rejects trailing garbage, unterminated literals and malformed escapes
//! — errors carry the byte offset for diagnosis.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Member lookup on an object; `None` for non-objects/missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON parse error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for JsonError {}

/// Parse a complete JSON document; trailing non-whitespace is an error.
pub fn parse(input: &str) -> Result<Json, JsonError> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters after document"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: impl Into<String>) -> JsonError {
        JsonError { offset: self.pos, message: msg.into() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(format!("unexpected character '{}'", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn literal(&mut self, word: &str, val: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            Err(self.err(format!("invalid literal (expected '{word}')")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // surrogate pair handling
                        let ch = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("unpaired high surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(c).ok_or_else(|| self.err("invalid codepoint"))?
                        } else {
                            char::from_u32(cp).ok_or_else(|| self.err("invalid codepoint"))?
                        };
                        out.push(ch);
                    }
                    _ => return Err(self.err("invalid escape sequence")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control character in string")),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences verbatim.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(c).ok_or_else(|| self.err("invalid UTF-8"))?;
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated UTF-8"));
                        }
                        let s = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid UTF-8"))?;
                        out.push_str(s);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("invalid hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| JsonError { offset: start, message: format!("invalid number '{text}'") })
    }
}

fn utf8_len(first: u8) -> Option<usize> {
    match first {
        0xC0..=0xDF => Some(2),
        0xE0..=0xEF => Some(3),
        0xF0..=0xF7 => Some(4),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].as_usize(), Some(2));
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn parses_escapes() {
        assert_eq!(parse(r#""a\nb\tA""#).unwrap(), Json::Str("a\nb\tA".into()));
        assert_eq!(parse(r#""😀""#).unwrap(), Json::Str("😀".into()));
    }

    #[test]
    fn parses_unicode_passthrough() {
        assert_eq!(parse("\"héllo ∀\"").unwrap(), Json::Str("héllo ∀".into()));
    }

    #[test]
    fn rejects_malformed() {
        for bad in ["{", "[1,", "\"abc", "01x", "{\"a\":}", "true false", "", "nul"] {
            assert!(parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(parse("{} x").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }

    #[test]
    fn accessor_type_mismatches() {
        let v = parse("[1]").unwrap();
        assert!(v.get("k").is_none());
        assert!(v.as_str().is_none());
        assert!(Json::Num(1.5).as_usize().is_none());
        assert!(Json::Num(-1.0).as_usize().is_none());
    }
}
