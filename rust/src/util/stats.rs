//! Timing statistics for the benchmark harness and figure drivers.
//!
//! The figure drivers report medians (robust against CPU-scheduler noise)
//! with p10/p90 spread, matching how the paper reports per-kernel times
//! averaged over repeated runs.

use std::time::{Duration, Instant};

/// Summary statistics over a sample of durations (or any f64 series).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub median: f64,
    pub p10: f64,
    pub p90: f64,
    pub min: f64,
    pub max: f64,
}

impl Summary {
    /// Compute a summary; panics on empty input.
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "summary of empty sample");
        let mut sorted: Vec<f64> = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).expect("NaN in samples"));
        let n = sorted.len();
        Summary {
            n,
            mean: sorted.iter().sum::<f64>() / n as f64,
            median: percentile_sorted(&sorted, 0.5),
            p10: percentile_sorted(&sorted, 0.10),
            p90: percentile_sorted(&sorted, 0.90),
            min: sorted[0],
            max: sorted[n - 1],
        }
    }
}

/// Linear-interpolated percentile of an ascending-sorted slice, q in [0,1].
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=1.0).contains(&q), "percentile q={q} out of range");
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Measure a closure: `warmup` discarded calls, then `reps` timed calls.
/// Returns per-call timings in **milliseconds**.
pub fn time_ms<F: FnMut()>(warmup: usize, reps: usize, mut f: F) -> Vec<f64> {
    for _ in 0..warmup {
        f();
    }
    let mut out = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        out.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    out
}

/// One benchmark row: a label plus its timing summary (in ms).
#[derive(Debug, Clone)]
pub struct BenchRow {
    pub label: String,
    pub summary: Summary,
}

impl BenchRow {
    pub fn measure<F: FnMut()>(label: impl Into<String>, warmup: usize, reps: usize, f: F) -> Self {
        BenchRow { label: label.into(), summary: Summary::of(&time_ms(warmup, reps, f)) }
    }

    /// Frame rate implied by the median time of one frame, in Hz.
    pub fn fps(&self) -> f64 {
        1000.0 / self.summary.median
    }
}

/// Render rows as an aligned text table (label, median, p10, p90, fps).
pub fn render_table(title: &str, rows: &[BenchRow]) -> String {
    let mut s = String::new();
    s.push_str(&format!("## {title}\n"));
    s.push_str(&format!(
        "{:<44} {:>10} {:>10} {:>10} {:>10}\n",
        "case", "median ms", "p10 ms", "p90 ms", "fps"
    ));
    for r in rows {
        s.push_str(&format!(
            "{:<44} {:>10.3} {:>10.3} {:>10.3} {:>10.1}\n",
            r.label, r.summary.median, r.summary.p10, r.summary.p90, r.fps()
        ));
    }
    s
}

/// Convenience: duration → milliseconds as f64.
pub fn ms(d: Duration) -> f64 {
    d.as_secs_f64() * 1e3
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_constant() {
        let s = Summary::of(&[5.0; 10]);
        assert_eq!(s.median, 5.0);
        assert_eq!(s.mean, 5.0);
        assert_eq!(s.p10, 5.0);
        assert_eq!(s.p90, 5.0);
    }

    #[test]
    fn median_of_even_interpolates() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        assert!((s.median - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentiles_ordered() {
        let samples: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let s = Summary::of(&samples);
        assert!(s.min <= s.p10 && s.p10 <= s.median && s.median <= s.p90 && s.p90 <= s.max);
        assert!((s.p10 - 9.9).abs() < 1e-9);
        assert!((s.p90 - 89.1).abs() < 1e-9);
    }

    #[test]
    fn unsorted_input_ok() {
        let s = Summary::of(&[3.0, 1.0, 2.0]);
        assert_eq!(s.median, 2.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
    }

    #[test]
    fn time_ms_counts_reps() {
        let mut calls = 0;
        let t = time_ms(2, 5, || calls += 1);
        assert_eq!(t.len(), 5);
        assert_eq!(calls, 7);
    }

    #[test]
    fn bench_row_fps() {
        let row = BenchRow { label: "x".into(), summary: Summary::of(&[10.0]) };
        assert!((row.fps() - 100.0).abs() < 1e-9);
    }

    #[test]
    #[should_panic]
    fn empty_summary_panics() {
        Summary::of(&[]);
    }
}
