//! Deterministic PRNG for synthetic workloads and property tests.
//!
//! xoshiro256** (Blackman & Vigna) seeded through splitmix64 — the
//! standard offline-friendly combination.  Deterministic across runs and
//! platforms so figure workloads and property tests are reproducible.

/// xoshiro256** generator.
#[derive(Debug, Clone)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via splitmix64 so any u64 (including 0) is a valid seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Self { s: [next(), next(), next(), next()] }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, bound)` via Lemire's multiply-shift (bound > 0).
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "below(0) is meaningless");
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform usize in `[lo, hi)`; panics if the range is empty.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "empty range {lo}..{hi}");
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli with probability p.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fill a slice with uniform bin indices in `[0, bins)`.
    pub fn fill_bins(&mut self, out: &mut [i32], bins: u32) {
        for v in out.iter_mut() {
            *v = self.below(bins as u64) as i32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let a: Vec<u64> = (0..8).map({ let mut r = Xoshiro256::new(42); move |_| r.next_u64() }).collect();
        let b: Vec<u64> = (0..8).map({ let mut r = Xoshiro256::new(42); move |_| r.next_u64() }).collect();
        assert_eq!(a, b);
    }

    #[test]
    fn seeds_diverge() {
        let mut a = Xoshiro256::new(1);
        let mut b = Xoshiro256::new(2);
        assert_ne!(
            (0..4).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..4).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn below_is_in_bounds() {
        let mut r = Xoshiro256::new(7);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn below_covers_all_residues() {
        let mut r = Xoshiro256::new(3);
        let mut seen = [false; 8];
        for _ in 0..1_000 {
            seen[r.below(8) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues of below(8) should appear");
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Xoshiro256::new(9);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f64_mean_is_about_half() {
        let mut r = Xoshiro256::new(11);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean} far from 0.5");
    }

    #[test]
    fn fill_bins_respects_bound() {
        let mut r = Xoshiro256::new(5);
        let mut buf = vec![0i32; 4096];
        r.fill_bins(&mut buf, 32);
        assert!(buf.iter().all(|&v| (0..32).contains(&v)));
        // every bin should be hit at ~128 expected occupancy
        let mut counts = [0u32; 32];
        for &v in &buf {
            counts[v as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c > 0));
    }

    #[test]
    #[should_panic]
    fn empty_range_panics() {
        Xoshiro256::new(0).range(3, 3);
    }
}
