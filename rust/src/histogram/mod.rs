//! Integral-histogram substrate: core types, CPU baselines, region queries.
//!
//! This module is the paper's *comparator* and *consumer* side:
//!
//! * [`types`] — the `b×h×w` integral-histogram tensor (Fig. 2 layout:
//!   3-D array mapped onto a 1-D row-major buffer) and strategy ids.
//! * [`sequential`] — Algorithm 1, the single-threaded CPU baseline every
//!   speedup figure is normalized against.
//! * [`parallel`] — the multi-threaded CPU baseline (the paper's OpenMP
//!   implementation on a hyper-threaded 8-core Xeon; here std scoped
//!   threads, 1–16 workers, parallel over bins then rows).
//! * [`tiled`] — cache-blocked single-pass CPU variant: the WF-TiS data
//!   movement scheme applied to the CPU cache hierarchy (used by the
//!   §Perf pass and as another baseline).
//! * [`engine`] — the hot path: the planned `ScanEngine` (multi-bin
//!   fused tile sweeps, anti-diagonal wavefront scheduling, zero-alloc
//!   buffer reuse) that the baselines above are comparators for.
//! * [`scan`] — prefix-sum helpers + the Eq. 4 scan-efficiency model.
//! * [`region`] — Eq. 2 constant-time region queries and batched lookups.
//! * [`binning`] — intensity→bin quantization (the Q function input).

//! * [`temporal`] — the §2.1 higher-dimensional extension: 3-D
//!   spatio-temporal integral histograms with 8-corner box queries.

pub mod binning;
pub mod engine;
pub mod parallel;
pub mod region;
pub mod scan;
pub mod sequential;
pub mod temporal;
pub mod tiled;
pub mod types;
