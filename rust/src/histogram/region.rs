//! Eq. 2 — constant-time region histograms from the integral tensor.
//!
//! `h(R, b) = H(r+, c+, b) − H(r−, c+, b) − H(r+, c−, b) + H(r−, c−, b)`
//! with the inclusive convention: the subtracted corners lie one row /
//! column *outside* the rectangle and are dropped at the image border.
//! This is the O(1)-per-bin lookup the integral histogram exists to
//! provide (Fig. 1 right); the exhaustive-search analytics in
//! [`crate::analytics`] are built entirely on it.

use crate::histogram::types::IntegralHistogram;

/// An inclusive rectangle `[r0..=r1] × [c0..=c1]` in image coordinates.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Rect {
    pub r0: usize,
    pub c0: usize,
    pub r1: usize,
    pub c1: usize,
}

impl Rect {
    /// Construct; panics if corners are not ordered.
    pub fn new(r0: usize, c0: usize, r1: usize, c1: usize) -> Rect {
        assert!(r0 <= r1 && c0 <= c1, "rect corners out of order: ({r0},{c0})..({r1},{c1})");
        Rect { r0, c0, r1, c1 }
    }

    /// Rectangle from top-left corner plus size (height, width ≥ 1).
    pub fn with_size(r0: usize, c0: usize, height: usize, width: usize) -> Rect {
        assert!(height >= 1 && width >= 1, "empty rect");
        Rect::new(r0, c0, r0 + height - 1, c0 + width - 1)
    }

    pub fn height(&self) -> usize {
        self.r1 - self.r0 + 1
    }

    pub fn width(&self) -> usize {
        self.c1 - self.c0 + 1
    }

    pub fn area(&self) -> usize {
        self.height() * self.width()
    }

    /// True if the rectangle lies inside an h×w image.
    pub fn fits(&self, h: usize, w: usize) -> bool {
        self.r1 < h && self.c1 < w
    }

    /// Clamp to the image extent (panics if fully outside).
    pub fn clamped(&self, h: usize, w: usize) -> Rect {
        assert!(self.r0 < h && self.c0 < w, "rect origin outside image");
        Rect { r0: self.r0, c0: self.c0, r1: self.r1.min(h - 1), c1: self.c1.min(w - 1) }
    }

    /// Encode as the (r0, c0, r1, c1) i32 quad the `region_query` HLO
    /// artifact consumes.
    pub fn encode(&self) -> [i32; 4] {
        [self.r0 as i32, self.c0 as i32, self.r1 as i32, self.c1 as i32]
    }
}

/// Histogram of one rectangle: `bins` lookups, 4 reads each (Eq. 2).
pub fn region_histogram(ih: &IntegralHistogram, rect: Rect) -> Vec<f32> {
    assert!(rect.fits(ih.h, ih.w), "rect {rect:?} outside {}x{}", ih.h, ih.w);
    let mut out = Vec::with_capacity(ih.bins);
    let plane = ih.h * ih.w;
    let w = ih.w;
    let (r0, c0, r1, c1) = (rect.r0, rect.c0, rect.r1, rect.c1);
    for b in 0..ih.bins {
        let base = b * plane;
        let d = &ih.data[base..base + plane];
        let mut v = d[r1 * w + c1];
        if r0 > 0 {
            v -= d[(r0 - 1) * w + c1];
        }
        if c0 > 0 {
            v -= d[r1 * w + c0 - 1];
        }
        if r0 > 0 && c0 > 0 {
            v += d[(r0 - 1) * w + c0 - 1];
        }
        out.push(v);
    }
    out
}

/// Batched region histograms: (n rects) → n×bins row-major matrix.
pub fn region_histogram_batch(ih: &IntegralHistogram, rects: &[Rect]) -> Vec<Vec<f32>> {
    rects.iter().map(|&r| region_histogram(ih, r)).collect()
}

/// Total mass (pixel count) of a region from its histogram.
pub fn histogram_mass(hist: &[f32]) -> f32 {
    hist.iter().sum()
}

/// Histogram intersection similarity (Swain–Ballard), the matching score
/// used by the fragments-based tracker the paper cites ([13]).
/// Both inputs are normalized internally; returns a value in [0, 1].
pub fn intersection_similarity(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len(), "histogram length mismatch");
    let sa: f32 = a.iter().sum();
    let sb: f32 = b.iter().sum();
    if sa <= 0.0 || sb <= 0.0 {
        return 0.0;
    }
    a.iter().zip(b).map(|(&x, &y)| (x / sa).min(y / sb)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::histogram::sequential::integral_histogram_seq;
    use crate::histogram::types::BinnedImage;
    use crate::util::prng::Xoshiro256;

    fn brute_force(img: &BinnedImage, rect: Rect) -> Vec<f32> {
        let mut h = vec![0.0f32; img.bins];
        for r in rect.r0..=rect.r1 {
            for c in rect.c0..=rect.c1 {
                let v = img.at(r, c);
                if v >= 0 {
                    h[v as usize] += 1.0;
                }
            }
        }
        h
    }

    fn random_image(h: usize, w: usize, bins: usize, seed: u64) -> BinnedImage {
        let mut rng = Xoshiro256::new(seed);
        let mut data = vec![0i32; h * w];
        rng.fill_bins(&mut data, bins as u32);
        BinnedImage::new(h, w, bins, data)
    }

    #[test]
    fn rect_geometry() {
        let r = Rect::with_size(2, 3, 4, 5);
        assert_eq!((r.r1, r.c1), (5, 7));
        assert_eq!(r.height(), 4);
        assert_eq!(r.width(), 5);
        assert_eq!(r.area(), 20);
        assert!(r.fits(6, 8));
        assert!(!r.fits(5, 8));
        assert_eq!(r.encode(), [2, 3, 5, 7]);
    }

    #[test]
    fn rect_clamp() {
        let r = Rect::new(1, 1, 100, 100).clamped(10, 20);
        assert_eq!((r.r1, r.c1), (9, 19));
    }

    #[test]
    #[should_panic]
    fn rect_rejects_disorder() {
        Rect::new(3, 0, 1, 5);
    }

    /// Property: Eq. 2 equals brute-force counting for random rects —
    /// the core invariant of the whole system.
    #[test]
    fn region_matches_brute_force_property() {
        let img = random_image(37, 53, 8, 99);
        let ih = integral_histogram_seq(&img);
        let mut rng = Xoshiro256::new(7);
        for _ in 0..200 {
            let r0 = rng.range(0, 37);
            let c0 = rng.range(0, 53);
            let r1 = rng.range(r0, 37);
            let c1 = rng.range(c0, 53);
            let rect = Rect::new(r0, c0, r1, c1);
            let fast = region_histogram(&ih, rect);
            let slow = brute_force(&img, rect);
            assert_eq!(fast, slow, "mismatch at {rect:?}");
        }
    }

    #[test]
    fn full_image_region_is_global_histogram() {
        let img = random_image(16, 16, 4, 3);
        let ih = integral_histogram_seq(&img);
        let hist = region_histogram(&ih, Rect::new(0, 0, 15, 15));
        assert_eq!(histogram_mass(&hist), 256.0);
    }

    #[test]
    fn intersection_similarity_properties() {
        let a = vec![1.0, 2.0, 3.0];
        // self-similarity is 1
        assert!((intersection_similarity(&a, &a) - 1.0).abs() < 1e-6);
        // disjoint histograms score 0
        assert_eq!(intersection_similarity(&[1.0, 0.0], &[0.0, 1.0]), 0.0);
        // symmetric
        let b = vec![3.0, 1.0, 0.5];
        let ab = intersection_similarity(&a, &b);
        let ba = intersection_similarity(&b, &a);
        assert!((ab - ba).abs() < 1e-6);
        // empty histogram guard
        assert_eq!(intersection_similarity(&[0.0, 0.0], &a[..2]), 0.0);
    }

    #[test]
    fn batch_matches_singles() {
        let img = random_image(20, 20, 4, 11);
        let ih = integral_histogram_seq(&img);
        let rects = vec![Rect::new(0, 0, 19, 19), Rect::new(3, 4, 10, 12)];
        let batch = region_histogram_batch(&ih, &rects);
        for (i, &r) in rects.iter().enumerate() {
            assert_eq!(batch[i], region_histogram(&ih, r));
        }
    }
}
