//! Algorithm 1 — the sequential CPU integral histogram.
//!
//! The O(N) recursive row-dependent method every speedup number in the
//! paper (Figs. 17, 19, 20) is normalized against:
//!
//! ```text
//! H(k,x,y) = H(k,x−1,y) + H(k,x,y−1) − H(k,x−1,y−1) + Q(k, I(x,y))
//! ```
//!
//! Two variants are provided: [`integral_histogram_seq`] is the literal
//! Algorithm 1 (bin-major loops, wavefront recurrence), and
//! [`integral_histogram_seq_rowsum`] is the classic running-row-sum
//! formulation with identical output, used to cross-check and as the
//! §Perf-pass optimized single-thread baseline.

use crate::histogram::types::{BinnedImage, IntegralHistogram};

/// Literal Algorithm 1: one plane per bin, four-term recurrence.
pub fn integral_histogram_seq(img: &BinnedImage) -> IntegralHistogram {
    let (h, w, bins) = (img.h, img.w, img.bins);
    let mut ih = IntegralHistogram::zeros(bins, h, w);
    for k in 0..bins {
        let base = k * h * w;
        for x in 0..h {
            for y in 0..w {
                let q = (img.data[x * w + y] == k as i32) as u32 as f32;
                let up = if x > 0 { ih.data[base + (x - 1) * w + y] } else { 0.0 };
                let left = if y > 0 { ih.data[base + x * w + y - 1] } else { 0.0 };
                let diag = if x > 0 && y > 0 { ih.data[base + (x - 1) * w + y - 1] } else { 0.0 };
                ih.data[base + x * w + y] = up + left - diag + q;
            }
        }
    }
    ih
}

/// Running-row-sum formulation: for each bin plane keep the cumulative
/// sum of the current row and add the row above.  Same output, fewer
/// dependent loads — the tuned single-threaded baseline.
pub fn integral_histogram_seq_rowsum(img: &BinnedImage) -> IntegralHistogram {
    let (h, w, bins) = (img.h, img.w, img.bins);
    let mut ih = IntegralHistogram::zeros(bins, h, w);
    for k in 0..bins {
        let base = k * h * w;
        let kk = k as i32;
        for x in 0..h {
            let mut rowsum = 0.0f32;
            let row = base + x * w;
            let above = row.wrapping_sub(w);
            for y in 0..w {
                rowsum += (img.data[x * w + y] == kk) as u32 as f32;
                let up = if x > 0 { ih.data[above + y] } else { 0.0 };
                ih.data[row + y] = rowsum + up;
            }
        }
    }
    ih
}

/// Single-pass variant that scans the image once and scatters into all
/// bin planes (image-major instead of bin-major).  Matches how a CPU
/// implementation would avoid re-reading the image `bins` times; used in
/// the ablation bench for the memory-traffic argument of §3.5.
pub fn integral_histogram_seq_imagemajor(img: &BinnedImage) -> IntegralHistogram {
    let (h, w, bins) = (img.h, img.w, img.bins);
    let plane = h * w;
    let mut ih = IntegralHistogram::zeros(bins, h, w);
    // rowsum per bin for the current row
    let mut rowsum = vec![0.0f32; bins];
    for x in 0..h {
        rowsum.iter_mut().for_each(|v| *v = 0.0);
        for y in 0..w {
            let v = img.data[x * w + y];
            if v >= 0 {
                rowsum[v as usize] += 1.0;
            }
            for k in 0..bins {
                let base = k * plane;
                let up = if x > 0 { ih.data[base + (x - 1) * w + y] } else { 0.0 };
                ih.data[base + x * w + y] = rowsum[k] + up;
            }
        }
    }
    ih
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::histogram::types::BinnedImage;
    use crate::util::prng::Xoshiro256;

    fn random_image(h: usize, w: usize, bins: usize, seed: u64) -> BinnedImage {
        let mut rng = Xoshiro256::new(seed);
        let mut data = vec![0i32; h * w];
        rng.fill_bins(&mut data, bins as u32);
        BinnedImage::new(h, w, bins, data)
    }

    fn brute(img: &BinnedImage, b: usize, x: usize, y: usize) -> f32 {
        let mut s = 0.0;
        for r in 0..=x {
            for c in 0..=y {
                if img.at(r, c) == b as i32 {
                    s += 1.0;
                }
            }
        }
        s
    }

    #[test]
    fn matches_brute_force() {
        let img = random_image(9, 13, 4, 1);
        let ih = integral_histogram_seq(&img);
        for b in 0..4 {
            for x in [0, 3, 8] {
                for y in [0, 5, 12] {
                    assert_eq!(ih.at(b, x, y), brute(&img, b, x, y));
                }
            }
        }
    }

    #[test]
    fn corner_sums_to_pixel_count() {
        let img = random_image(17, 11, 8, 2);
        let ih = integral_histogram_seq(&img);
        let total: f32 = (0..8).map(|b| ih.at(b, 16, 10)).sum();
        assert_eq!(total, (17 * 11) as f32);
    }

    #[test]
    fn variants_agree() {
        let img = random_image(23, 31, 8, 3);
        let a = integral_histogram_seq(&img);
        let b = integral_histogram_seq_rowsum(&img);
        let c = integral_histogram_seq_imagemajor(&img);
        assert_eq!(a.max_abs_diff(&b), 0.0);
        assert_eq!(a.max_abs_diff(&c), 0.0);
    }

    #[test]
    fn negative_bin_counts_nowhere() {
        // padding pixels (bin −1) contribute to no plane
        let img = BinnedImage::new(2, 2, 2, vec![-1, 0, 1, -1]);
        let ih = integral_histogram_seq_rowsum(&img);
        assert_eq!(ih.at(0, 1, 1), 1.0);
        assert_eq!(ih.at(1, 1, 1), 1.0);
        let im = integral_histogram_seq_imagemajor(&img);
        assert_eq!(ih.max_abs_diff(&im), 0.0);
    }

    #[test]
    fn single_pixel_image() {
        let img = BinnedImage::new(1, 1, 3, vec![2]);
        let ih = integral_histogram_seq(&img);
        assert_eq!(ih.at(2, 0, 0), 1.0);
        assert_eq!(ih.at(0, 0, 0), 0.0);
    }

    /// Monotonicity property: integral histograms are nondecreasing
    /// along rows and columns for every bin.
    #[test]
    fn monotone_property() {
        let img = random_image(16, 16, 4, 5);
        let ih = integral_histogram_seq_rowsum(&img);
        for b in 0..4 {
            for x in 0..16 {
                for y in 1..16 {
                    assert!(ih.at(b, x, y) >= ih.at(b, x, y - 1));
                }
            }
            for y in 0..16 {
                for x in 1..16 {
                    assert!(ih.at(b, x, y) >= ih.at(b, x - 1, y));
                }
            }
        }
    }
}
