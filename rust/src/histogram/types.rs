//! Core data structures: the integral-histogram tensor and strategy ids.
//!
//! The integral histogram of an `h×w` image with `b` bins is a `b×h×w`
//! tensor stored bin-major in one contiguous 1-D row-major buffer —
//! exactly the Fig. 2 layout the paper uses so the whole tensor moves
//! over PCIe in a single transaction.

use crate::histogram::region::Rect;
use std::fmt;
use std::str::FromStr;

/// The four GPU kernel strategies evaluated by the paper (§3), plus the
/// CPU baselines used in the speedup figures.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Strategy {
    /// Algorithm 2 — naive cross-weave baseline (SDK prescan + 2-D transpose).
    CwB,
    /// Algorithm 3 — single scan-transpose-scan (SDK kernels, one launch each).
    CwSts,
    /// Algorithm 4 — custom tiled horizontal/vertical strip scans.
    CwTis,
    /// Algorithm 5 — fused wave-front tiled scan (the paper's fastest).
    WfTis,
}

impl Strategy {
    pub const ALL: [Strategy; 4] = [Strategy::CwB, Strategy::CwSts, Strategy::CwTis, Strategy::WfTis];

    /// The artifact-name prefix used by `python/compile/aot.py`.
    pub fn artifact_prefix(self) -> &'static str {
        match self {
            Strategy::CwB => "cw_b",
            Strategy::CwSts => "cw_sts",
            Strategy::CwTis => "cw_tis",
            Strategy::WfTis => "wf_tis",
        }
    }

    /// Number of distinct kernel launches the GPU implementation issues
    /// for an `h×w` image with `b` bins (§3.3): the launch-overhead model
    /// used by [`crate::simulator::gpu_model`].  CW-B launches one scan
    /// per row per bin plus per-bin transposes; the others are O(1).
    pub fn kernel_launches(self, h: usize, w: usize, bins: usize, tile: usize) -> usize {
        match self {
            Strategy::CwB => bins * h + bins + bins * w,
            Strategy::CwSts => 3,
            // one launch per strip per pass
            Strategy::CwTis => w.div_ceil(tile) + h.div_ceil(tile),
            // one launch per anti-diagonal (Eq. 6)
            Strategy::WfTis => w.div_ceil(tile) + h.div_ceil(tile) - 1,
        }
    }

    /// Number of times the b×h×w tensor crosses the global-memory
    /// boundary (reads + writes), the §3.5 traffic argument:
    /// CW-B/CW-STS: scan(2) + transpose(2) + scan(2) + transpose(2);
    /// CW-TiS: two passes; WF-TiS: single fused pass.
    pub fn tensor_passes(self) -> usize {
        match self {
            Strategy::CwB | Strategy::CwSts => 8,
            Strategy::CwTis => 4,
            Strategy::WfTis => 2,
        }
    }
}

impl fmt::Display for Strategy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.artifact_prefix())
    }
}

impl FromStr for Strategy {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "cw_b" | "cw-b" => Ok(Strategy::CwB),
            "cw_sts" | "cw-sts" => Ok(Strategy::CwSts),
            "cw_tis" | "cw-tis" => Ok(Strategy::CwTis),
            "wf_tis" | "wf-tis" => Ok(Strategy::WfTis),
            other => Err(format!("unknown strategy '{other}' (expected cw_b|cw_sts|cw_tis|wf_tis)")),
        }
    }
}

/// An image already quantized to bin indices (the input to every kernel).
#[derive(Debug, Clone, PartialEq)]
pub struct BinnedImage {
    pub h: usize,
    pub w: usize,
    pub bins: usize,
    /// Row-major h×w bin indices; −1 means "no bin" (padding).
    pub data: Vec<i32>,
}

impl BinnedImage {
    pub fn new(h: usize, w: usize, bins: usize, data: Vec<i32>) -> Self {
        assert_eq!(data.len(), h * w, "data length must be h*w");
        debug_assert!(
            data.iter().all(|&v| v >= -1 && (v as i64) < bins as i64),
            "bin index out of range"
        );
        BinnedImage { h, w, bins, data }
    }

    pub fn at(&self, r: usize, c: usize) -> i32 {
        self.data[r * self.w + c]
    }

    /// Zero-pad (bin −1) to the next multiple of `tile` in each dim,
    /// the §3.4 padding rule.  Returns self unchanged if already aligned.
    pub fn pad_to_tile(&self, tile: usize) -> BinnedImage {
        let ph = self.h.div_ceil(tile) * tile;
        let pw = self.w.div_ceil(tile) * tile;
        if ph == self.h && pw == self.w {
            return self.clone();
        }
        let mut data = vec![-1i32; ph * pw];
        for r in 0..self.h {
            data[r * pw..r * pw + self.w].copy_from_slice(&self.data[r * self.w..(r + 1) * self.w]);
        }
        BinnedImage { h: ph, w: pw, bins: self.bins, data }
    }
}

/// The `b×h×w` integral-histogram tensor (inclusive convention).
#[derive(Debug, Clone, PartialEq)]
pub struct IntegralHistogram {
    pub bins: usize,
    pub h: usize,
    pub w: usize,
    /// Bin-major 1-D row-major buffer of length `bins*h*w` (Fig. 2).
    pub data: Vec<f32>,
}

impl IntegralHistogram {
    pub fn zeros(bins: usize, h: usize, w: usize) -> Self {
        IntegralHistogram { bins, h, w, data: vec![0.0; bins * h * w] }
    }

    pub fn from_raw(bins: usize, h: usize, w: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), bins * h * w, "raw buffer length mismatch");
        IntegralHistogram { bins, h, w, data }
    }

    /// Rebuild a tensor over **recycled** storage: the buffer is resized
    /// to `bins·h·w` but retained elements are *not* zeroed — contents
    /// are unspecified until a full-overwrite kernel (e.g.
    /// [`crate::histogram::engine::ScanEngine::compute_into`]) fills
    /// them.  This is the `FramePool` reuse primitive that removes the
    /// per-frame `zeros()` allocation+memset from the hot path.
    pub fn from_storage(bins: usize, h: usize, w: usize, mut storage: Vec<f32>) -> Self {
        storage.resize(bins * h * w, 0.0);
        IntegralHistogram { bins, h, w, data: storage }
    }

    /// Surrender the backing storage for recycling (the inverse of
    /// [`Self::from_storage`]).
    pub fn into_storage(self) -> Vec<f32> {
        self.data
    }

    #[inline]
    pub fn idx(&self, b: usize, r: usize, c: usize) -> usize {
        (b * self.h + r) * self.w + c
    }

    #[inline]
    pub fn at(&self, b: usize, r: usize, c: usize) -> f32 {
        self.data[self.idx(b, r, c)]
    }

    /// One bin plane as a row-major h×w slice.
    pub fn plane(&self, b: usize) -> &[f32] {
        &self.data[b * self.h * self.w..(b + 1) * self.h * self.w]
    }

    /// Size in bytes of the tensor buffer (what moves over PCIe).
    pub fn nbytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
    }

    /// Eq. 2: histogram of an inclusive rectangle in O(bins) time.
    pub fn region(&self, rect: Rect) -> Vec<f32> {
        crate::histogram::region::region_histogram(self, rect)
    }

    /// Restrict to the top-left `h×w` corner (undo §3.4 padding).
    pub fn crop(&self, h: usize, w: usize) -> IntegralHistogram {
        assert!(h <= self.h && w <= self.w, "crop larger than tensor");
        if h == self.h && w == self.w {
            return self.clone();
        }
        let mut out = IntegralHistogram::zeros(self.bins, h, w);
        for b in 0..self.bins {
            for r in 0..h {
                let src = self.idx(b, r, 0);
                let dst = out.idx(b, r, 0);
                out.data[dst..dst + w].copy_from_slice(&self.data[src..src + w]);
            }
        }
        out
    }

    /// Max absolute difference against another tensor (test helper).
    pub fn max_abs_diff(&self, other: &IntegralHistogram) -> f32 {
        assert_eq!((self.bins, self.h, self.w), (other.bins, other.h, other.w));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strategy_roundtrip() {
        for s in Strategy::ALL {
            assert_eq!(s.artifact_prefix().parse::<Strategy>().unwrap(), s);
        }
        assert!("bogus".parse::<Strategy>().is_err());
    }

    #[test]
    fn launch_counts_match_paper() {
        // CW-B: b*h + b + b*w launches (§3.3)
        assert_eq!(Strategy::CwB.kernel_launches(512, 512, 32, 64), 32 * 512 + 32 + 32 * 512);
        assert_eq!(Strategy::CwSts.kernel_launches(512, 512, 32, 64), 3);
        // WF-TiS: Eq. 6 = ceil(w/t) + ceil(h/t) - 1
        assert_eq!(Strategy::WfTis.kernel_launches(512, 512, 32, 64), 8 + 8 - 1);
        assert_eq!(Strategy::WfTis.kernel_launches(480, 640, 32, 64), 10 + 8 - 1);
    }

    #[test]
    fn tensor_pass_ordering() {
        assert!(Strategy::WfTis.tensor_passes() < Strategy::CwTis.tensor_passes());
        assert!(Strategy::CwTis.tensor_passes() < Strategy::CwSts.tensor_passes());
    }

    #[test]
    fn binned_image_pad() {
        let img = BinnedImage::new(3, 5, 4, vec![0; 15]);
        let p = img.pad_to_tile(4);
        assert_eq!((p.h, p.w), (4, 8));
        assert_eq!(p.at(0, 0), 0);
        assert_eq!(p.at(3, 0), -1);
        assert_eq!(p.at(0, 5), -1);
        // aligned image returns unchanged
        let img2 = BinnedImage::new(4, 4, 4, vec![1; 16]);
        assert_eq!(img2.pad_to_tile(4), img2);
    }

    #[test]
    fn ih_indexing_bin_major() {
        let mut ih = IntegralHistogram::zeros(2, 3, 4);
        let k = ih.idx(1, 2, 3);
        assert_eq!(k, (1 * 3 + 2) * 4 + 3);
        ih.data[k] = 7.0;
        assert_eq!(ih.at(1, 2, 3), 7.0);
        assert_eq!(ih.plane(1)[2 * 4 + 3], 7.0);
    }

    #[test]
    fn crop_keeps_corner() {
        let mut ih = IntegralHistogram::zeros(1, 4, 4);
        for r in 0..4 {
            for c in 0..4 {
                let k = ih.idx(0, r, c);
                ih.data[k] = (r * 10 + c) as f32;
            }
        }
        let c = ih.crop(2, 3);
        assert_eq!((c.h, c.w), (2, 3));
        assert_eq!(c.at(0, 1, 2), 12.0);
    }

    #[test]
    #[should_panic]
    fn from_raw_rejects_bad_len() {
        IntegralHistogram::from_raw(2, 2, 2, vec![0.0; 7]);
    }

    #[test]
    fn storage_roundtrip_keeps_capacity() {
        let ih = IntegralHistogram::zeros(2, 4, 4);
        let mut buf = ih.into_storage();
        assert_eq!(buf.len(), 32);
        buf[0] = 9.0; // dirty
        let cap = buf.capacity();
        // same-size rebuild: no realloc, dirty contents retained
        let ih2 = IntegralHistogram::from_storage(2, 4, 4, buf);
        assert_eq!(ih2.data.capacity(), cap);
        assert_eq!(ih2.data[0], 9.0);
        // smaller rebuild truncates, larger grows (new tail zeroed)
        let ih3 = IntegralHistogram::from_storage(1, 2, 2, ih2.into_storage());
        assert_eq!(ih3.data.len(), 4);
        let ih4 = IntegralHistogram::from_storage(3, 4, 4, ih3.into_storage());
        assert_eq!(ih4.data.len(), 48);
        assert_eq!(ih4.data[47], 0.0);
    }
}
