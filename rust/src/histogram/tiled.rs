//! Cache-blocked single-pass CPU integral histogram — WF-TiS on a CPU.
//!
//! The wave-front tiled scan's insight is substrate-independent: sweep
//! tiles so each crosses the slow-memory boundary once, carrying the
//! post-horizontal right edge and post-vertical bottom edge between
//! neighbours.  Applied to the CPU cache hierarchy (tile ≈ L1-resident
//! block) it yields the optimized single-thread baseline used by the
//! §Perf pass, and doubles as an executable model of the Algorithm 5
//! data flow that the property tests validate against Algorithm 1.
//!
//! Note these variants are still **bin-major** (the whole image is
//! re-read once per bin plane); the serving hot path is the multi-bin
//! fused [`crate::histogram::engine::ScanEngine`], which this module
//! remains a benchmark baseline for (`benches/hotpath.rs`).

use crate::histogram::types::{BinnedImage, IntegralHistogram};

/// Default tile edge: 64×64 f32 = 16 KiB, comfortably L1-resident —
/// the same 64×64 the paper lands on for the GPU (Fig. 10).
pub const DEFAULT_TILE: usize = 64;

/// Single-pass wavefront-tiled integral histogram.
///
/// Per bin plane, tiles are processed in row-major order (a linear
/// extension of the wavefront partial order).  For each tile:
/// horizontal scan with carried left edge, then vertical scan with
/// carried top edge — the exact Algorithm 5 schedule.
pub fn integral_histogram_tiled(img: &BinnedImage, tile: usize) -> IntegralHistogram {
    assert!(tile >= 1, "tile must be positive");
    let (h, w, bins) = (img.h, img.w, img.bins);
    let mut ih = IntegralHistogram::zeros(bins, h, w);
    let plane = h * w;

    // Carries: colc = right edge of the tile to the left (post-H scan);
    // rowc = bottom edge of the tile above (post-V scan), full width.
    let mut colc = vec![0.0f32; tile];
    let mut rowc = vec![0.0f32; w];
    // In-tile scratch buffer, padded row stride to keep indexing simple.
    let mut buf = vec![0.0f32; tile * tile];

    for k in 0..bins {
        let kk = k as i32;
        let base = k * plane;
        rowc.iter_mut().for_each(|v| *v = 0.0);
        let mut ti = 0;
        while ti < h {
            let th = tile.min(h - ti);
            colc.iter_mut().for_each(|v| *v = 0.0);
            let mut tj = 0;
            while tj < w {
                let tw = tile.min(w - tj);
                // 1. binning + horizontal scan with left carry into buf
                for r in 0..th {
                    let img_row = (ti + r) * w + tj;
                    let mut run = colc[r];
                    for c in 0..tw {
                        run += (img.data[img_row + c] == kk) as u32 as f32;
                        buf[r * tile + c] = run;
                    }
                    colc[r] = run; // preserve right edge BEFORE v-scan (§3.5)
                }
                // 2. vertical scan with top carry, write to output
                for c in 0..tw {
                    let mut run = rowc[tj + c];
                    for r in 0..th {
                        run += buf[r * tile + c];
                        ih.data[base + (ti + r) * w + tj + c] = run;
                    }
                    rowc[tj + c] = run; // bottom edge for the tile below
                }
                tj += tile;
            }
            ti += tile;
        }
    }
    ih
}

/// Two-pass cross-weave tiled variant (the CW-TiS schedule on CPU):
/// a full horizontal pass over all tiles, then a full vertical pass.
/// Exists to make the §3.5 traffic argument measurable on CPU — same
/// arithmetic as [`integral_histogram_tiled`], twice the tensor traffic.
pub fn integral_histogram_tiled_twopass(img: &BinnedImage, tile: usize) -> IntegralHistogram {
    assert!(tile >= 1);
    let (h, w, bins) = (img.h, img.w, img.bins);
    let mut ih = IntegralHistogram::zeros(bins, h, w);
    let plane = h * w;

    for k in 0..bins {
        let kk = k as i32;
        let base = k * plane;
        // Pass 1: horizontal, strip-wise with carried right edge.
        for ti in (0..h).step_by(tile) {
            let th = tile.min(h - ti);
            let mut colc = vec![0.0f32; th];
            for tj in (0..w).step_by(tile) {
                let tw = tile.min(w - tj);
                for r in 0..th {
                    let row = (ti + r) * w + tj;
                    let mut run = colc[r];
                    for c in 0..tw {
                        run += (img.data[row + c] == kk) as u32 as f32;
                        ih.data[base + row + c] = run;
                    }
                    colc[r] = run;
                }
            }
        }
        // Pass 2: vertical, strip-wise with carried bottom edge.
        for tj in (0..w).step_by(tile) {
            let tw = tile.min(w - tj);
            let mut rowc = vec![0.0f32; tw];
            for ti in (0..h).step_by(tile) {
                let th = tile.min(h - ti);
                for c in 0..tw {
                    let mut run = rowc[c];
                    for r in 0..th {
                        let idx = base + (ti + r) * w + tj + c;
                        run += ih.data[idx];
                        ih.data[idx] = run;
                    }
                    rowc[c] = run;
                }
            }
        }
    }
    ih
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::histogram::sequential::integral_histogram_seq;
    use crate::util::prng::Xoshiro256;

    fn random_image(h: usize, w: usize, bins: usize, seed: u64) -> BinnedImage {
        let mut rng = Xoshiro256::new(seed);
        let mut data = vec![0i32; h * w];
        rng.fill_bins(&mut data, bins as u32);
        BinnedImage::new(h, w, bins, data)
    }

    #[test]
    fn tiled_matches_sequential_aligned() {
        let img = random_image(64, 128, 4, 1);
        let expected = integral_histogram_seq(&img);
        for tile in [16, 32, 64] {
            let got = integral_histogram_tiled(&img, tile);
            assert_eq!(expected.max_abs_diff(&got), 0.0, "tile={tile}");
        }
    }

    /// Tiles that do NOT divide the image exercise the ragged-edge path.
    #[test]
    fn tiled_matches_sequential_ragged() {
        let img = random_image(37, 53, 8, 2);
        let expected = integral_histogram_seq(&img);
        for tile in [5, 16, 40, 64, 100] {
            let got = integral_histogram_tiled(&img, tile);
            assert_eq!(expected.max_abs_diff(&got), 0.0, "tile={tile}");
        }
    }

    #[test]
    fn twopass_matches_singlepass() {
        let img = random_image(45, 29, 4, 3);
        let a = integral_histogram_tiled(&img, 16);
        let b = integral_histogram_tiled_twopass(&img, 16);
        assert_eq!(a.max_abs_diff(&b), 0.0);
    }

    #[test]
    fn tile_of_one() {
        let img = random_image(7, 9, 2, 4);
        let expected = integral_histogram_seq(&img);
        assert_eq!(expected.max_abs_diff(&integral_histogram_tiled(&img, 1)), 0.0);
    }

    #[test]
    fn tile_larger_than_image() {
        let img = random_image(10, 12, 4, 5);
        let expected = integral_histogram_seq(&img);
        assert_eq!(expected.max_abs_diff(&integral_histogram_tiled(&img, 256)), 0.0);
    }

    /// Randomized property sweep: shapes × tiles × bins.
    #[test]
    fn property_sweep() {
        let mut rng = Xoshiro256::new(42);
        for _ in 0..15 {
            let h = rng.range(1, 50);
            let w = rng.range(1, 50);
            let bins = rng.range(1, 9);
            let tile = rng.range(1, 33);
            let img = random_image(h, w, bins, rng.next_u64());
            let expected = integral_histogram_seq(&img);
            let got = integral_histogram_tiled(&img, tile);
            assert_eq!(
                expected.max_abs_diff(&got),
                0.0,
                "h={h} w={w} bins={bins} tile={tile}"
            );
        }
    }
}
