//! Spatio-temporal (3-D) integral histogram — the §2.1 extension
//! ("the integral histogram is extensible to higher dimensions").
//!
//! `H(b, t, x, y) = Σ_{τ≤t, r≤x, c≤y} Q(I_τ(r,c), b)` over a sliding
//! window of frames, so the histogram of any *spatio-temporal box*
//! (a rectangle over a frame range) is 8 lookups per bin — the
//! primitive behind the paper's spatio-temporal median-filter motion
//! detection ([28]) and temporal likelihood maps.

use crate::histogram::region::Rect;
use crate::histogram::types::BinnedImage;

/// Integral histogram over a (bounded) temporal window of frames.
#[derive(Debug, Clone)]
pub struct TemporalIntegralHistogram {
    pub bins: usize,
    pub frames: usize,
    pub h: usize,
    pub w: usize,
    /// Layout: bin-major, then time: `[(b·frames + t)·h·w + x·w + y]`.
    data: Vec<f32>,
}

impl TemporalIntegralHistogram {
    /// Build from a sequence of binned frames (all same geometry).
    pub fn build(frames: &[BinnedImage], bins: usize) -> TemporalIntegralHistogram {
        assert!(!frames.is_empty(), "need at least one frame");
        let (h, w) = (frames[0].h, frames[0].w);
        assert!(frames.iter().all(|f| (f.h, f.w) == (h, w)), "inconsistent frame dims");
        let nt = frames.len();
        let plane = h * w;
        let mut data = vec![0.0f32; bins * nt * plane];
        for b in 0..bins {
            let bb = b as i32;
            for t in 0..nt {
                let base = (b * nt + t) * plane;
                let prev_t = base.wrapping_sub(plane);
                // spatial integral of frame t's Q plane, plus temporal carry
                for x in 0..h {
                    let mut rowsum = 0.0f32;
                    for y in 0..w {
                        rowsum += (frames[t].data[x * w + y] == bb) as u32 as f32;
                        let up = if x > 0 { data[base + (x - 1) * w + y] } else { 0.0 };
                        let tprev = if t > 0 { data[prev_t + x * w + y] } else { 0.0 };
                        // note: `up` already includes this frame's rows above
                        // AND the temporal prefix of those rows, so subtract
                        // the double-counted temporal part of `up`:
                        let up_tprev = if t > 0 && x > 0 { data[prev_t + (x - 1) * w + y] } else { 0.0 };
                        data[base + x * w + y] = rowsum + up + tprev - up_tprev;
                    }
                }
            }
        }
        TemporalIntegralHistogram { bins, frames: nt, h, w, data }
    }

    #[inline]
    fn at(&self, b: usize, t: usize, x: usize, y: usize) -> f32 {
        self.data[((b * self.frames + t) * self.h + x) * self.w + y]
    }

    /// Histogram of the spatio-temporal box `rect × [t0..=t1]`:
    /// inclusion–exclusion over the 8 corners (Eq. 2 lifted to 3-D).
    pub fn box_histogram(&self, t0: usize, t1: usize, rect: Rect) -> Vec<f32> {
        assert!(t0 <= t1 && t1 < self.frames, "bad frame range {t0}..={t1}");
        assert!(rect.fits(self.h, self.w), "rect outside frame");
        let mut out = Vec::with_capacity(self.bins);
        for b in 0..self.bins {
            let f = |t: isize, x: isize, y: isize| -> f32 {
                if t < 0 || x < 0 || y < 0 {
                    0.0
                } else {
                    self.at(b, t as usize, x as usize, y as usize)
                }
            };
            let (ta, tb) = (t0 as isize - 1, t1 as isize);
            let (xa, xb) = (rect.r0 as isize - 1, rect.r1 as isize);
            let (ya, yb) = (rect.c0 as isize - 1, rect.c1 as isize);
            let v = f(tb, xb, yb) - f(ta, xb, yb) - f(tb, xa, yb) - f(tb, xb, ya)
                + f(ta, xa, yb)
                + f(ta, xb, ya)
                + f(tb, xa, ya)
                - f(ta, xa, ya);
            out.push(v);
        }
        out
    }

    /// Temporal-median-style background score: fraction of the window's
    /// mass whose bin matches the modal bin of the *whole* time range —
    /// the building block of the median-filter motion detector [28].
    pub fn stability(&self, t0: usize, t1: usize, rect: Rect) -> f32 {
        let hist = self.box_histogram(t0, t1, rect);
        let total: f32 = hist.iter().sum();
        if total <= 0.0 {
            return 0.0;
        }
        hist.iter().fold(0.0f32, |m, &v| m.max(v)) / total
    }

    pub fn nbytes(&self) -> usize {
        self.data.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Xoshiro256;

    fn random_frames(n: usize, h: usize, w: usize, bins: usize, seed: u64) -> Vec<BinnedImage> {
        let mut rng = Xoshiro256::new(seed);
        (0..n)
            .map(|_| {
                let mut data = vec![0i32; h * w];
                rng.fill_bins(&mut data, bins as u32);
                BinnedImage::new(h, w, bins, data)
            })
            .collect()
    }

    fn brute(frames: &[BinnedImage], bins: usize, t0: usize, t1: usize, rect: Rect) -> Vec<f32> {
        let mut h = vec![0.0f32; bins];
        for f in &frames[t0..=t1] {
            for r in rect.r0..=rect.r1 {
                for c in rect.c0..=rect.c1 {
                    let v = f.at(r, c);
                    if v >= 0 {
                        h[v as usize] += 1.0;
                    }
                }
            }
        }
        h
    }

    #[test]
    fn box_matches_brute_force_property() {
        let frames = random_frames(6, 17, 23, 5, 9);
        let tih = TemporalIntegralHistogram::build(&frames, 5);
        let mut rng = Xoshiro256::new(4);
        for case in 0..60 {
            let t0 = rng.range(0, 6);
            let t1 = rng.range(t0, 6);
            let r0 = rng.range(0, 17);
            let r1 = rng.range(r0, 17);
            let c0 = rng.range(0, 23);
            let c1 = rng.range(c0, 23);
            let rect = Rect::new(r0, c0, r1, c1);
            let fast = tih.box_histogram(t0, t1, rect);
            let slow = brute(&frames, 5, t0, t1, rect);
            assert_eq!(fast, slow, "case {case}: t={t0}..={t1} {rect:?}");
        }
    }

    #[test]
    fn single_frame_reduces_to_2d() {
        let frames = random_frames(1, 12, 12, 4, 2);
        let tih = TemporalIntegralHistogram::build(&frames, 4);
        let ih2d = crate::histogram::sequential::integral_histogram_seq(&frames[0]);
        let rect = Rect::new(2, 3, 9, 11);
        assert_eq!(
            tih.box_histogram(0, 0, rect),
            crate::histogram::region::region_histogram(&ih2d, rect)
        );
    }

    #[test]
    fn full_box_counts_all_pixels() {
        let frames = random_frames(4, 8, 8, 4, 1);
        let tih = TemporalIntegralHistogram::build(&frames, 4);
        let hist = tih.box_histogram(0, 3, Rect::new(0, 0, 7, 7));
        assert_eq!(hist.iter().sum::<f32>(), (4 * 64) as f32);
    }

    #[test]
    fn stability_detects_static_vs_dynamic() {
        let h = 8;
        // static region: same bin value every frame → stability 1
        let static_frames: Vec<BinnedImage> =
            (0..5).map(|_| BinnedImage::new(h, h, 4, vec![2; h * h])).collect();
        let tih = TemporalIntegralHistogram::build(&static_frames, 4);
        assert_eq!(tih.stability(0, 4, Rect::new(0, 0, 7, 7)), 1.0);
        // alternating region → stability ≈ spread across bins
        let dyn_frames: Vec<BinnedImage> =
            (0..4).map(|t| BinnedImage::new(h, h, 4, vec![t as i32; h * h])).collect();
        let tih = TemporalIntegralHistogram::build(&dyn_frames, 4);
        assert!((tih.stability(0, 3, Rect::new(0, 0, 7, 7)) - 0.25).abs() < 1e-6);
    }

    #[test]
    #[should_panic]
    fn rejects_bad_frame_range() {
        let frames = random_frames(2, 4, 4, 2, 0);
        let tih = TemporalIntegralHistogram::build(&frames, 2);
        tih.box_histogram(1, 2, Rect::new(0, 0, 3, 3));
    }
}
