//! Anti-diagonal wavefront execution of the fused tile kernel —
//! Algorithm 5's schedule reproduced on CPU threads.
//!
//! The tile grid forms a DAG: tile `(i, j)` may run once `(i−1, j)` and
//! `(i, j−1)` are complete (it reads the bottom output row of the tile
//! above and the `colc` row-prefix carries written by the tile to the
//! left).  Tiles on the same anti-diagonal are independent, so
//! parallelism scales with `min(h/t, w/t)` **independent of the bin
//! count** — the axis the bin-plane-parallel baseline cannot exploit
//! at low bin counts (§4, Fig. 19b).
//!
//! Scheduling is a dependency-counted task pool: each tile carries an
//! outstanding-dependency counter; finishing a tile decrements its right
//! and down neighbours and enqueues any that reach zero.  All counter
//! updates happen under one mutex (two lock acquisitions per tile —
//! negligible against a tile's ~`bins·t²` element writes), and that same
//! mutex release/acquire pair orders the plain tile writes between a
//! task and its dependents, so no atomics are needed on the data path.
//! Workers share the output tensor and carry plane through
//! [`SharedTensor`] windows that hand out disjoint row-segment slices —
//! see the aliasing notes in [`crate::histogram::engine::kernel`].
//!
//! Execution draws on a persistent [`WorkerPool`]: the calling thread
//! participates as worker 0 with its own scratch, helpers are parked
//! pool threads each owning a reusable scratch slab, so a steady-state
//! frame spawns no threads and allocates nothing (see
//! [`crate::histogram::engine::worker_pool`]).

use crate::histogram::engine::kernel::{scan_tile_v, KernelVariant, SharedTensor, TileScratch};
use crate::histogram::engine::worker_pool::WorkerPool;
use crate::histogram::types::{BinnedImage, IntegralHistogram};
use std::sync::{Condvar, Mutex};

/// Reusable scheduler storage (dependency counters + ready stack) so a
/// steady-state frame allocates nothing.
#[derive(Debug, Default)]
pub struct WavefrontScratch {
    deps: Vec<u8>,
    ready: Vec<u32>,
}

/// Scheduler state shared under one mutex.  Borrows the reusable
/// vectors from [`WavefrontScratch`] to keep their capacity across
/// frames.
struct Sched<'a> {
    ready: &'a mut Vec<u32>,
    deps: &'a mut Vec<u8>,
    remaining: usize,
}

/// Serial fused sweep: tiles in row-major order (a linear extension of
/// the wavefront partial order), all bins per tile.  The single-thread
/// schedule the planner picks for small frames, and the arbiter the
/// parallel path is property-tested against.
pub fn fused_scan_into(
    img: &BinnedImage,
    tile: usize,
    colc: &mut [f32],
    scratch: &mut TileScratch,
    out: &mut [f32],
) {
    fused_scan_into_v(img, tile, colc, scratch, out, KernelVariant::Reference);
}

/// [`fused_scan_into`] with an explicit tile-kernel variant — the entry
/// the tuned plan drives ([`crate::tune::TunedPlanner`]).  Both
/// variants are bit-identical; see
/// [`crate::histogram::engine::kernel`].
pub fn fused_scan_into_v(
    img: &BinnedImage,
    tile: usize,
    colc: &mut [f32],
    scratch: &mut TileScratch,
    out: &mut [f32],
    variant: KernelVariant,
) {
    assert!(tile >= 1, "tile must be positive");
    let (h, w) = (img.h, img.w);
    scratch.ensure(tile, img.bins);
    let colc_win = SharedTensor::new(colc);
    let out_win = SharedTensor::new(out);
    let mut ti = 0;
    while ti < h {
        let th = tile.min(h - ti);
        let mut tj = 0;
        while tj < w {
            let tw = tile.min(w - tj);
            scan_tile_v(img, ti, tj, th, tw, &colc_win, &out_win, scratch, variant);
            tj += tile;
        }
        ti += tile;
    }
}

/// Wavefront-parallel fused sweep with `workers` threads: the calling
/// thread (worker 0, using `scratch`) plus up to `workers − 1` helpers
/// drawn from `pool` (each using its own persistent scratch slab).
///
/// Falls back to the serial sweep when the tile grid offers no
/// parallelism (a single tile row/column) or `workers <= 1`.  Fewer
/// pool threads than requested helpers is fine — the dependency-counted
/// scheduler completes with any worker count.
pub fn wavefront_scan_into(
    img: &BinnedImage,
    tile: usize,
    workers: usize,
    colc: &mut [f32],
    scratch: &mut TileScratch,
    pool: &mut WorkerPool,
    ws: &mut WavefrontScratch,
    out: &mut [f32],
) {
    wavefront_scan_into_v(
        img,
        tile,
        workers,
        colc,
        scratch,
        pool,
        ws,
        out,
        KernelVariant::Reference,
    );
}

/// [`wavefront_scan_into`] with an explicit tile-kernel variant — the
/// parallel counterpart of [`fused_scan_into_v`].  The variant changes
/// only each tile's internal loop shape, never the inter-tile
/// dependency order, so the aliasing and determinism arguments are
/// unchanged.
#[allow(clippy::too_many_arguments)]
pub fn wavefront_scan_into_v(
    img: &BinnedImage,
    tile: usize,
    workers: usize,
    colc: &mut [f32],
    scratch: &mut TileScratch,
    pool: &mut WorkerPool,
    ws: &mut WavefrontScratch,
    out: &mut [f32],
    variant: KernelVariant,
) {
    assert!(tile >= 1, "tile must be positive");
    let (h, w) = (img.h, img.w);
    let tr = h.div_ceil(tile);
    let tc = w.div_ceil(tile);
    let n_tasks = tr * tc;
    let workers = workers.clamp(1, tr.min(tc));
    if workers <= 1 || n_tasks == 1 {
        fused_scan_into_v(img, tile, colc, scratch, out, variant);
        return;
    }
    assert_eq!(colc.len(), img.bins * h);
    assert_eq!(out.len(), img.bins * h * w);

    // Seed the dependency counters: left and top neighbours.
    ws.deps.clear();
    ws.deps.resize(n_tasks, 0);
    for i in 0..tr {
        for j in 0..tc {
            ws.deps[i * tc + j] = (i > 0) as u8 + (j > 0) as u8;
        }
    }
    ws.ready.clear();
    ws.ready.push(0);

    let state = Mutex::new(Sched {
        ready: &mut ws.ready,
        deps: &mut ws.deps,
        remaining: n_tasks,
    });
    let cv = Condvar::new();
    let out_win = SharedTensor::new(out);
    let colc_win = SharedTensor::new(colc);

    let run_worker = |_slot: usize, scratch: &mut TileScratch| {
        // Persistent per-worker slab: reallocates only when (tile, bins)
        // changes, so steady-state frames at one geometry allocate
        // nothing.
        scratch.ensure(tile, img.bins);
        loop {
            // Claim the next ready tile (or exit once all are done).
            let task = {
                let mut st = state.lock().expect("scheduler lock");
                loop {
                    if let Some(t) = st.ready.pop() {
                        break Some(t as usize);
                    }
                    if st.remaining == 0 {
                        break None;
                    }
                    st = cv.wait(st).expect("scheduler wait");
                }
            };
            let Some(t) = task else { break };
            let (i, j) = (t / tc, t % tc);
            let (ti, tj) = (i * tile, j * tile);
            let th = tile.min(h - ti);
            let tw = tile.min(w - tj);
            // The dependency order gives this task exclusive claim to
            // its tile's row segments of `out` (per bin) and rows
            // [ti, ti+th) of `colc`; its only cross-task reads (the
            // tile above's bottom row) were published under the
            // scheduler mutex we just acquired.  `scan_tile` borrows
            // exactly those disjoint segments through the windows.
            scan_tile_v(img, ti, tj, th, tw, &colc_win, &out_win, scratch, variant);
            // Publish completion: unlock right/down neighbours.
            let mut st = state.lock().expect("scheduler lock");
            st.remaining -= 1;
            let mut woke = 0usize;
            if j + 1 < tc {
                st.deps[t + 1] -= 1;
                if st.deps[t + 1] == 0 {
                    st.ready.push((t + 1) as u32);
                    woke += 1;
                }
            }
            if i + 1 < tr {
                st.deps[t + tc] -= 1;
                if st.deps[t + tc] == 0 {
                    st.ready.push((t + tc) as u32);
                    woke += 1;
                }
            }
            let all_done = st.remaining == 0;
            drop(st);
            if all_done {
                cv.notify_all();
            } else {
                for _ in 0..woke {
                    cv.notify_one();
                }
            }
        }
    };

    // The calling thread is worker 0; helpers are parked pool threads.
    pool.run(workers - 1, scratch, run_worker);
}

/// Allocating convenience wrapper over [`fused_scan_into`] — the
/// single-thread fused baseline for benches and property tests.
pub fn integral_histogram_fused(img: &BinnedImage, tile: usize) -> IntegralHistogram {
    integral_histogram_fused_v(img, tile, KernelVariant::Reference)
}

/// Allocating wrapper over [`fused_scan_into_v`] — lets benches, the
/// calibrator's microbench and property tests drive a specific kernel
/// variant.
pub fn integral_histogram_fused_v(
    img: &BinnedImage,
    tile: usize,
    variant: KernelVariant,
) -> IntegralHistogram {
    let mut out = IntegralHistogram::zeros(img.bins, img.h, img.w);
    let mut colc = vec![0.0f32; img.bins * img.h];
    let mut scratch = TileScratch::default();
    fused_scan_into_v(img, tile, &mut colc, &mut scratch, &mut out.data, variant);
    out
}

/// Allocating convenience wrapper over [`wavefront_scan_into`] with a
/// transient pool (benches/tests; the serving path holds a long-lived
/// pool inside [`crate::histogram::engine::ScanEngine`] instead).
pub fn integral_histogram_wavefront(
    img: &BinnedImage,
    tile: usize,
    workers: usize,
) -> IntegralHistogram {
    integral_histogram_wavefront_v(img, tile, workers, KernelVariant::Reference)
}

/// Allocating wrapper over [`wavefront_scan_into_v`] with a transient
/// pool.
pub fn integral_histogram_wavefront_v(
    img: &BinnedImage,
    tile: usize,
    workers: usize,
    variant: KernelVariant,
) -> IntegralHistogram {
    let mut out = IntegralHistogram::zeros(img.bins, img.h, img.w);
    let mut colc = vec![0.0f32; img.bins * img.h];
    let mut scratch = TileScratch::default();
    let mut pool = WorkerPool::new(workers.saturating_sub(1));
    let mut ws = WavefrontScratch::default();
    wavefront_scan_into_v(
        img,
        tile,
        workers,
        &mut colc,
        &mut scratch,
        &mut pool,
        &mut ws,
        &mut out.data,
        variant,
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::histogram::sequential::integral_histogram_seq;
    use crate::util::prng::Xoshiro256;

    fn random_image(h: usize, w: usize, bins: usize, seed: u64) -> BinnedImage {
        let mut rng = Xoshiro256::new(seed);
        let mut data = vec![0i32; h * w];
        rng.fill_bins(&mut data, bins as u32);
        BinnedImage::new(h, w, bins, data)
    }

    #[test]
    fn fused_matches_algorithm1() {
        let img = random_image(37, 53, 8, 2);
        let expected = integral_histogram_seq(&img);
        for tile in [1usize, 5, 16, 40, 64, 100] {
            let got = integral_histogram_fused(&img, tile);
            assert_eq!(expected.max_abs_diff(&got), 0.0, "tile={tile}");
        }
    }

    #[test]
    fn wavefront_matches_algorithm1() {
        let img = random_image(64, 96, 8, 3);
        let expected = integral_histogram_seq(&img);
        for tile in [8usize, 16, 32] {
            for workers in [1usize, 2, 3, 4] {
                let got = integral_histogram_wavefront(&img, tile, workers);
                assert_eq!(
                    expected.max_abs_diff(&got),
                    0.0,
                    "tile={tile} workers={workers}"
                );
            }
        }
    }

    #[test]
    fn wavefront_ragged_edges() {
        let img = random_image(45, 77, 4, 4);
        let expected = integral_histogram_seq(&img);
        let got = integral_histogram_wavefront(&img, 16, 4);
        assert_eq!(expected.max_abs_diff(&got), 0.0);
    }

    #[test]
    fn degenerate_shapes() {
        for (h, w) in [(1usize, 33usize), (29, 1), (1, 1), (2, 200)] {
            let img = random_image(h, w, 3, (h + w) as u64);
            let expected = integral_histogram_seq(&img);
            let got = integral_histogram_wavefront(&img, 8, 4);
            assert_eq!(expected.max_abs_diff(&got), 0.0, "{h}x{w}");
        }
    }

    #[test]
    fn single_bin_and_padding() {
        let mut img = random_image(20, 20, 1, 9);
        img.data[5] = -1;
        img.data[399] = -1;
        let expected = integral_histogram_seq(&img);
        let got = integral_histogram_wavefront(&img, 8, 2);
        assert_eq!(expected.max_abs_diff(&got), 0.0);
    }

    /// The tuned kernel variant under both schedules is bit-identical
    /// to the reference — including shapes narrower than the unroll
    /// lane and ragged tile grids.
    #[test]
    fn tuned_variant_schedules_are_bit_identical() {
        for (h, w, bins, tile, workers) in [
            (45usize, 77usize, 4usize, 16usize, 4usize),
            (64, 96, 8, 32, 3),
            (3, 2, 5, 8, 2), // w < lane width
            (29, 1, 3, 8, 4),
        ] {
            let img = random_image(h, w, bins, (h * 31 + w) as u64);
            let reference = integral_histogram_wavefront(&img, tile, workers);
            let tuned_wf =
                integral_histogram_wavefront_v(&img, tile, workers, KernelVariant::Tuned);
            let tuned_fused = integral_histogram_fused_v(&img, tile, KernelVariant::Tuned);
            assert_eq!(reference, tuned_wf, "{h}x{w}x{bins} wavefront");
            assert_eq!(reference, tuned_fused, "{h}x{w}x{bins} fused");
        }
    }

    /// Integer counts in f32: the parallel schedule must be bit-identical
    /// across runs (no accumulation-order ambiguity).
    #[test]
    fn wavefront_is_deterministic() {
        let img = random_image(48, 48, 8, 11);
        let a = integral_histogram_wavefront(&img, 16, 4);
        let b = integral_histogram_wavefront(&img, 16, 4);
        assert_eq!(a, b);
    }

    /// Property sweep across random shapes, tiles, workers, bin blocks.
    #[test]
    fn property_sweep() {
        let mut rng = Xoshiro256::new(0xAB5E);
        for _ in 0..12 {
            let h = rng.range(1, 60);
            let w = rng.range(1, 60);
            let bins = rng.range(1, 10);
            let tile = rng.range(1, 34);
            let workers = rng.range(1, 5);
            let img = random_image(h, w, bins, rng.next_u64());
            let expected = integral_histogram_seq(&img);
            let got = integral_histogram_wavefront(&img, tile, workers);
            assert_eq!(
                expected.max_abs_diff(&got),
                0.0,
                "h={h} w={w} bins={bins} tile={tile} workers={workers}"
            );
        }
    }
}
