//! The fused multi-bin tile kernel — one image read per tile, all bins.
//!
//! This is the §3.5 data-movement argument applied inside a tile: the
//! per-bin strategies ([`crate::histogram::parallel`],
//! [`crate::histogram::tiled`]) re-read the image once per bin plane and
//! spend one compare per (bin, pixel) recovering the one-hot Q value.
//! Here each tile row is read **once** and counting-sorted into per-bin
//! column buckets; the scan then exploits that the Q function is one-hot:
//! for a fixed bin the row prefix is a step function, so the recurrence
//!
//! ```text
//! H(k, x, y) = H(k, x-1, y) + rowprefix(k, x, y)
//! ```
//!
//! is evaluated segment-wise — between two bin-k pixels the row prefix
//! `run` is constant and the inner loop degenerates to `cur[c] = prev[c]
//! + run`, a branch-free slice add the compiler vectorizes.  Amortized
//! work per output element drops from ~6 dependent scalar ops (load,
//! compare, two adds, carried sum, store) to ~1 SIMD-friendly add+store,
//! and image traffic drops `bins×`.
//!
//! Carries between tiles follow Algorithm 5: `colc[k·h + x]` holds the
//! bin-k row prefix of global row `x` up to the tile's left edge (the
//! WF-TiS right-edge carry), and the top-edge carry needs no extra
//! buffer — the tile above's bottom output row *is* `H(k, x-1, ·)` and
//! is read directly from the output tensor (its completion is ordered by
//! the wavefront dependency).
//!
//! ## Aliasing discipline
//!
//! Concurrent wavefront workers share the output tensor and the carry
//! plane through [`SharedTensor`], which hands out **row-segment**
//! slices, never whole-buffer `&mut` views.  Two tiles may run
//! concurrently only if they are dependency-incomparable, which for the
//! left/top dependency DAG implies different tile rows *and* different
//! tile columns — so their written row segments `(bin, row, [tj,
//! tj+tw))` are disjoint, and a tile's read of the row above (its top
//! carry) shares no element with any concurrently written segment.
//! Every live reference therefore covers a disjoint element range.

use crate::histogram::types::BinnedImage;

/// A shared window over one `f32` buffer from which workers borrow
/// disjoint row-segment slices.  The wavefront dependency order (plus
/// the scheduler's mutex for the happens-before edge) guarantees the
/// segments requested by concurrent tiles never overlap — see the
/// module-level aliasing notes.
pub struct SharedTensor {
    ptr: *mut f32,
    len: usize,
}

unsafe impl Send for SharedTensor {}
unsafe impl Sync for SharedTensor {}

impl SharedTensor {
    pub fn new(buf: &mut [f32]) -> SharedTensor {
        SharedTensor { ptr: buf.as_mut_ptr(), len: buf.len() }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Mutable segment `[start, start + n)`.
    ///
    /// # Safety
    /// The caller must guarantee no other live reference overlaps the
    /// range (the wavefront schedule provides this for tile segments;
    /// the engine's pooled bin-parallel path provides it by handing
    /// each claimed bin plane to exactly one worker).
    #[inline]
    pub(crate) unsafe fn seg_mut(&self, start: usize, n: usize) -> &mut [f32] {
        debug_assert!(start + n <= self.len);
        std::slice::from_raw_parts_mut(self.ptr.add(start), n)
    }

    /// Shared segment `[start, start + n)`.
    ///
    /// # Safety
    /// The caller must guarantee no live *mutable* reference overlaps
    /// the range and that its contents have been published (here: via
    /// the scheduler mutex) before the read.
    #[inline]
    unsafe fn seg(&self, start: usize, n: usize) -> &[f32] {
        debug_assert!(start + n <= self.len);
        std::slice::from_raw_parts(self.ptr.add(start), n)
    }
}

/// Reusable per-worker scratch: the per-row counting-sort buckets.
/// Sized for one `tile × tile` block at a given bin count; `ensure`
/// reallocates only when the configuration changes, so steady-state
/// frames perform no heap allocation.
#[derive(Debug, Default)]
pub struct TileScratch {
    /// Per-row bucket boundaries: `start[r·(bins+1) + k]` is the first
    /// index in `pos` of row r's bin-k columns (prefix-sum layout).
    start: Vec<u32>,
    /// Per-row pixel columns grouped by bin, ascending within a bin:
    /// `pos[r·tile + j]`.
    pos: Vec<u32>,
    /// Write cursors for the counting sort (length `bins`).
    cur: Vec<u32>,
    tile: usize,
    bins: usize,
}

impl TileScratch {
    /// (Re)size for `tile` and `bins`; no-op when already sized.
    pub fn ensure(&mut self, tile: usize, bins: usize) {
        if self.tile != tile || self.bins != bins {
            self.start = vec![0; tile * (bins + 1)];
            self.pos = vec![0; tile * tile];
            self.cur = vec![0; bins];
            self.tile = tile;
            self.bins = bins;
        }
    }

    pub fn tile(&self) -> usize {
        self.tile
    }
}

/// `cur[i] = run` over a segment (constant row prefix, no bin-k pixel).
#[inline]
fn fill_run(cur: &mut [f32], run: f32) {
    for v in cur {
        *v = run;
    }
}

/// `cur[i] = prev[i] + run` over a segment — the vectorizable hot loop.
#[inline]
fn add_run(cur: &mut [f32], prev: &[f32], run: f32) {
    for (v, &p) in cur.iter_mut().zip(prev) {
        *v = p + run;
    }
}

/// Scan one `th × tw` tile at origin `(ti, tj)` for **all** bins,
/// writing final integral-histogram values into `out` (the full
/// `bins×h×w` tensor window) and updating the left-edge carries in
/// `colc` (layout `bins×h`).  Requires the tile above and to the left
/// (if any) to be complete — the wavefront partial order.
///
/// Bins are swept plane-major: the bucketed tile (phase 1) is reused
/// from L1 across every bin — the multi-bin fusion that amortizes the
/// image read `bins×` — while each bin's active window is just two
/// `tw`-wide rows, so the tile itself already bounds the working set
/// and no further bin-axis blocking is needed (the paper's "B-bin
/// block" alternative applies to un-tiled full-row sweeps).
///
/// Pixels with values outside `[0, bins)` (e.g. the −1 padding of
/// §3.4, or any stray out-of-range index) count in no bin, matching
/// the per-bin baselines' `== k` semantics.
pub fn scan_tile(
    img: &BinnedImage,
    ti: usize,
    tj: usize,
    th: usize,
    tw: usize,
    colc: &SharedTensor,
    out: &SharedTensor,
    scratch: &mut TileScratch,
) {
    let (h, w, bins) = (img.h, img.w, img.bins);
    let plane = h * w;
    let tile = scratch.tile;
    debug_assert!(th <= tile && tw <= tile, "scratch sized for a smaller tile");
    debug_assert_eq!(scratch.bins, bins, "scratch sized for a different bin count");
    debug_assert_eq!(colc.len(), bins * h);
    debug_assert_eq!(out.len(), bins * plane);
    let bp1 = bins + 1;

    // Phase 1: one pass over the tile's pixels — counting-sort each
    // row's columns by bin.  This is the only read of the image.
    for r in 0..th {
        let rowbase = (ti + r) * w + tj;
        let st = &mut scratch.start[r * bp1..(r + 1) * bp1];
        st.fill(0);
        for c in 0..tw {
            let v = img.data[rowbase + c];
            if v >= 0 && (v as usize) < bins {
                st[v as usize + 1] += 1;
            }
        }
        for k in 0..bins {
            st[k + 1] += st[k];
        }
        scratch.cur.copy_from_slice(&st[..bins]);
        let posr = &mut scratch.pos[r * tile..r * tile + tw];
        for c in 0..tw {
            let v = img.data[rowbase + c];
            if v >= 0 && (v as usize) < bins {
                let k = v as usize;
                posr[scratch.cur[k] as usize] = c as u32;
                scratch.cur[k] += 1;
            }
        }
    }

    // Phase 2: per bin, per row: segment-wise
    //   out[x] = out[x-1] + run,   run stepping at bin-k pixel columns.
    for k in 0..bins {
        let pbase = k * plane;
        // SAFETY: rows [ti, ti+th) of bin k's carry column are written
        // only by tiles in this tile-row strip, which the
        // left-dependency chain serializes.
        let carry = unsafe { colc.seg_mut(k * h + ti, th) };
        for r in 0..th {
            let x = ti + r;
            let mut run = carry[r];
            let o = pbase + x * w + tj;
            let row = r * bp1;
            let s0 = scratch.start[row + k] as usize;
            let s1 = scratch.start[row + k + 1] as usize;
            let steps = &scratch.pos[r * tile + s0..r * tile + s1];
            if x == 0 {
                // Top image row: no row above, H(k,0,y) = run.
                // SAFETY: this tile exclusively owns segment
                // (k, x, [tj, tj+tw)) until its completion is
                // published.
                let cur = unsafe { out.seg_mut(o, tw) };
                let mut c0 = 0usize;
                for &pc in steps {
                    let pc = pc as usize;
                    fill_run(&mut cur[c0..pc], run);
                    run += 1.0;
                    cur[pc] = run;
                    c0 = pc + 1;
                }
                fill_run(&mut cur[c0..], run);
            } else {
                // SAFETY: the write segment is exclusively owned as
                // above.  The read segment is one row up in the same
                // columns: for r > 0 it was written by this same tile;
                // for r == 0 it belongs to the finished tile above
                // (published via the scheduler mutex), and no
                // concurrent tile's write segment overlaps it
                // (different tile row AND column — see module aliasing
                // notes).
                let (cur, prev) = unsafe { (out.seg_mut(o, tw), out.seg(o - w, tw)) };
                let mut c0 = 0usize;
                for &pc in steps {
                    let pc = pc as usize;
                    add_run(&mut cur[c0..pc], &prev[c0..pc], run);
                    run += 1.0;
                    cur[pc] = prev[pc] + run;
                    c0 = pc + 1;
                }
                add_run(&mut cur[c0..], &prev[c0..], run);
            }
            carry[r] = run;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::histogram::sequential::integral_histogram_seq;
    use crate::histogram::types::IntegralHistogram;
    use crate::util::prng::Xoshiro256;

    fn random_image(h: usize, w: usize, bins: usize, seed: u64) -> BinnedImage {
        let mut rng = Xoshiro256::new(seed);
        let mut data = vec![0i32; h * w];
        rng.fill_bins(&mut data, bins as u32);
        BinnedImage::new(h, w, bins, data)
    }

    fn run_single_tile(img: &BinnedImage) -> IntegralHistogram {
        let (h, w, bins) = (img.h, img.w, img.bins);
        let tile = h.max(w);
        let mut scratch = TileScratch::default();
        scratch.ensure(tile, bins);
        let mut colc = vec![0.0f32; bins * h];
        let mut out = vec![0.0f32; bins * h * w];
        scan_tile(
            img,
            0,
            0,
            h,
            w,
            &SharedTensor::new(&mut colc),
            &SharedTensor::new(&mut out),
            &mut scratch,
        );
        IntegralHistogram::from_raw(bins, h, w, out)
    }

    /// One tile covering the whole image must reproduce Algorithm 1.
    #[test]
    fn single_tile_matches_algorithm1() {
        for (h, w, bins) in [(1, 1, 1), (7, 9, 4), (16, 16, 8), (13, 5, 3)] {
            let img = random_image(h, w, bins, (h * 100 + w) as u64);
            let expected = integral_histogram_seq(&img);
            let got = run_single_tile(&img);
            assert_eq!(expected.max_abs_diff(&got), 0.0, "{h}x{w}x{bins}");
        }
    }

    /// Row-major tile sweep (wavefront-legal order) over ragged tiles.
    #[test]
    fn tile_sweep_matches_algorithm1() {
        let (h, w, bins, tile) = (23, 31, 5, 8);
        let img = random_image(h, w, bins, 99);
        let expected = integral_histogram_seq(&img);
        let mut scratch = TileScratch::default();
        scratch.ensure(tile, bins);
        let mut colc = vec![0.0f32; bins * h];
        let mut out = vec![0.0f32; bins * h * w];
        {
            let colc_win = SharedTensor::new(&mut colc);
            let out_win = SharedTensor::new(&mut out);
            let mut ti = 0;
            while ti < h {
                let th = tile.min(h - ti);
                let mut tj = 0;
                while tj < w {
                    let tw = tile.min(w - tj);
                    scan_tile(&img, ti, tj, th, tw, &colc_win, &out_win, &mut scratch);
                    tj += tile;
                }
                ti += tile;
            }
        }
        let got = IntegralHistogram::from_raw(bins, h, w, out);
        assert_eq!(expected.max_abs_diff(&got), 0.0);
    }

    /// Padding pixels (bin −1) and stray out-of-range values count in
    /// no plane — matching the `== k` baselines' tolerance.
    #[test]
    fn out_of_range_bins_are_ignored() {
        let mut img = BinnedImage::new(2, 3, 2, vec![-1, 0, 1, 1, -1, 0]);
        let expected = integral_histogram_seq(&img);
        let got = run_single_tile(&img);
        assert_eq!(expected.max_abs_diff(&got), 0.0);
        // a stray value == bins must not panic and counts nowhere
        img.data[1] = 2;
        let expected = integral_histogram_seq(&img);
        let got = run_single_tile(&img);
        assert_eq!(expected.max_abs_diff(&got), 0.0);
    }

    /// A dirty output buffer must not leak into the result (every
    /// element is written) — the FramePool reuse precondition.
    #[test]
    fn overwrites_dirty_buffer() {
        let (h, w, bins) = (9, 11, 3);
        let img = random_image(h, w, bins, 5);
        let expected = integral_histogram_seq(&img);
        let mut scratch = TileScratch::default();
        scratch.ensure(16, bins);
        let mut colc = vec![0.0f32; bins * h];
        let mut out = vec![f32::NAN; bins * h * w];
        scan_tile(
            &img,
            0,
            0,
            h,
            w,
            &SharedTensor::new(&mut colc),
            &SharedTensor::new(&mut out),
            &mut scratch,
        );
        let got = IntegralHistogram::from_raw(bins, h, w, out);
        assert_eq!(expected.max_abs_diff(&got), 0.0);
    }

    #[test]
    fn scratch_ensure_is_idempotent() {
        let mut s = TileScratch::default();
        s.ensure(8, 4);
        let p0 = s.pos.as_ptr();
        s.ensure(8, 4);
        assert_eq!(p0, s.pos.as_ptr(), "no realloc when already sized");
        s.ensure(16, 4);
        assert_eq!(s.tile(), 16);
    }
}
