//! The fused multi-bin tile kernel — one image read per tile, all bins.
//!
//! This is the §3.5 data-movement argument applied inside a tile: the
//! per-bin strategies ([`crate::histogram::parallel`],
//! [`crate::histogram::tiled`]) re-read the image once per bin plane and
//! spend one compare per (bin, pixel) recovering the one-hot Q value.
//! Here each tile row is read **once** and counting-sorted into per-bin
//! column buckets; the scan then exploits that the Q function is one-hot:
//! for a fixed bin the row prefix is a step function, so the recurrence
//!
//! ```text
//! H(k, x, y) = H(k, x-1, y) + rowprefix(k, x, y)
//! ```
//!
//! is evaluated segment-wise — between two bin-k pixels the row prefix
//! `run` is constant and the inner loop degenerates to `cur[c] = prev[c]
//! + run`, a branch-free slice add the compiler vectorizes.  Amortized
//! work per output element drops from ~6 dependent scalar ops (load,
//! compare, two adds, carried sum, store) to ~1 SIMD-friendly add+store,
//! and image traffic drops `bins×`.
//!
//! Carries between tiles follow Algorithm 5: `colc[k·h + x]` holds the
//! bin-k row prefix of global row `x` up to the tile's left edge (the
//! WF-TiS right-edge carry), and the top-edge carry needs no extra
//! buffer — the tile above's bottom output row *is* `H(k, x-1, ·)` and
//! is read directly from the output tensor (its completion is ordered by
//! the wavefront dependency).
//!
//! ## Kernel variants
//!
//! Phase 2 ships in two shapes selected by the tuned plan
//! ([`KernelVariant`], chosen by [`crate::tune::TunedPlanner`] from
//! measured per-tile throughput):
//!
//! * [`KernelVariant::Reference`] — the original bin-major sweep above;
//!   the arbiter every other path is property-tested against.
//! * [`KernelVariant::Tuned`] — same per-cell arithmetic, two extra
//!   levers: the segment fill/add inner loops are explicitly unrolled
//!   4-wide (one f32 SIMD lane), and the row loop is cache-blocked
//!   ([`ROW_BLOCK`] rows × all bins per block) so the two active output
//!   rows of every bin stay L1/L2-resident across the bin sweep instead
//!   of being evicted `bins` times per tile.  Bit-identical by
//!   construction: each `(bin, row)` cell performs the identical
//!   element-wise ops (no reassociation), and blocking only reorders
//!   cells across *bins*, never past the row-above dependency within a
//!   bin (block rows are swept top-to-bottom with all bins completing a
//!   block before the next starts).
//!
//! ## Aliasing discipline
//!
//! Concurrent wavefront workers share the output tensor and the carry
//! plane through [`SharedTensor`], which hands out **row-segment**
//! slices, never whole-buffer `&mut` views.  Two tiles may run
//! concurrently only if they are dependency-incomparable, which for the
//! left/top dependency DAG implies different tile rows *and* different
//! tile columns — so their written row segments `(bin, row, [tj,
//! tj+tw))` are disjoint, and a tile's read of the row above (its top
//! carry) shares no element with any concurrently written segment.
//! Every live reference therefore covers a disjoint element range.

use crate::histogram::types::BinnedImage;

/// A shared window over one `f32` buffer from which workers borrow
/// disjoint row-segment slices.  The wavefront dependency order (plus
/// the scheduler's mutex for the happens-before edge) guarantees the
/// segments requested by concurrent tiles never overlap — see the
/// module-level aliasing notes.
pub struct SharedTensor {
    ptr: *mut f32,
    len: usize,
}

unsafe impl Send for SharedTensor {}
unsafe impl Sync for SharedTensor {}

impl SharedTensor {
    pub fn new(buf: &mut [f32]) -> SharedTensor {
        SharedTensor { ptr: buf.as_mut_ptr(), len: buf.len() }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Mutable segment `[start, start + n)`.
    ///
    /// # Safety
    /// The caller must guarantee no other live reference overlaps the
    /// range (the wavefront schedule provides this for tile segments;
    /// the engine's pooled bin-parallel path provides it by handing
    /// each claimed bin plane to exactly one worker).
    #[inline]
    pub(crate) unsafe fn seg_mut(&self, start: usize, n: usize) -> &mut [f32] {
        debug_assert!(start + n <= self.len);
        std::slice::from_raw_parts_mut(self.ptr.add(start), n)
    }

    /// Shared segment `[start, start + n)`.
    ///
    /// # Safety
    /// The caller must guarantee no live *mutable* reference overlaps
    /// the range and that its contents have been published (here: via
    /// the scheduler mutex) before the read.
    #[inline]
    unsafe fn seg(&self, start: usize, n: usize) -> &[f32] {
        debug_assert!(start + n <= self.len);
        std::slice::from_raw_parts(self.ptr.add(start), n)
    }
}

/// Reusable per-worker scratch: the per-row counting-sort buckets.
/// Sized for one `tile × tile` block at a given bin count; `ensure`
/// reallocates only when the configuration changes, so steady-state
/// frames perform no heap allocation.
#[derive(Debug, Default)]
pub struct TileScratch {
    /// Per-row bucket boundaries: `start[r·(bins+1) + k]` is the first
    /// index in `pos` of row r's bin-k columns (prefix-sum layout).
    start: Vec<u32>,
    /// Per-row pixel columns grouped by bin, ascending within a bin:
    /// `pos[r·tile + j]`.
    pos: Vec<u32>,
    /// Write cursors for the counting sort (length `bins`).
    cur: Vec<u32>,
    tile: usize,
    bins: usize,
}

impl TileScratch {
    /// (Re)size for `tile` and `bins`; no-op when already sized.
    pub fn ensure(&mut self, tile: usize, bins: usize) {
        if self.tile != tile || self.bins != bins {
            self.start = vec![0; tile * (bins + 1)];
            self.pos = vec![0; tile * tile];
            self.cur = vec![0; bins];
            self.tile = tile;
            self.bins = bins;
        }
    }

    pub fn tile(&self) -> usize {
        self.tile
    }
}

/// Which phase-2 code shape to run — the auto-tuner's kernel lever (see
/// the module-level "Kernel variants" notes).  Both variants produce
/// bit-identical tensors; they differ only in loop structure.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KernelVariant {
    /// Bin-major sweep, compiler-vectorized segment loops — the
    /// arbiter.
    #[default]
    Reference,
    /// Row-blocked bin sweep + explicitly 4-wide-unrolled segment
    /// loops.
    Tuned,
}

impl KernelVariant {
    pub const ALL: [KernelVariant; 2] = [KernelVariant::Reference, KernelVariant::Tuned];

    /// Stable lowercase name for plan caches and bench JSON.
    pub fn name(self) -> &'static str {
        match self {
            KernelVariant::Reference => "reference",
            KernelVariant::Tuned => "tuned",
        }
    }

    /// Inverse of [`KernelVariant::name`] (for tuning-cache loads).
    pub fn from_name(s: &str) -> Option<KernelVariant> {
        match s {
            "reference" => Some(KernelVariant::Reference),
            "tuned" => Some(KernelVariant::Tuned),
            _ => None,
        }
    }
}

/// Rows per cache block in the tuned phase 2: all bins' segment scans
/// for one block of rows run before the next block starts, keeping
/// every bin's two active `tw`-wide rows hot.  8 rows × 128 cols × 2
/// rows-live × 4 B ≈ 8 KiB per bin pair — comfortably L1 at any
/// [`crate::tune::TILE_CANDIDATES`] edge.
pub const ROW_BLOCK: usize = 8;

/// `cur[i] = run` over a segment (constant row prefix, no bin-k pixel).
#[inline]
fn fill_run(cur: &mut [f32], run: f32) {
    for v in cur {
        *v = run;
    }
}

/// `cur[i] = prev[i] + run` over a segment — the vectorizable hot loop.
#[inline]
fn add_run(cur: &mut [f32], prev: &[f32], run: f32) {
    for (v, &p) in cur.iter_mut().zip(prev) {
        *v = p + run;
    }
}

/// [`fill_run`], explicitly unrolled 4-wide (one f32 SSE lane).  A
/// plain store loop either way — trivially value-identical; the
/// remainder loop covers segments and tiles narrower than the lane.
#[inline]
fn fill_run_x4(cur: &mut [f32], run: f32) {
    let mut it = cur.chunks_exact_mut(4);
    for c in &mut it {
        c[0] = run;
        c[1] = run;
        c[2] = run;
        c[3] = run;
    }
    for v in it.into_remainder() {
        *v = run;
    }
}

/// [`add_run`], explicitly unrolled 4-wide.  Each element is computed
/// as exactly `prev[i] + run` — element-wise, no reassociation — so the
/// result is bit-identical to the reference loop.
#[inline]
fn add_run_x4(cur: &mut [f32], prev: &[f32], run: f32) {
    let n = cur.len();
    debug_assert_eq!(prev.len(), n);
    let mut i = 0usize;
    while i + 4 <= n {
        cur[i] = prev[i] + run;
        cur[i + 1] = prev[i + 1] + run;
        cur[i + 2] = prev[i + 2] + run;
        cur[i + 3] = prev[i + 3] + run;
        i += 4;
    }
    while i < n {
        cur[i] = prev[i] + run;
        i += 1;
    }
}

/// Phase 1: one pass over the tile's pixels — counting-sort each row's
/// columns by bin.  This is the only read of the image; both phase-2
/// variants consume the same bucket structure.
///
/// Pixels with values outside `[0, bins)` (e.g. the −1 padding of
/// §3.4, or any stray out-of-range index) count in no bin, matching
/// the per-bin baselines' `== k` semantics.
#[inline]
fn bucket_tile(
    img: &BinnedImage,
    ti: usize,
    tj: usize,
    th: usize,
    tw: usize,
    scratch: &mut TileScratch,
) {
    let (w, bins) = (img.w, img.bins);
    let tile = scratch.tile;
    let bp1 = bins + 1;
    for r in 0..th {
        let rowbase = (ti + r) * w + tj;
        let st = &mut scratch.start[r * bp1..(r + 1) * bp1];
        st.fill(0);
        for c in 0..tw {
            let v = img.data[rowbase + c];
            if v >= 0 && (v as usize) < bins {
                st[v as usize + 1] += 1;
            }
        }
        for k in 0..bins {
            st[k + 1] += st[k];
        }
        scratch.cur.copy_from_slice(&st[..bins]);
        let posr = &mut scratch.pos[r * tile..r * tile + tw];
        for c in 0..tw {
            let v = img.data[rowbase + c];
            if v >= 0 && (v as usize) < bins {
                let k = v as usize;
                posr[scratch.cur[k] as usize] = c as u32;
                scratch.cur[k] += 1;
            }
        }
    }
}

/// Phase 2 for one `(bin, row)` cell: segment-wise
/// `out[x] = out[x-1] + run`, `run` stepping at bin-k pixel columns.
/// Shared verbatim by both variants (`X4` only swaps the segment
/// helpers), so their per-cell arithmetic is identical by construction.
/// Returns the updated right-edge carry.
///
/// # Safety
/// Caller must own segment `(o, tw)` of `out` exclusively, and (for
/// `x > 0`) the row above `(o − w, tw)` must be complete and published
/// with no overlapping mutable borrow — the tile dependency order
/// provides both (see [`scan_tile`]'s SAFETY notes).
#[inline(always)]
unsafe fn scan_cell<const X4: bool>(
    out: &SharedTensor,
    o: usize,
    w: usize,
    x: usize,
    tw: usize,
    steps: &[u32],
    mut run: f32,
) -> f32 {
    if x == 0 {
        // Top image row: no row above, H(k,0,y) = run.
        let cur = unsafe { out.seg_mut(o, tw) };
        let mut c0 = 0usize;
        for &pc in steps {
            let pc = pc as usize;
            if X4 {
                fill_run_x4(&mut cur[c0..pc], run);
            } else {
                fill_run(&mut cur[c0..pc], run);
            }
            run += 1.0;
            cur[pc] = run;
            c0 = pc + 1;
        }
        if X4 {
            fill_run_x4(&mut cur[c0..], run);
        } else {
            fill_run(&mut cur[c0..], run);
        }
    } else {
        let (cur, prev) = unsafe { (out.seg_mut(o, tw), out.seg(o - w, tw)) };
        let mut c0 = 0usize;
        for &pc in steps {
            let pc = pc as usize;
            if X4 {
                add_run_x4(&mut cur[c0..pc], &prev[c0..pc], run);
            } else {
                add_run(&mut cur[c0..pc], &prev[c0..pc], run);
            }
            run += 1.0;
            cur[pc] = prev[pc] + run;
            c0 = pc + 1;
        }
        if X4 {
            add_run_x4(&mut cur[c0..], &prev[c0..], run);
        } else {
            add_run(&mut cur[c0..], &prev[c0..], run);
        }
    }
    run
}

/// Scan one `th × tw` tile at origin `(ti, tj)` for **all** bins,
/// writing final integral-histogram values into `out` (the full
/// `bins×h×w` tensor window) and updating the left-edge carries in
/// `colc` (layout `bins×h`).  Requires the tile above and to the left
/// (if any) to be complete — the wavefront partial order.
///
/// Bins are swept plane-major: the bucketed tile (phase 1) is reused
/// from L1 across every bin — the multi-bin fusion that amortizes the
/// image read `bins×` — while each bin's active window is just two
/// `tw`-wide rows, so the tile itself already bounds the working set
/// and no further bin-axis blocking is needed (the paper's "B-bin
/// block" alternative applies to un-tiled full-row sweeps).
pub fn scan_tile(
    img: &BinnedImage,
    ti: usize,
    tj: usize,
    th: usize,
    tw: usize,
    colc: &SharedTensor,
    out: &SharedTensor,
    scratch: &mut TileScratch,
) {
    let (h, w, bins) = (img.h, img.w, img.bins);
    let plane = h * w;
    let tile = scratch.tile;
    debug_assert!(th <= tile && tw <= tile, "scratch sized for a smaller tile");
    debug_assert_eq!(scratch.bins, bins, "scratch sized for a different bin count");
    debug_assert_eq!(colc.len(), bins * h);
    debug_assert_eq!(out.len(), bins * plane);
    let bp1 = bins + 1;

    bucket_tile(img, ti, tj, th, tw, scratch);

    // Phase 2: per bin, per row: segment-wise
    //   out[x] = out[x-1] + run,   run stepping at bin-k pixel columns.
    for k in 0..bins {
        let pbase = k * plane;
        // SAFETY: rows [ti, ti+th) of bin k's carry column are written
        // only by tiles in this tile-row strip, which the
        // left-dependency chain serializes.
        let carry = unsafe { colc.seg_mut(k * h + ti, th) };
        for r in 0..th {
            let x = ti + r;
            let o = pbase + x * w + tj;
            let row = r * bp1;
            let s0 = scratch.start[row + k] as usize;
            let s1 = scratch.start[row + k + 1] as usize;
            let steps = &scratch.pos[r * tile + s0..r * tile + s1];
            // SAFETY: this tile exclusively owns segment (k, x, [tj,
            // tj+tw)) until its completion is published.  The read
            // segment is one row up in the same columns: for r > 0 it
            // was written by this same tile; for r == 0 it belongs to
            // the finished tile above (published via the scheduler
            // mutex), and no concurrent tile's write segment overlaps
            // it (different tile row AND column — see module aliasing
            // notes).
            carry[r] = unsafe { scan_cell::<false>(out, o, w, x, tw, steps, carry[r]) };
        }
    }
}

/// The tuned-variant tile scan: identical phase 1, row-blocked phase 2
/// with the 4-wide-unrolled segment loops.
///
/// Bit-identity: every `(bin, row)` cell runs the same [`scan_cell`]
/// arithmetic on the same inputs.  Blocking reorders cells only across
/// bins; within a bin, rows are still visited strictly top-to-bottom
/// (ascending blocks, ascending rows inside a block), so cell `(k, r)`
/// always runs after `(k, r−1)` — the only intra-tile dependency (via
/// the output row above and nothing else; each cell touches exactly its
/// own `carry[r]` slot).
pub fn scan_tile_tuned(
    img: &BinnedImage,
    ti: usize,
    tj: usize,
    th: usize,
    tw: usize,
    colc: &SharedTensor,
    out: &SharedTensor,
    scratch: &mut TileScratch,
) {
    let (h, w, bins) = (img.h, img.w, img.bins);
    let plane = h * w;
    let tile = scratch.tile;
    debug_assert!(th <= tile && tw <= tile, "scratch sized for a smaller tile");
    debug_assert_eq!(scratch.bins, bins, "scratch sized for a different bin count");
    debug_assert_eq!(colc.len(), bins * h);
    debug_assert_eq!(out.len(), bins * plane);
    let bp1 = bins + 1;

    bucket_tile(img, ti, tj, th, tw, scratch);

    // Phase 2, cache-blocked: ROW_BLOCK rows × all bins per block.
    let mut r0 = 0usize;
    while r0 < th {
        let r1 = (r0 + ROW_BLOCK).min(th);
        for k in 0..bins {
            let pbase = k * plane;
            // SAFETY: as in `scan_tile` — this tile owns rows
            // [ti, ti+th) of bin k's carry column; re-borrowing the
            // same segment per block is still exclusive (no borrow
            // outlives the block).
            let carry = unsafe { colc.seg_mut(k * h + ti, th) };
            for r in r0..r1 {
                let x = ti + r;
                let o = pbase + x * w + tj;
                let row = r * bp1;
                let s0 = scratch.start[row + k] as usize;
                let s1 = scratch.start[row + k + 1] as usize;
                let steps = &scratch.pos[r * tile + s0..r * tile + s1];
                // SAFETY: identical ownership argument to `scan_tile`;
                // the row above (x − 1) is complete because blocks and
                // rows-within-block both ascend.
                carry[r] = unsafe { scan_cell::<true>(out, o, w, x, tw, steps, carry[r]) };
            }
        }
        r0 = r1;
    }
}

/// Variant dispatch — the single entry the schedules call with the
/// tuned plan's [`KernelVariant`].
#[inline]
pub fn scan_tile_v(
    img: &BinnedImage,
    ti: usize,
    tj: usize,
    th: usize,
    tw: usize,
    colc: &SharedTensor,
    out: &SharedTensor,
    scratch: &mut TileScratch,
    variant: KernelVariant,
) {
    match variant {
        KernelVariant::Reference => scan_tile(img, ti, tj, th, tw, colc, out, scratch),
        KernelVariant::Tuned => scan_tile_tuned(img, ti, tj, th, tw, colc, out, scratch),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::histogram::sequential::integral_histogram_seq;
    use crate::histogram::types::IntegralHistogram;
    use crate::util::prng::Xoshiro256;

    fn random_image(h: usize, w: usize, bins: usize, seed: u64) -> BinnedImage {
        let mut rng = Xoshiro256::new(seed);
        let mut data = vec![0i32; h * w];
        rng.fill_bins(&mut data, bins as u32);
        BinnedImage::new(h, w, bins, data)
    }

    fn run_single_tile(img: &BinnedImage) -> IntegralHistogram {
        run_single_tile_v(img, KernelVariant::Reference)
    }

    fn run_single_tile_v(img: &BinnedImage, variant: KernelVariant) -> IntegralHistogram {
        let (h, w, bins) = (img.h, img.w, img.bins);
        let tile = h.max(w);
        let mut scratch = TileScratch::default();
        scratch.ensure(tile, bins);
        let mut colc = vec![0.0f32; bins * h];
        let mut out = vec![0.0f32; bins * h * w];
        scan_tile_v(
            img,
            0,
            0,
            h,
            w,
            &SharedTensor::new(&mut colc),
            &SharedTensor::new(&mut out),
            &mut scratch,
            variant,
        );
        IntegralHistogram::from_raw(bins, h, w, out)
    }

    /// One tile covering the whole image must reproduce Algorithm 1.
    #[test]
    fn single_tile_matches_algorithm1() {
        for (h, w, bins) in [(1, 1, 1), (7, 9, 4), (16, 16, 8), (13, 5, 3)] {
            let img = random_image(h, w, bins, (h * 100 + w) as u64);
            let expected = integral_histogram_seq(&img);
            let got = run_single_tile(&img);
            assert_eq!(expected.max_abs_diff(&got), 0.0, "{h}x{w}x{bins}");
        }
    }

    /// The tuned variant is bit-identical to the reference on
    /// adversarial shapes — including `w < 4` (below the unroll lane
    /// width), rows taller than [`ROW_BLOCK`], and non-multiples of
    /// both.
    #[test]
    fn tuned_variant_is_bit_identical() {
        for (h, w, bins) in [
            (1, 1, 1),
            (3, 2, 5),   // w < lane width
            (9, 3, 4),   // block remainder + w < lane
            (8, 8, 2),   // exact ROW_BLOCK
            (17, 23, 7), // ragged everything
            (33, 5, 3),  // several blocks, narrow
        ] {
            let img = random_image(h, w, bins, (h * 1000 + w * 10 + bins) as u64);
            let reference = run_single_tile_v(&img, KernelVariant::Reference);
            let tuned = run_single_tile_v(&img, KernelVariant::Tuned);
            assert_eq!(reference, tuned, "{h}x{w}x{bins} must be bit-identical");
            let expected = integral_histogram_seq(&img);
            assert_eq!(expected.max_abs_diff(&tuned), 0.0, "{h}x{w}x{bins} vs Algorithm 1");
        }
    }

    /// Tuned multi-tile sweep (carries crossing tiles) is bit-identical
    /// to the reference sweep over the same tiling.
    #[test]
    fn tuned_tile_sweep_is_bit_identical() {
        let (h, w, bins, tile) = (23, 31, 5, 8);
        let img = random_image(h, w, bins, 99);
        let mut outs = Vec::new();
        for variant in KernelVariant::ALL {
            let mut scratch = TileScratch::default();
            scratch.ensure(tile, bins);
            let mut colc = vec![0.0f32; bins * h];
            let mut out = vec![0.0f32; bins * h * w];
            {
                let colc_win = SharedTensor::new(&mut colc);
                let out_win = SharedTensor::new(&mut out);
                let mut ti = 0;
                while ti < h {
                    let th = tile.min(h - ti);
                    let mut tj = 0;
                    while tj < w {
                        let tw = tile.min(w - tj);
                        scan_tile_v(&img, ti, tj, th, tw, &colc_win, &out_win, &mut scratch, variant);
                        tj += tile;
                    }
                    ti += tile;
                }
            }
            outs.push(out);
        }
        assert_eq!(outs[0], outs[1], "sweep variants must be bit-identical");
    }

    /// Row-major tile sweep (wavefront-legal order) over ragged tiles.
    #[test]
    fn tile_sweep_matches_algorithm1() {
        let (h, w, bins, tile) = (23, 31, 5, 8);
        let img = random_image(h, w, bins, 99);
        let expected = integral_histogram_seq(&img);
        let mut scratch = TileScratch::default();
        scratch.ensure(tile, bins);
        let mut colc = vec![0.0f32; bins * h];
        let mut out = vec![0.0f32; bins * h * w];
        {
            let colc_win = SharedTensor::new(&mut colc);
            let out_win = SharedTensor::new(&mut out);
            let mut ti = 0;
            while ti < h {
                let th = tile.min(h - ti);
                let mut tj = 0;
                while tj < w {
                    let tw = tile.min(w - tj);
                    scan_tile(&img, ti, tj, th, tw, &colc_win, &out_win, &mut scratch);
                    tj += tile;
                }
                ti += tile;
            }
        }
        let got = IntegralHistogram::from_raw(bins, h, w, out);
        assert_eq!(expected.max_abs_diff(&got), 0.0);
    }

    /// Padding pixels (bin −1) and stray out-of-range values count in
    /// no plane — matching the `== k` baselines' tolerance.
    #[test]
    fn out_of_range_bins_are_ignored() {
        let mut img = BinnedImage::new(2, 3, 2, vec![-1, 0, 1, 1, -1, 0]);
        let expected = integral_histogram_seq(&img);
        let got = run_single_tile(&img);
        assert_eq!(expected.max_abs_diff(&got), 0.0);
        // a stray value == bins must not panic and counts nowhere
        img.data[1] = 2;
        let expected = integral_histogram_seq(&img);
        for variant in KernelVariant::ALL {
            let got = run_single_tile_v(&img, variant);
            assert_eq!(expected.max_abs_diff(&got), 0.0, "{}", variant.name());
        }
    }

    /// A dirty output buffer must not leak into the result (every
    /// element is written) — the FramePool reuse precondition.
    #[test]
    fn overwrites_dirty_buffer() {
        let (h, w, bins) = (9, 11, 3);
        let img = random_image(h, w, bins, 5);
        let expected = integral_histogram_seq(&img);
        for variant in KernelVariant::ALL {
            let mut scratch = TileScratch::default();
            scratch.ensure(16, bins);
            let mut colc = vec![0.0f32; bins * h];
            let mut out = vec![f32::NAN; bins * h * w];
            scan_tile_v(
                &img,
                0,
                0,
                h,
                w,
                &SharedTensor::new(&mut colc),
                &SharedTensor::new(&mut out),
                &mut scratch,
                variant,
            );
            let got = IntegralHistogram::from_raw(bins, h, w, out);
            assert_eq!(expected.max_abs_diff(&got), 0.0, "{}", variant.name());
        }
    }

    #[test]
    fn scratch_ensure_is_idempotent() {
        let mut s = TileScratch::default();
        s.ensure(8, 4);
        let p0 = s.pos.as_ptr();
        s.ensure(8, 4);
        assert_eq!(p0, s.pos.as_ptr(), "no realloc when already sized");
        s.ensure(16, 4);
        assert_eq!(s.tile(), 16);
    }

    #[test]
    fn variant_names_roundtrip() {
        for v in KernelVariant::ALL {
            assert_eq!(KernelVariant::from_name(v.name()), Some(v));
        }
        assert_eq!(KernelVariant::from_name("bogus"), None);
    }
}
