//! `WorkerPool` — long-lived parked worker threads with epoch-based
//! task handoff, replacing the per-frame `thread::scope` spawning the
//! parallel schedules used through PR 1 (the "persistent worker pool"
//! DESIGN.md §2.4 deferred).
//!
//! The paper's serving layer owes its steady-state throughput to never
//! paying setup costs per frame: buffers are page-locked once (§4.4),
//! executors compiled once, devices owned for the whole run (§4.6).
//! Thread creation was the one remaining per-frame setup cost on the
//! CPU substrate.  This pool closes it:
//!
//! * **Parked workers.** `new(n)` spawns `n` threads once; between jobs
//!   they block on a condvar.  A steady-state frame performs zero
//!   `thread::spawn` calls — the [`WorkerPoolStats::spawned`] counter
//!   makes that assertable (`tests/engine_property.rs`,
//!   `tests/server_concurrency.rs`).
//! * **Per-worker scratch slabs.** Each worker thread owns a
//!   [`TileScratch`] that persists across jobs; `TileScratch::ensure`
//!   reallocates only when the (tile, bins) configuration changes, so
//!   repeated frames at one geometry touch no allocator.
//! * **Epoch handoff.** A job is published as a type-erased call
//!   (`fn`-pointer + context pointer) under one mutex together with a
//!   bumped epoch; workers whose slot index is below the job's
//!   participant count run it, everyone else just records the epoch and
//!   parks again.  The submitting thread participates as slot 0 (with
//!   its own scratch) and then blocks until every participant has
//!   finished — the structured-concurrency invariant that makes the
//!   lifetime erasure sound: the borrowed closure outlives every use.
//!
//! One pool serves one submitter at a time ([`WorkerPool::run`] takes
//! `&mut self`), which is exactly the [`super::ScanEngine`] ownership
//! model: each engine (one per stream lane / server checkout) owns its
//! pool, so concurrent streams never contend on a scheduler.

use crate::histogram::engine::kernel::TileScratch;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;

/// A published job: lifetime-erased `Fn(slot, &mut TileScratch)`.
#[derive(Clone, Copy)]
struct Task {
    run: unsafe fn(*const (), usize, &mut TileScratch),
    ctx: *const (),
}

// SAFETY: `ctx` points at a closure that is `Sync` (enforced by the
// bound on `run`) and is kept alive by the submitting thread until
// every participant reports completion.
unsafe impl Send for Task {}

unsafe fn call_thunk<F: Fn(usize, &mut TileScratch) + Sync>(
    ctx: *const (),
    slot: usize,
    scratch: &mut TileScratch,
) {
    (*(ctx as *const F))(slot, scratch)
}

/// Scheduler state shared between the submitter and the workers.
struct State {
    /// Bumped once per job; workers compare against their last-seen value.
    epoch: u64,
    task: Option<Task>,
    /// Workers with slot index `< participants` run the current job.
    participants: usize,
    /// Participants still running the current job.
    active: usize,
    /// A participant panicked while running the current job.
    poisoned: bool,
    /// Slots whose worker threads unwound away on a task panic.  The
    /// submitter replaces them (join + respawn) at the top of the next
    /// [`WorkerPool::run`], so a panic costs one job's parallelism,
    /// not the pool's.
    dead_slots: Vec<usize>,
    shutdown: bool,
}

struct Shared {
    state: Mutex<State>,
    /// Workers park here waiting for a new epoch.
    work: Condvar,
    /// The submitter parks here waiting for `active == 0`.
    done: Condvar,
}

impl Shared {
    /// Lock the state, recovering from mutex poisoning (our critical
    /// sections contain no panicking operations; a poisoned lock only
    /// means some worker's task panicked outside it).
    fn lock(&self) -> MutexGuard<'_, State> {
        self.state.lock().unwrap_or_else(|e| e.into_inner())
    }
}

/// Pool observability counters.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct WorkerPoolStats {
    /// Worker threads currently alive in the pool.
    pub threads: usize,
    /// Threads ever spawned — constant in a panic-free run; the
    /// steady-state "zero thread spawns" assertion reads this, and it
    /// grows by exactly one per replaced worker.
    pub spawned: usize,
    /// Jobs dispatched through [`WorkerPool::run`] (parallel or not).
    pub jobs: usize,
    /// Dead workers detected and replaced (counter-asserted in
    /// `pool_replaces_dead_worker_after_panic`).
    pub replaced: usize,
}

/// A fixed-size pool of parked worker threads.  See the module docs.
pub struct WorkerPool {
    shared: Arc<Shared>,
    /// Slot-indexed; `None` only if a respawn failed (the pool then
    /// degrades to caller-only rather than deadlock on the empty slot).
    handles: Vec<Option<JoinHandle<()>>>,
    spawned: usize,
    replaced: usize,
    jobs: AtomicUsize,
}

impl std::fmt::Debug for WorkerPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("WorkerPool")
            .field("threads", &self.handles.len())
            .field("jobs", &self.jobs.load(Ordering::Relaxed))
            .finish()
    }
}

/// Decrements `active` when a worker finishes (or unwinds out of) a
/// task, so the submitter can never deadlock on a panicked participant.
struct ActiveGuard<'a> {
    shared: &'a Shared,
    slot: usize,
}

impl Drop for ActiveGuard<'_> {
    fn drop(&mut self) {
        let mut st = self.shared.lock();
        if std::thread::panicking() {
            st.poisoned = true;
            st.dead_slots.push(self.slot);
        }
        st.active -= 1;
        if st.active == 0 {
            self.shared.done.notify_all();
        }
    }
}

/// Blocks until the in-flight job completes; runs even if the
/// submitter's own slot-0 call unwinds, so borrowed context is never
/// freed while a helper still uses it.
struct JobGuard<'a> {
    shared: &'a Shared,
}

impl Drop for JobGuard<'_> {
    fn drop(&mut self) {
        let mut st = self.shared.lock();
        while st.active > 0 {
            st = match self.shared.done.wait(st) {
                Ok(g) => g,
                Err(e) => e.into_inner(),
            };
        }
        st.task = None;
    }
}

/// `init_epoch` is the scheduler epoch at spawn time — a replacement
/// worker must start from the *current* epoch, not 0: starting behind
/// would make it "see" an epoch bump for a job that already drained
/// (stale `task` is `None` → panic), and starting ahead would make it
/// skip the next real job (its `active` slot never drains → deadlock).
fn worker_loop(shared: &Shared, slot: usize, init_epoch: u64) {
    let mut scratch = TileScratch::default();
    let mut seen = init_epoch;
    loop {
        let task = {
            let mut st = shared.lock();
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != seen {
                    seen = st.epoch;
                    if slot < st.participants {
                        break st.task.expect("task published with the epoch");
                    }
                }
                st = match shared.work.wait(st) {
                    Ok(g) => g,
                    Err(e) => e.into_inner(),
                };
            }
        };
        // Run outside the lock; the guard keeps `active` correct even
        // if the task panics (the panic then ends this worker thread,
        // registers its slot for replacement, and the submitter
        // re-raises via the poison flag).
        let _g = ActiveGuard { shared, slot };
        // SAFETY: the submitter keeps the closure alive until `active`
        // reaches 0, which this thread only signals after returning.
        unsafe { (task.run)(task.ctx, slot + 1, &mut scratch) };
    }
}

impl WorkerPool {
    /// Spawn `threads` parked workers (0 is valid: every job then runs
    /// on the caller alone).
    pub fn new(threads: usize) -> WorkerPool {
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                epoch: 0,
                task: None,
                participants: 0,
                active: 0,
                poisoned: false,
                dead_slots: Vec::new(),
                shutdown: false,
            }),
            work: Condvar::new(),
            done: Condvar::new(),
        });
        let mut handles = Vec::with_capacity(threads);
        for slot in 0..threads {
            let shared = Arc::clone(&shared);
            let h = std::thread::Builder::new()
                .name(format!("inthist-worker-{slot}"))
                .spawn(move || worker_loop(&shared, slot, 0))
                .expect("spawn pool worker");
            handles.push(Some(h));
        }
        WorkerPool { shared, spawned: threads, replaced: 0, handles, jobs: AtomicUsize::new(0) }
    }

    /// Worker threads currently alive in the pool.
    pub fn threads(&self) -> usize {
        self.handles.iter().filter(|h| h.is_some()).count()
    }

    pub fn stats(&self) -> WorkerPoolStats {
        WorkerPoolStats {
            threads: self.threads(),
            spawned: self.spawned,
            jobs: self.jobs.load(Ordering::Relaxed),
            replaced: self.replaced,
        }
    }

    /// Join and respawn any workers lost to task panics since the last
    /// job.  Runs at the top of [`Self::run`]: `&mut self` guarantees
    /// no job is in flight, so the epoch read here is the one the
    /// replacement worker must resume from.
    fn replace_dead(&mut self) {
        let (dead, epoch) = {
            let mut st = self.shared.lock();
            if st.dead_slots.is_empty() {
                return;
            }
            (std::mem::take(&mut st.dead_slots), st.epoch)
        };
        for slot in dead {
            if let Some(h) = self.handles[slot].take() {
                let _ = h.join(); // the unwinding thread; completes promptly
            }
            let shared = Arc::clone(&self.shared);
            match std::thread::Builder::new()
                .name(format!("inthist-worker-{slot}"))
                .spawn(move || worker_loop(&shared, slot, epoch))
            {
                Ok(h) => {
                    self.handles[slot] = Some(h);
                    self.spawned += 1;
                    self.replaced += 1;
                }
                Err(_) => {
                    // Respawn refused (fd/thread exhaustion): leave the
                    // slot empty; run() degrades to caller-only.
                }
            }
        }
    }

    /// Run `f` on `helpers` pool workers (slots `1..=helpers`, clamped
    /// to the pool size) plus the calling thread (slot 0, using
    /// `caller_scratch`), returning once every participant finished.
    ///
    /// `&mut self` enforces one job in flight per pool; the blocking
    /// return is what lets `f` borrow from the caller's stack.
    pub fn run<F>(&mut self, helpers: usize, caller_scratch: &mut TileScratch, f: F)
    where
        F: Fn(usize, &mut TileScratch) + Sync,
    {
        self.jobs.fetch_add(1, Ordering::Relaxed);
        self.replace_dead();
        // Slot assignment is fixed per thread, so an empty slot below
        // the participant count could never drain `active` (deadlock).
        // After replacement the only empty slots are failed respawns —
        // then run caller-only rather than risk dispatching into one.
        let helpers = if self.handles.iter().any(|h| h.is_none()) {
            0
        } else {
            helpers.min(self.handles.len())
        };
        if helpers == 0 {
            f(0, caller_scratch);
            return;
        }
        let task = Task { run: call_thunk::<F>, ctx: &f as *const F as *const () };
        {
            let mut st = self.shared.lock();
            st.epoch += 1;
            st.task = Some(task);
            st.participants = helpers;
            st.active = helpers;
            st.poisoned = false;
            self.shared.work.notify_all();
        }
        {
            // Wait for the helpers even if f(0) unwinds.
            let _job = JobGuard { shared: &self.shared };
            f(0, caller_scratch);
        }
        if self.shared.lock().poisoned {
            panic!("worker pool task panicked");
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.lock();
            st.shutdown = true;
            self.shared.work.notify_all();
        }
        for h in self.handles.drain(..).flatten() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU32;

    #[test]
    fn all_participants_run_exactly_once() {
        let mut pool = WorkerPool::new(3);
        for job in 0..50 {
            let seen = Mutex::new(Vec::new());
            let helpers = job % 4; // 0..=3
            pool.run(helpers, &mut TileScratch::default(), |slot, _s| {
                seen.lock().unwrap().push(slot);
            });
            let mut got = seen.into_inner().unwrap();
            got.sort_unstable();
            let want: Vec<usize> = (0..=helpers).collect();
            assert_eq!(got, want, "job {job}");
        }
        assert_eq!(pool.stats().jobs, 50);
        assert_eq!(pool.stats().spawned, 3);
    }

    #[test]
    fn helpers_clamped_to_pool_size() {
        let mut pool = WorkerPool::new(2);
        let count = AtomicU32::new(0);
        pool.run(16, &mut TileScratch::default(), |_slot, _s| {
            count.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 3, "caller + 2 pool workers");
    }

    #[test]
    fn zero_thread_pool_runs_caller_only() {
        let mut pool = WorkerPool::new(0);
        let count = AtomicU32::new(0);
        pool.run(4, &mut TileScratch::default(), |slot, _s| {
            assert_eq!(slot, 0);
            count.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 1);
        assert_eq!(pool.stats().spawned, 0);
    }

    #[test]
    fn pool_does_real_parallel_work() {
        let mut pool = WorkerPool::new(3);
        let total = AtomicU32::new(0);
        let next = AtomicU32::new(0);
        pool.run(3, &mut TileScratch::default(), |_slot, _s| loop {
            let i = next.fetch_add(1, Ordering::Relaxed);
            if i >= 1000 {
                break;
            }
            total.fetch_add(i, Ordering::Relaxed);
        });
        assert_eq!(total.load(Ordering::Relaxed), 999 * 1000 / 2);
    }

    #[test]
    fn spawn_counter_is_flat_across_jobs() {
        let mut pool = WorkerPool::new(2);
        for _ in 0..100 {
            pool.run(2, &mut TileScratch::default(), |_s, _t| {});
        }
        let st = pool.stats();
        assert_eq!(st.spawned, 2, "steady state must never spawn");
        assert_eq!(st.jobs, 100);
    }

    #[test]
    #[should_panic(expected = "worker pool task panicked")]
    fn helper_panic_propagates_to_submitter() {
        let mut pool = WorkerPool::new(1);
        pool.run(1, &mut TileScratch::default(), |slot, _s| {
            if slot == 1 {
                panic!("boom");
            }
        });
    }

    /// After a caught helper panic the pool must detect the dead slot,
    /// replace the worker, and restore full parallelism — never
    /// deadlock, never permanently degrade.
    #[test]
    fn pool_replaces_dead_worker_after_panic() {
        let mut pool = WorkerPool::new(1);
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(1, &mut TileScratch::default(), |slot, _s| {
                if slot == 1 {
                    panic!("boom");
                }
            });
        }));
        assert!(outcome.is_err(), "panic must propagate");
        let seen = Mutex::new(Vec::new());
        pool.run(1, &mut TileScratch::default(), |slot, _s| {
            seen.lock().unwrap().push(slot);
        });
        let mut got = seen.into_inner().unwrap();
        got.sort_unstable();
        assert_eq!(got, vec![0, 1], "replacement restores full parallelism");
        let st = pool.stats();
        assert_eq!(st.replaced, 1, "exactly one worker was replaced");
        assert_eq!(st.spawned, 2, "original + replacement");
        assert_eq!(st.threads, 1);
    }

    /// Replacement must work repeatedly — every panic cycle costs one
    /// respawn and nothing else.
    #[test]
    fn repeated_panics_keep_replacing() {
        let mut pool = WorkerPool::new(2);
        for cycle in 0..3u32 {
            let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                pool.run(2, &mut TileScratch::default(), |slot, _s| {
                    if slot == 2 {
                        panic!("cycle {cycle}");
                    }
                });
            }));
            assert!(outcome.is_err());
            let count = AtomicU32::new(0);
            pool.run(2, &mut TileScratch::default(), |_slot, _s| {
                count.fetch_add(1, Ordering::Relaxed);
            });
            assert_eq!(count.load(Ordering::Relaxed), 3, "cycle {cycle}: caller + 2 workers");
        }
        let st = pool.stats();
        assert_eq!(st.replaced, 3);
        assert_eq!(st.spawned, 5, "2 original + 3 replacements");
        assert_eq!(st.threads, 2);
    }
}
