//! The execution planner: picks tile size and schedule from the
//! request geometry and worker budget.
//!
//! The paper tunes one kernel configuration per artifact offline
//! (§4.2's block/tile sweeps); serving arbitrary geometries needs the
//! choice made per request instead.  The planner is deliberately a
//! small, deterministic decision table (observable via
//! [`crate::histogram::engine::ScanEngine::last_plan`]):
//!
//! * **Serial** — one worker, fused tile sweep.  Picked when the frame
//!   is too small to amortize thread hand-off, or only one worker is
//!   available.
//! * **BinParallel** — the classic per-bin-plane distribution
//!   ([`crate::histogram::parallel`]).  Picked only when the tile grid
//!   degenerates (a single tile row/column) so the wavefront has no
//!   diagonal to spread over, yet several bin planes exist.
//! * **Wavefront** — the fused anti-diagonal tile schedule
//!   ([`crate::histogram::engine::wavefront`]), the default whenever the
//!   grid is at least 2×2: its parallelism `min(h/t, w/t)` is
//!   bin-independent and its memory traffic is the WF-TiS single pass.

use crate::histogram::engine::kernel::KernelVariant;

/// Which execution schedule to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Schedule {
    /// Single-thread fused tile sweep.
    Serial,
    /// One worker per bin plane (the paper's OpenMP-style axis).
    BinParallel,
    /// Dependency-scheduled anti-diagonal tile wavefront (Algorithm 5).
    Wavefront,
}

/// A concrete execution plan for one request geometry.
///
/// The tile edge doubles as the cache-blocking knob: inside a tile the
/// bins are swept plane-major over an L1-resident bucket structure, so
/// no separate bin-axis blocking dimension exists (see
/// [`crate::histogram::engine::kernel`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Plan {
    pub schedule: Schedule,
    /// Tile edge in pixels.
    pub tile: usize,
    /// Workers the schedule will actually use (≤ the engine budget).
    pub workers: usize,
    /// Which tile-kernel code shape to run.  The static planner always
    /// picks the reference kernel; [`crate::tune::TunedPlanner`]
    /// selects per tile size from measured throughput.
    pub kernel: KernelVariant,
}

/// Work (in output elements) below which threading overhead dominates
/// and the serial schedule wins outright.
const SERIAL_WORK_LIMIT: usize = 1 << 17;

/// The planner.  Overrides exist so tests and benches can pin a
/// schedule or tile while keeping the engine's buffer management.
#[derive(Debug, Clone, Copy, Default)]
pub struct Planner {
    pub tile_override: Option<usize>,
    pub schedule_override: Option<Schedule>,
}

impl Planner {
    /// Plan for an `h×w`, `bins`-bin request with up to `workers`
    /// threads.
    pub fn plan(&self, h: usize, w: usize, bins: usize, workers: usize) -> Plan {
        assert!(h >= 1 && w >= 1 && bins >= 1, "empty request");
        let workers = workers.max(1);
        let tile = self.tile_override.unwrap_or_else(|| default_tile(h, w)).max(1);
        let tr = h.div_ceil(tile);
        let tc = w.div_ceil(tile);
        let diag = tr.min(tc);
        let schedule = self.schedule_override.unwrap_or({
            if workers == 1 || bins * h * w < SERIAL_WORK_LIMIT {
                Schedule::Serial
            } else if diag == 1 {
                // No anti-diagonal to spread over; fall back to the
                // bin axis if it exists.
                if bins > 1 {
                    Schedule::BinParallel
                } else {
                    Schedule::Serial
                }
            } else {
                Schedule::Wavefront
            }
        });
        let workers = match schedule {
            Schedule::Serial => 1,
            Schedule::BinParallel => workers.min(bins),
            Schedule::Wavefront => workers.min(diag.max(1)),
        };
        Plan { schedule, tile, workers, kernel: KernelVariant::Reference }
    }
}

/// Default tile edge: 64 (the paper's tuned WF-TiS tile, Fig. 10) for
/// large frames, shrinking so small frames still get a ≥2-wide grid.
pub fn default_tile(h: usize, w: usize) -> usize {
    let m = h.min(w);
    if m >= 256 {
        64
    } else if m >= 64 {
        32
    } else {
        16
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn big_frames_go_wavefront() {
        let p = Planner::default().plan(512, 512, 32, 8);
        assert_eq!(p.schedule, Schedule::Wavefront);
        assert_eq!(p.tile, 64);
        assert_eq!(p.workers, 8);
    }

    #[test]
    fn small_frames_go_serial() {
        let p = Planner::default().plan(64, 64, 8, 8);
        assert_eq!(p.schedule, Schedule::Serial);
        assert_eq!(p.workers, 1);
    }

    #[test]
    fn single_worker_goes_serial() {
        let p = Planner::default().plan(512, 512, 32, 1);
        assert_eq!(p.schedule, Schedule::Serial);
    }

    #[test]
    fn degenerate_grid_goes_bin_parallel() {
        // 1×N image: one tile row — no wavefront diagonal.
        let p = Planner::default().plan(8, 4096, 32, 4);
        assert_eq!(p.schedule, Schedule::BinParallel);
        assert_eq!(p.workers, 4);
        // ... unless there is only one bin plane too.
        let p1 = Planner::default().plan(8, 65536, 1, 4);
        assert_eq!(p1.schedule, Schedule::Serial);
    }

    #[test]
    fn wavefront_workers_capped_by_diagonal() {
        // 128×512 @ tile 32 → 4×16 grid → at most 4 wavefront workers.
        let p = Planner { tile_override: Some(32), ..Default::default() }.plan(128, 512, 32, 16);
        assert_eq!(p.schedule, Schedule::Wavefront);
        assert_eq!(p.workers, 4);
    }

    #[test]
    fn overrides_pin_choices() {
        let p = Planner {
            tile_override: Some(16),
            schedule_override: Some(Schedule::Wavefront),
        }
        .plan(40, 40, 2, 4);
        assert_eq!(p.schedule, Schedule::Wavefront);
        assert_eq!(p.tile, 16);
    }

    #[test]
    fn bin_parallel_capped_by_bins() {
        let p = Planner { schedule_override: Some(Schedule::BinParallel), ..Default::default() }
            .plan(512, 512, 4, 16);
        assert_eq!(p.workers, 4);
    }

    #[test]
    fn static_plans_use_the_reference_kernel() {
        assert_eq!(Planner::default().plan(512, 512, 32, 8).kernel, KernelVariant::Reference);
        assert_eq!(Planner::default().plan(64, 64, 8, 1).kernel, KernelVariant::Reference);
    }

    #[test]
    fn tile_shrinks_with_frame() {
        assert_eq!(default_tile(512, 512), 64);
        assert_eq!(default_tile(128, 512), 32);
        assert_eq!(default_tile(32, 512), 16);
    }
}
