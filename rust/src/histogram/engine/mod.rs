//! The `ScanEngine` — planned, zero-allocation, fully parallel integral
//! histograms on the CPU substrate.
//!
//! This subsystem replaces the ad-hoc strategy functions on the hot
//! path with the three mechanisms the paper's WF-TiS kernel owes its
//! 300 fps to (§3.5, Algorithm 5), mapped onto CPU hardware:
//!
//! 1. **Multi-bin fused sweeps** ([`kernel`]) — each image tile is read
//!    once and counting-sorted into per-bin column buckets; every bin
//!    plane is then produced from that L1-resident bucket structure with
//!    segment-wise vectorizable adds.  Image traffic drops `bins×`
//!    versus the per-plane baselines.
//! 2. **Anti-diagonal wavefront scheduling** ([`wavefront`]) — tiles
//!    become dependency-counted tasks executed by scoped workers, so
//!    parallelism scales with `(h/t)·(w/t)` tiles rather than with the
//!    bin count, reproducing Algorithm 5's schedule on threads.
//! 3. **Planned execution** ([`planner`]) — a small decision table picks
//!    serial / bin-parallel / wavefront plus the tile size per request
//!    geometry.
//!
//! Buffers (output tensor via the coordinator's
//! [`crate::coordinator::frame_pool::FramePool`], carries and scratch
//! owned by the engine) are recycled across frames: after warm-up the
//! steady-state [`ScanEngine::compute_into`] path allocates **no
//! per-frame buffers**.  (Parallel schedules still spawn scoped worker
//! threads per call — sub-1% of a frame's compute at 512²×32; a
//! persistent worker pool is deliberate future work.)
//!
//! The legacy baselines ([`crate::histogram::sequential`],
//! [`crate::histogram::parallel`], [`crate::histogram::tiled`]) remain
//! as the comparators the engine is benchmarked and property-tested
//! against (`benches/hotpath.rs`, `tests/engine_property.rs`).

pub mod kernel;
pub mod planner;
pub mod wavefront;

pub use kernel::TileScratch;
pub use planner::{Plan, Planner, Schedule};
pub use wavefront::{integral_histogram_fused, integral_histogram_wavefront};

use crate::histogram::types::{BinnedImage, IntegralHistogram};

/// The planned scan engine.  Owns every reusable buffer except the
/// output tensor (which the caller provides, typically from a
/// `FramePool`), so repeated [`Self::compute_into`] calls at a fixed
/// configuration allocate nothing.
#[derive(Debug, Default)]
pub struct ScanEngine {
    planner: Planner,
    workers: usize,
    /// Per-worker tile bucket scratch.
    scratches: Vec<TileScratch>,
    /// Left-edge row-prefix carries, `bins×h` (Algorithm 5's inter-tile
    /// carry), zero-filled per frame without reallocation.
    colc: Vec<f32>,
    /// Scheduler storage (dependency counters, ready stack).
    wave: wavefront::WavefrontScratch,
    last_plan: Option<Plan>,
}

impl ScanEngine {
    /// Engine with a default planner and a `workers` thread budget
    /// (0 ⇒ all available cores).
    pub fn new(workers: usize) -> ScanEngine {
        Self::with_planner(workers, Planner::default())
    }

    pub fn with_planner(workers: usize, planner: Planner) -> ScanEngine {
        let workers = if workers == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            workers
        };
        ScanEngine { planner, workers, ..Default::default() }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    pub fn planner(&self) -> &Planner {
        &self.planner
    }

    pub fn planner_mut(&mut self) -> &mut Planner {
        &mut self.planner
    }

    /// The plan the engine would execute for this image.
    pub fn plan_for(&self, img: &BinnedImage) -> Plan {
        self.planner.plan(img.h, img.w, img.bins, self.workers)
    }

    /// The plan executed by the most recent compute call.
    pub fn last_plan(&self) -> Option<Plan> {
        self.last_plan
    }

    /// Allocating entry point (tests, one-off calls).
    pub fn compute(&mut self, img: &BinnedImage) -> IntegralHistogram {
        let mut out = IntegralHistogram::zeros(img.bins, img.h, img.w);
        self.compute_into(img, &mut out);
        out
    }

    /// Zero-allocation entry point: computes the integral histogram of
    /// `img` into `out`, resizing `out`'s storage only if its geometry
    /// differs (recycled buffers are reused *without* zeroing — every
    /// element is overwritten).
    pub fn compute_into(&mut self, img: &BinnedImage, out: &mut IntegralHistogram) {
        let n = img.bins * img.h * img.w;
        out.bins = img.bins;
        out.h = img.h;
        out.w = img.w;
        if out.data.len() != n {
            out.data.resize(n, 0.0);
        }
        let plan = self.planner.plan(img.h, img.w, img.bins, self.workers);
        self.last_plan = Some(plan);
        match plan.schedule {
            Schedule::BinParallel => {
                crate::histogram::parallel::integral_histogram_parallel_into(
                    img,
                    plan.workers,
                    &mut out.data,
                );
            }
            Schedule::Serial => {
                self.reset_carries(img);
                if self.scratches.is_empty() {
                    self.scratches.push(TileScratch::default());
                }
                wavefront::fused_scan_into(
                    img,
                    plan.tile,
                    &mut self.colc,
                    &mut self.scratches[0],
                    &mut out.data,
                );
            }
            Schedule::Wavefront => {
                self.reset_carries(img);
                wavefront::wavefront_scan_into(
                    img,
                    plan.tile,
                    plan.workers,
                    &mut self.colc,
                    &mut self.scratches,
                    &mut self.wave,
                    &mut out.data,
                );
            }
        }
    }

    /// Zero-fill the `bins×h` carry plane, reusing its capacity.
    fn reset_carries(&mut self, img: &BinnedImage) {
        self.colc.clear();
        self.colc.resize(img.bins * img.h, 0.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::histogram::sequential::integral_histogram_seq;
    use crate::util::prng::Xoshiro256;

    fn random_image(h: usize, w: usize, bins: usize, seed: u64) -> BinnedImage {
        let mut rng = Xoshiro256::new(seed);
        let mut data = vec![0i32; h * w];
        rng.fill_bins(&mut data, bins as u32);
        BinnedImage::new(h, w, bins, data)
    }

    #[test]
    fn engine_matches_algorithm1_across_schedules() {
        let img = random_image(70, 90, 6, 1);
        let expected = integral_histogram_seq(&img);
        for schedule in [Schedule::Serial, Schedule::BinParallel, Schedule::Wavefront] {
            let planner = Planner {
                tile_override: Some(16),
                schedule_override: Some(schedule),
            };
            let mut eng = ScanEngine::with_planner(4, planner);
            let got = eng.compute(&img);
            assert_eq!(expected.max_abs_diff(&got), 0.0, "{schedule:?}");
            assert_eq!(eng.last_plan().unwrap().schedule, schedule);
        }
    }

    #[test]
    fn compute_into_reuses_dirty_buffer() {
        let img_a = random_image(33, 47, 8, 2);
        let img_b = random_image(33, 47, 8, 3);
        let mut eng = ScanEngine::new(2);
        let mut buf = eng.compute(&img_a);
        // Recompute a different frame into the dirty buffer ...
        eng.compute_into(&img_b, &mut buf);
        let fresh = integral_histogram_seq(&img_b);
        assert_eq!(fresh.max_abs_diff(&buf), 0.0, "dirty reuse must be invisible");
        // ... and back, bit-identically.
        eng.compute_into(&img_a, &mut buf);
        let fresh_a = integral_histogram_seq(&img_a);
        assert_eq!(fresh_a.max_abs_diff(&buf), 0.0);
    }

    #[test]
    fn compute_into_resizes_on_geometry_change() {
        let mut eng = ScanEngine::new(2);
        let mut buf = eng.compute(&random_image(16, 16, 4, 4));
        let big = random_image(40, 24, 2, 5);
        eng.compute_into(&big, &mut buf);
        assert_eq!((buf.bins, buf.h, buf.w), (2, 40, 24));
        assert_eq!(buf.data.len(), 2 * 40 * 24);
        let expected = integral_histogram_seq(&big);
        assert_eq!(expected.max_abs_diff(&buf), 0.0);
    }

    #[test]
    fn zero_workers_means_available_parallelism() {
        let eng = ScanEngine::new(0);
        assert!(eng.workers() >= 1);
    }

    #[test]
    fn plan_for_is_stable() {
        let eng = ScanEngine::new(4);
        let img = random_image(512, 512, 32, 6);
        let p = eng.plan_for(&img);
        assert_eq!(p.schedule, Schedule::Wavefront);
        assert_eq!(p, eng.planner().plan(512, 512, 32, 4));
    }
}
