//! The `ScanEngine` — planned, zero-allocation, fully parallel integral
//! histograms on the CPU substrate.
//!
//! This subsystem replaces the ad-hoc strategy functions on the hot
//! path with the three mechanisms the paper's WF-TiS kernel owes its
//! 300 fps to (§3.5, Algorithm 5), mapped onto CPU hardware:
//!
//! 1. **Multi-bin fused sweeps** ([`kernel`]) — each image tile is read
//!    once and counting-sorted into per-bin column buckets; every bin
//!    plane is then produced from that L1-resident bucket structure with
//!    segment-wise vectorizable adds.  Image traffic drops `bins×`
//!    versus the per-plane baselines.
//! 2. **Anti-diagonal wavefront scheduling** ([`wavefront`]) — tiles
//!    become dependency-counted tasks executed by parked pool workers,
//!    so parallelism scales with `(h/t)·(w/t)` tiles rather than with
//!    the bin count, reproducing Algorithm 5's schedule on threads.
//! 3. **Planned execution** ([`planner`]) — a small decision table picks
//!    serial / bin-parallel / wavefront plus the tile size per request
//!    geometry.
//!
//! Buffers (output tensor via the coordinator's
//! [`crate::coordinator::frame_pool::FramePool`], carries and scratch
//! owned by the engine) are recycled across frames, and the parallel
//! schedules execute on a persistent [`WorkerPool`] of parked threads
//! ([`worker_pool`]): after warm-up the steady-state
//! [`ScanEngine::compute_into`] path allocates **no per-frame buffers
//! and spawns no threads** — both counter-asserted
//! (`tests/engine_property.rs`, `tests/server_concurrency.rs`).
//!
//! The legacy baselines ([`crate::histogram::sequential`],
//! [`crate::histogram::parallel`], [`crate::histogram::tiled`]) remain
//! as the comparators the engine is benchmarked and property-tested
//! against (`benches/hotpath.rs`, `tests/engine_property.rs`).

pub mod kernel;
pub mod planner;
pub mod wavefront;
pub mod worker_pool;

pub use kernel::{KernelVariant, TileScratch};
pub use planner::{Plan, Planner, Schedule};
pub use wavefront::{
    integral_histogram_fused, integral_histogram_fused_v, integral_histogram_wavefront,
    integral_histogram_wavefront_v,
};
pub use worker_pool::{WorkerPool, WorkerPoolStats};

use crate::histogram::engine::kernel::SharedTensor;
use crate::histogram::types::{BinnedImage, IntegralHistogram};
use crate::tune::TunedPlanner;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// The planned scan engine.  Owns every reusable buffer except the
/// output tensor (which the caller provides, typically from a
/// `FramePool`), plus a lazily-spawned persistent [`WorkerPool`], so
/// repeated [`Self::compute_into`] calls at a fixed configuration
/// allocate nothing and spawn nothing.
#[derive(Debug, Default)]
pub struct ScanEngine {
    planner: Planner,
    workers: usize,
    /// The calling thread's tile bucket scratch (worker slot 0; pool
    /// helpers own their slabs on their own threads).
    scratch: TileScratch,
    /// Left-edge row-prefix carries, `bins×h` (Algorithm 5's inter-tile
    /// carry), zero-filled per frame without reallocation.
    colc: Vec<f32>,
    /// Scheduler storage (dependency counters, ready stack).
    wave: wavefront::WavefrontScratch,
    /// Persistent helper threads, spawned once on the first parallel
    /// plan and parked between frames.
    pool: Option<WorkerPool>,
    last_plan: Option<Plan>,
    /// Optional auto-tuner (see [`crate::tune`]): when set, plans come
    /// from its calibrated cached search instead of the static decision
    /// table, and tile-sweep timings are fed back into its calibrator.
    /// Engines sharing one `Arc` share one tuning cache.
    tuner: Option<Arc<TunedPlanner>>,
}

impl ScanEngine {
    /// Engine with a default planner and a `workers` thread budget
    /// (0 ⇒ all available cores).
    pub fn new(workers: usize) -> ScanEngine {
        Self::with_planner(workers, Planner::default())
    }

    pub fn with_planner(workers: usize, planner: Planner) -> ScanEngine {
        let workers = if workers == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            workers
        };
        ScanEngine { planner, workers, ..Default::default() }
    }

    /// Engine planned by a shared [`TunedPlanner`] (calibrated cached
    /// auto-tune) instead of the static table.
    pub fn with_tuner(workers: usize, tuner: Arc<TunedPlanner>) -> ScanEngine {
        let mut eng = Self::new(workers);
        eng.tuner = Some(tuner);
        eng
    }

    /// Attach or detach the auto-tuner.
    pub fn set_tuner(&mut self, tuner: Option<Arc<TunedPlanner>>) {
        self.tuner = tuner;
    }

    pub fn tuner(&self) -> Option<&Arc<TunedPlanner>> {
        self.tuner.as_ref()
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    pub fn planner(&self) -> &Planner {
        &self.planner
    }

    pub fn planner_mut(&mut self) -> &mut Planner {
        &mut self.planner
    }

    /// The plan the engine would execute for this image.
    pub fn plan_for(&self, img: &BinnedImage) -> Plan {
        self.make_plan(img.h, img.w, img.bins)
    }

    /// Tuned plan when a tuner is attached, static plan otherwise.
    fn make_plan(&self, h: usize, w: usize, bins: usize) -> Plan {
        match &self.tuner {
            Some(t) => t.plan(h, w, bins, self.workers),
            None => self.planner.plan(h, w, bins, self.workers),
        }
    }

    /// The plan executed by the most recent compute call.
    pub fn last_plan(&self) -> Option<Plan> {
        self.last_plan
    }

    /// Worker-pool counters (zeros until the first parallel plan spawns
    /// the pool) — the steady-state "zero thread spawns" observability.
    pub fn pool_stats(&self) -> WorkerPoolStats {
        self.pool.as_ref().map(|p| p.stats()).unwrap_or_default()
    }

    /// Allocating entry point (tests, one-off calls).
    pub fn compute(&mut self, img: &BinnedImage) -> IntegralHistogram {
        let mut out = IntegralHistogram::zeros(img.bins, img.h, img.w);
        self.compute_into(img, &mut out);
        out
    }

    /// Zero-allocation entry point: computes the integral histogram of
    /// `img` into `out`, resizing `out`'s storage only if its geometry
    /// differs (recycled buffers are reused *without* zeroing — every
    /// element is overwritten).
    pub fn compute_into(&mut self, img: &BinnedImage, out: &mut IntegralHistogram) {
        let n = img.bins * img.h * img.w;
        out.bins = img.bins;
        out.h = img.h;
        out.w = img.w;
        if out.data.len() != n {
            out.data.resize(n, 0.0);
        }
        let plan = self.make_plan(img.h, img.w, img.bins);
        self.last_plan = Some(plan);
        // Tile-sweep schedules feed their wall time back into the
        // calibrator (EWMA), closing the predicted-vs-measured loop;
        // without a tuner no clock is read.
        let t0 = self.tuner.as_ref().map(|_| Instant::now());
        match plan.schedule {
            Schedule::BinParallel => {
                if plan.workers <= 1 {
                    crate::histogram::parallel::integral_histogram_parallel_into(
                        img,
                        1,
                        &mut out.data,
                    );
                } else {
                    if self.pool.is_none() {
                        self.pool = Some(WorkerPool::new(self.workers.saturating_sub(1)));
                    }
                    let pool = self.pool.as_mut().expect("pool just ensured");
                    let plane = img.h * img.w;
                    let bins = img.bins;
                    let next = AtomicUsize::new(0);
                    let out_win = SharedTensor::new(&mut out.data);
                    // Pull-based plane distribution (the paper's bin
                    // axis) on the parked pool: each participant claims
                    // plane indices from the shared counter.
                    let fill = |_slot: usize, _scratch: &mut TileScratch| loop {
                        let k = next.fetch_add(1, Ordering::Relaxed);
                        if k >= bins {
                            break;
                        }
                        // SAFETY: each plane index is claimed exactly
                        // once, and planes are disjoint slices of the
                        // output buffer.
                        let chunk = unsafe { out_win.seg_mut(k * plane, plane) };
                        crate::histogram::parallel::fill_plane_rowsum(img, k as i32, chunk);
                    };
                    pool.run(plan.workers - 1, &mut self.scratch, fill);
                }
            }
            Schedule::Serial => {
                self.reset_carries(img);
                wavefront::fused_scan_into_v(
                    img,
                    plan.tile,
                    &mut self.colc,
                    &mut self.scratch,
                    &mut out.data,
                    plan.kernel,
                );
            }
            Schedule::Wavefront => {
                self.reset_carries(img);
                if plan.workers <= 1 {
                    // Degenerate grid: no diagonal to spread over, so
                    // no reason to spawn (or wake) the pool.
                    wavefront::fused_scan_into_v(
                        img,
                        plan.tile,
                        &mut self.colc,
                        &mut self.scratch,
                        &mut out.data,
                        plan.kernel,
                    );
                } else {
                    if self.pool.is_none() {
                        self.pool = Some(WorkerPool::new(self.workers.saturating_sub(1)));
                    }
                    wavefront::wavefront_scan_into_v(
                        img,
                        plan.tile,
                        plan.workers,
                        &mut self.colc,
                        &mut self.scratch,
                        self.pool.as_mut().expect("pool just ensured"),
                        &mut self.wave,
                        &mut out.data,
                        plan.kernel,
                    );
                }
            }
        }
        if let (Some(t0), Some(tuner)) = (t0, self.tuner.as_ref()) {
            if plan.schedule != Schedule::BinParallel {
                // Per-worker tile throughput: divide the frame's
                // element count by the workers that swept it, so the
                // parallel wavefront reports a number comparable to the
                // serial sweep (scheduling/ramp losses included — which
                // is exactly what the wavefront cost model divides by).
                let per_worker = n as f64 / plan.workers.max(1) as f64;
                tuner
                    .calibrator()
                    .observe_tile(plan.tile, plan.kernel, per_worker, t0.elapsed());
            }
        }
    }

    /// Zero-fill the `bins×h` carry plane, reusing its capacity.
    fn reset_carries(&mut self, img: &BinnedImage) {
        self.colc.clear();
        self.colc.resize(img.bins * img.h, 0.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::histogram::sequential::integral_histogram_seq;
    use crate::util::prng::Xoshiro256;

    fn random_image(h: usize, w: usize, bins: usize, seed: u64) -> BinnedImage {
        let mut rng = Xoshiro256::new(seed);
        let mut data = vec![0i32; h * w];
        rng.fill_bins(&mut data, bins as u32);
        BinnedImage::new(h, w, bins, data)
    }

    #[test]
    fn engine_matches_algorithm1_across_schedules() {
        let img = random_image(70, 90, 6, 1);
        let expected = integral_histogram_seq(&img);
        for schedule in [Schedule::Serial, Schedule::BinParallel, Schedule::Wavefront] {
            let planner = Planner {
                tile_override: Some(16),
                schedule_override: Some(schedule),
            };
            let mut eng = ScanEngine::with_planner(4, planner);
            let got = eng.compute(&img);
            assert_eq!(expected.max_abs_diff(&got), 0.0, "{schedule:?}");
            assert_eq!(eng.last_plan().unwrap().schedule, schedule);
        }
    }

    #[test]
    fn compute_into_reuses_dirty_buffer() {
        let img_a = random_image(33, 47, 8, 2);
        let img_b = random_image(33, 47, 8, 3);
        let mut eng = ScanEngine::new(2);
        let mut buf = eng.compute(&img_a);
        // Recompute a different frame into the dirty buffer ...
        eng.compute_into(&img_b, &mut buf);
        let fresh = integral_histogram_seq(&img_b);
        assert_eq!(fresh.max_abs_diff(&buf), 0.0, "dirty reuse must be invisible");
        // ... and back, bit-identically.
        eng.compute_into(&img_a, &mut buf);
        let fresh_a = integral_histogram_seq(&img_a);
        assert_eq!(fresh_a.max_abs_diff(&buf), 0.0);
    }

    #[test]
    fn compute_into_resizes_on_geometry_change() {
        let mut eng = ScanEngine::new(2);
        let mut buf = eng.compute(&random_image(16, 16, 4, 4));
        let big = random_image(40, 24, 2, 5);
        eng.compute_into(&big, &mut buf);
        assert_eq!((buf.bins, buf.h, buf.w), (2, 40, 24));
        assert_eq!(buf.data.len(), 2 * 40 * 24);
        let expected = integral_histogram_seq(&big);
        assert_eq!(expected.max_abs_diff(&buf), 0.0);
    }

    #[test]
    fn zero_workers_means_available_parallelism() {
        let eng = ScanEngine::new(0);
        assert!(eng.workers() >= 1);
    }

    /// The tentpole claim: after the first parallel frame the engine
    /// never spawns another thread — the pool is parked, not respawned.
    #[test]
    fn steady_state_spawns_no_threads() {
        let img = random_image(200, 200, 8, 7);
        let planner = Planner {
            tile_override: Some(32),
            schedule_override: Some(Schedule::Wavefront),
        };
        let mut eng = ScanEngine::with_planner(4, planner);
        assert_eq!(eng.pool_stats(), WorkerPoolStats::default(), "pool is lazy");
        let mut out = eng.compute(&img);
        let s0 = eng.pool_stats();
        assert_eq!(s0.spawned, 3, "one pool of workers-1 helpers");
        for _ in 0..10 {
            eng.compute_into(&img, &mut out);
        }
        let s1 = eng.pool_stats();
        assert_eq!(s1.spawned, 3, "steady state must not spawn threads");
        assert_eq!(s1.threads, 3);
        assert_eq!(s1.jobs, s0.jobs + 10, "every frame is one pool job");
        let expected = integral_histogram_seq(&img);
        assert_eq!(expected.max_abs_diff(&out), 0.0);
    }

    /// The pooled BinParallel schedule shares the same parked pool and
    /// stays bit-identical to Algorithm 1.
    #[test]
    fn bin_parallel_draws_from_the_pool() {
        let img = random_image(60, 44, 16, 8);
        let planner = Planner {
            tile_override: None,
            schedule_override: Some(Schedule::BinParallel),
        };
        let mut eng = ScanEngine::with_planner(4, planner);
        let out = eng.compute(&img);
        let expected = integral_histogram_seq(&img);
        assert_eq!(expected.max_abs_diff(&out), 0.0);
        let s = eng.pool_stats();
        assert_eq!(s.spawned, 3);
        assert_eq!(s.jobs, 1);
        // Switching schedules reuses the same pool.
        let planner = eng.planner_mut();
        planner.schedule_override = Some(Schedule::Wavefront);
        planner.tile_override = Some(16);
        let out2 = eng.compute(&img);
        assert_eq!(expected.max_abs_diff(&out2), 0.0);
        assert_eq!(eng.pool_stats().spawned, 3, "schedule switch must not respawn");
        assert_eq!(eng.pool_stats().jobs, 2);
    }

    #[test]
    fn plan_for_is_stable() {
        let eng = ScanEngine::new(4);
        let img = random_image(512, 512, 32, 6);
        let p = eng.plan_for(&img);
        assert_eq!(p.schedule, Schedule::Wavefront);
        assert_eq!(p, eng.planner().plan(512, 512, 32, 4));
    }

    /// Engines sharing one tuner share one cache, stay bit-identical to
    /// Algorithm 1, and feed their tile timings back to the calibrator.
    #[test]
    fn tuned_engine_is_bit_identical_and_shares_one_cache() {
        use crate::simulator::pcie::Card;
        use crate::tune::Calibrator;
        let tuner = Arc::new(TunedPlanner::new(Arc::new(Calibrator::new(Card::Gtx480))));
        let img = random_image(90, 70, 6, 9);
        let expected = integral_histogram_seq(&img);
        let mut a = ScanEngine::with_tuner(4, Arc::clone(&tuner));
        let mut b = ScanEngine::with_tuner(4, Arc::clone(&tuner));
        let out_a = a.compute(&img);
        let out_b = b.compute(&img);
        assert_eq!(expected.max_abs_diff(&out_a), 0.0);
        assert_eq!(expected.max_abs_diff(&out_b), 0.0);
        assert_eq!(a.last_plan(), b.last_plan());
        let s = tuner.stats();
        assert_eq!(s.misses, 1, "second engine must hit the shared cache");
        assert!(s.hits >= 1);
        // The tile sweep reported its wall time into the calibrator.
        assert!(tuner.calibrator().snapshot().samples >= 1);
    }
}
