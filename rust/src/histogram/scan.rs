//! Prefix-sum helpers and the Eq. 4 scan-efficiency model.
//!
//! The Blelloch prescan that CW-B/CW-STS reuse schedules all n lanes for
//! 2·log2(n) steps but only 3(n−1) of those lane-cycles do useful work;
//! Eq. 4 of the paper bounds its efficiency at ≈ 3/log2(n).  The model
//! here feeds the figure drivers (the paper quotes 30% for n = 1024) and
//! the CPU-side scans are used by the coordinator when assembling
//! partial results.

/// Inclusive in-place prefix sum.
pub fn inclusive_scan(xs: &mut [f32]) {
    let mut run = 0.0f32;
    for x in xs.iter_mut() {
        run += *x;
        *x = run;
    }
}

/// Exclusive in-place prefix sum (Blelloch convention, Eq. 3).
pub fn exclusive_scan(xs: &mut [f32]) {
    let mut run = 0.0f32;
    for x in xs.iter_mut() {
        let v = *x;
        *x = run;
        run += v;
    }
}

/// Work-inefficient Blelloch scan on a power-of-two slice, performing
/// the literal up-sweep / down-sweep tree of Fig. 3.  Exists as an
/// executable model of the SDK kernel (unit-tested against
/// [`exclusive_scan`]) and for the Eq. 4 efficiency measurements.
/// Returns the number of element operations performed.
pub fn blelloch_scan(xs: &mut [f32]) -> usize {
    let n = xs.len();
    assert!(n.is_power_of_two(), "blelloch_scan needs a power-of-two length");
    let mut ops = 0;
    // up-sweep
    let mut stride = 1;
    while stride < n {
        let mut k = 2 * stride - 1;
        while k < n {
            xs[k] += xs[k - stride];
            ops += 1;
            k += 2 * stride;
        }
        stride *= 2;
    }
    // clear root + down-sweep
    xs[n - 1] = 0.0;
    stride = n / 2;
    while stride >= 1 {
        let mut k = 2 * stride - 1;
        while k < n {
            let t = xs[k - stride];
            xs[k - stride] = xs[k];
            xs[k] += t;
            ops += 2;
            k += 2 * stride;
        }
        stride /= 2;
    }
    ops
}

/// Eq. 4: efficiency of the SIMT Blelloch scan on an n-element array,
/// `3(n−1) / (n·log2 n)` — the working-cycles over scheduled-cycles
/// ratio that motivates the custom CW-TiS/WF-TiS kernels.
pub fn scan_efficiency(n: usize) -> f64 {
    assert!(n >= 2 && n.is_power_of_two(), "Eq. 4 is defined for power-of-two n ≥ 2");
    let nf = n as f64;
    3.0 * (nf - 1.0) / (nf * nf.log2())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Xoshiro256;

    #[test]
    fn inclusive_basic() {
        let mut v = vec![1.0, 2.0, 3.0, 4.0];
        inclusive_scan(&mut v);
        assert_eq!(v, vec![1.0, 3.0, 6.0, 10.0]);
    }

    #[test]
    fn exclusive_basic() {
        // Eq. 3: [a0, a1, ...] → [0, a0, a0+a1, ...]
        let mut v = vec![3.0, 1.0, 7.0, 0.0, 4.0];
        exclusive_scan(&mut v);
        assert_eq!(v, vec![0.0, 3.0, 4.0, 11.0, 11.0]);
    }

    #[test]
    fn blelloch_matches_exclusive() {
        let mut rng = Xoshiro256::new(1);
        for log_n in 1..=10 {
            let n = 1 << log_n;
            let orig: Vec<f32> = (0..n).map(|_| rng.range(0, 10) as f32).collect();
            let mut a = orig.clone();
            let mut b = orig.clone();
            blelloch_scan(&mut a);
            exclusive_scan(&mut b);
            assert_eq!(a, b, "n={n}");
        }
    }

    #[test]
    fn blelloch_op_count_is_3n_minus_3() {
        // 2(n−1) additions + (n−1) swaps ≈ 3(n−1) element ops; our count
        // tallies additions once and swap+add pairs as 2.
        let mut v = vec![1.0f32; 1024];
        let ops = blelloch_scan(&mut v);
        assert_eq!(ops, 3 * (1024 - 1));
    }

    #[test]
    fn efficiency_matches_paper_example() {
        // §3.4: "the efficiency of the scan on a 1024-element array is only 30%"
        let e = scan_efficiency(1024);
        assert!((e - 0.2997).abs() < 0.001, "got {e}");
    }

    #[test]
    fn efficiency_decreases_with_n() {
        let mut prev = f64::MAX;
        for log_n in 3..=20 {
            let e = scan_efficiency(1 << log_n);
            assert!(e < prev);
            prev = e;
        }
    }

    #[test]
    fn empty_and_singleton() {
        let mut v: Vec<f32> = vec![];
        inclusive_scan(&mut v);
        exclusive_scan(&mut v);
        let mut s = vec![5.0];
        inclusive_scan(&mut s);
        assert_eq!(s, vec![5.0]);
    }
}
