//! Multi-threaded CPU baseline — the paper's OpenMP comparator.
//!
//! The paper's CPU comparison (Figs. 17, 19, 20) runs an OpenMP
//! implementation on a hyper-threaded 8-core Xeon E5620 with 1–16
//! threads.  This module reproduces it with std scoped threads and the
//! same parallelization axes:
//!
//! * bins are embarrassingly parallel (each plane independent) — the
//!   primary axis, matching the paper's bin-level distribution;
//! * when there are more workers than bins, planes are additionally
//!   split row-wise in a cross-weave fashion (horizontal scan of row
//!   blocks, barrier, then column scan of column blocks).

use crate::histogram::types::{BinnedImage, IntegralHistogram};
use std::sync::atomic::{AtomicUsize, Ordering};

/// Multi-threaded integral histogram with `threads` workers (≥ 1).
///
/// Work distribution: a shared atomic counter hands out bin planes;
/// each worker computes its plane with the tuned running-row-sum kernel.
/// With `threads == 1` this degenerates to the sequential baseline.
pub fn integral_histogram_parallel(img: &BinnedImage, threads: usize) -> IntegralHistogram {
    let mut ih = IntegralHistogram::zeros(img.bins, img.h, img.w);
    integral_histogram_parallel_into(img, threads, &mut ih.data);
    ih
}

/// In-place variant writing into a caller-provided `bins×h×w` buffer
/// (every element is overwritten, so recycled storage needs no zeroing).
/// This is the `BinParallel` schedule of the
/// [`crate::histogram::engine::ScanEngine`].
pub fn integral_histogram_parallel_into(img: &BinnedImage, threads: usize, out: &mut [f32]) {
    assert!(threads >= 1, "need at least one thread");
    let (h, w, bins) = (img.h, img.w, img.bins);
    let plane = h * w;
    assert_eq!(out.len(), bins * plane, "output buffer must be bins*h*w");

    if threads == 1 || bins == 1 {
        // avoid thread overhead in the degenerate case
        for (k, chunk) in out.chunks_mut(plane).enumerate() {
            fill_plane_rowsum(img, k as i32, chunk);
        }
        return;
    }

    let next = AtomicUsize::new(0);
    // Split the output buffer into per-bin chunks so each worker owns
    // disjoint memory (no locks on the hot path).
    let chunks: Vec<&mut [f32]> = out.chunks_mut(plane).collect();
    // Hand out chunks through a mutex-free work queue: each worker grabs
    // plane indices from the atomic counter and writes into the matching
    // chunk, transferred via raw pointer because chunks are disjoint.
    struct SendPtr(*mut f32);
    unsafe impl Send for SendPtr {}
    unsafe impl Sync for SendPtr {}
    let ptrs: Vec<SendPtr> = chunks.into_iter().map(|c| SendPtr(c.as_mut_ptr())).collect();

    std::thread::scope(|scope| {
        for _ in 0..threads.min(bins) {
            let next = &next;
            let ptrs = &ptrs;
            scope.spawn(move || loop {
                let k = next.fetch_add(1, Ordering::Relaxed);
                if k >= bins {
                    break;
                }
                // SAFETY: each k is claimed exactly once; chunks are
                // disjoint plane-sized slices of the output buffer.
                let chunk = unsafe { std::slice::from_raw_parts_mut(ptrs[k].0, plane) };
                fill_plane_rowsum(img, k as i32, chunk);
            });
        }
    });
}

/// Compute one bin plane into `out` (len h·w) with the running-row-sum
/// recurrence.  Also the per-plane task body of the
/// [`crate::histogram::engine::ScanEngine`]'s pooled `BinParallel`
/// schedule.
pub(crate) fn fill_plane_rowsum(img: &BinnedImage, bin: i32, out: &mut [f32]) {
    let (h, w) = (img.h, img.w);
    debug_assert_eq!(out.len(), h * w);
    for x in 0..h {
        let mut rowsum = 0.0f32;
        for y in 0..w {
            rowsum += (img.data[x * w + y] == bin) as u32 as f32;
            let up = if x > 0 { out[(x - 1) * w + y] } else { 0.0 };
            out[x * w + y] = rowsum + up;
        }
    }
}

/// Cross-weave row/column-parallel variant used when `threads > bins`
/// would leave workers idle: horizontal scans of all (bin, row) pairs in
/// parallel, a barrier, then vertical scans of all (bin, column) pairs.
/// This is the CPU mirror of the paper's cross-weave scan mode (Fig. 1).
pub fn integral_histogram_crossweave(img: &BinnedImage, threads: usize) -> IntegralHistogram {
    assert!(threads >= 1);
    let (h, w, bins) = (img.h, img.w, img.bins);
    let mut ih = IntegralHistogram::zeros(bins, h, w);
    let plane = h * w;

    // Phase 1: horizontal prefix sums of Q values, parallel over (bin, row).
    {
        let next = AtomicUsize::new(0);
        let total = bins * h;
        struct SendPtr(*mut f32);
        unsafe impl Send for SendPtr {}
        unsafe impl Sync for SendPtr {}
        let base = SendPtr(ih.data.as_mut_ptr());
        std::thread::scope(|scope| {
            for _ in 0..threads {
                let next = &next;
                let base = &base;
                scope.spawn(move || loop {
                    let t = next.fetch_add(1, Ordering::Relaxed);
                    if t >= total {
                        break;
                    }
                    let (k, x) = (t / h, t % h);
                    // SAFETY: task t owns row x of plane k exclusively.
                    let row = unsafe {
                        std::slice::from_raw_parts_mut(base.0.add(k * plane + x * w), w)
                    };
                    let kk = k as i32;
                    let mut run = 0.0f32;
                    for y in 0..w {
                        run += (img.data[x * w + y] == kk) as u32 as f32;
                        row[y] = run;
                    }
                });
            }
        });
    }

    // Phase 2 (after the barrier implied by scope join): vertical prefix
    // sums, parallel over (bin, column).
    {
        let next = AtomicUsize::new(0);
        let total = bins * w;
        struct SendPtr(*mut f32);
        unsafe impl Send for SendPtr {}
        unsafe impl Sync for SendPtr {}
        let base = SendPtr(ih.data.as_mut_ptr());
        std::thread::scope(|scope| {
            for _ in 0..threads {
                let next = &next;
                let base = &base;
                scope.spawn(move || loop {
                    let t = next.fetch_add(1, Ordering::Relaxed);
                    if t >= total {
                        break;
                    }
                    let (k, y) = (t / w, t % w);
                    // SAFETY: task t owns column y of plane k exclusively;
                    // column writes are strided but disjoint across tasks.
                    let p = unsafe { std::slice::from_raw_parts_mut(base.0.add(k * plane), plane) };
                    let mut run = 0.0f32;
                    for x in 0..h {
                        run += p[x * w + y];
                        p[x * w + y] = run;
                    }
                });
            }
        });
    }
    ih
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::histogram::sequential::integral_histogram_seq;
    use crate::util::prng::Xoshiro256;

    fn random_image(h: usize, w: usize, bins: usize, seed: u64) -> BinnedImage {
        let mut rng = Xoshiro256::new(seed);
        let mut data = vec![0i32; h * w];
        rng.fill_bins(&mut data, bins as u32);
        BinnedImage::new(h, w, bins, data)
    }

    #[test]
    fn parallel_matches_sequential() {
        let img = random_image(33, 47, 8, 1);
        let expected = integral_histogram_seq(&img);
        for threads in [1, 2, 4, 7, 16] {
            let got = integral_histogram_parallel(&img, threads);
            assert_eq!(expected.max_abs_diff(&got), 0.0, "threads={threads}");
        }
    }

    #[test]
    fn crossweave_matches_sequential() {
        let img = random_image(21, 19, 4, 2);
        let expected = integral_histogram_seq(&img);
        for threads in [1, 3, 8] {
            let got = integral_histogram_crossweave(&img, threads);
            assert_eq!(expected.max_abs_diff(&got), 0.0, "threads={threads}");
        }
    }

    #[test]
    fn more_threads_than_bins() {
        let img = random_image(16, 16, 2, 3);
        let expected = integral_histogram_seq(&img);
        let got = integral_histogram_parallel(&img, 12);
        assert_eq!(expected.max_abs_diff(&got), 0.0);
    }

    #[test]
    fn single_bin() {
        let img = random_image(8, 8, 1, 4);
        let got = integral_histogram_parallel(&img, 4);
        assert_eq!(got.at(0, 7, 7), 64.0);
    }

    /// Determinism property: repeated parallel runs are bit-identical
    /// (integer counts in f32; no accumulation-order ambiguity).
    #[test]
    fn parallel_is_deterministic() {
        let img = random_image(32, 32, 8, 5);
        let a = integral_histogram_parallel(&img, 8);
        let b = integral_histogram_parallel(&img, 8);
        assert_eq!(a, b);
    }

    /// The in-place variant overwrites recycled (dirty) storage fully.
    #[test]
    fn into_variant_overwrites_dirty_buffer() {
        let img = random_image(19, 23, 4, 6);
        let expected = integral_histogram_seq(&img);
        let mut buf = vec![f32::NAN; 4 * 19 * 23];
        integral_histogram_parallel_into(&img, 3, &mut buf);
        let got = IntegralHistogram::from_raw(4, 19, 23, buf);
        assert_eq!(expected.max_abs_diff(&got), 0.0);
    }
}
