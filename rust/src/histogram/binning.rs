//! Intensity → bin quantization (the input side of the Q function).
//!
//! The paper's histograms bin 8-bit intensity (or any scalar feature
//! map) into `b` equal-width bins.  This module converts raw u8 frames
//! into [`BinnedImage`]s and provides the same quantization rule the
//! Python oracle uses (`kernels/ref.py::quantize`), so both sides of
//! the stack bin identically.

use crate::histogram::types::BinnedImage;

/// Number of raw intensity levels in 8-bit imagery.
pub const LEVELS: usize = 256;

/// Quantize one intensity value into `[0, bins)` with equal-width bins:
/// `bin = v * bins / 256` — identical to the Python-side rule.
#[inline]
pub fn quantize_u8(v: u8, bins: usize) -> i32 {
    debug_assert!(bins >= 1 && bins <= LEVELS);
    ((v as usize * bins) / LEVELS) as i32
}

/// Quantize a raw u8 frame into a [`BinnedImage`].
pub fn quantize_frame(pixels: &[u8], h: usize, w: usize, bins: usize) -> BinnedImage {
    let mut out = BinnedImage::new(0, 0, 1, Vec::new());
    quantize_frame_into(pixels, h, w, bins, &mut out);
    out
}

/// Quantize into a **recycled** [`BinnedImage`], reusing its index
/// buffer (no allocation once capacity suffices) — the input-side half
/// of the zero-alloc pipeline path (see `coordinator::frame_pool`).
pub fn quantize_frame_into(pixels: &[u8], h: usize, w: usize, bins: usize, out: &mut BinnedImage) {
    assert_eq!(pixels.len(), h * w, "pixel buffer length mismatch");
    assert!((1..=LEVELS).contains(&bins), "bins must be in 1..=256");
    out.h = h;
    out.w = w;
    out.bins = bins;
    out.data.clear();
    out.data.extend(pixels.iter().map(|&p| quantize_u8(p, bins)));
}

/// Inverse lookup: the inclusive intensity range covered by `bin`.
pub fn bin_range(bin: usize, bins: usize) -> (u8, u8) {
    assert!(bin < bins && bins <= LEVELS);
    // smallest v with v*bins/256 == bin is ceil(bin*256/bins)
    let lo = (bin * LEVELS).div_ceil(bins);
    let hi = ((bin + 1) * LEVELS).div_ceil(bins) - 1;
    (lo as u8, hi.min(LEVELS - 1) as u8)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantize_bounds() {
        for bins in [1, 2, 16, 32, 128, 256] {
            assert_eq!(quantize_u8(0, bins), 0);
            assert_eq!(quantize_u8(255, bins), bins as i32 - 1);
        }
    }

    #[test]
    fn quantize_equal_width() {
        // 32 bins → 8 levels per bin
        assert_eq!(quantize_u8(7, 32), 0);
        assert_eq!(quantize_u8(8, 32), 1);
        assert_eq!(quantize_u8(127, 32), 15);
        assert_eq!(quantize_u8(128, 32), 16);
    }

    #[test]
    fn bin_range_roundtrip() {
        for bins in [2usize, 16, 32, 100] {
            for bin in 0..bins {
                let (lo, hi) = bin_range(bin, bins);
                assert_eq!(quantize_u8(lo, bins), bin as i32, "lo of bin {bin}/{bins}");
                assert_eq!(quantize_u8(hi, bins), bin as i32, "hi of bin {bin}/{bins}");
                if lo > 0 {
                    assert_ne!(quantize_u8(lo - 1, bins), bin as i32);
                }
            }
        }
    }

    #[test]
    fn frame_quantization() {
        let px = vec![0u8, 8, 127, 128, 255, 64];
        let img = quantize_frame(&px, 2, 3, 32);
        assert_eq!(img.data, vec![0, 1, 15, 16, 31, 8]);
        assert_eq!((img.h, img.w, img.bins), (2, 3, 32));
    }

    #[test]
    fn bins_256_is_identity() {
        let px: Vec<u8> = (0..=255).collect();
        let img = quantize_frame(&px, 16, 16, 256);
        for (i, &b) in img.data.iter().enumerate() {
            assert_eq!(b, i as i32);
        }
    }

    #[test]
    #[should_panic]
    fn rejects_wrong_length() {
        quantize_frame(&[0u8; 10], 2, 6, 16);
    }

    #[test]
    fn into_variant_matches_and_reuses_capacity() {
        let px = vec![0u8, 8, 127, 128, 255, 64];
        let mut img = quantize_frame(&[0u8; 6], 2, 3, 4);
        let cap = img.data.capacity();
        quantize_frame_into(&px, 2, 3, 32, &mut img);
        assert_eq!(img, quantize_frame(&px, 2, 3, 32));
        assert_eq!(img.data.capacity(), cap, "same-size requantize must not realloc");
        // geometry change is allowed and tracked
        quantize_frame_into(&px[..4], 2, 2, 8, &mut img);
        assert_eq!((img.h, img.w, img.bins), (2, 2, 8));
    }
}
