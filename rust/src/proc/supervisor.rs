//! Parent side of the proc plane: a supervised pool of `proc-worker`
//! child processes behind the unchanged [`FrameTicket`] API.
//!
//! The in-process `ShardExecutor` contains *panics* with
//! `catch_unwind`; it cannot contain aborts, OOM kills or a stray
//! SIGKILL — those take the whole server with them.  This supervisor
//! moves shard compute behind a process boundary and supervises it
//! with the same bounded ladder the thread pool uses:
//!
//! * **death detection** — pipe EOF is the primary signal (closed the
//!   instant the child dies, however it dies), `try_wait` reaps the
//!   exit status, and a heartbeat age guard catches the
//!   hung-but-alive case;
//! * **replace + requeue** — a dead child is respawned and every shard
//!   it had in flight goes back on the queue with its attempt count
//!   bumped; a shard that exhausts
//!   [`ProcPoolConfig::max_attempts`] fails its frame typed through
//!   [`ShardError`], never silently;
//! * **unchanged contract** — tickets come from
//!   `FrameTicket::external`, so reassembly, deadlines, spilling and
//!   the bit-identity guarantee are byte-for-byte the in-process code
//!   paths.
//!
//! Dispatch honors the per-node placement computed from child
//! [`CalibrationReport`](crate::proc::protocol::ProcMsg)s (see
//! [`crate::proc::placement`]) as a *soft* affinity: a dead or
//! saturated preferred node falls back to the least-loaded live one —
//! placement is an optimization, supervision is the invariant.
//!
//! Chaos hooks: [`ProcSupervisor::kill_worker`] SIGKILLs a child on
//! demand (force-disconnects a remote link), and a wired
//! [`FaultInjector`] consults [`FaultSite::WorkerAbort`] per dispatch —
//! when it fires, the chosen child is killed *for real*
//! (`tests/fault_property.rs`).
//!
//! **Remote nodes.**  `ProcPoolConfig::remote_workers` adds socket
//! slots behind the same ladder: each address is a `proc-worker
//! --listen` endpoint, connected through
//! [`connect_remote`](crate::proc::transport::connect_remote) (v3
//! `Hello` handshake with capability bits).  Remote shards ride the
//! in-band **stream data plane** — the strip is pushed and the partial
//! pulled as bounded `Chunk` frames over the same connection — since
//! neither spill files nor `/dev/shm` cross hosts.  A dropped
//! connection is a death like any other: in-flight shards requeue with
//! a burned attempt, and the slot reconnects under a bounded
//! backoff ladder (`remote_reconnect_attempts`); exhaustion leaves the
//! slot dead and frames fail typed, never silent.  Deadlines cross the
//! clock domain as *remaining budget* (micros at dispatch), never as
//! an `Instant` — the worker re-anchors at assignment arrival.

use crate::coordinator::backpressure::{MemoryBudget, MemoryReservation};
use crate::fault::{FaultAction, FaultInjector, FaultSite};
use crate::histogram::types::BinnedImage;
use crate::proc::protocol::{
    checksum_bytes, checksum_f32, ProcMsg, WireAssign, CHUNK_DATA_MAX, PLANE_FILE, PLANE_SHM,
    PLANE_STREAM,
};
use crate::proc::shm::{self, ShmRing};
use crate::proc::transport::{connect_remote, PipeTransport, Transport};
use crate::shard::executor::{Shared, ShardMsg};
use crate::shard::{
    FrameTicket, ResidentGauge, ShardError, ShardPlan, ShardSpec, TaggedShard, TensorStore,
};
use crate::tune::CostSnapshot;
use crate::util::sync::lock_recover;
use anyhow::{anyhow, Context, Result};
use std::collections::{HashMap, VecDeque};
use std::io::{Read, Write};
use std::path::{Path, PathBuf};
use std::process::{Command, Stdio};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Which data plane carries shard bytes between supervisor and child.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DataPlane {
    /// Pick at construction: shm when the platform supports it
    /// ([`shm::available`]), else the spill-file plane.
    Auto,
    /// Spill-file round-trip (`TensorStore` files named per shard).
    File,
    /// Shared-memory ring ([`crate::proc::shm`]): strips in, partials
    /// out of per-child mmap slots; only control frames on the pipe.
    Shm,
}

impl DataPlane {
    /// Collapse `Auto` to what this host can actually serve.
    pub fn resolve(self) -> DataPlane {
        match self {
            DataPlane::Auto => {
                if shm::available() {
                    DataPlane::Shm
                } else {
                    DataPlane::File
                }
            }
            other => other,
        }
    }
}

/// Process-pool knobs.
#[derive(Debug, Clone)]
pub struct ProcPoolConfig {
    /// Child processes (the per-NUMA-node analog of device count).
    pub workers: usize,
    /// `ScanEngine` thread budget inside each child.
    pub engine_workers: usize,
    /// Attempts per shard across all children before its frame fails
    /// typed (a child death burns one attempt for each shard it held).
    pub max_attempts: usize,
    /// Shards one child may hold concurrently (1 computing + queue).
    pub per_child_inflight: usize,
    /// Completed-shard backpressure depth per frame
    /// (0 ⇒ `workers × per_child_inflight + 1`).
    pub channel_depth: usize,
    /// Child heartbeat interval.
    pub heartbeat: Duration,
    /// Silence longer than this marks a child hung: it is killed and
    /// replaced like any other death.
    pub heartbeat_timeout: Duration,
    /// Children run the `Calibrator` microbench at startup (slower
    /// boot, measured placement); off reports the static prior.
    pub calibrate_children: bool,
    /// Explicit `proc-worker` binary; `None` ⇒ `INTHIST_PROC_WORKER`
    /// env var, then a sibling of the current executable.
    pub worker_bin: Option<PathBuf>,
    /// Directory for the data-plane spill files (`None` ⇒ the shm
    /// tmpfs dir on the shm plane — a spill there is a memcpy — else
    /// the temp dir).
    pub spill_dir: Option<PathBuf>,
    /// How shard bytes travel (see [`DataPlane`]); `Auto` resolves at
    /// construction.
    pub data_plane: DataPlane,
    /// Chaos hook, forwarded to every child as `--boot-delay-ms`: the
    /// child sleeps this long before its first byte of output,
    /// modeling a slow boot for the heartbeat-deferral tests.
    pub boot_delay: Duration,
    /// `proc-worker --listen` endpoints to attach as remote node slots
    /// (in addition to the `workers` local children; with remote nodes
    /// present `workers: 0` builds a pure-remote pool).  Remote shards
    /// ride the in-band stream data plane.
    pub remote_workers: Vec<String>,
    /// Connect + handshake timeout per remote attempt.
    pub remote_connect_timeout: Duration,
    /// Reconnect attempts after a remote link drops before the slot is
    /// left dead (each drop also burns one attempt per in-flight
    /// shard, exactly like a local child death).
    pub remote_reconnect_attempts: usize,
    /// Pause between remote reconnect attempts.
    pub remote_reconnect_backoff: Duration,
}

impl Default for ProcPoolConfig {
    fn default() -> ProcPoolConfig {
        ProcPoolConfig {
            workers: 2,
            engine_workers: 1,
            max_attempts: 3,
            per_child_inflight: 2,
            channel_depth: 0,
            heartbeat: Duration::from_millis(200),
            heartbeat_timeout: Duration::from_secs(5),
            calibrate_children: false,
            worker_bin: None,
            spill_dir: None,
            data_plane: DataPlane::Auto,
            boot_delay: Duration::ZERO,
            remote_workers: Vec::new(),
            remote_connect_timeout: Duration::from_secs(2),
            remote_reconnect_attempts: 3,
            remote_reconnect_backoff: Duration::from_millis(50),
        }
    }
}

/// Locate the `proc-worker` binary: explicit config path, then the
/// `INTHIST_PROC_WORKER` env var, then a sibling of the current
/// executable (popping a `deps/` segment for cargo test layouts).
pub fn resolve_worker_bin(explicit: Option<&Path>) -> Result<PathBuf> {
    if let Some(p) = explicit {
        if p.exists() {
            return Ok(p.to_path_buf());
        }
        return Err(anyhow!("worker binary {} does not exist", p.display()));
    }
    if let Ok(p) = std::env::var("INTHIST_PROC_WORKER") {
        let p = PathBuf::from(p);
        if p.exists() {
            return Ok(p);
        }
        return Err(anyhow!("INTHIST_PROC_WORKER={} does not exist", p.display()));
    }
    let exe = std::env::current_exe().context("locate current executable")?;
    let mut dir = exe.parent().map(Path::to_path_buf).unwrap_or_default();
    if dir.file_name().map(|n| n == "deps").unwrap_or(false) {
        dir.pop();
    }
    for name in ["proc-worker", "proc-worker.exe"] {
        let cand = dir.join(name);
        if cand.exists() {
            return Ok(cand);
        }
    }
    Err(anyhow!(
        "proc-worker binary not found near {} — set INTHIST_PROC_WORKER or \
         ProcPoolConfig::worker_bin",
        dir.display()
    ))
}

/// Supervisor observability snapshot.
#[derive(Debug, Clone)]
pub struct ProcStats {
    /// Configured child count.
    pub workers: usize,
    /// Children currently alive.
    pub workers_alive: usize,
    /// Children respawned after a death (any cause).
    pub respawns: usize,
    /// Assignments written to children.
    pub dispatched: usize,
    /// Shards materialized and delivered to tickets.
    pub completed: usize,
    /// Shards put back on the queue after a failed attempt or death.
    pub requeued: usize,
    /// Shards that exhausted their attempt budget (typed error sent).
    pub shard_failures: usize,
    /// Cross-process payloads whose checksum did not verify (each one
    /// a failed attempt, never served).
    pub checksum_failures: usize,
    /// Shards dropped pre-dispatch on an expired frame deadline.
    pub skipped_deadline: usize,
    /// Heartbeats observed across all children.
    pub heartbeats: usize,
    /// Children that have reported a calibration snapshot.
    pub calibrated_nodes: usize,
    /// Heartbeat kills *not* issued because the child had never spoken
    /// yet (boot/calibration still in progress) — each one was a
    /// spurious kill→respawn→recalibrate loop before the fix.
    pub heartbeat_kills_averted: usize,
    /// Assignments that rode the shared-memory plane.
    pub shm_dispatched: usize,
    /// Shm-eligible assignments that fell back to the spill-file plane
    /// (ring busy, too small while busy, creation failed, or budget
    /// refused the mapping).
    pub shm_fallbacks: usize,
    /// Ring slots reclaimed from dead children on reap.
    pub slots_reclaimed: usize,
    /// Ring bytes currently mapped (all nodes).
    pub shm_mapped_bytes: usize,
    /// Remote node slots configured (subset of `workers`).
    pub remote_workers: usize,
    /// Remote links re-established after a drop.
    pub remote_reconnects: usize,
    /// Assignments that rode the in-band stream data plane.
    pub stream_dispatched: usize,
    /// Shards a *worker* skipped because their remaining-budget
    /// deadline expired after dispatch (in transfer or in queue) —
    /// distinct from `skipped_deadline`, the parent-side pre-dispatch
    /// drop.
    pub skipped_deadline_worker: usize,
}

#[derive(Default)]
struct Counters {
    alive: AtomicUsize,
    respawns: AtomicUsize,
    dispatched: AtomicUsize,
    completed: AtomicUsize,
    requeued: AtomicUsize,
    shard_failures: AtomicUsize,
    checksum_failures: AtomicUsize,
    skipped_deadline: AtomicUsize,
    heartbeats: AtomicUsize,
    heartbeat_kills_averted: AtomicUsize,
    shm_dispatched: AtomicUsize,
    shm_fallbacks: AtomicUsize,
    slots_reclaimed: AtomicUsize,
    remote_reconnects: AtomicUsize,
    stream_dispatched: AtomicUsize,
    skipped_deadline_worker: AtomicUsize,
}

enum Event {
    Msg { node: usize, gen: u64, msg: ProcMsg },
    Eof { node: usize, gen: u64 },
    Submit(FrameJob),
    Kill(usize),
    Shutdown,
}

struct FrameJob {
    frame_id: u64,
    img_h: usize,
    w: usize,
    img_path: PathBuf,
    shards: Vec<ShardSpec>,
    assignment: Option<Vec<usize>>,
    out: mpsc::SyncSender<ShardMsg>,
    gauge: Arc<ResidentGauge>,
    expires: Option<Instant>,
    deadline: Duration,
}

struct FrameState {
    img_h: usize,
    w: usize,
    img_path: PathBuf,
    out: mpsc::SyncSender<ShardMsg>,
    gauge: Arc<ResidentGauge>,
    expires: Option<Instant>,
    deadline: Duration,
    expected: usize,
    /// Shards not yet retired (completed, failed, skipped or dropped);
    /// at zero the frame's image spill file is deleted.
    outstanding: usize,
    /// A typed error was already delivered; remaining shards retire
    /// silently.
    failed: bool,
}

struct Task {
    frame_id: u64,
    spec: ShardSpec,
    attempts: usize,
    preferred: Option<usize>,
    out_path: PathBuf,
    /// Ring slot this dispatch holds on its node's ring (`None` on the
    /// file plane and always `None` while the task sits in `pending`).
    slot: Option<usize>,
    /// This dispatch rode the stream plane: the partial arrives as
    /// `Chunk` frames, not through a spill file or ring slot.
    stream: bool,
}

/// What stands behind a node slot: a spawned local child, or a
/// connected remote `proc-worker --listen` endpoint (kept for the
/// reconnect ladder — a respawn of a remote node is a re-connect).
enum NodeKind {
    Local,
    Remote { addr: String },
}

struct Slot {
    link: Box<dyn Transport>,
    kind: NodeKind,
    gen: u64,
    alive: bool,
    last_seen: Instant,
    /// When this child was spawned — bounds the boot grace for a child
    /// that has never spoken.
    spawned_at: Instant,
    /// The child has produced at least one protocol frame; heartbeat
    /// age is only enforced after this (a booting/calibrating child is
    /// silent but not hung).  Remote links start `true` — the
    /// handshake already proved the peer speaks.
    spoken: bool,
    /// A heartbeat kill was already averted (and counted) this boot.
    averted: bool,
    inflight: HashMap<(u64, u64), Task>,
    reader: Option<JoinHandle<()>>,
}

fn reader_loop<R: Read>(node: usize, gen: u64, mut stdout: R, tx: mpsc::Sender<Event>) {
    loop {
        match ProcMsg::read_from(&mut stdout) {
            Ok(Some(msg)) => {
                if tx.send(Event::Msg { node, gen, msg }).is_err() {
                    return; // dispatcher gone
                }
            }
            Ok(None) | Err(_) => {
                // Clean EOF and a torn frame look the same from here:
                // the child is no longer speaking the protocol.
                let _ = tx.send(Event::Eof { node, gen });
                return;
            }
        }
    }
}

fn spawn_child(
    cfg: &ProcPoolConfig,
    bin: &Path,
    node: usize,
    gen: u64,
    evt_tx: &mpsc::Sender<Event>,
) -> Result<Slot> {
    let mut cmd = Command::new(bin);
    cmd.arg("--calibrate")
        .arg(if cfg.calibrate_children { "1" } else { "0" })
        .arg("--engine-workers")
        .arg(cfg.engine_workers.max(1).to_string())
        .arg("--heartbeat-ms")
        .arg(cfg.heartbeat.as_millis().max(1).to_string());
    if !cfg.boot_delay.is_zero() {
        cmd.arg("--boot-delay-ms").arg(cfg.boot_delay.as_millis().to_string());
    }
    let mut child = cmd
        .stdin(Stdio::piped())
        .stdout(Stdio::piped())
        .stderr(Stdio::inherit())
        .spawn()
        .with_context(|| format!("spawn proc worker {node} from {}", bin.display()))?;
    let stdin = child.stdin.take().expect("piped stdin");
    let stdout = child.stdout.take().expect("piped stdout");
    let tx = evt_tx.clone();
    let reader = std::thread::Builder::new()
        .name(format!("inthist-proc-reader-{node}"))
        .spawn(move || reader_loop(node, gen, stdout, tx))
        .context("spawn reader thread")?;
    Ok(Slot {
        link: Box::new(PipeTransport::new(child, stdin)),
        kind: NodeKind::Local,
        gen,
        alive: true,
        last_seen: Instant::now(),
        spawned_at: Instant::now(),
        spoken: false,
        averted: false,
        inflight: HashMap::new(),
        reader: Some(reader),
    })
}

/// Connect (or re-connect) node slot `node` to a remote worker at
/// `addr`: TCP connect, v3 `Hello` handshake with capability checks,
/// then a reader thread over the socket's read half — the exact shape
/// the pipe reader has, so every downstream event path is shared.
fn connect_slot(
    cfg: &ProcPoolConfig,
    addr: &str,
    node: usize,
    gen: u64,
    evt_tx: &mpsc::Sender<Event>,
) -> Result<Slot> {
    let (link, read_half) =
        connect_remote(addr, cfg.remote_connect_timeout, &format!("inthist-supervisor-n{node}"))?;
    let tx = evt_tx.clone();
    let reader = std::thread::Builder::new()
        .name(format!("inthist-proc-reader-{node}"))
        .spawn(move || reader_loop(node, gen, read_half, tx))
        .context("spawn remote reader thread")?;
    Ok(Slot {
        link: Box::new(link),
        kind: NodeKind::Remote { addr: addr.to_string() },
        gen,
        alive: true,
        last_seen: Instant::now(),
        spawned_at: Instant::now(),
        spoken: true, // the handshake already round-tripped
        averted: false,
        inflight: HashMap::new(),
        reader: Some(reader),
    })
}

/// Write one stream-plane dispatch: the assignment frame followed by
/// the strip as dense, in-order chunks of at most [`CHUNK_DATA_MAX`]
/// bytes, then a single flush — the worker sees the whole dispatch or
/// a torn stream, never an interleaving.
fn write_stream_assign(
    w: &mut dyn Write,
    assign: &ProcMsg,
    key: (u64, u64),
    strip: &[u8],
) -> Result<(), crate::proc::protocol::ProtocolError> {
    let mut w = w;
    assign.write_to(&mut w)?;
    let total = strip.len() as u64;
    let mut off = 0usize;
    loop {
        let end = (off + CHUNK_DATA_MAX).min(strip.len());
        ProcMsg::Chunk {
            frame_id: key.0,
            shard_id: key.1,
            dir: 0,
            offset: off as u64,
            total,
            data: strip[off..end].to_vec(),
        }
        .write_to(&mut w)?;
        if end == strip.len() {
            break;
        }
        off = end;
    }
    w.flush()?;
    Ok(())
}

struct Dispatcher {
    cfg: ProcPoolConfig,
    bin: PathBuf,
    rx: mpsc::Receiver<Event>,
    evt_tx: mpsc::Sender<Event>,
    slots: Vec<Slot>,
    next_gen: u64,
    pending: VecDeque<Task>,
    frames: HashMap<u64, FrameState>,
    shared: Arc<Shared>,
    counters: Arc<Counters>,
    snapshots: Arc<Mutex<Vec<Option<CostSnapshot>>>>,
    faults: Option<Arc<FaultInjector>>,
    spill_dir: PathBuf,
    /// Resolved data plane (never `Auto` here).
    plane: DataPlane,
    /// Where ring files live (tmpfs when the platform has one).
    shm_dir: PathBuf,
    /// Per-node rings, created lazily at first shm dispatch and
    /// re-created (larger, under a fresh name) when an idle ring is
    /// too small for a task.  Survive child respawns.
    rings: Vec<Option<ShmRing>>,
    /// Host-memory reservations backing each node's ring mapping
    /// (held for their RAII drop only — never read back).
    #[allow(dead_code)]
    ring_res: Vec<Option<MemoryReservation>>,
    /// Nodes downgraded to the file plane after a ring-creation
    /// failure.
    shm_ok: Vec<bool>,
    /// Monotonic ring name generation — a re-created ring must never
    /// reuse a path a child may still have cached.
    ring_gen: u64,
    /// Server-wide memory bucket (rings reserve; `None` ⇒ unmetered).
    mem: Option<Arc<MemoryBudget>>,
    /// Mapped ring bytes, for `ProcStats::shm_mapped_bytes`.
    shm_gauge: Arc<ResidentGauge>,
    /// Partial-result reassembly buffers for in-flight stream-plane
    /// shards, keyed `(frame_id, shard_id)`.  Chunks append in order;
    /// any gap or overrun drops the buffer and the shard retries
    /// typed.  Entries die with their task (done, failed, requeued or
    /// node death) — never leaked.
    stream_rx: HashMap<(u64, u64), Vec<u8>>,
    shutting_down: bool,
}

impl Dispatcher {
    fn run(mut self) {
        loop {
            match self.rx.recv_timeout(Duration::from_millis(20)) {
                Ok(ev) => self.handle(ev),
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
            while let Ok(ev) = self.rx.try_recv() {
                self.handle(ev);
            }
            self.check_children();
            self.pump();
            if self.shutting_down && self.frames.is_empty() && self.pending.is_empty() {
                break;
            }
        }
        self.shutdown_children();
    }

    fn handle(&mut self, ev: Event) {
        match ev {
            Event::Submit(job) => self.admit(job),
            Event::Kill(node) => {
                if let Some(slot) = self.slots.get_mut(node) {
                    if slot.alive {
                        slot.link.kill(); // death lands as Eof
                    }
                }
            }
            Event::Shutdown => self.shutting_down = true,
            Event::Eof { node, gen } => {
                if self.slots[node].gen == gen {
                    self.child_died(node, "pipe closed");
                }
            }
            Event::Msg { node, gen, msg } => {
                if self.slots[node].gen != gen {
                    return; // stale reader of a replaced child
                }
                self.slots[node].last_seen = Instant::now();
                self.slots[node].spoken = true;
                match msg {
                    ProcMsg::Heartbeat { .. } => {
                        self.counters.heartbeats.fetch_add(1, Ordering::Relaxed);
                    }
                    ProcMsg::CalibrationReport { snapshot } => {
                        lock_recover(&self.snapshots)[node] = Some(snapshot);
                    }
                    ProcMsg::ShardDone { frame_id, shard_id, kernel_time_us, checksum, .. } => {
                        self.on_done(node, frame_id, shard_id, kernel_time_us, checksum);
                    }
                    ProcMsg::ShardFailed { frame_id, shard_id, panicked, deadline, reason } => {
                        if let Some(mut task) =
                            self.slots[node].inflight.remove(&(frame_id, shard_id))
                        {
                            self.free_task_slot(node, &mut task);
                            self.stream_rx.remove(&(frame_id, shard_id));
                            std::fs::remove_file(&task.out_path).ok();
                            if deadline {
                                // The worker's remaining-budget clock
                                // ran out after dispatch (transfer or
                                // queue latency).  That is the frame's
                                // deadline expiring, not a compute
                                // fault: surface it typed and burn no
                                // retry attempt — a retry would only
                                // be *later*.
                                self.counters
                                    .skipped_deadline_worker
                                    .fetch_add(1, Ordering::Relaxed);
                                self.shared.note_skipped_deadline();
                                let (dl, expected) = self
                                    .frames
                                    .get(&frame_id)
                                    .map(|f| (f.deadline, f.expected))
                                    .unwrap_or((Duration::ZERO, 0));
                                self.fail_frame(
                                    frame_id,
                                    ShardError::DeadlineExceeded {
                                        frame_id,
                                        deadline: dl,
                                        completed: 0,
                                        expected,
                                    },
                                );
                                self.retire(frame_id);
                            } else {
                                self.retry_or_fail(node, task, panicked, reason);
                            }
                        }
                    }
                    ProcMsg::Chunk { frame_id, shard_id, dir, offset, total, data } => {
                        self.on_chunk(node, frame_id, shard_id, dir, offset, total, data);
                    }
                    // A late Hello is just liveness; parent-bound-only
                    // frames from a confused child are not fatal.
                    ProcMsg::Hello { .. } | ProcMsg::AssignShard(_) | ProcMsg::Shutdown => {}
                }
            }
        }
    }

    fn admit(&mut self, job: FrameJob) {
        let n = job.shards.len();
        self.frames.insert(
            job.frame_id,
            FrameState {
                img_h: job.img_h,
                w: job.w,
                img_path: job.img_path,
                out: job.out,
                gauge: job.gauge,
                expires: job.expires,
                deadline: job.deadline,
                expected: n,
                outstanding: n,
                failed: false,
            },
        );
        for (i, spec) in job.shards.iter().enumerate() {
            let preferred = job.assignment.as_ref().and_then(|a| a.get(i).copied());
            self.pending.push_back(Task {
                frame_id: job.frame_id,
                spec: *spec,
                attempts: 0,
                preferred,
                out_path: PathBuf::new(), // named at dispatch
                slot: None,               // acquired at dispatch
                stream: false,            // decided at dispatch
            });
        }
    }

    /// Retire one shard of `frame_id`; at zero outstanding the frame's
    /// image spill file goes away.
    fn retire(&mut self, frame_id: u64) {
        let done = match self.frames.get_mut(&frame_id) {
            Some(f) => {
                f.outstanding = f.outstanding.saturating_sub(1);
                f.outstanding == 0
            }
            None => false,
        };
        if done {
            if let Some(f) = self.frames.remove(&frame_id) {
                std::fs::remove_file(&f.img_path).ok();
            }
        }
    }

    /// Deliver a typed error for the frame (first one wins) and mark
    /// it failed so the rest of its shards retire silently.
    fn fail_frame(&mut self, frame_id: u64, err: ShardError) {
        if let Some(f) = self.frames.get_mut(&frame_id) {
            if !f.failed {
                f.failed = true;
                let _ = f.out.send(Err(err));
            }
        }
    }

    fn retry_or_fail(&mut self, node: usize, mut task: Task, panicked: bool, reason: String) {
        task.attempts += 1;
        if task.attempts >= self.cfg.max_attempts.max(1) {
            self.counters.shard_failures.fetch_add(1, Ordering::Relaxed);
            self.shared.note_job(node);
            let err = if panicked {
                ShardError::ComputePanicked {
                    frame_id: task.frame_id,
                    shard_id: task.spec.shard_id,
                    attempts: task.attempts,
                }
            } else {
                ShardError::ComputeFailed {
                    frame_id: task.frame_id,
                    shard_id: task.spec.shard_id,
                    attempts: task.attempts,
                    reason,
                }
            };
            self.fail_frame(task.frame_id, err);
            self.retire(task.frame_id);
        } else {
            self.counters.requeued.fetch_add(1, Ordering::Relaxed);
            self.pending.push_back(task);
        }
    }

    /// Return a dispatched task's ring slot (if any) to its node's
    /// free list.  Every path that takes a task out of `inflight` must
    /// come through here — a slot leak is a permanently smaller ring.
    fn free_task_slot(&mut self, node: usize, task: &mut Task) {
        if let Some(slot) = task.slot.take() {
            if let Some(ring) = self.rings.get_mut(node).and_then(Option::as_mut) {
                ring.release(slot);
            }
        }
    }

    /// Claim a ring slot on `node` able to hold `need` bytes, creating
    /// or growing the node's ring when possible.  `None` means "use
    /// the file plane for this task" — ring busy, budget refused, or
    /// the node is downgraded.
    fn acquire_slot(&mut self, node: usize, need: usize) -> Option<usize> {
        // Round slot capacity up so frames of similar geometry reuse
        // the ring instead of re-creating it every submit.
        const ALIGN: usize = 64 * 1024;
        let want = need.checked_add(ALIGN - 1)? / ALIGN * ALIGN;
        let recreate = match &self.rings[node] {
            Some(r) if r.slot_bytes() >= need => false,
            Some(r) if r.in_use() == 0 => true,
            Some(_) => return None, // too small but busy: per-task fallback
            None => true,
        };
        if recreate {
            if let Some(old) = self.rings[node].take() {
                self.shm_gauge.sub(old.ring_bytes());
            }
            self.ring_res[node] = None; // release the old reservation first
            let nslots = self.cfg.per_child_inflight.max(1);
            let ring_bytes = want.checked_mul(nslots)?;
            let res = match &self.mem {
                Some(m) => match m.try_reserve(ring_bytes) {
                    Some(r) => Some(r),
                    None => return None, // budget refused: file plane, not overcommit
                },
                None => None,
            };
            let tag = format!("n{node}-g{}", self.ring_gen);
            self.ring_gen += 1;
            match ShmRing::create(&self.shm_dir, &tag, nslots, want) {
                Ok(ring) => {
                    self.shm_gauge.add(ring.ring_bytes());
                    self.rings[node] = Some(ring);
                    self.ring_res[node] = res;
                }
                Err(_) => {
                    // This node cannot serve shm; downgrade it for good
                    // rather than paying a failed create per dispatch.
                    self.shm_ok[node] = false;
                    return None;
                }
            }
        }
        self.rings[node].as_mut().and_then(ShmRing::acquire)
    }

    /// Append one inbound partial chunk (stream plane, child→parent).
    /// Chunks must arrive dense and in order on the per-shard buffer;
    /// a gap, replay or overrun is wire corruption — the buffer drops
    /// and the shard retries under the normal attempt ladder.  Chunks
    /// for keys this node does not hold are stale (e.g. the shard was
    /// requeued past this worker) and are ignored.
    #[allow(clippy::too_many_arguments)]
    fn on_chunk(
        &mut self,
        node: usize,
        frame_id: u64,
        shard_id: u64,
        dir: u8,
        offset: u64,
        total: u64,
        data: Vec<u8>,
    ) {
        if dir != 1 {
            return; // parent→child direction echoed back: nonsense, drop
        }
        let key = (frame_id, shard_id);
        if !self.slots[node].inflight.contains_key(&key) {
            return;
        }
        let buf = self
            .stream_rx
            .entry(key)
            .or_insert_with(|| Vec::with_capacity((total as usize).min(1 << 20)));
        let in_order = offset as usize == buf.len()
            && data.len() <= CHUNK_DATA_MAX
            && buf.len() + data.len() <= total as usize;
        if in_order {
            buf.extend_from_slice(&data);
            return;
        }
        let have = buf.len();
        self.stream_rx.remove(&key);
        if let Some(mut task) = self.slots[node].inflight.remove(&key) {
            self.free_task_slot(node, &mut task);
            self.retry_or_fail(
                node,
                task,
                false,
                format!(
                    "stream partial chunk out of order (offset {offset}, have {have}, \
                     total {total})"
                ),
            );
        }
    }

    fn on_done(&mut self, node: usize, frame_id: u64, shard_id: u64, kernel_us: u64, sum: u32) {
        let mut task = match self.slots[node].inflight.remove(&(frame_id, shard_id)) {
            Some(t) => t,
            None => return, // stale (e.g. answer raced a requeue)
        };
        let was_shm = task.slot.is_some();
        let (failed, w) = match self.frames.get(&frame_id) {
            Some(f) => (f.failed, f.w),
            None => {
                self.free_task_slot(node, &mut task);
                self.stream_rx.remove(&(frame_id, shard_id));
                std::fs::remove_file(&task.out_path).ok();
                return;
            }
        };
        if failed {
            self.free_task_slot(node, &mut task);
            self.stream_rx.remove(&(frame_id, shard_id));
            std::fs::remove_file(&task.out_path).ok();
            self.retire(frame_id);
            return;
        }
        let spec = task.spec;
        // Materialize the child's partial from the data plane and
        // verify the protocol checksum over exactly the bytes read —
        // the cross-process analog of the store's in-RAM row sums.
        // Stream plane: the partial was reassembled chunk by chunk in
        // `stream_rx`; shm plane: it sits in the task's ring slot
        // right after the strip.  The checksum moved with it either
        // way.
        let materialized = if task.stream {
            let key = (frame_id, shard_id);
            let expected = spec.nbins * spec.nrows * w * 4;
            match self.stream_rx.remove(&key) {
                Some(bytes) if bytes.len() == expected => {
                    let mut partial = self.shared.acquire_partial(spec.nbins, spec.nrows, w);
                    for (dst, src) in partial.data.iter_mut().zip(bytes.chunks_exact(4)) {
                        *dst = f32::from_le_bytes([src[0], src[1], src[2], src[3]]);
                    }
                    if checksum_f32(&partial.data) == sum {
                        Ok(partial)
                    } else {
                        self.shared.release_partial(partial);
                        Err(anyhow!("stream partial checksum mismatch"))
                    }
                }
                Some(bytes) => Err(anyhow!(
                    "stream partial truncated: {} of {expected} bytes",
                    bytes.len()
                )),
                None => Err(anyhow!("stream partial never arrived before ShardDone")),
            }
        } else if let Some(slot) = task.slot {
            let res = match self.rings[node].as_ref() {
                Some(ring) => {
                    let strip_bytes = spec.nrows * w * 4;
                    let mut bytes = vec![0u8; spec.nbins * spec.nrows * w * 4];
                    ring.read(slot, strip_bytes, &mut bytes);
                    let mut partial = self.shared.acquire_partial(spec.nbins, spec.nrows, w);
                    for (dst, src) in partial.data.iter_mut().zip(bytes.chunks_exact(4)) {
                        *dst = f32::from_le_bytes([src[0], src[1], src[2], src[3]]);
                    }
                    if checksum_f32(&partial.data) == sum {
                        Ok(partial)
                    } else {
                        self.shared.release_partial(partial);
                        Err(anyhow!("ring slot checksum mismatch"))
                    }
                }
                None => Err(anyhow!("ring vanished under an in-flight slot")),
            };
            self.free_task_slot(node, &mut task);
            res
        } else {
            (|| -> Result<crate::histogram::types::IntegralHistogram> {
                let store = TensorStore::open(&task.out_path, spec.nbins, spec.nrows, w)?;
                let mut partial = self.shared.acquire_partial(spec.nbins, spec.nrows, w);
                let plane = spec.nrows * w;
                for b in 0..spec.nbins {
                    if let Err(e) = store.read_rows(
                        b,
                        0,
                        spec.nrows,
                        &mut partial.data[b * plane..(b + 1) * plane],
                    ) {
                        self.shared.release_partial(partial);
                        return Err(e);
                    }
                }
                if checksum_f32(&partial.data) != sum {
                    self.shared.release_partial(partial);
                    return Err(anyhow!("payload checksum mismatch"));
                }
                Ok(partial)
            })()
        };
        if !was_shm {
            std::fs::remove_file(&task.out_path).ok();
        }
        match materialized {
            Ok(partial) => {
                self.counters.completed.fetch_add(1, Ordering::Relaxed);
                self.shared.note_job(node);
                let charged = spec.nbytes(w);
                let (gauge, out) = {
                    let f = self.frames.get(&frame_id).expect("frame checked above");
                    (Arc::clone(&f.gauge), f.out.clone())
                };
                gauge.add(charged);
                let tagged = TaggedShard {
                    frame_id,
                    spec,
                    partial,
                    worker: node,
                    kernel_time: Duration::from_micros(kernel_us),
                };
                if let Err(e) = out.send(Ok(tagged)) {
                    // Ticket dropped before reassembly: recycle.
                    if let Ok(t) = e.0 {
                        self.shared.release_partial(t.partial);
                        gauge.sub(charged);
                    }
                }
                self.retire(frame_id);
            }
            Err(e) => {
                self.counters.checksum_failures.fetch_add(1, Ordering::Relaxed);
                self.retry_or_fail(node, task, false, format!("materialize partial: {e:#}"));
            }
        }
    }

    fn child_died(&mut self, node: usize, why: &str) {
        if !self.slots[node].alive {
            return;
        }
        self.slots[node].alive = false;
        self.slots[node].link.kill();
        self.slots[node].link.reap();
        if let Some(r) = self.slots[node].reader.take() {
            let _ = r.join();
        }
        lock_recover(&self.snapshots)[node] = None;
        // Reclaim-on-reap: free every ring slot the corpse held
        // *before* the respawn, so the replacement child can never
        // race a ghost writer for a slot.
        if let Some(ring) = self.rings.get_mut(node).and_then(Option::as_mut) {
            let reclaimed = ring.release_all();
            if reclaimed > 0 {
                self.counters.slots_reclaimed.fetch_add(reclaimed, Ordering::Relaxed);
            }
        }
        // Every shard the child held burns one attempt and requeues —
        // the survival path for aborts and OOM kills, not just panics.
        // Stream partials mid-reassembly die with their tasks.
        let inflight: Vec<Task> =
            self.slots[node].inflight.drain().map(|(_, t)| t).collect();
        for mut task in inflight {
            task.slot = None; // its slot was just reclaimed wholesale
            self.stream_rx.remove(&(task.frame_id, task.spec.shard_id as u64));
            std::fs::remove_file(&task.out_path).ok();
            self.retry_or_fail(node, task, false, format!("worker process died: {why}"));
        }
        // Replace the node (unless we are draining for shutdown): a
        // local child respawns, a remote link re-connects under a
        // bounded backoff ladder.  Either failure leaves the slot
        // dead — pump() fails frames typed if the whole pool is gone.
        if !self.shutting_down {
            let gen = self.next_gen;
            self.next_gen += 1;
            let remote_addr = match &self.slots[node].kind {
                NodeKind::Local => None,
                NodeKind::Remote { addr } => Some(addr.clone()),
            };
            match remote_addr {
                None => {
                    if let Ok(slot) = spawn_child(&self.cfg, &self.bin, node, gen, &self.evt_tx) {
                        self.slots[node] = slot;
                        self.counters.respawns.fetch_add(1, Ordering::Relaxed);
                    }
                }
                Some(addr) => {
                    for attempt in 0..self.cfg.remote_reconnect_attempts.max(1) {
                        if attempt > 0 {
                            std::thread::sleep(self.cfg.remote_reconnect_backoff);
                        }
                        if let Ok(slot) = connect_slot(&self.cfg, &addr, node, gen, &self.evt_tx) {
                            self.slots[node] = slot;
                            self.counters.remote_reconnects.fetch_add(1, Ordering::Relaxed);
                            self.counters.respawns.fetch_add(1, Ordering::Relaxed);
                            break;
                        }
                    }
                }
            }
        }
        let alive = self.slots.iter().filter(|s| s.alive).count();
        self.counters.alive.store(alive, Ordering::Relaxed);
    }

    fn check_children(&mut self) {
        // A never-spoken child gets this much total boot time before
        // silence is treated as a hang anyway — the backstop for a
        // child wedged before its heartbeat ticker even started.
        let boot_grace = self.cfg.heartbeat_timeout * 10;
        for node in 0..self.slots.len() {
            if !self.slots[node].alive {
                continue;
            }
            // Pipes observe child exit directly; a remote link's death
            // arrives as reader EOF instead (`exited` is never true).
            if self.slots[node].link.exited() {
                self.child_died(node, "process exited");
                continue;
            }
            if self.slots[node].last_seen.elapsed() > self.cfg.heartbeat_timeout {
                // Heartbeat age only convicts a child that has already
                // spoken: a silent *booting* child is almost always the
                // startup Calibrator microbench, and killing it just
                // buys another slow boot (the pre-fix respawn loop).
                if !self.slots[node].spoken {
                    if self.slots[node].spawned_at.elapsed() <= boot_grace {
                        if !self.slots[node].averted {
                            self.slots[node].averted = true;
                            self.counters.heartbeat_kills_averted.fetch_add(1, Ordering::Relaxed);
                        }
                        continue;
                    }
                    // Past the grace with zero frames ever: truly hung.
                }
                self.slots[node].link.kill();
                self.child_died(node, "heartbeat timeout");
            }
        }
    }

    fn pump(&mut self) {
        let cap = self.cfg.per_child_inflight.max(1);
        let mut tries = self.pending.len();
        while tries > 0 {
            tries -= 1;
            let mut task = match self.pending.pop_front() {
                Some(t) => t,
                None => return,
            };
            let frame_id = task.frame_id;
            let (frame_failed, expires, deadline, expected, img_h, w, img_path) =
                match self.frames.get(&frame_id) {
                    Some(f) => (
                        f.failed,
                        f.expires,
                        f.deadline,
                        f.expected,
                        f.img_h,
                        f.w,
                        f.img_path.clone(),
                    ),
                    None => continue, // frame already gone
                };
            if frame_failed {
                self.retire(frame_id);
                continue;
            }
            // Deadline-aware scheduling, proc flavor: expired frames
            // never reach a child.
            if let Some(exp) = expires {
                if Instant::now() >= exp {
                    self.counters.skipped_deadline.fetch_add(1, Ordering::Relaxed);
                    self.shared.note_skipped_deadline();
                    self.fail_frame(
                        frame_id,
                        ShardError::DeadlineExceeded {
                            frame_id,
                            deadline,
                            completed: 0,
                            expected,
                        },
                    );
                    self.retire(frame_id);
                    continue;
                }
            }
            // Soft placement: the calibrated node if it is alive and
            // has a slot, else least-loaded alive node with capacity.
            let chosen = {
                let ok = |n: usize| {
                    self.slots.get(n).map(|s| s.alive && s.inflight.len() < cap).unwrap_or(false)
                };
                match task.preferred.filter(|&n| ok(n)) {
                    Some(n) => Some(n),
                    None => self
                        .slots
                        .iter()
                        .enumerate()
                        .filter(|(_, s)| s.alive && s.inflight.len() < cap)
                        .min_by_key(|(_, s)| s.inflight.len())
                        .map(|(n, _)| n),
                }
            };
            let node = match chosen {
                Some(n) => n,
                None => {
                    if self.slots.iter().all(|s| !s.alive) {
                        // Whole pool gone and irreplaceable: no hangs.
                        self.fail_frame(frame_id, ShardError::WorkersGone { frame_id });
                        self.retire(frame_id);
                        continue;
                    }
                    self.pending.push_front(task);
                    return; // all live children saturated; wait
                }
            };
            // Chaos arm: the injected abort kills the chosen child for
            // real — SIGKILL, not a catchable panic.  The task requeues
            // through the normal death path.
            if let Some(f) = &self.faults {
                if f.decide(FaultSite::WorkerAbort) == Some(FaultAction::Abort) {
                    self.slots[node].link.kill();
                    self.pending.push_front(task);
                    return;
                }
            }
            // Deadline crosses the process (and possibly host) boundary
            // as *remaining budget* in micros, computed at dispatch —
            // an `Instant` is meaningless in another clock domain.  The
            // expired case was already dropped above, so clamp to ≥ 1
            // (0 is the "no deadline" sentinel).
            let deadline_us = expires
                .map(|e| {
                    (e.saturating_duration_since(Instant::now()).as_micros() as u64).max(1)
                })
                .unwrap_or(0);
            task.out_path = self.spill_dir.join(format!(
                "inthist-proc-{}-f{}-s{}-a{}.bin",
                std::process::id(),
                frame_id,
                task.spec.shard_id,
                task.attempts
            ));
            let mut wire = WireAssign {
                frame_id,
                shard_id: task.spec.shard_id as u64,
                bin0: task.spec.bin0 as u64,
                nbins: task.spec.nbins as u64,
                row0: task.spec.row0 as u64,
                nrows: task.spec.nrows as u64,
                img_h: img_h as u64,
                img_w: w as u64,
                img_path: img_path.to_string_lossy().into_owned(),
                out_path: task.out_path.to_string_lossy().into_owned(),
                plane: PLANE_FILE,
                slot: 0,
                slot_off: 0,
                ring_bytes: 0,
                ring_path: String::new(),
                deadline_us,
                strip_checksum: 0,
            };
            // Remote nodes always ride the stream plane: the strip is
            // pushed as bounded chunks over the socket and the partial
            // comes back the same way.  A strip-read failure burns an
            // attempt through the normal ladder (the spill file may be
            // gone with its frame).
            if self.slots[node].link.is_remote() {
                let strip = TensorStore::open(&img_path, 1, img_h, w)
                    .and_then(|s| s.read_rows_raw(0, task.spec.row0, task.spec.nrows));
                let bytes = match strip {
                    Ok(b) => b,
                    Err(e) => {
                        self.retry_or_fail(
                            node,
                            task,
                            false,
                            format!("read strip for stream dispatch: {e:#}"),
                        );
                        continue;
                    }
                };
                wire.plane = PLANE_STREAM;
                wire.strip_checksum = checksum_bytes(&bytes);
                wire.out_path = String::new();
                task.out_path = PathBuf::new();
                task.stream = true;
                let key = (frame_id, task.spec.shard_id as u64);
                self.stream_rx.remove(&key); // no stale partial survives a re-dispatch
                let assign = ProcMsg::AssignShard(wire);
                let wrote = write_stream_assign(self.slots[node].link.writer(), &assign, key, &bytes);
                match wrote {
                    Ok(()) => {
                        self.counters.dispatched.fetch_add(1, Ordering::Relaxed);
                        self.counters.stream_dispatched.fetch_add(1, Ordering::Relaxed);
                        self.slots[node].inflight.insert(key, task);
                    }
                    Err(_) => {
                        // Link dropped mid-dispatch: requeue through the
                        // death path (no attempt burned — the shard
                        // never fully reached the worker).
                        task.stream = false;
                        self.pending.push_front(task);
                        self.child_died(node, "write failed");
                        return;
                    }
                }
                continue;
            }
            task.stream = false;
            // Shm plane: load the strip into a ring slot and point the
            // assignment at it; any miss (busy ring, budget refusal,
            // downgraded node, unreadable image) rides the file plane
            // for this task — counted, never silent.
            if self.plane == DataPlane::Shm && self.shm_ok[node] {
                let strip_bytes = task.spec.nrows * w * 4;
                let need = strip_bytes + task.spec.nbins * task.spec.nrows * w * 4;
                match self.acquire_slot(node, need) {
                    Some(slot) => {
                        let strip = TensorStore::open(&img_path, 1, img_h, w)
                            .and_then(|s| s.read_rows_raw(0, task.spec.row0, task.spec.nrows));
                        match strip {
                            Ok(bytes) => {
                                let ring =
                                    self.rings[node].as_mut().expect("acquired slot implies ring");
                                ring.write(slot, 0, &bytes);
                                wire.plane = PLANE_SHM;
                                wire.slot = slot as u64;
                                wire.slot_off = ring.slot_off(slot);
                                wire.ring_bytes = ring.ring_bytes() as u64;
                                wire.ring_path = ring.path().to_string_lossy().into_owned();
                                task.slot = Some(slot);
                            }
                            Err(_) => {
                                if let Some(r) = self.rings[node].as_mut() {
                                    r.release(slot);
                                }
                                self.counters.shm_fallbacks.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                    }
                    None => {
                        self.counters.shm_fallbacks.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
            let assign = ProcMsg::AssignShard(wire);
            let wrote = {
                let mut link = self.slots[node].link.writer();
                assign.write_to(&mut link).and_then(|()| link.flush().map_err(Into::into))
            };
            match wrote {
                Ok(()) => {
                    self.counters.dispatched.fetch_add(1, Ordering::Relaxed);
                    if task.slot.is_some() {
                        self.counters.shm_dispatched.fetch_add(1, Ordering::Relaxed);
                    }
                    let key = (frame_id, task.spec.shard_id as u64);
                    self.slots[node].inflight.insert(key, task);
                }
                Err(_) => {
                    // Broken pipe: the child is dead; requeue through
                    // the death path (which bumps no attempt for this
                    // task — it never reached the child).  The slot it
                    // held goes back first: pending tasks own no slots.
                    self.free_task_slot(node, &mut task);
                    self.pending.push_front(task);
                    self.child_died(node, "write failed");
                    return;
                }
            }
        }
    }

    fn shutdown_children(&mut self) {
        for slot in self.slots.iter_mut() {
            if slot.alive {
                let mut w = slot.link.writer();
                let _ = ProcMsg::Shutdown.write_to(&mut w);
                let _ = w.flush();
            }
        }
        let grace = Instant::now() + Duration::from_millis(500);
        for slot in self.slots.iter_mut() {
            slot.link.wait_exit(grace);
            if let Some(r) = slot.reader.take() {
                let _ = r.join();
            }
        }
        self.counters.alive.store(0, Ordering::Relaxed);
        // Any stray data-plane files from frames that never retired.
        for (_, f) in self.frames.drain() {
            std::fs::remove_file(&f.img_path).ok();
        }
    }
}

/// The multi-process shard executor.  All methods take `&self`; submit
/// from any number of threads.  See the module docs for the contract.
pub struct ProcSupervisor {
    cfg: ProcPoolConfig,
    /// Total node slots: local children plus remote links.
    nodes: usize,
    tx: Mutex<Option<mpsc::Sender<Event>>>,
    dispatcher: Option<JoinHandle<()>>,
    shared: Arc<Shared>,
    counters: Arc<Counters>,
    snapshots: Arc<Mutex<Vec<Option<CostSnapshot>>>>,
    frame_seq: AtomicU64,
    spill_dir: PathBuf,
    plane: DataPlane,
    shm_gauge: Arc<ResidentGauge>,
}

impl std::fmt::Debug for ProcSupervisor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ProcSupervisor")
            .field("workers", &self.cfg.workers)
            .field("alive", &self.counters.alive.load(Ordering::Relaxed))
            .finish()
    }
}

impl ProcSupervisor {
    pub fn new(cfg: ProcPoolConfig) -> Result<ProcSupervisor> {
        ProcSupervisor::with_faults(cfg, None)
    }

    /// Build a supervisor whose dispatch loop consults `faults` at the
    /// [`FaultSite::WorkerAbort`] site (inert unless compiled with
    /// `--features fault-injection`).
    pub fn with_faults(
        cfg: ProcPoolConfig,
        faults: Option<Arc<FaultInjector>>,
    ) -> Result<ProcSupervisor> {
        ProcSupervisor::with_instruments(cfg, faults, None)
    }

    /// [`Self::with_faults`] plus a server-wide [`MemoryBudget`] that
    /// ring mappings are reserved against — the proc plane's share of
    /// the host-memory accounting fix (a refused reservation falls the
    /// task back to the file plane instead of overcommitting).
    pub fn with_instruments(
        cfg: ProcPoolConfig,
        faults: Option<Arc<FaultInjector>>,
        mem: Option<Arc<MemoryBudget>>,
    ) -> Result<ProcSupervisor> {
        // With remote endpoints configured, `workers: 0` is a valid
        // pure-remote pool; an all-local config keeps the ≥ 1 floor.
        let local = if cfg.remote_workers.is_empty() {
            cfg.workers.max(1)
        } else {
            cfg.workers
        };
        let nodes = local + cfg.remote_workers.len();
        // The worker binary is only needed for local children — a
        // pure-remote supervisor must not fail on a missing sibling.
        let bin = if local > 0 {
            resolve_worker_bin(cfg.worker_bin.as_deref())?
        } else {
            PathBuf::new()
        };
        let plane = cfg.data_plane.resolve();
        let shm_dir = shm::default_dir().unwrap_or_else(std::env::temp_dir);
        // On the shm plane the image spill defaults into the same
        // tmpfs: spilling becomes a memcpy and the children's strip
        // reads never touch a disk.
        let spill_dir = cfg.spill_dir.clone().unwrap_or_else(|| {
            if plane == DataPlane::Shm {
                shm_dir.clone()
            } else {
                std::env::temp_dir()
            }
        });
        let (evt_tx, evt_rx) = mpsc::channel::<Event>();
        let mut slots = Vec::with_capacity(nodes);
        for node in 0..local {
            slots.push(spawn_child(&cfg, &bin, node, node as u64, &evt_tx)?);
        }
        for (i, addr) in cfg.remote_workers.iter().enumerate() {
            let node = local + i;
            slots.push(connect_slot(&cfg, addr, node, node as u64, &evt_tx)?);
        }
        let counters = Arc::new(Counters::default());
        counters.alive.store(nodes, Ordering::Relaxed);
        let snapshots = Arc::new(Mutex::new(vec![None; nodes]));
        let shared = Shared::external(nodes, cfg.max_attempts);
        let shm_gauge = Arc::new(ResidentGauge::default());
        // Shm is a local plane: remote nodes never qualify (their
        // shards ride the stream plane instead).
        let shm_ok = slots
            .iter()
            .map(|s| plane == DataPlane::Shm && !s.link.is_remote())
            .collect();
        let dispatcher = Dispatcher {
            cfg: ProcPoolConfig { workers: local, ..cfg.clone() },
            bin,
            rx: evt_rx,
            evt_tx: evt_tx.clone(),
            slots,
            next_gen: nodes as u64,
            pending: VecDeque::new(),
            frames: HashMap::new(),
            shared: Arc::clone(&shared),
            counters: Arc::clone(&counters),
            snapshots: Arc::clone(&snapshots),
            faults,
            spill_dir: spill_dir.clone(),
            plane,
            shm_dir,
            rings: (0..nodes).map(|_| None).collect(),
            ring_res: (0..nodes).map(|_| None).collect(),
            shm_ok,
            ring_gen: 0,
            mem,
            shm_gauge: Arc::clone(&shm_gauge),
            stream_rx: HashMap::new(),
            shutting_down: false,
        };
        let handle = std::thread::Builder::new()
            .name("inthist-proc-dispatcher".into())
            .spawn(move || dispatcher.run())
            .context("spawn dispatcher thread")?;
        Ok(ProcSupervisor {
            cfg,
            nodes,
            tx: Mutex::new(Some(evt_tx)),
            dispatcher: Some(handle),
            shared,
            counters,
            snapshots,
            frame_seq: AtomicU64::new(0),
            spill_dir,
            plane,
            shm_gauge,
        })
    }

    /// The data plane this supervisor resolved to (never `Auto`).
    pub fn data_plane(&self) -> DataPlane {
        self.plane
    }

    /// Total node slots (local children + remote links).
    pub fn workers(&self) -> usize {
        self.nodes
    }

    pub fn config(&self) -> &ProcPoolConfig {
        &self.cfg
    }

    pub fn stats(&self) -> ProcStats {
        let c = &self.counters;
        ProcStats {
            workers: self.workers(),
            workers_alive: c.alive.load(Ordering::Relaxed),
            respawns: c.respawns.load(Ordering::Relaxed),
            dispatched: c.dispatched.load(Ordering::Relaxed),
            completed: c.completed.load(Ordering::Relaxed),
            requeued: c.requeued.load(Ordering::Relaxed),
            shard_failures: c.shard_failures.load(Ordering::Relaxed),
            checksum_failures: c.checksum_failures.load(Ordering::Relaxed),
            skipped_deadline: c.skipped_deadline.load(Ordering::Relaxed),
            heartbeats: c.heartbeats.load(Ordering::Relaxed),
            calibrated_nodes: lock_recover(&self.snapshots).iter().filter(|s| s.is_some()).count(),
            heartbeat_kills_averted: c.heartbeat_kills_averted.load(Ordering::Relaxed),
            shm_dispatched: c.shm_dispatched.load(Ordering::Relaxed),
            shm_fallbacks: c.shm_fallbacks.load(Ordering::Relaxed),
            slots_reclaimed: c.slots_reclaimed.load(Ordering::Relaxed),
            shm_mapped_bytes: self.shm_gauge.current(),
            remote_workers: self.cfg.remote_workers.len(),
            remote_reconnects: c.remote_reconnects.load(Ordering::Relaxed),
            stream_dispatched: c.stream_dispatched.load(Ordering::Relaxed),
            skipped_deadline_worker: c.skipped_deadline_worker.load(Ordering::Relaxed),
        }
    }

    /// Per-node calibration snapshots as reported so far (`None` for a
    /// node that has not reported since its last spawn).
    pub fn snapshots(&self) -> Vec<Option<CostSnapshot>> {
        lock_recover(&self.snapshots).clone()
    }

    /// Block until every node has reported a calibration snapshot or
    /// `timeout` elapses; returns the number of calibrated nodes.
    pub fn wait_calibrated(&self, timeout: Duration) -> usize {
        let until = Instant::now() + timeout;
        loop {
            let n = lock_recover(&self.snapshots).iter().filter(|s| s.is_some()).count();
            if n >= self.workers() || Instant::now() >= until {
                return n;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
    }

    /// SIGKILL child `node` — the chaos/bench hook behind the respawn
    /// ladder (the supervisor treats it exactly like an OOM kill).
    pub fn kill_worker(&self, node: usize) -> Result<()> {
        self.send_event(Event::Kill(node))
    }

    /// Submit every shard of `plan` against `image` (unbounded queue
    /// deadline).  Non-blocking; drive the returned ticket exactly as
    /// with the in-process executor.
    pub fn submit(&self, image: &Arc<BinnedImage>, plan: &ShardPlan) -> Result<FrameTicket> {
        self.submit_inner(image, plan, None, None)
    }

    /// [`Self::submit`] with the frame deadline pushed into the
    /// dispatch queue (expired shards never reach a child).
    pub fn submit_with_deadline(
        &self,
        image: &Arc<BinnedImage>,
        plan: &ShardPlan,
        deadline: Duration,
    ) -> Result<FrameTicket> {
        self.submit_inner(image, plan, Some(deadline), None)
    }

    /// [`Self::submit`] with a per-shard node assignment (from
    /// [`crate::proc::placement`]) applied as soft affinity.
    pub fn submit_assigned(
        &self,
        image: &Arc<BinnedImage>,
        plan: &ShardPlan,
        assignment: &[usize],
    ) -> Result<FrameTicket> {
        if assignment.len() != plan.shards.len() {
            return Err(anyhow!(
                "assignment covers {} shards, plan has {}",
                assignment.len(),
                plan.shards.len()
            ));
        }
        self.submit_inner(image, plan, None, Some(assignment.to_vec()))
    }

    fn submit_inner(
        &self,
        image: &Arc<BinnedImage>,
        plan: &ShardPlan,
        deadline: Option<Duration>,
        assignment: Option<Vec<usize>>,
    ) -> Result<FrameTicket> {
        if (image.h, image.w, image.bins) != (plan.h, plan.w, plan.bins) {
            return Err(anyhow!(
                "plan {}x{}x{} does not match image {}x{}x{}",
                plan.bins,
                plan.h,
                plan.w,
                image.bins,
                image.h,
                image.w
            ));
        }
        let frame_id = self.frame_seq.fetch_add(1, Ordering::Relaxed);
        // Data plane, inbound: spill the binned image once as f32 (bin
        // indices are small integers — exact in f32) for all children
        // to strip-read.
        let img_path = self.spill_dir.join(format!(
            "inthist-proc-{}-img-{}.bin",
            std::process::id(),
            frame_id
        ));
        let store = TensorStore::create(&img_path, 1, image.h, image.w)
            .context("spill image for proc plane")?;
        let chunk_rows = 256usize.max(1);
        let mut row0 = 0usize;
        let mut scratch: Vec<f32> = Vec::with_capacity(chunk_rows * image.w);
        while row0 < image.h {
            let nrows = chunk_rows.min(image.h - row0);
            scratch.clear();
            scratch.extend(
                image.data[row0 * image.w..(row0 + nrows) * image.w].iter().map(|&v| v as f32),
            );
            store.write_rows(0, row0, &scratch).context("spill image rows")?;
            row0 += nrows;
        }
        store.flush().context("flush spilled image")?;

        let depth = if self.cfg.channel_depth == 0 {
            self.workers() * self.cfg.per_child_inflight.max(1) + 1
        } else {
            self.cfg.channel_depth
        };
        let (out_tx, out_rx) = mpsc::sync_channel::<ShardMsg>(depth.max(1));
        let gauge = Arc::new(ResidentGauge::default());
        let job = FrameJob {
            frame_id,
            img_h: image.h,
            w: image.w,
            img_path: img_path.clone(),
            shards: plan.shards.clone(),
            assignment,
            out: out_tx,
            gauge: Arc::clone(&gauge),
            expires: deadline.map(|d| Instant::now() + d),
            deadline: deadline.unwrap_or(Duration::ZERO),
        };
        if let Err(e) = self.send_event(Event::Submit(job)) {
            std::fs::remove_file(&img_path).ok();
            return Err(e);
        }
        self.shared.note_submitted();
        Ok(FrameTicket::external(frame_id, plan.clone(), out_rx, gauge, Arc::clone(&self.shared)))
    }

    fn send_event(&self, ev: Event) -> Result<()> {
        let tx = {
            let guard = lock_recover(&self.tx);
            guard.as_ref().ok_or_else(|| anyhow!("supervisor already shut down"))?.clone()
        };
        tx.send(ev).map_err(|_| anyhow!("dispatcher exited"))
    }

    /// Drain, stop the children and join the dispatcher (also done on
    /// drop).
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        let tx = lock_recover(&self.tx).take();
        if let Some(tx) = tx {
            let _ = tx.send(Event::Shutdown);
        }
        if let Some(h) = self.dispatcher.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ProcSupervisor {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Child-spawning coverage lives in `tests/proc_property.rs` (which
    // cargo hands the built `proc-worker` path via CARGO_BIN_EXE_);
    // unit tests here cover the pieces that need no subprocess.

    #[test]
    fn explicit_missing_worker_bin_errors_typed() {
        let err = resolve_worker_bin(Some(Path::new("/nonexistent/proc-worker")))
            .expect_err("missing binary must not resolve");
        assert!(err.to_string().contains("does not exist"), "{err}");
    }

    #[test]
    fn defaults_are_sane() {
        let cfg = ProcPoolConfig::default();
        assert!(cfg.workers >= 1);
        assert!(cfg.max_attempts >= 1);
        assert!(cfg.per_child_inflight >= 1);
        assert!(cfg.heartbeat < cfg.heartbeat_timeout);
        assert!(cfg.remote_workers.is_empty());
        assert!(cfg.remote_reconnect_attempts >= 1);
        assert!(cfg.remote_reconnect_backoff < cfg.remote_connect_timeout);
    }

    /// The stream dispatch writer splits a strip into dense, in-order
    /// chunks of at most `CHUNK_DATA_MAX` bytes that reassemble
    /// bit-identically, with one trailing short chunk.
    #[test]
    fn stream_assign_chunks_are_dense_and_bounded() {
        let strip: Vec<u8> = (0..CHUNK_DATA_MAX * 2 + 12345).map(|i| (i * 7 % 251) as u8).collect();
        let assign = ProcMsg::Heartbeat { seq: 0 }; // any frame works as the header here
        let mut wire = Vec::new();
        write_stream_assign(&mut wire, &assign, (3, 4), &strip).expect("write stream");
        let mut off = 0usize;
        let (first, used) = ProcMsg::decode(&wire).expect("decode header frame");
        assert_eq!(first, assign);
        off += used;
        let mut rebuilt = Vec::new();
        while off < wire.len() {
            let (msg, used) = ProcMsg::decode(&wire[off..]).expect("decode chunk");
            off += used;
            match msg {
                ProcMsg::Chunk { frame_id: 3, shard_id: 4, dir: 0, offset, total, data } => {
                    assert_eq!(offset as usize, rebuilt.len(), "chunks arrive dense");
                    assert_eq!(total as usize, strip.len());
                    assert!(!data.is_empty() && data.len() <= CHUNK_DATA_MAX);
                    rebuilt.extend_from_slice(&data);
                }
                other => panic!("unexpected frame {other:?}"),
            }
        }
        assert_eq!(rebuilt, strip, "reassembly is bit-identical");
    }

    #[test]
    fn supervisor_with_missing_bin_fails_construction() {
        let cfg = ProcPoolConfig {
            worker_bin: Some(PathBuf::from("/nonexistent/proc-worker")),
            ..Default::default()
        };
        assert!(ProcSupervisor::new(cfg).is_err());
    }
}
