//! Shared-memory data plane for the multi-process execution plane
//! (DESIGN.md §10): a per-child ring of fixed-size slots in one
//! `mmap`-shared tmpfs file, replacing the spill-file round-trip that
//! every shard's input strip and output partial used to make.
//!
//! The file plane is the software analog of an unpinned, unoverlapped
//! PCIe copy (the §4.4 failure mode): the child `write`s + `fsync`s a
//! partial to disk and the supervisor `open`s + `read`s + `unlink`s it
//! back.  Here the supervisor copies the input strip into a ring slot,
//! the child computes the partial *in place* in the same slot, and the
//! only per-shard traffic left on the pipe is the fixed-size control
//! frame — the shard bytes never touch a filesystem that isn't RAM.
//!
//! ## Ring layout and slot lifecycle
//!
//! One ring per child, `nslots` (= `per_child_inflight`) slots of
//! `slot_bytes` each, sized from the plan's largest shard
//! (`strip + partial` bytes).  A slot's interior is task-shaped:
//! the input strip occupies `[0, strip_bytes)` and the partial is
//! written contiguously at `[strip_bytes, strip_bytes + partial_bytes)`
//! — no per-ring header, so slot bookkeeping lives entirely in the
//! supervisor and the protocol carries `(slot, slot_off, ring_bytes,
//! ring_path)` (v2 `AssignShard`).
//!
//! Slot states (supervisor-side; the child never tracks them):
//!
//! ```text
//!   Free ──acquire──▶ Loaded ──AssignShard──▶ (child computes) ──ShardDone──▶ verify ──▶ Free
//!                        │                                                        │
//!                        └────────── child died: reclaimed on reap ◀──────────────┘
//! ```
//!
//! A SIGKILLed child's in-flight slots are reclaimed when the
//! supervisor reaps the corpse — *before* the respawn — so a
//! replacement child never races a ghost writer: the orphaned task is
//! requeued and lands in a freshly acquired slot (possibly on another
//! node).  Reclaims are counted (`ProcStats::slots_reclaimed`).
//!
//! ## Integrity and accounting
//!
//! The cross-process FNV-1a checksum moves from the spill-file payload
//! to the ring slot: the child checksums the partial it wrote in
//! place, the supervisor recomputes over the bytes it reads back out
//! of the slot, and a mismatch is a retry, exactly like the file
//! plane.  Mapped ring bytes are metered through the supervisor's
//! [`ResidentGauge`](crate::shard::ResidentGauge) and (when the server
//! provides one) reserved against the server-wide
//! [`MemoryBudget`](crate::coordinator::backpressure::MemoryBudget),
//! so shared mappings can't silently overcommit the host.
//!
//! ## Fallback ladder
//!
//! [`available`] is false when the platform has no usable `mmap` or no
//! tmpfs mount; `ProcPoolConfig::data_plane = Auto` then resolves to
//! the spill-file plane.  At runtime, a task too large for the ring's
//! slots falls back to the file plane per-task when the ring is busy
//! (and the ring is re-created larger once idle), and a ring-creation
//! failure downgrades the node to the file plane — every downgrade is
//! counted, never silent.

use anyhow::{anyhow, Context, Result};
use std::fs::OpenOptions;
use std::path::{Path, PathBuf};

/// Preferred tmpfs mount for ring files on Linux.
const DEV_SHM: &str = "/dev/shm";

/// True when this platform can serve the shared-memory plane: a
/// working `mmap` and a tmpfs directory to back the ring files.
pub fn available() -> bool {
    cfg!(unix) && default_dir().is_some()
}

/// The directory ring files live in: `/dev/shm` when it exists (RAM,
/// no disk I/O, no fsync cost), else `None` — callers fall back to
/// the spill-file plane rather than paying disk latency for a "shared
/// memory" that isn't.
pub fn default_dir() -> Option<PathBuf> {
    let p = PathBuf::from(DEV_SHM);
    if p.is_dir() {
        Some(p)
    } else {
        None
    }
}

#[cfg(unix)]
mod sys {
    use std::os::raw::{c_int, c_void};

    pub const PROT_READ: c_int = 1;
    pub const PROT_WRITE: c_int = 2;
    pub const MAP_SHARED: c_int = 1;

    // std already links libc on every unix target; declaring the two
    // symbols we need avoids growing a dependency the container can't
    // install (the repo vendors no libc crate).
    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: c_int,
            flags: c_int,
            fd: c_int,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> c_int;
    }

    pub fn map_failed() -> *mut c_void {
        usize::MAX as *mut c_void
    }
}

/// A shared read-write mapping of a ring file.  Raw-pointer copies
/// only — the mapping is written concurrently by another process, so
/// no long-lived `&[u8]`/`&mut [u8]` over it is ever materialized;
/// every access is bounds-checked against the mapped length.
struct MmapRegion {
    ptr: *mut u8,
    len: usize,
}

// The region is an owned OS mapping; the raw pointer is not tied to
// any thread. Cross-process synchronization rides the pipe protocol
// (a slot is only touched by one side at a time).
unsafe impl Send for MmapRegion {}

impl MmapRegion {
    #[cfg(unix)]
    fn map(file: &std::fs::File, len: usize) -> Result<MmapRegion> {
        use std::os::unix::io::AsRawFd;
        if len == 0 {
            return Err(anyhow!("refusing to map an empty ring"));
        }
        let ptr = unsafe {
            sys::mmap(
                std::ptr::null_mut(),
                len,
                sys::PROT_READ | sys::PROT_WRITE,
                sys::MAP_SHARED,
                file.as_raw_fd(),
                0,
            )
        };
        if ptr == sys::map_failed() || ptr.is_null() {
            return Err(anyhow!("mmap of {len} B ring failed"));
        }
        Ok(MmapRegion { ptr: ptr as *mut u8, len })
    }

    #[cfg(not(unix))]
    fn map(_file: &std::fs::File, _len: usize) -> Result<MmapRegion> {
        Err(anyhow!("shared-memory plane unavailable on this platform"))
    }

    fn copy_in(&self, off: usize, src: &[u8]) {
        assert!(
            off.checked_add(src.len()).is_some_and(|end| end <= self.len),
            "shm write of {} B at {off} past mapping of {} B",
            src.len(),
            self.len
        );
        unsafe { std::ptr::copy_nonoverlapping(src.as_ptr(), self.ptr.add(off), src.len()) };
    }

    fn copy_out(&self, off: usize, dst: &mut [u8]) {
        assert!(
            off.checked_add(dst.len()).is_some_and(|end| end <= self.len),
            "shm read of {} B at {off} past mapping of {} B",
            dst.len(),
            self.len
        );
        unsafe { std::ptr::copy_nonoverlapping(self.ptr.add(off), dst.as_mut_ptr(), dst.len()) };
    }
}

impl Drop for MmapRegion {
    fn drop(&mut self) {
        #[cfg(unix)]
        unsafe {
            sys::munmap(self.ptr as *mut std::os::raw::c_void, self.len);
        }
    }
}

/// Supervisor side: the per-child slot ring.  Owns the backing file
/// (unlinked on drop — the child's own mapping survives until it
/// unmaps) and the slot free-list; the child never sees the
/// bookkeeping, only `(slot, slot_off)` coordinates in `AssignShard`.
pub struct ShmRing {
    path: PathBuf,
    map: MmapRegion,
    nslots: usize,
    slot_bytes: usize,
    free: Vec<bool>,
}

impl ShmRing {
    /// Create a ring of `nslots × slot_bytes` under `dir` (tmpfs for
    /// the real plane; any directory works for tests).  `tag` keys the
    /// file name so one process can own many rings (one per child,
    /// re-created on growth).
    pub fn create(dir: &Path, tag: &str, nslots: usize, slot_bytes: usize) -> Result<ShmRing> {
        if nslots == 0 || slot_bytes == 0 {
            return Err(anyhow!("degenerate ring geometry {nslots}x{slot_bytes}"));
        }
        let ring_bytes = nslots
            .checked_mul(slot_bytes)
            .ok_or_else(|| anyhow!("ring size overflow {nslots}x{slot_bytes}"))?;
        let path = dir.join(format!("inthist-shm-{}-{tag}.ring", std::process::id()));
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(&path)
            .with_context(|| format!("create ring file {}", path.display()))?;
        file.set_len(ring_bytes as u64)
            .with_context(|| format!("size ring file {}", path.display()))?;
        let map = match MmapRegion::map(&file, ring_bytes) {
            Ok(m) => m,
            Err(e) => {
                let _ = std::fs::remove_file(&path);
                return Err(e);
            }
        };
        Ok(ShmRing { path, map, nslots, slot_bytes, free: vec![true; nslots] })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    pub fn ring_bytes(&self) -> usize {
        self.nslots * self.slot_bytes
    }

    pub fn slot_bytes(&self) -> usize {
        self.slot_bytes
    }

    pub fn nslots(&self) -> usize {
        self.nslots
    }

    /// Byte offset of `slot` within the ring (what `AssignShard`
    /// carries as `slot_off`).
    pub fn slot_off(&self, slot: usize) -> u64 {
        assert!(slot < self.nslots, "slot {slot} out of {}", self.nslots);
        (slot * self.slot_bytes) as u64
    }

    /// Claim a free slot (`None` when all are in flight — the caller
    /// queues, exactly like a full `per_child_inflight` window).
    pub fn acquire(&mut self) -> Option<usize> {
        let slot = self.free.iter().position(|f| *f)?;
        self.free[slot] = false;
        Some(slot)
    }

    /// Return a slot to the free list after its partial was read out
    /// (or its task was requeued).
    pub fn release(&mut self, slot: usize) {
        assert!(slot < self.nslots, "slot {slot} out of {}", self.nslots);
        self.free[slot] = true;
    }

    /// Reclaim-on-reap: free every in-flight slot of a child that just
    /// died (called after the corpse is reaped, before the respawn, so
    /// no ghost writer can race the replacement).  Returns how many
    /// slots were reclaimed.
    pub fn release_all(&mut self) -> usize {
        let mut n = 0;
        for f in &mut self.free {
            if !*f {
                *f = true;
                n += 1;
            }
        }
        n
    }

    /// Slots currently assigned.
    pub fn in_use(&self) -> usize {
        self.free.iter().filter(|f| !**f).count()
    }

    /// Copy `src` into `slot` at byte offset `off` (supervisor loads
    /// the input strip here before sending `AssignShard`).
    pub fn write(&mut self, slot: usize, off: usize, src: &[u8]) {
        assert!(off + src.len() <= self.slot_bytes, "write past slot capacity");
        self.map.copy_in(slot * self.slot_bytes + off, src);
    }

    /// Copy `dst.len()` bytes out of `slot` at byte offset `off`
    /// (supervisor reads the partial back after `ShardDone`).
    pub fn read(&self, slot: usize, off: usize, dst: &mut [u8]) {
        assert!(off + dst.len() <= self.slot_bytes, "read past slot capacity");
        self.map.copy_out(slot * self.slot_bytes + off, dst);
    }
}

impl Drop for ShmRing {
    fn drop(&mut self) {
        // The supervisor owns the file; children hold their own
        // mappings, which stay valid after the unlink.
        let _ = std::fs::remove_file(&self.path);
    }
}

/// Child side: a flat mapping of a ring file the supervisor named in
/// `AssignShard`.  The child does no slot bookkeeping — it reads the
/// strip at `slot_off`, writes the partial contiguously after it, and
/// the supervisor's free-list does the rest.
pub struct ShmMap {
    map: MmapRegion,
    len: usize,
}

impl ShmMap {
    /// Map an existing ring file read-write.  `ring_bytes` comes from
    /// the wire and is validated against the file's actual length so a
    /// malformed assignment can't map past the file.
    pub fn open(path: &Path, ring_bytes: usize) -> Result<ShmMap> {
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .open(path)
            .with_context(|| format!("open ring file {}", path.display()))?;
        let actual = file.metadata()?.len();
        if actual < ring_bytes as u64 {
            return Err(anyhow!(
                "ring file {} is {actual} B, assignment claims {ring_bytes} B",
                path.display()
            ));
        }
        let map = MmapRegion::map(&file, ring_bytes)?;
        Ok(ShmMap { map, len: ring_bytes })
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Read `dst.len()` bytes at absolute ring offset `off`.
    pub fn read(&self, off: usize, dst: &mut [u8]) {
        self.map.copy_out(off, dst);
    }

    /// Write `src` at absolute ring offset `off`.
    pub fn write(&self, off: usize, src: &[u8]) {
        self.map.copy_in(off, src);
    }
}

#[cfg(test)]
#[cfg(unix)]
mod tests {
    use super::*;

    fn ring_dir() -> PathBuf {
        // Prefer the real tmpfs when present; any dir works for the
        // mapping semantics under test.
        default_dir().unwrap_or_else(std::env::temp_dir)
    }

    #[test]
    fn plane_is_available_on_unix_with_tmpfs() {
        if default_dir().is_some() {
            assert!(available());
        }
    }

    #[test]
    fn ring_round_trips_bytes_through_both_sides() {
        let mut ring = ShmRing::create(&ring_dir(), "t-rt", 2, 4096).expect("ring");
        let slot = ring.acquire().expect("slot");
        let strip: Vec<u8> = (0..1024u32).map(|i| (i % 251) as u8).collect();
        ring.write(slot, 0, &strip);

        // The child's view: an independent mapping of the same file.
        let child = ShmMap::open(ring.path(), ring.ring_bytes()).expect("child map");
        let off = ring.slot_off(slot) as usize;
        let mut seen = vec![0u8; strip.len()];
        child.read(off, &mut seen);
        assert_eq!(seen, strip, "child must see the supervisor's strip");

        // Child writes the partial in place after the strip…
        let partial: Vec<u8> = (0..512u32).map(|i| (i % 97) as u8 ^ 0x5A).collect();
        child.write(off + strip.len(), &partial);
        // …and the supervisor reads it back out of the slot.
        let mut back = vec![0u8; partial.len()];
        ring.read(slot, strip.len(), &mut back);
        assert_eq!(back, partial, "supervisor must see the child's partial");
        ring.release(slot);
        assert_eq!(ring.in_use(), 0);
    }

    #[test]
    fn acquire_exhausts_and_release_recycles() {
        let mut ring = ShmRing::create(&ring_dir(), "t-acq", 2, 64).expect("ring");
        let a = ring.acquire().expect("slot a");
        let b = ring.acquire().expect("slot b");
        assert_ne!(a, b);
        assert!(ring.acquire().is_none(), "two slots, two holders");
        assert_eq!(ring.in_use(), 2);
        ring.release(a);
        assert_eq!(ring.acquire(), Some(a), "freed slot is reusable");
    }

    #[test]
    fn release_all_reclaims_in_flight_slots() {
        let mut ring = ShmRing::create(&ring_dir(), "t-reap", 3, 64).expect("ring");
        let _ = ring.acquire().expect("a");
        let _ = ring.acquire().expect("b");
        assert_eq!(ring.release_all(), 2, "both in-flight slots reclaimed");
        assert_eq!(ring.release_all(), 0, "reclaim is idempotent");
        assert_eq!(ring.in_use(), 0);
    }

    #[test]
    fn drop_unlinks_the_ring_file_but_child_mapping_survives() {
        let dir = ring_dir();
        let ring = ShmRing::create(&dir, "t-drop", 1, 256).expect("ring");
        let path = ring.path().to_path_buf();
        let child = ShmMap::open(&path, ring.ring_bytes()).expect("child map");
        assert!(path.exists());
        drop(ring);
        assert!(!path.exists(), "supervisor drop unlinks the ring file");
        // The unlinked file's pages stay valid under the live mapping.
        let mut buf = [0u8; 16];
        child.read(0, &mut buf);
    }

    #[test]
    fn degenerate_geometry_is_refused() {
        assert!(ShmRing::create(&ring_dir(), "t-degen", 0, 64).is_err());
        assert!(ShmRing::create(&ring_dir(), "t-degen2", 4, 0).is_err());
    }

    #[test]
    fn open_rejects_oversized_claims() {
        let ring = ShmRing::create(&ring_dir(), "t-claim", 1, 128).expect("ring");
        let err = ShmMap::open(ring.path(), ring.ring_bytes() * 2).expect_err("overclaim");
        assert!(err.to_string().contains("claims"), "{err}");
    }
}
