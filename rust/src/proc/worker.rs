//! Child-process side of the proc plane — the loop behind the
//! `proc-worker` bin target.
//!
//! One worker process is deliberately boring: a single thread blocks
//! on stdin decoding [`ProcMsg`] frames, executes each
//! [`AssignShard`](ProcMsg::AssignShard) with a locally checked-out
//! [`ScanEngine`], and answers on stdout.  Bulk data stays in
//! [`TensorStore`] files: the image strip is *read* from the path the
//! supervisor spilled, the partial tensor is *written* to the path the
//! assignment names, and only paths + a payload checksum cross the
//! pipe.  A heartbeat thread ticks on the shared stdout so the
//! supervisor can tell a hung child from a busy one; calibration runs
//! once at startup and is reported before the first assignment, which
//! is what per-node placement feeds on.
//!
//! Compute runs under `catch_unwind` exactly like the in-process
//! executor — a panic discards the engine and reports a typed
//! [`ShardFailed`](ProcMsg::ShardFailed); the *supervisor* owns the
//! retry budget, so the child never retries on its own.  Anything the
//! child cannot survive (abort, OOM kill, SIGKILL) ends the process,
//! which the supervisor observes as pipe EOF — that is the whole point
//! of the process boundary.

use crate::histogram::engine::ScanEngine;
use crate::histogram::types::{BinnedImage, IntegralHistogram};
use crate::proc::protocol::{checksum_f32, ProcMsg, WireAssign, NO_SLOT, PLANE_SHM};
use crate::proc::shm::ShmMap;
use crate::shard::TensorStore;
use crate::tune::Calibrator;
use crate::util::sync::lock_recover;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::io::Write;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Worker-side knobs (mirrored by the `proc-worker` CLI flags).
#[derive(Debug, Clone)]
pub struct WorkerConfig {
    /// Run the `Calibrator` startup microbench and report measured
    /// numbers; off ⇒ report the static prior (fast startup for
    /// tests).
    pub calibrate: bool,
    /// `ScanEngine` thread budget (the in-process executor's
    /// `engine_workers` analog).
    pub engine_workers: usize,
    /// Heartbeat interval on stdout.
    pub heartbeat: Duration,
    /// Chaos hook: sleep this long before the first byte of output —
    /// simulates a slow boot (cold page cache, loaded node, long
    /// calibration) for the supervisor's heartbeat-deferral tests.
    pub boot_delay: Duration,
}

impl Default for WorkerConfig {
    fn default() -> WorkerConfig {
        WorkerConfig {
            calibrate: true,
            engine_workers: 1,
            heartbeat: Duration::from_millis(200),
            boot_delay: Duration::ZERO,
        }
    }
}

/// Child-side ring cache: one [`ShmMap`] per ring file named by an
/// assignment.  Rings are re-created (under new names) when the
/// supervisor grows slots, so a bounded cache with wholesale eviction
/// is enough — stale mappings are merely unused pages.
const MAX_CACHED_RINGS: usize = 16;

fn ring_map<'m>(
    rings: &'m mut HashMap<String, ShmMap>,
    a: &WireAssign,
) -> std::result::Result<&'m ShmMap, String> {
    let need = a.ring_bytes as usize;
    let cached = rings.get(&a.ring_path).map_or(false, |m| m.len() >= need);
    if !cached {
        if rings.len() >= MAX_CACHED_RINGS {
            rings.clear();
        }
        let m = ShmMap::open(Path::new(&a.ring_path), need).map_err(|e| format!("map ring: {e:#}"))?;
        rings.insert(a.ring_path.clone(), m);
    }
    Ok(rings.get(&a.ring_path).expect("just inserted"))
}

/// Execute one wire assignment and produce the reply frame.  Pure with
/// respect to the pipes (pulled out of [`run`] so tests can drive it
/// in-process).  On the file plane it reads `a.img_path` and writes
/// `a.out_path`; on the shm plane the strip is read from the ring slot
/// at `a.slot_off` and the partial is written in place right after it —
/// no store round-trip at all.  Returns `ShardDone` or a typed
/// `ShardFailed`.  `engine` is a cache slot — a panicking compute
/// discards the engine (its scheduler state is suspect), matching the
/// in-process executor's discipline.  `rings` caches child-side ring
/// mappings across assignments.
pub fn execute_assign(
    a: &WireAssign,
    engine_workers: usize,
    engine: &mut Option<ScanEngine>,
    rings: &mut HashMap<String, ShmMap>,
) -> ProcMsg {
    let fail = |panicked: bool, reason: String| ProcMsg::ShardFailed {
        frame_id: a.frame_id,
        shard_id: a.shard_id,
        panicked,
        reason,
    };
    let (h, w) = (a.img_h as usize, a.img_w as usize);
    let (nbins, nrows, row0) = (a.nbins as usize, a.nrows as usize, a.row0 as usize);
    // Pull the strip (bin indices as f32 — small integers, exact in
    // f32, so the i32 roundtrip is lossless): from the ring slot on
    // the shm plane, from the spilled image store otherwise.
    let shm = a.plane == PLANE_SHM;
    let strip_bytes = nrows * w * 4;
    let mut strip = vec![0.0f32; nrows * w];
    if shm {
        let map = match ring_map(rings, a) {
            Ok(m) => m,
            Err(e) => return fail(false, e),
        };
        let mut bytes = vec![0u8; strip_bytes];
        map.read(a.slot_off as usize, &mut bytes);
        for (dst, src) in strip.iter_mut().zip(bytes.chunks_exact(4)) {
            *dst = f32::from_le_bytes([src[0], src[1], src[2], src[3]]);
        }
    } else {
        let img = match TensorStore::open(&a.img_path, 1, h, w) {
            Ok(s) => s,
            Err(e) => return fail(false, format!("open image: {e:#}")),
        };
        if let Err(e) = img.read_rows(0, row0, nrows, &mut strip) {
            return fail(false, format!("read image strip: {e:#}"));
        }
    }
    // Bin shift: values in [bin0, bin0+nbins) land in [0, nbins),
    // everything else is -1 (counts toward no bin) — the same slicing
    // the in-process worker_loop applies.
    let lo = a.bin0 as i32;
    let hi = (a.bin0 + a.nbins) as i32;
    let data: Vec<i32> = strip
        .iter()
        .map(|&f| {
            let v = f as i32;
            if v >= lo && v < hi {
                v - lo
            } else {
                -1
            }
        })
        .collect();
    let sub = BinnedImage { h: nrows, w, bins: nbins, data };

    let mut eng = match engine.take() {
        Some(e) => e,
        None => ScanEngine::new(engine_workers.max(1)),
    };
    let mut partial = IntegralHistogram::zeros(nbins, nrows, w);
    let t0 = Instant::now();
    let run = catch_unwind(AssertUnwindSafe(|| {
        eng.compute_into(&sub, &mut partial);
    }));
    let kernel_time = t0.elapsed();
    match run {
        Ok(()) => *engine = Some(eng),
        Err(_) => {
            drop(eng); // suspect mid-job state: rebuild on next checkout
            return fail(true, "compute panicked".into());
        }
    }

    // Commit the partial and checksum what we committed — the
    // supervisor verifies the same function over the bytes it reads
    // back.  Shm plane: raw f32 LE bytes in place, directly after the
    // strip in the same slot.  File plane: out store + flush.
    if shm {
        let map = rings.get(&a.ring_path).expect("mapped while reading the strip");
        let mut bytes = Vec::with_capacity(partial.data.len() * 4);
        for v in &partial.data {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        map.write(a.slot_off as usize + strip_bytes, &bytes);
    } else {
        let out = match TensorStore::create(&a.out_path, nbins, nrows, w) {
            Ok(s) => s,
            Err(e) => return fail(false, format!("create out store: {e:#}")),
        };
        for b in 0..nbins {
            if let Err(e) = out.write_rows(b, 0, partial.plane(b)) {
                return fail(false, format!("commit plane {b}: {e:#}"));
            }
        }
        if let Err(e) = out.flush() {
            return fail(false, format!("flush out store: {e:#}"));
        }
    }
    ProcMsg::ShardDone {
        frame_id: a.frame_id,
        shard_id: a.shard_id,
        kernel_time_us: kernel_time.as_micros() as u64,
        checksum: checksum_f32(&partial.data),
        slot: if shm { a.slot } else { NO_SLOT },
    }
}

/// Send one frame on the shared stdout: whole frame under the lock,
/// flushed immediately (a buffered reply is an invisible reply).
fn send(out: &Arc<Mutex<std::io::Stdout>>, msg: &ProcMsg) -> Result<()> {
    let mut o = lock_recover(out);
    msg.write_to(&mut *o).context("write protocol frame")?;
    o.flush().context("flush stdout")?;
    Ok(())
}

/// The worker main loop: heartbeat ticker → calibrate → report → serve
/// assignments until `Shutdown` or clean stdin EOF.
///
/// Order matters: the ticker spawns *before* calibration so the
/// supervisor hears from a slow-booting child while the microbench is
/// still running — calibration can legitimately exceed the heartbeat
/// timeout, and a silent boot used to read as a hang (spurious
/// kill→respawn→recalibrate loop).  The supervisor additionally defers
/// age enforcement until the first frame arrives, so even a child
/// stalled before the ticker (see `boot_delay`) is not killed early.
pub fn run(cfg: WorkerConfig) -> Result<()> {
    if !cfg.boot_delay.is_zero() {
        // Chaos hook: model the pre-fix world where nothing reaches
        // the pipe until calibration finishes.
        std::thread::sleep(cfg.boot_delay);
    }
    let out = Arc::new(Mutex::new(std::io::stdout()));

    // Heartbeat ticker first: liveness on the shared pipe, serialized
    // by the stdout lock so frames never interleave mid-frame.
    let stop = Arc::new(AtomicBool::new(false));
    let hb_out = Arc::clone(&out);
    let hb_stop = Arc::clone(&stop);
    let interval = cfg.heartbeat.max(Duration::from_millis(10));
    let seq = Arc::new(AtomicU64::new(0));
    let hb_seq = Arc::clone(&seq);
    let ticker = std::thread::Builder::new()
        .name("proc-worker-heartbeat".into())
        .spawn(move || {
            while !hb_stop.load(Ordering::Relaxed) {
                std::thread::sleep(interval);
                if hb_stop.load(Ordering::Relaxed) {
                    break;
                }
                let n = hb_seq.fetch_add(1, Ordering::Relaxed);
                if send(&hb_out, &ProcMsg::Heartbeat { seq: n }).is_err() {
                    break; // parent gone: nothing left to signal
                }
            }
        })
        .context("spawn heartbeat thread")?;

    // Calibrate this node and report before accepting work — the
    // supervisor's placement pass wants every node's snapshot up
    // front.  `calibrate: false` reports the prior (cheap startup).
    let cal = Calibrator::default();
    let snapshot = if cfg.calibrate { cal.calibrate() } else { cal.snapshot() };
    send(&out, &ProcMsg::CalibrationReport { snapshot })?;

    let mut stdin = std::io::stdin().lock();
    let mut engine: Option<ScanEngine> = None;
    let mut rings: HashMap<String, ShmMap> = HashMap::new();
    loop {
        match ProcMsg::read_from(&mut stdin) {
            Ok(None) | Ok(Some(ProcMsg::Shutdown)) => break,
            Ok(Some(ProcMsg::AssignShard(a))) => {
                let reply = execute_assign(&a, cfg.engine_workers, &mut engine, &mut rings);
                if send(&out, &reply).is_err() {
                    break; // parent gone
                }
            }
            // Parent-bound message types arriving here mean a confused
            // peer; ignore rather than die (the supervisor's heartbeat
            // timeout is the backstop).
            Ok(Some(_)) => {}
            Err(e) => {
                // A framing error on stdin is unrecoverable — resync
                // is impossible on a byte pipe.  Exit; the supervisor
                // sees EOF and respawns.
                stop.store(true, Ordering::Relaxed);
                let _ = ticker.join();
                return Err(anyhow::anyhow!("protocol error on stdin: {e}"));
            }
        }
    }
    stop.store(true, Ordering::Relaxed);
    let _ = ticker.join();
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::histogram::sequential::integral_histogram_seq;
    use crate::proc::protocol::PLANE_FILE;
    use crate::proc::shm::ShmRing;
    use crate::util::prng::Xoshiro256;

    fn spill_image(h: usize, w: usize, bins: usize, seed: u64) -> (BinnedImage, std::path::PathBuf) {
        let mut rng = Xoshiro256::new(seed);
        let mut data = vec![0i32; h * w];
        rng.fill_bins(&mut data, bins as u32);
        let img = BinnedImage::new(h, w, bins, data);
        let path = std::env::temp_dir().join(format!(
            "inthist-proc-test-img-{}-{seed}.bin",
            std::process::id()
        ));
        let store = TensorStore::create(&path, 1, h, w).expect("create");
        let rows: Vec<f32> = img.data.iter().map(|&v| v as f32).collect();
        store.write_rows(0, 0, &rows).expect("write");
        store.flush().expect("flush");
        (img, path)
    }

    #[test]
    fn execute_assign_matches_the_in_process_bin_shift_compute() {
        let (img, img_path) = spill_image(24, 18, 6, 77);
        let out_path = std::env::temp_dir()
            .join(format!("inthist-proc-test-out-{}.bin", std::process::id()));
        let a = WireAssign {
            frame_id: 5,
            shard_id: 2,
            bin0: 2,
            nbins: 3,
            row0: 6,
            nrows: 10,
            img_h: 24,
            img_w: 18,
            img_path: img_path.to_string_lossy().into_owned(),
            out_path: out_path.to_string_lossy().into_owned(),
            plane: PLANE_FILE,
            slot: 0,
            slot_off: 0,
            ring_bytes: 0,
            ring_path: String::new(),
        };
        let mut engine = None;
        let mut rings = HashMap::new();
        let reply = execute_assign(&a, 1, &mut engine, &mut rings);
        let (checksum, kernel_time_us) = match reply {
            ProcMsg::ShardDone { frame_id: 5, shard_id: 2, kernel_time_us, checksum, slot } => {
                assert_eq!(slot, NO_SLOT, "file plane replies carry no slot");
                (checksum, kernel_time_us)
            }
            other => panic!("expected ShardDone, got {other:?}"),
        };
        assert!(engine.is_some(), "engine cached for the next shard");
        let _ = kernel_time_us;

        // Oracle: the same slice + shift computed directly.
        let mut sub = BinnedImage { h: 10, w: 18, bins: 3, data: Vec::new() };
        sub.data = img.data[6 * 18..16 * 18]
            .iter()
            .map(|&v| if (2..5).contains(&v) { v - 2 } else { -1 })
            .collect();
        let want = integral_histogram_seq(&sub);

        let store = TensorStore::open(&out_path, 3, 10, 18).expect("open out");
        let got = store.to_histogram().expect("read back");
        assert_eq!(want.max_abs_diff(&got), 0.0, "cross-file result bit-identical");
        assert_eq!(checksum, checksum_f32(&want.data), "checksum covers the payload");
        std::fs::remove_file(&img_path).ok();
        std::fs::remove_file(&out_path).ok();
    }

    #[test]
    fn missing_image_fails_typed_not_fatal() {
        let a = WireAssign {
            frame_id: 1,
            shard_id: 0,
            bin0: 0,
            nbins: 2,
            row0: 0,
            nrows: 4,
            img_h: 8,
            img_w: 8,
            img_path: "/nonexistent/img.bin".into(),
            out_path: "/nonexistent/out.bin".into(),
            plane: PLANE_FILE,
            slot: 0,
            slot_off: 0,
            ring_bytes: 0,
            ring_path: String::new(),
        };
        let mut engine = None;
        let mut rings = HashMap::new();
        match execute_assign(&a, 1, &mut engine, &mut rings) {
            ProcMsg::ShardFailed { frame_id: 1, shard_id: 0, panicked: false, reason } => {
                assert!(reason.contains("open image"), "{reason}");
            }
            other => panic!("expected typed ShardFailed, got {other:?}"),
        }
    }

    #[cfg(unix)]
    #[test]
    fn shm_plane_matches_the_file_plane_bit_for_bit() {
        let (img, img_path) = spill_image(17, 13, 5, 91);
        let out_path = std::env::temp_dir()
            .join(format!("inthist-proc-test-shmcmp-{}.bin", std::process::id()));
        let (nrows, row0, nbins, w) = (9usize, 4usize, 3usize, 13usize);
        let strip_bytes = nrows * w * 4;
        let partial_bytes = nbins * nrows * w * 4;

        // Ring with one slot: supervisor-side write of the strip bytes.
        let dir = crate::proc::shm::default_dir().unwrap_or_else(std::env::temp_dir);
        let mut ring =
            ShmRing::create(&dir, "worker-ut", 1, strip_bytes + partial_bytes).expect("ring");
        let slot = ring.acquire().expect("free slot");
        let mut strip_raw = Vec::with_capacity(strip_bytes);
        for r in row0..row0 + nrows {
            for c in 0..w {
                strip_raw.extend_from_slice(&(img.data[r * w + c] as f32).to_le_bytes());
            }
        }
        ring.write(slot, 0, &strip_raw);

        let base = WireAssign {
            frame_id: 9,
            shard_id: 1,
            bin0: 1,
            nbins: nbins as u64,
            row0: row0 as u64,
            nrows: nrows as u64,
            img_h: 17,
            img_w: w as u64,
            img_path: img_path.to_string_lossy().into_owned(),
            out_path: out_path.to_string_lossy().into_owned(),
            plane: PLANE_FILE,
            slot: 0,
            slot_off: 0,
            ring_bytes: 0,
            ring_path: String::new(),
        };
        let shm_a = WireAssign {
            plane: PLANE_SHM,
            slot: slot as u64,
            slot_off: ring.slot_off(slot),
            ring_bytes: ring.ring_bytes() as u64,
            ring_path: ring.path().to_string_lossy().into_owned(),
            ..base.clone()
        };

        let mut engine = None;
        let mut rings = HashMap::new();
        let file_reply = execute_assign(&base, 1, &mut engine, &mut rings);
        let shm_reply = execute_assign(&shm_a, 1, &mut engine, &mut rings);
        let file_ck = match file_reply {
            ProcMsg::ShardDone { checksum, .. } => checksum,
            other => panic!("file plane: {other:?}"),
        };
        let (shm_ck, shm_slot) = match shm_reply {
            ProcMsg::ShardDone { checksum, slot, .. } => (checksum, slot),
            other => panic!("shm plane: {other:?}"),
        };
        assert_eq!(shm_ck, file_ck, "same payload checksum on both planes");
        assert_eq!(shm_slot, slot as u64, "reply names the slot it filled");

        // The slot's partial region holds the same bytes the file plane
        // committed to its out store.
        let store = TensorStore::open(&out_path, nbins, nrows, w).expect("open out");
        let file_hist = store.to_histogram().expect("read back");
        let mut slot_partial = vec![0u8; partial_bytes];
        ring.read(slot, strip_bytes, &mut slot_partial);
        let slot_f32: Vec<f32> = slot_partial
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        assert_eq!(slot_f32, file_hist.data, "ring partial bit-identical to out store");
        assert_eq!(checksum_f32(&slot_f32), shm_ck, "slot bytes match the wire checksum");

        std::fs::remove_file(&img_path).ok();
        std::fs::remove_file(&out_path).ok();
    }
}
