//! Child-process side of the proc plane — the loop behind the
//! `proc-worker` bin target.
//!
//! One worker process is deliberately boring: a single thread blocks
//! on stdin decoding [`ProcMsg`] frames, executes each
//! [`AssignShard`](ProcMsg::AssignShard) with a locally checked-out
//! [`ScanEngine`], and answers on stdout.  Bulk data stays in
//! [`TensorStore`] files: the image strip is *read* from the path the
//! supervisor spilled, the partial tensor is *written* to the path the
//! assignment names, and only paths + a payload checksum cross the
//! pipe.  A heartbeat thread ticks on the shared stdout so the
//! supervisor can tell a hung child from a busy one; calibration runs
//! once at startup and is reported before the first assignment, which
//! is what per-node placement feeds on.
//!
//! Compute runs under `catch_unwind` exactly like the in-process
//! executor — a panic discards the engine and reports a typed
//! [`ShardFailed`](ProcMsg::ShardFailed); the *supervisor* owns the
//! retry budget, so the child never retries on its own.  Anything the
//! child cannot survive (abort, OOM kill, SIGKILL) ends the process,
//! which the supervisor observes as pipe EOF — that is the whole point
//! of the process boundary.

use crate::histogram::engine::ScanEngine;
use crate::histogram::types::{BinnedImage, IntegralHistogram};
use crate::proc::protocol::{checksum_f32, ProcMsg, WireAssign};
use crate::shard::TensorStore;
use crate::tune::Calibrator;
use crate::util::sync::lock_recover;
use anyhow::{Context, Result};
use std::io::Write;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Worker-side knobs (mirrored by the `proc-worker` CLI flags).
#[derive(Debug, Clone)]
pub struct WorkerConfig {
    /// Run the `Calibrator` startup microbench and report measured
    /// numbers; off ⇒ report the static prior (fast startup for
    /// tests).
    pub calibrate: bool,
    /// `ScanEngine` thread budget (the in-process executor's
    /// `engine_workers` analog).
    pub engine_workers: usize,
    /// Heartbeat interval on stdout.
    pub heartbeat: Duration,
}

impl Default for WorkerConfig {
    fn default() -> WorkerConfig {
        WorkerConfig {
            calibrate: true,
            engine_workers: 1,
            heartbeat: Duration::from_millis(200),
        }
    }
}

/// Execute one wire assignment against the spill-file data plane and
/// produce the reply frame.  Pure with respect to the pipes (pulled
/// out of [`run`] so tests can drive it in-process): reads
/// `a.img_path`, writes `a.out_path`, returns `ShardDone` or a typed
/// `ShardFailed`.  `engine` is a cache slot — a panicking compute
/// discards the engine (its scheduler state is suspect), matching the
/// in-process executor's discipline.
pub fn execute_assign(
    a: &WireAssign,
    engine_workers: usize,
    engine: &mut Option<ScanEngine>,
) -> ProcMsg {
    let fail = |panicked: bool, reason: String| ProcMsg::ShardFailed {
        frame_id: a.frame_id,
        shard_id: a.shard_id,
        panicked,
        reason,
    };
    let (h, w) = (a.img_h as usize, a.img_w as usize);
    let (nbins, nrows, row0) = (a.nbins as usize, a.nrows as usize, a.row0 as usize);
    // Pull the strip from the spilled image (bin indices as f32 — small
    // integers, exact in f32, so the i32 roundtrip is lossless).
    let img = match TensorStore::open(&a.img_path, 1, h, w) {
        Ok(s) => s,
        Err(e) => return fail(false, format!("open image: {e:#}")),
    };
    let mut strip = vec![0.0f32; nrows * w];
    if let Err(e) = img.read_rows(0, row0, nrows, &mut strip) {
        return fail(false, format!("read image strip: {e:#}"));
    }
    // Bin shift: values in [bin0, bin0+nbins) land in [0, nbins),
    // everything else is -1 (counts toward no bin) — the same slicing
    // the in-process worker_loop applies.
    let lo = a.bin0 as i32;
    let hi = (a.bin0 + a.nbins) as i32;
    let data: Vec<i32> = strip
        .iter()
        .map(|&f| {
            let v = f as i32;
            if v >= lo && v < hi {
                v - lo
            } else {
                -1
            }
        })
        .collect();
    let sub = BinnedImage { h: nrows, w, bins: nbins, data };

    let mut eng = match engine.take() {
        Some(e) => e,
        None => ScanEngine::new(engine_workers.max(1)),
    };
    let mut partial = IntegralHistogram::zeros(nbins, nrows, w);
    let t0 = Instant::now();
    let run = catch_unwind(AssertUnwindSafe(|| {
        eng.compute_into(&sub, &mut partial);
    }));
    let kernel_time = t0.elapsed();
    match run {
        Ok(()) => *engine = Some(eng),
        Err(_) => {
            drop(eng); // suspect mid-job state: rebuild on next checkout
            return fail(true, "compute panicked".into());
        }
    }

    // Commit the partial to the out store, flush to stable storage,
    // and checksum what we committed — the supervisor verifies the
    // same function over the bytes it reads back.
    let out = match TensorStore::create(&a.out_path, nbins, nrows, w) {
        Ok(s) => s,
        Err(e) => return fail(false, format!("create out store: {e:#}")),
    };
    for b in 0..nbins {
        if let Err(e) = out.write_rows(b, 0, partial.plane(b)) {
            return fail(false, format!("commit plane {b}: {e:#}"));
        }
    }
    if let Err(e) = out.flush() {
        return fail(false, format!("flush out store: {e:#}"));
    }
    ProcMsg::ShardDone {
        frame_id: a.frame_id,
        shard_id: a.shard_id,
        kernel_time_us: kernel_time.as_micros() as u64,
        checksum: checksum_f32(&partial.data),
    }
}

/// Send one frame on the shared stdout: whole frame under the lock,
/// flushed immediately (a buffered reply is an invisible reply).
fn send(out: &Arc<Mutex<std::io::Stdout>>, msg: &ProcMsg) -> Result<()> {
    let mut o = lock_recover(out);
    msg.write_to(&mut *o).context("write protocol frame")?;
    o.flush().context("flush stdout")?;
    Ok(())
}

/// The worker main loop: calibrate → report → serve assignments until
/// `Shutdown` or clean stdin EOF.
pub fn run(cfg: WorkerConfig) -> Result<()> {
    let out = Arc::new(Mutex::new(std::io::stdout()));

    // Calibrate this node and report before accepting work — the
    // supervisor's placement pass wants every node's snapshot up
    // front.  `calibrate: false` reports the prior (cheap startup).
    let cal = Calibrator::default();
    let snapshot = if cfg.calibrate { cal.calibrate() } else { cal.snapshot() };
    send(&out, &ProcMsg::CalibrationReport { snapshot })?;

    // Heartbeat ticker: liveness on the same pipe, serialized by the
    // stdout lock so frames never interleave mid-frame.
    let stop = Arc::new(AtomicBool::new(false));
    let hb_out = Arc::clone(&out);
    let hb_stop = Arc::clone(&stop);
    let interval = cfg.heartbeat.max(Duration::from_millis(10));
    let seq = Arc::new(AtomicU64::new(0));
    let hb_seq = Arc::clone(&seq);
    let ticker = std::thread::Builder::new()
        .name("proc-worker-heartbeat".into())
        .spawn(move || {
            while !hb_stop.load(Ordering::Relaxed) {
                std::thread::sleep(interval);
                if hb_stop.load(Ordering::Relaxed) {
                    break;
                }
                let n = hb_seq.fetch_add(1, Ordering::Relaxed);
                if send(&hb_out, &ProcMsg::Heartbeat { seq: n }).is_err() {
                    break; // parent gone: nothing left to signal
                }
            }
        })
        .context("spawn heartbeat thread")?;

    let mut stdin = std::io::stdin().lock();
    let mut engine: Option<ScanEngine> = None;
    loop {
        match ProcMsg::read_from(&mut stdin) {
            Ok(None) | Ok(Some(ProcMsg::Shutdown)) => break,
            Ok(Some(ProcMsg::AssignShard(a))) => {
                let reply = execute_assign(&a, cfg.engine_workers, &mut engine);
                if send(&out, &reply).is_err() {
                    break; // parent gone
                }
            }
            // Parent-bound message types arriving here mean a confused
            // peer; ignore rather than die (the supervisor's heartbeat
            // timeout is the backstop).
            Ok(Some(_)) => {}
            Err(e) => {
                // A framing error on stdin is unrecoverable — resync
                // is impossible on a byte pipe.  Exit; the supervisor
                // sees EOF and respawns.
                stop.store(true, Ordering::Relaxed);
                let _ = ticker.join();
                return Err(anyhow::anyhow!("protocol error on stdin: {e}"));
            }
        }
    }
    stop.store(true, Ordering::Relaxed);
    let _ = ticker.join();
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::histogram::sequential::integral_histogram_seq;
    use crate::util::prng::Xoshiro256;

    fn spill_image(h: usize, w: usize, bins: usize, seed: u64) -> (BinnedImage, std::path::PathBuf) {
        let mut rng = Xoshiro256::new(seed);
        let mut data = vec![0i32; h * w];
        rng.fill_bins(&mut data, bins as u32);
        let img = BinnedImage::new(h, w, bins, data);
        let path = std::env::temp_dir().join(format!(
            "inthist-proc-test-img-{}-{seed}.bin",
            std::process::id()
        ));
        let store = TensorStore::create(&path, 1, h, w).expect("create");
        let rows: Vec<f32> = img.data.iter().map(|&v| v as f32).collect();
        store.write_rows(0, 0, &rows).expect("write");
        store.flush().expect("flush");
        (img, path)
    }

    #[test]
    fn execute_assign_matches_the_in_process_bin_shift_compute() {
        let (img, img_path) = spill_image(24, 18, 6, 77);
        let out_path = std::env::temp_dir()
            .join(format!("inthist-proc-test-out-{}.bin", std::process::id()));
        let a = WireAssign {
            frame_id: 5,
            shard_id: 2,
            bin0: 2,
            nbins: 3,
            row0: 6,
            nrows: 10,
            img_h: 24,
            img_w: 18,
            img_path: img_path.to_string_lossy().into_owned(),
            out_path: out_path.to_string_lossy().into_owned(),
        };
        let mut engine = None;
        let reply = execute_assign(&a, 1, &mut engine);
        let (checksum, kernel_time_us) = match reply {
            ProcMsg::ShardDone { frame_id: 5, shard_id: 2, kernel_time_us, checksum } => {
                (checksum, kernel_time_us)
            }
            other => panic!("expected ShardDone, got {other:?}"),
        };
        assert!(engine.is_some(), "engine cached for the next shard");
        let _ = kernel_time_us;

        // Oracle: the same slice + shift computed directly.
        let mut sub = BinnedImage { h: 10, w: 18, bins: 3, data: Vec::new() };
        sub.data = img.data[6 * 18..16 * 18]
            .iter()
            .map(|&v| if (2..5).contains(&v) { v - 2 } else { -1 })
            .collect();
        let want = integral_histogram_seq(&sub);

        let store = TensorStore::open(&out_path, 3, 10, 18).expect("open out");
        let got = store.to_histogram().expect("read back");
        assert_eq!(want.max_abs_diff(&got), 0.0, "cross-file result bit-identical");
        assert_eq!(checksum, checksum_f32(&want.data), "checksum covers the payload");
        std::fs::remove_file(&img_path).ok();
        std::fs::remove_file(&out_path).ok();
    }

    #[test]
    fn missing_image_fails_typed_not_fatal() {
        let a = WireAssign {
            frame_id: 1,
            shard_id: 0,
            bin0: 0,
            nbins: 2,
            row0: 0,
            nrows: 4,
            img_h: 8,
            img_w: 8,
            img_path: "/nonexistent/img.bin".into(),
            out_path: "/nonexistent/out.bin".into(),
        };
        let mut engine = None;
        match execute_assign(&a, 1, &mut engine) {
            ProcMsg::ShardFailed { frame_id: 1, shard_id: 0, panicked: false, reason } => {
                assert!(reason.contains("open image"), "{reason}");
            }
            other => panic!("expected typed ShardFailed, got {other:?}"),
        }
    }
}
