//! Child-process side of the proc plane — the loop behind the
//! `proc-worker` bin target.
//!
//! One worker process is deliberately boring: a single thread blocks
//! on its control stream decoding [`ProcMsg`] frames, executes each
//! [`AssignShard`](ProcMsg::AssignShard) with a locally checked-out
//! [`ScanEngine`], and answers on the shared write half.  The loop is
//! generic over the byte streams ([`serve`]): the classic pipe worker
//! feeds it stdin/stdout, the socket worker (`proc-worker --listen`)
//! feeds it a connected [`TcpStream`] after the `Hello` handshake.
//!
//! Bulk data rides whichever plane the assignment names: the file
//! plane exchanges [`TensorStore`] paths, the shm plane a ring slot,
//! and the v3 **stream plane** moves the strip and the partial as
//! bounded [`Chunk`](ProcMsg::Chunk) frames over the connection
//! itself — the remote worker shares neither filesystem nor memory
//! with the supervisor.  A heartbeat thread ticks on the shared write
//! half so the supervisor can tell a hung child from a busy one;
//! calibration runs once at startup and is reported before the first
//! assignment, which is what per-node placement feeds on.
//!
//! Deadlines arrive as *remaining budget* (`deadline_us`), never as
//! instants — wall clocks and `Instant` epochs do not line up across
//! process or host boundaries.  The worker anchors the budget at the
//! assignment's arrival and skips shards whose budget has already
//! burned down before compute starts (strip transfer on the stream
//! plane), reporting a `deadline`-flagged `ShardFailed` the supervisor
//! charges to `skipped_deadline` rather than the retry ladder.
//!
//! Compute runs under `catch_unwind` exactly like the in-process
//! executor — a panic discards the engine and reports a typed
//! [`ShardFailed`](ProcMsg::ShardFailed); the *supervisor* owns the
//! retry budget, so the child never retries on its own.  Anything the
//! child cannot survive (abort, OOM kill, SIGKILL) ends the process,
//! which the supervisor observes as pipe EOF or socket disconnect —
//! that is the whole point of the process boundary.

use crate::histogram::engine::ScanEngine;
use crate::histogram::types::{BinnedImage, IntegralHistogram};
use crate::proc::protocol::{
    checksum_bytes, checksum_f32, ProcMsg, WireAssign, CAPS_ALL, CHUNK_DATA_MAX, NO_SLOT,
    PLANE_SHM, PLANE_STREAM, PROTOCOL_VERSION,
};
use crate::proc::shm::ShmMap;
use crate::shard::TensorStore;
use crate::tune::Calibrator;
use crate::util::sync::lock_recover;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Worker-side knobs (mirrored by the `proc-worker` CLI flags).
#[derive(Debug, Clone)]
pub struct WorkerConfig {
    /// Run the `Calibrator` startup microbench and report measured
    /// numbers; off ⇒ report the static prior (fast startup for
    /// tests).
    pub calibrate: bool,
    /// `ScanEngine` thread budget (the in-process executor's
    /// `engine_workers` analog).
    pub engine_workers: usize,
    /// Heartbeat interval on the write half.
    pub heartbeat: Duration,
    /// Chaos hook: sleep this long before the first byte of output —
    /// simulates a slow boot (cold page cache, loaded node, long
    /// calibration) for the supervisor's heartbeat-deferral tests.
    pub boot_delay: Duration,
}

impl Default for WorkerConfig {
    fn default() -> WorkerConfig {
        WorkerConfig {
            calibrate: true,
            engine_workers: 1,
            heartbeat: Duration::from_millis(200),
            boot_delay: Duration::ZERO,
        }
    }
}

/// Child-side ring cache: one [`ShmMap`] per ring file named by an
/// assignment.  Rings are re-created (under new names) when the
/// supervisor grows slots, so a bounded cache with wholesale eviction
/// is enough — stale mappings are merely unused pages.
const MAX_CACHED_RINGS: usize = 16;

fn ring_map<'m>(
    rings: &'m mut HashMap<String, ShmMap>,
    a: &WireAssign,
) -> std::result::Result<&'m ShmMap, String> {
    let need = a.ring_bytes as usize;
    let cached = rings.get(&a.ring_path).map_or(false, |m| m.len() >= need);
    if !cached {
        if rings.len() >= MAX_CACHED_RINGS {
            rings.clear();
        }
        let m = ShmMap::open(Path::new(&a.ring_path), need).map_err(|e| format!("map ring: {e:#}"))?;
        rings.insert(a.ring_path.clone(), m);
    }
    Ok(rings.get(&a.ring_path).expect("just inserted"))
}

/// Has a wire deadline budget burned down since the assignment
/// arrived?  `deadline_us == 0` means no deadline.  The budget is
/// anchored at *arrival* — the only instant both clock domains agree
/// on, because this side observed it.
pub fn deadline_expired(deadline_us: u64, arrival: Instant) -> bool {
    deadline_us > 0 && arrival.elapsed() >= Duration::from_micros(deadline_us)
}

/// Bin-shift the strip and run the engine under `catch_unwind`.
/// Shared by every data plane.  `Err((panicked, reason))` on failure.
fn compute_partial(
    strip: &[f32],
    a: &WireAssign,
    engine_workers: usize,
    engine: &mut Option<ScanEngine>,
) -> std::result::Result<(IntegralHistogram, Duration), (bool, String)> {
    let (w, nbins, nrows) = (a.img_w as usize, a.nbins as usize, a.nrows as usize);
    // Bin shift: values in [bin0, bin0+nbins) land in [0, nbins),
    // everything else is -1 (counts toward no bin) — the same slicing
    // the in-process worker_loop applies.
    let lo = a.bin0 as i32;
    let hi = (a.bin0 + a.nbins) as i32;
    let data: Vec<i32> = strip
        .iter()
        .map(|&f| {
            let v = f as i32;
            if v >= lo && v < hi {
                v - lo
            } else {
                -1
            }
        })
        .collect();
    let sub = BinnedImage { h: nrows, w, bins: nbins, data };

    let mut eng = match engine.take() {
        Some(e) => e,
        None => ScanEngine::new(engine_workers.max(1)),
    };
    let mut partial = IntegralHistogram::zeros(nbins, nrows, w);
    let t0 = Instant::now();
    let run = catch_unwind(AssertUnwindSafe(|| {
        eng.compute_into(&sub, &mut partial);
    }));
    let kernel_time = t0.elapsed();
    match run {
        Ok(()) => {
            *engine = Some(eng);
            Ok((partial, kernel_time))
        }
        Err(_) => {
            drop(eng); // suspect mid-job state: rebuild on next checkout
            Err((true, "compute panicked".into()))
        }
    }
}

/// Execute one wire assignment and produce the reply frame.  Pure with
/// respect to the pipes (pulled out of [`serve`] so tests can drive it
/// in-process).  On the file plane it reads `a.img_path` and writes
/// `a.out_path`; on the shm plane the strip is read from the ring slot
/// at `a.slot_off` and the partial is written in place right after it —
/// no store round-trip at all.  Returns `ShardDone` or a typed
/// `ShardFailed`.  `engine` is a cache slot — a panicking compute
/// discards the engine (its scheduler state is suspect), matching the
/// in-process executor's discipline.  `rings` caches child-side ring
/// mappings across assignments.
pub fn execute_assign(
    a: &WireAssign,
    engine_workers: usize,
    engine: &mut Option<ScanEngine>,
    rings: &mut HashMap<String, ShmMap>,
) -> ProcMsg {
    let fail = |panicked: bool, reason: String| ProcMsg::ShardFailed {
        frame_id: a.frame_id,
        shard_id: a.shard_id,
        panicked,
        deadline: false,
        reason,
    };
    let (h, w) = (a.img_h as usize, a.img_w as usize);
    let (nbins, nrows, row0) = (a.nbins as usize, a.nrows as usize, a.row0 as usize);
    // Pull the strip (bin indices as f32 — small integers, exact in
    // f32, so the i32 roundtrip is lossless): from the ring slot on
    // the shm plane, from the spilled image store otherwise.
    let shm = a.plane == PLANE_SHM;
    let strip_bytes = nrows * w * 4;
    let mut strip = vec![0.0f32; nrows * w];
    if shm {
        let map = match ring_map(rings, a) {
            Ok(m) => m,
            Err(e) => return fail(false, e),
        };
        let mut bytes = vec![0u8; strip_bytes];
        map.read(a.slot_off as usize, &mut bytes);
        for (dst, src) in strip.iter_mut().zip(bytes.chunks_exact(4)) {
            *dst = f32::from_le_bytes([src[0], src[1], src[2], src[3]]);
        }
    } else {
        let img = match TensorStore::open(&a.img_path, 1, h, w) {
            Ok(s) => s,
            Err(e) => return fail(false, format!("open image: {e:#}")),
        };
        if let Err(e) = img.read_rows(0, row0, nrows, &mut strip) {
            return fail(false, format!("read image strip: {e:#}"));
        }
    }
    let (partial, kernel_time) = match compute_partial(&strip, a, engine_workers, engine) {
        Ok(r) => r,
        Err((panicked, reason)) => return fail(panicked, reason),
    };

    // Commit the partial and checksum what we committed — the
    // supervisor verifies the same function over the bytes it reads
    // back.  Shm plane: raw f32 LE bytes in place, directly after the
    // strip in the same slot.  File plane: out store + flush.
    if shm {
        let map = rings.get(&a.ring_path).expect("mapped while reading the strip");
        let mut bytes = Vec::with_capacity(partial.data.len() * 4);
        for v in &partial.data {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        map.write(a.slot_off as usize + strip_bytes, &bytes);
    } else {
        let out = match TensorStore::create(&a.out_path, nbins, nrows, w) {
            Ok(s) => s,
            Err(e) => return fail(false, format!("create out store: {e:#}")),
        };
        for b in 0..nbins {
            if let Err(e) = out.write_rows(b, 0, partial.plane(b)) {
                return fail(false, format!("commit plane {b}: {e:#}"));
            }
        }
        if let Err(e) = out.flush() {
            return fail(false, format!("flush out store: {e:#}"));
        }
    }
    ProcMsg::ShardDone {
        frame_id: a.frame_id,
        shard_id: a.shard_id,
        kernel_time_us: kernel_time.as_micros() as u64,
        checksum: checksum_f32(&partial.data),
        slot: if shm { a.slot } else { NO_SLOT },
    }
}

/// Execute a stream-plane assignment whose strip was assembled from
/// [`Chunk`](ProcMsg::Chunk) frames.  Returns the reply plus, on
/// success, the partial's raw f32 LE bytes for the caller to stream
/// back before the `ShardDone`.
pub fn execute_stream(
    a: &WireAssign,
    strip_raw: &[u8],
    engine_workers: usize,
    engine: &mut Option<ScanEngine>,
) -> (ProcMsg, Option<Vec<u8>>) {
    let fail = |panicked: bool, reason: String| ProcMsg::ShardFailed {
        frame_id: a.frame_id,
        shard_id: a.shard_id,
        panicked,
        deadline: false,
        reason,
    };
    let strip: Vec<f32> = strip_raw
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    match compute_partial(&strip, a, engine_workers, engine) {
        Ok((partial, kernel_time)) => {
            let mut bytes = Vec::with_capacity(partial.data.len() * 4);
            for v in &partial.data {
                bytes.extend_from_slice(&v.to_le_bytes());
            }
            let done = ProcMsg::ShardDone {
                frame_id: a.frame_id,
                shard_id: a.shard_id,
                kernel_time_us: kernel_time.as_micros() as u64,
                checksum: checksum_f32(&partial.data),
                slot: NO_SLOT,
            };
            (done, Some(bytes))
        }
        Err((panicked, reason)) => (fail(panicked, reason), None),
    }
}

/// Send one frame on the shared write half: whole frame under the
/// lock, flushed immediately (a buffered reply is an invisible reply).
fn send<W: Write>(out: &Arc<Mutex<W>>, msg: &ProcMsg) -> Result<()> {
    let mut o = lock_recover(out);
    msg.write_to(&mut *o).context("write protocol frame")?;
    o.flush().context("flush control stream")?;
    Ok(())
}

/// A stream-plane assignment whose strip is still in flight.
struct PendingStream {
    a: WireAssign,
    /// When the assignment arrived — the anchor for its deadline
    /// budget.
    arrival: Instant,
    buf: Vec<u8>,
}

/// The worker main loop over arbitrary byte streams: heartbeat ticker
/// → calibrate → report → serve assignments until `Shutdown` or clean
/// EOF.  [`run`] feeds it stdin/stdout; [`serve_conn`] feeds it a
/// connected socket.
///
/// Order matters: the ticker spawns *before* calibration so the
/// supervisor hears from a slow-booting child while the microbench is
/// still running — calibration can legitimately exceed the heartbeat
/// timeout, and a silent boot used to read as a hang (spurious
/// kill→respawn→recalibrate loop).  The supervisor additionally defers
/// age enforcement until the first frame arrives, so even a child
/// stalled before the ticker (see `boot_delay`) is not killed early.
pub fn serve<R: Read, W: Write + Send + 'static>(
    mut input: R,
    out: Arc<Mutex<W>>,
    cfg: &WorkerConfig,
) -> Result<()> {
    // Heartbeat ticker first: liveness on the shared write half,
    // serialized by its lock so frames never interleave mid-frame.
    let stop = Arc::new(AtomicBool::new(false));
    let hb_out = Arc::clone(&out);
    let hb_stop = Arc::clone(&stop);
    let interval = cfg.heartbeat.max(Duration::from_millis(10));
    let seq = Arc::new(AtomicU64::new(0));
    let hb_seq = Arc::clone(&seq);
    let ticker = std::thread::Builder::new()
        .name("proc-worker-heartbeat".into())
        .spawn(move || {
            while !hb_stop.load(Ordering::Relaxed) {
                std::thread::sleep(interval);
                if hb_stop.load(Ordering::Relaxed) {
                    break;
                }
                let n = hb_seq.fetch_add(1, Ordering::Relaxed);
                if send(&hb_out, &ProcMsg::Heartbeat { seq: n }).is_err() {
                    break; // parent gone: nothing left to signal
                }
            }
        })
        .context("spawn heartbeat thread")?;
    let stop_ticker = |err: Option<anyhow::Error>| {
        stop.store(true, Ordering::Relaxed);
        let _ = ticker.join();
        match err {
            None => Ok(()),
            Some(e) => Err(e),
        }
    };

    // Calibrate this node and report before accepting work — the
    // supervisor's placement pass wants every node's snapshot up
    // front.  `calibrate: false` reports the prior (cheap startup).
    let cal = Calibrator::default();
    let snapshot = if cfg.calibrate { cal.calibrate() } else { cal.snapshot() };
    if let Err(e) = send(&out, &ProcMsg::CalibrationReport { snapshot }) {
        return stop_ticker(Some(e));
    }

    let mut engine: Option<ScanEngine> = None;
    let mut rings: HashMap<String, ShmMap> = HashMap::new();
    let mut streams: HashMap<(u64, u64), PendingStream> = HashMap::new();
    loop {
        match ProcMsg::read_from(&mut input) {
            Ok(None) | Ok(Some(ProcMsg::Shutdown)) => break,
            Ok(Some(ProcMsg::AssignShard(a))) => {
                let arrival = Instant::now();
                if a.plane == PLANE_STREAM {
                    // Strip follows as chunks; anchor the deadline now.
                    // Capacity is a hint capped defensively — growth is
                    // bounded by the per-chunk checks below either way.
                    let total = (a.strip_bytes().unwrap_or(0) as usize).min(1 << 20);
                    streams.insert(
                        (a.frame_id, a.shard_id),
                        PendingStream { a, arrival, buf: Vec::with_capacity(total) },
                    );
                    continue;
                }
                let reply = if deadline_expired(a.deadline_us, arrival) {
                    ProcMsg::ShardFailed {
                        frame_id: a.frame_id,
                        shard_id: a.shard_id,
                        panicked: false,
                        deadline: true,
                        reason: "deadline budget expired before compute".into(),
                    }
                } else {
                    execute_assign(&a, cfg.engine_workers, &mut engine, &mut rings)
                };
                if send(&out, &reply).is_err() {
                    break; // parent gone
                }
            }
            Ok(Some(ProcMsg::Chunk { frame_id, shard_id, dir, offset, total, data })) => {
                if dir != 0 {
                    continue; // parent-bound chunk echoed here: confused peer
                }
                let key = (frame_id, shard_id);
                let Some(p) = streams.get_mut(&key) else {
                    // Chunk without a pending assignment — stale after
                    // a reconnect; the supervisor re-sends everything.
                    continue;
                };
                let expected = p.a.strip_bytes().unwrap_or(0);
                let in_order = offset as usize == p.buf.len()
                    && total == expected
                    && (p.buf.len() + data.len()) as u64 <= expected;
                if !in_order {
                    streams.remove(&key);
                    let reply = ProcMsg::ShardFailed {
                        frame_id,
                        shard_id,
                        panicked: false,
                        deadline: false,
                        reason: format!(
                            "stream chunk out of order (offset {offset}, total {total}, \
                             expected strip {expected} B)"
                        ),
                    };
                    if send(&out, &reply).is_err() {
                        break;
                    }
                    continue;
                }
                p.buf.extend_from_slice(&data);
                if (p.buf.len() as u64) < expected {
                    continue; // strip still in flight
                }
                let p = streams.remove(&key).expect("pending stream present");
                let reply = if checksum_bytes(&p.buf) != p.a.strip_checksum {
                    ProcMsg::ShardFailed {
                        frame_id,
                        shard_id,
                        panicked: false,
                        deadline: false,
                        reason: "strip checksum mismatch after transfer".into(),
                    }
                } else if deadline_expired(p.a.deadline_us, p.arrival) {
                    // The budget burned down during transfer: skip the
                    // compute entirely, flagged so the supervisor
                    // charges `skipped_deadline`, not the retry ladder.
                    ProcMsg::ShardFailed {
                        frame_id,
                        shard_id,
                        panicked: false,
                        deadline: true,
                        reason: "deadline budget expired before compute".into(),
                    }
                } else {
                    let (done, partial) =
                        execute_stream(&p.a, &p.buf, cfg.engine_workers, &mut engine);
                    if let Some(bytes) = partial {
                        if send_chunks(&out, frame_id, shard_id, 1, &bytes).is_err() {
                            break;
                        }
                    }
                    done
                };
                if send(&out, &reply).is_err() {
                    break;
                }
            }
            // Parent-bound message types arriving here mean a confused
            // peer; ignore rather than die (the supervisor's heartbeat
            // timeout is the backstop).
            Ok(Some(_)) => {}
            Err(e) => {
                // A framing error on the control stream is
                // unrecoverable — resync is impossible on a byte
                // stream.  Exit; the supervisor sees EOF/disconnect
                // and respawns or reconnects.
                return stop_ticker(Some(anyhow::anyhow!("protocol error on control stream: {e}")));
            }
        }
    }
    stop_ticker(None)
}

/// Push `bytes` as ordered [`Chunk`](ProcMsg::Chunk) frames, each at
/// most [`CHUNK_DATA_MAX`] so heartbeats interleave with the transfer.
fn send_chunks<W: Write>(
    out: &Arc<Mutex<W>>,
    frame_id: u64,
    shard_id: u64,
    dir: u8,
    bytes: &[u8],
) -> Result<()> {
    let total = bytes.len() as u64;
    let mut off = 0usize;
    while off < bytes.len() {
        let end = (off + CHUNK_DATA_MAX as usize).min(bytes.len());
        send(
            out,
            &ProcMsg::Chunk {
                frame_id,
                shard_id,
                dir,
                offset: off as u64,
                total,
                data: bytes[off..end].to_vec(),
            },
        )?;
        off = end;
    }
    Ok(())
}

/// The classic pipe worker: [`serve`] over stdin/stdout.
pub fn run(cfg: WorkerConfig) -> Result<()> {
    if !cfg.boot_delay.is_zero() {
        // Chaos hook: model the pre-fix world where nothing reaches
        // the pipe until calibration finishes.
        std::thread::sleep(cfg.boot_delay);
    }
    let out = Arc::new(Mutex::new(std::io::stdout()));
    let stdin = std::io::stdin().lock();
    serve(stdin, out, &cfg)
}

/// Serve one accepted socket connection: `Hello` handshake (worker
/// speaks first), then the same [`serve`] loop the pipe worker runs.
/// Returns when the supervisor disconnects or sends `Shutdown`; the
/// listener keeps accepting, which is what makes reconnect cheap.
pub fn serve_conn(stream: TcpStream, cfg: &WorkerConfig) -> Result<()> {
    stream.set_nodelay(true).ok();
    let mut reader = stream.try_clone().context("clone socket read half")?;
    let out = Arc::new(Mutex::new(stream));
    send(
        &out,
        &ProcMsg::Hello { version: PROTOCOL_VERSION, caps: CAPS_ALL, tag: "proc-worker".into() },
    )
    .context("send handshake")?;
    // Require the supervisor's reply before any work flows — and don't
    // let a silent peer pin this connection thread forever.
    lock_recover(&out)
        .set_read_timeout(Some(Duration::from_secs(10)))
        .context("arm handshake read timeout")?;
    match ProcMsg::read_from(&mut reader) {
        Ok(Some(ProcMsg::Hello { .. })) => {}
        Ok(other) => anyhow::bail!("handshake: expected Hello, got {other:?}"),
        Err(e) => anyhow::bail!("handshake: {e}"),
    }
    lock_recover(&out).set_read_timeout(None).context("disarm handshake read timeout")?;
    serve(reader, out, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::histogram::sequential::integral_histogram_seq;
    use crate::proc::protocol::PLANE_FILE;
    use crate::proc::shm::ShmRing;
    use crate::util::prng::Xoshiro256;
    use std::sync::mpsc;

    fn spill_image(h: usize, w: usize, bins: usize, seed: u64) -> (BinnedImage, std::path::PathBuf) {
        let mut rng = Xoshiro256::new(seed);
        let mut data = vec![0i32; h * w];
        rng.fill_bins(&mut data, bins as u32);
        let img = BinnedImage::new(h, w, bins, data);
        let path = std::env::temp_dir().join(format!(
            "inthist-proc-test-img-{}-{seed}.bin",
            std::process::id()
        ));
        let store = TensorStore::create(&path, 1, h, w).expect("create");
        let rows: Vec<f32> = img.data.iter().map(|&v| v as f32).collect();
        store.write_rows(0, 0, &rows).expect("write");
        store.flush().expect("flush");
        (img, path)
    }

    #[test]
    fn execute_assign_matches_the_in_process_bin_shift_compute() {
        let (img, img_path) = spill_image(24, 18, 6, 77);
        let out_path = std::env::temp_dir()
            .join(format!("inthist-proc-test-out-{}.bin", std::process::id()));
        let a = WireAssign {
            frame_id: 5,
            shard_id: 2,
            bin0: 2,
            nbins: 3,
            row0: 6,
            nrows: 10,
            img_h: 24,
            img_w: 18,
            img_path: img_path.to_string_lossy().into_owned(),
            out_path: out_path.to_string_lossy().into_owned(),
            plane: PLANE_FILE,
            slot: 0,
            slot_off: 0,
            ring_bytes: 0,
            ring_path: String::new(),
            deadline_us: 0,
            strip_checksum: 0,
        };
        let mut engine = None;
        let mut rings = HashMap::new();
        let reply = execute_assign(&a, 1, &mut engine, &mut rings);
        let (checksum, kernel_time_us) = match reply {
            ProcMsg::ShardDone { frame_id: 5, shard_id: 2, kernel_time_us, checksum, slot } => {
                assert_eq!(slot, NO_SLOT, "file plane replies carry no slot");
                (checksum, kernel_time_us)
            }
            other => panic!("expected ShardDone, got {other:?}"),
        };
        assert!(engine.is_some(), "engine cached for the next shard");
        let _ = kernel_time_us;

        // Oracle: the same slice + shift computed directly.
        let mut sub = BinnedImage { h: 10, w: 18, bins: 3, data: Vec::new() };
        sub.data = img.data[6 * 18..16 * 18]
            .iter()
            .map(|&v| if (2..5).contains(&v) { v - 2 } else { -1 })
            .collect();
        let want = integral_histogram_seq(&sub);

        let store = TensorStore::open(&out_path, 3, 10, 18).expect("open out");
        let got = store.to_histogram().expect("read back");
        assert_eq!(want.max_abs_diff(&got), 0.0, "cross-file result bit-identical");
        assert_eq!(checksum, checksum_f32(&want.data), "checksum covers the payload");

        // The stream plane produces the very same partial from the
        // same strip bytes — bit-identical across data planes.
        let mut strip_raw = Vec::new();
        for r in 6..16 {
            for c in 0..18 {
                strip_raw.extend_from_slice(&(img.data[r * 18 + c] as f32).to_le_bytes());
            }
        }
        let sa = WireAssign {
            img_path: String::new(),
            out_path: String::new(),
            plane: PLANE_STREAM,
            strip_checksum: checksum_bytes(&strip_raw),
            ..a
        };
        let (reply, partial) = execute_stream(&sa, &strip_raw, 1, &mut engine);
        match reply {
            ProcMsg::ShardDone { checksum: sck, .. } => {
                assert_eq!(sck, checksum, "stream plane checksum matches file plane")
            }
            other => panic!("expected ShardDone, got {other:?}"),
        }
        let bytes = partial.expect("stream success carries the partial bytes");
        let got: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        assert_eq!(got, want.data, "streamed partial bit-identical");

        std::fs::remove_file(&img_path).ok();
        std::fs::remove_file(&out_path).ok();
    }

    #[test]
    fn missing_image_fails_typed_not_fatal() {
        let a = WireAssign {
            frame_id: 1,
            shard_id: 0,
            bin0: 0,
            nbins: 2,
            row0: 0,
            nrows: 4,
            img_h: 8,
            img_w: 8,
            img_path: "/nonexistent/img.bin".into(),
            out_path: "/nonexistent/out.bin".into(),
            plane: PLANE_FILE,
            slot: 0,
            slot_off: 0,
            ring_bytes: 0,
            ring_path: String::new(),
            deadline_us: 0,
            strip_checksum: 0,
        };
        let mut engine = None;
        let mut rings = HashMap::new();
        match execute_assign(&a, 1, &mut engine, &mut rings) {
            ProcMsg::ShardFailed { frame_id: 1, shard_id: 0, panicked: false, deadline, reason } => {
                assert!(!deadline, "an I/O failure is not a deadline skip");
                assert!(reason.contains("open image"), "{reason}");
            }
            other => panic!("expected typed ShardFailed, got {other:?}"),
        }
    }

    #[cfg(unix)]
    #[test]
    fn shm_plane_matches_the_file_plane_bit_for_bit() {
        let (img, img_path) = spill_image(17, 13, 5, 91);
        let out_path = std::env::temp_dir()
            .join(format!("inthist-proc-test-shmcmp-{}.bin", std::process::id()));
        let (nrows, row0, nbins, w) = (9usize, 4usize, 3usize, 13usize);
        let strip_bytes = nrows * w * 4;
        let partial_bytes = nbins * nrows * w * 4;

        // Ring with one slot: supervisor-side write of the strip bytes.
        let dir = crate::proc::shm::default_dir().unwrap_or_else(std::env::temp_dir);
        let mut ring =
            ShmRing::create(&dir, "worker-ut", 1, strip_bytes + partial_bytes).expect("ring");
        let slot = ring.acquire().expect("free slot");
        let mut strip_raw = Vec::with_capacity(strip_bytes);
        for r in row0..row0 + nrows {
            for c in 0..w {
                strip_raw.extend_from_slice(&(img.data[r * w + c] as f32).to_le_bytes());
            }
        }
        ring.write(slot, 0, &strip_raw);

        let base = WireAssign {
            frame_id: 9,
            shard_id: 1,
            bin0: 1,
            nbins: nbins as u64,
            row0: row0 as u64,
            nrows: nrows as u64,
            img_h: 17,
            img_w: w as u64,
            img_path: img_path.to_string_lossy().into_owned(),
            out_path: out_path.to_string_lossy().into_owned(),
            plane: PLANE_FILE,
            slot: 0,
            slot_off: 0,
            ring_bytes: 0,
            ring_path: String::new(),
            deadline_us: 0,
            strip_checksum: 0,
        };
        let shm_a = WireAssign {
            plane: PLANE_SHM,
            slot: slot as u64,
            slot_off: ring.slot_off(slot),
            ring_bytes: ring.ring_bytes() as u64,
            ring_path: ring.path().to_string_lossy().into_owned(),
            ..base.clone()
        };

        let mut engine = None;
        let mut rings = HashMap::new();
        let file_reply = execute_assign(&base, 1, &mut engine, &mut rings);
        let shm_reply = execute_assign(&shm_a, 1, &mut engine, &mut rings);
        let file_ck = match file_reply {
            ProcMsg::ShardDone { checksum, .. } => checksum,
            other => panic!("file plane: {other:?}"),
        };
        let (shm_ck, shm_slot) = match shm_reply {
            ProcMsg::ShardDone { checksum, slot, .. } => (checksum, slot),
            other => panic!("shm plane: {other:?}"),
        };
        assert_eq!(shm_ck, file_ck, "same payload checksum on both planes");
        assert_eq!(shm_slot, slot as u64, "reply names the slot it filled");

        // The slot's partial region holds the same bytes the file plane
        // committed to its out store.
        let store = TensorStore::open(&out_path, nbins, nrows, w).expect("open out");
        let file_hist = store.to_histogram().expect("read back");
        let mut slot_partial = vec![0u8; partial_bytes];
        ring.read(slot, strip_bytes, &mut slot_partial);
        let slot_f32: Vec<f32> = slot_partial
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        assert_eq!(slot_f32, file_hist.data, "ring partial bit-identical to out store");
        assert_eq!(checksum_f32(&slot_f32), shm_ck, "slot bytes match the wire checksum");

        std::fs::remove_file(&img_path).ok();
        std::fs::remove_file(&out_path).ok();
    }

    /// Feed [`serve`] from an in-memory channel so the test controls
    /// inter-frame timing exactly.
    struct ChanReader {
        rx: mpsc::Receiver<Vec<u8>>,
        buf: Vec<u8>,
        pos: usize,
    }

    impl Read for ChanReader {
        fn read(&mut self, out: &mut [u8]) -> std::io::Result<usize> {
            if self.pos >= self.buf.len() {
                match self.rx.recv() {
                    Ok(b) => {
                        self.buf = b;
                        self.pos = 0;
                    }
                    Err(_) => return Ok(0), // clean EOF
                }
            }
            let n = (self.buf.len() - self.pos).min(out.len());
            out[..n].copy_from_slice(&self.buf[self.pos..self.pos + n]);
            self.pos += n;
            Ok(n)
        }
    }

    fn serve_script(frames: Vec<(Vec<u8>, Duration)>, cfg: &WorkerConfig) -> Vec<ProcMsg> {
        let (tx, rx) = mpsc::channel();
        let out: Arc<Mutex<std::io::Cursor<Vec<u8>>>> =
            Arc::new(Mutex::new(std::io::Cursor::new(Vec::new())));
        let captured = Arc::clone(&out);
        let reader = ChanReader { rx, buf: Vec::new(), pos: 0 };
        let cfg = cfg.clone();
        let server = std::thread::spawn(move || serve(reader, captured, &cfg));
        for (bytes, pause) in frames {
            if !pause.is_zero() {
                std::thread::sleep(pause);
            }
            tx.send(bytes).expect("feed frame");
        }
        drop(tx); // EOF
        server.join().expect("serve thread").expect("serve exits clean");
        let raw = lock_recover(&out).get_ref().clone();
        let mut msgs = Vec::new();
        let mut r = &raw[..];
        while let Some(m) = ProcMsg::read_from(&mut r).expect("parse worker output") {
            msgs.push(m);
        }
        msgs
    }

    fn quiet_cfg() -> WorkerConfig {
        // Short heartbeat so the ticker join after EOF is prompt;
        // parsers below skip Heartbeat frames.
        WorkerConfig {
            calibrate: false,
            engine_workers: 1,
            heartbeat: Duration::from_millis(20),
            boot_delay: Duration::ZERO,
        }
    }

    fn stream_assign_for(img: &BinnedImage, deadline_us: u64) -> (WireAssign, Vec<u8>) {
        let mut strip_raw = Vec::new();
        for &v in &img.data {
            strip_raw.extend_from_slice(&(v as f32).to_le_bytes());
        }
        let a = WireAssign {
            frame_id: 3,
            shard_id: 1,
            bin0: 0,
            nbins: img.bins as u64,
            row0: 0,
            nrows: img.h as u64,
            img_h: img.h as u64,
            img_w: img.w as u64,
            img_path: String::new(),
            out_path: String::new(),
            plane: PLANE_STREAM,
            slot: 0,
            slot_off: 0,
            ring_bytes: 0,
            ring_path: String::new(),
            deadline_us,
            strip_checksum: checksum_bytes(&strip_raw),
        };
        (a, strip_raw)
    }

    /// Full stream-plane round trip through [`serve`]: assign + strip
    /// chunks in, partial chunks + `ShardDone` out, all bit-identical
    /// to the sequential oracle.
    #[test]
    fn serve_stream_plane_round_trips_bit_identical() {
        let mut rng = Xoshiro256::new(0xA11CE);
        let (h, w, bins) = (14usize, 11usize, 4usize);
        let mut data = vec![0i32; h * w];
        rng.fill_bins(&mut data, bins as u32);
        let img = BinnedImage::new(h, w, bins, data);
        let (a, strip_raw) = stream_assign_for(&img, 0);

        // Deliberately tiny chunks so reassembly is exercised.
        let mut frames = vec![(ProcMsg::AssignShard(a.clone()).encode(), Duration::ZERO)];
        let total = strip_raw.len() as u64;
        for (i, piece) in strip_raw.chunks(97).enumerate() {
            frames.push((
                ProcMsg::Chunk {
                    frame_id: a.frame_id,
                    shard_id: a.shard_id,
                    dir: 0,
                    offset: (i * 97) as u64,
                    total,
                    data: piece.to_vec(),
                }
                .encode(),
                Duration::ZERO,
            ));
        }
        let msgs = serve_script(frames, &quiet_cfg());

        let mut partial_buf = Vec::new();
        let mut done_ck = None;
        for m in msgs {
            match m {
                ProcMsg::Chunk { dir: 1, offset, data, .. } => {
                    assert_eq!(offset as usize, partial_buf.len(), "ordered partial chunks");
                    partial_buf.extend_from_slice(&data);
                }
                ProcMsg::ShardDone { checksum, slot, .. } => {
                    assert_eq!(slot, NO_SLOT);
                    done_ck = Some(checksum);
                }
                ProcMsg::ShardFailed { reason, .. } => panic!("unexpected failure: {reason}"),
                _ => {} // heartbeats, calibration
            }
        }
        let want = integral_histogram_seq(&img);
        let got: Vec<f32> = partial_buf
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        assert_eq!(got, want.data, "streamed partial bit-identical to oracle");
        assert_eq!(done_ck, Some(checksum_f32(&want.data)), "checksum covers the payload");
    }

    /// A strip whose bytes were corrupted in flight is rejected by the
    /// checksum before compute — typed, never silent.
    #[test]
    fn serve_rejects_corrupted_strip_checksum() {
        let img = BinnedImage::new(6, 5, 3, vec![1i32; 30]);
        let (a, mut strip_raw) = stream_assign_for(&img, 0);
        strip_raw[8] ^= 0x40; // flip one payload bit after checksumming
        let total = strip_raw.len() as u64;
        let frames = vec![
            (ProcMsg::AssignShard(a.clone()).encode(), Duration::ZERO),
            (
                ProcMsg::Chunk {
                    frame_id: a.frame_id,
                    shard_id: a.shard_id,
                    dir: 0,
                    offset: 0,
                    total,
                    data: strip_raw,
                }
                .encode(),
                Duration::ZERO,
            ),
        ];
        let msgs = serve_script(frames, &quiet_cfg());
        let failed = msgs.iter().find_map(|m| match m {
            ProcMsg::ShardFailed { deadline, reason, .. } => Some((*deadline, reason.clone())),
            _ => None,
        });
        let (deadline, reason) = failed.expect("corruption must fail typed");
        assert!(!deadline, "corruption is not a deadline skip");
        assert!(reason.contains("checksum"), "{reason}");
        assert!(
            !msgs.iter().any(|m| matches!(m, ProcMsg::ShardDone { .. })),
            "no completion for a corrupt strip"
        );
    }

    /// A deadline budget that burns down while the strip is still in
    /// flight makes the worker skip compute and flag the failure as a
    /// deadline skip — the supervisor charges `skipped_deadline`.
    #[test]
    fn serve_skips_shard_whose_budget_expired_in_transfer() {
        let img = BinnedImage::new(6, 5, 3, vec![1i32; 30]);
        // 1 ms budget, 60 ms transfer stall: unambiguously expired.
        let (a, strip_raw) = stream_assign_for(&img, 1_000);
        let total = strip_raw.len() as u64;
        let frames = vec![
            (ProcMsg::AssignShard(a.clone()).encode(), Duration::ZERO),
            (
                ProcMsg::Chunk {
                    frame_id: a.frame_id,
                    shard_id: a.shard_id,
                    dir: 0,
                    offset: 0,
                    total,
                    data: strip_raw,
                }
                .encode(),
                Duration::from_millis(60),
            ),
        ];
        let msgs = serve_script(frames, &quiet_cfg());
        let failed = msgs.iter().find_map(|m| match m {
            ProcMsg::ShardFailed { deadline, reason, .. } => Some((*deadline, reason.clone())),
            _ => None,
        });
        let (deadline, reason) = failed.expect("expired budget must fail");
        assert!(deadline, "flagged as a deadline skip: {reason}");
        assert!(
            !msgs.iter().any(|m| matches!(m, ProcMsg::ShardDone { .. })),
            "no completion for a skipped shard"
        );
    }

    #[test]
    fn deadline_expired_anchors_at_arrival() {
        let now = Instant::now();
        assert!(!deadline_expired(0, now - Duration::from_secs(10)), "0 = no deadline");
        assert!(deadline_expired(1_000, now - Duration::from_secs(10)), "stale arrival expired");
        assert!(!deadline_expired(u64::MAX, now), "huge budget never expires");
    }
}
