//! Child-link transports for the proc plane: the supervisor speaks
//! the same length-prefixed [`protocol`](crate::proc::protocol) frames
//! whether the worker hangs off a pipe pair or a TCP socket.
//!
//! [`PipeTransport`] owns a spawned local child and its stdin;
//! [`SocketTransport`] owns a connected stream to a `proc-worker
//! --listen` process that may live on another host.  Both hand their
//! read half to the supervisor's per-node reader thread at
//! construction, so the trait only carries the write half plus the
//! lifecycle verbs the dispatcher needs: `kill`, `reap`, a
//! non-blocking death probe and a graceful-exit wait.
//!
//! **Handshake.**  A socket link starts with a [`ProcMsg::Hello`]
//! exchange — the worker announces first on `accept`, the supervisor
//! validates protocol-version overlap plus required capability bits
//! ([`CAP_STREAM`], [`CAP_DEADLINE`]) and replies.  Pipes skip the
//! handshake: both ends are the same build by construction.

use super::protocol::{ProcMsg, CAPS_ALL, CAP_DEADLINE, CAP_STREAM, PROTOCOL_VERSION};
use anyhow::{anyhow, bail, Context, Result};
use std::io::{Read, Write};
use std::net::{Shutdown, TcpStream, ToSocketAddrs};
use std::process::{Child, ChildStdin};
use std::time::{Duration, Instant};

/// One child byte-stream link, write half + lifecycle.  The read half
/// is split off at construction and lives in the reader thread.
pub trait Transport: Send {
    /// The frame sink toward the worker.
    fn writer(&mut self) -> &mut dyn Write;
    /// Force-disconnect: SIGKILL a local child, shut down a socket.
    fn kill(&mut self);
    /// Release OS resources after `kill` (reap a zombie; no-op for
    /// sockets).
    fn reap(&mut self);
    /// Non-blocking death probe.  Pipes can observe child exit
    /// directly; sockets report death through reader EOF instead, so
    /// they always answer `false` here.
    fn exited(&mut self) -> bool;
    /// Wait until `deadline` for a voluntary exit after `Shutdown`,
    /// then force the link down.
    fn wait_exit(&mut self, deadline: Instant);
    /// Human-readable peer identity for error text.
    fn describe(&self) -> String;
    /// `true` when the worker is not a local child process.
    fn is_remote(&self) -> bool;
}

/// Local child over its stdin/stdout pipe pair (stdout already moved
/// to the reader thread).
pub struct PipeTransport {
    child: Child,
    stdin: ChildStdin,
}

impl PipeTransport {
    pub fn new(child: Child, stdin: ChildStdin) -> Self {
        PipeTransport { child, stdin }
    }
}

impl Transport for PipeTransport {
    fn writer(&mut self) -> &mut dyn Write {
        &mut self.stdin
    }

    fn kill(&mut self) {
        let _ = self.child.kill();
    }

    fn reap(&mut self) {
        let _ = self.child.wait();
    }

    fn exited(&mut self) -> bool {
        matches!(self.child.try_wait(), Ok(Some(_)))
    }

    fn wait_exit(&mut self, deadline: Instant) {
        loop {
            match self.child.try_wait() {
                Ok(Some(_)) => return,
                Ok(None) if Instant::now() < deadline => {
                    std::thread::sleep(Duration::from_millis(10));
                }
                _ => {
                    let _ = self.child.kill();
                    let _ = self.child.wait();
                    return;
                }
            }
        }
    }

    fn describe(&self) -> String {
        format!("local child pid {}", self.child.id())
    }

    fn is_remote(&self) -> bool {
        false
    }
}

/// Remote worker over TCP.  Death is observed as reader EOF; `kill`
/// is a bidirectional shutdown that forces that EOF promptly.
pub struct SocketTransport {
    stream: TcpStream,
    peer: String,
}

impl Transport for SocketTransport {
    fn writer(&mut self) -> &mut dyn Write {
        &mut self.stream
    }

    fn kill(&mut self) {
        let _ = self.stream.shutdown(Shutdown::Both);
    }

    fn reap(&mut self) {}

    fn exited(&mut self) -> bool {
        false
    }

    fn wait_exit(&mut self, _deadline: Instant) {
        let _ = self.stream.shutdown(Shutdown::Both);
    }

    fn describe(&self) -> String {
        format!("remote worker {}", self.peer)
    }

    fn is_remote(&self) -> bool {
        true
    }
}

/// Connect to a `proc-worker --listen` endpoint and run the v3
/// handshake.  Returns the write-half transport and the read half for
/// the caller's reader thread.  Every failure is typed: unreachable
/// address, handshake timeout, version skew and missing capabilities
/// all surface as errors, never as a wedged dispatcher.
pub fn connect_remote(
    addr: &str,
    timeout: Duration,
    tag: &str,
) -> Result<(SocketTransport, Box<dyn Read + Send>)> {
    let sockaddr = addr
        .to_socket_addrs()
        .with_context(|| format!("resolve remote worker address {addr:?}"))?
        .next()
        .ok_or_else(|| anyhow!("remote worker address {addr:?} resolves to nothing"))?;
    let stream = TcpStream::connect_timeout(&sockaddr, timeout)
        .with_context(|| format!("connect to remote worker {addr}"))?;
    stream.set_nodelay(true).ok();
    // The handshake is the only read on this half; a peer that
    // connects but never speaks must not wedge the dispatcher.
    stream
        .set_read_timeout(Some(timeout))
        .context("arm handshake read timeout")?;
    let mut reader = stream.try_clone().context("clone socket read half")?;
    // The worker speaks first on accept.
    match ProcMsg::read_from(&mut reader) {
        Ok(Some(ProcMsg::Hello { version, caps, tag: peer_tag })) => {
            if caps & CAP_STREAM == 0 || caps & CAP_DEADLINE == 0 {
                bail!(
                    "remote worker {addr} ({peer_tag}, protocol v{version}) lacks required \
                     capabilities (caps {caps:#x})"
                );
            }
        }
        Ok(other) => bail!("remote worker {addr} handshake: expected Hello, got {other:?}"),
        Err(e) => bail!("remote worker {addr} handshake: {e}"),
    }
    {
        let mut w = &stream;
        ProcMsg::Hello { version: PROTOCOL_VERSION, caps: CAPS_ALL, tag: tag.to_string() }
            .write_to(&mut w)
            .with_context(|| format!("send handshake reply to {addr}"))?;
        w.flush().ok();
    }
    stream.set_read_timeout(None).context("disarm handshake read timeout")?;
    Ok((SocketTransport { stream, peer: addr.to_string() }, Box::new(reader)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    /// A peer that sends garbage instead of a Hello is rejected with a
    /// typed error, and a silent peer trips the handshake timeout.
    #[test]
    fn handshake_rejects_garbage_and_silence() {
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
        let addr = listener.local_addr().expect("addr").to_string();
        let server = std::thread::spawn(move || {
            // First connection: garbage banner.
            let (mut s, _) = listener.accept().expect("accept");
            s.write_all(b"HTTP/1.1 200 OK\r\n\r\n").ok();
            // Second connection: say nothing until the client gives up.
            let (s2, _) = listener.accept().expect("accept");
            std::thread::sleep(Duration::from_millis(400));
            drop(s2);
            drop(s);
        });
        let err = connect_remote(&addr, Duration::from_millis(200), "test")
            .expect_err("garbage banner must fail");
        assert!(err.to_string().contains("handshake"), "typed handshake error: {err:#}");
        let err = connect_remote(&addr, Duration::from_millis(200), "test")
            .expect_err("silent peer must time out");
        assert!(err.to_string().contains("handshake"), "typed timeout error: {err:#}");
        server.join().expect("server thread");
    }

    /// A peer advertising no stream capability is refused even when it
    /// speaks valid protocol frames.
    #[test]
    fn handshake_requires_stream_capability() {
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
        let addr = listener.local_addr().expect("addr").to_string();
        let server = std::thread::spawn(move || {
            let (s, _) = listener.accept().expect("accept");
            let mut w = &s;
            ProcMsg::Hello { version: PROTOCOL_VERSION, caps: 0, tag: "legacy".into() }
                .write_to(&mut w)
                .expect("send hello");
            w.flush().ok();
            std::thread::sleep(Duration::from_millis(100));
            drop(s);
        });
        let err = connect_remote(&addr, Duration::from_millis(500), "test")
            .expect_err("capability-less peer must be refused");
        assert!(err.to_string().contains("capabilities"), "typed caps error: {err:#}");
        server.join().expect("server thread");
    }

    /// Unreachable addresses fail typed and promptly.
    #[test]
    fn connect_to_dead_endpoint_errors_typed() {
        // Bind then drop to get a port nobody is listening on.
        let addr = {
            let l = TcpListener::bind("127.0.0.1:0").expect("bind");
            l.local_addr().expect("addr").to_string()
        };
        let err = connect_remote(&addr, Duration::from_millis(300), "test")
            .expect_err("dead endpoint must fail");
        assert!(err.to_string().contains("connect"), "typed connect error: {err:#}");
    }
}
