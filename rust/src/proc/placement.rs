//! Per-node calibrated placement for the proc plane.
//!
//! Each child process runs the `Calibrator` startup microbench on the
//! node it actually landed on and reports its [`CostSnapshot`] over
//! the control protocol.  This module turns those per-child reports
//! into a shard plan sized for the *aggregate* pool and a per-shard
//! child assignment weighted by each child's measured throughput
//! (LPT greedy — see [`ShardPlanner::plan_per_node`]).
//!
//! Children that have not (yet) reported — still calibrating, or
//! freshly respawned after a death — take no part in sizing; the
//! planner places shards across the calibrated subset and the
//! supervisor's soft-affinity dispatch spreads overflow onto the
//! rest.  With *zero* reports the whole plan degrades to the static
//! prior on child 0, so cold start is never blocked on calibration.

use crate::shard::{ShardPlan, ShardPlanner};
use crate::tune::CostSnapshot;

/// A per-shard child assignment plus how many children informed it.
#[derive(Debug, Clone)]
pub struct PlacementMap {
    /// `assignment[i]` = child index that should run `plan.shards[i]`
    /// (soft affinity — the supervisor falls back when that child is
    /// dead or saturated).
    pub assignment: Vec<usize>,
    /// Children whose measured snapshot informed the placement.
    pub calibrated_nodes: usize,
}

/// Size a plan for the pool described by `snaps` (one entry per child,
/// `None` = not yet calibrated) and assign each shard a child.
///
/// The planner works over the *calibrated* children only; the returned
/// assignment maps its compact node indices back to real child
/// indices, skipping uncalibrated gaps.
pub fn plan_for_nodes(
    planner: &ShardPlanner,
    bins: usize,
    h: usize,
    w: usize,
    snaps: &[Option<CostSnapshot>],
) -> (ShardPlan, PlacementMap) {
    // Compact the calibrated children: child_of[k] = child index of
    // the planner's node k.
    let child_of: Vec<usize> =
        snaps.iter().enumerate().filter(|(_, s)| s.is_some()).map(|(i, _)| i).collect();
    let measured: Vec<CostSnapshot> = snaps.iter().filter_map(|s| *s).collect();
    let (plan, nodes) = planner.plan_per_node(bins, h, w, &measured);
    let assignment: Vec<usize> = nodes
        .into_iter()
        .map(|k| child_of.get(k).copied().unwrap_or(0))
        .collect();
    (
        plan,
        PlacementMap { assignment, calibrated_nodes: child_of.len() },
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::shard::ShardPolicy;
    use crate::tune::calibrate::Calibrator;

    fn snap(scale: f64) -> CostSnapshot {
        let mut s = Calibrator::default().snapshot();
        for t in s.tile_throughput.iter_mut() {
            *t *= scale;
        }
        for t in s.tile_throughput_tuned.iter_mut() {
            *t *= scale;
        }
        s.samples = 1;
        s
    }

    fn planner() -> ShardPlanner {
        ShardPlanner::new(ShardPolicy { workers: 4, ..Default::default() })
    }

    #[test]
    fn gaps_map_back_to_real_child_indices() {
        // Children 0 and 2 calibrated; child 1 still booting.
        let snaps = vec![Some(snap(1.0)), None, Some(snap(1.0))];
        let (plan, map) = plan_for_nodes(&planner(), 24, 96, 80, &snaps);
        assert_eq!(map.calibrated_nodes, 2);
        assert_eq!(map.assignment.len(), plan.shards.len());
        for &c in &map.assignment {
            assert!(c == 0 || c == 2, "child 1 is uncalibrated, got {c}");
        }
        assert!(map.assignment.iter().any(|&c| c == 0));
        assert!(map.assignment.iter().any(|&c| c == 2));
    }

    #[test]
    fn no_snapshots_degrades_to_child_zero() {
        let snaps: Vec<Option<CostSnapshot>> = vec![None, None];
        let (plan, map) = plan_for_nodes(&planner(), 16, 64, 64, &snaps);
        assert!(!plan.shards.is_empty());
        assert_eq!(map.calibrated_nodes, 0);
        assert!(map.assignment.iter().all(|&c| c == 0));
    }

    #[test]
    fn placement_is_deterministic() {
        let snaps = vec![Some(snap(1.0)), Some(snap(3.0))];
        let a = plan_for_nodes(&planner(), 32, 128, 96, &snaps);
        let b = plan_for_nodes(&planner(), 32, 128, 96, &snaps);
        assert_eq!(a.1.assignment, b.1.assignment);
        assert_eq!(a.0.shards.len(), b.0.shards.len());
    }
}
