//! The proc-plane control protocol: versioned, length-prefixed binary
//! frames over a child's stdin/stdout pipes.
//!
//! **Control only.**  The messages here carry assignments, completions,
//! heartbeats and calibration snapshots — kilobytes.  Bulk tensor data
//! never crosses a pipe.  On the **file plane** (v1) the supervisor
//! spills the binned image to a
//! [`TensorStore`](crate::shard::TensorStore) file, the child writes
//! its partial tensor to another, and the protocol exchanges *paths*.
//! On the **shared-memory plane** (v2, [`crate::proc::shm`]) the
//! assignment instead names a ring slot — `(ring_path, ring_bytes,
//! slot, slot_off)` — whose interior holds the input strip and, after
//! compute, the partial written in place.  Either way a payload
//! checksum rides the control frame, because the store's per-row
//! checksums live in the writer's RAM and cannot follow the bytes
//! across the process boundary.
//!
//! On the **stream plane** (v3, remote workers) no filesystem is
//! shared at all: the supervisor pushes the input strip as bounded
//! [`ProcMsg::Chunk`] frames over the same connection, the worker
//! pulls the partial back the same way, and both directions carry the
//! FNV-1a checksum of the full payload.
//!
//! **Versioning.**  Versions 2 and 3 are minor bumps: the v2 payloads
//! are the v1 layouts with the data-plane fields appended, v3 appends
//! the deadline budget / stream-plane fields and adds the `Chunk` and
//! `Hello` frames, and this side still *decodes* v1/v2 frames (as
//! file-/shm-plane assignments with no deadline) so a mixed-version
//! link fails soft, not weird.  Writers always emit the current
//! version.
//!
//! **Wire format.**  Every frame is
//!
//! ```text
//! [magic u16 LE][version u16 LE][type u8][len u32 LE][payload: len bytes]
//! ```
//!
//! All integers little-endian and fixed-width; strings are a `u32`
//! length followed by UTF-8 bytes.  Decoding is total: truncated
//! frames, foreign magic, version skew, oversized lengths and unknown
//! type bytes all land in a typed [`ProtocolError`] — never a panic,
//! never UB, never a partial message acted upon (fuzzed in the module
//! tests and pre-validated in
//! `python/tests/test_proc_prevalidation.py`).

use crate::tune::CostSnapshot;
use std::io::{Read, Write};

/// "IH" — rejects garbage on the pipe before any length is trusted.
pub const PROTOCOL_MAGIC: u16 = 0x4948;
/// Bumped on any wire-format change.  v2 added the shared-memory
/// data-plane fields to `AssignShard`/`ShardDone`; v3 added the
/// deadline budget, the chunked stream plane and the `Hello`
/// handshake; frames down to [`PROTOCOL_VERSION_MIN`] still decode
/// (minor bumps).
pub const PROTOCOL_VERSION: u16 = 3;
/// Oldest version this side still decodes (v1 = file-plane payloads).
pub const PROTOCOL_VERSION_MIN: u16 = 1;
/// `WireAssign::plane` — spill-file data plane (v1 behaviour).
pub const PLANE_FILE: u8 = 0;
/// `WireAssign::plane` — shared-memory ring slot data plane.
pub const PLANE_SHM: u8 = 1;
/// `WireAssign::plane` — chunked in-band stream data plane (v3,
/// remote workers: no shared filesystem, no shared memory).
pub const PLANE_STREAM: u8 = 2;
/// Largest `Chunk::data` a well-formed peer sends.  Keeps any single
/// frame well under [`MAX_PAYLOAD`] and bounds per-frame latency so
/// heartbeats interleave with bulk transfer.
pub const CHUNK_DATA_MAX: u32 = 256 * 1024;
/// `Hello::caps` bit: peer speaks the chunked stream data plane.
pub const CAP_STREAM: u32 = 1;
/// `Hello::caps` bit: peer honours wire deadline budgets.
pub const CAP_DEADLINE: u32 = 2;
/// Every capability this build implements.
pub const CAPS_ALL: u32 = CAP_STREAM | CAP_DEADLINE;
/// `ShardDone::slot` value meaning "no ring slot" (file plane / v1).
pub const NO_SLOT: u64 = u64::MAX;
/// Control frames are small; anything bigger than this is a corrupt
/// length field, not a message worth buffering.
pub const MAX_PAYLOAD: u32 = 1 << 20;
/// Frame header bytes (magic + version + type + len).
pub const HEADER_LEN: usize = 9;

/// Typed protocol failure — the complete decode error surface.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ProtocolError {
    /// The stream ended inside a frame (header or payload).
    Truncated,
    /// First two bytes were not [`PROTOCOL_MAGIC`].
    BadMagic { got: u16 },
    /// Frame speaks a different protocol version.
    VersionMismatch { got: u16 },
    /// Declared payload length exceeds [`MAX_PAYLOAD`].
    Oversized { len: u32 },
    /// Type byte names no known message.
    UnknownType { ty: u8 },
    /// Payload failed structural validation (bad lengths, non-UTF-8
    /// strings, trailing bytes, value out of range).
    Malformed(String),
    /// The underlying pipe failed (kind carried as text; `io::Error`
    /// is not `Clone`/`PartialEq`).
    Io(String),
}

impl std::fmt::Display for ProtocolError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ProtocolError::Truncated => write!(f, "truncated protocol frame"),
            ProtocolError::BadMagic { got } => write!(f, "bad protocol magic {got:#06x}"),
            ProtocolError::VersionMismatch { got } => {
                write!(
                    f,
                    "protocol version {got} (this side speaks \
                     {PROTOCOL_VERSION_MIN}..={PROTOCOL_VERSION})"
                )
            }
            ProtocolError::Oversized { len } => {
                write!(f, "payload length {len} exceeds cap {MAX_PAYLOAD}")
            }
            ProtocolError::UnknownType { ty } => write!(f, "unknown message type {ty}"),
            ProtocolError::Malformed(why) => write!(f, "malformed payload: {why}"),
            ProtocolError::Io(why) => write!(f, "pipe error: {why}"),
        }
    }
}

impl std::error::Error for ProtocolError {}

impl From<std::io::Error> for ProtocolError {
    fn from(e: std::io::Error) -> ProtocolError {
        if e.kind() == std::io::ErrorKind::UnexpectedEof {
            ProtocolError::Truncated
        } else {
            ProtocolError::Io(e.to_string())
        }
    }
}

/// A shard assignment as it travels the wire — mirrors
/// [`ShardSpec`](crate::shard::ShardSpec) plus the frame geometry and
/// the two data-plane paths.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WireAssign {
    pub frame_id: u64,
    pub shard_id: u64,
    pub bin0: u64,
    pub nbins: u64,
    pub row0: u64,
    pub nrows: u64,
    /// Full-image geometry (the image store is `1×h×w`).
    pub img_h: u64,
    pub img_w: u64,
    /// Spilled binned image (bin indices as f32, Fig. 2 layout).
    /// Empty on the shm plane — the strip is already in the slot.
    pub img_path: String,
    /// Where the child must leave its `nbins×nrows×w` partial
    /// ([`PLANE_FILE`] only; empty on the shm plane).
    pub out_path: String,
    /// Data plane: [`PLANE_FILE`] or [`PLANE_SHM`] (v2; v1 frames
    /// decode as [`PLANE_FILE`]).
    pub plane: u8,
    /// Ring slot index ([`PLANE_SHM`] only).
    pub slot: u64,
    /// Byte offset of the slot within the ring.  The input strip
    /// (`nrows×img_w` f32 LE) starts here; the partial
    /// (`nbins×nrows×img_w` f32 LE) is written in place directly
    /// after it.
    pub slot_off: u64,
    /// Total mapped ring size — the child validates `slot_off + strip
    /// + partial` against this *and* against the ring file's real
    /// length before touching the mapping.
    pub ring_bytes: u64,
    /// Ring file to `mmap` ([`PLANE_SHM`] only).
    pub ring_path: String,
    /// Remaining deadline budget in microseconds at dispatch time;
    /// `0` = no deadline.  A *duration*, never an instant — wall
    /// clocks and `Instant` epochs do not agree across process or
    /// host boundaries (v3; v1/v2 frames decode as `0`).
    pub deadline_us: u64,
    /// FNV-1a checksum of the input strip bytes ([`PLANE_STREAM`]
    /// only — the worker verifies the assembled strip before compute).
    pub strip_checksum: u32,
}

impl WireAssign {
    /// Input strip bytes (`nrows × img_w` f32 LE).  `None` on overflow
    /// — decode rejects such frames as malformed.
    pub fn strip_bytes(&self) -> Option<u64> {
        self.nrows.checked_mul(self.img_w)?.checked_mul(4)
    }

    /// Partial tensor bytes (`nbins × nrows × img_w` f32 LE).
    pub fn partial_bytes(&self) -> Option<u64> {
        self.nbins.checked_mul(self.nrows)?.checked_mul(self.img_w)?.checked_mul(4)
    }
}

/// One control-plane message.
#[derive(Debug, Clone, PartialEq)]
pub enum ProcMsg {
    /// Parent → child: compute one shard.
    AssignShard(WireAssign),
    /// Child → parent: shard done; partial at `AssignShard.out_path`
    /// (file plane) or in ring slot `slot` ([`NO_SLOT`] = file plane),
    /// `checksum` = FNV-1a over its f32 LE bytes — computed over the
    /// ring-slot bytes on the shm plane, the file payload otherwise.
    ShardDone { frame_id: u64, shard_id: u64, kernel_time_us: u64, checksum: u32, slot: u64 },
    /// Child → parent: one compute attempt failed (the *supervisor*
    /// owns the retry budget).  `deadline` marks a shard the worker
    /// skipped pre-compute because its wire budget had already
    /// expired — not a compute failure, so the supervisor charges it
    /// to `skipped_deadline`, not to the retry ladder (v3; v1/v2
    /// frames decode as `false`).
    ShardFailed { frame_id: u64, shard_id: u64, panicked: bool, deadline: bool, reason: String },
    /// Child → parent: liveness tick.
    Heartbeat { seq: u64 },
    /// Child → parent, once at startup: this node's measured costs.
    CalibrationReport { snapshot: CostSnapshot },
    /// Parent → child: drain and exit cleanly.
    Shutdown,
    /// Bulk payload chunk on the stream plane (v3).  `dir` 0 = input
    /// strip parent→child, 1 = partial child→parent; chunks arrive in
    /// offset order and `data` is capped at [`CHUNK_DATA_MAX`].
    Chunk { frame_id: u64, shard_id: u64, dir: u8, offset: u64, total: u64, data: Vec<u8> },
    /// Socket handshake (v3): each side announces its protocol
    /// version and capability bits before any work flows.  The worker
    /// speaks first on `accept`; the supervisor replies after
    /// validating version overlap and required capabilities.
    Hello { version: u16, caps: u32, tag: String },
}

const TY_ASSIGN: u8 = 1;
const TY_DONE: u8 = 2;
const TY_FAILED: u8 = 3;
const TY_HEARTBEAT: u8 = 4;
const TY_CALIBRATION: u8 = 5;
const TY_SHUTDOWN: u8 = 6;
const TY_CHUNK: u8 = 7;
const TY_HELLO: u8 = 8;

/// FNV-1a over a raw byte slice — the cross-process payload checksum
/// (the store's per-row sums stay in the writer's RAM, so integrity
/// must ride the control message).
pub fn checksum_bytes(data: &[u8]) -> u32 {
    let mut h: u32 = 0x811C_9DC5;
    for &b in data {
        h ^= b as u32;
        h = h.wrapping_mul(0x0100_0193);
    }
    h
}

/// [`checksum_bytes`] over the LE bytes of an f32 slice — identical
/// to hashing the raw on-wire representation of the tensor.
pub fn checksum_f32(data: &[f32]) -> u32 {
    let mut h: u32 = 0x811C_9DC5;
    for v in data {
        for b in v.to_le_bytes() {
            h ^= b as u32;
            h = h.wrapping_mul(0x0100_0193);
        }
    }
    h
}

/// Bounded cursor over a payload: every read is range-checked, so a
/// hostile payload can only produce a typed error.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], ProtocolError> {
        if self.pos + n > self.buf.len() {
            return Err(ProtocolError::Truncated);
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32, ProtocolError> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64, ProtocolError> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes(b.try_into().expect("8 bytes")))
    }

    fn f64(&mut self) -> Result<f64, ProtocolError> {
        Ok(f64::from_bits(self.u64()?))
    }

    fn string(&mut self) -> Result<String, ProtocolError> {
        let len = self.u32()? as usize;
        if len > MAX_PAYLOAD as usize {
            return Err(ProtocolError::Malformed(format!("string length {len}")));
        }
        let bytes = self.take(len)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| ProtocolError::Malformed("non-UTF-8 string".into()))
    }

    fn done(&self) -> Result<(), ProtocolError> {
        if self.pos == self.buf.len() {
            Ok(())
        } else {
            Err(ProtocolError::Malformed(format!(
                "{} trailing payload bytes",
                self.buf.len() - self.pos
            )))
        }
    }
}

fn put_string(out: &mut Vec<u8>, s: &str) {
    out.extend_from_slice(&(s.len() as u32).to_le_bytes());
    out.extend_from_slice(s.as_bytes());
}

impl ProcMsg {
    fn type_byte(&self) -> u8 {
        match self {
            ProcMsg::AssignShard(_) => TY_ASSIGN,
            ProcMsg::ShardDone { .. } => TY_DONE,
            ProcMsg::ShardFailed { .. } => TY_FAILED,
            ProcMsg::Heartbeat { .. } => TY_HEARTBEAT,
            ProcMsg::CalibrationReport { .. } => TY_CALIBRATION,
            ProcMsg::Shutdown => TY_SHUTDOWN,
            ProcMsg::Chunk { .. } => TY_CHUNK,
            ProcMsg::Hello { .. } => TY_HELLO,
        }
    }

    fn payload(&self) -> Vec<u8> {
        let mut p = Vec::new();
        match self {
            ProcMsg::AssignShard(a) => {
                for v in [a.frame_id, a.shard_id, a.bin0, a.nbins, a.row0, a.nrows, a.img_h, a.img_w]
                {
                    p.extend_from_slice(&v.to_le_bytes());
                }
                put_string(&mut p, &a.img_path);
                put_string(&mut p, &a.out_path);
                // v2 data-plane tail (appended so the v1 prefix layout
                // is unchanged).
                p.push(a.plane);
                p.extend_from_slice(&a.slot.to_le_bytes());
                p.extend_from_slice(&a.slot_off.to_le_bytes());
                p.extend_from_slice(&a.ring_bytes.to_le_bytes());
                put_string(&mut p, &a.ring_path);
                // v3 tail: deadline budget + stream-plane strip checksum.
                p.extend_from_slice(&a.deadline_us.to_le_bytes());
                p.extend_from_slice(&a.strip_checksum.to_le_bytes());
            }
            ProcMsg::ShardDone { frame_id, shard_id, kernel_time_us, checksum, slot } => {
                p.extend_from_slice(&frame_id.to_le_bytes());
                p.extend_from_slice(&shard_id.to_le_bytes());
                p.extend_from_slice(&kernel_time_us.to_le_bytes());
                p.extend_from_slice(&checksum.to_le_bytes());
                p.extend_from_slice(&slot.to_le_bytes());
            }
            ProcMsg::ShardFailed { frame_id, shard_id, panicked, deadline, reason } => {
                p.extend_from_slice(&frame_id.to_le_bytes());
                p.extend_from_slice(&shard_id.to_le_bytes());
                p.push(u8::from(*panicked));
                put_string(&mut p, reason);
                // v3 tail: deadline-skip marker.
                p.push(u8::from(*deadline));
            }
            ProcMsg::Heartbeat { seq } => p.extend_from_slice(&seq.to_le_bytes()),
            ProcMsg::CalibrationReport { snapshot } => {
                p.extend_from_slice(&snapshot.memcpy_bps.to_bits().to_le_bytes());
                for t in snapshot.tile_throughput.iter().chain(snapshot.tile_throughput_tuned.iter())
                {
                    p.extend_from_slice(&t.to_bits().to_le_bytes());
                }
                p.extend_from_slice(&snapshot.dispatch_overhead_s.to_bits().to_le_bytes());
                p.extend_from_slice(&snapshot.spill_read_latency_s.to_bits().to_le_bytes());
                p.extend_from_slice(&snapshot.spill_read_bps.to_bits().to_le_bytes());
                p.extend_from_slice(&snapshot.samples.to_le_bytes());
            }
            ProcMsg::Shutdown => {}
            ProcMsg::Chunk { frame_id, shard_id, dir, offset, total, data } => {
                p.extend_from_slice(&frame_id.to_le_bytes());
                p.extend_from_slice(&shard_id.to_le_bytes());
                p.push(*dir);
                p.extend_from_slice(&offset.to_le_bytes());
                p.extend_from_slice(&total.to_le_bytes());
                p.extend_from_slice(&(data.len() as u32).to_le_bytes());
                p.extend_from_slice(data);
            }
            ProcMsg::Hello { version, caps, tag } => {
                p.extend_from_slice(&version.to_le_bytes());
                p.extend_from_slice(&caps.to_le_bytes());
                put_string(&mut p, tag);
            }
        }
        p
    }

    /// Encode one complete frame (header + payload).
    pub fn encode(&self) -> Vec<u8> {
        let payload = self.payload();
        debug_assert!(payload.len() <= MAX_PAYLOAD as usize);
        let mut out = Vec::with_capacity(HEADER_LEN + payload.len());
        out.extend_from_slice(&PROTOCOL_MAGIC.to_le_bytes());
        out.extend_from_slice(&PROTOCOL_VERSION.to_le_bytes());
        out.push(self.type_byte());
        out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        out.extend_from_slice(&payload);
        out
    }

    /// Decode one frame from `buf`, returning the message and the
    /// bytes consumed.  Total: every failure is a typed error.
    pub fn decode(buf: &[u8]) -> Result<(ProcMsg, usize), ProtocolError> {
        if buf.len() < HEADER_LEN {
            return Err(ProtocolError::Truncated);
        }
        let magic = u16::from_le_bytes([buf[0], buf[1]]);
        if magic != PROTOCOL_MAGIC {
            return Err(ProtocolError::BadMagic { got: magic });
        }
        let version = u16::from_le_bytes([buf[2], buf[3]]);
        if !(PROTOCOL_VERSION_MIN..=PROTOCOL_VERSION).contains(&version) {
            return Err(ProtocolError::VersionMismatch { got: version });
        }
        let ty = buf[4];
        let len = u32::from_le_bytes([buf[5], buf[6], buf[7], buf[8]]);
        if len > MAX_PAYLOAD {
            return Err(ProtocolError::Oversized { len });
        }
        let len = len as usize;
        if buf.len() < HEADER_LEN + len {
            return Err(ProtocolError::Truncated);
        }
        let msg = Self::decode_payload(ty, version, &buf[HEADER_LEN..HEADER_LEN + len])?;
        Ok((msg, HEADER_LEN + len))
    }

    fn decode_payload(ty: u8, version: u16, payload: &[u8]) -> Result<ProcMsg, ProtocolError> {
        let mut c = Cursor { buf: payload, pos: 0 };
        let msg = match ty {
            TY_ASSIGN => {
                let frame_id = c.u64()?;
                let shard_id = c.u64()?;
                let bin0 = c.u64()?;
                let nbins = c.u64()?;
                let row0 = c.u64()?;
                let nrows = c.u64()?;
                let img_h = c.u64()?;
                let img_w = c.u64()?;
                let img_path = c.string()?;
                let out_path = c.string()?;
                // v1 frames stop here and are file-plane by definition.
                let (plane, slot, slot_off, ring_bytes, ring_path) = if version >= 2 {
                    let plane = c.take(1)?[0];
                    (plane, c.u64()?, c.u64()?, c.u64()?, c.string()?)
                } else {
                    (PLANE_FILE, 0, 0, 0, String::new())
                };
                // v2 frames stop here: no deadline, no stream plane.
                let (deadline_us, strip_checksum) =
                    if version >= 3 { (c.u64()?, c.u32()?) } else { (0, 0) };
                if nbins == 0 || nrows == 0 || img_h == 0 || img_w == 0 {
                    return Err(ProtocolError::Malformed("degenerate shard geometry".into()));
                }
                if row0.checked_add(nrows).map_or(true, |end| end > img_h) {
                    return Err(ProtocolError::Malformed("shard strip past image".into()));
                }
                let a = WireAssign {
                    frame_id,
                    shard_id,
                    bin0,
                    nbins,
                    row0,
                    nrows,
                    img_h,
                    img_w,
                    img_path,
                    out_path,
                    plane,
                    slot,
                    slot_off,
                    ring_bytes,
                    ring_path,
                    deadline_us,
                    strip_checksum,
                };
                match a.plane {
                    PLANE_FILE => {}
                    PLANE_STREAM => {
                        if version < 3 {
                            return Err(ProtocolError::Malformed(
                                "stream plane needs protocol v3".into(),
                            ));
                        }
                        // The strip/partial sizes drive buffer
                        // allocation on both ends — overflow is
                        // malformed, not UB.
                        if a.strip_bytes().zip(a.partial_bytes()).is_none() {
                            return Err(ProtocolError::Malformed(
                                "stream payload size overflows".into(),
                            ));
                        }
                    }
                    PLANE_SHM => {
                        // A hostile/corrupt slot geometry must never
                        // reach the child's mmap arithmetic.
                        if a.ring_path.is_empty() {
                            return Err(ProtocolError::Malformed("shm assign without ring".into()));
                        }
                        let need = a
                            .strip_bytes()
                            .zip(a.partial_bytes())
                            .and_then(|(s, p)| s.checked_add(p))
                            .and_then(|n| n.checked_add(a.slot_off));
                        match need {
                            Some(n) if n <= a.ring_bytes => {}
                            _ => {
                                return Err(ProtocolError::Malformed(
                                    "shm slot region past ring".into(),
                                ))
                            }
                        }
                    }
                    other => {
                        return Err(ProtocolError::Malformed(format!("data plane byte {other}")))
                    }
                }
                ProcMsg::AssignShard(a)
            }
            TY_DONE => ProcMsg::ShardDone {
                frame_id: c.u64()?,
                shard_id: c.u64()?,
                kernel_time_us: c.u64()?,
                checksum: c.u32()?,
                slot: if version >= 2 { c.u64()? } else { NO_SLOT },
            },
            TY_FAILED => {
                let frame_id = c.u64()?;
                let shard_id = c.u64()?;
                let panicked = match c.take(1)?[0] {
                    0 => false,
                    1 => true,
                    other => {
                        return Err(ProtocolError::Malformed(format!("bool byte {other}")));
                    }
                };
                let reason = c.string()?;
                let deadline = if version >= 3 {
                    match c.take(1)?[0] {
                        0 => false,
                        1 => true,
                        other => {
                            return Err(ProtocolError::Malformed(format!("bool byte {other}")));
                        }
                    }
                } else {
                    false
                };
                ProcMsg::ShardFailed { frame_id, shard_id, panicked, deadline, reason }
            }
            TY_HEARTBEAT => ProcMsg::Heartbeat { seq: c.u64()? },
            TY_CALIBRATION => {
                let memcpy_bps = c.f64()?;
                let mut tile_throughput = [0.0f64; 4];
                for t in tile_throughput.iter_mut() {
                    *t = c.f64()?;
                }
                let mut tile_throughput_tuned = [0.0f64; 4];
                for t in tile_throughput_tuned.iter_mut() {
                    *t = c.f64()?;
                }
                let dispatch_overhead_s = c.f64()?;
                let spill_read_latency_s = c.f64()?;
                let spill_read_bps = c.f64()?;
                let samples = c.u64()?;
                ProcMsg::CalibrationReport {
                    snapshot: CostSnapshot {
                        memcpy_bps,
                        tile_throughput,
                        tile_throughput_tuned,
                        dispatch_overhead_s,
                        spill_read_latency_s,
                        spill_read_bps,
                        samples,
                    },
                }
            }
            TY_SHUTDOWN => ProcMsg::Shutdown,
            TY_CHUNK if version >= 3 => {
                let frame_id = c.u64()?;
                let shard_id = c.u64()?;
                let dir = c.take(1)?[0];
                if dir > 1 {
                    return Err(ProtocolError::Malformed(format!("chunk dir byte {dir}")));
                }
                let offset = c.u64()?;
                let total = c.u64()?;
                let dlen = c.u32()?;
                if dlen > CHUNK_DATA_MAX {
                    return Err(ProtocolError::Malformed(format!("chunk data {dlen} B")));
                }
                let data = c.take(dlen as usize)?.to_vec();
                // A chunk past its declared total is corrupt framing.
                if offset.checked_add(dlen as u64).map_or(true, |end| end > total) {
                    return Err(ProtocolError::Malformed("chunk past declared total".into()));
                }
                ProcMsg::Chunk { frame_id, shard_id, dir, offset, total, data }
            }
            TY_HELLO if version >= 3 => {
                let hver = u16::from_le_bytes(c.take(2)?.try_into().expect("2 bytes"));
                let caps = c.u32()?;
                let tag = c.string()?;
                ProcMsg::Hello { version: hver, caps, tag }
            }
            other => return Err(ProtocolError::UnknownType { ty: other }),
        };
        c.done()?;
        Ok(msg)
    }

    /// Write one frame to a pipe (single `write_all` — callers holding
    /// a shared stdout lock get whole-frame atomicity from the lock,
    /// not from the OS).
    pub fn write_to(&self, w: &mut impl Write) -> Result<(), ProtocolError> {
        w.write_all(&self.encode())?;
        Ok(())
    }

    /// Read one frame from a pipe.  `Ok(None)` is a *clean* EOF — the
    /// peer closed between frames; EOF inside a frame is
    /// [`ProtocolError::Truncated`].
    pub fn read_from(r: &mut impl Read) -> Result<Option<ProcMsg>, ProtocolError> {
        let mut header = [0u8; HEADER_LEN];
        let mut got = 0usize;
        while got < HEADER_LEN {
            match r.read(&mut header[got..]) {
                Ok(0) if got == 0 => return Ok(None),
                Ok(0) => return Err(ProtocolError::Truncated),
                Ok(n) => got += n,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e.into()),
            }
        }
        let magic = u16::from_le_bytes([header[0], header[1]]);
        if magic != PROTOCOL_MAGIC {
            return Err(ProtocolError::BadMagic { got: magic });
        }
        let version = u16::from_le_bytes([header[2], header[3]]);
        if !(PROTOCOL_VERSION_MIN..=PROTOCOL_VERSION).contains(&version) {
            return Err(ProtocolError::VersionMismatch { got: version });
        }
        let ty = header[4];
        let len = u32::from_le_bytes([header[5], header[6], header[7], header[8]]);
        if len > MAX_PAYLOAD {
            return Err(ProtocolError::Oversized { len });
        }
        let mut payload = vec![0u8; len as usize];
        r.read_exact(&mut payload)?;
        Self::decode_payload(ty, version, &payload).map(Some)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::pcie::Card;
    use crate::util::prng::Xoshiro256;

    fn file_assign() -> WireAssign {
        WireAssign {
            frame_id: 7,
            shard_id: 3,
            bin0: 8,
            nbins: 4,
            row0: 16,
            nrows: 10,
            img_h: 64,
            img_w: 48,
            img_path: "/tmp/img.bin".into(),
            out_path: "/tmp/out-7-3.bin".into(),
            plane: PLANE_FILE,
            slot: 0,
            slot_off: 0,
            ring_bytes: 0,
            ring_path: String::new(),
            deadline_us: 0,
            strip_checksum: 0,
        }
    }

    fn shm_assign() -> WireAssign {
        // strip = 10×48×4 = 1920 B, partial = 4×10×48×4 = 7680 B.
        WireAssign {
            img_path: String::new(),
            out_path: String::new(),
            plane: PLANE_SHM,
            slot: 1,
            slot_off: 16384,
            ring_bytes: 32768,
            ring_path: "/dev/shm/inthist-shm-1-n0.ring".into(),
            ..file_assign()
        }
    }

    fn stream_assign() -> WireAssign {
        WireAssign {
            img_path: String::new(),
            out_path: String::new(),
            plane: PLANE_STREAM,
            deadline_us: 250_000,
            strip_checksum: 0xBEEF_CAFE,
            ..file_assign()
        }
    }

    fn samples() -> Vec<ProcMsg> {
        vec![
            ProcMsg::AssignShard(file_assign()),
            ProcMsg::AssignShard(shm_assign()),
            ProcMsg::AssignShard(stream_assign()),
            ProcMsg::ShardDone {
                frame_id: 7,
                shard_id: 3,
                kernel_time_us: 1234,
                checksum: 0xDEAD,
                slot: 1,
            },
            ProcMsg::ShardFailed {
                frame_id: 7,
                shard_id: 3,
                panicked: true,
                deadline: false,
                reason: "injected".into(),
            },
            ProcMsg::ShardFailed {
                frame_id: 8,
                shard_id: 0,
                panicked: false,
                deadline: true,
                reason: "deadline budget expired at worker".into(),
            },
            ProcMsg::Heartbeat { seq: 42 },
            ProcMsg::CalibrationReport { snapshot: CostSnapshot::static_prior(Card::Gtx480) },
            ProcMsg::Shutdown,
            ProcMsg::Chunk {
                frame_id: 7,
                shard_id: 3,
                dir: 1,
                offset: 4096,
                total: 7680,
                data: vec![0xAB; 512],
            },
            ProcMsg::Hello { version: PROTOCOL_VERSION, caps: CAPS_ALL, tag: "proc-worker".into() },
        ]
    }

    #[test]
    fn every_message_roundtrips_bit_identical() {
        for msg in samples() {
            let bytes = msg.encode();
            let (back, used) = ProcMsg::decode(&bytes).expect("decode");
            assert_eq!(back, msg);
            assert_eq!(used, bytes.len(), "whole frame consumed");
            // Stream API agrees with the slice API.
            let mut r = &bytes[..];
            assert_eq!(ProcMsg::read_from(&mut r).expect("read"), Some(msg));
            assert_eq!(ProcMsg::read_from(&mut r).expect("eof"), None, "clean EOF after frame");
        }
    }

    #[test]
    fn back_to_back_frames_stream() {
        let mut stream = Vec::new();
        for m in samples() {
            stream.extend_from_slice(&m.encode());
        }
        let mut r = &stream[..];
        for want in samples() {
            assert_eq!(ProcMsg::read_from(&mut r).expect("read"), Some(want));
        }
        assert_eq!(ProcMsg::read_from(&mut r).expect("eof"), None);
    }

    #[test]
    fn every_truncation_point_errors_typed() {
        for msg in samples() {
            let bytes = msg.encode();
            for cut in 0..bytes.len() {
                let err = ProcMsg::decode(&bytes[..cut]).expect_err("truncated must fail");
                assert!(
                    matches!(err, ProtocolError::Truncated | ProtocolError::Malformed(_)),
                    "cut at {cut}: {err:?}"
                );
                if cut > 0 {
                    let mut r = &bytes[..cut];
                    assert!(ProcMsg::read_from(&mut r).is_err(), "mid-frame EOF at {cut}");
                }
            }
        }
    }

    #[test]
    fn bad_magic_version_type_and_length_are_rejected() {
        let good = ProcMsg::Heartbeat { seq: 1 }.encode();
        let mut bad = good.clone();
        bad[0] ^= 0xFF;
        assert!(matches!(ProcMsg::decode(&bad), Err(ProtocolError::BadMagic { .. })));
        let mut bad = good.clone();
        bad[2] = 99;
        assert!(matches!(
            ProcMsg::decode(&bad),
            Err(ProtocolError::VersionMismatch { got: 99 })
        ));
        let mut bad = good.clone();
        bad[4] = 200;
        assert!(matches!(ProcMsg::decode(&bad), Err(ProtocolError::UnknownType { ty: 200 })));
        let mut bad = good.clone();
        bad[5..9].copy_from_slice(&(MAX_PAYLOAD + 1).to_le_bytes());
        assert!(matches!(ProcMsg::decode(&bad), Err(ProtocolError::Oversized { .. })));
        // Trailing payload bytes are malformed, not silently ignored.
        let mut bad = good;
        bad[5..9].copy_from_slice(&9u32.to_le_bytes());
        bad.extend_from_slice(&[0u8; 1]);
        assert!(matches!(ProcMsg::decode(&bad), Err(ProtocolError::Malformed(_))));
    }

    #[test]
    fn degenerate_assignments_are_rejected() {
        let mut a = WireAssign { nbins: 0, ..file_assign() }; // degenerate
        let bytes = ProcMsg::AssignShard(a.clone()).encode();
        assert!(matches!(ProcMsg::decode(&bytes), Err(ProtocolError::Malformed(_))));
        a.nbins = 2;
        a.row0 = 60;
        a.nrows = 10; // past the image
        let bytes = ProcMsg::AssignShard(a).encode();
        assert!(matches!(ProcMsg::decode(&bytes), Err(ProtocolError::Malformed(_))));
    }

    /// The v2 slot geometry is validated at decode, before any mmap
    /// arithmetic could trust it: a slot region past the ring, a
    /// ringless shm assign and an unknown plane byte are all malformed.
    #[test]
    fn hostile_slot_geometry_is_rejected() {
        let past_ring = WireAssign { ring_bytes: 1024, ..shm_assign() };
        let bytes = ProcMsg::AssignShard(past_ring).encode();
        assert!(matches!(ProcMsg::decode(&bytes), Err(ProtocolError::Malformed(_))));

        let no_ring = WireAssign { ring_path: String::new(), ..shm_assign() };
        let bytes = ProcMsg::AssignShard(no_ring).encode();
        assert!(matches!(ProcMsg::decode(&bytes), Err(ProtocolError::Malformed(_))));

        // Overflowing strip/partial arithmetic is malformed, not UB.
        let huge = WireAssign { nrows: 1, row0: 0, img_h: u64::MAX, img_w: u64::MAX, ..shm_assign() };
        let bytes = ProcMsg::AssignShard(huge).encode();
        assert!(matches!(ProcMsg::decode(&bytes), Err(ProtocolError::Malformed(_))));

        let bad_plane = WireAssign { plane: 7, ..shm_assign() };
        let bytes = ProcMsg::AssignShard(bad_plane).encode();
        assert!(matches!(ProcMsg::decode(&bytes), Err(ProtocolError::Malformed(_))));
    }

    /// Minor-version compatibility: a v1 frame (no data-plane tail)
    /// still decodes, as a file-plane assignment / slotless completion.
    #[test]
    fn v1_frames_decode_as_file_plane() {
        // Hand-build the v1 AssignShard payload: 8 u64s + two strings.
        let a = file_assign();
        let mut p = Vec::new();
        for v in [a.frame_id, a.shard_id, a.bin0, a.nbins, a.row0, a.nrows, a.img_h, a.img_w] {
            p.extend_from_slice(&v.to_le_bytes());
        }
        for s in [&a.img_path, &a.out_path] {
            p.extend_from_slice(&(s.len() as u32).to_le_bytes());
            p.extend_from_slice(s.as_bytes());
        }
        let mut wire = Vec::new();
        wire.extend_from_slice(&PROTOCOL_MAGIC.to_le_bytes());
        wire.extend_from_slice(&1u16.to_le_bytes());
        wire.push(1); // TY_ASSIGN
        wire.extend_from_slice(&(p.len() as u32).to_le_bytes());
        wire.extend_from_slice(&p);
        let (msg, used) = ProcMsg::decode(&wire).expect("v1 assign decodes");
        assert_eq!(used, wire.len());
        assert_eq!(msg, ProcMsg::AssignShard(a), "v1 decodes to the file plane");

        // v1 ShardDone: three u64s + u32, no slot.
        let mut p = Vec::new();
        p.extend_from_slice(&7u64.to_le_bytes());
        p.extend_from_slice(&3u64.to_le_bytes());
        p.extend_from_slice(&1234u64.to_le_bytes());
        p.extend_from_slice(&0xDEADu32.to_le_bytes());
        let mut wire = Vec::new();
        wire.extend_from_slice(&PROTOCOL_MAGIC.to_le_bytes());
        wire.extend_from_slice(&1u16.to_le_bytes());
        wire.push(2); // TY_DONE
        wire.extend_from_slice(&(p.len() as u32).to_le_bytes());
        wire.extend_from_slice(&p);
        let (msg, _) = ProcMsg::decode(&wire).expect("v1 done decodes");
        assert_eq!(
            msg,
            ProcMsg::ShardDone {
                frame_id: 7,
                shard_id: 3,
                kernel_time_us: 1234,
                checksum: 0xDEAD,
                slot: NO_SLOT,
            }
        );

        // Version 0 and future versions stay rejected.
        let mut bad = wire.clone();
        bad[2..4].copy_from_slice(&0u16.to_le_bytes());
        assert!(matches!(ProcMsg::decode(&bad), Err(ProtocolError::VersionMismatch { got: 0 })));
        let mut bad = wire;
        bad[2..4].copy_from_slice(&(PROTOCOL_VERSION + 1).to_le_bytes());
        assert!(matches!(ProcMsg::decode(&bad), Err(ProtocolError::VersionMismatch { .. })));
    }

    /// v2 frames (data-plane tail, no deadline tail) still decode:
    /// assignments carry no deadline budget, failures no deadline
    /// marker, and the v3-only frame types are rejected at v2.
    #[test]
    fn v2_frames_decode_without_deadline_tail() {
        // Hand-build the v2 AssignShard payload: v1 prefix + plane tail.
        let a = shm_assign();
        let mut p = Vec::new();
        for v in [a.frame_id, a.shard_id, a.bin0, a.nbins, a.row0, a.nrows, a.img_h, a.img_w] {
            p.extend_from_slice(&v.to_le_bytes());
        }
        for s in [&a.img_path, &a.out_path] {
            p.extend_from_slice(&(s.len() as u32).to_le_bytes());
            p.extend_from_slice(s.as_bytes());
        }
        p.push(a.plane);
        p.extend_from_slice(&a.slot.to_le_bytes());
        p.extend_from_slice(&a.slot_off.to_le_bytes());
        p.extend_from_slice(&a.ring_bytes.to_le_bytes());
        p.extend_from_slice(&(a.ring_path.len() as u32).to_le_bytes());
        p.extend_from_slice(a.ring_path.as_bytes());
        let frame = |ty: u8, p: &[u8]| {
            let mut wire = Vec::new();
            wire.extend_from_slice(&PROTOCOL_MAGIC.to_le_bytes());
            wire.extend_from_slice(&2u16.to_le_bytes());
            wire.push(ty);
            wire.extend_from_slice(&(p.len() as u32).to_le_bytes());
            wire.extend_from_slice(p);
            wire
        };
        let (msg, _) = ProcMsg::decode(&frame(1, &p)).expect("v2 assign decodes");
        let want = WireAssign { deadline_us: 0, strip_checksum: 0, ..shm_assign() };
        assert_eq!(msg, ProcMsg::AssignShard(want), "v2 decodes with no deadline budget");

        // v2 ShardFailed: ids + bool + reason, no deadline marker.
        let mut p = Vec::new();
        p.extend_from_slice(&7u64.to_le_bytes());
        p.extend_from_slice(&3u64.to_le_bytes());
        p.push(1);
        p.extend_from_slice(&8u32.to_le_bytes());
        p.extend_from_slice(b"injected");
        let (msg, _) = ProcMsg::decode(&frame(3, &p)).expect("v2 failed decodes");
        assert_eq!(
            msg,
            ProcMsg::ShardFailed {
                frame_id: 7,
                shard_id: 3,
                panicked: true,
                deadline: false,
                reason: "injected".into(),
            }
        );

        // Chunk and Hello are v3-only: at v2 the type byte is unknown.
        let chunk = ProcMsg::Chunk {
            frame_id: 1,
            shard_id: 0,
            dir: 0,
            offset: 0,
            total: 4,
            data: vec![1, 2, 3, 4],
        };
        let mut wire = chunk.encode();
        wire[2..4].copy_from_slice(&2u16.to_le_bytes());
        assert!(matches!(ProcMsg::decode(&wire), Err(ProtocolError::UnknownType { ty: 7 })));
        // And a stream-plane assign cannot claim to be v2.
        let mut wire = ProcMsg::AssignShard(stream_assign()).encode();
        wire[2..4].copy_from_slice(&2u16.to_le_bytes());
        assert!(ProcMsg::decode(&wire).is_err(), "v2 stream assign must not decode");
    }

    /// Chunk framing is validated before any buffer trusts it: an
    /// out-of-range dir byte, a data run past the declared total and
    /// an oversized data length are all malformed.
    #[test]
    fn hostile_chunks_are_rejected() {
        let good = ProcMsg::Chunk {
            frame_id: 7,
            shard_id: 3,
            dir: 0,
            offset: 0,
            total: 512,
            data: vec![0u8; 512],
        };
        let bytes = good.encode();
        let (back, _) = ProcMsg::decode(&bytes).expect("good chunk decodes");
        assert_eq!(back, good);

        let bad_dir = ProcMsg::Chunk { dir: 2, ..good.clone() };
        assert!(matches!(
            ProcMsg::decode(&bad_dir.encode()),
            Err(ProtocolError::Malformed(_))
        ));

        let past_total = ProcMsg::Chunk { offset: 1, ..good.clone() };
        assert!(matches!(
            ProcMsg::decode(&past_total.encode()),
            Err(ProtocolError::Malformed(_))
        ));

        let overflow = ProcMsg::Chunk { offset: u64::MAX, total: u64::MAX, ..good };
        assert!(matches!(
            ProcMsg::decode(&overflow.encode()),
            Err(ProtocolError::Malformed(_))
        ));

        let oversized = ProcMsg::Chunk {
            frame_id: 7,
            shard_id: 3,
            dir: 0,
            offset: 0,
            total: CHUNK_DATA_MAX as u64 + 1,
            data: vec![0u8; CHUNK_DATA_MAX as usize + 1],
        };
        assert!(matches!(
            ProcMsg::decode(&oversized.encode()),
            Err(ProtocolError::Malformed(_))
        ));
    }

    #[test]
    fn checksum_bytes_matches_checksum_f32() {
        let data = [1.0f32, -2.5, 3.25, 0.0];
        let mut raw = Vec::new();
        for v in data {
            raw.extend_from_slice(&v.to_le_bytes());
        }
        assert_eq!(checksum_bytes(&raw), checksum_f32(&data));
        assert_eq!(checksum_bytes(&[]), 0x811C_9DC5);
    }

    #[test]
    fn random_bytes_never_panic_the_decoder() {
        let mut rng = Xoshiro256::new(0xF00D);
        for trial in 0..500 {
            let len = rng.range(0, 64);
            let mut buf = vec![0u8; len];
            for b in buf.iter_mut() {
                *b = rng.range(0, 256) as u8;
            }
            // Half the trials get a valid header prefix so the fuzz
            // reaches the payload decoders too.
            if trial % 2 == 0 && buf.len() >= HEADER_LEN {
                buf[0..2].copy_from_slice(&PROTOCOL_MAGIC.to_le_bytes());
                buf[2..4].copy_from_slice(&PROTOCOL_VERSION.to_le_bytes());
                buf[4] = (rng.range(0, 8) + 1) as u8;
                let plen = (buf.len() - HEADER_LEN) as u32;
                buf[5..9].copy_from_slice(&plen.to_le_bytes());
            }
            let _ = ProcMsg::decode(&buf); // must return, never panic
            let mut r = &buf[..];
            let _ = ProcMsg::read_from(&mut r);
        }
    }

    #[test]
    fn checksum_is_stable_and_bit_sensitive() {
        let data = [1.0f32, 2.0, 3.5, -0.0];
        let a = checksum_f32(&data);
        assert_eq!(a, checksum_f32(&data), "deterministic");
        let mut flipped = data;
        flipped[2] = 3.5000002; // one mantissa step
        assert_ne!(a, checksum_f32(&flipped));
        // Mirrors the Python pre-validation constant.
        assert_eq!(checksum_f32(&[]), 0x811C_9DC5);
    }
}
