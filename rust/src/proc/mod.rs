//! Multi-process execution plane: process-isolated shard workers.
//!
//! The in-process `ShardExecutor` (see [`crate::shard`]) contains
//! worker *panics* with `catch_unwind`, but a panic is the gentlest
//! way compute dies.  An abort in native code, the kernel's OOM
//! killer, or a stray SIGKILL takes the whole server with it — the
//! paper's per-node scheduling story needs a failure domain smaller
//! than the process.  This subsystem provides one:
//!
//! * [`protocol`] — a versioned, length-prefixed binary control
//!   protocol spoken over the child's stdin/stdout pipes.  *Control
//!   only*: assignments, completions, failures, heartbeats and
//!   calibration reports.  Bulk tensor data never rides the pipes —
//!   it travels the data plane: a shared-memory slot ring ([`shm`],
//!   the default where the platform supports it) or `TensorStore`
//!   spill files in the paper's Fig. 2 bin-major layout (the fallback,
//!   selected via `ProcPoolConfig::data_plane`).
//! * [`shm`] — the shared-memory data plane: per-child mmap rings of
//!   fixed-size slots; the supervisor loads input strips in, the child
//!   writes partials in place, and only control frames cross the pipe
//!   (cuts the measured isolation tax of the spill-file round-trip).
//! * [`worker`] — the child side: a `ScanEngine` loop that executes
//!   assignments and streams back `(frame_id, shard_id)`-tagged
//!   results (compiled into the `proc-worker` bin target).
//! * [`supervisor`] — the parent side: spawns and monitors the pool
//!   (pipe EOF + exit status + heartbeat age), respawns dead children,
//!   requeues their in-flight shards under the bounded attempt ladder,
//!   and fails frames *typed* through `ShardError` — never a hang.
//! * [`placement`] — per-node calibrated placement: every child runs
//!   the `Calibrator` microbench on the node it actually landed on,
//!   and shard groups are sized and assigned per process from the
//!   measured snapshots.
//! * [`transport`] — the child-link abstraction: the same protocol
//!   frames flow over a local pipe pair ([`transport::PipeTransport`])
//!   or a TCP socket to a `proc-worker --listen` process on another
//!   host ([`transport::SocketTransport`], v3 `Hello` handshake).
//!   Remote nodes use the chunked in-band **stream data plane** —
//!   strips pushed and partials pulled as bounded `Chunk` frames with
//!   FNV-1a checksums — because neither spill files nor `/dev/shm`
//!   cross hosts.
//!
//! The plane hangs off the same `FrameTicket` API as the in-process
//! executor, so reassembly, deadline accounting and the bit-identity
//! contract are shared code, and `Server` can route frames to either
//! behind a config flag (`ServerConfig::process_isolation`; the
//! in-process path stays the default — process isolation buys fault
//! containment at an IPC + spill tax, measured in `benches/shard.rs`).

pub mod placement;
pub mod protocol;
pub mod shm;
pub mod supervisor;
pub mod transport;
pub mod worker;

pub use placement::{plan_for_nodes, PlacementMap};
pub use protocol::{checksum_bytes, checksum_f32, ProcMsg, ProtocolError, WireAssign};
pub use shm::{ShmMap, ShmRing};
pub use supervisor::{
    resolve_worker_bin, DataPlane, ProcPoolConfig, ProcStats, ProcSupervisor,
};
pub use transport::{connect_remote, PipeTransport, SocketTransport, Transport};
pub use worker::{
    run as run_worker, serve as serve_worker, serve_conn as serve_worker_conn, WorkerConfig,
};
