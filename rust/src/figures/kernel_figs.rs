//! Kernel-side figures: Fig. 7 (cumulative kernel time), Fig. 8
//! (breakdown), Fig. 9/10 (tuning), Eq. 4 (scan efficiency).

use super::{fmt_ms, FigContext};
use crate::histogram::scan::scan_efficiency;
use crate::histogram::types::Strategy;
use crate::simulator::gpu_model::{self, BlockDemand, SmResources};
use anyhow::Result;

/// Fig. 7 — cumulative kernel execution time of the four GPU
/// implementations across image sizes, 32 bins (log-scale plot in the
/// paper; we print the values).  The CW-B row additionally reports the
/// launch-overhead-adjusted time (§3.3): on real hardware its thousands
/// of launches dominate, which a single fused HLO module cannot exhibit.
pub fn fig7(ctx: &mut FigContext) -> Result<()> {
    println!("\n=== Fig. 7: cumulative kernel time, 32-bin integral histogram (ms) ===");
    let sizes = [128usize, 256, 512, 1024];
    println!(
        "{:<10} {:>10} {:>10} {:>10} {:>10} {:>14}",
        "size", "CW-B", "CW-STS", "CW-TiS", "WF-TiS", "CW-B +launch"
    );
    for &s in &sizes {
        let cwb = ctx.strategy_kernel_ms(Strategy::CwB, s, s, 32)?;
        let sts = ctx.strategy_kernel_ms(Strategy::CwSts, s, s, 32)?;
        let tis = ctx.strategy_kernel_ms(Strategy::CwTis, s, s, 32)?;
        let wf = ctx.strategy_kernel_ms(Strategy::WfTis, s, s, 32)?;
        let cwb_launch = cwb.map(|ms| {
            ms + gpu_model::launch_overhead(Strategy::CwB, s, s, 32, 32).as_secs_f64() * 1e3
        });
        println!(
            "{:<10} {} {} {} {} {:>14}",
            format!("{s}x{s}"),
            fmt_ms(cwb),
            fmt_ms(sts),
            fmt_ms(tis),
            fmt_ms(wf),
            cwb_launch.map(|v| format!("{v:.2}")).unwrap_or_else(|| "-".into()),
        );
    }
    // the paper's headline ratios
    if let (Some(tis), Some(wf)) = (
        ctx.strategy_kernel_ms(Strategy::CwTis, 512, 512, 32)?,
        ctx.strategy_kernel_ms(Strategy::WfTis, 512, 512, 32)?,
    ) {
        println!("WF-TiS speedup over CW-TiS @512: {:.2}x (paper: up to ~1.5x)", tis / wf);
    }
    if let (Some(sts), Some(tis)) = (
        ctx.strategy_kernel_ms(Strategy::CwSts, 512, 512, 32)?,
        ctx.strategy_kernel_ms(Strategy::CwTis, 512, 512, 32)?,
    ) {
        println!("CW-TiS speedup over CW-STS @512: {:.2}x (paper: 2x-3x)", sts / tis);
    }
    Ok(())
}

/// Fig. 8 — kernel-time breakdown at 512²×32 and 1024²×32.  The paper
/// splits init / SDK-prescan / transpose / custom scans; we measure the
/// init artifact directly and derive the scan and transpose+overhead
/// components from strategy differences (documented in EXPERIMENTS.md).
pub fn fig8(ctx: &mut FigContext) -> Result<()> {
    println!("\n=== Fig. 8: kernel time breakdown (ms) ===");
    for &s in &[512usize, 1024] {
        let init = if s == 512 { Some(ctx.kernel_ms("init_only_512x512_b32_t64")?) } else { None };
        let sts = ctx.strategy_kernel_ms(Strategy::CwSts, s, s, 32)?;
        let tis = ctx.strategy_kernel_ms(Strategy::CwTis, s, s, 32)?;
        let wf = ctx.strategy_kernel_ms(Strategy::WfTis, s, s, 32)?;
        println!("--- {s}x{s}x32 ---");
        if let Some(i) = init {
            println!("  init (binning) kernel          : {i:>9.2}");
        }
        if let (Some(t), Some(w)) = (tis, wf) {
            println!("  CW-TiS custom h+v scans        : {t:>9.2}");
            println!("  WF-TiS fused wavefront scan    : {w:>9.2}");
            println!("  saved by fusing the two passes : {:>9.2}", t - w);
        }
        if let (Some(s_), Some(t)) = (sts, tis) {
            println!("  CW-STS (SDK prescan+transpose) : {s_:>9.2}");
            println!("  SDK-scan + transpose overhead  : {:>9.2}", s_ - t);
        }
    }
    println!("(paper: transpose ≈ 20% of total and ≈ 50% of one prescan at 512²)");
    Ok(())
}

/// Fig. 9 — execution time and occupancy vs thread-block configuration.
/// Thread blocks do not exist on this substrate; we report (a) the
/// occupancy-calculator model for the paper's block configs — which
/// reproduces the "100% occupancy for both best and worst config"
/// observation — and (b) the measured analogue of block tuning here:
/// the Pallas tile-size sweep.
pub fn fig9(ctx: &mut FigContext) -> Result<()> {
    println!("\n=== Fig. 9: occupancy model (Kepler SMX, WF-TiS demand) ===");
    println!("{:<10} {:>10} {:>10}", "threads", "blocks/SM", "occupancy");
    for threads in [64usize, 128, 256, 512, 1024] {
        let (blocks, occ) =
            gpu_model::occupancy(SmResources::kepler_smx(), BlockDemand::wf_tis(threads, 64));
        println!("{threads:<10} {blocks:>10} {:>9.0}%", occ * 100.0);
    }
    println!("\nmeasured tile sweep (the block-config analogue), WF-TiS 512²x32:");
    println!("{:<10} {:>12}", "tile", "kernel ms");
    for tile in [16usize, 32, 64] {
        let name = format!("wf_tis_512x512_b32_t{tile}");
        match ctx.kernel_ms(&name) {
            Ok(ms) => println!("{tile:<10} {ms:>12.2}"),
            Err(_) => println!("{tile:<10} {:>12}", "-"),
        }
    }
    Ok(())
}

/// Fig. 10 — WF-TiS tile-size comparison (32 vs 64; the paper finds
/// 64×64 wins through better shared-memory use, and 16×16 loses by
/// starving warps).
pub fn fig10(ctx: &mut FigContext) -> Result<()> {
    println!("\n=== Fig. 10: WF-TiS tile configuration, 512²x32 ===");
    let t16 = ctx.kernel_ms("wf_tis_512x512_b32_t16").ok();
    let t32 = ctx.kernel_ms("wf_tis_512x512_b32_t32").ok();
    let t64 = ctx.kernel_ms("wf_tis_512x512_b32_t64").ok();
    println!("{:<10} {:>12}", "tile", "kernel ms");
    println!("{:<10} {}", "16x16", fmt_ms(t16));
    println!("{:<10} {}", "32x32", fmt_ms(t32));
    println!("{:<10} {}", "64x64", fmt_ms(t64));
    if let (Some(a), Some(b)) = (t32, t64) {
        println!("64x64 vs 32x32: {:.2}x (paper: 64x64 wins)", a / b);
    }
    Ok(())
}

/// Eq. 4 — efficiency of the SIMT Blelloch scan vs array length.
pub fn eq4() -> Result<()> {
    println!("\n=== Eq. 4: Blelloch scan efficiency 3(n-1)/(n·log2 n) ===");
    println!("{:<10} {:>12}", "n", "efficiency");
    for log_n in [6u32, 8, 10, 12, 14] {
        let n = 1usize << log_n;
        println!("{n:<10} {:>11.1}%", scan_efficiency(n) * 100.0);
    }
    println!("(paper quotes 30% at n = 1024 — the motivation for custom scan kernels)");
    Ok(())
}
