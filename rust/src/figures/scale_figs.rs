//! Scaling figures: Fig. 16 (multi-device frame rate), Fig. 17
//! (multi-device speedup over CPU threading), Fig. 19 (GPU vs CPU
//! threading speedup).
//!
//! The paper's largest workloads (WHSXGA, 8k×8k; 32 GB tensors) exceed
//! what a CPU-PJRT substrate can run in reasonable time, so these
//! figures run the same *code path* (bin task queue over the device
//! pool) on 512² and HD frames and report the same columns; the
//! size-scaling narrative is preserved by the bins axis (tensor bytes
//! grow linearly in bins exactly as in rows×cols).  See EXPERIMENTS.md.

use super::FigContext;
use crate::coordinator::task_queue::{BinTaskQueue, TaskQueueConfig};
use crate::histogram::parallel::integral_histogram_parallel;
use crate::histogram::types::Strategy;
use crate::util::stats::{time_ms, Summary};
use crate::video::synth::SyntheticVideo;
use anyhow::Result;
use std::sync::Arc;

/// Run the bin task queue once: (workload label, h, w, total bins).
fn run_queue(
    ctx: &FigContext,
    artifact: &str,
    h: usize,
    w: usize,
    total_bins: usize,
    workers: usize,
    group: usize,
) -> Result<(f64, Vec<usize>)> {
    let queue = BinTaskQueue::new(
        Arc::clone(&ctx.manifest),
        // Strict artifact execution: figure timings must never silently
        // come from the CPU fallback.
        TaskQueueConfig { workers, group, artifact: artifact.to_string(), cpu_fallback: false },
    )?;
    let video = SyntheticVideo::new(h, w, 4, 7);
    let image = Arc::new(video.frame(0).binned(total_bins));
    // warm-up run compiles each worker's executor
    let _ = queue.compute_discard(&image, total_bins)?;
    let report = queue.compute_discard(&image, total_bins)?;
    let fps = report.fps();
    let per_worker = report.per_worker.clone();
    queue.shutdown();
    Ok((fps, per_worker))
}

/// Fig. 16 — frame rate of the multi-device bin task queue:
/// (a) across frame sizes at 32 bins, (b) across bins for 512²/HD.
pub fn fig16(ctx: &mut FigContext) -> Result<()> {
    println!("\n=== Fig. 16: multi-device task queue (4 workers, 8-bin groups) ===");
    println!("(paper runs HD…8k×8k on 4 GTX 480s; this substrate runs the same");
    println!(" code path on 512² and HD — see EXPERIMENTS.md for the scale note)");
    println!("{:<14} {:>6} {:>12} {:>18}", "frame", "bins", "fr/sec", "tasks per worker");
    for (label, h, w, art) in [
        ("512x512", 512usize, 512usize, "wf_tis_512x512_b8_t64"),
        ("HD 1280x720", 720, 1280, "wf_tis_720x1280_b8_t64"),
    ] {
        for bins in [32usize, 64, 128] {
            match run_queue(ctx, art, h, w, bins, 4, 8) {
                Ok((fps, pw)) => {
                    println!("{label:<14} {bins:>6} {fps:>12.3} {:>18}", format!("{pw:?}"))
                }
                Err(e) => println!("{label:<14} {bins:>6} skipped: {e}"),
            }
        }
    }
    Ok(())
}

/// Fig. 17 — speedup of the 4-worker pool over CPU threading at 128
/// bins (the paper's heaviest bin count).
pub fn fig17(ctx: &mut FigContext) -> Result<()> {
    println!("\n=== Fig. 17: 128-bin speedup, 4-worker pool vs CPU threads ===");
    println!("{:<14} {:>12} {:>8} {:>8} {:>8} {:>8}", "frame", "pool fps", "vs CPU1", "vs CPU4", "vs CPU8", "vs CPU16");
    for (label, h, w, art) in [
        ("512x512", 512usize, 512usize, "wf_tis_512x512_b8_t64"),
        ("HD 1280x720", 720, 1280, "wf_tis_720x1280_b8_t64"),
    ] {
        let (pool_fps, _) = match run_queue(ctx, art, h, w, 128, 4, 8) {
            Ok(v) => v,
            Err(e) => {
                println!("{label:<14} skipped: {e}");
                continue;
            }
        };
        let video = SyntheticVideo::new(h, w, 4, 7);
        let img = video.frame(0).binned(128);
        let mut cpu_fps = Vec::new();
        for threads in [1usize, 4, 8, 16] {
            let reps = ctx.reps.min(3);
            let samples = time_ms(0, reps, || {
                integral_histogram_parallel(&img, threads);
            });
            cpu_fps.push(1e3 / Summary::of(&samples).median);
        }
        println!(
            "{label:<14} {pool_fps:>12.3} {:>7.1}x {:>7.1}x {:>7.1}x {:>7.1}x",
            pool_fps / cpu_fps[0],
            pool_fps / cpu_fps[1],
            pool_fps / cpu_fps[2],
            pool_fps / cpu_fps[3],
        );
    }
    println!("(paper: 3x for HD up to 153x for 64MB images over 1-thread CPU)");
    Ok(())
}

/// Fig. 19 — WF-TiS speedup over the multithreaded CPU baseline:
/// (a) across image sizes at 32 bins, (b) across bins at 512².
pub fn fig19(ctx: &mut FigContext) -> Result<()> {
    use crate::simulator::pcie::{Card, PcieModel};
    let model = PcieModel::for_card(Card::TitanX);
    // On GPU hardware the tuned kernels are transfer-bound (§4.3), so the
    // modeled GPU frame time is the PCIe transfer of image + tensor; the
    // "subst" column is this substrate's actual PJRT kernel (which shares
    // the host's single core with the CPU baseline — see DESIGN.md note).
    let gpu_ms = |bins: usize, s: usize| {
        (model.image_upload(s, s) + model.tensor_download(bins, s, s)).as_secs_f64() * 1e3
    };
    println!("\n=== Fig. 19a: speedup vs CPU threads, 32 bins, across sizes ===");
    println!(
        "{:<10} {:>10} {:>10} {:>8} {:>8} {:>8} {:>8} {:>10}",
        "size", "subst ms", "GPUmod ms", "CPU1", "CPU4", "CPU8", "CPU16", "mod vs 1T"
    );
    for &s in &[256usize, 512, 1024] {
        let Some(kms) = ctx.strategy_kernel_ms(Strategy::WfTis, s, s, 32)? else {
            continue;
        };
        let gm = gpu_ms(32, s);
        let video = SyntheticVideo::new(s, s, 4, 7);
        let img = video.frame(0).binned(32);
        let mut cpu = Vec::new();
        for threads in [1usize, 4, 8, 16] {
            let samples = time_ms(0, ctx.reps.min(3), || {
                integral_histogram_parallel(&img, threads);
            });
            cpu.push(Summary::of(&samples).median);
        }
        println!(
            "{:<10} {kms:>10.2} {gm:>10.2} {:>7.1}x {:>7.1}x {:>7.1}x {:>7.1}x {:>9.1}x",
            format!("{s}x{s}"),
            cpu[0] / kms,
            cpu[1] / kms,
            cpu[2] / kms,
            cpu[3] / kms,
            cpu[0] / gm,
        );
    }
    println!("\n=== Fig. 19b: speedup vs CPU threads, 512², across bins ===");
    println!(
        "{:<6} {:>10} {:>10} {:>8} {:>8} {:>8} {:>8} {:>10}",
        "bins", "subst ms", "GPUmod ms", "CPU1", "CPU4", "CPU8", "CPU16", "mod vs 1T"
    );
    for bins in [16usize, 32, 64, 128] {
        let Some(kms) = ctx.strategy_kernel_ms(Strategy::WfTis, 512, 512, bins)? else {
            continue;
        };
        let gm = gpu_ms(bins, 512);
        let video = SyntheticVideo::new(512, 512, 4, 7);
        let img = video.frame(0).binned(bins);
        let mut cpu = Vec::new();
        for threads in [1usize, 4, 8, 16] {
            let samples = time_ms(0, ctx.reps.min(3), || {
                integral_histogram_parallel(&img, threads);
            });
            cpu.push(Summary::of(&samples).median);
        }
        println!(
            "{bins:<6} {kms:>10.2} {gm:>10.2} {:>7.1}x {:>7.1}x {:>7.1}x {:>7.1}x {:>9.1}x",
            cpu[0] / kms,
            cpu[1] / kms,
            cpu[2] / kms,
            cpu[3] / kms,
            cpu[0] / gm,
        );
    }
    println!("(paper: ~60x over 1 thread, 8-30x over 16 threads; the 'mod vs 1T'");
    println!(" column applies the paper's transfer-bound GPU model — the 'subst'");
    println!(" columns share one host core with the CPU baseline, see DESIGN.md)");
    Ok(())
}
