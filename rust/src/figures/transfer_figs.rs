//! Transfer-bound figures: Fig. 11 (kernel vs transfer), Fig. 13
//! (dual-buffering), Fig. 15 (frame rates), Fig. 20 (cross-platform).
//!
//! Transfers come from the calibrated PCIe model (DESIGN.md §4).  Where
//! a figure's *mechanism* depends on the kernel:transfer ratio (Figs.
//! 13), the model is scaled so the ratio matches the paper's GPU — the
//! CPU substrate runs kernels ~50-100× slower than a Titan X while the
//! modeled PCIe times are absolute, which would otherwise make
//! everything kernel-bound.  The scale used is printed with the figure.

use super::{fmt_ms, FigContext};
use crate::coordinator::pipeline::{Pipeline, PipelineConfig, TransferModel};
use crate::histogram::types::Strategy;
use crate::simulator::pcie::{Card, FrameRateModel, PcieModel};
use crate::video::synth::SyntheticVideo;
use anyhow::Result;
use std::sync::Arc;
use std::time::Duration;

/// Fig. 11 — kernel execution vs data-transfer time, 512² and 1024²,
/// 32 bins, on the K40c and Titan X models.  Reproduces the structural
/// finding: CW-B is compute-bound, everything else transfer-bound.
pub fn fig11(ctx: &mut FigContext) -> Result<()> {
    println!("\n=== Fig. 11: kernel vs transfer time, 32 bins (ms) ===");
    for card in [Card::K40c, Card::TitanX] {
        let model = PcieModel::for_card(card);
        for &s in &[512usize, 1024] {
            let transfer_ms = (model.image_upload(s, s) + model.tensor_download(32, s, s))
                .as_secs_f64()
                * 1e3;
            println!("--- {} {s}x{s} (transfer model: {transfer_ms:.2} ms) ---", card.name());
            println!("{:<10} {:>10} {:>14} {:>16}", "impl", "kernel", "kernel+launch", "bound (paper)");
            for strat in Strategy::ALL {
                let kernel = ctx.strategy_kernel_ms(strat, s, s, 32)?;
                let with_launch = kernel.map(|ms| {
                    ms + crate::simulator::gpu_model::launch_overhead(strat, s, s, 32, 64)
                        .as_secs_f64()
                        * 1e3
                });
                // The paper's classification on GPU hardware:
                let paper_bound = if strat == Strategy::CwB { "compute" } else { "transfer" };
                println!(
                    "{:<10} {} {} {:>16}",
                    strat.artifact_prefix(),
                    fmt_ms(kernel),
                    fmt_ms(with_launch),
                    paper_bound
                );
            }
        }
    }
    println!("(on this CPU substrate kernels are slower than modeled PCIe, so the");
    println!(" bound column reports the paper's GPU-hardware classification)");
    Ok(())
}

/// Fig. 13 — effect of dual-buffering on a 100-frame HD sequence across
/// bin counts, WF-TiS kernel.  Lanes=1 (serial) vs lanes=2 (the paper's
/// two CUDA streams).  The PCIe model is scaled to preserve the paper's
/// kernel:transfer ratio (≈1:1 at 16 bins on the GTX 480).
pub fn fig13(ctx: &mut FigContext) -> Result<()> {
    println!("\n=== Fig. 13: dual-buffering on HD (1280x720) frames, WF-TiS ===");
    let frames = 20; // 100 in the paper; scaled for CPU-substrate runtime
    println!("{:<6} {:>12} {:>12} {:>9} {:>8}", "bins", "serial fps", "dual fps", "speedup", "scale");
    for bins in [8usize, 16, 32] {
        let name = format!("wf_tis_720x1280_b{bins}_t64");
        let Ok(kernel_ms) = ctx.kernel_ms(&name) else {
            println!("{bins:<6} {:>12} {:>12}", "-", "-");
            continue;
        };
        // Calibrate: paper's GTX 480 at 16 bins has transfer ≈ kernel.
        // Our modeled HD 16-bin transfer vs our measured kernel sets the
        // scale; the same scale is reused for every bin count so the
        // *trend* across bins is the model's, not per-point tuning.
        let model = PcieModel::for_card(Card::Gtx480);
        let t16 = (model.image_upload(720, 1280) + model.tensor_download(16, 720, 1280))
            .as_secs_f64()
            * 1e3;
        let k16 = ctx.kernel_ms("wf_tis_720x1280_b16_t64").unwrap_or(kernel_ms);
        let scale = k16 / t16;
        let manifest = Arc::clone(&ctx.manifest);
        let mut fps = [0.0f64; 2];
        for (i, lanes) in [1usize, 2].iter().enumerate() {
            let cfg = PipelineConfig::new(name.clone(), bins).lanes(*lanes).transfer(
                TransferModel::Simulated { model, scale },
            );
            let src = Box::new(SyntheticVideo::new(720, 1280, 4, 7).take_frames(frames));
            let report = Pipeline::new(Arc::clone(&manifest), cfg).run(src)?;
            fps[i] = report.fps();
        }
        println!(
            "{bins:<6} {:>12.2} {:>12.2} {:>8.2}x {:>8.1}",
            fps[0],
            fps[1],
            fps[1] / fps[0],
            scale
        );
    }
    println!("(paper: ~2x at 16 bins, shrinking as bins grow)");
    Ok(())
}

/// Fig. 15 — frame rates with dual-buffering: (a/b) across image sizes
/// at 32 bins, (c/d) across bin counts at 512².  Frame rate =
/// 1/max(kernel, transfer) per Fig. 14; both the kernel-bound (this
/// substrate) and the transfer-bound (paper GPU model) rates print.
pub fn fig15(ctx: &mut FigContext) -> Result<()> {
    println!("\n=== Fig. 15a/b: frame rate vs image size, 32 bins ===");
    let model = PcieModel::for_card(Card::TitanX);
    println!(
        "{:<10} {:>10} {:>12} {:>14} {:>16}",
        "size", "impl", "kernel fps", "transfer fps", "fps=1/max (GPU)"
    );
    for &s in &[128usize, 256, 512, 1024] {
        for strat in [Strategy::CwSts, Strategy::CwTis, Strategy::WfTis] {
            if let Some(kms) = ctx.strategy_kernel_ms(strat, s, s, 32)? {
                let frm = FrameRateModel::for_frame(
                    &model,
                    Duration::from_secs_f64(kms / 1e3),
                    32,
                    s,
                    s,
                );
                let tms = frm.transfer.as_secs_f64() * 1e3;
                println!(
                    "{:<10} {:>10} {:>12.2} {:>12.2} {:>16.2}",
                    format!("{s}x{s}"),
                    strat.artifact_prefix(),
                    1e3 / kms,
                    1e3 / tms,
                    frm.fps_dual_buffered()
                );
            }
        }
    }
    println!("\n=== Fig. 15c/d: frame rate vs bins, 512², WF-TiS ===");
    println!("{:<6} {:>12} {:>14} {:>16}", "bins", "kernel fps", "transfer fps", "fps=1/max");
    for bins in [16usize, 32, 64, 128] {
        if let Some(kms) = ctx.strategy_kernel_ms(Strategy::WfTis, 512, 512, bins)? {
            let frm =
                FrameRateModel::for_frame(&model, Duration::from_secs_f64(kms / 1e3), bins, 512, 512);
            println!(
                "{bins:<6} {:>12.2} {:>14.2} {:>16.2}",
                1e3 / kms,
                1e3 / (frm.transfer.as_secs_f64() * 1e3),
                frm.fps_dual_buffered()
            );
        }
    }
    println!("(paper: best impls are transfer-bound; rate degrades ~linearly with bins)");
    Ok(())
}

/// Fig. 20 — WF-TiS frame rate on the standard 640×480×32 workload:
/// our measured kernel + per-card transfer models, the CPU baselines,
/// and the published Cell/B.E. results from [48] as reference constants.
pub fn fig20(ctx: &mut FigContext) -> Result<()> {
    println!("\n=== Fig. 20: 640x480, 32 bins — frame rate comparison ===");
    let kms = ctx.kernel_ms("wf_tis_480x640_b32_t32")?;
    println!("{:<26} {:>10}", "platform", "fr/sec");
    println!("{:<26} {:>10.2}", "this substrate (kernel)", 1e3 / kms);
    for card in Card::ALL {
        let model = PcieModel::for_card(card);
        let frm = FrameRateModel::for_frame(&model, Duration::from_secs_f64(kms / 1e3), 32, 480, 640);
        // On real GPUs the kernel is far faster than this substrate; the
        // transfer side is the binding constraint the paper reports.
        let transfer_fps = 1.0 / frm.transfer.as_secs_f64();
        println!("{:<26} {:>10.2}", format!("{} (transfer bound)", card.name()), transfer_fps);
    }
    // CPU baselines (measured here):
    let video = SyntheticVideo::new(480, 640, 4, 7);
    let img = video.frame(0).binned(32);
    for threads in [1usize, 8, 16] {
        let samples = crate::util::stats::time_ms(1, ctx.reps, || {
            crate::histogram::parallel::integral_histogram_parallel(&img, threads);
        });
        let ms = crate::util::stats::Summary::of(&samples).median;
        println!("{:<26} {:>10.2}", format!("CPU {threads} thread(s)"), 1e3 / ms);
    }
    // Published Cell/B.E. numbers (Bellens et al. [48], 8 SPEs), as the
    // paper itself cites them — reference constants, not measured here.
    println!("{:<26} {:>10.2}", "Cell/B.E. WF (8 SPEs) [48]", 49.0);
    println!("{:<26} {:>10.2}", "Cell/B.E. CW (8 SPEs) [48]", 28.0);
    println!("(paper: Titan X ≈ 300.4 fr/sec on this workload, transfer-bound)");
    Ok(())
}
