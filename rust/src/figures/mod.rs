//! Figure drivers: regenerate every table/figure of the paper's
//! evaluation (§4) on this substrate.
//!
//! Each `figNN` function prints the same rows/series the paper plots,
//! with measured kernel times from the PJRT artifacts, modeled
//! transfers from [`crate::simulator::pcie`], and CPU baselines from
//! [`crate::histogram`].  EXPERIMENTS.md records paper-vs-measured for
//! each.  Absolute numbers differ (CPU substrate vs the authors' GPUs);
//! the *shape* — who wins, by what factor, where regimes cross — is the
//! reproduction target (DESIGN.md §4).

mod kernel_figs;
mod scale_figs;
mod transfer_figs;

use crate::histogram::types::Strategy;
use crate::runtime::artifact::ArtifactManifest;
use crate::runtime::client::HistogramExecutor;
use crate::util::stats::{time_ms, Summary};
use crate::video::synth::SyntheticVideo;
use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;

/// Run one figure (or `all`).
pub fn run(artifact_dir: &str, which: &str, reps: usize) -> Result<()> {
    let mut ctx = FigContext::new(artifact_dir, reps)?;
    match which {
        "fig7" => kernel_figs::fig7(&mut ctx),
        "fig8" => kernel_figs::fig8(&mut ctx),
        "fig9" => kernel_figs::fig9(&mut ctx),
        "fig10" => kernel_figs::fig10(&mut ctx),
        "eq4" => kernel_figs::eq4(),
        "fig11" => transfer_figs::fig11(&mut ctx),
        "fig13" => transfer_figs::fig13(&mut ctx),
        "fig15" => transfer_figs::fig15(&mut ctx),
        "fig20" => transfer_figs::fig20(&mut ctx),
        "fig16" => scale_figs::fig16(&mut ctx),
        "fig17" => scale_figs::fig17(&mut ctx),
        "fig19" => scale_figs::fig19(&mut ctx),
        "all" => {
            kernel_figs::eq4()?;
            kernel_figs::fig7(&mut ctx)?;
            kernel_figs::fig8(&mut ctx)?;
            kernel_figs::fig9(&mut ctx)?;
            kernel_figs::fig10(&mut ctx)?;
            transfer_figs::fig11(&mut ctx)?;
            transfer_figs::fig13(&mut ctx)?;
            transfer_figs::fig15(&mut ctx)?;
            scale_figs::fig16(&mut ctx)?;
            scale_figs::fig17(&mut ctx)?;
            scale_figs::fig19(&mut ctx)?;
            transfer_figs::fig20(&mut ctx)
        }
        other => bail!("unknown figure '{other}' (fig7|fig8|fig9|fig10|fig11|fig13|fig15|fig16|fig17|fig19|fig20|eq4|all)"),
    }
}

/// Shared measurement context: manifest, executor cache, kernel-time
/// memo (so `all` does not re-measure across figures).
pub struct FigContext {
    pub manifest: std::sync::Arc<ArtifactManifest>,
    pub reps: usize,
    executors: HashMap<String, HistogramExecutor>,
    kernel_ms: HashMap<String, f64>,
}

impl FigContext {
    pub fn new(dir: &str, reps: usize) -> Result<FigContext> {
        Ok(FigContext {
            manifest: std::sync::Arc::new(ArtifactManifest::load(dir)?),
            reps: reps.max(2),
            executors: HashMap::new(),
            kernel_ms: HashMap::new(),
        })
    }

    /// Median kernel-only time (ms) of a named artifact on a synthetic
    /// frame, memoized.
    pub fn kernel_ms(&mut self, artifact: &str) -> Result<f64> {
        if let Some(&ms) = self.kernel_ms.get(artifact) {
            return Ok(ms);
        }
        let meta = self
            .manifest
            .find_named(artifact)
            .ok_or_else(|| anyhow!("artifact '{artifact}' missing — re-run `make artifacts`"))?
            .clone();
        if !self.executors.contains_key(artifact) {
            let exe = HistogramExecutor::compile(&self.manifest, &meta)?;
            self.executors.insert(artifact.to_string(), exe);
        }
        let exe = &self.executors[artifact];
        let video = SyntheticVideo::new(meta.height, meta.width, 4, 7);
        let img = video.frame(0).binned(meta.bins);
        let samples = time_ms(1, self.reps, || {
            exe.compute_timed(&img).expect("kernel execution failed");
        });
        let ms = Summary::of(&samples).median;
        self.kernel_ms.insert(artifact.to_string(), ms);
        Ok(ms)
    }

    /// Kernel ms for a (strategy, size, bins) point using the tuned
    /// (largest-tile) artifact; `None` if not in the artifact matrix.
    pub fn strategy_kernel_ms(
        &mut self,
        strategy: Strategy,
        h: usize,
        w: usize,
        bins: usize,
    ) -> Result<Option<f64>> {
        let name = match self.manifest.find_strategy(strategy, h, w, bins) {
            Some(m) => m.name.clone(),
            None => return Ok(None),
        };
        Ok(Some(self.kernel_ms(&name)?))
    }
}

/// Format a millisecond value aligned, with `-` for absent points.
pub fn fmt_ms(v: Option<f64>) -> String {
    match v {
        Some(ms) => format!("{ms:>10.2}"),
        None => format!("{:>10}", "-"),
    }
}
