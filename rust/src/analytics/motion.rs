//! Block-wise histogram change detection — the motion-likelihood map of
//! the paper's surveillance applications ([16], [28]).
//!
//! Divide the frame into a grid of blocks; for each block compare its
//! histogram (one Eq. 2 lookup) against the same block in the previous
//! frame.  Blocks whose distribution shifted beyond a threshold are
//! flagged as motion.  Cost per frame: `grid² × bins` — independent of
//! block size, which is exactly the integral histogram's selling point.

use crate::histogram::region::{region_histogram, Rect};
use crate::histogram::types::IntegralHistogram;

/// L1 distance between two histograms normalized to unit mass.
pub fn l1_distance(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    let sa: f32 = a.iter().sum::<f32>().max(1e-9);
    let sb: f32 = b.iter().sum::<f32>().max(1e-9);
    a.iter().zip(b).map(|(&x, &y)| (x / sa - y / sb).abs()).sum()
}

/// Motion detector over a `grid × grid` block decomposition.
#[derive(Debug)]
pub struct MotionDetector {
    grid: usize,
    threshold: f32,
    prev: Option<Vec<Vec<f32>>>,
}

/// Per-frame motion result.
#[derive(Debug, Clone)]
pub struct MotionMap {
    pub grid: usize,
    /// Row-major per-block change scores (L1 distances in [0, 2]).
    pub scores: Vec<f32>,
    pub threshold: f32,
}

impl MotionMap {
    /// Indices of blocks flagged as moving.
    pub fn active_blocks(&self) -> Vec<usize> {
        self.scores
            .iter()
            .enumerate()
            .filter(|(_, &s)| s > self.threshold)
            .map(|(i, _)| i)
            .collect()
    }

    /// Fraction of blocks in motion.
    pub fn activity(&self) -> f32 {
        if self.scores.is_empty() {
            return 0.0;
        }
        self.active_blocks().len() as f32 / self.scores.len() as f32
    }
}

impl MotionDetector {
    pub fn new(grid: usize, threshold: f32) -> MotionDetector {
        assert!(grid >= 1);
        MotionDetector { grid, threshold, prev: None }
    }

    /// Block rectangle (i, j) of the grid over an h×w frame.
    fn block_rect(&self, i: usize, j: usize, h: usize, w: usize) -> Rect {
        let r0 = i * h / self.grid;
        let r1 = ((i + 1) * h / self.grid).max(r0 + 1) - 1;
        let c0 = j * w / self.grid;
        let c1 = ((j + 1) * w / self.grid).max(c0 + 1) - 1;
        Rect::new(r0, c0, r1.min(h - 1), c1.min(w - 1))
    }

    /// Feed the next frame's tensor; first frame yields all-zero scores.
    pub fn step(&mut self, ih: &IntegralHistogram) -> MotionMap {
        let mut hists = Vec::with_capacity(self.grid * self.grid);
        for i in 0..self.grid {
            for j in 0..self.grid {
                hists.push(region_histogram(ih, self.block_rect(i, j, ih.h, ih.w)));
            }
        }
        let scores = match &self.prev {
            None => vec![0.0; hists.len()],
            Some(prev) => prev.iter().zip(&hists).map(|(a, b)| l1_distance(a, b)).collect(),
        };
        self.prev = Some(hists);
        MotionMap { grid: self.grid, scores, threshold: self.threshold }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::histogram::sequential::integral_histogram_seq;
    use crate::histogram::types::BinnedImage;

    fn ih_with_patch(val: i32, at: Option<(usize, usize)>) -> IntegralHistogram {
        let mut data = vec![0i32; 64 * 64];
        if let Some((r, c)) = at {
            for dr in 0..8 {
                for dc in 0..8 {
                    data[(r + dr) * 64 + c + dc] = val;
                }
            }
        }
        integral_histogram_seq(&BinnedImage::new(64, 64, 4, data))
    }

    #[test]
    fn first_frame_is_quiet() {
        let mut det = MotionDetector::new(4, 0.1);
        let m = det.step(&ih_with_patch(3, None));
        assert_eq!(m.active_blocks(), Vec::<usize>::new());
        assert_eq!(m.activity(), 0.0);
    }

    #[test]
    fn static_scene_stays_quiet() {
        let mut det = MotionDetector::new(4, 0.1);
        let ih = ih_with_patch(3, Some((8, 8)));
        det.step(&ih);
        let m = det.step(&ih);
        assert!(m.active_blocks().is_empty());
    }

    #[test]
    fn appearing_patch_fires_its_block() {
        let mut det = MotionDetector::new(4, 0.1);
        det.step(&ih_with_patch(3, None));
        // patch appears inside block (0,0): rows/cols 0..16
        let m = det.step(&ih_with_patch(3, Some((4, 4))));
        assert_eq!(m.active_blocks(), vec![0]);
        assert!(m.activity() > 0.0);
    }

    #[test]
    fn moving_patch_fires_source_and_destination() {
        let mut det = MotionDetector::new(4, 0.1);
        det.step(&ih_with_patch(3, Some((4, 4)))); // block 0
        let m = det.step(&ih_with_patch(3, Some((40, 40)))); // block 10
        let active = m.active_blocks();
        assert!(active.contains(&0), "source block should fire: {active:?}");
        assert!(active.contains(&10), "destination block should fire: {active:?}");
    }

    #[test]
    fn l1_distance_bounds() {
        assert_eq!(l1_distance(&[1.0, 0.0], &[1.0, 0.0]), 0.0);
        let d = l1_distance(&[1.0, 0.0], &[0.0, 1.0]);
        assert!((d - 2.0).abs() < 1e-6, "disjoint unit histograms are distance 2");
    }

    #[test]
    fn block_grid_covers_frame() {
        let det = MotionDetector::new(3, 0.1);
        // union of blocks covers every pixel exactly once
        let mut covered = vec![false; 50 * 70];
        for i in 0..3 {
            for j in 0..3 {
                let r = det.block_rect(i, j, 50, 70);
                for rr in r.r0..=r.r1 {
                    for cc in r.c0..=r.c1 {
                        assert!(!covered[rr * 70 + cc], "overlap at ({rr},{cc})");
                        covered[rr * 70 + cc] = true;
                    }
                }
            }
        }
        assert!(covered.iter().all(|&c| c));
    }
}
