//! Video-analytics consumers built on integral-histogram region queries
//! — the application layer the paper's introduction motivates.
//!
//! * [`tracker`] — histogram-matching object tracker in the style of the
//!   fragments-based tracker the paper cites ([13], Adam et al.):
//!   exhaustive local search scored by histogram intersection, O(1) per
//!   candidate window thanks to Eq. 2.
//! * [`motion`] — block-wise temporal change detector: per-block
//!   histogram distance between consecutive frames (the likelihood-map
//!   building block of the paper's surveillance use cases [16, 28]).

//! * [`search`] — multi-scale exhaustive histogram search with the
//!   O(bins)-per-window cost model (the abstract's "multi-scale
//!   histogram-based search problem").

pub mod motion;
pub mod search;
pub mod tracker;
