//! Multi-scale exhaustive histogram search — the workload the integral
//! histogram was invented for (§1: "an optimum and complete solution
//! for the multi-scale histogram-based search problem").
//!
//! Given a template histogram, scan every window position at every
//! scale and return the best matches.  Cost per candidate is O(bins)
//! regardless of window size (Eq. 2) — without the integral histogram
//! each candidate would cost O(window area).  This module quantifies
//! exactly that trade (see [`naive_cost`] / [`integral_cost`]) and is
//! used by the detection-style examples and the ablation bench.

use crate::histogram::region::{intersection_similarity, region_histogram, Rect};
use crate::histogram::types::IntegralHistogram;

/// One search hit.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Match {
    pub rect: Rect,
    pub score: f32,
}

/// Search configuration.
#[derive(Debug, Clone)]
pub struct SearchConfig {
    /// Window scales as (height, width) pairs.
    pub scales: Vec<(usize, usize)>,
    /// Spatial stride between candidate windows.
    pub stride: usize,
    /// Keep matches scoring at least this (intersection ∈ [0,1]).
    pub min_score: f32,
    /// Maximum matches returned (best-first).
    pub top_k: usize,
}

impl SearchConfig {
    /// Scale pyramid around a base window: ±`levels` steps of `ratio`.
    pub fn pyramid(base_h: usize, base_w: usize, levels: usize, ratio: f64) -> SearchConfig {
        let mut scales = Vec::new();
        for l in 0..=(2 * levels) {
            let f = ratio.powi(l as i32 - levels as i32);
            let h = ((base_h as f64 * f).round() as usize).max(1);
            let w = ((base_w as f64 * f).round() as usize).max(1);
            if !scales.contains(&(h, w)) {
                scales.push((h, w));
            }
        }
        SearchConfig { scales, stride: 4, min_score: 0.5, top_k: 8 }
    }
}

/// Exhaustive multi-scale search of `template` over `ih`.
/// Returns matches sorted best-first, greedily non-overlapping.
pub fn search(ih: &IntegralHistogram, template: &[f32], config: &SearchConfig) -> Vec<Match> {
    assert_eq!(template.len(), ih.bins, "template bins mismatch");
    assert!(config.stride >= 1);
    let mut hits: Vec<Match> = Vec::new();
    for &(wh, ww) in &config.scales {
        if wh > ih.h || ww > ih.w {
            continue;
        }
        let mut r = 0;
        while r + wh <= ih.h {
            let mut c = 0;
            while c + ww <= ih.w {
                let rect = Rect::with_size(r, c, wh, ww);
                let hist = region_histogram(ih, rect);
                let score = intersection_similarity(template, &hist);
                if score >= config.min_score {
                    hits.push(Match { rect, score });
                }
                c += config.stride;
            }
            r += config.stride;
        }
    }
    hits.sort_by(|a, b| b.score.total_cmp(&a.score));
    // greedy non-maximum suppression by center containment
    let mut kept: Vec<Match> = Vec::new();
    for m in hits {
        if kept.len() >= config.top_k {
            break;
        }
        let cr = (m.rect.r0 + m.rect.r1) / 2;
        let cc = (m.rect.c0 + m.rect.c1) / 2;
        let overlaps = kept.iter().any(|k| {
            cr >= k.rect.r0 && cr <= k.rect.r1 && cc >= k.rect.c0 && cc <= k.rect.c1
        });
        if !overlaps {
            kept.push(m);
        }
    }
    kept
}

/// Candidate-window count of a search (the workload model).
pub fn candidate_count(h: usize, w: usize, config: &SearchConfig) -> usize {
    let mut n = 0;
    for &(wh, ww) in &config.scales {
        if wh > h || ww > w {
            continue;
        }
        let rows = (h - wh) / config.stride + 1;
        let cols = (w - ww) / config.stride + 1;
        n += rows * cols;
    }
    n
}

/// Element operations for the naive per-window histogram approach:
/// Σ windows × window-area (what §2.1 calls the exhaustive problem).
pub fn naive_cost(h: usize, w: usize, config: &SearchConfig) -> u64 {
    let mut ops = 0u64;
    for &(wh, ww) in &config.scales {
        if wh > h || ww > w {
            continue;
        }
        let rows = ((h - wh) / config.stride + 1) as u64;
        let cols = ((w - ww) / config.stride + 1) as u64;
        ops += rows * cols * (wh as u64) * (ww as u64);
    }
    ops
}

/// Element operations with the integral histogram: build (2 passes of
/// b·h·w) + 4·bins reads per candidate — constant per window (Eq. 2).
pub fn integral_cost(h: usize, w: usize, bins: usize, config: &SearchConfig) -> u64 {
    let build = 2 * (bins * h * w) as u64;
    build + candidate_count(h, w, config) as u64 * 4 * bins as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::histogram::sequential::integral_histogram_seq;
    use crate::histogram::types::BinnedImage;

    /// Image with one 8×8 patch of bin 3 at (r, c) on a bin-0 background.
    fn scene(r: usize, c: usize) -> IntegralHistogram {
        let mut data = vec![0i32; 64 * 64];
        for dr in 0..8 {
            for dc in 0..8 {
                data[(r + dr) * 64 + c + dc] = 3;
            }
        }
        integral_histogram_seq(&BinnedImage::new(64, 64, 4, data))
    }

    fn template() -> Vec<f32> {
        let mut t = vec![0.0f32; 4];
        t[3] = 64.0; // pure bin-3 patch of 8×8
        t
    }

    #[test]
    fn finds_the_patch_at_exact_scale() {
        let ih = scene(24, 40);
        let cfg = SearchConfig { scales: vec![(8, 8)], stride: 1, min_score: 0.9, top_k: 3 };
        let hits = search(&ih, &template(), &cfg);
        assert!(!hits.is_empty());
        assert_eq!((hits[0].rect.r0, hits[0].rect.c0), (24, 40));
        assert!((hits[0].score - 1.0).abs() < 1e-5);
    }

    #[test]
    fn pyramid_search_finds_scaled_patch() {
        let ih = scene(10, 10);
        let cfg = SearchConfig { stride: 2, min_score: 0.8, top_k: 2, ..SearchConfig::pyramid(16, 16, 1, 2.0) };
        // pyramid around 16×16 with ratio 2 includes the true 8×8 scale
        assert!(cfg.scales.contains(&(8, 8)));
        let hits = search(&ih, &template(), &cfg);
        assert!(!hits.is_empty());
        let best = hits[0].rect;
        assert_eq!(best.height(), 8, "should lock onto the true scale");
        assert_eq!((best.r0, best.c0), (10, 10));
    }

    #[test]
    fn nms_suppresses_overlaps() {
        let ih = scene(20, 20);
        let cfg = SearchConfig { scales: vec![(8, 8)], stride: 1, min_score: 0.5, top_k: 10 };
        let hits = search(&ih, &template(), &cfg);
        // many raw candidates overlap the patch; NMS keeps non-overlapping reps
        for (i, a) in hits.iter().enumerate() {
            for b in &hits[i + 1..] {
                let cr = (b.rect.r0 + b.rect.r1) / 2;
                let cc = (b.rect.c0 + b.rect.c1) / 2;
                assert!(
                    !(cr >= a.rect.r0 && cr <= a.rect.r1 && cc >= a.rect.c0 && cc <= a.rect.c1),
                    "center of {b:?} inside {a:?}"
                );
            }
        }
    }

    #[test]
    fn empty_when_nothing_matches() {
        let ih = scene(0, 0);
        let mut t = vec![0.0f32; 4];
        t[1] = 1.0; // bin 1 never appears
        let cfg = SearchConfig { scales: vec![(8, 8)], stride: 4, min_score: 0.5, top_k: 4 };
        assert!(search(&ih, &t, &cfg).is_empty());
    }

    #[test]
    fn cost_model_favours_integral() {
        let cfg = SearchConfig { scales: vec![(32, 32), (64, 64)], stride: 2, min_score: 0.5, top_k: 4 };
        let naive = naive_cost(512, 512, &cfg);
        let fast = integral_cost(512, 512, 32, &cfg);
        assert!(
            naive > 5 * fast,
            "integral histogram must dominate exhaustive search (naive {naive} vs {fast})"
        );
        // the one-off build cost amortizes: per-query advantage is larger
        let per_query_naive = naive / candidate_count(512, 512, &cfg) as u64;
        assert!(per_query_naive > 4 * 32 * 4, "per-candidate Eq. 2 is 4·bins reads");
    }

    #[test]
    fn candidate_count_matches_loop() {
        let cfg = SearchConfig { scales: vec![(8, 8), (16, 16)], stride: 4, min_score: 0.0, top_k: 1 };
        let mut n = 0;
        for &(wh, ww) in &cfg.scales {
            let mut r = 0;
            while r + wh <= 64 {
                let mut c = 0;
                while c + ww <= 64 {
                    n += 1;
                    c += cfg.stride;
                }
                r += cfg.stride;
            }
        }
        assert_eq!(candidate_count(64, 64, &cfg), n);
    }
}
