//! Histogram-matching tracker (fragments-style, per the paper's ref [13]).
//!
//! Track = a template histogram plus a current rectangle.  Per frame:
//! exhaustive search of candidate windows in a radius around the last
//! position, each scored in O(bins) with Eq. 2 region lookups — the
//! workload the integral histogram makes real-time ("histogram-based
//! exhaustive search", §2.1).

use crate::histogram::region::{intersection_similarity, region_histogram, Rect};
use crate::histogram::types::IntegralHistogram;

/// Tracker configuration.
#[derive(Debug, Clone, Copy)]
pub struct TrackerConfig {
    /// Search radius around the previous position, pixels.
    pub radius: usize,
    /// Search stride (1 = dense exhaustive search).
    pub stride: usize,
    /// Template adaptation rate in [0, 1): 0 = fixed template.
    pub adapt: f32,
}

impl Default for TrackerConfig {
    fn default() -> Self {
        TrackerConfig { radius: 12, stride: 1, adapt: 0.05 }
    }
}

/// One tracked object.
#[derive(Debug, Clone)]
pub struct Track {
    pub rect: Rect,
    pub template: Vec<f32>,
    pub score: f32,
    config: TrackerConfig,
}

impl Track {
    /// Initialize from the object's rectangle in the first frame.
    pub fn init(ih: &IntegralHistogram, rect: Rect, config: TrackerConfig) -> Track {
        let template = region_histogram(ih, rect);
        Track { rect, template, score: 1.0, config }
    }

    /// Advance to the next frame's tensor: exhaustive window search
    /// around the previous location, histogram-intersection scored.
    pub fn step(&mut self, ih: &IntegralHistogram) -> Rect {
        let (hgt, wid) = (self.rect.height(), self.rect.width());
        let cfg = self.config;
        let r_min = self.rect.r0.saturating_sub(cfg.radius);
        let c_min = self.rect.c0.saturating_sub(cfg.radius);
        let r_max = (self.rect.r0 + cfg.radius).min(ih.h.saturating_sub(hgt));
        let c_max = (self.rect.c0 + cfg.radius).min(ih.w.saturating_sub(wid));
        let mut best = (f32::MIN, self.rect);
        let mut r = r_min;
        while r <= r_max {
            let mut c = c_min;
            while c <= c_max {
                let cand = Rect::with_size(r, c, hgt, wid);
                let hist = region_histogram(ih, cand);
                let s = intersection_similarity(&self.template, &hist);
                if s > best.0 {
                    best = (s, cand);
                }
                c += cfg.stride;
            }
            r += cfg.stride;
        }
        self.score = best.0;
        self.rect = best.1;
        if cfg.adapt > 0.0 {
            let new = region_histogram(ih, self.rect);
            for (t, n) in self.template.iter_mut().zip(new) {
                *t = *t * (1.0 - cfg.adapt) + n * cfg.adapt;
            }
        }
        self.rect
    }

    /// Number of candidate windows evaluated per step (workload model
    /// for the figure narratives).
    pub fn candidates_per_step(&self) -> usize {
        let n = 2 * self.config.radius / self.config.stride + 1;
        n * n
    }
}

/// Center distance between two rects (tracking-error metric).
pub fn center_distance(a: Rect, b: Rect) -> f64 {
    let ac = ((a.r0 + a.r1) as f64 / 2.0, (a.c0 + a.c1) as f64 / 2.0);
    let bc = ((b.r0 + b.r1) as f64 / 2.0, (b.c0 + b.c1) as f64 / 2.0);
    ((ac.0 - bc.0).powi(2) + (ac.1 - bc.1).powi(2)).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::histogram::sequential::integral_histogram_seq;
    use crate::histogram::types::BinnedImage;

    /// Build a frame with a distinctive block at (r, c).
    fn frame_with_block(h: usize, w: usize, r: usize, c: usize) -> IntegralHistogram {
        let mut data = vec![0i32; h * w];
        for dr in 0..6 {
            for dc in 0..6 {
                data[(r + dr) * w + c + dc] = 3;
            }
        }
        integral_histogram_seq(&BinnedImage::new(h, w, 4, data))
    }

    #[test]
    fn tracks_a_moving_block() {
        let cfg = TrackerConfig { radius: 6, stride: 1, adapt: 0.0 };
        let ih0 = frame_with_block(48, 48, 10, 10);
        let mut track = Track::init(&ih0, Rect::with_size(10, 10, 6, 6), cfg);
        // move the block by (3, 4) per frame; tracker should follow
        for step in 1..5 {
            let pos = (10 + 3 * step, 10 + 4 * step);
            let ih = frame_with_block(48, 48, pos.0, pos.1);
            let r = track.step(&ih);
            assert_eq!((r.r0, r.c0), pos, "step {step}");
            assert!(track.score > 0.99);
        }
    }

    #[test]
    fn lost_object_keeps_low_score() {
        let cfg = TrackerConfig { radius: 4, stride: 1, adapt: 0.0 };
        let ih0 = frame_with_block(48, 48, 10, 10);
        let mut track = Track::init(&ih0, Rect::with_size(10, 10, 6, 6), cfg);
        // object teleports far outside the search radius
        let ih = frame_with_block(48, 48, 40, 40);
        track.step(&ih);
        assert!(track.score < 0.5, "score {}", track.score);
    }

    #[test]
    fn candidates_count() {
        let cfg = TrackerConfig { radius: 6, stride: 2, adapt: 0.0 };
        let ih = frame_with_block(32, 32, 5, 5);
        let t = Track::init(&ih, Rect::with_size(5, 5, 6, 6), cfg);
        assert_eq!(t.candidates_per_step(), 49);
    }

    #[test]
    fn center_distance_metric() {
        let a = Rect::with_size(0, 0, 2, 2);
        let b = Rect::with_size(3, 4, 2, 2);
        assert!((center_distance(a, b) - 5.0).abs() < 1e-9);
        assert_eq!(center_distance(a, a), 0.0);
    }

    #[test]
    fn adaptation_moves_template() {
        let cfg = TrackerConfig { radius: 2, stride: 1, adapt: 0.5 };
        let ih = frame_with_block(32, 32, 8, 8);
        let mut t = Track::init(&ih, Rect::with_size(8, 8, 6, 6), cfg);
        let before = t.template.clone();
        // the object vanishes: the best match is background, so the
        // adaptive template must drift toward it
        let empty = integral_histogram_seq(&BinnedImage::new(32, 32, 4, vec![0i32; 32 * 32]));
        t.step(&empty);
        assert_ne!(before, t.template);
    }
}
