//! `Server` — the shared, persistent multi-stream serving layer.
//!
//! The paper's system sections describe a *service*: dual-buffered
//! streams keep one kernel at 300 fps (§4.4) and a bin task queue
//! spreads one oversized frame across devices (§4.6).  The
//! single-session [`crate::coordinator::router::Engine`] can replay
//! that for one stream; production traffic means *many* concurrent
//! streams ("Fast Histograms using Adaptive CUDA Streams", PAPERS.md)
//! issuing many small region queries each ("Multi-Scale Spatially
//! Weighted Local Histograms in O(1)").  This module is the shared
//! front door:
//!
//! * **`&self` compute.**  All cross-stream state is interior-mutable —
//!   the [`CompileCache`], one server-wide [`FramePool`] arena, a
//!   checkout stack of [`ScanEngine`] lanes (each owning its persistent
//!   parked [`WorkerPool`](crate::histogram::engine::WorkerPool)), and
//!   the lazily-built [`BinTaskQueue`] — so any number of threads call
//!   [`Server::compute`] concurrently.  Steady state does zero heap
//!   allocation and zero thread spawning per frame
//!   (`tests/server_concurrency.rs` counter-asserts both).
//! * **One front door for every size.**  [`Server::compute`] routes
//!   small frames to the artifact path (CPU `ScanEngine` fallback in
//!   the offline build) and frames whose tensor exceeds the device
//!   budget through the shared bin task queue — sessions never care
//!   which.
//! * **Sessions.**  [`Server::open_session`] hands out a per-stream
//!   [`Session`] owning a [`CpuPipeline`] lane (recycling through the
//!   server arena), a [`QueryBatcher`], and an optional analytics
//!   attachment (motion detector / tracker).  Admission control is a
//!   bounded [`backpressure`](crate::coordinator::backpressure) queue:
//!   capacity = `max_sessions`, occupancy = live sessions, high-water =
//!   peak concurrency — over-capacity `open_session` calls are rejected,
//!   not queued, so an overloaded server degrades predictably.
//! * **Metrics.**  Global frame/query/session counters plus a latency
//!   reservoir summarized as p50/p95/p99 + jitter
//!   ([`LatencySummary`]), and per-session latency histories.

use crate::analytics::motion::{MotionDetector, MotionMap};
use crate::analytics::tracker::{Track, TrackerConfig};
use crate::coordinator::backpressure::{bounded, BoundedReceiver, BoundedSender, QueueStats};
use crate::coordinator::batcher::{QueryBatcher, QueryResponse};
use crate::coordinator::frame_pool::{FramePool, PoolStats, PooledTensor};
use crate::coordinator::metrics::LatencySummary;
use crate::coordinator::pipeline::{CpuPipeline, CpuPipelineConfig, PipelineReport};
use crate::coordinator::router::{EngineConfig, Route};
use crate::coordinator::task_queue::BinTaskQueue;
use crate::histogram::engine::ScanEngine;
use crate::histogram::region::Rect;
use crate::histogram::types::{BinnedImage, IntegralHistogram};
use crate::runtime::artifact::ArtifactManifest;
use crate::runtime::compile_cache::CompileCache;
use crate::video::source::{FrameSource, VideoFrame};
use anyhow::{anyhow, Result};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Serving configuration: routing/fallback knobs come from the
/// existing [`EngineConfig`]; the rest is multi-stream policy.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Routing, strategy, budgets and CPU-fallback policy.
    pub engine: EngineConfig,
    /// Hard cap on concurrently open sessions (admission control).
    pub max_sessions: usize,
    /// Pipeline depth of each session's lane (2 = dual buffering).
    pub lanes: usize,
    /// `ScanEngine` worker budget per stream lane / checkout engine.
    /// Small on purpose: cross-stream parallelism comes from running
    /// streams concurrently, not from one stream grabbing every core.
    pub workers_per_stream: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            engine: EngineConfig::default(),
            max_sessions: 64,
            lanes: 2,
            workers_per_stream: 2,
        }
    }
}

/// Capacity of the global latency reservoir (ring overwrite beyond).
const LATENCY_RESERVOIR: usize = 1 << 16;
/// Capacity of each session's latency history — bounded so long-lived
/// streams (hours at video rate) don't grow memory per frame.
const SESSION_LATENCY_RESERVOIR: usize = 1 << 12;

/// Bounded latency sample ring: keeps the most recent `cap` samples,
/// overwriting the oldest.  Percentiles over the ring describe the
/// recent serving window; jitter is exact until the first wrap.
struct LatencyRing {
    buf: Vec<f64>,
    count: usize,
    cap: usize,
}

impl LatencyRing {
    fn with_cap(cap: usize) -> LatencyRing {
        LatencyRing { buf: Vec::new(), count: 0, cap }
    }

    fn push(&mut self, ms: f64) {
        if self.buf.len() < self.cap {
            self.buf.push(ms);
        } else {
            self.buf[self.count % self.cap] = ms;
        }
        self.count += 1;
    }

    fn clear(&mut self) {
        self.buf.clear();
        self.count = 0;
    }
}

struct Metrics {
    frames: AtomicUsize,
    queries: AtomicUsize,
    sessions_opened: AtomicUsize,
    sessions_rejected: AtomicUsize,
    latencies_ms: Mutex<LatencyRing>,
}

impl Default for Metrics {
    fn default() -> Metrics {
        Metrics {
            frames: AtomicUsize::new(0),
            queries: AtomicUsize::new(0),
            sessions_opened: AtomicUsize::new(0),
            sessions_rejected: AtomicUsize::new(0),
            latencies_ms: Mutex::new(LatencyRing::with_cap(LATENCY_RESERVOIR)),
        }
    }
}

impl Metrics {
    fn push_latency(&self, ms: f64) {
        self.latencies_ms.lock().expect("latency lock").push(ms);
    }
}

/// Point-in-time view of the server's global counters.
#[derive(Debug, Clone)]
pub struct ServerSnapshot {
    /// Frames computed through [`Server::compute`] (all routes).
    pub frames: usize,
    /// Region queries answered through sessions.
    pub queries: usize,
    pub sessions_opened: usize,
    pub sessions_rejected: usize,
    pub sessions_active: usize,
    /// Peak concurrently-open sessions.
    pub sessions_peak: usize,
    /// CPU engines ever created for the checkout stack — flat in
    /// steady state (each checkout reuses a parked engine).
    pub engines_created: usize,
    /// Engines currently parked on the checkout stack.
    pub engines_idle: usize,
    /// Worker threads ever spawned by the idle engines' pools — flat
    /// in steady state (read at quiescence; checked-out engines are
    /// not visible).
    pub threads_spawned: usize,
    /// Pool jobs dispatched by the idle engines (≈ parallel frames).
    pub pool_jobs: usize,
    /// The shared tensor arena's counters.
    pub frame_pool: PoolStats,
    /// p50/p95/p99 + jitter over the global latency reservoir.
    pub latency: LatencySummary,
}

struct Inner {
    config: ServerConfig,
    compile: CompileCache,
    pool: Arc<FramePool>,
    /// Parked CPU engines, checked out per in-flight compute.  LIFO so
    /// the hottest engine (warm scratch, spawned pool) is reused first.
    engines: Mutex<Vec<ScanEngine>>,
    engines_created: AtomicUsize,
    /// Shared large-image path: the queue plus the `(h, w)` it was
    /// built for (queues are geometry-bound — a different large
    /// geometry rebuilds).  The mutex both lazily builds the queue and
    /// serializes whole-frame jobs on it — the queue owns the device
    /// pool, and interleaving two frames' bin groups would cross their
    /// results.
    large: Mutex<Option<(usize, usize, BinTaskQueue)>>,
    metrics: Metrics,
    admission_tx: Mutex<BoundedSender<()>>,
    admission_rx: Mutex<BoundedReceiver<()>>,
    admission_stats: Arc<QueueStats>,
    session_seq: AtomicUsize,
}

impl Inner {
    fn route_for(&self, h: usize, w: usize) -> Route {
        self.config.engine.route_for(h, w)
    }

    fn cpu_allowed(&self, img: &BinnedImage) -> bool {
        self.config.engine.cpu_fallback_allowed(img)
    }

    /// Serve a frame on a checked-out CPU engine with pooled storage.
    fn compute_cpu(&self, img: &BinnedImage) -> Result<(PooledTensor, Duration)> {
        let t0 = Instant::now();
        let mut engine = match self.engines.lock().expect("engine stack lock").pop() {
            Some(e) => e,
            None => {
                self.engines_created.fetch_add(1, Ordering::Relaxed);
                ScanEngine::new(self.config.workers_per_stream)
            }
        };
        let mut out = PooledTensor::acquire(&self.pool, img.bins, img.h, img.w);
        engine.compute_into(img, &mut out);
        self.engines.lock().expect("engine stack lock").push(engine);
        Ok((out, t0.elapsed()))
    }

    /// Large-image route: the shared bin task queue (§4.6), built on
    /// first use from the group-bin artifact matching this geometry.
    fn compute_large(&self, img: &BinnedImage) -> Result<(IntegralHistogram, Duration)> {
        let mut guard = self.large.lock().expect("task queue lock");
        let stale = !matches!(&*guard, Some((h, w, _)) if (*h, *w) == (img.h, img.w));
        if stale {
            let queue = self.config.engine.build_bin_task_queue(
                self.compile.manifest(),
                img.h,
                img.w,
            )?;
            *guard = Some((img.h, img.w, queue));
        }
        let queue = &guard.as_ref().expect("queue just built").2;
        let image = Arc::new(img.clone());
        let (ih, report) = queue.compute(&image, img.bins)?;
        Ok((ih, report.wall))
    }

    /// The shared front door: route, compute, account.
    fn compute(&self, img: &BinnedImage) -> Result<(PooledTensor, Duration)> {
        let res = match self.route_for(img.h, img.w) {
            Route::Direct => {
                let strategy = self.config.engine.strategy;
                // Memoized availability check: when no artifact matches
                // (always true offline), the steady-state CPU path runs
                // with no per-frame manifest scans or error strings.
                if self.cpu_allowed(img)
                    && !self.compile.has_strategy(strategy, img.h, img.w, img.bins)
                {
                    self.compute_cpu(img)
                } else {
                    match self.compile.strategy_executor(strategy, img.h, img.w, img.bins) {
                        Ok(exe) => exe
                            .compute_timed(img)
                            .map(|(ih, d)| (PooledTensor::adopt(&self.pool, ih), d)),
                        Err(_) if self.cpu_allowed(img) => self.compute_cpu(img),
                        Err(e) => Err(e),
                    }
                }
            }
            Route::TaskQueue => match self.compute_large(img) {
                Ok((ih, wall)) => Ok((PooledTensor::adopt(&self.pool, ih), wall)),
                Err(_) if self.cpu_allowed(img) => self.compute_cpu(img),
                Err(e) => Err(e),
            },
        };
        if let Ok((_, d)) = &res {
            self.metrics.frames.fetch_add(1, Ordering::Relaxed);
            self.metrics.push_latency(d.as_secs_f64() * 1e3);
        }
        res
    }
}

/// The shared serving front door.  Cheap to clone (an `Arc` handle);
/// every method takes `&self` and is safe from any number of threads.
#[derive(Clone)]
pub struct Server {
    inner: Arc<Inner>,
}

impl Server {
    pub fn new(manifest: Arc<ArtifactManifest>, config: ServerConfig) -> Server {
        let (admission_tx, admission_rx, admission_stats) =
            bounded::<()>(config.max_sessions.max(1));
        Server {
            inner: Arc::new(Inner {
                compile: CompileCache::new(manifest),
                pool: Arc::new(FramePool::new()),
                engines: Mutex::new(Vec::new()),
                engines_created: AtomicUsize::new(0),
                large: Mutex::new(None),
                metrics: Metrics::default(),
                admission_tx: Mutex::new(admission_tx),
                admission_rx: Mutex::new(admission_rx),
                admission_stats,
                session_seq: AtomicUsize::new(0),
                config,
            }),
        }
    }

    pub fn config(&self) -> &ServerConfig {
        &self.inner.config
    }

    /// Routing decision for an `h×w` frame at the configured bin count.
    pub fn route_for(&self, h: usize, w: usize) -> Route {
        self.inner.route_for(h, w)
    }

    /// Compute the integral histogram of an already-binned image —
    /// callable concurrently from any thread; results are bit-identical
    /// to serial execution.  Returns the pooled tensor (recycled into
    /// the server arena on drop) and the compute duration.
    pub fn compute(&self, img: &BinnedImage) -> Result<(PooledTensor, Duration)> {
        self.inner.compute(img)
    }

    /// Admit a new stream.  Rejected (not queued) once `max_sessions`
    /// sessions are live; the slot frees when the `Session` drops.
    pub fn open_session(&self) -> Result<Session> {
        let admitted = self
            .inner
            .admission_tx
            .lock()
            .expect("admission lock")
            .try_send(())
            .is_ok();
        if !admitted {
            self.inner.metrics.sessions_rejected.fetch_add(1, Ordering::Relaxed);
            return Err(anyhow!(
                "admission rejected: {} sessions live (max {})",
                self.inner.admission_stats.depth(),
                self.inner.config.max_sessions
            ));
        }
        self.inner.metrics.sessions_opened.fetch_add(1, Ordering::Relaxed);
        let id = self.inner.session_seq.fetch_add(1, Ordering::Relaxed) as u64;
        let cfg = &self.inner.config;
        let lane_cfg = CpuPipelineConfig::new(cfg.engine.bins)
            .lanes(cfg.lanes)
            .workers(cfg.workers_per_stream);
        let pipeline = CpuPipeline::with_pool(lane_cfg, Arc::clone(&self.inner.pool));
        Ok(Session {
            inner: Arc::clone(&self.inner),
            id,
            bins: cfg.engine.bins,
            img: BinnedImage::new(0, 0, 1, Vec::new()),
            pipeline,
            batcher: QueryBatcher::new(),
            analytics: None,
            latencies_ms: LatencyRing::with_cap(SESSION_LATENCY_RESERVOIR),
            frames: 0,
            queries: 0,
        })
    }

    /// Currently live sessions.
    pub fn sessions_active(&self) -> usize {
        self.inner.admission_stats.depth()
    }

    /// Drop compiled executors and negative compile results (e.g.
    /// after regenerating `artifacts/`).
    pub fn clear_compile_cache(&self) {
        self.inner.compile.clear();
    }

    /// Clear the global latency reservoir, starting a fresh
    /// measurement window — call after warm-up so reported percentiles
    /// describe steady-state serving, not cold-start frames.  Counters
    /// (frames, sessions, arena, pools) are unaffected.
    pub fn reset_latency_stats(&self) {
        self.inner.metrics.latencies_ms.lock().expect("latency lock").clear();
    }

    /// Snapshot the global counters.  `threads_spawned`/`pool_jobs`
    /// aggregate the *idle* checkout engines — read at quiescence for
    /// the steady-state assertions.
    pub fn snapshot(&self) -> ServerSnapshot {
        let inner = &self.inner;
        let (engines_idle, threads_spawned, pool_jobs) = {
            let engines = inner.engines.lock().expect("engine stack lock");
            let mut spawned = 0;
            let mut jobs = 0;
            for e in engines.iter() {
                let s = e.pool_stats();
                spawned += s.spawned;
                jobs += s.jobs;
            }
            (engines.len(), spawned, jobs)
        };
        let latency = {
            let ring = inner.metrics.latencies_ms.lock().expect("latency lock");
            LatencySummary::of_ms(&ring.buf)
        };
        ServerSnapshot {
            frames: inner.metrics.frames.load(Ordering::Relaxed),
            queries: inner.metrics.queries.load(Ordering::Relaxed),
            sessions_opened: inner.metrics.sessions_opened.load(Ordering::Relaxed),
            sessions_rejected: inner.metrics.sessions_rejected.load(Ordering::Relaxed),
            sessions_active: inner.admission_stats.depth(),
            sessions_peak: inner.admission_stats.high_water(),
            engines_created: inner.engines_created.load(Ordering::Relaxed),
            engines_idle,
            threads_spawned,
            pool_jobs,
            frame_pool: inner.pool.stats(),
            latency,
        }
    }
}

/// Analytics attachment of a session — the downstream consumers the
/// paper's introduction motivates, fed from the session's own tensors.
pub enum SessionAnalytics {
    Motion(MotionDetector),
    Tracker(Track),
}

/// What an analytics step produced.
#[derive(Debug, Clone)]
pub enum AnalyticsEvent {
    Motion(MotionMap),
    Track(Rect),
}

/// Per-session (stream-local) counters.
#[derive(Debug, Clone)]
pub struct SessionSnapshot {
    pub id: u64,
    pub frames: usize,
    pub queries: usize,
    /// (answered, unique-computed) batcher counters.
    pub batcher: (usize, usize),
    pub latency: LatencySummary,
}

/// One stream's handle on the server: a pipeline lane, a query
/// batcher, an optional analytics attachment, and stream-local
/// metrics.  Owns an admission slot; dropping the session frees it.
///
/// `Session` is `Send` — open it on one thread, drive it from another.
pub struct Session {
    inner: Arc<Inner>,
    id: u64,
    bins: usize,
    /// Recycled quantization buffer (no per-frame image allocation).
    img: BinnedImage,
    pipeline: CpuPipeline,
    batcher: QueryBatcher,
    analytics: Option<SessionAnalytics>,
    /// Bounded recent-latency history (ring; see
    /// [`SESSION_LATENCY_RESERVOIR`]).
    latencies_ms: LatencyRing,
    frames: usize,
    queries: usize,
}

impl Session {
    pub fn id(&self) -> u64 {
        self.id
    }

    pub fn bins(&self) -> usize {
        self.bins
    }

    /// Compute one frame through the server front door (any route).
    /// The returned tensor recycles into the server arena on drop.
    pub fn process(&mut self, frame: &VideoFrame) -> Result<PooledTensor> {
        let t0 = Instant::now();
        frame.binned_into(self.bins, &mut self.img);
        let (ih, _kernel) = self.inner.compute(&self.img)?;
        self.frames += 1;
        self.latencies_ms.push(t0.elapsed().as_secs_f64() * 1e3);
        Ok(ih)
    }

    /// Drive a whole stream through this session's pipeline lane
    /// (read → compute → sink overlapped across `lanes` frames),
    /// folding the run's per-frame latencies into the session and
    /// server metrics.
    pub fn run_stream(
        &mut self,
        source: Box<dyn FrameSource>,
        sink: impl FnMut(usize, PooledTensor) + Send,
    ) -> Result<PipelineReport> {
        let report = self.pipeline.run_with(source, sink)?;
        self.frames += report.throughput.frames;
        self.inner.metrics.frames.fetch_add(report.throughput.frames, Ordering::Relaxed);
        for s in &report.throughput.stats {
            let ms = s.latency.as_secs_f64() * 1e3;
            self.latencies_ms.push(ms);
            self.inner.metrics.push_latency(ms);
        }
        Ok(report)
    }

    /// Enqueue a region query for the next [`Self::answer_queries`].
    pub fn submit_query(&mut self, id: u64, rect: Rect) {
        self.batcher.submit(id, rect);
    }

    /// Pending (unanswered) queries.
    pub fn pending_queries(&self) -> usize {
        self.batcher.pending()
    }

    /// Answer every pending query against `ih` (deduplicated,
    /// submission order preserved — see [`QueryBatcher`]).
    pub fn answer_queries(&mut self, ih: &IntegralHistogram) -> Vec<QueryResponse> {
        let responses = self.batcher.flush(ih);
        self.queries += responses.len();
        self.inner.metrics.queries.fetch_add(responses.len(), Ordering::Relaxed);
        responses
    }

    /// Attach a block-motion detector (replaces any attachment).
    pub fn attach_motion(&mut self, grid: usize, threshold: f32) {
        self.analytics = Some(SessionAnalytics::Motion(MotionDetector::new(grid, threshold)));
    }

    /// Attach a histogram-matching tracker initialized from `rect` in
    /// `ih` (replaces any attachment).
    pub fn attach_tracker(&mut self, ih: &IntegralHistogram, rect: Rect, config: TrackerConfig) {
        self.analytics = Some(SessionAnalytics::Tracker(Track::init(ih, rect, config)));
    }

    pub fn detach_analytics(&mut self) -> Option<SessionAnalytics> {
        self.analytics.take()
    }

    /// Advance the attachment on this frame's tensor, if any.
    pub fn step_analytics(&mut self, ih: &IntegralHistogram) -> Option<AnalyticsEvent> {
        match self.analytics.as_mut()? {
            SessionAnalytics::Motion(m) => Some(AnalyticsEvent::Motion(m.step(ih))),
            SessionAnalytics::Tracker(t) => Some(AnalyticsEvent::Track(t.step(ih))),
        }
    }

    /// The lane engine's worker-pool counters (zero-spawn assertions).
    pub fn lane_pool_stats(&self) -> crate::histogram::engine::WorkerPoolStats {
        self.pipeline.engine_pool_stats()
    }

    /// Stream-local counters and latency distribution (over the most
    /// recent [`SESSION_LATENCY_RESERVOIR`] frames).
    pub fn stats(&self) -> SessionSnapshot {
        SessionSnapshot {
            id: self.id,
            frames: self.frames,
            queries: self.queries,
            batcher: self.batcher.stats(),
            latency: LatencySummary::of_ms(&self.latencies_ms.buf),
        }
    }
}

impl Drop for Session {
    fn drop(&mut self) {
        // Return the admission slot.
        if let Ok(rx) = self.inner.admission_rx.lock() {
            let _ = rx.try_recv();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::histogram::region::region_histogram;
    use crate::histogram::sequential::integral_histogram_seq;
    use crate::video::synth::SyntheticVideo;
    use std::path::PathBuf;

    fn manifest() -> Arc<ArtifactManifest> {
        Arc::new(ArtifactManifest {
            dir: PathBuf::from("/nonexistent"),
            profile: "test".into(),
            artifacts: vec![],
        })
    }

    fn server() -> Server {
        Server::new(manifest(), ServerConfig::default())
    }

    #[test]
    fn compute_is_bit_identical_to_serial() {
        let srv = server();
        let video = SyntheticVideo::new(96, 80, 2, 3);
        for t in 0..3 {
            let img = video.frame(t).binned(8);
            let (ih, _) = srv.compute(&img).expect("cpu route");
            let expected = integral_histogram_seq(&img);
            assert_eq!(expected.max_abs_diff(&ih), 0.0, "frame {t}");
        }
        let snap = srv.snapshot();
        assert_eq!(snap.frames, 3);
        assert_eq!(snap.engines_created, 1, "one checkout engine serves serial traffic");
        assert_eq!(snap.latency.n, 3);
        // all three tensors recycled through one arena buffer
        let fp = snap.frame_pool;
        assert_eq!(fp.allocated, 1, "{fp:?}");
        assert_eq!(fp.reused, 2);
    }

    #[test]
    fn latency_window_resets_without_touching_counters() {
        let srv = server();
        let img = SyntheticVideo::new(48, 48, 1, 1).frame(0).binned(8);
        for _ in 0..4 {
            let _ = srv.compute(&img).expect("compute");
        }
        assert_eq!(srv.snapshot().latency.n, 4);
        srv.reset_latency_stats();
        let snap = srv.snapshot();
        assert_eq!(snap.latency.n, 0, "reservoir cleared");
        assert_eq!(snap.frames, 4, "counters survive the window reset");
        let _ = srv.compute(&img).expect("compute");
        assert_eq!(srv.snapshot().latency.n, 1);
    }

    #[test]
    fn admission_control_caps_sessions() {
        let mut cfg = ServerConfig::default();
        cfg.max_sessions = 2;
        let srv = Server::new(manifest(), cfg);
        let s1 = srv.open_session().expect("slot 1");
        let _s2 = srv.open_session().expect("slot 2");
        assert_eq!(srv.sessions_active(), 2);
        let err = srv.open_session().err().expect("must reject").to_string();
        assert!(err.contains("admission"), "{err}");
        drop(s1);
        assert_eq!(srv.sessions_active(), 1);
        let _s3 = srv.open_session().expect("slot freed by drop");
        let snap = srv.snapshot();
        assert_eq!(snap.sessions_opened, 3);
        assert_eq!(snap.sessions_rejected, 1);
        assert_eq!(snap.sessions_peak, 2);
    }

    #[test]
    fn session_processes_and_answers_queries() {
        let srv = server();
        let mut session = srv.open_session().expect("session");
        let video = SyntheticVideo::new(64, 64, 2, 5);
        let frame = video.frame(0);
        let ih = session.process(&frame).expect("process");
        let expected = integral_histogram_seq(&frame.binned(32));
        assert_eq!(expected.max_abs_diff(&ih), 0.0);

        let r1 = Rect::with_size(0, 0, 64, 64);
        let r2 = Rect::with_size(5, 9, 20, 30);
        session.submit_query(10, r1);
        session.submit_query(11, r2);
        session.submit_query(12, r1); // duplicate — deduped
        let rs = session.answer_queries(&ih);
        assert_eq!(rs.len(), 3);
        assert_eq!(rs[0].id, 10);
        assert_eq!(rs[2].id, 12);
        assert_eq!(rs[0].histogram, region_histogram(&expected, r1));
        assert_eq!(rs[1].histogram, region_histogram(&expected, r2));
        assert_eq!(rs[0].histogram, rs[2].histogram);

        let st = session.stats();
        assert_eq!(st.frames, 1);
        assert_eq!(st.queries, 3);
        assert_eq!(st.batcher, (3, 2), "duplicate rect computed once");
        assert_eq!(st.latency.n, 1);
        assert_eq!(srv.snapshot().queries, 3);
    }

    #[test]
    fn session_runs_stream_on_its_lane() {
        let srv = server();
        let mut session = srv.open_session().expect("session");
        let frames = 6usize;
        let video = SyntheticVideo::new(64, 48, 2, 9);
        let src = Box::new(SyntheticVideo::new(64, 48, 2, 9).take_frames(frames));
        let mut checked = 0usize;
        let report = session
            .run_stream(src, |seq, ih| {
                let expected = integral_histogram_seq(&video.frame(seq).binned(32));
                assert_eq!(expected.max_abs_diff(&ih), 0.0, "frame {seq}");
                checked += 1;
            })
            .expect("stream");
        assert_eq!(report.throughput.frames, frames);
        assert_eq!(checked, frames);
        let st = session.stats();
        assert_eq!(st.frames, frames);
        assert_eq!(st.latency.n, frames);
        let snap = srv.snapshot();
        assert_eq!(snap.frames, frames, "lane frames count globally");
        assert!(snap.frame_pool.allocated <= 4, "lane recycles via the shared arena");
    }

    #[test]
    fn session_analytics_attachment_steps() {
        let srv = server();
        let mut session = srv.open_session().expect("session");
        let video = SyntheticVideo::new(64, 64, 3, 4);
        let ih0 = session.process(&video.frame(0)).expect("frame 0");
        session.attach_motion(4, 0.05);
        match session.step_analytics(&ih0) {
            Some(AnalyticsEvent::Motion(map)) => assert_eq!(map.scores.len(), 16),
            other => panic!("expected motion event, got {:?}", other.is_some()),
        }
        // swap to a tracker seeded from the same tensor
        session.attach_tracker(&ih0, Rect::with_size(10, 10, 16, 16), TrackerConfig::default());
        let ih1 = session.process(&video.frame(1)).expect("frame 1");
        match session.step_analytics(&ih1) {
            Some(AnalyticsEvent::Track(rect)) => {
                assert_eq!((rect.height(), rect.width()), (16, 16));
            }
            other => panic!("expected track event, got {:?}", other.is_some()),
        }
        assert!(session.detach_analytics().is_some());
        assert!(session.step_analytics(&ih1).is_none());
    }

    #[test]
    fn oversized_frames_route_through_the_same_front_door() {
        let mut cfg = ServerConfig::default();
        cfg.engine.bins = 8;
        cfg.engine.device_memory_budget = 1 << 10; // force TaskQueue route
        let srv = Server::new(manifest(), cfg);
        assert_eq!(srv.route_for(40, 40), Route::TaskQueue);
        let img = SyntheticVideo::new(40, 40, 1, 2).frame(0).binned(8);
        // no group artifact in the offline build → CPU serves it
        let (ih, _) = srv.compute(&img).expect("cpu fallback for large frames");
        let expected = integral_histogram_seq(&img);
        assert_eq!(expected.max_abs_diff(&ih), 0.0);
    }

    #[test]
    fn fallback_disabled_propagates_error() {
        let mut cfg = ServerConfig::default();
        cfg.engine.cpu_fallback = false;
        let srv = Server::new(manifest(), cfg);
        let img = SyntheticVideo::new(32, 32, 1, 1).frame(0).binned(8);
        assert!(srv.compute(&img).is_err());
    }
}
