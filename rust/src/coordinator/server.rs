//! `Server` — the shared, persistent multi-stream serving layer.
//!
//! The paper's system sections describe a *service*: dual-buffered
//! streams keep one kernel at 300 fps (§4.4) and a bin task queue
//! spreads one oversized frame across devices (§4.6).  The
//! single-session [`crate::coordinator::router::Engine`] can replay
//! that for one stream; production traffic means *many* concurrent
//! streams ("Fast Histograms using Adaptive CUDA Streams", PAPERS.md)
//! issuing many small region queries each ("Multi-Scale Spatially
//! Weighted Local Histograms in O(1)").  This module is the shared
//! front door:
//!
//! * **`&self` compute.**  All cross-stream state is interior-mutable —
//!   the [`CompileCache`], one server-wide [`FramePool`] arena, a
//!   checkout stack of [`ScanEngine`] lanes (each owning its persistent
//!   parked [`WorkerPool`](crate::histogram::engine::WorkerPool)), and
//!   the lazily-built [`ShardExecutor`] — so any number of threads call
//!   [`Server::compute`] concurrently.  Steady state does zero heap
//!   allocation and zero thread spawning per frame
//!   (`tests/server_concurrency.rs` counter-asserts both).
//! * **One front door for every size.**  [`Server::compute`] routes
//!   small frames to the artifact path (CPU `ScanEngine` fallback in
//!   the offline build) and frames whose tensor exceeds the device
//!   budget through the sharded out-of-core subsystem
//!   ([`crate::shard`]): a lazily-built [`ShardExecutor`] runs
//!   bin-range/row-strip shards from *all* sessions' large frames
//!   interleaved on one worker set — the old whole-frame-serialized
//!   `BinTaskQueue` route (head-of-line blocking across streams) is
//!   gone.  Frames whose tensor also exceeds the host budget go
//!   through [`Server::compute_spilled`] into a disk-backed
//!   [`TensorStore`] that answers region queries without ever
//!   materializing the tensor.  Sessions never care which.
//! * **Sessions.**  [`Server::open_session`] hands out a per-stream
//!   [`Session`] owning a [`CpuPipeline`] lane (recycling through the
//!   server arena), a [`QueryBatcher`], and an optional analytics
//!   attachment (motion detector / tracker).  Admission control is an
//!   [`AdmissionControl`] slot counter: capacity = `max_sessions`,
//!   occupancy = live sessions — over-capacity `open_session` calls are
//!   rejected, not queued, so an overloaded server degrades
//!   predictably.  The slot is an RAII [`AdmissionGuard`] held *inside*
//!   the session, so every exit path — drop, `?`, panic unwind — frees
//!   it (the old token-channel scheme leaked the slot if a session
//!   panicked).
//! * **Metrics.**  Global frame/query/session counters plus a latency
//!   reservoir summarized as p50/p95/p99 + jitter
//!   ([`LatencySummary`]), and per-session latency histories.
//! * **Fault posture (DESIGN.md §8).**  The server is a supervisor:
//!   shard-route frames ride the retrying [`ShardExecutor`] (typed
//!   [`crate::shard::ShardError`]s, optional per-frame deadline), the
//!   compile cache retries with backoff per its
//!   [`RetryPolicy`], and the server itself runs a small lifecycle
//!   state machine — `Running → Draining → Stopped` — with an in-flight
//!   op gauge.  Under overload (`overload_inflight_limit`) it sheds
//!   load in degradation order: large-route (shard) work is refused
//!   first, small-frame work only at twice the limit, and every shed is
//!   counted.  [`Server::health`] snapshots all of it.

use crate::analytics::motion::{MotionDetector, MotionMap};
use crate::analytics::tracker::{Track, TrackerConfig};
use crate::coordinator::backpressure::{
    AdmissionControl, AdmissionGuard, MemoryBudget, MemoryReservation,
};
use crate::coordinator::batcher::{QueryBatcher, QueryResponse};
use crate::coordinator::frame_pool::{FramePool, PoolStats, PooledTensor};
use crate::coordinator::metrics::LatencySummary;
use crate::coordinator::pipeline::{CpuPipeline, CpuPipelineConfig, PipelineReport};
use crate::coordinator::router::{EngineConfig, Route};
use crate::fault::FaultInjector;
use crate::histogram::engine::ScanEngine;
use crate::histogram::region::Rect;
use crate::histogram::types::{BinnedImage, IntegralHistogram};
use crate::proc::{ProcPoolConfig, ProcStats, ProcSupervisor};
use crate::runtime::artifact::ArtifactManifest;
use crate::runtime::compile_cache::{CompileCache, ExecutorScope, RetryPolicy};
use crate::shard::{
    FrameTicket, ShardExecutor, ShardExecutorConfig, ShardExecutorStats, ShardPlan, ShardPlanner,
    ShardReport, TensorStore,
};
use crate::tune::{Calibrator, CostSnapshot, TunedPlanner};
use crate::util::sync::lock_recover;
use crate::video::source::{FrameSource, VideoFrame};
use anyhow::{anyhow, Result};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, AtomicU8, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Serving configuration: routing/fallback knobs come from the
/// existing [`EngineConfig`]; the rest is multi-stream policy.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Routing, strategy, budgets and CPU-fallback policy.
    pub engine: EngineConfig,
    /// Hard cap on concurrently open sessions (admission control).
    pub max_sessions: usize,
    /// Pipeline depth of each session's lane (2 = dual buffering).
    pub lanes: usize,
    /// `ScanEngine` worker budget per stream lane / checkout engine.
    /// Small on purpose: cross-stream parallelism comes from running
    /// streams concurrently, not from one stream grabbing every core.
    pub workers_per_stream: usize,
    /// Workers of the shared [`ShardExecutor`] serving the
    /// large-request route (the paper's Fig. 18 device count).
    pub shard_workers: usize,
    /// Peak resident bytes one large frame may hold on the host.
    /// In-RAM sharded assembly is refused past it
    /// ([`Server::compute_spilled`] serves those frames from disk),
    /// and the shard planner sizes shards so reassembly stays inside
    /// it.  Precedence note: a frame past this budget but inside the
    /// engine's `cpu_fallback_budget` still takes the legacy
    /// whole-frame CPU path (which materializes the full tensor) —
    /// set `cpu_fallback_budget ≤ host_memory_budget` to enforce
    /// strict residency.
    pub host_memory_budget: usize,
    /// Server-wide cap on *concurrently reserved* host bytes across
    /// every in-flight compute op (sharded reassembly buffers, spilled
    /// peak residency, proc-plane shm rings).  `host_memory_budget`
    /// above is per-frame; this bucket is what stops N concurrent
    /// in-budget frames from overcommitting the host N× — the
    /// accounting bug the per-frame check alone cannot catch.  Work
    /// past the cap is shed typed (an `overload:` error), never
    /// queued.  `0` (the default) = unlimited but still metered, so
    /// [`Server::health`] reports live reservation/high-water numbers
    /// either way.
    pub host_memory_cap: usize,
    /// Compile retry/backoff/negative-TTL policy for the shared
    /// [`CompileCache`].
    pub compile_retry: RetryPolicy,
    /// Shard compute attempts per shard before the frame fails typed
    /// (passed to [`ShardExecutorConfig::max_attempts`]).
    pub shard_max_attempts: usize,
    /// Per-frame reassembly deadline for the shard routes; `None` =
    /// wait unbounded (the pre-supervision behaviour).
    pub frame_deadline: Option<Duration>,
    /// Overload shedding threshold on concurrently in-flight compute
    /// ops: at `limit` the large (shard) route is shed, at `2×limit`
    /// small-frame work is shed too.  `0` disables shedding.
    pub overload_inflight_limit: usize,
    /// Chaos-test fault injector, threaded through to the compile
    /// cache, shard executor and spill store.  Inert unless the crate
    /// is built with `--features fault-injection`.
    pub faults: Option<Arc<FaultInjector>>,
    /// Self-calibrating cost model (DESIGN.md §9).  When set, the
    /// server runs the one-shot startup microbenches, checks out CPU
    /// engines through a shared [`TunedPlanner`] (auto-tuned tile /
    /// schedule / kernel variant, EWMA feedback from every frame), and
    /// sizes shard plans with measured numbers instead of the paper's
    /// static priors.  `None` keeps the pre-calibration static paths.
    pub calibrator: Option<Arc<Calibrator>>,
    /// Route large frames through the multi-process execution plane
    /// ([`crate::proc`]): shard compute runs in supervised `proc-worker`
    /// child processes that survive aborts and OOM kills, not just
    /// panics.  Off by default — the in-process [`ShardExecutor`] stays
    /// the fast path; isolation buys fault containment at an IPC +
    /// spill tax (measured in `benches/shard.rs`).
    pub process_isolation: bool,
    /// Pool knobs for the proc plane (child count, attempt ladder,
    /// heartbeats, worker-binary discovery).  Read only when
    /// [`Self::process_isolation`] is on.
    pub proc: ProcPoolConfig,
    /// Remote `proc-worker --listen` endpoints attached as extra node
    /// slots of the multi-process plane (shards to them ride the
    /// chunked in-band stream data plane; see
    /// [`crate::proc::transport`]).  Read only when
    /// [`Self::process_isolation`] is on; non-empty overrides
    /// `proc.remote_workers`.  With remote nodes present,
    /// `proc.workers: 0` builds a pure-remote pool — the same
    /// [`FrameTicket`] API either way.
    pub remote_workers: Vec<String>,
    /// Persist the [`TunedPlanner`] cache here: loaded at
    /// [`Server::new`] (missing/corrupt files are ignored — the cache
    /// simply starts cold) and saved on [`Server::drain`] /
    /// [`Server::shutdown`], so a restarted server skips its plan
    /// searches.  [`Server::recalibrate`] deletes it explicitly.
    pub tune_cache_path: Option<PathBuf>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            engine: EngineConfig::default(),
            max_sessions: 64,
            lanes: 2,
            workers_per_stream: 2,
            shard_workers: 4,
            host_memory_budget: 1 << 30,
            host_memory_cap: 0,
            compile_retry: RetryPolicy::default(),
            shard_max_attempts: 3,
            frame_deadline: None,
            overload_inflight_limit: 0,
            faults: None,
            calibrator: None,
            process_isolation: false,
            proc: ProcPoolConfig::default(),
            remote_workers: Vec::new(),
            tune_cache_path: None,
        }
    }
}

/// Lifecycle of the serving front door (DESIGN.md §8).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServerState {
    /// Accepting sessions and work.
    Running,
    /// Refusing new work; in-flight ops completing.
    Draining,
    /// Drained and shut down; the shard executor is joined.
    Stopped,
}

const STATE_RUNNING: u8 = 0;
const STATE_DRAINING: u8 = 1;
const STATE_STOPPED: u8 = 2;

/// Point-in-time fault/degradation view — what an operator pages on.
#[derive(Debug, Clone)]
pub struct ServerHealth {
    pub state: ServerState,
    /// Compute ops currently in flight (all routes).
    pub inflight: usize,
    pub sessions_active: usize,
    /// True when overload shedding is active for the large route.
    pub degraded: bool,
    /// Effective shedding threshold: calibration-derived when the cost
    /// model has measured samples, else the static config value
    /// (0 = shedding disabled).
    pub overload_limit: usize,
    /// Large-route ops refused under overload.
    pub shed_large: usize,
    /// Small-frame ops refused under overload (≥ 2× the limit).
    pub shed_small: usize,
    /// Live shard workers / configured shard workers (equal when
    /// healthy; the executor replaces no threads — it survives worker
    /// death by retrying on the remaining ones).
    pub shard_workers_alive: usize,
    pub shard_workers_total: usize,
    /// Frames that resolved to a typed error.
    pub shard_frames_failed: usize,
    /// Frames whose ticket was dropped before reassembly.
    pub shard_frames_abandoned: usize,
    /// Host bytes currently reserved against the server-wide memory
    /// bucket (sharded buffers + spilled peaks + proc shm rings).
    pub mem_reserved: usize,
    /// High-water mark of `mem_reserved` — ≤ `mem_cap` when capped.
    pub mem_high_water: usize,
    /// Compute ops shed because a reservation would overcommit the cap.
    pub mem_shed: usize,
    /// The configured [`ServerConfig::host_memory_cap`] (0 = unlimited).
    pub mem_cap: usize,
}

/// Capacity of the global latency reservoir (ring overwrite beyond).
const LATENCY_RESERVOIR: usize = 1 << 16;
/// Capacity of each session's latency history — bounded so long-lived
/// streams (hours at video rate) don't grow memory per frame.
const SESSION_LATENCY_RESERVOIR: usize = 1 << 12;

/// Bounded latency sample ring: keeps the most recent `cap` samples,
/// overwriting the oldest.  Percentiles over the ring describe the
/// recent serving window; jitter is exact until the first wrap.
struct LatencyRing {
    buf: Vec<f64>,
    count: usize,
    cap: usize,
}

impl LatencyRing {
    fn with_cap(cap: usize) -> LatencyRing {
        LatencyRing { buf: Vec::new(), count: 0, cap }
    }

    fn push(&mut self, ms: f64) {
        if self.buf.len() < self.cap {
            self.buf.push(ms);
        } else {
            self.buf[self.count % self.cap] = ms;
        }
        self.count += 1;
    }

    fn clear(&mut self) {
        self.buf.clear();
        self.count = 0;
    }
}

struct Metrics {
    frames: AtomicUsize,
    queries: AtomicUsize,
    sessions_opened: AtomicUsize,
    sessions_rejected: AtomicUsize,
    shed_large: AtomicUsize,
    shed_small: AtomicUsize,
    latencies_ms: Mutex<LatencyRing>,
}

impl Default for Metrics {
    fn default() -> Metrics {
        Metrics {
            frames: AtomicUsize::new(0),
            queries: AtomicUsize::new(0),
            sessions_opened: AtomicUsize::new(0),
            sessions_rejected: AtomicUsize::new(0),
            shed_large: AtomicUsize::new(0),
            shed_small: AtomicUsize::new(0),
            latencies_ms: Mutex::new(LatencyRing::with_cap(LATENCY_RESERVOIR)),
        }
    }
}

impl Metrics {
    fn push_latency(&self, ms: f64) {
        // The ring is valid at every instruction boundary; recover a
        // poisoned lock rather than abort the serving thread.
        lock_recover(&self.latencies_ms).push(ms);
    }
}

/// Point-in-time view of the server's global counters.
#[derive(Debug, Clone)]
pub struct ServerSnapshot {
    /// Frames computed through [`Server::compute`] (all routes).
    pub frames: usize,
    /// Region queries answered through sessions.
    pub queries: usize,
    pub sessions_opened: usize,
    pub sessions_rejected: usize,
    pub sessions_active: usize,
    /// Peak concurrently-open sessions.
    pub sessions_peak: usize,
    /// CPU engines ever created for the checkout stack — flat in
    /// steady state (each checkout reuses a parked engine).
    pub engines_created: usize,
    /// Engines currently parked on the checkout stack.
    pub engines_idle: usize,
    /// Worker threads ever spawned by the idle engines' pools — flat
    /// in steady state (read at quiescence; checked-out engines are
    /// not visible).
    pub threads_spawned: usize,
    /// Pool jobs dispatched by the idle engines (≈ parallel frames).
    pub pool_jobs: usize,
    /// The shared tensor arena's counters.
    pub frame_pool: PoolStats,
    /// p50/p95/p99 + jitter over the global latency reservoir.
    pub latency: LatencySummary,
    /// Shard executor counters (None until the first large request
    /// builds it).
    pub shard: Option<ShardExecutorStats>,
    /// Multi-process plane counters (None until `process_isolation`
    /// routes its first large request).
    pub proc: Option<ProcStats>,
    /// Live calibration snapshot (None when the server runs static;
    /// `samples > 0` once live frames have fed the EWMA loop).
    pub calibration: Option<CostSnapshot>,
}

struct Inner {
    config: ServerConfig,
    compile: CompileCache,
    pool: Arc<FramePool>,
    /// Parked CPU engines, checked out per in-flight compute.  LIFO so
    /// the hottest engine (warm scratch, spawned pool) is reused first.
    engines: Mutex<Vec<ScanEngine>>,
    engines_created: AtomicUsize,
    /// Shared large-image path: one lazily-built [`ShardExecutor`] for
    /// the whole server.  The mutex guards construction only — submits
    /// happen on a cloned handle outside it, so any number of large
    /// frames are in flight interleaved (tagged reassembly keeps them
    /// apart), unlike the old whole-frame-serialized `BinTaskQueue`
    /// route.  Geometry-agnostic: plans are per-request.
    shard: Mutex<Option<Arc<ShardExecutor>>>,
    /// The multi-process plane, built lazily on the first large frame
    /// when `config.process_isolation` is on (same discipline as the
    /// in-process executor above: the lock guards construction only).
    proc: Mutex<Option<Arc<ProcSupervisor>>>,
    /// One shared auto-tuning planner for every checkout engine (one
    /// plan search per geometry per server), present iff the config
    /// carries a calibrator.
    tuner: Option<Arc<TunedPlanner>>,
    /// Overload limit derived from the calibrated per-frame cost of a
    /// nominal large frame (0 = not derived; the static
    /// `overload_inflight_limit` applies).  Refreshed by
    /// [`Server::recalibrate`].
    overload_limit_derived: AtomicUsize,
    /// Server-wide host-memory token bucket ([`ServerConfig::
    /// host_memory_cap`]): every route's peak-residency bytes are
    /// reserved here for the life of the op, and the proc plane's shm
    /// ring mappings charge it too, so concurrent in-budget frames
    /// can no longer overcommit the host unmetered.
    mem: Arc<MemoryBudget>,
    /// Feedback-corrected admission ratio for the sharded route
    /// (measured peak residency ÷ planned charge, EWMA α = 0.25,
    /// stored as `f64` bits).  The planned charge is a static estimate
    /// — the reassembly tensor alone — that ignores the shard partial
    /// buffers genuinely resident on top of it, so the bucket used to
    /// under-admit protection; the measured ratio corrects it.
    admit_ratio_sharded: AtomicU64,
    /// Same feedback loop for the spilled route, where the static
    /// charge (the per-frame budget ceiling) over-states typical peak
    /// residency and used to shed frames the host could serve.
    admit_ratio_spilled: AtomicU64,
    metrics: Metrics,
    admission: Arc<AdmissionControl>,
    session_seq: AtomicUsize,
    /// Lifecycle: `STATE_RUNNING` / `STATE_DRAINING` / `STATE_STOPPED`.
    state: AtomicU8,
    /// Compute ops currently in flight (RAII-counted by [`OpGuard`]).
    inflight: AtomicUsize,
}

/// RAII in-flight marker: [`Inner::begin_op`] increments the gauge,
/// dropping the guard — on success, error, or unwind — decrements it,
/// so `drain` can never wait on an op that already died.
struct OpGuard<'a> {
    inner: &'a Inner,
}

impl Drop for OpGuard<'_> {
    fn drop(&mut self) {
        self.inner.inflight.fetch_sub(1, Ordering::AcqRel);
    }
}

impl Inner {
    /// Gate every compute op on lifecycle state and overload, in
    /// degradation order: draining/stopped refuse everything; under
    /// overload the large (shard) route is shed at the limit, small
    /// frames only at twice it — an overloaded server keeps serving
    /// cheap frames after it stops accepting expensive ones.
    fn begin_op(&self, large: bool) -> Result<OpGuard<'_>> {
        match self.state.load(Ordering::Acquire) {
            STATE_RUNNING => {}
            STATE_DRAINING => return Err(anyhow!("server draining: new work refused")),
            _ => return Err(anyhow!("server stopped")),
        }
        let limit = self.overload_limit();
        if limit > 0 {
            let inflight = self.inflight.load(Ordering::Acquire);
            if large && inflight >= limit {
                self.metrics.shed_large.fetch_add(1, Ordering::Relaxed);
                return Err(anyhow!(
                    "overload: large-route work shed ({inflight} ops in flight, limit {limit})"
                ));
            }
            if !large && inflight >= 2 * limit {
                self.metrics.shed_small.fetch_add(1, Ordering::Relaxed);
                return Err(anyhow!(
                    "overload: work shed ({inflight} ops in flight, limit {})",
                    2 * limit
                ));
            }
        }
        self.inflight.fetch_add(1, Ordering::AcqRel);
        Ok(OpGuard { inner: self })
    }

    /// Effective shedding threshold: the calibration-derived limit when
    /// one has been computed (measured per-frame cost against the frame
    /// deadline — see [`Server::recalibrate`]), else the static
    /// `overload_inflight_limit` as cold-start fallback.
    fn overload_limit(&self) -> usize {
        let derived = self.overload_limit_derived.load(Ordering::Relaxed);
        if derived > 0 {
            derived
        } else {
            self.config.overload_inflight_limit
        }
    }

    /// Derive the shedding threshold from the calibrated cost model:
    /// how many nominal large frames fit inside the frame deadline
    /// (default 1 s of queueing tolerance) at the measured throughput.
    /// Returns 0 — "not derived" — while the snapshot is still the
    /// static prior, so cold start falls back to the static limit.
    fn derive_overload_limit(&self) -> usize {
        let Some(cal) = &self.config.calibrator else { return 0 };
        let snap = cal.snapshot();
        if snap.is_prior() {
            return 0;
        }
        // Nominal large frame: the smallest tensor that takes the
        // shard route (the device-budget boundary), square.
        let bins = self.config.engine.bins.max(1);
        let pixels = (self.config.engine.device_memory_budget / 4).max(1) / bins;
        let side = (pixels as f64).sqrt().ceil().max(8.0) as usize;
        let plan = self.shard_plan(bins, side, side);
        let wall = plan
            .predict_total_with(&snap, self.config.shard_workers.max(1))
            .wall
            .as_secs_f64();
        if wall <= 0.0 {
            return 0;
        }
        let budget = self
            .config
            .frame_deadline
            .unwrap_or(Duration::from_secs(1))
            .as_secs_f64();
        ((budget / wall) as usize).clamp(2, 256)
    }

    fn route_for(&self, h: usize, w: usize) -> Route {
        self.config.engine.route_for(h, w)
    }

    fn cpu_allowed(&self, img: &BinnedImage) -> bool {
        self.config.engine.cpu_fallback_allowed(img)
    }

    /// Serve a frame on a checked-out CPU engine with pooled storage.
    ///
    /// Poisoning policy: the checkout stack only ever holds engines
    /// between frames (complete at every instruction boundary), so a
    /// poisoned stack lock is recovered.  An engine that PANICKED
    /// mid-compute never returns here — the unwind drops it before the
    /// push — so recovery cannot resurrect a suspect engine.
    fn compute_cpu(&self, img: &BinnedImage) -> Result<(PooledTensor, Duration)> {
        let t0 = Instant::now();
        let mut engine = match lock_recover(&self.engines).pop() {
            Some(e) => e,
            None => {
                self.engines_created.fetch_add(1, Ordering::Relaxed);
                match &self.tuner {
                    Some(t) => {
                        ScanEngine::with_tuner(self.config.workers_per_stream, Arc::clone(t))
                    }
                    None => ScanEngine::new(self.config.workers_per_stream),
                }
            }
        };
        let mut out = PooledTensor::acquire(&self.pool, img.bins, img.h, img.w);
        engine.compute_into(img, &mut out);
        lock_recover(&self.engines).push(engine);
        Ok((out, t0.elapsed()))
    }

    /// The server's shared shard executor, built on first large
    /// request (the lock guards construction, never execution).
    fn shard_executor(&self) -> Arc<ShardExecutor> {
        let mut guard = lock_recover(&self.shard);
        if guard.is_none() {
            let cfg = ShardExecutorConfig {
                workers: self.config.shard_workers.max(1),
                engine_workers: 1,
                channel_depth: 0,
                max_attempts: self.config.shard_max_attempts.max(1),
            };
            let exec = ShardExecutor::with_instruments(
                cfg,
                self.config.faults.clone(),
                self.config.calibrator.clone(),
            );
            *guard = Some(Arc::new(exec));
        }
        Arc::clone(guard.as_ref().expect("executor just built"))
    }

    /// The server's multi-process plane, built on first use when
    /// `process_isolation` is on.  Spawn failure (e.g. the
    /// `proc-worker` binary is missing) surfaces typed to the caller —
    /// it is a deployment error, not a reason to silently fall back to
    /// the unisolated path the operator opted out of.
    fn proc_supervisor(&self) -> Result<Arc<ProcSupervisor>> {
        let mut guard = lock_recover(&self.proc);
        if guard.is_none() {
            let remote_workers = if self.config.remote_workers.is_empty() {
                self.config.proc.remote_workers.clone()
            } else {
                self.config.remote_workers.clone()
            };
            // With remote nodes attached, `workers: 0` is a valid
            // pure-remote pool — only an all-local pool is floored to
            // one child.
            let workers = if remote_workers.is_empty() {
                self.config.proc.workers.max(1)
            } else {
                self.config.proc.workers
            };
            let cfg = ProcPoolConfig {
                workers,
                max_attempts: self.config.shard_max_attempts.max(1),
                remote_workers,
                ..self.config.proc.clone()
            };
            // The supervisor charges its shm ring mappings against the
            // same server-wide bucket every compute op reserves from,
            // so data-plane memory is part of the overcommit math.
            let sup = ProcSupervisor::with_instruments(
                cfg,
                self.config.faults.clone(),
                Some(Arc::clone(&self.mem)),
            )?;
            *guard = Some(Arc::new(sup));
        }
        Ok(Arc::clone(guard.as_ref().expect("supervisor just built")))
    }

    /// Submit a planned large frame to whichever execution plane the
    /// config selects, pushing the frame deadline into the dispatch
    /// queue (expired shards are dropped before compute on both
    /// planes).  Returns the same [`FrameTicket`] either way —
    /// reassembly and the bit-identity contract are shared code.
    fn submit_ticket(&self, image: &Arc<BinnedImage>, plan: &ShardPlan) -> Result<FrameTicket> {
        if self.config.process_isolation {
            let sup = self.proc_supervisor()?;
            match self.config.frame_deadline {
                Some(d) => sup.submit_with_deadline(image, plan, d),
                None => sup.submit(image, plan),
            }
        } else {
            let exec = self.shard_executor();
            match self.config.frame_deadline {
                Some(d) => exec.submit_with_deadline(image, plan, d),
                None => exec.submit(image, plan),
            }
        }
    }

    /// Plan a request under the server's shard policy.  With a
    /// calibrator, shards are sized against the measured cost snapshot
    /// (closing the predicted-vs-measured loop); without one, the
    /// paper's static priors apply.
    fn shard_plan(&self, bins: usize, h: usize, w: usize) -> crate::shard::ShardPlan {
        let exec_workers = self.config.shard_workers.max(1);
        let policy = self
            .config
            .engine
            .shard_policy(self.config.host_memory_budget, exec_workers);
        let planner = ShardPlanner::new(policy);
        match &self.config.calibrator {
            Some(cal) => planner.plan_calibrated(bins, h, w, &cal.snapshot()),
            None => planner.plan(bins, h, w),
        }
    }

    /// Reserve `bytes` of an op's peak host residency against the
    /// server-wide bucket for the life of the returned guard, or shed
    /// typed.  The per-frame `host_memory_budget` check cannot see
    /// *concurrent* frames — N in-budget ops used to overcommit the
    /// host N× unmetered; this bucket is the fix.
    fn reserve_host(&self, bytes: usize) -> Result<MemoryReservation> {
        self.mem.try_reserve(bytes).ok_or_else(|| {
            anyhow!(
                "overload: host memory overcommit refused ({bytes} B requested, \
                 {} B of {} B cap already reserved)",
                self.mem.reserved(),
                self.mem.cap()
            )
        })
    }

    /// The feedback-corrected admission charge for a route: the
    /// static `planned` estimate scaled by the route's measured EWMA
    /// ratio, clamped to `[planned/4, 4·planned]` so a few wild
    /// reports can never collapse admission control to zero or
    /// quadruple-charge it forever.
    fn admission_charge(planned: usize, ratio_bits: &AtomicU64) -> usize {
        let ratio = f64::from_bits(ratio_bits.load(Ordering::Relaxed));
        let raw = (planned as f64 * ratio) as usize;
        raw.clamp(planned / 4, planned.saturating_mul(4)).max(1)
    }

    /// Fold one measured peak-residency observation into a route's
    /// EWMA ratio (α = 0.25).  The observation itself is clamped to
    /// the same `[1/4, 4]` band as the charge, so a single hostile
    /// `ShardReport` cannot slam the ratio outside the range the
    /// charge clamp honours anyway.  Racy read-modify-write by
    /// design: concurrent frames may drop an update, never corrupt.
    fn observe_admission(planned: usize, measured: usize, ratio_bits: &AtomicU64) {
        if planned == 0 {
            return;
        }
        let obs = (measured as f64 / planned as f64).clamp(0.25, 4.0);
        let old = f64::from_bits(ratio_bits.load(Ordering::Relaxed));
        let new = old * 0.75 + obs * 0.25;
        ratio_bits.store(new.to_bits(), Ordering::Relaxed);
    }

    /// Close the predicted-vs-measured loop on the tuning cache: when
    /// a frame's report contradicts the cost model's prediction badly
    /// enough, the [`TunedPlanner`] entry for that geometry is stale
    /// (machine changed, thermal shift) and gets evicted so the next
    /// frame re-searches instead of serving the stale plan forever.
    fn note_drift(&self, bins: usize, h: usize, w: usize, plan: &ShardPlan, measured: Duration) {
        let (Some(tuner), Some(cal)) = (&self.tuner, &self.config.calibrator) else {
            return;
        };
        let workers = self.config.shard_workers.max(1);
        let predicted = plan.predict_total_with(&cal.snapshot(), workers).wall;
        tuner.observe_report(h, w, bins, workers, predicted, measured);
    }

    /// Large-image route: interleaved sharded execution reassembled
    /// into a pooled host tensor.  Refused when the tensor exceeds the
    /// host budget — that is [`Self::compute_spilled`]'s job.
    fn compute_sharded(&self, img: &BinnedImage) -> Result<(PooledTensor, Duration)> {
        let tensor_bytes = img.bins * img.h * img.w * 4;
        if tensor_bytes > self.config.host_memory_budget {
            return Err(anyhow!(
                "tensor of {tensor_bytes} B exceeds the host budget of {} B; \
                 use Server::compute_spilled / Session::process_spilled",
                self.config.host_memory_budget
            ));
        }
        // The reassembly tensor is resident for the whole op, plus
        // whatever shard partials ride on top of it — charge the
        // EWMA-corrected estimate against the server-wide bucket
        // before committing any work, and settle the ratio from the
        // measured report afterwards.
        let _mem =
            self.reserve_host(Self::admission_charge(tensor_bytes, &self.admit_ratio_sharded))?;
        let plan = self.shard_plan(img.bins, img.h, img.w);
        let image = Arc::new(img.clone());
        let ticket = self.submit_ticket(&image, &plan)?;
        let mut out = PooledTensor::acquire(&self.pool, img.bins, img.h, img.w);
        let report = match self.config.frame_deadline {
            Some(d) => ticket.reassemble_into_deadline(&mut out, d)?,
            None => ticket.reassemble_into(&mut out)?,
        };
        Self::observe_admission(
            tensor_bytes,
            tensor_bytes + report.peak_resident_bytes,
            &self.admit_ratio_sharded,
        );
        self.note_drift(img.bins, img.h, img.w, &plan, report.wall);
        Ok((out, report.wall))
    }

    /// Out-of-core route: sharded execution spilled to a disk-backed
    /// [`TensorStore`] — peak host residency stays within the shard
    /// budget, never the full tensor.
    fn compute_spilled(&self, image: &Arc<BinnedImage>) -> Result<(TensorStore, ShardReport)> {
        let _op = self.begin_op(true)?;
        // Peak residency on this route is bounded by the shard plan
        // (never the full tensor — that's the point of spilling).
        // The per-frame budget ceiling is the static estimate; the
        // EWMA of measured `ShardReport::peak_resident_bytes` corrects
        // it, so frames the host can actually serve stop being shed
        // on the pessimistic ceiling alone.
        let tensor_bytes = image.bins * image.h * image.w * 4;
        let planned = tensor_bytes.min(self.config.host_memory_budget);
        let _mem = self.reserve_host(Self::admission_charge(planned, &self.admit_ratio_spilled))?;
        let plan = self.shard_plan(image.bins, image.h, image.w);
        let ticket = self.submit_ticket(image, &plan)?;
        let (store, report) = match self.config.frame_deadline {
            Some(d) => ticket.reassemble_spilled_deadline(d)?,
            None => ticket.reassemble_spilled()?,
        };
        Self::observe_admission(planned, report.peak_resident_bytes, &self.admit_ratio_spilled);
        self.note_drift(image.bins, image.h, image.w, &plan, report.wall);
        self.metrics.frames.fetch_add(1, Ordering::Relaxed);
        self.metrics.push_latency(report.wall.as_secs_f64() * 1e3);
        Ok((store, report))
    }

    /// The shared front door: gate (lifecycle + overload), route,
    /// compute, account.
    fn compute(&self, img: &BinnedImage) -> Result<(PooledTensor, Duration)> {
        let route = self.route_for(img.h, img.w);
        let _op = self.begin_op(route == Route::TaskQueue)?;
        let res = match route {
            Route::Direct => {
                let strategy = self.config.engine.strategy;
                // Memoized availability check: when no artifact matches
                // (always true offline), the steady-state CPU path runs
                // with no per-frame manifest scans or error strings.
                if self.cpu_allowed(img)
                    && !self.compile.has_strategy(strategy, img.h, img.w, img.bins)
                {
                    self.compute_cpu(img)
                } else {
                    match self.compile.strategy_executor(strategy, img.h, img.w, img.bins) {
                        Ok(exe) => exe
                            .compute_timed(img)
                            .map(|(ih, d)| (PooledTensor::adopt(&self.pool, ih), d)),
                        Err(_) if self.cpu_allowed(img) => self.compute_cpu(img),
                        Err(e) => Err(e),
                    }
                }
            }
            // In-budget large frames always run sharded (a shard
            // failure propagates — it is never silently recomputed).
            // Past the host budget, the pre-shard whole-frame CPU
            // escape hatch applies if `cpu_fallback_budget` still
            // allows the allocation (set it ≤ `host_memory_budget` to
            // enforce strict residency); past both, compute_sharded
            // surfaces the actionable "use compute_spilled" error.
            Route::TaskQueue => {
                let tensor_bytes = img.bins * img.h * img.w * 4;
                if tensor_bytes > self.config.host_memory_budget && self.cpu_allowed(img) {
                    self.compute_cpu(img)
                } else {
                    self.compute_sharded(img)
                }
            }
        };
        if let Ok((_, d)) = &res {
            self.metrics.frames.fetch_add(1, Ordering::Relaxed);
            self.metrics.push_latency(d.as_secs_f64() * 1e3);
        }
        res
    }
}

/// The shared serving front door.  Cheap to clone (an `Arc` handle);
/// every method takes `&self` and is safe from any number of threads.
#[derive(Clone)]
pub struct Server {
    inner: Arc<Inner>,
}

impl Server {
    pub fn new(manifest: Arc<ArtifactManifest>, config: ServerConfig) -> Server {
        let admission = AdmissionControl::new(config.max_sessions.max(1));
        let mut compile =
            CompileCache::with_policy(manifest, ExecutorScope::Shared, config.compile_retry);
        if let Some(f) = &config.faults {
            compile.set_faults(Arc::clone(f));
        }
        // Startup hook of the calibration loop (DESIGN.md §9): run the
        // one-shot microbenches once, before any frame, so the first
        // plan search already works from measured numbers; live frames
        // keep the EWMA fresh from here on.
        let tuner = config.calibrator.as_ref().map(|cal| {
            cal.calibrate();
            Arc::new(TunedPlanner::new(Arc::clone(cal)))
        });
        // Warm the tuning cache from the persisted file, if configured.
        // Errors (missing file on first boot, corrupt content) are
        // deliberately ignored — the cache just starts cold.
        if let (Some(t), Some(p)) = (&tuner, &config.tune_cache_path) {
            let _ = t.load_from(p);
        }
        let server = Server {
            inner: Arc::new(Inner {
                compile,
                pool: Arc::new(FramePool::new()),
                engines: Mutex::new(Vec::new()),
                engines_created: AtomicUsize::new(0),
                shard: Mutex::new(None),
                proc: Mutex::new(None),
                tuner,
                mem: MemoryBudget::new(config.host_memory_cap),
                admit_ratio_sharded: AtomicU64::new(1f64.to_bits()),
                admit_ratio_spilled: AtomicU64::new(1f64.to_bits()),
                metrics: Metrics::default(),
                admission,
                session_seq: AtomicUsize::new(0),
                state: AtomicU8::new(STATE_RUNNING),
                inflight: AtomicUsize::new(0),
                overload_limit_derived: AtomicUsize::new(0),
                config,
            }),
        };
        // The startup microbench has run by now, so the calibrated
        // shedding threshold can be derived immediately.
        let derived = server.inner.derive_overload_limit();
        server.inner.overload_limit_derived.store(derived, Ordering::Relaxed);
        server
    }

    /// Drop every learned tuning artifact and re-run the startup
    /// microbenches: clears the [`TunedPlanner`] cache, deletes the
    /// persisted cache file (if configured), recalibrates the cost
    /// model and re-derives the overload limit.  The admin hook for
    /// "the machine changed under me" — new hardware, new thermal
    /// envelope, suspicious tail latencies.  Returns the number of
    /// cached plans dropped.
    pub fn recalibrate(&self) -> usize {
        let dropped = self.inner.tuner.as_ref().map(|t| t.clear()).unwrap_or(0);
        if let Some(p) = &self.inner.config.tune_cache_path {
            let _ = std::fs::remove_file(p);
        }
        if let Some(cal) = &self.inner.config.calibrator {
            cal.calibrate();
        }
        let derived = self.inner.derive_overload_limit();
        self.inner.overload_limit_derived.store(derived, Ordering::Relaxed);
        dropped
    }

    pub fn config(&self) -> &ServerConfig {
        &self.inner.config
    }

    /// Routing decision for an `h×w` frame at the configured bin count.
    pub fn route_for(&self, h: usize, w: usize) -> Route {
        self.inner.route_for(h, w)
    }

    /// Compute the integral histogram of an already-binned image —
    /// callable concurrently from any thread; results are bit-identical
    /// to serial execution.  Returns the pooled tensor (recycled into
    /// the server arena on drop) and the compute duration.
    pub fn compute(&self, img: &BinnedImage) -> Result<(PooledTensor, Duration)> {
        self.inner.compute(img)
    }

    /// Compute out-of-core: sharded execution spilled to a disk-backed
    /// [`TensorStore`] whose [`TensorStore::query`] answers Eq. 2
    /// region lookups without materializing the tensor.  This is the
    /// §4.6 / Fig. 18 path — frames whose tensor exceeds even the host
    /// budget complete here with peak residency bounded by the shard
    /// plan (see `ShardReport::peak_resident_bytes`).
    pub fn compute_spilled(
        &self,
        image: &Arc<BinnedImage>,
    ) -> Result<(TensorStore, ShardReport)> {
        self.inner.compute_spilled(image)
    }

    /// Admit a new stream.  Rejected (not queued) once `max_sessions`
    /// sessions are live; the slot is an RAII guard inside the session,
    /// freed on any exit path (drop, error, panic unwind).  Refused
    /// while draining or stopped.
    pub fn open_session(&self) -> Result<Session> {
        if self.inner.state.load(Ordering::Acquire) != STATE_RUNNING {
            self.inner.metrics.sessions_rejected.fetch_add(1, Ordering::Relaxed);
            return Err(anyhow!("server not running: session refused"));
        }
        let Some(admission) = self.inner.admission.try_admit() else {
            self.inner.metrics.sessions_rejected.fetch_add(1, Ordering::Relaxed);
            return Err(anyhow!(
                "admission rejected: {} sessions live (max {})",
                self.inner.admission.active(),
                self.inner.config.max_sessions
            ));
        };
        self.inner.metrics.sessions_opened.fetch_add(1, Ordering::Relaxed);
        let id = self.inner.session_seq.fetch_add(1, Ordering::Relaxed) as u64;
        let cfg = &self.inner.config;
        let lane_cfg = CpuPipelineConfig::new(cfg.engine.bins)
            .lanes(cfg.lanes)
            .workers(cfg.workers_per_stream);
        let pipeline = CpuPipeline::with_pool(lane_cfg, Arc::clone(&self.inner.pool));
        Ok(Session {
            inner: Arc::clone(&self.inner),
            _admission: admission,
            id,
            bins: cfg.engine.bins,
            img: BinnedImage::new(0, 0, 1, Vec::new()),
            pipeline,
            batcher: QueryBatcher::new(),
            analytics: None,
            latencies_ms: LatencyRing::with_cap(SESSION_LATENCY_RESERVOIR),
            frames: 0,
            queries: 0,
        })
    }

    /// Currently live sessions.
    pub fn sessions_active(&self) -> usize {
        self.inner.admission.active()
    }

    /// Operator-facing health view: lifecycle state, in-flight gauge,
    /// shedding counters, and the shard executor's failure counters.
    pub fn health(&self) -> ServerHealth {
        let inner = &self.inner;
        let state = match inner.state.load(Ordering::Acquire) {
            STATE_RUNNING => ServerState::Running,
            STATE_DRAINING => ServerState::Draining,
            _ => ServerState::Stopped,
        };
        let inflight = inner.inflight.load(Ordering::Acquire);
        let limit = inner.overload_limit();
        let shard = lock_recover(&inner.shard).as_ref().map(|e| e.stats());
        let (alive, total, failed, abandoned) = match &shard {
            Some(s) => (
                s.workers_alive,
                inner.config.shard_workers.max(1),
                s.frames_failed,
                s.frames_abandoned,
            ),
            None => (0, 0, 0, 0),
        };
        ServerHealth {
            state,
            inflight,
            sessions_active: inner.admission.active(),
            degraded: limit > 0 && inflight >= limit,
            overload_limit: limit,
            shed_large: inner.metrics.shed_large.load(Ordering::Relaxed),
            shed_small: inner.metrics.shed_small.load(Ordering::Relaxed),
            shard_workers_alive: alive,
            shard_workers_total: total,
            shard_frames_failed: failed,
            shard_frames_abandoned: abandoned,
            mem_reserved: inner.mem.reserved(),
            mem_high_water: inner.mem.high_water(),
            mem_shed: inner.mem.shed(),
            mem_cap: inner.mem.cap(),
        }
    }

    /// Stop accepting new work (sessions and compute ops) and wait up
    /// to `timeout` for in-flight ops to finish.  Returns `true` when
    /// the server drained fully.  Existing sessions stay open — their
    /// compute calls fail typed until [`Self::resume`].
    pub fn drain(&self, timeout: Duration) -> bool {
        self.inner.state.store(STATE_DRAINING, Ordering::Release);
        let t0 = Instant::now();
        let drained = loop {
            if self.inner.inflight.load(Ordering::Acquire) == 0 {
                break true;
            }
            if t0.elapsed() >= timeout {
                break false;
            }
            std::thread::sleep(Duration::from_millis(1));
        };
        // Persist the tuning cache at the quiet point so a restarted
        // server skips its plan searches (best-effort: an unwritable
        // path costs the warm start, not the drain).
        if let (Some(t), Some(p)) = (&self.inner.tuner, &self.inner.config.tune_cache_path) {
            let _ = t.save_to(p);
        }
        drained
    }

    /// [`Self::drain`], then stop for good: the shard executor is
    /// dropped (its worker threads join — no in-flight tickets exist
    /// after a clean drain).  Returns the drain result.
    pub fn shutdown(&self, timeout: Duration) -> bool {
        let drained = self.drain(timeout);
        self.inner.state.store(STATE_STOPPED, Ordering::Release);
        // Joining the workers happens in the executor's Drop; a timed-
        // out drain leaves stragglers to finish against the channel.
        *lock_recover(&self.inner.shard) = None;
        // The proc supervisor's Drop shuts the children down (Shutdown
        // frame, grace period, then kill) and joins its dispatcher.
        *lock_recover(&self.inner.proc) = None;
        drained
    }

    /// Return to `Running` from `Draining` (or `Stopped`; a later
    /// large frame lazily rebuilds the shard executor).
    pub fn resume(&self) {
        self.inner.state.store(STATE_RUNNING, Ordering::Release);
    }

    /// Test hook: pretend `n` ops are in flight so overload shedding
    /// can be asserted deterministically.
    #[cfg(test)]
    fn force_inflight(&self, n: usize) {
        self.inner.inflight.store(n, Ordering::Release);
    }

    /// Drop compiled executors and negative compile results (e.g.
    /// after regenerating `artifacts/`).
    pub fn clear_compile_cache(&self) {
        self.inner.compile.clear();
    }

    /// Clear the global latency reservoir, starting a fresh
    /// measurement window — call after warm-up so reported percentiles
    /// describe steady-state serving, not cold-start frames.  Counters
    /// (frames, sessions, arena, pools) are unaffected.
    pub fn reset_latency_stats(&self) {
        lock_recover(&self.inner.metrics.latencies_ms).clear();
    }

    /// Snapshot the global counters.  `threads_spawned`/`pool_jobs`
    /// aggregate the *idle* checkout engines — read at quiescence for
    /// the steady-state assertions.
    pub fn snapshot(&self) -> ServerSnapshot {
        let inner = &self.inner;
        let (engines_idle, threads_spawned, pool_jobs) = {
            let engines = lock_recover(&inner.engines);
            let mut spawned = 0;
            let mut jobs = 0;
            for e in engines.iter() {
                let s = e.pool_stats();
                spawned += s.spawned;
                jobs += s.jobs;
            }
            (engines.len(), spawned, jobs)
        };
        let latency = {
            let ring = lock_recover(&inner.metrics.latencies_ms);
            LatencySummary::of_ms(&ring.buf)
        };
        let shard = lock_recover(&inner.shard).as_ref().map(|e| e.stats());
        ServerSnapshot {
            frames: inner.metrics.frames.load(Ordering::Relaxed),
            queries: inner.metrics.queries.load(Ordering::Relaxed),
            sessions_opened: inner.metrics.sessions_opened.load(Ordering::Relaxed),
            sessions_rejected: inner.metrics.sessions_rejected.load(Ordering::Relaxed),
            sessions_active: inner.admission.active(),
            sessions_peak: inner.admission.high_water(),
            engines_created: inner.engines_created.load(Ordering::Relaxed),
            engines_idle,
            threads_spawned,
            pool_jobs,
            frame_pool: inner.pool.stats(),
            latency,
            shard,
            proc: lock_recover(&inner.proc).as_ref().map(|p| p.stats()),
            calibration: inner.config.calibrator.as_ref().map(|c| c.snapshot()),
        }
    }
}

/// Analytics attachment of a session — the downstream consumers the
/// paper's introduction motivates, fed from the session's own tensors.
pub enum SessionAnalytics {
    Motion(MotionDetector),
    Tracker(Track),
}

/// What an analytics step produced.
#[derive(Debug, Clone)]
pub enum AnalyticsEvent {
    Motion(MotionMap),
    Track(Rect),
}

/// Per-session (stream-local) counters.
#[derive(Debug, Clone)]
pub struct SessionSnapshot {
    pub id: u64,
    pub frames: usize,
    pub queries: usize,
    /// (answered, unique-computed) batcher counters.
    pub batcher: (usize, usize),
    pub latency: LatencySummary,
}

/// One stream's handle on the server: a pipeline lane, a query
/// batcher, an optional analytics attachment, and stream-local
/// metrics.  Owns an admission slot; dropping the session frees it.
///
/// `Session` is `Send` — open it on one thread, drive it from another.
pub struct Session {
    inner: Arc<Inner>,
    /// The admission slot itself: dropping the session — or unwinding
    /// out of it — releases the slot.  Nothing else does.
    _admission: AdmissionGuard,
    id: u64,
    bins: usize,
    /// Recycled quantization buffer (no per-frame image allocation).
    img: BinnedImage,
    pipeline: CpuPipeline,
    batcher: QueryBatcher,
    analytics: Option<SessionAnalytics>,
    /// Bounded recent-latency history (ring; see
    /// [`SESSION_LATENCY_RESERVOIR`]).
    latencies_ms: LatencyRing,
    frames: usize,
    queries: usize,
}

impl Session {
    pub fn id(&self) -> u64 {
        self.id
    }

    pub fn bins(&self) -> usize {
        self.bins
    }

    /// Compute one frame through the server front door (any route).
    /// The returned tensor recycles into the server arena on drop.
    pub fn process(&mut self, frame: &VideoFrame) -> Result<PooledTensor> {
        let t0 = Instant::now();
        frame.binned_into(self.bins, &mut self.img);
        let (ih, _kernel) = self.inner.compute(&self.img)?;
        self.frames += 1;
        self.latencies_ms.push(t0.elapsed().as_secs_f64() * 1e3);
        Ok(ih)
    }

    /// Compute one frame out-of-core: the tensor lands in a
    /// disk-backed [`TensorStore`] (never fully resident) whose
    /// `query` answers this session's region lookups bit-identically
    /// to the in-RAM path.  The route for frames whose `b×h×w` tensor
    /// exceeds the server's host memory budget.
    pub fn process_spilled(&mut self, frame: &VideoFrame) -> Result<(TensorStore, ShardReport)> {
        let t0 = Instant::now();
        // Bin straight into a fresh shared image (one allocation, no
        // second copy): on this route frames are huge by definition,
        // and the shard workers need to share the buffer.
        let image = Arc::new(frame.binned(self.bins));
        let res = self.inner.compute_spilled(&image)?;
        self.frames += 1;
        self.latencies_ms.push(t0.elapsed().as_secs_f64() * 1e3);
        Ok(res)
    }

    /// Drive a whole stream through this session's pipeline lane
    /// (read → compute → sink overlapped across `lanes` frames),
    /// folding the run's per-frame latencies into the session and
    /// server metrics.
    pub fn run_stream(
        &mut self,
        source: Box<dyn FrameSource>,
        sink: impl FnMut(usize, PooledTensor) + Send,
    ) -> Result<PipelineReport> {
        let report = self.pipeline.run_with(source, sink)?;
        self.frames += report.throughput.frames;
        self.inner.metrics.frames.fetch_add(report.throughput.frames, Ordering::Relaxed);
        for s in &report.throughput.stats {
            let ms = s.latency.as_secs_f64() * 1e3;
            self.latencies_ms.push(ms);
            self.inner.metrics.push_latency(ms);
        }
        Ok(report)
    }

    /// Enqueue a region query for the next [`Self::answer_queries`].
    pub fn submit_query(&mut self, id: u64, rect: Rect) {
        self.batcher.submit(id, rect);
    }

    /// Pending (unanswered) queries.
    pub fn pending_queries(&self) -> usize {
        self.batcher.pending()
    }

    /// Answer every pending query against `ih` (deduplicated,
    /// submission order preserved — see [`QueryBatcher`]).
    pub fn answer_queries(&mut self, ih: &IntegralHistogram) -> Vec<QueryResponse> {
        let responses = self.batcher.flush(ih);
        self.queries += responses.len();
        self.inner.metrics.queries.fetch_add(responses.len(), Ordering::Relaxed);
        responses
    }

    /// Attach a block-motion detector (replaces any attachment).
    pub fn attach_motion(&mut self, grid: usize, threshold: f32) {
        self.analytics = Some(SessionAnalytics::Motion(MotionDetector::new(grid, threshold)));
    }

    /// Attach a histogram-matching tracker initialized from `rect` in
    /// `ih` (replaces any attachment).
    pub fn attach_tracker(&mut self, ih: &IntegralHistogram, rect: Rect, config: TrackerConfig) {
        self.analytics = Some(SessionAnalytics::Tracker(Track::init(ih, rect, config)));
    }

    pub fn detach_analytics(&mut self) -> Option<SessionAnalytics> {
        self.analytics.take()
    }

    /// Advance the attachment on this frame's tensor, if any.
    pub fn step_analytics(&mut self, ih: &IntegralHistogram) -> Option<AnalyticsEvent> {
        match self.analytics.as_mut()? {
            SessionAnalytics::Motion(m) => Some(AnalyticsEvent::Motion(m.step(ih))),
            SessionAnalytics::Tracker(t) => Some(AnalyticsEvent::Track(t.step(ih))),
        }
    }

    /// The lane engine's worker-pool counters (zero-spawn assertions).
    pub fn lane_pool_stats(&self) -> crate::histogram::engine::WorkerPoolStats {
        self.pipeline.engine_pool_stats()
    }

    /// Stream-local counters and latency distribution (over the most
    /// recent [`SESSION_LATENCY_RESERVOIR`] frames).
    pub fn stats(&self) -> SessionSnapshot {
        SessionSnapshot {
            id: self.id,
            frames: self.frames,
            queries: self.queries,
            batcher: self.batcher.stats(),
            latency: LatencySummary::of_ms(&self.latencies_ms.buf),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::histogram::region::region_histogram;
    use crate::histogram::sequential::integral_histogram_seq;
    use crate::video::synth::SyntheticVideo;
    use std::path::PathBuf;

    fn manifest() -> Arc<ArtifactManifest> {
        Arc::new(ArtifactManifest {
            dir: PathBuf::from("/nonexistent"),
            profile: "test".into(),
            artifacts: vec![],
        })
    }

    fn server() -> Server {
        Server::new(manifest(), ServerConfig::default())
    }

    #[test]
    fn compute_is_bit_identical_to_serial() {
        let srv = server();
        let video = SyntheticVideo::new(96, 80, 2, 3);
        for t in 0..3 {
            let img = video.frame(t).binned(8);
            let (ih, _) = srv.compute(&img).expect("cpu route");
            let expected = integral_histogram_seq(&img);
            assert_eq!(expected.max_abs_diff(&ih), 0.0, "frame {t}");
        }
        let snap = srv.snapshot();
        assert_eq!(snap.frames, 3);
        assert_eq!(snap.engines_created, 1, "one checkout engine serves serial traffic");
        assert_eq!(snap.latency.n, 3);
        // all three tensors recycled through one arena buffer
        let fp = snap.frame_pool;
        assert_eq!(fp.allocated, 1, "{fp:?}");
        assert_eq!(fp.reused, 2);
    }

    #[test]
    fn latency_window_resets_without_touching_counters() {
        let srv = server();
        let img = SyntheticVideo::new(48, 48, 1, 1).frame(0).binned(8);
        for _ in 0..4 {
            let _ = srv.compute(&img).expect("compute");
        }
        assert_eq!(srv.snapshot().latency.n, 4);
        srv.reset_latency_stats();
        let snap = srv.snapshot();
        assert_eq!(snap.latency.n, 0, "reservoir cleared");
        assert_eq!(snap.frames, 4, "counters survive the window reset");
        let _ = srv.compute(&img).expect("compute");
        assert_eq!(srv.snapshot().latency.n, 1);
    }

    #[test]
    fn admission_control_caps_sessions() {
        let mut cfg = ServerConfig::default();
        cfg.max_sessions = 2;
        let srv = Server::new(manifest(), cfg);
        let s1 = srv.open_session().expect("slot 1");
        let _s2 = srv.open_session().expect("slot 2");
        assert_eq!(srv.sessions_active(), 2);
        let err = srv.open_session().err().expect("must reject").to_string();
        assert!(err.contains("admission"), "{err}");
        drop(s1);
        assert_eq!(srv.sessions_active(), 1);
        let _s3 = srv.open_session().expect("slot freed by drop");
        let snap = srv.snapshot();
        assert_eq!(snap.sessions_opened, 3);
        assert_eq!(snap.sessions_rejected, 1);
        assert_eq!(snap.sessions_peak, 2);
    }

    #[test]
    fn session_processes_and_answers_queries() {
        let srv = server();
        let mut session = srv.open_session().expect("session");
        let video = SyntheticVideo::new(64, 64, 2, 5);
        let frame = video.frame(0);
        let ih = session.process(&frame).expect("process");
        let expected = integral_histogram_seq(&frame.binned(32));
        assert_eq!(expected.max_abs_diff(&ih), 0.0);

        let r1 = Rect::with_size(0, 0, 64, 64);
        let r2 = Rect::with_size(5, 9, 20, 30);
        session.submit_query(10, r1);
        session.submit_query(11, r2);
        session.submit_query(12, r1); // duplicate — deduped
        let rs = session.answer_queries(&ih);
        assert_eq!(rs.len(), 3);
        assert_eq!(rs[0].id, 10);
        assert_eq!(rs[2].id, 12);
        assert_eq!(rs[0].histogram, region_histogram(&expected, r1));
        assert_eq!(rs[1].histogram, region_histogram(&expected, r2));
        assert_eq!(rs[0].histogram, rs[2].histogram);

        let st = session.stats();
        assert_eq!(st.frames, 1);
        assert_eq!(st.queries, 3);
        assert_eq!(st.batcher, (3, 2), "duplicate rect computed once");
        assert_eq!(st.latency.n, 1);
        assert_eq!(srv.snapshot().queries, 3);
    }

    #[test]
    fn session_runs_stream_on_its_lane() {
        let srv = server();
        let mut session = srv.open_session().expect("session");
        let frames = 6usize;
        let video = SyntheticVideo::new(64, 48, 2, 9);
        let src = Box::new(SyntheticVideo::new(64, 48, 2, 9).take_frames(frames));
        let mut checked = 0usize;
        let report = session
            .run_stream(src, |seq, ih| {
                let expected = integral_histogram_seq(&video.frame(seq).binned(32));
                assert_eq!(expected.max_abs_diff(&ih), 0.0, "frame {seq}");
                checked += 1;
            })
            .expect("stream");
        assert_eq!(report.throughput.frames, frames);
        assert_eq!(checked, frames);
        let st = session.stats();
        assert_eq!(st.frames, frames);
        assert_eq!(st.latency.n, frames);
        let snap = srv.snapshot();
        assert_eq!(snap.frames, frames, "lane frames count globally");
        assert!(snap.frame_pool.allocated <= 4, "lane recycles via the shared arena");
    }

    #[test]
    fn session_analytics_attachment_steps() {
        let srv = server();
        let mut session = srv.open_session().expect("session");
        let video = SyntheticVideo::new(64, 64, 3, 4);
        let ih0 = session.process(&video.frame(0)).expect("frame 0");
        session.attach_motion(4, 0.05);
        match session.step_analytics(&ih0) {
            Some(AnalyticsEvent::Motion(map)) => assert_eq!(map.scores.len(), 16),
            other => panic!("expected motion event, got {:?}", other.is_some()),
        }
        // swap to a tracker seeded from the same tensor
        session.attach_tracker(&ih0, Rect::with_size(10, 10, 16, 16), TrackerConfig::default());
        let ih1 = session.process(&video.frame(1)).expect("frame 1");
        match session.step_analytics(&ih1) {
            Some(AnalyticsEvent::Track(rect)) => {
                assert_eq!((rect.height(), rect.width()), (16, 16));
            }
            other => panic!("expected track event, got {:?}", other.is_some()),
        }
        assert!(session.detach_analytics().is_some());
        assert!(session.step_analytics(&ih1).is_none());
    }

    #[test]
    fn oversized_frames_route_through_the_same_front_door() {
        let mut cfg = ServerConfig::default();
        cfg.engine.bins = 8;
        cfg.engine.device_memory_budget = 1 << 10; // force the sharded route
        cfg.shard_workers = 2;
        let srv = Server::new(manifest(), cfg);
        assert_eq!(srv.route_for(40, 40), Route::TaskQueue);
        let img = SyntheticVideo::new(40, 40, 1, 2).frame(0).binned(8);
        // the interleaved shard executor serves it, bit-identically
        let (ih, _) = srv.compute(&img).expect("sharded route for large frames");
        let expected = integral_histogram_seq(&img);
        assert_eq!(expected.max_abs_diff(&ih), 0.0);
        let snap = srv.snapshot();
        let shard = snap.shard.expect("executor built on first large frame");
        assert!(shard.jobs >= 1, "large frame ran as shard jobs");
        assert_eq!(shard.frames_inflight, 0);
    }

    #[test]
    fn over_host_budget_frames_spill_to_the_tensor_store() {
        let mut cfg = ServerConfig::default();
        cfg.engine.bins = 8;
        cfg.engine.device_memory_budget = 1 << 10; // large route
        cfg.engine.cpu_fallback_budget = 16 << 10; // CPU may not serve it either
        cfg.host_memory_budget = 8 << 10; // 8 KiB host budget
        cfg.shard_workers = 2;
        let srv = Server::new(manifest(), cfg);
        let video = SyntheticVideo::new(48, 40, 1, 6);
        let img = video.frame(0).binned(8);
        // 8×48×40×4 = 60 KiB tensor > 8 KiB budget → in-RAM route refuses…
        let err = srv.compute(&img).err().expect("must refuse").to_string();
        assert!(err.contains("compute_spilled"), "{err}");
        // …and the spilled route completes inside the budget.
        let mut session = srv.open_session().expect("session");
        let (store, report) = session.process_spilled(&video.frame(0)).expect("spill");
        assert!(
            report.peak_resident_bytes <= srv.config().host_memory_budget,
            "peak resident {} must stay within the {} B budget",
            report.peak_resident_bytes,
            srv.config().host_memory_budget
        );
        let expected = integral_histogram_seq(&img);
        let back = store.to_histogram().expect("materialize for verification");
        assert_eq!(expected.max_abs_diff(&back), 0.0);
        // Region queries served straight from the spilled planes.
        let rect = Rect::with_size(3, 5, 20, 17);
        assert_eq!(
            store.query(rect).expect("store query"),
            crate::histogram::region::region_histogram(&expected, rect)
        );
        assert_eq!(session.stats().frames, 1);
    }

    /// The server-wide memory-accounting fix: the per-frame
    /// `host_memory_budget` check cannot see *concurrent* frames, so
    /// N in-budget ops used to overcommit the host N× unmetered.  Now
    /// every op reserves its peak-residency bytes from one shared
    /// token bucket and overcommit sheds typed — and the bucket's
    /// high-water mark proves it never exceeded the cap.
    #[test]
    fn host_memory_cap_sheds_concurrent_overcommit_typed() {
        let mut cfg = ServerConfig::default();
        cfg.engine.bins = 8;
        cfg.engine.device_memory_budget = 1 << 10; // large route
        cfg.engine.cpu_fallback_budget = 16 << 10;
        cfg.host_memory_budget = 8 << 10; // per-frame: the spill route
        cfg.host_memory_cap = 12 << 10; // server-wide: one frame fits, two don't
        cfg.shard_workers = 2;
        let srv = Server::new(manifest(), cfg);
        let img = SyntheticVideo::new(48, 40, 1, 6).frame(0).binned(8);
        let image = Arc::new(img.clone());

        // A concurrent op's worth of bytes held against the bucket…
        let hold = srv.inner.mem.try_reserve(8 << 10).expect("first reservation fits the cap");
        // …means this frame's 8 KiB charge would overcommit the cap.
        let err = srv.compute_spilled(&image).err().expect("must shed").to_string();
        assert!(err.contains("overcommit"), "{err}");
        drop(hold);

        // Once the concurrent hold frees, the same frame serves.
        let (store, report) = srv.compute_spilled(&image).expect("fits after the hold frees");
        assert!(report.peak_resident_bytes <= srv.config().host_memory_budget);
        let expected = integral_histogram_seq(&img);
        let back = store.to_histogram().expect("materialize for verification");
        assert_eq!(expected.max_abs_diff(&back), 0.0);

        let h = srv.health();
        assert_eq!(h.mem_cap, 12 << 10);
        assert!(h.mem_high_water <= h.mem_cap, "bucket never overcommitted: {h:?}");
        assert!(h.mem_shed >= 1, "the refused op is counted");
        assert_eq!(h.mem_reserved, 0, "reservations settle when ops finish");
    }

    /// The admission-estimate bugfix: the spilled route used to charge
    /// the per-frame budget *ceiling* against the bucket no matter
    /// what frames actually measured, so a host with room for the real
    /// peak residency kept shedding on the pessimistic static
    /// estimate.  The EWMA of measured `peak_resident_bytes` corrects
    /// the charge (clamped to `[planned/4, 4·planned]`), and a taught
    /// server admits a frame the untaught one sheds.
    #[test]
    fn ewma_admission_learns_measured_residency_and_admits() {
        // The clamp contract first, on a bare ratio cell: hostile
        // taught ratios can move the charge at most 4× either way,
        // and a zero-planned observation is ignored outright.
        let r = AtomicU64::new(1f64.to_bits());
        assert_eq!(Inner::admission_charge(8 << 10, &r), 8 << 10);
        r.store(100.0f64.to_bits(), Ordering::Relaxed);
        assert_eq!(Inner::admission_charge(8 << 10, &r), 32 << 10);
        r.store(0.0f64.to_bits(), Ordering::Relaxed);
        assert_eq!(Inner::admission_charge(8 << 10, &r), 2 << 10);
        assert_eq!(Inner::admission_charge(0, &r), 1, "charge never hits zero");
        Inner::observe_admission(0, 123, &r);
        assert_eq!(r.load(Ordering::Relaxed), 0.0f64.to_bits());

        let mut cfg = ServerConfig::default();
        cfg.engine.bins = 8;
        cfg.engine.device_memory_budget = 1 << 10; // large route
        cfg.engine.cpu_fallback_budget = 16 << 10;
        cfg.host_memory_budget = 8 << 10;
        cfg.host_memory_cap = 12 << 10;
        cfg.shard_workers = 2;
        let srv = Server::new(manifest(), cfg);
        let img = SyntheticVideo::new(48, 40, 1, 6).frame(0).binned(8);
        let image = Arc::new(img.clone());

        // Untaught (ratio 1.0): the ceiling charge of 8 KiB cannot fit
        // beside an 8 KiB concurrent hold under the 12 KiB cap.
        let hold = srv.inner.mem.try_reserve(8 << 10).expect("hold fits the cap");
        let err = srv.compute_spilled(&image).err().expect("untaught charge sheds").to_string();
        assert!(err.contains("overcommit"), "{err}");

        // Taught — the state observe_admission converges to once
        // measured peaks run well under the ceiling — the charge
        // shrinks toward measured reality and the same frame fits
        // beside the same hold, bit-identical.
        srv.inner.admit_ratio_spilled.store(0.3f64.to_bits(), Ordering::Relaxed);
        let (store, report) = srv.compute_spilled(&image).expect("taught charge admits");
        assert!(report.peak_resident_bytes <= srv.config().host_memory_budget);
        let expected = integral_histogram_seq(&img);
        let back = store.to_histogram().expect("materialize for verification");
        assert_eq!(expected.max_abs_diff(&back), 0.0);
        drop(hold);

        // The successful op settled its own measured observation into
        // the ratio: moved off the forced value, still in-band.
        let taught = f64::from_bits(srv.inner.admit_ratio_spilled.load(Ordering::Relaxed));
        assert!(taught > 0.25 && taught < 4.0 && taught != 0.3, "taught ratio {taught}");

        let h = srv.health();
        assert!(h.mem_high_water <= h.mem_cap, "bucket never overcommitted: {h:?}");
        assert!(h.mem_shed >= 1, "the untaught refusal is counted");
    }

    /// With no cap configured (the default) the bucket is unlimited
    /// but still metered: health reports a live high-water mark and
    /// nothing sheds — the tier-1 behaviour is unchanged.
    #[test]
    fn uncapped_memory_bucket_meters_without_shedding() {
        let mut cfg = ServerConfig::default();
        cfg.engine.bins = 8;
        cfg.engine.device_memory_budget = 1 << 10; // large route
        cfg.shard_workers = 2;
        let srv = Server::new(manifest(), cfg);
        let img = SyntheticVideo::new(40, 40, 1, 2).frame(0).binned(8);
        let (ih, _) = srv.compute(&img).expect("uncapped bucket never sheds");
        let expected = integral_histogram_seq(&img);
        assert_eq!(expected.max_abs_diff(&ih), 0.0);
        let h = srv.health();
        assert_eq!(h.mem_cap, 0);
        assert_eq!(h.mem_shed, 0);
        assert!(
            h.mem_high_water >= 8 * 40 * 40 * 4,
            "the sharded op's tensor bytes were metered: {}",
            h.mem_high_water
        );
        assert_eq!(h.mem_reserved, 0);
    }

    #[test]
    fn fallback_disabled_propagates_error() {
        let mut cfg = ServerConfig::default();
        cfg.engine.cpu_fallback = false;
        let srv = Server::new(manifest(), cfg);
        let img = SyntheticVideo::new(32, 32, 1, 1).frame(0).binned(8);
        assert!(srv.compute(&img).is_err());
    }

    /// The AdmissionGuard regression test at the server level: a
    /// session that panics on its owning thread must free its slot via
    /// unwind, where the old token-channel admission leaked it.
    #[test]
    fn panicked_session_frees_its_admission_slot() {
        let mut cfg = ServerConfig::default();
        cfg.max_sessions = 1;
        let srv = Server::new(manifest(), cfg);
        let srv2 = srv.clone();
        let t = std::thread::spawn(move || {
            let _session = srv2.open_session().expect("slot");
            panic!("stream thread died");
        });
        assert!(t.join().is_err());
        assert_eq!(srv.sessions_active(), 0, "unwind must free the slot");
        let _s = srv.open_session().expect("slot reusable after the panic");
    }

    #[test]
    fn drain_refuses_work_then_resume_restores() {
        let srv = server();
        let img = SyntheticVideo::new(48, 48, 1, 1).frame(0).binned(8);
        let _ = srv.compute(&img).expect("running server serves");
        assert!(srv.drain(Duration::from_secs(1)), "no in-flight ops: drains immediately");
        assert_eq!(srv.health().state, ServerState::Draining);
        let err = srv.compute(&img).err().expect("draining refuses work").to_string();
        assert!(err.contains("draining"), "{err}");
        assert!(srv.open_session().is_err(), "draining refuses sessions");
        srv.resume();
        assert_eq!(srv.health().state, ServerState::Running);
        let _ = srv.compute(&img).expect("resumed server serves again");
        assert_eq!(srv.snapshot().frames, 2);
    }

    #[test]
    fn shutdown_joins_the_shard_executor() {
        let mut cfg = ServerConfig::default();
        cfg.engine.bins = 8;
        cfg.engine.device_memory_budget = 1 << 10; // force the sharded route
        cfg.shard_workers = 2;
        let srv = Server::new(manifest(), cfg);
        let img = SyntheticVideo::new(40, 40, 1, 2).frame(0).binned(8);
        let _ = srv.compute(&img).expect("sharded route");
        assert!(srv.snapshot().shard.is_some(), "executor built");
        assert!(srv.shutdown(Duration::from_secs(1)));
        assert_eq!(srv.health().state, ServerState::Stopped);
        assert!(srv.snapshot().shard.is_none(), "executor dropped and joined");
        assert!(srv.compute(&img).is_err(), "stopped server refuses work");
    }

    /// Degradation order under overload: the large (shard) route sheds
    /// at the limit while small frames still serve; small frames shed
    /// only at twice the limit; everything recovers when load falls.
    #[test]
    fn overload_sheds_large_before_small() {
        let mut cfg = ServerConfig::default();
        cfg.engine.bins = 8;
        cfg.engine.device_memory_budget = 1 << 10; // 40×40 routes large
        cfg.shard_workers = 2;
        cfg.overload_inflight_limit = 2;
        let srv = Server::new(manifest(), cfg);
        let small = SyntheticVideo::new(16, 16, 1, 1).frame(0).binned(8);
        let large = SyntheticVideo::new(40, 40, 1, 2).frame(0).binned(8);
        assert_eq!(srv.route_for(40, 40), Route::TaskQueue);
        assert_eq!(srv.route_for(16, 16), Route::Direct);

        srv.force_inflight(2); // at the limit
        let err = srv.compute(&large).err().expect("large is shed").to_string();
        assert!(err.contains("overload"), "{err}");
        let _ = srv.compute(&small).expect("small frames still serve at 1× limit");
        assert!(srv.health().degraded);

        srv.force_inflight(4); // at 2× the limit
        let err = srv.compute(&small).err().expect("small is shed too").to_string();
        assert!(err.contains("overload"), "{err}");

        srv.force_inflight(0); // load falls off
        let _ = srv.compute(&large).expect("large serves again");
        let _ = srv.compute(&small).expect("small serves again");
        let health = srv.health();
        assert!(!health.degraded);
        assert_eq!(health.shed_large, 1);
        assert_eq!(health.shed_small, 1);
        assert_eq!(health.inflight, 0, "op guards settled the gauge");
    }

    #[test]
    fn health_reports_shard_worker_liveness() {
        let mut cfg = ServerConfig::default();
        cfg.engine.bins = 8;
        cfg.engine.device_memory_budget = 1 << 10;
        cfg.shard_workers = 2;
        let srv = Server::new(manifest(), cfg);
        let h0 = srv.health();
        assert_eq!(h0.state, ServerState::Running);
        assert_eq!((h0.shard_workers_alive, h0.shard_workers_total), (0, 0), "no executor yet");
        let img = SyntheticVideo::new(40, 40, 1, 2).frame(0).binned(8);
        let _ = srv.compute(&img).expect("sharded route");
        let h1 = srv.health();
        assert_eq!(h1.shard_workers_total, 2);
        assert_eq!(h1.shard_workers_alive, 2, "healthy workers all alive");
        assert_eq!(h1.shard_frames_failed, 0);
        assert_eq!(h1.shard_frames_abandoned, 0);
        assert_eq!(h1.inflight, 0);
    }

    /// The calibration loop end-to-end at the serving layer: a server
    /// built with a calibrator microbenches at startup, serves both
    /// routes bit-identically through the shared tuned planner, and
    /// its snapshot exposes a live (sample-fed) cost snapshot.
    #[test]
    fn calibrated_server_serves_bit_identically_and_reports_snapshot() {
        let mut cfg = ServerConfig::default();
        cfg.engine.bins = 8;
        // 8×48×40×4 = 60 KiB fits; 8×64×64×4 = 128 KiB routes large.
        cfg.engine.device_memory_budget = 64 << 10;
        cfg.shard_workers = 2;
        cfg.calibrator = Some(Arc::new(Calibrator::default()));
        let srv = Server::new(manifest(), cfg);
        let baseline = srv.snapshot().calibration.expect("calibrator configured");
        assert!(baseline.samples > 0, "startup microbench must seed the snapshot");

        let small = SyntheticVideo::new(48, 40, 2, 5).frame(0).binned(8);
        let large = SyntheticVideo::new(64, 64, 2, 5).frame(1).binned(8);
        assert_eq!(srv.route_for(48, 40), Route::Direct);
        assert_eq!(srv.route_for(64, 64), Route::TaskQueue);
        for img in [&small, &large] {
            let (ih, _) = srv.compute(img).expect("calibrated route");
            let expected = integral_histogram_seq(img);
            assert_eq!(expected.max_abs_diff(&ih), 0.0);
        }
        let snap = srv.snapshot();
        let live = snap.calibration.expect("snapshot carries calibration");
        assert!(live.samples > baseline.samples, "live frames must feed the EWMA loop");
        let shard = snap.shard.expect("large frame built the executor");
        assert!(shard.tune.is_some(), "shard engines run through the tuned planner");
    }

    /// The calibrated-shedding satellite: with a measured cost model
    /// and the static limit left at 0 (disabled), the effective limit
    /// is derived from per-frame cost — and enforced.
    #[test]
    fn calibrated_cost_model_derives_the_overload_limit() {
        let mut cfg = ServerConfig::default();
        cfg.engine.bins = 8;
        cfg.engine.device_memory_budget = 1 << 10;
        cfg.shard_workers = 2;
        cfg.calibrator = Some(Arc::new(Calibrator::default()));
        let srv = Server::new(manifest(), cfg);
        let h = srv.health();
        assert!(
            (2..=256).contains(&h.overload_limit),
            "derived limit must land in the clamp range, got {}",
            h.overload_limit
        );
        // Saturate past the clamp ceiling: the large route sheds.
        srv.force_inflight(256);
        let large = SyntheticVideo::new(40, 40, 1, 2).frame(0).binned(8);
        let err = srv.compute(&large).err().expect("calibrated shedding").to_string();
        assert!(err.contains("overload"), "{err}");
        srv.force_inflight(0);
        let _ = srv.compute(&large).expect("recovers when load falls");
        // Cold-start fallback: no calibrator ⇒ the static value (here
        // 0 = disabled) stays in force.
        let srv2 = server();
        assert_eq!(srv2.health().overload_limit, 0);
    }

    /// The tuning-cache persistence satellite: drain saves the learned
    /// plans, a fresh server generation warms from the file, and
    /// `recalibrate()` drops both cache and file explicitly.
    #[test]
    fn tune_cache_persists_across_server_generations() {
        let path = std::env::temp_dir()
            .join(format!("inthist-tunecache-test-{}.json", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let mut cfg = ServerConfig::default();
        cfg.calibrator = Some(Arc::new(Calibrator::default()));
        cfg.tune_cache_path = Some(path.clone());
        let srv = Server::new(manifest(), cfg.clone());
        let img = SyntheticVideo::new(48, 48, 1, 1).frame(0).binned(8);
        let _ = srv.compute(&img).expect("compute populates the tuner");
        assert!(srv.drain(Duration::from_secs(1)));
        assert!(path.exists(), "drain persists the tuning cache");
        // A fresh generation warms from the file: recalibrate() reports
        // how many cached plans it dropped, which proves the load.
        let srv2 = Server::new(manifest(), cfg);
        let dropped = srv2.recalibrate();
        assert!(dropped >= 1, "warmed cache must hold the persisted plan, got {dropped}");
        assert!(!path.exists(), "recalibrate deletes the persisted cache");
    }

    /// Process isolation is opt-in and fails loud: a missing
    /// `proc-worker` binary is a typed deployment error, never a
    /// silent fallback to the unisolated path.  (Live child-process
    /// coverage runs in `tests/proc_property.rs`, where cargo provides
    /// the built binary.)
    #[test]
    fn process_isolation_with_missing_worker_binary_fails_typed() {
        let mut cfg = ServerConfig::default();
        cfg.engine.bins = 8;
        cfg.engine.device_memory_budget = 1 << 10;
        cfg.process_isolation = true;
        cfg.proc.worker_bin = Some(PathBuf::from("/nonexistent/proc-worker"));
        let srv = Server::new(manifest(), cfg);
        let img = SyntheticVideo::new(40, 40, 1, 2).frame(0).binned(8);
        let err = srv.compute(&img).err().expect("missing worker binary").to_string();
        assert!(err.contains("does not exist"), "{err}");
        assert!(srv.snapshot().proc.is_none(), "no supervisor was built");
    }

    /// A configured frame deadline rides through the server to the
    /// shard route; a generous one never fires on healthy traffic.
    #[test]
    fn frame_deadline_passes_through_healthy() {
        let mut cfg = ServerConfig::default();
        cfg.engine.bins = 8;
        cfg.engine.device_memory_budget = 1 << 10;
        cfg.shard_workers = 2;
        cfg.frame_deadline = Some(Duration::from_secs(30));
        let srv = Server::new(manifest(), cfg);
        let img = SyntheticVideo::new(40, 40, 1, 2).frame(0).binned(8);
        let (ih, _) = srv.compute(&img).expect("deadline must not fire");
        let expected = integral_histogram_seq(&img);
        assert_eq!(expected.max_abs_diff(&ih), 0.0);
    }
}
