//! `FramePool` — a buffer arena recycling integral-histogram storage
//! across frames.
//!
//! The paper's pipeline (§4.4) keeps two *page-locked* host buffers
//! alive for the whole run and ping-pongs frames through them; it never
//! allocates per frame.  The CPU-substrate analogue: a `512²×32` tensor
//! is a 32 MB allocation whose `zeros()` memset plus page faults cost
//! milliseconds — comparable to the scan itself.  The pool keeps
//! returned buffers on a free list and re-issues them **without
//! zeroing** (every engine schedule overwrites every element; the
//! property tests prove a recycled buffer yields bit-identical output),
//! so the steady-state request path performs zero heap allocation.
//!
//! The `allocated` / `reused` counters make the steady-state claim
//! observable and are asserted by `tests/engine_property.rs` and
//! reported by `benches/hotpath.rs`.

use crate::histogram::types::IntegralHistogram;
use crate::util::sync::lock_recover;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Thread-safe free list of tensor storage buffers.
#[derive(Debug, Default)]
pub struct FramePool {
    free: Mutex<Vec<Vec<f32>>>,
    allocated: AtomicUsize,
    reused: AtomicUsize,
}

/// Pool observability counters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolStats {
    /// Buffers created because the free list was empty.
    pub allocated: usize,
    /// Acquisitions served by recycling a returned buffer.
    pub reused: usize,
    /// Buffers currently idle on the free list.
    pub idle: usize,
}

impl FramePool {
    pub fn new() -> FramePool {
        FramePool::default()
    }

    /// Take a `bins×h×w` tensor: recycled storage when available
    /// (resized, **not** zeroed), a fresh zeroed allocation otherwise.
    pub fn acquire(&self, bins: usize, h: usize, w: usize) -> IntegralHistogram {
        // Free-list entries are whole buffers (valid at every
        // instruction boundary), so a poisoned lock — some other
        // holder panicked — is recovered, not propagated: buffer reuse
        // must survive unrelated thread deaths (DESIGN.md §8).
        let recycled = lock_recover(&self.free).pop();
        match recycled {
            Some(buf) => {
                self.reused.fetch_add(1, Ordering::Relaxed);
                IntegralHistogram::from_storage(bins, h, w, buf)
            }
            None => {
                self.allocated.fetch_add(1, Ordering::Relaxed);
                IntegralHistogram::zeros(bins, h, w)
            }
        }
    }

    /// Idle buffers retained per pool.  Past the cap released storage
    /// is dropped instead of kept: producers that only *adopt* tensors
    /// into the pool (artifact / task-queue routes) would otherwise
    /// grow the free list by one tensor per frame, unbounded.
    const MAX_IDLE: usize = 64;

    /// Return a tensor's storage to the free list (dropped once
    /// [`Self::MAX_IDLE`] buffers are already idle).
    pub fn release(&self, ih: IntegralHistogram) {
        let mut free = lock_recover(&self.free);
        if free.len() < Self::MAX_IDLE {
            free.push(ih.into_storage());
        }
    }

    pub fn stats(&self) -> PoolStats {
        PoolStats {
            allocated: self.allocated.load(Ordering::Relaxed),
            reused: self.reused.load(Ordering::Relaxed),
            idle: lock_recover(&self.free).len(),
        }
    }
}

/// An [`IntegralHistogram`] checked out of a [`FramePool`]; derefs to
/// the tensor and returns its storage to the pool on drop.
#[derive(Debug)]
pub struct PooledTensor {
    ih: Option<IntegralHistogram>,
    pool: Arc<FramePool>,
}

impl PooledTensor {
    /// RAII acquire from `pool`: the tensor returns to the pool when
    /// the handle drops (unless detached with [`Self::take`]).
    pub fn acquire(pool: &Arc<FramePool>, bins: usize, h: usize, w: usize) -> PooledTensor {
        PooledTensor { ih: Some(pool.acquire(bins, h, w)), pool: Arc::clone(pool) }
    }

    /// Detach the tensor from the pool (it will not be recycled).
    pub fn take(mut self) -> IntegralHistogram {
        self.ih.take().expect("tensor already taken")
    }

    /// Adopt an already-computed tensor into `pool`'s recycling
    /// discipline: the handle behaves exactly like an acquired one and
    /// returns the storage to the pool on drop.  Used by the server to
    /// give artifact-path and task-queue results the same RAII shape as
    /// the pooled CPU path.
    pub fn adopt(pool: &Arc<FramePool>, ih: IntegralHistogram) -> PooledTensor {
        PooledTensor { ih: Some(ih), pool: Arc::clone(pool) }
    }
}

impl std::ops::Deref for PooledTensor {
    type Target = IntegralHistogram;

    fn deref(&self) -> &IntegralHistogram {
        self.ih.as_ref().expect("tensor already taken")
    }
}

impl std::ops::DerefMut for PooledTensor {
    fn deref_mut(&mut self) -> &mut IntegralHistogram {
        self.ih.as_mut().expect("tensor already taken")
    }
}

impl Drop for PooledTensor {
    fn drop(&mut self) {
        if let Some(ih) = self.ih.take() {
            self.pool.release(ih);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn acquire_release_recycles() {
        let pool = FramePool::new();
        let a = pool.acquire(2, 4, 4);
        assert_eq!(pool.stats(), PoolStats { allocated: 1, reused: 0, idle: 0 });
        pool.release(a);
        assert_eq!(pool.stats().idle, 1);
        let b = pool.acquire(2, 4, 4);
        assert_eq!(pool.stats(), PoolStats { allocated: 1, reused: 1, idle: 0 });
        drop(b);
    }

    #[test]
    fn recycled_buffer_is_not_zeroed() {
        let pool = FramePool::new();
        let mut a = pool.acquire(1, 2, 2);
        a.data[3] = 42.0;
        pool.release(a);
        let b = pool.acquire(1, 2, 2);
        assert_eq!(b.data[3], 42.0, "reuse must skip the memset");
    }

    #[test]
    fn geometry_change_resizes() {
        let pool = FramePool::new();
        pool.release(pool.acquire(1, 2, 2));
        let big = pool.acquire(2, 8, 8);
        assert_eq!(big.data.len(), 128);
        assert_eq!(pool.stats().reused, 1, "resize still counts as reuse");
    }

    #[test]
    fn handle_returns_on_drop_and_take_detaches() {
        let pool = Arc::new(FramePool::new());
        {
            let h = PooledTensor::acquire(&pool, 1, 3, 3);
            assert_eq!((h.bins, h.h, h.w), (1, 3, 3));
        }
        assert_eq!(pool.stats().idle, 1, "drop must return the buffer");
        let h = PooledTensor::acquire(&pool, 1, 3, 3);
        let owned = h.take();
        assert_eq!(owned.data.len(), 9);
        assert_eq!(pool.stats().idle, 0, "take must detach");
    }

    #[test]
    fn idle_list_is_bounded() {
        let pool = FramePool::new();
        for _ in 0..FramePool::MAX_IDLE + 9 {
            pool.release(IntegralHistogram::zeros(1, 1, 1));
        }
        assert_eq!(
            pool.stats().idle,
            FramePool::MAX_IDLE,
            "excess released buffers must be dropped, not retained"
        );
    }

    #[test]
    fn adopt_recycles_foreign_tensors() {
        let pool = Arc::new(FramePool::new());
        let ih = IntegralHistogram::zeros(2, 3, 3);
        {
            let h = PooledTensor::adopt(&pool, ih);
            assert_eq!((h.bins, h.h, h.w), (2, 3, 3));
        }
        let st = pool.stats();
        assert_eq!(st.idle, 1, "adopted storage must land on the free list");
        assert_eq!(st.allocated, 0, "adoption is not a pool allocation");
    }

    #[test]
    fn pool_is_shared_across_threads() {
        let pool = Arc::new(FramePool::new());
        std::thread::scope(|s| {
            for _ in 0..4 {
                let pool = Arc::clone(&pool);
                s.spawn(move || {
                    for _ in 0..10 {
                        let t = pool.acquire(1, 8, 8);
                        pool.release(t);
                    }
                });
            }
        });
        let st = pool.stats();
        assert_eq!(st.allocated + st.reused, 40);
        assert_eq!(st.idle, st.allocated);
    }
}
