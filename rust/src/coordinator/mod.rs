//! The L3 coordination layer — the paper's system contribution, serving
//! shaped: frames stream in, integral histograms (and region-query
//! results) stream out, with the paper's two scaling mechanisms as
//! first-class features:
//!
//! * [`pipeline`] — the dual-buffered frame pipeline (Algorithm 6,
//!   Figs. 12/14): read → H2D → kernel → D2H stages overlapped across
//!   in-flight frames ("CUDA streams" = pipeline lanes).
//! * [`task_queue`] — the multi-device bin task queue (§4.6, Fig. 18)
//!   for images whose tensor exceeds one device's memory.
//! * [`router`] — [`router::Engine`]: the front door.  Picks strategy
//!   and artifact for a request, owns executor caches, routes small
//!   frames to the direct path and large frames to the task queue.
//! * [`batcher`] — groups region-query requests against cached tensors
//!   (the O(1) lookup service downstream analytics call).
//! * [`frame_pool`] — the buffer arena recycling integral-histogram
//!   storage across frames (the paper's persistent page-locked buffers,
//!   §4.4): steady-state requests allocate nothing.
//! * [`backpressure`] — bounded hand-off queues with occupancy stats.
//! * [`metrics`] — per-frame stage timings and throughput accounting.

pub mod backpressure;
pub mod batcher;
pub mod frame_pool;
pub mod metrics;
pub mod pipeline;
pub mod router;
pub mod task_queue;
