//! The L3 coordination layer — the paper's system contribution, serving
//! shaped: frames stream in, integral histograms (and region-query
//! results) stream out, with the paper's two scaling mechanisms as
//! first-class features:
//!
//! * [`pipeline`] — the dual-buffered frame pipeline (Algorithm 6,
//!   Figs. 12/14): read → H2D → kernel → D2H stages overlapped across
//!   in-flight frames ("CUDA streams" = pipeline lanes).
//! * [`task_queue`] — the multi-device bin task queue (§4.6, Fig. 18)
//!   for images whose tensor exceeds one device's memory.
//! * [`server`] — [`server::Server`]: the shared multi-stream front
//!   door.  `&self` compute from any number of threads, per-stream
//!   [`server::Session`]s (pipeline lane + query batcher + analytics
//!   attachment), admission control, global + per-stream metrics.
//! * [`router`] — [`router::Engine`]: the single-session router.
//!   Picks strategy and artifact for a request, routes small frames to
//!   the direct path and large frames to the task queue.
//! * [`batcher`] — groups region-query requests against cached tensors
//!   (the O(1) lookup service downstream analytics call).
//! * [`frame_pool`] — the buffer arena recycling integral-histogram
//!   storage across frames (the paper's persistent page-locked buffers,
//!   §4.4): steady-state requests allocate nothing.
//! * [`backpressure`] — bounded hand-off queues with occupancy stats
//!   (also the server's admission-control primitive).
//! * [`metrics`] — per-frame stage timings, throughput accounting and
//!   latency percentiles/jitter.

pub mod backpressure;
pub mod batcher;
pub mod frame_pool;
pub mod metrics;
pub mod pipeline;
pub mod router;
pub mod server;
pub mod task_queue;
