//! Per-frame stage timings and throughput accounting, plus latency
//! distribution summaries (percentiles + jitter) for the serving layer.

use crate::util::stats::percentile_sorted;
use std::time::Duration;

/// Latency distribution of a run or a serving window, in milliseconds.
///
/// Tail percentiles, not the mean, are what a serving SLO is written
/// against; `jitter_ms` is the RFC 3550-style mean absolute difference
/// between *consecutive* latencies (arrival order), the frame-pacing
/// measure a video consumer feels.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct LatencySummary {
    pub n: usize,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub jitter_ms: f64,
}

impl LatencySummary {
    /// Summarize latencies given in **arrival order** (jitter depends
    /// on it; percentiles do not).  Empty input yields all zeros.
    pub fn of_ms(samples_ms: &[f64]) -> LatencySummary {
        let n = samples_ms.len();
        if n == 0 {
            return LatencySummary::default();
        }
        let mut sorted = samples_ms.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).expect("NaN latency"));
        let jitter_ms = if n < 2 {
            0.0
        } else {
            samples_ms.windows(2).map(|w| (w[1] - w[0]).abs()).sum::<f64>() / (n - 1) as f64
        };
        LatencySummary {
            n,
            mean_ms: samples_ms.iter().sum::<f64>() / n as f64,
            p50_ms: percentile_sorted(&sorted, 0.50),
            p95_ms: percentile_sorted(&sorted, 0.95),
            p99_ms: percentile_sorted(&sorted, 0.99),
            jitter_ms,
        }
    }

    /// The JSON object fragment every bench emits for a latency block.
    pub fn to_json(&self) -> String {
        format!(
            "{{\"n\": {}, \"mean_ms\": {:.4}, \"p50_ms\": {:.4}, \"p95_ms\": {:.4}, \"p99_ms\": {:.4}, \"jitter_ms\": {:.4}}}",
            self.n, self.mean_ms, self.p50_ms, self.p95_ms, self.p99_ms, self.jitter_ms
        )
    }
}

/// Timing of one frame through the pipeline stages (Algorithm 6).
#[derive(Debug, Clone, Copy, Default)]
pub struct FrameStat {
    pub seq: usize,
    /// Disk/source read time.
    pub read: Duration,
    /// Host→device transfer (simulated, DESIGN.md §4).
    pub h2d: Duration,
    /// Pure kernel execution time on the PJRT device.
    pub kernel: Duration,
    /// Device→host transfer of the tensor (simulated).
    pub d2h: Duration,
    /// End-to-end latency (enqueue → result available).
    pub latency: Duration,
}

impl FrameStat {
    /// Serial single-lane cost of this frame (no overlap).
    pub fn serial_cost(&self) -> Duration {
        self.read + self.h2d + self.kernel + self.d2h
    }
}

/// Aggregated pipeline run report.
#[derive(Debug, Clone)]
pub struct Throughput {
    pub frames: usize,
    pub wall: Duration,
    pub stats: Vec<FrameStat>,
}

impl Throughput {
    /// Achieved frames/second over the whole run.
    pub fn fps(&self) -> f64 {
        if self.wall.is_zero() {
            return 0.0;
        }
        self.frames as f64 / self.wall.as_secs_f64()
    }

    /// Mean end-to-end latency.
    pub fn mean_latency(&self) -> Duration {
        if self.stats.is_empty() {
            return Duration::ZERO;
        }
        self.stats.iter().map(|s| s.latency).sum::<Duration>() / self.stats.len() as u32
    }

    /// Latency percentiles + jitter over the run's frames (in frame
    /// order — `stats` is seq-sorted by the pipeline reports).
    pub fn latency_summary(&self) -> LatencySummary {
        let ms: Vec<f64> = self.stats.iter().map(|s| s.latency.as_secs_f64() * 1e3).collect();
        LatencySummary::of_ms(&ms)
    }

    /// Sum of one stage across frames (stage pressure analysis).
    pub fn stage_total(&self, f: impl Fn(&FrameStat) -> Duration) -> Duration {
        self.stats.iter().map(f).sum()
    }

    /// What a perfectly serial (lane = 1, no overlap) run would take:
    /// the Fig. 14(a) "no dual-buffering" reference.
    pub fn serial_estimate(&self) -> Duration {
        self.stats.iter().map(|s| s.serial_cost()).sum()
    }

    /// Overlap speedup actually achieved vs the serial estimate.
    pub fn overlap_speedup(&self) -> f64 {
        if self.wall.is_zero() {
            return 1.0;
        }
        self.serial_estimate().as_secs_f64() / self.wall.as_secs_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stat(ms: u64) -> FrameStat {
        FrameStat {
            seq: 0,
            read: Duration::from_millis(ms),
            h2d: Duration::from_millis(ms),
            kernel: Duration::from_millis(2 * ms),
            d2h: Duration::from_millis(ms),
            latency: Duration::from_millis(5 * ms),
        }
    }

    #[test]
    fn fps_and_latency() {
        let t = Throughput {
            frames: 10,
            wall: Duration::from_secs(2),
            stats: vec![stat(10); 10],
        };
        assert!((t.fps() - 5.0).abs() < 1e-9);
        assert_eq!(t.mean_latency(), Duration::from_millis(50));
    }

    #[test]
    fn serial_estimate_sums_stages() {
        let t = Throughput { frames: 2, wall: Duration::from_millis(60), stats: vec![stat(10); 2] };
        assert_eq!(t.serial_estimate(), Duration::from_millis(100));
        assert!((t.overlap_speedup() - 100.0 / 60.0).abs() < 1e-9);
    }

    #[test]
    fn empty_run() {
        let t = Throughput { frames: 0, wall: Duration::ZERO, stats: vec![] };
        assert_eq!(t.fps(), 0.0);
        assert_eq!(t.mean_latency(), Duration::ZERO);
        assert_eq!(t.overlap_speedup(), 1.0);
    }

    #[test]
    fn stage_total() {
        let t = Throughput { frames: 3, wall: Duration::from_secs(1), stats: vec![stat(5); 3] };
        assert_eq!(t.stage_total(|s| s.kernel), Duration::from_millis(30));
    }

    #[test]
    fn latency_summary_percentiles_and_jitter() {
        let s = LatencySummary::of_ms(&[100.0, 10.0, 30.0, 20.0, 40.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean_ms - 40.0).abs() < 1e-9);
        assert!((s.p50_ms - 30.0).abs() < 1e-9);
        assert!((s.p95_ms - 88.0).abs() < 1e-9, "p95 {}", s.p95_ms);
        assert!((s.p99_ms - 97.6).abs() < 1e-9, "p99 {}", s.p99_ms);
        // (|10-100| + |30-10| + |20-30| + |40-20|) / 4
        assert!((s.jitter_ms - 35.0).abs() < 1e-9, "jitter {}", s.jitter_ms);
    }

    #[test]
    fn latency_summary_degenerate_inputs() {
        assert_eq!(LatencySummary::of_ms(&[]), LatencySummary::default());
        let one = LatencySummary::of_ms(&[7.0]);
        assert_eq!((one.n, one.jitter_ms), (1, 0.0));
        assert_eq!(one.p50_ms, 7.0);
        assert_eq!(one.p99_ms, 7.0);
        // steady pacing = zero jitter
        let steady = LatencySummary::of_ms(&[5.0; 8]);
        assert_eq!(steady.jitter_ms, 0.0);
    }

    #[test]
    fn throughput_latency_summary() {
        let t = Throughput {
            frames: 4,
            wall: Duration::from_secs(1),
            stats: vec![stat(10); 4],
        };
        let s = t.latency_summary();
        assert_eq!(s.n, 4);
        assert!((s.p50_ms - 50.0).abs() < 1e-9);
        assert_eq!(s.jitter_ms, 0.0);
        let j = s.to_json();
        assert!(j.contains("\"p95_ms\""), "{j}");
    }
}
