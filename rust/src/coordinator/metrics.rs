//! Per-frame stage timings and throughput accounting.

use std::time::Duration;

/// Timing of one frame through the pipeline stages (Algorithm 6).
#[derive(Debug, Clone, Copy, Default)]
pub struct FrameStat {
    pub seq: usize,
    /// Disk/source read time.
    pub read: Duration,
    /// Host→device transfer (simulated, DESIGN.md §4).
    pub h2d: Duration,
    /// Pure kernel execution time on the PJRT device.
    pub kernel: Duration,
    /// Device→host transfer of the tensor (simulated).
    pub d2h: Duration,
    /// End-to-end latency (enqueue → result available).
    pub latency: Duration,
}

impl FrameStat {
    /// Serial single-lane cost of this frame (no overlap).
    pub fn serial_cost(&self) -> Duration {
        self.read + self.h2d + self.kernel + self.d2h
    }
}

/// Aggregated pipeline run report.
#[derive(Debug, Clone)]
pub struct Throughput {
    pub frames: usize,
    pub wall: Duration,
    pub stats: Vec<FrameStat>,
}

impl Throughput {
    /// Achieved frames/second over the whole run.
    pub fn fps(&self) -> f64 {
        if self.wall.is_zero() {
            return 0.0;
        }
        self.frames as f64 / self.wall.as_secs_f64()
    }

    /// Mean end-to-end latency.
    pub fn mean_latency(&self) -> Duration {
        if self.stats.is_empty() {
            return Duration::ZERO;
        }
        self.stats.iter().map(|s| s.latency).sum::<Duration>() / self.stats.len() as u32
    }

    /// Sum of one stage across frames (stage pressure analysis).
    pub fn stage_total(&self, f: impl Fn(&FrameStat) -> Duration) -> Duration {
        self.stats.iter().map(f).sum()
    }

    /// What a perfectly serial (lane = 1, no overlap) run would take:
    /// the Fig. 14(a) "no dual-buffering" reference.
    pub fn serial_estimate(&self) -> Duration {
        self.stats.iter().map(|s| s.serial_cost()).sum()
    }

    /// Overlap speedup actually achieved vs the serial estimate.
    pub fn overlap_speedup(&self) -> f64 {
        if self.wall.is_zero() {
            return 1.0;
        }
        self.serial_estimate().as_secs_f64() / self.wall.as_secs_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stat(ms: u64) -> FrameStat {
        FrameStat {
            seq: 0,
            read: Duration::from_millis(ms),
            h2d: Duration::from_millis(ms),
            kernel: Duration::from_millis(2 * ms),
            d2h: Duration::from_millis(ms),
            latency: Duration::from_millis(5 * ms),
        }
    }

    #[test]
    fn fps_and_latency() {
        let t = Throughput {
            frames: 10,
            wall: Duration::from_secs(2),
            stats: vec![stat(10); 10],
        };
        assert!((t.fps() - 5.0).abs() < 1e-9);
        assert_eq!(t.mean_latency(), Duration::from_millis(50));
    }

    #[test]
    fn serial_estimate_sums_stages() {
        let t = Throughput { frames: 2, wall: Duration::from_millis(60), stats: vec![stat(10); 2] };
        assert_eq!(t.serial_estimate(), Duration::from_millis(100));
        assert!((t.overlap_speedup() - 100.0 / 60.0).abs() < 1e-9);
    }

    #[test]
    fn empty_run() {
        let t = Throughput { frames: 0, wall: Duration::ZERO, stats: vec![] };
        assert_eq!(t.fps(), 0.0);
        assert_eq!(t.mean_latency(), Duration::ZERO);
        assert_eq!(t.overlap_speedup(), 1.0);
    }

    #[test]
    fn stage_total() {
        let t = Throughput { frames: 3, wall: Duration::from_secs(1), stats: vec![stat(5); 3] };
        assert_eq!(t.stage_total(|s| s.kernel), Duration::from_millis(30));
    }
}
