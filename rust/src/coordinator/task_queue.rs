//! Bin task queue — integral histograms for large-scale images on
//! multiple devices (§4.6, Fig. 18).
//!
//! For images whose `b×h×w` tensor exceeds one device's memory (the
//! paper's 8k×8k ×128-bin = 32 GB case), bins are grouped into equal
//! tasks on a queue; whenever a device is free the dispatcher hands it
//! the next group, and completed groups stream back to the host while
//! other devices keep computing (compute/copy overlap via the pool's
//! output channel).  The queue layer also tracks per-worker utilization
//! so heterogeneous pools are observable.
//!
//! Scope note: this is the *single-frame* §4.6 measurement path — one
//! `compute` call owns the whole pool until its frame assembles, which
//! is exactly the whole-frame serialization the serving layer used to
//! inherit.  The `Server`'s large-request route now runs on the
//! interleaved [`crate::shard::ShardExecutor`] instead (multiple
//! frames in flight, tagged reassembly, spill-backed output);
//! `BinTaskQueue` remains as the artifact-path Fig. 18 driver and as
//! the serial-frame baseline `benches/shard.rs` measures against, and
//! runs offline via the device pool's CPU fallback
//! ([`TaskQueueConfig::cpu_fallback`]).

use crate::histogram::types::{BinnedImage, IntegralHistogram};
use crate::runtime::artifact::ArtifactManifest;
use crate::runtime::device_pool::{DevicePool, Job};
use anyhow::{anyhow, Result};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Configuration of the large-image path.
#[derive(Debug, Clone)]
pub struct TaskQueueConfig {
    /// Worker (device) count — the paper's 4 GTX 480s.
    pub workers: usize,
    /// Bins per task (16 in the paper's 64-bin example).
    pub group: usize,
    /// The `group`-bin strategy artifact every task executes.
    pub artifact: String,
    /// Serve tasks on per-worker CPU engines when the artifact (or the
    /// backend) is unavailable — keeps the queue runnable in the
    /// offline build; results are bit-identical.
    pub cpu_fallback: bool,
}

/// Report of one large-image computation.
#[derive(Debug, Clone)]
pub struct TaskQueueReport {
    pub tasks: usize,
    pub wall: Duration,
    /// Kernel time of each task, in completion order.
    pub task_kernel_times: Vec<Duration>,
    /// Tasks completed per worker (utilization of the pool).
    pub per_worker: Vec<usize>,
}

impl TaskQueueReport {
    /// Effective frame rate: one whole frame per `wall`.
    pub fn fps(&self) -> f64 {
        1.0 / self.wall.as_secs_f64()
    }

    /// Sum of kernel times — the single-device (serial) estimate.
    pub fn serial_kernel_time(&self) -> Duration {
        self.task_kernel_times.iter().sum()
    }

    /// Parallel efficiency: serial estimate / (workers × wall).
    pub fn efficiency(&self, workers: usize) -> f64 {
        self.serial_kernel_time().as_secs_f64() / (workers as f64 * self.wall.as_secs_f64())
    }
}

/// The bin task queue over a device pool.
pub struct BinTaskQueue {
    pool: DevicePool,
    config: TaskQueueConfig,
    group_bins: usize,
}

impl BinTaskQueue {
    /// Validate the artifact and spin up the pool.  A missing artifact
    /// is an error unless `cpu_fallback` is set (the offline build),
    /// in which case the workers serve every task on CPU engines; an
    /// artifact that *exists* with the wrong bin count is always an
    /// error.
    pub fn new(manifest: Arc<ArtifactManifest>, config: TaskQueueConfig) -> Result<BinTaskQueue> {
        match manifest.find_named(&config.artifact) {
            Some(meta) => {
                if meta.bins != config.group {
                    return Err(anyhow!(
                        "artifact '{}' computes {} bins but group size is {}",
                        config.artifact,
                        meta.bins,
                        config.group
                    ));
                }
            }
            None if !config.cpu_fallback => {
                return Err(anyhow!("artifact '{}' not in manifest", config.artifact));
            }
            None => {} // offline: CPU fallback serves the tasks
        }
        let pool = DevicePool::with_cpu_fallback(manifest, config.workers, config.cpu_fallback);
        Ok(BinTaskQueue { pool, group_bins: config.group, config })
    }

    pub fn config(&self) -> &TaskQueueConfig {
        &self.config
    }

    /// Compute the full `total_bins` integral histogram of one frame,
    /// assembling the group results as they stream back.
    pub fn compute(
        &self,
        image: &Arc<BinnedImage>,
        total_bins: usize,
    ) -> Result<(IntegralHistogram, TaskQueueReport)> {
        if total_bins % self.group_bins != 0 {
            return Err(anyhow!(
                "total bins {total_bins} not divisible by group {}",
                self.group_bins
            ));
        }
        let n_tasks = total_bins / self.group_bins;
        let t0 = Instant::now();
        for j in 0..n_tasks {
            self.pool.submit(Job {
                job_id: j,
                artifact: self.config.artifact.clone(),
                bin_offset: j * self.group_bins,
                group: self.group_bins,
                image: Arc::clone(image),
            })?;
        }
        let mut full = IntegralHistogram::zeros(total_bins, image.h, image.w);
        let plane = image.h * image.w;
        let mut times = Vec::with_capacity(n_tasks);
        let mut per_worker = vec![0usize; self.config.workers];
        // Drain ALL n_tasks results even after a failure: an early
        // return would leave this frame's remaining outputs queued in
        // the pool channel, to be mistaken for the *next* frame's
        // groups (silent cross-frame corruption).  A hung-up channel
        // errors without blocking, so the full drain is always cheap.
        let mut first_err = None;
        for _ in 0..n_tasks {
            match self.pool.recv() {
                Ok(out) if first_err.is_none() => {
                    let dst = out.bin_offset * plane;
                    full.data[dst..dst + out.partial.data.len()]
                        .copy_from_slice(&out.partial.data);
                    times.push(out.kernel_time);
                    per_worker[out.worker] += 1;
                }
                Ok(_) => {}
                Err(e) => {
                    first_err.get_or_insert(e);
                }
            }
        }
        if let Some(e) = first_err {
            return Err(e);
        }
        let report = TaskQueueReport {
            tasks: n_tasks,
            wall: t0.elapsed(),
            task_kernel_times: times,
            per_worker,
        };
        Ok((full, report))
    }

    /// Timing-only variant that discards the (possibly huge) tensor
    /// group-by-group instead of assembling it — the §4.6 measurement
    /// mode for tensors larger than host memory would allow.
    pub fn compute_discard(
        &self,
        image: &Arc<BinnedImage>,
        total_bins: usize,
    ) -> Result<TaskQueueReport> {
        if total_bins % self.group_bins != 0 {
            return Err(anyhow!(
                "total bins {total_bins} not divisible by group {}",
                self.group_bins
            ));
        }
        let n_tasks = total_bins / self.group_bins;
        let t0 = Instant::now();
        for j in 0..n_tasks {
            self.pool.submit(Job {
                job_id: j,
                artifact: self.config.artifact.clone(),
                bin_offset: j * self.group_bins,
                group: self.group_bins,
                image: Arc::clone(image),
            })?;
        }
        let mut times = Vec::with_capacity(n_tasks);
        let mut per_worker = vec![0usize; self.config.workers];
        // Full drain, as in `compute`: never leave this frame's
        // results queued for a later frame to pop.
        let mut first_err = None;
        for _ in 0..n_tasks {
            match self.pool.recv() {
                Ok(out) if first_err.is_none() => {
                    times.push(out.kernel_time);
                    per_worker[out.worker] += 1;
                }
                Ok(_) => {}
                Err(e) => {
                    first_err.get_or_insert(e);
                }
            }
        }
        if let Some(e) = first_err {
            return Err(e);
        }
        Ok(TaskQueueReport { tasks: n_tasks, wall: t0.elapsed(), task_kernel_times: times, per_worker })
    }

    /// Shut the pool down, joining the workers.
    pub fn shutdown(self) {
        self.pool.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_math() {
        let r = TaskQueueReport {
            tasks: 4,
            wall: Duration::from_millis(100),
            task_kernel_times: vec![Duration::from_millis(40); 4],
            per_worker: vec![2, 2],
        };
        assert!((r.fps() - 10.0).abs() < 1e-9);
        assert_eq!(r.serial_kernel_time(), Duration::from_millis(160));
        assert!((r.efficiency(2) - 0.8).abs() < 1e-9);
    }
}
