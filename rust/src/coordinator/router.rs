//! The engine / request router — the coordinator's front door.
//!
//! Owns the artifact manifest and a cache of compiled executors, picks
//! the right artifact for each request (strategy, geometry, bins), and
//! routes:
//!
//! * small frames → the direct PJRT path (optionally the fused serve
//!   graph that also answers region queries);
//! * frames whose tensor exceeds the device-memory budget → the
//!   multi-device bin task queue (§4.6), mirroring how the paper falls
//!   back to bin tiling when "limited GPU global memory becomes the
//!   bottleneck".

use crate::coordinator::task_queue::{BinTaskQueue, TaskQueueConfig, TaskQueueReport};
use crate::histogram::region::Rect;
use crate::histogram::types::{BinnedImage, IntegralHistogram, Strategy};
use crate::runtime::artifact::{ArtifactKind, ArtifactManifest};
use crate::runtime::client::HistogramExecutor;
use crate::video::source::VideoFrame;
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::sync::Arc;
use std::time::Duration;

/// Engine configuration.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Histogram bins for quantization and artifact selection.
    pub bins: usize,
    /// Preferred strategy for direct requests (WF-TiS: the tuned winner).
    pub strategy: Strategy,
    /// Tensors larger than this (bytes) go to the bin task queue —
    /// the "GPU global memory" budget.  12 GB ≈ the Titan X.
    pub device_memory_budget: usize,
    /// Workers for the large-image pool.
    pub pool_workers: usize,
    /// Bin group size for large-image tasks.
    pub bin_group: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            bins: 32,
            strategy: Strategy::WfTis,
            device_memory_budget: 12 << 30,
            pool_workers: 4,
            bin_group: 8,
        }
    }
}

/// How a request was (or would be) routed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Route {
    /// Single-device direct execution.
    Direct,
    /// Bin-grouped multi-device task queue.
    TaskQueue,
}

/// The serving engine.
pub struct Engine {
    manifest: Arc<ArtifactManifest>,
    config: EngineConfig,
    executors: HashMap<String, HistogramExecutor>,
    task_queue: Option<BinTaskQueue>,
}

impl Engine {
    /// Load the manifest from `dir` with default config.
    pub fn from_artifact_dir(dir: impl AsRef<std::path::Path>) -> Result<Engine> {
        Ok(Engine::new(Arc::new(ArtifactManifest::load(dir)?), EngineConfig::default()))
    }

    pub fn new(manifest: Arc<ArtifactManifest>, config: EngineConfig) -> Engine {
        Engine { manifest, config, executors: HashMap::new(), task_queue: None }
    }

    pub fn manifest(&self) -> &ArtifactManifest {
        &self.manifest
    }

    pub fn config(&self) -> &EngineConfig {
        &self.config
    }

    /// Routing decision for an `h×w` frame at the configured bin count:
    /// tensor fits the device budget → direct, else task queue.
    pub fn route_for(&self, h: usize, w: usize) -> Route {
        let tensor = self.config.bins * h * w * 4;
        if tensor > self.config.device_memory_budget {
            Route::TaskQueue
        } else {
            Route::Direct
        }
    }

    /// Compute the integral histogram of a frame with the configured
    /// strategy, returning the tensor and the kernel time.
    pub fn compute_frame_timed(
        &mut self,
        frame: &VideoFrame,
    ) -> Result<(IntegralHistogram, Duration)> {
        let img = frame.binned(self.config.bins);
        self.compute_timed(self.config.strategy, &img)
    }

    /// Compute with an explicit strategy on an already-binned image.
    pub fn compute_timed(
        &mut self,
        strategy: Strategy,
        img: &BinnedImage,
    ) -> Result<(IntegralHistogram, Duration)> {
        match self.route_for(img.h, img.w) {
            Route::Direct => {
                let exe = self.executor_for(strategy, img.h, img.w, img.bins)?;
                exe.compute_timed(img)
            }
            Route::TaskQueue => {
                let (ih, report) = self.compute_large(img)?;
                Ok((ih, report.wall))
            }
        }
    }

    /// Convenience wrapper dropping the timing.
    pub fn compute(&mut self, strategy: Strategy, img: &BinnedImage) -> Result<IntegralHistogram> {
        Ok(self.compute_timed(strategy, img)?.0)
    }

    /// Large-image path: bin-grouped fan-out over the device pool.
    pub fn compute_large(
        &mut self,
        img: &BinnedImage,
    ) -> Result<(IntegralHistogram, TaskQueueReport)> {
        let group = self.config.bin_group;
        if self.task_queue.is_none() {
            // find the group-bin artifact matching this geometry
            let meta = self
                .manifest
                .artifacts
                .iter()
                .find(|a| {
                    a.kind == ArtifactKind::Strategy
                        && a.bins == group
                        && a.height == img.h
                        && a.width == img.w
                })
                .ok_or_else(|| {
                    anyhow!(
                        "no {}-bin group artifact for {}x{} (re-run `make artifacts`)",
                        group,
                        img.h,
                        img.w
                    )
                })?;
            self.task_queue = Some(BinTaskQueue::new(
                Arc::clone(&self.manifest),
                TaskQueueConfig {
                    workers: self.config.pool_workers,
                    group,
                    artifact: meta.name.clone(),
                },
            )?);
        }
        let image = Arc::new(img.clone());
        self.task_queue.as_ref().unwrap().compute(&image, img.bins)
    }

    /// Fused serve request: tensor + batched region histograms.  Uses
    /// the AOT serve graph when one matches, otherwise computes the
    /// tensor and answers the queries on the CPU (identical results).
    pub fn serve(
        &mut self,
        frame: &VideoFrame,
        rects: &[Rect],
    ) -> Result<(IntegralHistogram, Vec<Vec<f32>>)> {
        let bins = self.config.bins;
        let img = frame.binned(bins);
        let serve_meta = self
            .manifest
            .artifacts
            .iter()
            .find(|a| {
                a.kind == ArtifactKind::Serve
                    && a.height == img.h
                    && a.width == img.w
                    && a.bins == bins
                    && a.n_rects >= rects.len()
            })
            .cloned();
        if let Some(meta) = serve_meta {
            if !self.executors.contains_key(&meta.name) {
                let exe = HistogramExecutor::compile(&self.manifest, &meta)?;
                self.executors.insert(meta.name.clone(), exe);
            }
            let exe = &self.executors[&meta.name];
            let (ih, hists, _) = exe.compute_with_queries(&img, rects)?;
            Ok((ih, hists))
        } else {
            let (ih, _) = self.compute_timed(self.config.strategy, &img)?;
            let hists = crate::histogram::region::region_histogram_batch(&ih, rects);
            Ok((ih, hists))
        }
    }

    /// Get-or-compile the executor for (strategy, h, w, bins).
    pub fn executor_for(
        &mut self,
        strategy: Strategy,
        h: usize,
        w: usize,
        bins: usize,
    ) -> Result<&HistogramExecutor> {
        let meta = self
            .manifest
            .find_strategy(strategy, h, w, bins)
            .ok_or_else(|| {
                anyhow!(
                    "no artifact for {strategy} {h}x{w} bins={bins}; available: {}",
                    self.manifest
                        .strategies()
                        .iter()
                        .map(|a| a.name.as_str())
                        .collect::<Vec<_>>()
                        .join(", ")
                )
            })?
            .clone();
        if !self.executors.contains_key(&meta.name) {
            let exe = HistogramExecutor::compile(&self.manifest, &meta)?;
            self.executors.insert(meta.name.clone(), exe);
        }
        Ok(&self.executors[&meta.name])
    }

    /// Number of compiled executors held by the cache.
    pub fn cached_executors(&self) -> usize {
        self.executors.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn manifest() -> Arc<ArtifactManifest> {
        // empty manifest is enough for routing tests
        Arc::new(ArtifactManifest {
            dir: PathBuf::from("/nonexistent"),
            profile: "test".into(),
            artifacts: vec![],
        })
    }

    #[test]
    fn routing_threshold() {
        let mut cfg = EngineConfig::default();
        cfg.bins = 128;
        cfg.device_memory_budget = 1 << 30; // 1 GiB budget
        let eng = Engine::new(manifest(), cfg);
        // 512×512×128×4 = 128 MiB → direct
        assert_eq!(eng.route_for(512, 512), Route::Direct);
        // 8k×8k×128×4 = 32 GiB → task queue
        assert_eq!(eng.route_for(8192, 8192), Route::TaskQueue);
    }

    #[test]
    fn missing_artifact_is_helpful_error() {
        let mut eng = Engine::new(manifest(), EngineConfig::default());
        let err = eng
            .executor_for(Strategy::WfTis, 64, 64, 32)
            .err()
            .expect("should fail")
            .to_string();
        assert!(err.contains("no artifact"), "{err}");
    }

    #[test]
    fn default_config_sane() {
        let c = EngineConfig::default();
        assert_eq!(c.strategy, Strategy::WfTis);
        assert!(c.device_memory_budget >= 1 << 30);
    }
}
