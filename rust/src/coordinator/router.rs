//! The engine / request router — the coordinator's front door.
//!
//! Owns the artifact manifest and a cache of compiled executors, picks
//! the right artifact for each request (strategy, geometry, bins), and
//! routes:
//!
//! * small frames → the direct PJRT path (optionally the fused serve
//!   graph that also answers region queries);
//! * frames whose tensor exceeds the device-memory budget → the
//!   multi-device bin task queue (§4.6), mirroring how the paper falls
//!   back to bin tiling when "limited GPU global memory becomes the
//!   bottleneck";
//! * requests with no usable artifact/backend → the CPU
//!   [`ScanEngine`] (planned wavefront scan over
//!   [`FramePool`]-recycled tensors), so the engine stays functional —
//!   and allocation-free in steady state — in the offline build.

use crate::coordinator::frame_pool::{FramePool, PoolStats};
use crate::coordinator::task_queue::{BinTaskQueue, TaskQueueConfig, TaskQueueReport};
use crate::histogram::engine::ScanEngine;
use crate::histogram::region::Rect;
use crate::histogram::types::{BinnedImage, IntegralHistogram, Strategy};
use crate::runtime::artifact::{ArtifactKind, ArtifactManifest};
use crate::runtime::client::HistogramExecutor;
use crate::runtime::compile_cache::CompileCache;
use crate::shard::planner::ShardPolicy;
use crate::simulator::pcie::Card;
use crate::video::source::VideoFrame;
use anyhow::{anyhow, Result};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Engine configuration.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Histogram bins for quantization and artifact selection.
    pub bins: usize,
    /// Preferred strategy for direct requests (WF-TiS: the tuned winner).
    pub strategy: Strategy,
    /// Tensors larger than this (bytes) go to the bin task queue —
    /// the "GPU global memory" budget.  12 GB ≈ the Titan X.
    pub device_memory_budget: usize,
    /// Workers for the large-image pool.
    pub pool_workers: usize,
    /// Bin group size for large-image tasks.
    pub bin_group: usize,
    /// Serve requests on the CPU [`ScanEngine`] when no PJRT artifact
    /// (or backend) is available — keeps the engine functional in the
    /// offline build (DESIGN.md §4).
    pub cpu_fallback: bool,
    /// CPU engine worker budget (0 ⇒ all available cores).
    pub cpu_workers: usize,
    /// Largest tensor (bytes) the CPU fallback will allocate host-side
    /// for frames routed to the task queue; beyond it the original
    /// "no group artifact" error surfaces instead of risking an
    /// allocation abort.
    pub cpu_fallback_budget: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            bins: 32,
            strategy: Strategy::WfTis,
            device_memory_budget: 12 << 30,
            pool_workers: 4,
            bin_group: 8,
            cpu_fallback: true,
            cpu_workers: 0,
            cpu_fallback_budget: 2 << 30,
        }
    }
}

// Routing policy shared by the single-session [`Engine`] and the
// multi-stream [`crate::coordinator::server::Server`] — one formula,
// two front doors.
impl EngineConfig {
    /// Routing decision for an `h×w` frame at the configured bin
    /// count: tensor fits the device budget → direct, else task queue.
    pub fn route_for(&self, h: usize, w: usize) -> Route {
        let tensor = self.bins * h * w * 4;
        if tensor > self.device_memory_budget {
            Route::TaskQueue
        } else {
            Route::Direct
        }
    }

    /// Whether the CPU engine may serve this image: fallback enabled
    /// and the tensor within the host allocation budget.
    pub fn cpu_fallback_allowed(&self, img: &BinnedImage) -> bool {
        self.cpu_fallback && img.bins * img.h * img.w * 4 <= self.cpu_fallback_budget
    }

    /// Build the §4.6 bin task queue for `h×w` frames: find the
    /// matching group-bin artifact in `manifest` and spin up the
    /// device pool.  (The artifact must exist even when `cpu_fallback`
    /// is set — the single-session engine's guarded whole-frame CPU
    /// path handles the fully-offline case, keeping the
    /// `cpu_fallback_budget` allocation bound in force.)
    pub fn build_bin_task_queue(
        &self,
        manifest: &Arc<ArtifactManifest>,
        h: usize,
        w: usize,
    ) -> Result<BinTaskQueue> {
        let group = self.bin_group;
        let meta = manifest
            .artifacts
            .iter()
            .find(|a| {
                a.kind == ArtifactKind::Strategy && a.bins == group && a.height == h && a.width == w
            })
            .ok_or_else(|| {
                anyhow!("no {group}-bin group artifact for {h}x{w} (re-run `make artifacts`)")
            })?;
        BinTaskQueue::new(
            Arc::clone(manifest),
            TaskQueueConfig {
                workers: self.pool_workers,
                group,
                artifact: meta.name.clone(),
                cpu_fallback: self.cpu_fallback,
            },
        )
    }

    /// Derive the [`ShardPolicy`] the multi-stream server's sharded
    /// large-request route runs under: the engine's bin-group size
    /// bounds shard granularity, the caller supplies the host resident
    /// budget and the shard worker count.
    pub fn shard_policy(&self, memory_budget: usize, workers: usize) -> ShardPolicy {
        ShardPolicy {
            memory_budget,
            workers: workers.max(1),
            max_group: self.bin_group.max(1),
            min_shards: 0,
            card: Card::Gtx480,
        }
    }
}

/// How a request was (or would be) routed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Route {
    /// Single-device direct execution.
    Direct,
    /// Bin-grouped multi-device task queue.
    TaskQueue,
}

/// The serving engine (single-session; see
/// [`crate::coordinator::server::Server`] for the shared multi-stream
/// front door built from the same pieces).
pub struct Engine {
    config: EngineConfig,
    /// Shared get-or-compile executor cache (negative caching included).
    compile: CompileCache,
    /// Large-image queue plus the `(h, w)` it was built for — queues
    /// are geometry-bound (one group artifact each), so a different
    /// large geometry rebuilds rather than misusing the old queue.
    task_queue: Option<(usize, usize, BinTaskQueue)>,
    /// CPU fallback path: planned wavefront engine + tensor arena.
    scan: ScanEngine,
    pool: Arc<FramePool>,
}

impl Engine {
    /// Load the manifest from `dir` with default config.
    pub fn from_artifact_dir(dir: impl AsRef<std::path::Path>) -> Result<Engine> {
        Ok(Engine::new(Arc::new(ArtifactManifest::load(dir)?), EngineConfig::default()))
    }

    pub fn new(manifest: Arc<ArtifactManifest>, config: EngineConfig) -> Engine {
        let scan = ScanEngine::new(config.cpu_workers);
        Engine {
            config,
            compile: CompileCache::new(manifest),
            task_queue: None,
            scan,
            pool: Arc::new(FramePool::new()),
        }
    }

    pub fn manifest(&self) -> &ArtifactManifest {
        self.compile.manifest()
    }

    pub fn config(&self) -> &EngineConfig {
        &self.config
    }

    /// Routing decision for an `h×w` frame at the configured bin count:
    /// tensor fits the device budget → direct, else task queue.
    pub fn route_for(&self, h: usize, w: usize) -> Route {
        self.config.route_for(h, w)
    }

    /// Compute the integral histogram of a frame with the configured
    /// strategy, returning the tensor and the kernel time.
    pub fn compute_frame_timed(
        &mut self,
        frame: &VideoFrame,
    ) -> Result<(IntegralHistogram, Duration)> {
        let img = frame.binned(self.config.bins);
        self.compute_timed(self.config.strategy, &img)
    }

    /// Compute with an explicit strategy on an already-binned image.
    ///
    /// Direct requests prefer the PJRT artifact path; when the artifact
    /// (or the XLA backend itself) is unavailable and `cpu_fallback` is
    /// set, the request is served by the CPU [`ScanEngine`] instead —
    /// bit-identical output, pooled storage (recycle tensors with
    /// [`Self::recycle`] to keep the steady state allocation-free).
    pub fn compute_timed(
        &mut self,
        strategy: Strategy,
        img: &BinnedImage,
    ) -> Result<(IntegralHistogram, Duration)> {
        match self.route_for(img.h, img.w) {
            Route::Direct => {
                let compiled =
                    self.compile.strategy_executor(strategy, img.h, img.w, img.bins);
                match compiled {
                    Ok(exe) => exe.compute_timed(img),
                    Err(_) if self.cpu_fallback_allowed(img) => self.compute_cpu_timed(img),
                    Err(e) => Err(e),
                }
            }
            Route::TaskQueue => match self.compute_large(img) {
                Ok((ih, report)) => Ok((ih, report.wall)),
                // No group artifact / no backend: the CPU engine still
                // serves the frame, but only within the host allocation
                // budget — past it the actionable artifact error beats
                // an allocation abort.
                Err(_) if self.cpu_fallback_allowed(img) => self.compute_cpu_timed(img),
                Err(e) => Err(e),
            },
        }
    }

    /// Whether the CPU engine may serve this frame: fallback enabled
    /// and the tensor within the host allocation budget.
    fn cpu_fallback_allowed(&self, img: &BinnedImage) -> bool {
        self.config.cpu_fallback_allowed(img)
    }

    /// Serve a request on the CPU wavefront engine with pooled storage.
    pub fn compute_cpu_timed(
        &mut self,
        img: &BinnedImage,
    ) -> Result<(IntegralHistogram, Duration)> {
        let t0 = Instant::now();
        let mut out = self.pool.acquire(img.bins, img.h, img.w);
        self.scan.compute_into(img, &mut out);
        Ok((out, t0.elapsed()))
    }

    /// Return a tensor obtained from the CPU path to the arena.
    pub fn recycle(&self, ih: IntegralHistogram) {
        self.pool.release(ih);
    }

    /// Arena counters (steady-state allocation observability).
    pub fn pool_stats(&self) -> PoolStats {
        self.pool.stats()
    }

    /// The CPU fallback engine (plan observability).
    pub fn cpu_engine(&self) -> &ScanEngine {
        &self.scan
    }

    /// Convenience wrapper dropping the timing.
    pub fn compute(&mut self, strategy: Strategy, img: &BinnedImage) -> Result<IntegralHistogram> {
        Ok(self.compute_timed(strategy, img)?.0)
    }

    /// Large-image path: bin-grouped fan-out over the device pool.
    pub fn compute_large(
        &mut self,
        img: &BinnedImage,
    ) -> Result<(IntegralHistogram, TaskQueueReport)> {
        let stale = !matches!(&self.task_queue, Some((h, w, _)) if (*h, *w) == (img.h, img.w));
        if stale {
            let queue = self.config.build_bin_task_queue(self.compile.manifest(), img.h, img.w)?;
            self.task_queue = Some((img.h, img.w, queue));
        }
        let image = Arc::new(img.clone());
        self.task_queue.as_ref().unwrap().2.compute(&image, img.bins)
    }

    /// Fused serve request: tensor + batched region histograms.  Uses
    /// the AOT serve graph when one matches, otherwise computes the
    /// tensor and answers the queries on the CPU (identical results).
    pub fn serve(
        &mut self,
        frame: &VideoFrame,
        rects: &[Rect],
    ) -> Result<(IntegralHistogram, Vec<Vec<f32>>)> {
        let bins = self.config.bins;
        let img = frame.binned(bins);
        let serve_meta = self
            .compile
            .manifest()
            .artifacts
            .iter()
            .find(|a| {
                a.kind == ArtifactKind::Serve
                    && a.height == img.h
                    && a.width == img.w
                    && a.bins == bins
                    && a.n_rects >= rects.len()
            })
            .cloned();
        if let Some(meta) = serve_meta {
            match self.compile.get_or_compile(&meta) {
                Ok(exe) => {
                    let (ih, hists, _) = exe.compute_with_queries(&img, rects)?;
                    return Ok((ih, hists));
                }
                Err(e) if !self.config.cpu_fallback => return Err(e),
                Err(_) => {} // backend unavailable: CPU answers identically
            }
        }
        let (ih, _) = self.compute_timed(self.config.strategy, &img)?;
        let hists = crate::histogram::region::region_histogram_batch(&ih, rects);
        Ok((ih, hists))
    }

    /// Drop every cached executor and negative compile result — call
    /// after regenerating `artifacts/` so previously failed compiles
    /// are retried.
    pub fn clear_compile_cache(&mut self) {
        self.compile.clear();
    }

    /// Get-or-compile the executor for (strategy, h, w, bins).
    pub fn executor_for(
        &mut self,
        strategy: Strategy,
        h: usize,
        w: usize,
        bins: usize,
    ) -> Result<Arc<HistogramExecutor>> {
        self.compile.strategy_executor(strategy, h, w, bins)
    }

    /// Number of compiled executors held by the cache.
    pub fn cached_executors(&self) -> usize {
        self.compile.compiled_count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn manifest() -> Arc<ArtifactManifest> {
        // empty manifest is enough for routing tests
        Arc::new(ArtifactManifest {
            dir: PathBuf::from("/nonexistent"),
            profile: "test".into(),
            artifacts: vec![],
        })
    }

    #[test]
    fn routing_threshold() {
        let mut cfg = EngineConfig::default();
        cfg.bins = 128;
        cfg.device_memory_budget = 1 << 30; // 1 GiB budget
        let eng = Engine::new(manifest(), cfg);
        // 512×512×128×4 = 128 MiB → direct
        assert_eq!(eng.route_for(512, 512), Route::Direct);
        // 8k×8k×128×4 = 32 GiB → task queue
        assert_eq!(eng.route_for(8192, 8192), Route::TaskQueue);
    }

    #[test]
    fn missing_artifact_is_helpful_error() {
        let mut eng = Engine::new(manifest(), EngineConfig::default());
        let err = eng
            .executor_for(Strategy::WfTis, 64, 64, 32)
            .err()
            .expect("should fail")
            .to_string();
        assert!(err.contains("no artifact"), "{err}");
    }

    #[test]
    fn default_config_sane() {
        let c = EngineConfig::default();
        assert_eq!(c.strategy, Strategy::WfTis);
        assert!(c.device_memory_budget >= 1 << 30);
        assert!(c.cpu_fallback, "offline builds need the CPU path on by default");
    }

    #[test]
    fn cpu_fallback_serves_without_artifacts() {
        use crate::histogram::sequential::integral_histogram_seq;
        let mut eng = Engine::new(manifest(), EngineConfig::default());
        let video = crate::video::synth::SyntheticVideo::new(96, 80, 2, 3);
        let img = video.frame(0).binned(8);
        let (ih, _) = eng.compute_timed(Strategy::WfTis, &img).expect("cpu fallback");
        let expected = integral_histogram_seq(&img);
        assert_eq!(expected.max_abs_diff(&ih), 0.0);
        // Recycling keeps the steady state allocation-free.
        eng.recycle(ih);
        let (ih2, _) = eng.compute_timed(Strategy::WfTis, &img).unwrap();
        let st = eng.pool_stats();
        assert_eq!((st.allocated, st.reused), (1, 1));
        assert_eq!(expected.max_abs_diff(&ih2), 0.0);
    }

    #[test]
    fn oversized_frames_fall_back_to_cpu() {
        use crate::histogram::sequential::integral_histogram_seq;
        let mut cfg = EngineConfig::default();
        cfg.bins = 8;
        cfg.device_memory_budget = 1 << 10; // force the TaskQueue route
        let mut eng = Engine::new(manifest(), cfg);
        let img = crate::video::synth::SyntheticVideo::new(40, 40, 1, 2).frame(0).binned(8);
        assert_eq!(eng.route_for(40, 40), Route::TaskQueue);
        let (ih, _) = eng.compute_timed(Strategy::WfTis, &img).expect("cpu serves large frames");
        let expected = integral_histogram_seq(&img);
        assert_eq!(expected.max_abs_diff(&ih), 0.0);
        // ... but not past the host allocation budget: the actionable
        // artifact error must surface instead of a giant allocation.
        let mut cfg = EngineConfig::default();
        cfg.bins = 8;
        cfg.device_memory_budget = 1 << 10;
        cfg.cpu_fallback_budget = 1 << 10;
        let mut eng = Engine::new(manifest(), cfg);
        let err = eng.compute_timed(Strategy::WfTis, &img).unwrap_err().to_string();
        assert!(err.contains("artifact"), "{err}");
    }

    #[test]
    fn fallback_disabled_propagates_error() {
        let mut cfg = EngineConfig::default();
        cfg.cpu_fallback = false;
        let mut eng = Engine::new(manifest(), cfg);
        let img = crate::video::synth::SyntheticVideo::new(32, 32, 1, 1).frame(0).binned(8);
        assert!(eng.compute_timed(Strategy::WfTis, &img).is_err());
    }

    #[test]
    fn serve_answers_queries_via_cpu() {
        use crate::histogram::region::region_histogram;
        use crate::histogram::sequential::integral_histogram_seq;
        let mut eng = Engine::new(manifest(), EngineConfig::default());
        let video = crate::video::synth::SyntheticVideo::new(64, 64, 2, 5);
        let frame = video.frame(0);
        let rects = vec![Rect::with_size(0, 0, 64, 64), Rect::with_size(5, 9, 20, 30)];
        let (ih, hists) = eng.serve(&frame, &rects).expect("serve via cpu");
        let expected = integral_histogram_seq(&frame.binned(32));
        assert_eq!(expected.max_abs_diff(&ih), 0.0);
        for (i, &r) in rects.iter().enumerate() {
            assert_eq!(hists[i], region_histogram(&expected, r), "query {i}");
        }
    }
}
