//! The dual-buffered frame pipeline — Algorithm 6 / Figs. 12 & 14.
//!
//! Four stages per frame, mirroring the paper's CUDA-streams design:
//!
//! ```text
//! read (disk/source) → H2D copy → kernel (PJRT) → D2H copy → consumer
//! ```
//!
//! Each stage runs on its own thread; stages are connected by bounded
//! queues whose capacity is the number of in-flight frames ("lanes").
//! `lanes = 1` reproduces the no-dual-buffering baseline (strictly
//! serial), `lanes = 2` is the paper's two CUDA streams with page-locked
//! double buffers, larger values deepen the software pipeline.
//!
//! Transfers are simulated (DESIGN.md §4): the H2D/D2H stages sleep for
//! the duration the PCIe model assigns to the buffer size, optionally
//! scaled to preserve the paper's kernel:transfer ratio on this
//! substrate.  The *kernel* stage is always real PJRT execution of the
//! AOT artifact.
//!
//! [`CpuPipeline`] is the artifact-free sibling: the same staging with
//! the kernel stage on the [`ScanEngine`] and every
//! per-frame buffer recycled (tensors via [`FramePool`], image index
//! buffers via a return ring) so the steady state allocates nothing.

use crate::coordinator::backpressure::bounded;
use crate::coordinator::frame_pool::{FramePool, PooledTensor};
use crate::coordinator::metrics::{FrameStat, Throughput};
use crate::histogram::engine::ScanEngine;
use crate::histogram::types::{BinnedImage, IntegralHistogram};
use crate::runtime::artifact::ArtifactManifest;
use crate::runtime::client::HistogramExecutor;
use crate::simulator::pcie::PcieModel;
use crate::video::source::FrameSource;
use anyhow::{anyhow, Context, Result};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// How the pipeline models CPU↔device transfers.
#[derive(Debug, Clone, Copy)]
pub enum TransferModel {
    /// No transfer cost (kernel-only runs; §4.3's "part of a larger GPU
    /// pipeline" scenario where the tensor never leaves the device).
    None,
    /// Sleep for `scale ×` the PCIe model's time for each buffer.
    /// `scale` calibrates the kernel:transfer ratio to the paper's GPU
    /// (see EXPERIMENTS.md per-figure notes).
    Simulated { model: PcieModel, scale: f64 },
}

impl TransferModel {
    fn h2d(&self, bytes: usize) -> Duration {
        match self {
            TransferModel::None => Duration::ZERO,
            TransferModel::Simulated { model, scale } => {
                model.transfer_time(bytes).mul_f64(*scale)
            }
        }
    }

    fn d2h(&self, bytes: usize) -> Duration {
        self.h2d(bytes)
    }
}

/// Pipeline configuration.
#[derive(Debug, Clone)]
pub struct PipelineConfig {
    /// In-flight frames (1 = serial, 2 = dual-buffering).
    pub lanes: usize,
    /// Bins for quantization (must match the artifact).
    pub bins: usize,
    /// Artifact name to execute per frame.
    pub artifact: String,
    pub transfer: TransferModel,
}

impl PipelineConfig {
    pub fn new(artifact: impl Into<String>, bins: usize) -> PipelineConfig {
        PipelineConfig {
            lanes: 2,
            bins,
            artifact: artifact.into(),
            transfer: TransferModel::None,
        }
    }

    pub fn lanes(mut self, lanes: usize) -> Self {
        assert!(lanes >= 1);
        self.lanes = lanes;
        self
    }

    pub fn transfer(mut self, t: TransferModel) -> Self {
        self.transfer = t;
        self
    }
}

/// Result of a pipeline run.
#[derive(Debug)]
pub struct PipelineReport {
    pub throughput: Throughput,
    pub lanes: usize,
    /// High-water marks of the three inter-stage queues.
    pub queue_high_water: [usize; 3],
}

impl PipelineReport {
    pub fn fps(&self) -> f64 {
        self.throughput.fps()
    }
}

/// The dual-buffered pipeline runner.
pub struct Pipeline {
    manifest: Arc<ArtifactManifest>,
    config: PipelineConfig,
}

struct InFlight {
    stat: FrameStat,
    t_enqueue: Instant,
    image: BinnedImage,
}

struct Computed {
    stat: FrameStat,
    t_enqueue: Instant,
    ih: IntegralHistogram,
}

impl Pipeline {
    pub fn new(manifest: Arc<ArtifactManifest>, config: PipelineConfig) -> Pipeline {
        Pipeline { manifest, config }
    }

    /// Run `source` to exhaustion, dropping results (figure timing runs).
    pub fn run(&self, source: Box<dyn FrameSource>) -> Result<PipelineReport> {
        self.run_with(source, |_, _| {})
    }

    /// Run `source` to exhaustion, handing each (seq, tensor) to `sink`
    /// on the output stage.
    pub fn run_with(
        &self,
        mut source: Box<dyn FrameSource>,
        mut sink: impl FnMut(usize, IntegralHistogram) + Send,
    ) -> Result<PipelineReport> {
        let cfg = &self.config;
        if cfg.lanes == 1 {
            return self.run_serial(&mut *source, &mut sink);
        }
        let meta = self
            .manifest
            .find_named(&cfg.artifact)
            .ok_or_else(|| anyhow!("artifact '{}' not in manifest", cfg.artifact))?
            .clone();
        let tensor_bytes = meta.tensor_bytes();
        let transfer = cfg.transfer;
        let bins = cfg.bins;

        let (q1_tx, q1_rx, s1) = bounded::<InFlight>(cfg.lanes);
        let (q2_tx, q2_rx, s2) = bounded::<InFlight>(cfg.lanes);
        let (q3_tx, q3_rx, s3) = bounded::<Computed>(cfg.lanes);
        // readiness signal: compute stage compiles its executor first
        let (ready_tx, ready_rx) = std::sync::mpsc::channel::<Result<()>>();

        let manifest = Arc::clone(&self.manifest);
        let meta_c = meta.clone();

        let report = std::thread::scope(|scope| -> Result<PipelineReport> {
            // Stage 2: H2D transfer (simulated DMA engine).
            scope.spawn(move || {
                while let Ok(mut item) = q1_rx.recv() {
                    let d = transfer.h2d(item.image.data.len() * 4);
                    if !d.is_zero() {
                        std::thread::sleep(d);
                    }
                    item.stat.h2d = d;
                    if q2_tx.send(item).is_err() {
                        break;
                    }
                }
            });

            // Stage 3: kernel execution (owns the PJRT executor).
            scope.spawn(move || {
                let exe = match HistogramExecutor::compile(&manifest, &meta_c) {
                    Ok(e) => {
                        let _ = ready_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(mut item) = q2_rx.recv() {
                    match exe.compute_timed(&item.image) {
                        Ok((ih, kernel)) => {
                            item.stat.kernel = kernel;
                            let c = Computed { stat: item.stat, t_enqueue: item.t_enqueue, ih };
                            if q3_tx.send(c).is_err() {
                                break;
                            }
                        }
                        Err(_) => break,
                    }
                }
            });

            // Wait for the executor before starting the clock: compile
            // time is a one-off, not part of steady-state throughput.
            ready_rx.recv().context("compute stage died")??;

            let t_start = Instant::now();

            // Stage 4: D2H + consumer. Borrows `sink` (scoped thread), so
            // results stream out without accumulating tensors in memory.
            let sink_ref = &mut sink;
            let d2h_handle = scope.spawn(move || -> Vec<FrameStat> {
                let mut stats = Vec::new();
                while let Ok(mut item) = q3_rx.recv() {
                    let d = transfer.d2h(tensor_bytes);
                    if !d.is_zero() {
                        std::thread::sleep(d);
                    }
                    item.stat.d2h = d;
                    item.stat.latency = item.t_enqueue.elapsed();
                    stats.push(item.stat);
                    sink_ref(item.stat.seq, item.ih);
                }
                stats
            });

            // Stage 1: read + quantize ("CopyImageFromDisk").
            let mut frames = 0usize;
            while let Some(frame) = source.next_frame() {
                let t_enqueue = Instant::now();
                let t0 = Instant::now();
                let image = frame.binned(bins);
                let stat = FrameStat { seq: frame.seq, read: t0.elapsed(), ..Default::default() };
                frames += 1;
                if q1_tx.send(InFlight { stat, t_enqueue, image }).is_err() {
                    break;
                }
            }
            drop(q1_tx); // close the pipeline; stages drain and exit

            let mut stats = d2h_handle.join().expect("d2h stage panicked");
            let wall = t_start.elapsed();
            stats.sort_by_key(|s| s.seq);
            Ok(PipelineReport {
                throughput: Throughput { frames, wall, stats },
                lanes: cfg.lanes,
                queue_high_water: [s1.high_water(), s2.high_water(), s3.high_water()],
            })
        })?;
        Ok(report)
    }

    /// Strictly serial baseline (`lanes = 1`, Fig. 14 without overlap):
    /// every stage completes before the next frame is read.
    fn run_serial(
        &self,
        source: &mut dyn FrameSource,
        sink: &mut (impl FnMut(usize, IntegralHistogram) + Send),
    ) -> Result<PipelineReport> {
        let cfg = &self.config;
        let meta = self
            .manifest
            .find_named(&cfg.artifact)
            .ok_or_else(|| anyhow!("artifact '{}' not in manifest", cfg.artifact))?;
        let exe = HistogramExecutor::compile(&self.manifest, meta)?;
        let tensor_bytes = meta.tensor_bytes();
        let t_start = Instant::now();
        let mut stats = Vec::new();
        let mut frames = 0usize;
        while let Some(frame) = source.next_frame() {
            let t_enqueue = Instant::now();
            let t0 = Instant::now();
            let image = frame.binned(cfg.bins);
            let read = t0.elapsed();
            let h2d = cfg.transfer.h2d(image.data.len() * 4);
            if !h2d.is_zero() {
                std::thread::sleep(h2d);
            }
            let (ih, kernel) = exe.compute_timed(&image)?;
            let d2h = cfg.transfer.d2h(tensor_bytes);
            if !d2h.is_zero() {
                std::thread::sleep(d2h);
            }
            stats.push(FrameStat {
                seq: frame.seq,
                read,
                h2d,
                kernel,
                d2h,
                latency: t_enqueue.elapsed(),
            });
            sink(frame.seq, ih);
            frames += 1;
        }
        Ok(PipelineReport {
            throughput: Throughput { frames, wall: t_start.elapsed(), stats },
            lanes: 1,
            queue_high_water: [0; 3],
        })
    }
}

/// Configuration of the CPU-substrate pipeline.
#[derive(Debug, Clone)]
pub struct CpuPipelineConfig {
    /// In-flight frames (1 = serial, 2 = dual-buffering).
    pub lanes: usize,
    /// Bins for quantization.
    pub bins: usize,
    /// `ScanEngine` worker budget (0 ⇒ all available cores).
    pub workers: usize,
}

impl CpuPipelineConfig {
    pub fn new(bins: usize) -> CpuPipelineConfig {
        CpuPipelineConfig { lanes: 2, bins, workers: 0 }
    }

    pub fn lanes(mut self, lanes: usize) -> Self {
        assert!(lanes >= 1);
        self.lanes = lanes;
        self
    }

    pub fn workers(mut self, workers: usize) -> Self {
        self.workers = workers;
        self
    }
}

struct CpuComputed {
    stat: FrameStat,
    t_enqueue: Instant,
    ih: PooledTensor,
}

/// The zero-allocation CPU pipeline: the same staged design as
/// [`Pipeline`] but with the kernel stage on the
/// [`ScanEngine`] and **every** per-frame buffer recycled —
/// output tensors through a [`FramePool`] (handed to the sink as RAII
/// [`PooledTensor`]s that return on drop) and quantized-image buffers
/// through a stage-2→stage-1 return ring.  After the first few frames
/// the steady-state path allocates no per-frame buffers; the pool's
/// counters make that assertable (`tests/engine_property.rs`).  The
/// engine — and with it its persistent worker pool of parked threads —
/// lives across runs, so repeated streams on one lane never respawn
/// workers (see `histogram::engine::worker_pool`).
///
/// This is the per-stream lane the server's sessions own
/// ([`crate::coordinator::server::Session`]); [`Self::with_pool`] lets
/// many lanes recycle tensors through one server-wide arena.
///
/// Transfer stages do not exist on this substrate (the tensor never
/// leaves host memory), mirroring the paper's "part of a larger GPU
/// pipeline" scenario of §4.3 where transfers amortize away.
pub struct CpuPipeline {
    config: CpuPipelineConfig,
    pool: Arc<FramePool>,
    /// Persistent compute engine (owns the parked worker pool).  The
    /// mutex only serializes runs on one lane — a lane processes one
    /// stream at a time by construction.
    engine: Mutex<ScanEngine>,
}

/// Lock the lane engine, recovering a poisoned lock by REPLACING the
/// engine with a fresh one.  Unlike the pool free-lists (valid at every
/// instruction boundary, recovered as-is), an engine abandoned
/// mid-compute holds suspect scheduler/scratch state — so poisoning
/// here means explicit invalidation: drop the old engine (its parked
/// workers join) and park a new one (DESIGN.md §8).
fn lock_engine(mx: &std::sync::Mutex<ScanEngine>, workers: usize) -> std::sync::MutexGuard<'_, ScanEngine> {
    match mx.lock() {
        Ok(g) => g,
        Err(poison) => {
            let mut g = poison.into_inner();
            *g = ScanEngine::new(workers);
            g
        }
    }
}

impl CpuPipeline {
    pub fn new(config: CpuPipelineConfig) -> CpuPipeline {
        Self::with_pool(config, Arc::new(FramePool::new()))
    }

    /// A lane recycling tensors through a shared (e.g. server-wide)
    /// arena instead of a private one.
    pub fn with_pool(config: CpuPipelineConfig, pool: Arc<FramePool>) -> CpuPipeline {
        let engine = Mutex::new(ScanEngine::new(config.workers));
        CpuPipeline { config, pool, engine }
    }

    /// The tensor arena (for steady-state allocation assertions).
    pub fn pool(&self) -> &Arc<FramePool> {
        &self.pool
    }

    /// Worker-pool counters of the lane's engine (zero thread-spawn
    /// observability across runs).
    pub fn engine_pool_stats(&self) -> crate::histogram::engine::WorkerPoolStats {
        lock_engine(&self.engine, self.config.workers).pool_stats()
    }

    /// Run `source` to exhaustion, dropping results (timing runs).
    pub fn run(&self, source: Box<dyn FrameSource>) -> Result<PipelineReport> {
        self.run_with(source, |_, _| {})
    }

    /// Run `source` to exhaustion, handing each (seq, pooled tensor) to
    /// `sink`; dropping the handle returns its buffer to the pool.
    pub fn run_with(
        &self,
        mut source: Box<dyn FrameSource>,
        mut sink: impl FnMut(usize, PooledTensor) + Send,
    ) -> Result<PipelineReport> {
        let cfg = &self.config;
        if cfg.lanes == 1 {
            return self.run_serial(&mut *source, &mut sink);
        }
        let bins = cfg.bins;
        let (q1_tx, q1_rx, s1) = bounded::<InFlight>(cfg.lanes);
        let (q2_tx, q2_rx, s2) = bounded::<CpuComputed>(cfg.lanes);
        // Recycling ring: stage 2 returns quantized-image buffers for
        // stage 1 to refill.
        let (ring_tx, ring_rx) = std::sync::mpsc::channel::<BinnedImage>();
        let pool = Arc::clone(&self.pool);
        let engine_mx = &self.engine;
        let cfg_workers = cfg.workers;
        let t_start = Instant::now();

        let report = std::thread::scope(|scope| -> Result<PipelineReport> {
            // Stage 2: the lane's persistent ScanEngine computes into
            // pooled tensors (the engine's parked workers survive the
            // run, so the next stream on this lane spawns nothing).
            scope.spawn(move || {
                let mut engine = lock_engine(engine_mx, cfg_workers);
                while let Ok(item) = q1_rx.recv() {
                    let InFlight { mut stat, t_enqueue, image } = item;
                    let t0 = Instant::now();
                    let mut ih = PooledTensor::acquire(&pool, image.bins, image.h, image.w);
                    engine.compute_into(&image, &mut ih);
                    stat.kernel = t0.elapsed();
                    let _ = ring_tx.send(image);
                    if q2_tx.send(CpuComputed { stat, t_enqueue, ih }).is_err() {
                        break;
                    }
                }
            });

            // Stage 3: consumer.
            let sink_ref = &mut sink;
            let out_handle = scope.spawn(move || -> Vec<FrameStat> {
                let mut stats = Vec::new();
                while let Ok(mut item) = q2_rx.recv() {
                    item.stat.latency = item.t_enqueue.elapsed();
                    stats.push(item.stat);
                    sink_ref(item.stat.seq, item.ih);
                }
                stats
            });

            // Stage 1 (this thread): read + quantize into recycled buffers.
            let mut frames = 0usize;
            while let Some(frame) = source.next_frame() {
                let t_enqueue = Instant::now();
                let t0 = Instant::now();
                let mut image = ring_rx
                    .try_recv()
                    .unwrap_or_else(|_| BinnedImage::new(0, 0, 1, Vec::new()));
                frame.binned_into(bins, &mut image);
                let stat = FrameStat { seq: frame.seq, read: t0.elapsed(), ..Default::default() };
                frames += 1;
                if q1_tx.send(InFlight { stat, t_enqueue, image }).is_err() {
                    break;
                }
            }
            drop(q1_tx); // close the pipeline; stages drain and exit

            let mut stats = out_handle.join().expect("sink stage panicked");
            let wall = t_start.elapsed();
            stats.sort_by_key(|s| s.seq);
            Ok(PipelineReport {
                throughput: Throughput { frames, wall, stats },
                lanes: cfg.lanes,
                queue_high_water: [s1.high_water(), s2.high_water(), 0],
            })
        })?;
        Ok(report)
    }

    /// Strictly serial CPU baseline (`lanes = 1`).
    fn run_serial(
        &self,
        source: &mut dyn FrameSource,
        sink: &mut (impl FnMut(usize, PooledTensor) + Send),
    ) -> Result<PipelineReport> {
        let bins = self.config.bins;
        let mut engine = lock_engine(&self.engine, self.config.workers);
        let mut image = BinnedImage::new(0, 0, 1, Vec::new());
        let t_start = Instant::now();
        let mut stats = Vec::new();
        let mut frames = 0usize;
        while let Some(frame) = source.next_frame() {
            let t_enqueue = Instant::now();
            let t0 = Instant::now();
            frame.binned_into(bins, &mut image);
            let read = t0.elapsed();
            let t1 = Instant::now();
            let mut ih = PooledTensor::acquire(&self.pool, image.bins, image.h, image.w);
            engine.compute_into(&image, &mut ih);
            let kernel = t1.elapsed();
            stats.push(FrameStat {
                seq: frame.seq,
                read,
                kernel,
                latency: t_enqueue.elapsed(),
                ..Default::default()
            });
            sink(frame.seq, ih);
            frames += 1;
        }
        Ok(PipelineReport {
            throughput: Throughput { frames, wall: t_start.elapsed(), stats },
            lanes: 1,
            queue_high_water: [0; 3],
        })
    }
}
