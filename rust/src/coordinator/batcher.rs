//! Region-query batcher — the O(1) lookup service, batched.
//!
//! Downstream analytics (trackers, detectors, filters) issue many small
//! rectangle queries per frame; answering them one-by-one wastes the
//! constant-time property the integral histogram buys.  The batcher
//! accumulates queries, deduplicates identical rectangles, and answers a
//! whole batch against one cached tensor — either with the AOT
//! `region_query` graph (fixed batch width, padded) or the CPU fallback
//! (Eq. 2 directly), which are bit-identical.

use crate::histogram::region::{region_histogram, Rect};
use crate::histogram::types::IntegralHistogram;
use std::collections::HashMap;

/// A pending query with a caller-supplied id.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QueryRequest {
    pub id: u64,
    pub rect: Rect,
}

/// One answered query.
#[derive(Debug, Clone, PartialEq)]
pub struct QueryResponse {
    pub id: u64,
    pub rect: Rect,
    pub histogram: Vec<f32>,
}

/// Batching accumulator for region queries against one frame's tensor.
#[derive(Debug, Default)]
pub struct QueryBatcher {
    pending: Vec<QueryRequest>,
    /// Total queries answered (metrics).
    answered: usize,
    /// Unique rectangles actually computed (dedup efficiency).
    computed: usize,
}

impl QueryBatcher {
    pub fn new() -> QueryBatcher {
        QueryBatcher::default()
    }

    /// Enqueue one query.
    pub fn submit(&mut self, id: u64, rect: Rect) {
        self.pending.push(QueryRequest { id, rect });
    }

    pub fn pending(&self) -> usize {
        self.pending.len()
    }

    /// Answer every pending query against `ih`, deduplicating repeated
    /// rectangles (common when many trackers probe the same candidate).
    /// Responses preserve submission order.
    pub fn flush(&mut self, ih: &IntegralHistogram) -> Vec<QueryResponse> {
        let mut cache: HashMap<Rect, Vec<f32>> = HashMap::new();
        let mut out = Vec::with_capacity(self.pending.len());
        for req in self.pending.drain(..) {
            let hist = cache
                .entry(req.rect)
                .or_insert_with(|| region_histogram(ih, req.rect))
                .clone();
            out.push(QueryResponse { id: req.id, rect: req.rect, histogram: hist });
        }
        self.answered += out.len();
        self.computed += cache.len();
        out
    }

    /// (answered, unique-computed) counters.
    pub fn stats(&self) -> (usize, usize) {
        (self.answered, self.computed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::histogram::sequential::integral_histogram_seq;
    use crate::histogram::types::BinnedImage;
    use crate::util::prng::Xoshiro256;

    fn ih() -> IntegralHistogram {
        let mut rng = Xoshiro256::new(1);
        let mut data = vec![0i32; 16 * 16];
        rng.fill_bins(&mut data, 4);
        integral_histogram_seq(&BinnedImage::new(16, 16, 4, data))
    }

    #[test]
    fn flush_answers_in_order() {
        let ih = ih();
        let mut b = QueryBatcher::new();
        b.submit(7, Rect::new(0, 0, 15, 15));
        b.submit(3, Rect::new(1, 1, 4, 4));
        let rs = b.flush(&ih);
        assert_eq!(rs.len(), 2);
        assert_eq!(rs[0].id, 7);
        assert_eq!(rs[1].id, 3);
        assert_eq!(rs[0].histogram, region_histogram(&ih, Rect::new(0, 0, 15, 15)));
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn dedup_counts() {
        let ih = ih();
        let mut b = QueryBatcher::new();
        let r = Rect::new(2, 2, 9, 9);
        for id in 0..5 {
            b.submit(id, r);
        }
        b.submit(99, Rect::new(0, 0, 1, 1));
        let rs = b.flush(&ih);
        assert_eq!(rs.len(), 6);
        let (answered, computed) = b.stats();
        assert_eq!(answered, 6);
        assert_eq!(computed, 2, "5 identical rects computed once");
        assert!(rs[..5].iter().all(|x| x.histogram == rs[0].histogram));
    }

    #[test]
    fn flush_empty_is_noop() {
        let ih = ih();
        let mut b = QueryBatcher::new();
        assert!(b.flush(&ih).is_empty());
        assert_eq!(b.stats(), (0, 0));
    }

    /// Batched answers must be bit-identical to answering each query
    /// one-by-one with Eq. 2 — dedup may share work, never change it.
    #[test]
    fn batched_answers_bit_identical_to_one_by_one() {
        let ih = ih();
        let mut rng = Xoshiro256::new(0xBA7C);
        let mut b = QueryBatcher::new();
        let mut rects = Vec::new();
        for id in 0..40u64 {
            let r0 = rng.range(0, 12);
            let c0 = rng.range(0, 12);
            let r1 = rng.range(r0 + 1, 16);
            let c1 = rng.range(c0 + 1, 16);
            let rect = if id % 4 == 3 { rects[0] } else { Rect::new(r0, c0, r1 - 1, c1 - 1) };
            rects.push(rect);
            b.submit(id, rect);
        }
        let batched = b.flush(&ih);
        assert_eq!(batched.len(), 40);
        for (i, resp) in batched.iter().enumerate() {
            assert_eq!(resp.id, i as u64, "submission order preserved");
            assert_eq!(resp.rect, rects[i]);
            let direct = region_histogram(&ih, rects[i]);
            assert_eq!(resp.histogram, direct, "query {i} must be bit-identical");
        }
        let (answered, computed) = b.stats();
        assert_eq!(answered, 40);
        assert!(computed < 40, "duplicates must be deduplicated, computed {computed}");
    }

    /// The id→response mapping must hold across multiple flushes (ids
    /// may repeat between batches; counters accumulate).
    #[test]
    fn id_mapping_and_counters_across_flushes() {
        let ih = ih();
        let mut b = QueryBatcher::new();
        let ra = Rect::new(0, 0, 7, 7);
        let rb = Rect::new(4, 4, 11, 11);

        b.submit(1, ra);
        b.submit(2, rb);
        let first = b.flush(&ih);
        assert_eq!(first.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 2]);
        assert_eq!(b.stats(), (2, 2));
        assert_eq!(b.pending(), 0);

        // Second batch reuses id 1 for a *different* rect and repeats
        // ra under a new id: responses map by submission, and the
        // dedup cache must not leak across flushes (fresh per batch).
        b.submit(1, rb);
        b.submit(7, ra);
        b.submit(7, ra);
        let second = b.flush(&ih);
        assert_eq!(second.len(), 3);
        assert_eq!(second[0].id, 1);
        assert_eq!(second[0].rect, rb);
        assert_eq!(second[0].histogram, region_histogram(&ih, rb));
        assert_eq!(second[1].id, 7);
        assert_eq!(second[1].histogram, region_histogram(&ih, ra));
        assert_eq!(second[1].histogram, second[2].histogram);
        // counters accumulate: 2+3 answered; 2 + 2 unique computed
        assert_eq!(b.stats(), (5, 4));

        // earlier responses are unaffected by later flushes
        assert_eq!(first[0].histogram, region_histogram(&ih, ra));
    }
}
