//! Region-query batcher — the O(1) lookup service, batched.
//!
//! Downstream analytics (trackers, detectors, filters) issue many small
//! rectangle queries per frame; answering them one-by-one wastes the
//! constant-time property the integral histogram buys.  The batcher
//! accumulates queries, deduplicates identical rectangles, and answers a
//! whole batch against one cached tensor — either with the AOT
//! `region_query` graph (fixed batch width, padded) or the CPU fallback
//! (Eq. 2 directly), which are bit-identical.

use crate::histogram::region::{region_histogram, Rect};
use crate::histogram::types::IntegralHistogram;
use std::collections::HashMap;

/// A pending query with a caller-supplied id.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QueryRequest {
    pub id: u64,
    pub rect: Rect,
}

/// One answered query.
#[derive(Debug, Clone, PartialEq)]
pub struct QueryResponse {
    pub id: u64,
    pub rect: Rect,
    pub histogram: Vec<f32>,
}

/// Batching accumulator for region queries against one frame's tensor.
#[derive(Debug, Default)]
pub struct QueryBatcher {
    pending: Vec<QueryRequest>,
    /// Total queries answered (metrics).
    answered: usize,
    /// Unique rectangles actually computed (dedup efficiency).
    computed: usize,
}

impl QueryBatcher {
    pub fn new() -> QueryBatcher {
        QueryBatcher::default()
    }

    /// Enqueue one query.
    pub fn submit(&mut self, id: u64, rect: Rect) {
        self.pending.push(QueryRequest { id, rect });
    }

    pub fn pending(&self) -> usize {
        self.pending.len()
    }

    /// Answer every pending query against `ih`, deduplicating repeated
    /// rectangles (common when many trackers probe the same candidate).
    /// Responses preserve submission order.
    pub fn flush(&mut self, ih: &IntegralHistogram) -> Vec<QueryResponse> {
        let mut cache: HashMap<Rect, Vec<f32>> = HashMap::new();
        let mut out = Vec::with_capacity(self.pending.len());
        for req in self.pending.drain(..) {
            let hist = cache
                .entry(req.rect)
                .or_insert_with(|| region_histogram(ih, req.rect))
                .clone();
            out.push(QueryResponse { id: req.id, rect: req.rect, histogram: hist });
        }
        self.answered += out.len();
        self.computed += cache.len();
        out
    }

    /// (answered, unique-computed) counters.
    pub fn stats(&self) -> (usize, usize) {
        (self.answered, self.computed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::histogram::sequential::integral_histogram_seq;
    use crate::histogram::types::BinnedImage;
    use crate::util::prng::Xoshiro256;

    fn ih() -> IntegralHistogram {
        let mut rng = Xoshiro256::new(1);
        let mut data = vec![0i32; 16 * 16];
        rng.fill_bins(&mut data, 4);
        integral_histogram_seq(&BinnedImage::new(16, 16, 4, data))
    }

    #[test]
    fn flush_answers_in_order() {
        let ih = ih();
        let mut b = QueryBatcher::new();
        b.submit(7, Rect::new(0, 0, 15, 15));
        b.submit(3, Rect::new(1, 1, 4, 4));
        let rs = b.flush(&ih);
        assert_eq!(rs.len(), 2);
        assert_eq!(rs[0].id, 7);
        assert_eq!(rs[1].id, 3);
        assert_eq!(rs[0].histogram, region_histogram(&ih, Rect::new(0, 0, 15, 15)));
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn dedup_counts() {
        let ih = ih();
        let mut b = QueryBatcher::new();
        let r = Rect::new(2, 2, 9, 9);
        for id in 0..5 {
            b.submit(id, r);
        }
        b.submit(99, Rect::new(0, 0, 1, 1));
        let rs = b.flush(&ih);
        assert_eq!(rs.len(), 6);
        let (answered, computed) = b.stats();
        assert_eq!(answered, 6);
        assert_eq!(computed, 2, "5 identical rects computed once");
        assert!(rs[..5].iter().all(|x| x.histogram == rs[0].histogram));
    }

    #[test]
    fn flush_empty_is_noop() {
        let ih = ih();
        let mut b = QueryBatcher::new();
        assert!(b.flush(&ih).is_empty());
        assert_eq!(b.stats(), (0, 0));
    }
}
