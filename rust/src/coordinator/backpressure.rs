//! Bounded hand-off queues with occupancy accounting, plus the RAII
//! session-admission primitive.
//!
//! The pipeline's stages are connected by bounded channels whose
//! capacity IS the dual-buffering depth: capacity 1 ⇒ strictly serial
//! hand-off, capacity 2 ⇒ the paper's two CUDA streams, capacity N ⇒
//! N-deep software pipelining.  Senders block when the consumer falls
//! behind — that is the backpressure that keeps a slow kernel stage
//! from buffering unbounded frames (and unbounded page-locked memory,
//! the §4.4 failure mode).
//!
//! [`AdmissionControl`] replaces the earlier token-channel session
//! limiter: a slot there was a `()` sent back on a channel in a `Drop`
//! impl, so a session that panicked between token receipt and
//! registration leaked its slot forever.  Here the slot IS an
//! [`AdmissionGuard`] — a value whose `Drop` decrements the live
//! count — so every exit path (return, `?`, panic unwind) frees the
//! slot by construction (DESIGN.md §8).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, RecvError, SendError, SyncSender};
use std::sync::Arc;

/// Occupancy statistics shared by both endpoints of a queue.
#[derive(Debug, Default)]
pub struct QueueStats {
    sent: AtomicUsize,
    received: AtomicUsize,
    high_water: AtomicUsize,
}

impl QueueStats {
    /// Messages currently in flight.
    pub fn depth(&self) -> usize {
        self.sent.load(Ordering::Relaxed).saturating_sub(self.received.load(Ordering::Relaxed))
    }

    /// Highest in-flight depth observed.
    pub fn high_water(&self) -> usize {
        self.high_water.load(Ordering::Relaxed)
    }

    pub fn sent(&self) -> usize {
        self.sent.load(Ordering::Relaxed)
    }
}

/// Sending half of a bounded queue.
pub struct BoundedSender<T> {
    tx: SyncSender<T>,
    stats: Arc<QueueStats>,
}

/// Receiving half of a bounded queue.
pub struct BoundedReceiver<T> {
    rx: Receiver<T>,
    stats: Arc<QueueStats>,
}

/// Create a bounded queue of `capacity` (≥ 1) with shared stats.
pub fn bounded<T>(capacity: usize) -> (BoundedSender<T>, BoundedReceiver<T>, Arc<QueueStats>) {
    assert!(capacity >= 1, "bounded queue needs capacity >= 1");
    let (tx, rx) = std::sync::mpsc::sync_channel(capacity);
    let stats = Arc::new(QueueStats::default());
    (
        BoundedSender { tx, stats: Arc::clone(&stats) },
        BoundedReceiver { rx, stats: Arc::clone(&stats) },
        stats,
    )
}

impl<T> BoundedSender<T> {
    /// Blocking send (applies backpressure when the queue is full).
    pub fn send(&self, value: T) -> Result<(), SendError<T>> {
        self.tx.send(value)?;
        let sent = self.stats.sent.fetch_add(1, Ordering::Relaxed) + 1;
        let depth = sent.saturating_sub(self.stats.received.load(Ordering::Relaxed));
        self.stats.high_water.fetch_max(depth, Ordering::Relaxed);
        Ok(())
    }

    /// Non-blocking send: `Err(value)` back when the queue is full (or
    /// closed) instead of waiting — the admission-control primitive the
    /// server builds session slots on.
    pub fn try_send(&self, value: T) -> Result<(), T> {
        use std::sync::mpsc::TrySendError;
        match self.tx.try_send(value) {
            Ok(()) => {
                let sent = self.stats.sent.fetch_add(1, Ordering::Relaxed) + 1;
                let depth = sent.saturating_sub(self.stats.received.load(Ordering::Relaxed));
                self.stats.high_water.fetch_max(depth, Ordering::Relaxed);
                Ok(())
            }
            Err(TrySendError::Full(v)) | Err(TrySendError::Disconnected(v)) => Err(v),
        }
    }
}

impl<T> BoundedReceiver<T> {
    /// Blocking receive; `Err` once all senders are dropped and drained.
    pub fn recv(&self) -> Result<T, RecvError> {
        let v = self.rx.recv()?;
        self.stats.received.fetch_add(1, Ordering::Relaxed);
        Ok(v)
    }

    /// Non-blocking receive; `None` when the queue is currently empty.
    pub fn try_recv(&self) -> Option<T> {
        match self.rx.try_recv() {
            Ok(v) => {
                self.stats.received.fetch_add(1, Ordering::Relaxed);
                Some(v)
            }
            Err(_) => None,
        }
    }

    /// Drain into an iterator until the channel closes.
    pub fn iter(&self) -> impl Iterator<Item = T> + '_ {
        std::iter::from_fn(move || self.recv().ok())
    }
}

/// Lock-free counting admission limiter with RAII slot release.
///
/// `try_admit` CAS-increments the live count and hands back an
/// [`AdmissionGuard`]; dropping the guard — on any path, including a
/// panic unwind — frees the slot.  No locks, so no poisoning, and no
/// token to lose.
#[derive(Debug)]
pub struct AdmissionControl {
    capacity: usize,
    active: AtomicUsize,
    high_water: AtomicUsize,
    admitted: AtomicUsize,
    rejected: AtomicUsize,
}

impl AdmissionControl {
    pub fn new(capacity: usize) -> Arc<AdmissionControl> {
        assert!(capacity >= 1, "admission control needs capacity >= 1");
        Arc::new(AdmissionControl {
            capacity,
            active: AtomicUsize::new(0),
            high_water: AtomicUsize::new(0),
            admitted: AtomicUsize::new(0),
            rejected: AtomicUsize::new(0),
        })
    }

    /// Claim a slot: `None` when all `capacity` slots are live.
    pub fn try_admit(self: &Arc<Self>) -> Option<AdmissionGuard> {
        let mut cur = self.active.load(Ordering::Relaxed);
        loop {
            if cur >= self.capacity {
                self.rejected.fetch_add(1, Ordering::Relaxed);
                return None;
            }
            match self.active.compare_exchange_weak(
                cur,
                cur + 1,
                Ordering::AcqRel,
                Ordering::Relaxed,
            ) {
                Ok(_) => {
                    self.admitted.fetch_add(1, Ordering::Relaxed);
                    self.high_water.fetch_max(cur + 1, Ordering::Relaxed);
                    return Some(AdmissionGuard { ctl: Arc::clone(self) });
                }
                Err(seen) => cur = seen,
            }
        }
    }

    /// Slots currently held by live guards.
    pub fn active(&self) -> usize {
        self.active.load(Ordering::Relaxed)
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Total successful admissions so far.
    pub fn admitted(&self) -> usize {
        self.admitted.load(Ordering::Relaxed)
    }

    /// Total rejected admission attempts so far.
    pub fn rejected(&self) -> usize {
        self.rejected.load(Ordering::Relaxed)
    }

    /// Highest concurrent slot count observed.
    pub fn high_water(&self) -> usize {
        self.high_water.load(Ordering::Relaxed)
    }
}

/// A held admission slot.  Dropping it — on return or unwind — frees
/// the slot; there is no other way to release one.
#[derive(Debug)]
pub struct AdmissionGuard {
    ctl: Arc<AdmissionControl>,
}

impl Drop for AdmissionGuard {
    fn drop(&mut self) {
        self.ctl.active.fetch_sub(1, Ordering::AcqRel);
    }
}

/// Server-wide host-memory token bucket — [`AdmissionControl`]
/// generalized from unit slots to byte-weighted ones.
///
/// The shard planner's budget is per-plan, so N concurrent spilled
/// frames could legitimately each stay under their own budget while
/// the server residents N× the host's — the overcommit bug this type
/// fixes.  Every byte-weighted holding (a frame's peak-resident
/// projection, a proc-plane ring mapping) CAS-reserves here first; a
/// refused reservation sheds typed at the caller instead of silently
/// overcommitting.
///
/// `cap == 0` means *unlimited but metered*: reservations always
/// succeed and the gauge still tracks, so enabling enforcement later
/// is a config change, not a code change.
#[derive(Debug)]
pub struct MemoryBudget {
    cap: usize,
    reserved: AtomicUsize,
    high_water: AtomicUsize,
    shed: AtomicUsize,
}

impl MemoryBudget {
    pub fn new(cap: usize) -> Arc<MemoryBudget> {
        Arc::new(MemoryBudget {
            cap,
            reserved: AtomicUsize::new(0),
            high_water: AtomicUsize::new(0),
            shed: AtomicUsize::new(0),
        })
    }

    /// Reserve `bytes` against the bucket: `None` (and a `shed` tick)
    /// when the reservation would exceed `cap`.  The returned guard
    /// releases on drop — any path, including unwind.
    pub fn try_reserve(self: &Arc<Self>, bytes: usize) -> Option<MemoryReservation> {
        let mut cur = self.reserved.load(Ordering::Relaxed);
        loop {
            let next = match cur.checked_add(bytes) {
                Some(n) => n,
                None => {
                    self.shed.fetch_add(1, Ordering::Relaxed);
                    return None;
                }
            };
            if self.cap != 0 && next > self.cap {
                self.shed.fetch_add(1, Ordering::Relaxed);
                return None;
            }
            match self.reserved.compare_exchange_weak(
                cur,
                next,
                Ordering::AcqRel,
                Ordering::Relaxed,
            ) {
                Ok(_) => {
                    self.high_water.fetch_max(next, Ordering::Relaxed);
                    return Some(MemoryReservation { budget: Arc::clone(self), bytes });
                }
                Err(seen) => cur = seen,
            }
        }
    }

    /// Configured cap in bytes (`0` ⇒ unlimited).
    pub fn cap(&self) -> usize {
        self.cap
    }

    /// Bytes currently reserved by live guards.
    pub fn reserved(&self) -> usize {
        self.reserved.load(Ordering::Relaxed)
    }

    /// Highest concurrent reservation observed.
    pub fn high_water(&self) -> usize {
        self.high_water.load(Ordering::Relaxed)
    }

    /// Reservations refused so far.
    pub fn shed(&self) -> usize {
        self.shed.load(Ordering::Relaxed)
    }
}

/// A held byte reservation; dropping it returns the bytes.
#[derive(Debug)]
pub struct MemoryReservation {
    budget: Arc<MemoryBudget>,
    bytes: usize,
}

impl MemoryReservation {
    pub fn bytes(&self) -> usize {
        self.bytes
    }
}

impl Drop for MemoryReservation {
    fn drop(&mut self) {
        self.budget.reserved.fetch_sub(self.bytes, Ordering::AcqRel);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn fifo_order() {
        let (tx, rx, _) = bounded(4);
        for i in 0..4 {
            tx.send(i).unwrap();
        }
        drop(tx);
        assert_eq!(rx.iter().collect::<Vec<_>>(), vec![0, 1, 2, 3]);
    }

    #[test]
    fn stats_track_depth() {
        let (tx, rx, stats) = bounded(8);
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        assert_eq!(stats.depth(), 2);
        assert_eq!(stats.high_water(), 2);
        rx.recv().unwrap();
        assert_eq!(stats.depth(), 1);
        assert_eq!(stats.high_water(), 2);
        assert_eq!(stats.sent(), 2);
    }

    #[test]
    fn capacity_blocks_sender() {
        let (tx, rx, _) = bounded(1);
        tx.send(0u32).unwrap();
        let t = std::thread::spawn(move || {
            // this send must block until the main thread receives
            tx.send(1).unwrap();
        });
        std::thread::sleep(Duration::from_millis(30));
        assert_eq!(rx.recv().unwrap(), 0);
        assert_eq!(rx.recv().unwrap(), 1);
        t.join().unwrap();
    }

    #[test]
    fn try_send_rejects_when_full_and_slot_frees_on_recv() {
        // The admission-control pattern: capacity = max sessions.
        let (tx, rx, stats) = bounded::<()>(2);
        assert!(tx.try_send(()).is_ok());
        assert!(tx.try_send(()).is_ok());
        assert!(tx.try_send(()).is_err(), "third slot must be rejected");
        assert_eq!(stats.depth(), 2);
        assert_eq!(stats.high_water(), 2);
        assert!(rx.try_recv().is_some(), "closing a session frees a slot");
        assert!(tx.try_send(()).is_ok());
        assert_eq!(stats.depth(), 2);
    }

    #[test]
    fn try_recv_on_empty_is_none() {
        let (_tx, rx, _) = bounded::<u8>(1);
        assert!(rx.try_recv().is_none());
    }

    #[test]
    fn recv_fails_after_close() {
        let (tx, rx, _) = bounded::<u8>(1);
        drop(tx);
        assert!(rx.recv().is_err());
    }

    #[test]
    #[should_panic]
    fn zero_capacity_rejected() {
        bounded::<u8>(0);
    }

    #[test]
    fn admission_caps_and_guard_frees_on_drop() {
        let ctl = AdmissionControl::new(2);
        let a = ctl.try_admit().expect("slot 1");
        let b = ctl.try_admit().expect("slot 2");
        assert!(ctl.try_admit().is_none(), "third slot must be rejected");
        assert_eq!(ctl.active(), 2);
        assert_eq!(ctl.high_water(), 2);
        assert_eq!(ctl.rejected(), 1);
        drop(a);
        assert_eq!(ctl.active(), 1);
        let c = ctl.try_admit().expect("freed slot is reusable");
        drop(b);
        drop(c);
        assert_eq!(ctl.active(), 0);
        assert_eq!(ctl.admitted(), 3);
    }

    #[test]
    fn memory_budget_caps_bytes_and_reservation_frees_on_drop() {
        let mem = MemoryBudget::new(1000);
        let a = mem.try_reserve(600).expect("first reservation fits");
        assert_eq!(mem.reserved(), 600);
        assert!(mem.try_reserve(600).is_none(), "1200 > cap must shed");
        assert_eq!(mem.shed(), 1);
        let b = mem.try_reserve(400).expect("exact fit");
        assert_eq!(mem.reserved(), 1000);
        assert_eq!(mem.high_water(), 1000);
        drop(a);
        assert_eq!(mem.reserved(), 400);
        let c = mem.try_reserve(500).expect("freed bytes reusable");
        assert_eq!(c.bytes(), 500);
        drop(b);
        drop(c);
        assert_eq!(mem.reserved(), 0);
        assert_eq!(mem.high_water(), 1000, "peak survives the drops");
    }

    #[test]
    fn zero_cap_budget_meters_without_shedding() {
        let mem = MemoryBudget::new(0);
        let r = mem.try_reserve(usize::MAX / 2).expect("unlimited always admits");
        assert_eq!(mem.reserved(), usize::MAX / 2);
        assert_eq!(mem.shed(), 0);
        drop(r);
        assert_eq!(mem.reserved(), 0);
    }

    #[test]
    fn panicking_reservation_holder_returns_bytes() {
        let mem = MemoryBudget::new(100);
        let mem2 = Arc::clone(&mem);
        let t = std::thread::spawn(move || {
            let _r = mem2.try_reserve(100).expect("bytes");
            panic!("frame died mid-flight");
        });
        assert!(t.join().is_err());
        assert_eq!(mem.reserved(), 0, "unwind must return the bytes");
        assert!(mem.try_reserve(100).is_some());
    }

    /// The token-leak regression this type exists to fix: a holder that
    /// PANICS must still release its slot (unwind runs the guard's
    /// `Drop`), where the old channel-token scheme leaked it.
    #[test]
    fn panicking_holder_releases_slot() {
        let ctl = AdmissionControl::new(1);
        let ctl2 = Arc::clone(&ctl);
        let t = std::thread::spawn(move || {
            let _guard = ctl2.try_admit().expect("slot");
            panic!("session died mid-flight");
        });
        assert!(t.join().is_err());
        assert_eq!(ctl.active(), 0, "unwind must free the slot");
        assert!(ctl.try_admit().is_some(), "slot reusable after the panic");
    }
}
