//! Deterministic synthetic video generator.
//!
//! Produces grayscale sequences with the statistics video analytics
//! cares about: a spatially varying textured background (so histograms
//! differ across regions) plus moving bright objects (so region
//! histograms change over time and trackers have something to follow).
//! The generator is seeded and pure — every figure run sees identical
//! data, and frames are generated on the fly so even 8k×8k sequences
//! need no disk.

use crate::util::prng::Xoshiro256;
use crate::video::source::{FrameSource, VideoFrame};

/// A moving object: an axis-aligned bright rectangle with constant
/// velocity, bouncing off the frame borders.
#[derive(Debug, Clone, Copy)]
struct Blob {
    r: f64,
    c: f64,
    dr: f64,
    dc: f64,
    height: usize,
    width: usize,
    intensity: u8,
}

/// Deterministic synthetic video source.
pub struct SyntheticVideo {
    h: usize,
    w: usize,
    frames_left: Option<usize>,
    next_seq: usize,
    blobs: Vec<Blob>,
    /// Static background texture, row-major.
    background: Vec<u8>,
}

impl SyntheticVideo {
    /// `n_blobs` moving objects over a textured background; unlimited
    /// length (use [`Self::take_frames`] or the pipeline's frame budget).
    pub fn new(h: usize, w: usize, n_blobs: usize, seed: u64) -> SyntheticVideo {
        let mut rng = Xoshiro256::new(seed);
        // Smooth-ish texture: sum of a coarse random grid and fine noise.
        let cell = 16usize.min(h.max(1)).min(w.max(1));
        let gh = h.div_ceil(cell) + 1;
        let gw = w.div_ceil(cell) + 1;
        let grid: Vec<u8> = (0..gh * gw).map(|_| rng.range(32, 160) as u8).collect();
        let mut background = vec![0u8; h * w];
        for r in 0..h {
            for c in 0..w {
                let base = grid[(r / cell) * gw + c / cell] as i32;
                let noise = rng.range(0, 24) as i32 - 12;
                background[r * w + c] = (base + noise).clamp(0, 255) as u8;
            }
        }
        let blobs = (0..n_blobs)
            .map(|_| {
                let height = rng.range(h.max(8) / 8, h.max(9) / 4 + 1).max(2).min(h);
                let width = rng.range(w.max(8) / 8, w.max(9) / 4 + 1).max(2).min(w);
                Blob {
                    r: rng.range(0, (h - height).max(1)) as f64,
                    c: rng.range(0, (w - width).max(1)) as f64,
                    dr: rng.f64() * 4.0 - 2.0,
                    dc: rng.f64() * 4.0 - 2.0,
                    height,
                    width,
                    intensity: rng.range(180, 256) as u8,
                }
            })
            .collect();
        SyntheticVideo { h, w, frames_left: None, next_seq: 0, blobs, background }
    }

    /// Limit the stream to `n` frames.
    pub fn take_frames(mut self, n: usize) -> SyntheticVideo {
        self.frames_left = Some(n);
        self
    }

    /// Render frame `t` without consuming the stream (pure function of
    /// the initial state — blob positions are closed-form in t).
    pub fn frame(&self, t: usize) -> VideoFrame {
        let mut pixels = self.background.clone();
        for blob in &self.blobs {
            let (r, c) = blob_position(blob, t, self.h, self.w);
            for dr in 0..blob.height {
                let rr = r + dr;
                if rr >= self.h {
                    break;
                }
                let row = rr * self.w;
                for dc in 0..blob.width {
                    let cc = c + dc;
                    if cc >= self.w {
                        break;
                    }
                    pixels[row + cc] = blob.intensity;
                }
            }
        }
        VideoFrame::new(t, self.h, self.w, pixels)
    }

    /// Ground-truth top-left corner of blob `i` at time `t` (for the
    /// tracker example's accuracy check).
    pub fn blob_rect(&self, i: usize, t: usize) -> crate::histogram::region::Rect {
        let b = &self.blobs[i];
        let (r, c) = blob_position(b, t, self.h, self.w);
        crate::histogram::region::Rect::with_size(
            r.min(self.h - 1),
            c.min(self.w - 1),
            b.height.min(self.h - r.min(self.h - 1)),
            b.width.min(self.w - c.min(self.w - 1)),
        )
    }

    pub fn n_blobs(&self) -> usize {
        self.blobs.len()
    }
}

/// Reflective (bouncing) position of a blob at time t.
fn blob_position(b: &Blob, t: usize, h: usize, w: usize) -> (usize, usize) {
    let max_r = (h - b.height.min(h)) as f64;
    let max_c = (w - b.width.min(w)) as f64;
    (reflect(b.r + b.dr * t as f64, max_r), reflect(b.c + b.dc * t as f64, max_c))
}

/// Reflect x into [0, m] (triangle wave); m == 0 → 0.
fn reflect(x: f64, m: f64) -> usize {
    if m <= 0.0 {
        return 0;
    }
    let period = 2.0 * m;
    let mut y = x.rem_euclid(period);
    if y > m {
        y = period - y;
    }
    y.round() as usize
}

impl FrameSource for SyntheticVideo {
    fn next_frame(&mut self) -> Option<VideoFrame> {
        if let Some(n) = self.frames_left {
            if n == 0 {
                return None;
            }
            self.frames_left = Some(n - 1);
        }
        let f = self.frame(self.next_seq);
        self.next_seq += 1;
        Some(f)
    }

    fn dims(&self) -> (usize, usize) {
        (self.h, self.w)
    }

    fn remaining(&self) -> Option<usize> {
        self.frames_left
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let a = SyntheticVideo::new(64, 64, 3, 5).frame(7);
        let b = SyntheticVideo::new(64, 64, 3, 5).frame(7);
        assert_eq!(a, b);
    }

    #[test]
    fn seeds_differ() {
        let a = SyntheticVideo::new(64, 64, 3, 5).frame(0);
        let b = SyntheticVideo::new(64, 64, 3, 6).frame(0);
        assert_ne!(a, b);
    }

    #[test]
    fn frames_move() {
        let v = SyntheticVideo::new(64, 64, 2, 1);
        assert_ne!(v.frame(0), v.frame(10), "objects should move");
    }

    #[test]
    fn stream_respects_budget() {
        let mut v = SyntheticVideo::new(32, 32, 1, 0).take_frames(3);
        let mut n = 0;
        while let Some(f) = v.next_frame() {
            assert_eq!(f.seq, n);
            n += 1;
        }
        assert_eq!(n, 3);
    }

    #[test]
    fn stream_matches_pure_frame() {
        let mut v = SyntheticVideo::new(32, 32, 2, 9).take_frames(4);
        let pure = SyntheticVideo::new(32, 32, 2, 9);
        let mut t = 0;
        while let Some(f) = v.next_frame() {
            assert_eq!(f, pure.frame(t));
            t += 1;
        }
    }

    #[test]
    fn blob_rect_in_bounds() {
        let v = SyntheticVideo::new(48, 80, 4, 3);
        for i in 0..v.n_blobs() {
            for t in [0, 13, 100, 1000] {
                let r = v.blob_rect(i, t);
                assert!(r.fits(48, 80), "blob {i} at t={t}: {r:?}");
            }
        }
    }

    #[test]
    fn reflect_stays_bounded() {
        for i in 0..500 {
            let x = i as f64 * 0.73 - 100.0;
            let y = reflect(x, 10.0);
            assert!(y <= 10);
        }
        assert_eq!(reflect(123.4, 0.0), 0);
    }

    #[test]
    fn blob_intensity_visible() {
        // the brightest pixels of a frame should come from blobs (≥180)
        let v = SyntheticVideo::new(64, 64, 3, 2);
        let f = v.frame(0);
        assert!(f.pixels.iter().copied().max().unwrap() >= 180);
    }
}
