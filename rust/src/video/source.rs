//! Frame sources and standard video formats.

use crate::histogram::binning::quantize_frame;
use crate::histogram::types::BinnedImage;
use std::fmt;

/// Standard image sizes used throughout the paper's evaluation (§4.6).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Format {
    /// 256×256 (Fig. 19a smallest point)
    Sq256,
    /// 512×512 (the tuning/breakdown size)
    Sq512,
    /// 640×480 — "standard image size" of Fig. 20
    Vga,
    /// 1024×1024
    Sq1024,
    /// 1280×720
    Hd,
    /// 1920×1080
    Fhd,
    /// 2048×2048
    Sq2048,
    /// 4096×3072
    Hxga,
    /// 6400×4800
    Whsxga,
    /// 8192×8192 — the "64MB image" of §4.6
    Sq8k,
}

impl Format {
    pub const ALL: [Format; 10] = [
        Format::Sq256,
        Format::Sq512,
        Format::Vga,
        Format::Sq1024,
        Format::Hd,
        Format::Fhd,
        Format::Sq2048,
        Format::Hxga,
        Format::Whsxga,
        Format::Sq8k,
    ];

    /// (height, width) in pixels.
    pub fn dims(self) -> (usize, usize) {
        match self {
            Format::Sq256 => (256, 256),
            Format::Sq512 => (512, 512),
            Format::Vga => (480, 640),
            Format::Sq1024 => (1024, 1024),
            Format::Hd => (720, 1280),
            Format::Fhd => (1080, 1920),
            Format::Sq2048 => (2048, 2048),
            Format::Hxga => (3072, 4096),
            Format::Whsxga => (4800, 6400),
            Format::Sq8k => (8192, 8192),
        }
    }

    pub fn pixels(self) -> usize {
        let (h, w) = self.dims();
        h * w
    }

    /// Integral-histogram tensor size in bytes for `bins` (f32).
    pub fn tensor_bytes(self, bins: usize) -> usize {
        self.pixels() * bins * 4
    }
}

impl fmt::Display for Format {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let (h, w) = self.dims();
        write!(f, "{w}x{h}")
    }
}

/// One raw video frame (8-bit grayscale).
#[derive(Debug, Clone, PartialEq)]
pub struct VideoFrame {
    pub seq: usize,
    pub h: usize,
    pub w: usize,
    pub pixels: Vec<u8>,
}

impl VideoFrame {
    pub fn new(seq: usize, h: usize, w: usize, pixels: Vec<u8>) -> Self {
        assert_eq!(pixels.len(), h * w, "pixel buffer length mismatch");
        VideoFrame { seq, h, w, pixels }
    }

    /// Quantize to `bins` equal-width intensity bins.
    pub fn binned(&self, bins: usize) -> BinnedImage {
        quantize_frame(&self.pixels, self.h, self.w, bins)
    }

    /// Quantize into a recycled [`BinnedImage`] (no allocation once its
    /// capacity suffices) — used by the zero-alloc pipeline path.
    pub fn binned_into(&self, bins: usize, out: &mut BinnedImage) {
        crate::histogram::binning::quantize_frame_into(&self.pixels, self.h, self.w, bins, out);
    }

    pub fn nbytes(&self) -> usize {
        self.pixels.len()
    }
}

/// Anything the coordinator can pull frames from.
pub trait FrameSource: Send {
    /// Next frame, or `None` at end of stream.
    fn next_frame(&mut self) -> Option<VideoFrame>;
    /// (height, width) of every frame this source yields.
    fn dims(&self) -> (usize, usize);
    /// Frames remaining, if known.
    fn remaining(&self) -> Option<usize>;
}

/// Wrap a fixed list of frames as a source (tests, replays).
pub struct VecSource {
    frames: std::vec::IntoIter<VideoFrame>,
    dims: (usize, usize),
    left: usize,
}

impl VecSource {
    pub fn new(frames: Vec<VideoFrame>) -> VecSource {
        assert!(!frames.is_empty(), "empty frame list");
        let dims = (frames[0].h, frames[0].w);
        assert!(frames.iter().all(|f| (f.h, f.w) == dims), "inconsistent frame dims");
        let left = frames.len();
        VecSource { frames: frames.into_iter(), dims, left }
    }
}

impl FrameSource for VecSource {
    fn next_frame(&mut self) -> Option<VideoFrame> {
        let f = self.frames.next();
        if f.is_some() {
            self.left -= 1;
        }
        f
    }

    fn dims(&self) -> (usize, usize) {
        self.dims
    }

    fn remaining(&self) -> Option<usize> {
        Some(self.left)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn format_dims_match_paper() {
        assert_eq!(Format::Vga.dims(), (480, 640));
        assert_eq!(Format::Hd.dims(), (720, 1280));
        assert_eq!(Format::Whsxga.dims(), (4800, 6400));
        // the "64MB image": 8k×8k×1 byte = 64 MiB of pixels
        assert_eq!(Format::Sq8k.pixels(), 64 * 1024 * 1024);
    }

    #[test]
    fn tensor_bytes_32gb_case() {
        // §4.6: 64MB image × 128 bins × 4B = 32 GiB integral histogram
        assert_eq!(Format::Sq8k.tensor_bytes(128), 32 * (1usize << 30));
    }

    #[test]
    fn frame_binning() {
        let f = VideoFrame::new(0, 2, 2, vec![0, 255, 128, 7]);
        let b = f.binned(32);
        assert_eq!(b.data, vec![0, 31, 16, 0]);
    }

    #[test]
    fn vec_source_drains() {
        let frames = vec![
            VideoFrame::new(0, 2, 2, vec![0; 4]),
            VideoFrame::new(1, 2, 2, vec![1; 4]),
        ];
        let mut src = VecSource::new(frames);
        assert_eq!(src.remaining(), Some(2));
        assert_eq!(src.next_frame().unwrap().seq, 0);
        assert_eq!(src.remaining(), Some(1));
        assert_eq!(src.next_frame().unwrap().seq, 1);
        assert!(src.next_frame().is_none());
    }

    #[test]
    #[should_panic]
    fn vec_source_rejects_mixed_dims() {
        VecSource::new(vec![
            VideoFrame::new(0, 2, 2, vec![0; 4]),
            VideoFrame::new(1, 2, 3, vec![0; 6]),
        ]);
    }
}
