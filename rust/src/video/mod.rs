//! Frame sources for the real-time pipeline.
//!
//! * [`synth`] — deterministic synthetic video: textured background plus
//!   moving objects, the stand-in for the paper's image sequences
//!   (DESIGN.md §4 substitutions).  Used by every figure driver and by
//!   the end-to-end examples.
//! * [`pgm`] — binary PGM (P5) image IO so real frames can be fed
//!   through the same path.
//! * [`source`] — the `FrameSource` abstraction the coordinator pulls
//!   frames from (disk reader or generator), with standard video-format
//!   presets (VGA/HD/FHD/…, §4.6).

pub mod pgm;
pub mod source;
pub mod synth;
