//! Binary PGM (P5) image IO.
//!
//! The minimal real-image on-ramp: the paper's pipeline consumes
//! grayscale frames from disk (Algorithm 6 step 1); PGM is the simplest
//! container that real tooling (ImageMagick, ffmpeg) can produce, so a
//! directory of PGM frames can be streamed through the same pipeline as
//! the synthetic source.

use crate::video::source::{FrameSource, VideoFrame};
use anyhow::{bail, Context, Result};
use std::io::Write;
use std::path::{Path, PathBuf};

/// Write a frame as binary PGM (maxval 255).
pub fn write_pgm(path: &Path, frame: &VideoFrame) -> Result<()> {
    let f = std::fs::File::create(path).with_context(|| format!("create {}", path.display()))?;
    let mut w = std::io::BufWriter::new(f);
    write!(w, "P5\n{} {}\n255\n", frame.w, frame.h)?;
    w.write_all(&frame.pixels)?;
    Ok(())
}

/// Read a binary PGM (P5, maxval ≤ 255). Comments (`#`) are supported.
pub fn read_pgm(path: &Path) -> Result<VideoFrame> {
    let data = std::fs::read(path).with_context(|| format!("read {}", path.display()))?;
    parse_pgm(&data).with_context(|| format!("parse {}", path.display()))
}

/// Parse PGM bytes (exposed for tests).
pub fn parse_pgm(data: &[u8]) -> Result<VideoFrame> {
    let mut pos = 0usize;
    let magic = next_token(data, &mut pos)?;
    if magic != b"P5" {
        bail!("not a binary PGM (magic {:?})", String::from_utf8_lossy(magic));
    }
    let w: usize = parse_int(next_token(data, &mut pos)?)?;
    let h: usize = parse_int(next_token(data, &mut pos)?)?;
    let maxval: usize = parse_int(next_token(data, &mut pos)?)?;
    if maxval == 0 || maxval > 255 {
        bail!("unsupported maxval {maxval} (only 8-bit PGM)");
    }
    // exactly one whitespace byte separates header from raster
    pos += 1;
    let need = w * h;
    if data.len() < pos + need {
        bail!("truncated raster: need {need} bytes, have {}", data.len().saturating_sub(pos));
    }
    Ok(VideoFrame::new(0, h, w, data[pos..pos + need].to_vec()))
}

fn next_token<'a>(data: &'a [u8], pos: &mut usize) -> Result<&'a [u8]> {
    // skip whitespace and comment lines
    loop {
        while *pos < data.len() && data[*pos].is_ascii_whitespace() {
            *pos += 1;
        }
        if *pos < data.len() && data[*pos] == b'#' {
            while *pos < data.len() && data[*pos] != b'\n' {
                *pos += 1;
            }
            continue;
        }
        break;
    }
    if *pos >= data.len() {
        bail!("unexpected end of header");
    }
    let start = *pos;
    while *pos < data.len() && !data[*pos].is_ascii_whitespace() {
        *pos += 1;
    }
    Ok(&data[start..*pos])
}

fn parse_int(tok: &[u8]) -> Result<usize> {
    std::str::from_utf8(tok)?
        .parse::<usize>()
        .with_context(|| format!("invalid integer {:?}", String::from_utf8_lossy(tok)))
}

/// Stream a sorted directory of `.pgm` files as a frame source.
pub struct PgmDirSource {
    files: Vec<PathBuf>,
    next: usize,
    dims: (usize, usize),
}

impl PgmDirSource {
    pub fn open(dir: &Path) -> Result<PgmDirSource> {
        let mut files: Vec<PathBuf> = std::fs::read_dir(dir)
            .with_context(|| format!("open {}", dir.display()))?
            .filter_map(|e| e.ok().map(|e| e.path()))
            .filter(|p| p.extension().is_some_and(|e| e == "pgm"))
            .collect();
        files.sort();
        if files.is_empty() {
            bail!("no .pgm files in {}", dir.display());
        }
        let first = read_pgm(&files[0])?;
        Ok(PgmDirSource { files, next: 0, dims: (first.h, first.w) })
    }
}

impl FrameSource for PgmDirSource {
    fn next_frame(&mut self) -> Option<VideoFrame> {
        while self.next < self.files.len() {
            let path = &self.files[self.next];
            self.next += 1;
            match read_pgm(path) {
                Ok(mut f) if (f.h, f.w) == self.dims => {
                    f.seq = self.next - 1;
                    return Some(f);
                }
                _ => continue, // skip unreadable/mismatched frames
            }
        }
        None
    }

    fn dims(&self) -> (usize, usize) {
        self.dims
    }

    fn remaining(&self) -> Option<usize> {
        Some(self.files.len() - self.next)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn line_read_smoke(s: &str) -> Vec<String> {
        use std::io::BufRead;
        s.as_bytes().lines().map(|l| l.unwrap()).collect()
    }

    #[test]
    fn roundtrip_in_memory() {
        let frame = VideoFrame::new(0, 3, 2, vec![1, 2, 3, 4, 5, 6]);
        let mut buf = Vec::new();
        write!(buf, "P5\n{} {}\n255\n", frame.w, frame.h).unwrap();
        buf.extend_from_slice(&frame.pixels);
        let parsed = parse_pgm(&buf).unwrap();
        assert_eq!(parsed.pixels, frame.pixels);
        assert_eq!((parsed.h, parsed.w), (3, 2));
    }

    #[test]
    fn roundtrip_on_disk() {
        let dir = std::env::temp_dir().join(format!("inthist_pgm_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let frame = VideoFrame::new(0, 4, 5, (0..20).collect());
        let path = dir.join("f0.pgm");
        write_pgm(&path, &frame).unwrap();
        let back = read_pgm(&path).unwrap();
        assert_eq!(back.pixels, frame.pixels);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn comments_are_skipped() {
        let mut buf = b"P5\n# created by test\n2 2\n# another\n255\n".to_vec();
        buf.extend_from_slice(&[9, 8, 7, 6]);
        let f = parse_pgm(&buf).unwrap();
        assert_eq!(f.pixels, vec![9, 8, 7, 6]);
    }

    #[test]
    fn rejects_bad_magic() {
        assert!(parse_pgm(b"P2\n2 2\n255\n....").is_err());
    }

    #[test]
    fn rejects_truncated() {
        assert!(parse_pgm(b"P5\n4 4\n255\nxx").is_err());
    }

    #[test]
    fn rejects_16bit() {
        assert!(parse_pgm(b"P5\n1 1\n65535\n\0\0").is_err());
    }

    #[test]
    fn dir_source_streams_sorted() {
        let dir = std::env::temp_dir().join(format!("inthist_pgmdir_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        for i in 0..3 {
            let f = VideoFrame::new(0, 2, 2, vec![i as u8; 4]);
            write_pgm(&dir.join(format!("frame_{i:03}.pgm")), &f).unwrap();
        }
        let mut src = PgmDirSource::open(&dir).unwrap();
        assert_eq!(src.remaining(), Some(3));
        let mut vals = Vec::new();
        while let Some(f) = src.next_frame() {
            vals.push(f.pixels[0]);
        }
        assert_eq!(vals, vec![0, 1, 2]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn empty_dir_errors() {
        let dir = std::env::temp_dir().join(format!("inthist_empty_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        assert!(PgmDirSource::open(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bufread_helper() {
        assert_eq!(line_read_smoke("a\nb"), vec!["a", "b"]);
    }
}
