//! `proc-worker` — the child half of the multi-process execution
//! plane (see `inthist::proc`).  Speaks the length-prefixed control
//! protocol on stdin/stdout; bulk tensors ride `TensorStore` spill
//! files named in each assignment.  Never launched by hand: the
//! `ProcSupervisor` spawns, monitors, kills and respawns these.
//!
//! Flags (hand-rolled `--key value`, matching the main CLI):
//!   --calibrate 0|1       run the startup microbench (default 1)
//!   --engine-workers N    ScanEngine thread budget (default 1)
//!   --heartbeat-ms N      liveness tick interval (default 200)
//!   --boot-delay-ms N     chaos hook: sleep before any output
//!                         (default 0; heartbeat-deferral tests)
//!   --selftest            protocol round-trip smoke, then exit 0
//!                         (CI hook; no supervisor needed)

use inthist::proc::protocol::{ProcMsg, WireAssign, NO_SLOT, PLANE_SHM};
use inthist::proc::worker::{run, WorkerConfig};
use std::time::Duration;

fn usage() -> ! {
    eprintln!(
        "proc-worker: child process of the inthist multi-process plane\n\
         usage: proc-worker [--calibrate 0|1] [--engine-workers N] \
         [--heartbeat-ms N] [--boot-delay-ms N] [--selftest]"
    );
    std::process::exit(2)
}

/// Round-trip every message shape through encode/decode — a cheap CI
/// smoke that the built binary speaks the protocol it was built with.
fn selftest() -> Result<(), String> {
    let msgs = [
        ProcMsg::AssignShard(WireAssign {
            frame_id: 7,
            shard_id: 3,
            bin0: 8,
            nbins: 8,
            row0: 32,
            nrows: 16,
            img_h: 64,
            img_w: 48,
            img_path: "/tmp/img.bin".into(),
            out_path: "/tmp/out.bin".into(),
            plane: PLANE_SHM,
            slot: 2,
            slot_off: 2 * (3072 + 98304),
            ring_bytes: 4 * (3072 + 98304),
            ring_path: "/dev/shm/inthist-selftest.ring".into(),
        }),
        ProcMsg::ShardDone {
            frame_id: 7,
            shard_id: 3,
            kernel_time_us: 120,
            checksum: 0xDEAD,
            slot: 2,
        },
        ProcMsg::ShardDone {
            frame_id: 7,
            shard_id: 4,
            kernel_time_us: 120,
            checksum: 0xBEEF,
            slot: NO_SLOT,
        },
        ProcMsg::ShardFailed {
            frame_id: 7,
            shard_id: 3,
            panicked: true,
            reason: "selftest".into(),
        },
        ProcMsg::Heartbeat { seq: 42 },
        ProcMsg::Shutdown,
    ];
    for msg in &msgs {
        let wire = msg.encode();
        let (back, used) = ProcMsg::decode(&wire).map_err(|e| format!("decode: {e}"))?;
        if used != wire.len() || &back != msg {
            return Err(format!("round-trip mismatch for {msg:?}"));
        }
    }
    Ok(())
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let mut cfg = WorkerConfig::default();
    let mut i = 0;
    while i < argv.len() {
        match argv[i].as_str() {
            "--selftest" => match selftest() {
                Ok(()) => {
                    println!("proc-worker selftest ok");
                    return;
                }
                Err(e) => {
                    eprintln!("proc-worker selftest FAILED: {e}");
                    std::process::exit(1);
                }
            },
            "--calibrate" => {
                let v = argv.get(i + 1).unwrap_or_else(|| usage());
                cfg.calibrate = match v.as_str() {
                    "0" | "false" => false,
                    "1" | "true" => true,
                    _ => usage(),
                };
                i += 2;
            }
            "--engine-workers" => {
                let v = argv.get(i + 1).unwrap_or_else(|| usage());
                cfg.engine_workers = v.parse().unwrap_or_else(|_| usage());
                i += 2;
            }
            "--heartbeat-ms" => {
                let v = argv.get(i + 1).unwrap_or_else(|| usage());
                let ms: u64 = v.parse().unwrap_or_else(|_| usage());
                cfg.heartbeat = Duration::from_millis(ms.max(1));
                i += 2;
            }
            "--boot-delay-ms" => {
                let v = argv.get(i + 1).unwrap_or_else(|| usage());
                let ms: u64 = v.parse().unwrap_or_else(|_| usage());
                cfg.boot_delay = Duration::from_millis(ms);
                i += 2;
            }
            "--help" | "-h" => usage(),
            _ => usage(),
        }
    }
    if let Err(e) = run(cfg) {
        eprintln!("proc-worker: {e:#}");
        std::process::exit(1);
    }
}
