//! `proc-worker` — the child half of the multi-process execution
//! plane (see `inthist::proc`).  Speaks the length-prefixed control
//! protocol on stdin/stdout when spawned by a local `ProcSupervisor`,
//! or serves the same protocol over TCP in `--listen` mode so a
//! supervisor on another host can attach it as a remote node (bulk
//! tensors then ride the in-band chunked stream plane instead of
//! spill files).
//!
//! Flags (hand-rolled `--key value`, matching the main CLI):
//!   --calibrate 0|1       run the startup microbench (default 1)
//!   --engine-workers N    ScanEngine thread budget (default 1)
//!   --heartbeat-ms N      liveness tick interval (default 200)
//!   --boot-delay-ms N     chaos hook: sleep before any output
//!                         (default 0; heartbeat-deferral tests)
//!   --listen ADDR         serve remote supervisors on ADDR (e.g.
//!                         127.0.0.1:0); prints `LISTEN <addr>` on
//!                         stdout once bound, then accepts any number
//!                         of connections, one serve loop each
//!   --selftest            protocol round-trip smoke, then exit 0
//!                         (CI hook; no supervisor needed); with
//!                         --listen, also runs a loopback TCP
//!                         handshake + stream-plane round-trip

use inthist::proc::protocol::{
    checksum_bytes, ProcMsg, WireAssign, CAPS_ALL, CHUNK_DATA_MAX, NO_SLOT, PLANE_SHM,
    PLANE_STREAM, PROTOCOL_VERSION,
};
use inthist::proc::worker::{run, serve_conn, WorkerConfig};
use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::time::Duration;

fn usage() -> ! {
    eprintln!(
        "proc-worker: child process of the inthist multi-process plane\n\
         usage: proc-worker [--calibrate 0|1] [--engine-workers N] \
         [--heartbeat-ms N] [--boot-delay-ms N] [--listen ADDR] [--selftest]"
    );
    std::process::exit(2)
}

/// Round-trip every message shape through encode/decode — a cheap CI
/// smoke that the built binary speaks the protocol it was built with.
fn selftest() -> Result<(), String> {
    let msgs = [
        ProcMsg::AssignShard(WireAssign {
            frame_id: 7,
            shard_id: 3,
            bin0: 8,
            nbins: 8,
            row0: 32,
            nrows: 16,
            img_h: 64,
            img_w: 48,
            img_path: "/tmp/img.bin".into(),
            out_path: "/tmp/out.bin".into(),
            plane: PLANE_SHM,
            slot: 2,
            slot_off: 2 * (3072 + 98304),
            ring_bytes: 4 * (3072 + 98304),
            ring_path: "/dev/shm/inthist-selftest.ring".into(),
            deadline_us: 250_000,
            strip_checksum: 0,
        }),
        ProcMsg::AssignShard(WireAssign {
            frame_id: 8,
            shard_id: 0,
            bin0: 0,
            nbins: 4,
            row0: 0,
            nrows: 16,
            img_h: 64,
            img_w: 48,
            img_path: String::new(),
            out_path: String::new(),
            plane: PLANE_STREAM,
            slot: 0,
            slot_off: 0,
            ring_bytes: 0,
            ring_path: String::new(),
            deadline_us: 0,
            strip_checksum: 0xBEEF_CAFE,
        }),
        ProcMsg::ShardDone {
            frame_id: 7,
            shard_id: 3,
            kernel_time_us: 120,
            checksum: 0xDEAD,
            slot: 2,
        },
        ProcMsg::ShardDone {
            frame_id: 7,
            shard_id: 4,
            kernel_time_us: 120,
            checksum: 0xBEEF,
            slot: NO_SLOT,
        },
        ProcMsg::ShardFailed {
            frame_id: 7,
            shard_id: 3,
            panicked: true,
            deadline: false,
            reason: "selftest".into(),
        },
        ProcMsg::ShardFailed {
            frame_id: 7,
            shard_id: 5,
            panicked: false,
            deadline: true,
            reason: "deadline budget expired".into(),
        },
        ProcMsg::Chunk {
            frame_id: 8,
            shard_id: 0,
            dir: 1,
            offset: 4096,
            total: 8192,
            data: vec![0xA5; 512],
        },
        ProcMsg::Hello { version: PROTOCOL_VERSION, caps: CAPS_ALL, tag: "selftest".into() },
        ProcMsg::Heartbeat { seq: 42 },
        ProcMsg::Shutdown,
    ];
    for msg in &msgs {
        let wire = msg.encode();
        let (back, used) = ProcMsg::decode(&wire).map_err(|e| format!("decode: {e}"))?;
        if used != wire.len() || &back != msg {
            return Err(format!("round-trip mismatch for {msg:?}"));
        }
    }
    Ok(())
}

/// Loopback smoke of the remote path: serve one connection from a
/// thread of this very process, drive the client side by hand —
/// handshake, a stream-plane assignment whose strip arrives as two
/// chunks, then verify the partial comes back chunked, checksummed
/// and complete, followed by `ShardDone`.  Exercises the exact code a
/// remote supervisor hits, with zero network assumptions beyond
/// loopback.
fn listen_selftest(cfg: &WorkerConfig) -> Result<(), String> {
    let listener =
        TcpListener::bind("127.0.0.1:0").map_err(|e| format!("bind loopback: {e}"))?;
    let addr = listener.local_addr().map_err(|e| format!("local addr: {e}"))?;
    let serve_cfg = WorkerConfig { calibrate: false, ..cfg.clone() };
    let server = std::thread::spawn(move || {
        if let Ok((stream, _)) = listener.accept() {
            let _ = serve_conn(stream, &serve_cfg);
        }
    });
    let stream = TcpStream::connect(addr).map_err(|e| format!("connect loopback: {e}"))?;
    stream.set_nodelay(true).ok();
    let mut r = stream.try_clone().map_err(|e| format!("clone stream: {e}"))?;
    // Worker speaks Hello first.
    match ProcMsg::read_from(&mut r) {
        Ok(Some(ProcMsg::Hello { caps, .. })) if caps & CAPS_ALL == CAPS_ALL => {}
        other => return Err(format!("expected capable Hello, got {other:?}")),
    }
    let mut w = &stream;
    ProcMsg::Hello { version: PROTOCOL_VERSION, caps: CAPS_ALL, tag: "selftest-sup".into() }
        .write_to(&mut w)
        .map_err(|e| format!("handshake reply: {e}"))?;
    // One 8-row × 6-col strip, 3 bins, pushed as two chunks.
    let (nrows, width, nbins) = (8usize, 6usize, 3usize);
    let strip: Vec<u8> = (0..nrows * width)
        .flat_map(|i| ((i % nbins) as f32).to_le_bytes())
        .collect();
    let assign = WireAssign {
        frame_id: 1,
        shard_id: 0,
        bin0: 0,
        nbins: nbins as u64,
        row0: 0,
        nrows: nrows as u64,
        img_h: nrows as u64,
        img_w: width as u64,
        img_path: String::new(),
        out_path: String::new(),
        plane: PLANE_STREAM,
        slot: 0,
        slot_off: 0,
        ring_bytes: 0,
        ring_path: String::new(),
        deadline_us: 0,
        strip_checksum: checksum_bytes(&strip),
    };
    ProcMsg::AssignShard(assign).write_to(&mut w).map_err(|e| format!("send assign: {e}"))?;
    let split = strip.len() / 2;
    for (off, part) in [(0usize, &strip[..split]), (split, &strip[split..])] {
        ProcMsg::Chunk {
            frame_id: 1,
            shard_id: 0,
            dir: 0,
            offset: off as u64,
            total: strip.len() as u64,
            data: part.to_vec(),
        }
        .write_to(&mut w)
        .map_err(|e| format!("send chunk: {e}"))?;
    }
    w.flush().ok();
    // Collect the chunked partial + ShardDone, skipping liveness noise.
    let expected = nbins * nrows * width * 4;
    let mut partial = Vec::with_capacity(expected);
    loop {
        match ProcMsg::read_from(&mut r) {
            Ok(Some(ProcMsg::Heartbeat { .. })) | Ok(Some(ProcMsg::CalibrationReport { .. })) => {}
            Ok(Some(ProcMsg::Chunk { dir: 1, offset, data, total, .. })) => {
                if offset as usize != partial.len() || total as usize != expected {
                    return Err(format!(
                        "partial chunk out of order: offset {offset}, have {}, total {total}",
                        partial.len()
                    ));
                }
                if data.len() > CHUNK_DATA_MAX {
                    return Err(format!("oversized chunk: {}", data.len()));
                }
                partial.extend_from_slice(&data);
            }
            Ok(Some(ProcMsg::ShardDone { frame_id: 1, shard_id: 0, .. })) => break,
            other => return Err(format!("unexpected frame: {other:?}")),
        }
    }
    if partial.len() != expected {
        return Err(format!("partial truncated: {} of {expected} bytes", partial.len()));
    }
    ProcMsg::Shutdown.write_to(&mut w).map_err(|e| format!("send shutdown: {e}"))?;
    w.flush().ok();
    drop(stream);
    drop(r);
    server.join().map_err(|_| "serve thread panicked".to_string())?;
    Ok(())
}

/// Bind `addr`, announce the bound address on stdout (so a script can
/// pass `:0` and read the port back), then serve every connection —
/// each gets its own serve loop and thread, so a supervisor
/// reconnecting after a drop just works.
fn listen(addr: &str, cfg: WorkerConfig) -> ! {
    let listener = match TcpListener::bind(addr) {
        Ok(l) => l,
        Err(e) => {
            eprintln!("proc-worker: bind {addr}: {e}");
            std::process::exit(1);
        }
    };
    let bound = listener.local_addr().map(|a| a.to_string()).unwrap_or_else(|_| addr.into());
    println!("LISTEN {bound}");
    std::io::stdout().flush().ok();
    loop {
        match listener.accept() {
            Ok((stream, peer)) => {
                let conn_cfg = cfg.clone();
                let tag = format!("inthist-proc-conn-{peer}");
                let spawned = std::thread::Builder::new().name(tag).spawn(move || {
                    if let Err(e) = serve_conn(stream, &conn_cfg) {
                        eprintln!("proc-worker: connection {peer}: {e:#}");
                    }
                });
                if let Err(e) = spawned {
                    eprintln!("proc-worker: spawn connection thread: {e}");
                }
            }
            Err(e) => {
                eprintln!("proc-worker: accept: {e}");
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let mut cfg = WorkerConfig::default();
    let mut listen_addr: Option<String> = None;
    let mut run_selftest = false;
    let mut i = 0;
    while i < argv.len() {
        match argv[i].as_str() {
            "--selftest" => {
                run_selftest = true;
                i += 1;
            }
            "--listen" => {
                let v = argv.get(i + 1).unwrap_or_else(|| usage());
                listen_addr = Some(v.clone());
                i += 2;
            }
            "--calibrate" => {
                let v = argv.get(i + 1).unwrap_or_else(|| usage());
                cfg.calibrate = match v.as_str() {
                    "0" | "false" => false,
                    "1" | "true" => true,
                    _ => usage(),
                };
                i += 2;
            }
            "--engine-workers" => {
                let v = argv.get(i + 1).unwrap_or_else(|| usage());
                cfg.engine_workers = v.parse().unwrap_or_else(|_| usage());
                i += 2;
            }
            "--heartbeat-ms" => {
                let v = argv.get(i + 1).unwrap_or_else(|| usage());
                let ms: u64 = v.parse().unwrap_or_else(|_| usage());
                cfg.heartbeat = Duration::from_millis(ms.max(1));
                i += 2;
            }
            "--boot-delay-ms" => {
                let v = argv.get(i + 1).unwrap_or_else(|| usage());
                let ms: u64 = v.parse().unwrap_or_else(|_| usage());
                cfg.boot_delay = Duration::from_millis(ms);
                i += 2;
            }
            "--help" | "-h" => usage(),
            _ => usage(),
        }
    }
    if run_selftest {
        if let Err(e) = selftest() {
            eprintln!("proc-worker selftest FAILED: {e}");
            std::process::exit(1);
        }
        if listen_addr.is_some() {
            if let Err(e) = listen_selftest(&cfg) {
                eprintln!("proc-worker listen selftest FAILED: {e}");
                std::process::exit(1);
            }
            println!("proc-worker selftest ok (protocol + loopback stream plane)");
        } else {
            println!("proc-worker selftest ok");
        }
        return;
    }
    if let Some(addr) = listen_addr {
        listen(&addr, cfg);
    }
    if let Err(e) = run(cfg) {
        eprintln!("proc-worker: {e:#}");
        std::process::exit(1);
    }
}
