//! # inthist — fast integral histograms for real-time video analytics
//!
//! A production-shaped reproduction of Poostchi et al., *"Fast Integral
//! Histogram Computations on GPU for Real-Time Video Analytics"* (2017),
//! built as a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 1/2 (build time, Python)** — the paper's four kernel
//!   strategies (CW-B, CW-STS, CW-TiS, WF-TiS) written as Pallas kernels
//!   and composed into JAX graphs, AOT-lowered to HLO text in
//!   `artifacts/`.
//! * **Layer 3 (this crate)** — the serving runtime: a PJRT executor that
//!   loads the artifacts ([`runtime`]), a dual-buffered frame pipeline and
//!   a multi-device bin task queue ([`coordinator`]), the planned
//!   zero-allocation wavefront `ScanEngine` plus the CPU baselines and
//!   region-query engine ([`histogram`]), the sharded out-of-core
//!   execution subsystem — shard planner, interleaved executor,
//!   tagged reassembly, spill-backed tensor store ([`shard`]) — the
//!   multi-process execution plane with supervised, process-isolated
//!   shard workers ([`proc`]) — a PCIe
//!   transfer simulator ([`simulator`]), synthetic video sources
//!   ([`video`]) and histogram-based analytics built on top
//!   ([`analytics`]).
//!
//! Python never runs on the request path: once `make artifacts` has been
//! run, the Rust binary is self-contained.
//!
//! ## Quick start
//!
//! ```no_run
//! use inthist::prelude::*;
//!
//! let mut engine = Engine::from_artifact_dir("artifacts")?;
//! let frame = inthist::video::synth::SyntheticVideo::new(512, 512, 4, 7).frame(0);
//! let ih = engine.compute(Strategy::WfTis, &frame.binned(32))?;
//! let hist = ih.region(Rect::new(100, 100, 200, 200));
//! # anyhow::Result::<()>::Ok(())
//! ```
//!
//! See `examples/` for the end-to-end drivers and `DESIGN.md` for the
//! paper-to-module map.

pub mod analytics;
pub mod coordinator;
pub mod fault;
pub mod figures;
pub mod histogram;
pub mod proc;
pub mod runtime;
pub mod shard;
pub mod simulator;
pub mod tune;
pub mod util;
pub mod video;

/// Convenient re-exports for downstream users.
pub mod prelude {
    pub use crate::coordinator::frame_pool::{FramePool, PooledTensor, PoolStats};
    pub use crate::coordinator::pipeline::{
        CpuPipeline, CpuPipelineConfig, Pipeline, PipelineConfig, PipelineReport,
    };
    pub use crate::coordinator::metrics::LatencySummary;
    pub use crate::coordinator::router::{Engine, EngineConfig};
    pub use crate::coordinator::server::{
        AnalyticsEvent, Server, ServerConfig, ServerSnapshot, Session, SessionSnapshot,
    };
    pub use crate::coordinator::task_queue::{BinTaskQueue, TaskQueueConfig};
    pub use crate::histogram::engine::{
        Plan, Planner, ScanEngine, Schedule, WorkerPool, WorkerPoolStats,
    };
    pub use crate::histogram::region::Rect;
    pub use crate::histogram::types::{IntegralHistogram, Strategy};
    pub use crate::fault::{FaultAction, FaultInjector, FaultSite, FaultSpec, FaultStats};
    pub use crate::proc::{
        DataPlane, PlacementMap, ProcMsg, ProcPoolConfig, ProcStats, ProcSupervisor,
        ProtocolError,
    };
    pub use crate::runtime::artifact::{ArtifactManifest, ArtifactMeta};
    pub use crate::runtime::client::HistogramExecutor;
    pub use crate::shard::{
        FrameTicket, ShardCost, ShardError, ShardExecutor, ShardExecutorConfig, ShardPlan,
        ShardPlanner, ShardPolicy, ShardReport, TensorStore,
    };
    pub use crate::simulator::pcie::PcieModel;
    pub use crate::tune::{Calibrator, CostSnapshot, TunedPlanner, TuneStats};
    pub use crate::video::source::{FrameSource, VideoFrame};
}
