//! Artifact manifest: what `python/compile/aot.py` lowered, and how to
//! pick the right module for a request.
//!
//! The manifest is the only contract between the build-time Python layer
//! and the Rust runtime.  Each entry records the strategy, the true and
//! padded image geometry (§3.4 padding rule), bin count, tile size and
//! the I/O signature of the lowered HLO module.

use crate::fault::{corrupt_bytes, FaultAction, FaultInjector, FaultSite};
use crate::histogram::types::Strategy;
use crate::util::json::{self, Json};
use anyhow::{anyhow, bail, Context, Result};
use std::path::{Path, PathBuf};

/// Dtype of a tensor in an artifact signature.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    I32,
    F32,
}

impl Dtype {
    fn parse(s: &str) -> Result<Dtype> {
        match s {
            "i32" => Ok(Dtype::I32),
            "f32" => Ok(Dtype::F32),
            other => bail!("unknown dtype '{other}'"),
        }
    }
}

/// One input/output tensor of a lowered module.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub dtype: Dtype,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// What kind of graph an artifact holds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArtifactKind {
    /// image → integral histogram (one of the four strategies)
    Strategy,
    /// image → one-hot planes (the Fig. 8 "init" slice)
    Init,
    /// (ih, rects) → per-rect histograms (Eq. 2 batched)
    Query,
    /// (image, rects) → (ih, histograms) — the fused serving graph
    Serve,
}

/// Metadata for one lowered HLO module.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactMeta {
    pub name: String,
    pub kind: ArtifactKind,
    pub strategy: String,
    /// True (pre-padding) image dims.
    pub height: usize,
    pub width: usize,
    /// Padded dims the module actually consumes (multiples of tile).
    pub padded_h: usize,
    pub padded_w: usize,
    pub bins: usize,
    pub tile: usize,
    pub n_rects: usize,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

impl ArtifactMeta {
    /// Parsed [`Strategy`] if this is a strategy artifact.
    pub fn strategy_id(&self) -> Option<Strategy> {
        self.strategy.parse().ok()
    }

    /// Bytes of the output integral-histogram tensor (what moves D2H).
    pub fn tensor_bytes(&self) -> usize {
        self.bins * self.padded_h * self.padded_w * 4
    }

    fn from_json(v: &Json) -> Result<ArtifactMeta> {
        let s = |k: &str| -> Result<String> {
            Ok(v.get(k)
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("manifest entry missing string '{k}'"))?
                .to_string())
        };
        let n = |k: &str| -> Result<usize> {
            v.get(k).and_then(Json::as_usize).ok_or_else(|| anyhow!("missing integer '{k}'"))
        };
        let kind = match s("kind")?.as_str() {
            "strategy" => ArtifactKind::Strategy,
            "init" => ArtifactKind::Init,
            "query" => ArtifactKind::Query,
            "serve" => ArtifactKind::Serve,
            other => bail!("unknown artifact kind '{other}'"),
        };
        let tensors = |k: &str| -> Result<Vec<TensorSpec>> {
            v.get(k)
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("missing array '{k}'"))?
                .iter()
                .map(|t| {
                    Ok(TensorSpec {
                        name: t
                            .get("name")
                            .and_then(Json::as_str)
                            .ok_or_else(|| anyhow!("tensor missing name"))?
                            .to_string(),
                        dtype: Dtype::parse(
                            t.get("dtype").and_then(Json::as_str).unwrap_or("f32"),
                        )?,
                        shape: t
                            .get("shape")
                            .and_then(Json::as_arr)
                            .ok_or_else(|| anyhow!("tensor missing shape"))?
                            .iter()
                            .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
                            .collect::<Result<_>>()?,
                    })
                })
                .collect()
        };
        Ok(ArtifactMeta {
            name: s("name")?,
            kind,
            strategy: s("strategy")?,
            height: n("height")?,
            width: n("width")?,
            padded_h: n("padded_h")?,
            padded_w: n("padded_w")?,
            bins: n("bins")?,
            tile: n("tile")?,
            n_rects: n("n_rects").unwrap_or(0),
            file: s("file")?,
            inputs: tensors("inputs")?,
            outputs: tensors("outputs")?,
        })
    }
}

/// The full manifest: every artifact in an `artifacts/` directory.
#[derive(Debug, Clone)]
pub struct ArtifactManifest {
    pub dir: PathBuf,
    pub profile: String,
    pub artifacts: Vec<ArtifactMeta>,
}

impl ArtifactManifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<ArtifactManifest> {
        Self::load_with_faults(dir, None)
    }

    /// [`Self::load`] with the artifact-load chaos probe armed: the
    /// disk read consults [`FaultSite::SpillRead`], so a seeded
    /// schedule can hand the parser a corrupted or torn (truncated)
    /// manifest — the same failure classes the spill store's read path
    /// probes.  The parse layer must then reject the bytes typed, never
    /// serve from them silently.  Inert (identical to [`Self::load`])
    /// without `--features fault-injection`.
    pub fn load_with_faults(
        dir: impl AsRef<Path>,
        faults: Option<&FaultInjector>,
    ) -> Result<ArtifactManifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {} (run `make artifacts` first)", path.display()))?;
        let text = match faults.and_then(|f| f.decide(FaultSite::SpillRead)) {
            Some(FaultAction::Corrupt) => {
                let mut bytes = text.into_bytes();
                corrupt_bytes(&mut bytes, 0xA871_FAC7);
                String::from_utf8_lossy(&bytes).into_owned()
            }
            // A torn file read back: only a prefix survived (byte-wise
            // — a torn disk page does not respect char boundaries).
            Some(FaultAction::ShortWrite) => {
                String::from_utf8_lossy(&text.as_bytes()[..text.len() / 2]).into_owned()
            }
            _ => text,
        };
        Self::parse(&text, dir)
    }

    /// Parse manifest text (exposed for tests).
    pub fn parse(text: &str, dir: PathBuf) -> Result<ArtifactManifest> {
        let root = json::parse(text).context("manifest.json is not valid JSON")?;
        let profile = root
            .get("profile")
            .and_then(Json::as_str)
            .unwrap_or("unknown")
            .to_string();
        let artifacts = root
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing 'artifacts' array"))?
            .iter()
            .map(ArtifactMeta::from_json)
            .collect::<Result<Vec<_>>>()?;
        Ok(ArtifactManifest { dir, profile, artifacts })
    }

    /// Absolute path of an artifact's HLO text file.
    pub fn path_of(&self, meta: &ArtifactMeta) -> PathBuf {
        self.dir.join(&meta.file)
    }

    /// Find the artifact for an exact (strategy, true-h, true-w, bins)
    /// request, preferring the largest tile (the tuned configuration).
    pub fn find_strategy(
        &self,
        strategy: Strategy,
        h: usize,
        w: usize,
        bins: usize,
    ) -> Option<&ArtifactMeta> {
        self.artifacts
            .iter()
            .filter(|a| {
                a.kind == ArtifactKind::Strategy
                    && a.strategy == strategy.artifact_prefix()
                    && a.height == h
                    && a.width == w
                    && a.bins == bins
            })
            .max_by_key(|a| a.tile)
    }

    /// Find a strategy artifact with an explicit tile size (tuning sweeps).
    pub fn find_strategy_tile(
        &self,
        strategy: Strategy,
        h: usize,
        w: usize,
        bins: usize,
        tile: usize,
    ) -> Option<&ArtifactMeta> {
        self.artifacts.iter().find(|a| {
            a.kind == ArtifactKind::Strategy
                && a.strategy == strategy.artifact_prefix()
                && a.height == h
                && a.width == w
                && a.bins == bins
                && a.tile == tile
        })
    }

    /// All strategy artifacts, sorted by (strategy, pixels, bins).
    pub fn strategies(&self) -> Vec<&ArtifactMeta> {
        let mut v: Vec<_> =
            self.artifacts.iter().filter(|a| a.kind == ArtifactKind::Strategy).collect();
        v.sort_by_key(|a| (a.strategy.clone(), a.height * a.width, a.bins, a.tile));
        v
    }

    pub fn find_named(&self, name: &str) -> Option<&ArtifactMeta> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    pub fn find_kind(&self, kind: ArtifactKind) -> Vec<&ArtifactMeta> {
        self.artifacts.iter().filter(|a| a.kind == kind).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "profile": "quick",
      "artifacts": [
        {"name": "wf_tis_64x64_b8_t32", "kind": "strategy", "strategy": "wf_tis",
         "height": 64, "width": 64, "padded_h": 64, "padded_w": 64,
         "bins": 8, "tile": 32, "n_rects": 0, "file": "wf_tis_64x64_b8_t32.hlo.txt",
         "inputs": [{"name": "image", "dtype": "i32", "shape": [64, 64]}],
         "outputs": [{"name": "ih", "dtype": "f32", "shape": [8, 64, 64]}]},
        {"name": "wf_tis_64x64_b8_t16", "kind": "strategy", "strategy": "wf_tis",
         "height": 64, "width": 64, "padded_h": 64, "padded_w": 64,
         "bins": 8, "tile": 16, "n_rects": 0, "file": "wf_tis_64x64_b8_t16.hlo.txt",
         "inputs": [{"name": "image", "dtype": "i32", "shape": [64, 64]}],
         "outputs": [{"name": "ih", "dtype": "f32", "shape": [8, 64, 64]}]},
        {"name": "serve_64", "kind": "serve", "strategy": "wf_tis_with_query",
         "height": 64, "width": 64, "padded_h": 64, "padded_w": 64,
         "bins": 8, "tile": 32, "n_rects": 16, "file": "serve_64.hlo.txt",
         "inputs": [{"name": "image", "dtype": "i32", "shape": [64, 64]},
                    {"name": "rects", "dtype": "i32", "shape": [16, 4]}],
         "outputs": [{"name": "ih", "dtype": "f32", "shape": [8, 64, 64]},
                     {"name": "hists", "dtype": "f32", "shape": [16, 8]}]}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = ArtifactManifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
        assert_eq!(m.profile, "quick");
        assert_eq!(m.artifacts.len(), 3);
        let a = &m.artifacts[0];
        assert_eq!(a.kind, ArtifactKind::Strategy);
        assert_eq!(a.strategy_id(), Some(Strategy::WfTis));
        assert_eq!(a.inputs[0].dtype, Dtype::I32);
        assert_eq!(a.outputs[0].elements(), 8 * 64 * 64);
        assert_eq!(a.tensor_bytes(), 8 * 64 * 64 * 4);
    }

    #[test]
    fn find_prefers_larger_tile() {
        let m = ArtifactManifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
        let a = m.find_strategy(Strategy::WfTis, 64, 64, 8).unwrap();
        assert_eq!(a.tile, 32);
        let b = m.find_strategy_tile(Strategy::WfTis, 64, 64, 8, 16).unwrap();
        assert_eq!(b.tile, 16);
        assert!(m.find_strategy(Strategy::CwB, 64, 64, 8).is_none());
    }

    #[test]
    fn find_kind_and_named() {
        let m = ArtifactManifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
        assert_eq!(m.find_kind(ArtifactKind::Serve).len(), 1);
        assert!(m.find_named("serve_64").is_some());
        assert!(m.find_named("nope").is_none());
        assert_eq!(m.path_of(&m.artifacts[2]), PathBuf::from("/tmp/a/serve_64.hlo.txt"));
    }

    #[test]
    fn rejects_bad_manifest() {
        assert!(ArtifactManifest::parse("{}", PathBuf::new()).is_err());
        assert!(ArtifactManifest::parse("not json", PathBuf::new()).is_err());
        let missing = r#"{"artifacts": [{"name": "x"}]}"#;
        assert!(ArtifactManifest::parse(missing, PathBuf::new()).is_err());
    }

    /// The artifact load path's `SpillRead` probe: a corrupted disk
    /// read must surface as a typed parse error or a visibly different
    /// manifest — never a silent clean load — and the probe budget
    /// makes the very next load clean again.
    #[cfg(feature = "fault-injection")]
    #[test]
    fn armed_load_probe_corrupts_the_manifest_read() {
        use crate::fault::FaultSpec;
        let dir = std::env::temp_dir().join(format!("ih_artifact_fault_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), SAMPLE).unwrap();
        let clean = ArtifactManifest::load(&dir).expect("clean load");
        let fi = FaultInjector::new(
            7,
            FaultSpec { spill_corrupt_read: 1.0, max_per_site: 1, ..FaultSpec::default() },
        );
        match ArtifactManifest::load_with_faults(&dir, Some(&fi)) {
            Err(_) => {} // typed rejection — the preferred outcome
            Ok(m) => assert!(
                m.profile != clean.profile || m.artifacts != clean.artifacts,
                "a corrupted manifest must not come back identical to the clean one"
            ),
        }
        assert_eq!(fi.stats().corrupt_reads, 1, "the probe fired exactly once");
        let again = ArtifactManifest::load_with_faults(&dir, Some(&fi)).expect("budget spent");
        assert_eq!(again.artifacts.len(), clean.artifacts.len());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn strategies_sorted() {
        let m = ArtifactManifest::parse(SAMPLE, PathBuf::from("/tmp")).unwrap();
        let s = m.strategies();
        assert_eq!(s.len(), 2);
        assert!(s[0].tile <= s[1].tile);
    }
}
